"""Paper Table 4: baseline solvers (CD, SCD, FISTA-reg, FISTA-const) over
the full regularization path — time, iterations, dot products, mean active
features."""
from __future__ import annotations

import time

import jax

from benchmarks.common import CSV, CI_DATASETS, SCALE, load_dataset, path_grids
from repro.core import CDConfig, FISTAConfig, path as path_lib

N_POINTS = 20 if SCALE == "ci" else 100


def run(csv: CSV, datasets=None):
    datasets = datasets or CI_DATASETS
    for name in datasets:
        Xt, y, ds = load_dataset(name)
        p, m = Xt.shape
        lams, deltas = path_grids(Xt, y, N_POINTS)

        solvers = {
            "cd": lambda: path_lib.cd_path(
                Xt, y, lams, CDConfig(lam=0.0, max_sweeps=200, tol=1e-3)
            ),
            "scd": lambda: path_lib.cd_path(
                Xt, y, lams, CDConfig(lam=0.0, max_sweeps=200, tol=1e-3, stochastic=True)
            ),
            "fista_reg": lambda: path_lib.fista_path(
                Xt, y, lams, FISTAConfig(max_iters=500, tol=1e-3)
            ),
            "fista_const": lambda: path_lib.fista_path(
                Xt, y, deltas, FISTAConfig(constrained=True, max_iters=500, tol=1e-3)
            ),
        }
        for sname, fn in solvers.items():
            t0 = time.perf_counter()
            res = fn()
            dt = time.perf_counter() - t0
            csv.emit(
                f"table4/{name}/{sname}",
                dt * 1e6 / N_POINTS,
                f"m={m};p={p};iters={res.total_iters};dots={res.total_dots};"
                f"mean_active={res.mean_active:.1f};total_s={dt:.2f}",
            )


if __name__ == "__main__":
    run(CSV())
