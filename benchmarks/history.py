"""Append-only perf trajectory: BENCH_*.json runs -> BENCH_history.jsonl.

Every ``BenchJSON.write()`` appends its full payload as one JSON line to
``BENCH_history.jsonl`` (same output dir, override with
``$REPRO_BENCH_HISTORY_PATH``, disable with ``$REPRO_BENCH_HISTORY=0``),
keyed by the ``bench_provenance()`` git sha the payload already carries.
One-shot BENCH snapshots answer "how fast is it now"; the history file
is what answers "did PR N make the hot loop slower" — the bench gate
(``scripts/bench_gate.py``) reads its tail as the rolling baseline.

JSONL on purpose: append is atomic-enough under CI's one-writer-per-run
model, partial trailing lines (a killed run) are skipped on load, and
the file diffs/merges linearly across PRs.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Optional

HISTORY_FILENAME = "BENCH_history.jsonl"


def history_enabled() -> bool:
    return os.environ.get("REPRO_BENCH_HISTORY", "1") not in ("0", "false", "")


def history_path(out_dir: Optional[str] = None) -> str:
    explicit = os.environ.get("REPRO_BENCH_HISTORY_PATH")
    if explicit:
        return explicit
    if out_dir is None:
        out_dir = os.environ.get("REPRO_BENCH_JSON_DIR", ".")
    return os.path.join(out_dir, HISTORY_FILENAME)


def append_run(payload: dict, source: str, path: Optional[str] = None) -> str:
    """Append one BenchJSON payload as a history line. ``source`` is the
    artifact filename (BENCH_kernels.json, ...) so one history file holds
    every benchmark family. Returns the history path."""
    path = history_path() if path is None else path
    line = {"source": source, **payload}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "at") as fh:
        fh.write(json.dumps(line, separators=(",", ":")) + "\n")
    return path


def load_history(path: Optional[str] = None,
                 source: Optional[str] = None) -> List[dict]:
    """All history lines, oldest first; malformed lines are skipped WITH
    a stderr warning — a torn line (killed mid-append) or a non-object
    line (hand-edited file) must not take the bench gate down, but it
    must not vanish silently either. ``source`` filters to one artifact
    family."""
    path = history_path() if path is None else path
    if not os.path.exists(path):
        return []
    runs: List[dict] = []
    with open(path, "rt") as fh:
        for lineno, raw in enumerate(fh, 1):
            raw = raw.strip()
            if not raw:
                continue
            try:
                run = json.loads(raw)
            except json.JSONDecodeError:
                print(
                    f"warning: {path}:{lineno}: skipping corrupt/truncated "
                    "history line (killed mid-append?)",
                    file=sys.stderr,
                )
                continue
            if not isinstance(run, dict):
                print(
                    f"warning: {path}:{lineno}: skipping non-object history "
                    f"line ({type(run).__name__})",
                    file=sys.stderr,
                )
                continue
            if source is None or run.get("source") == source:
                runs.append(run)
    return runs


def run_metrics(run: dict, fields: tuple = ("us_per_iter",)) -> Dict[str, float]:
    """Flatten one history line (or live BenchJSON payload) into
    ``{"<source>:<record name>:<field>": value}`` for the gated fields.
    Non-numeric values are skipped."""
    out: Dict[str, float] = {}
    if not isinstance(run, dict):
        return out
    source = run.get("source", "")
    records = run.get("records", ())
    if not isinstance(records, (list, tuple)):
        return out
    for rec in records:
        if not isinstance(rec, dict):
            continue
        for field in fields:
            v = rec.get(field)
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                out[f"{source}:{rec.get('name', '?')}:{field}"] = float(v)
    return out


def metric_series(runs: List[dict],
                  fields: tuple = ("us_per_iter",)) -> Dict[str, List[float]]:
    """Per-metric value series across runs (oldest first) — the rolling
    window the gate's min-of-k baseline is computed over."""
    series: Dict[str, List[float]] = {}
    for run in runs:
        for key, v in run_metrics(run, fields).items():
            series.setdefault(key, []).append(v)
    return series
