"""Paper Figs. 1-2: growth of the 10 most significant coefficients along
the path, FW vs CD (the paper's 'sanity check')."""
from __future__ import annotations

import time
from pathlib import Path

import numpy as np

from benchmarks.common import CSV, SCALE, load_dataset, path_grids
from repro.core import CDConfig, FWConfig, path as path_lib
from repro.core.sampling import kappa_confidence

N_POINTS = 20 if SCALE == "ci" else 100
OUT = Path(__file__).resolve().parents[1] / "experiments" / "figures"


def _dense(pt, p):
    a = np.zeros(p)
    a[pt.alpha_nnz_idx] = pt.alpha_nnz_val
    return a


def run(csv: CSV, dataset: str = "synthetic-10000"):
    OUT.mkdir(parents=True, exist_ok=True)
    Xt, y, ds = load_dataset(dataset)
    p, m = Xt.shape
    lams, deltas = path_grids(Xt, y, N_POINTS)

    t0 = time.perf_counter()
    # high-precision CD reference defines the "relevant" variables (paper §5.1)
    cd = path_lib.cd_path(Xt, y, lams, CDConfig(lam=0.0, max_sweeps=400, tol=1e-5))
    mean_abs = np.zeros(p)
    for pt in cd.points:
        mean_abs[pt.alpha_nnz_idx] += np.abs(pt.alpha_nnz_val)
    top10 = np.argsort(-mean_abs)[:10]

    # paper §5.1 sampling: kappa from the confidence rule with the empirical
    # sparsity estimate (mean active along the CD path)
    s_hat = max(1, int(round(cd.mean_active)))
    kappa = kappa_confidence(p, s_hat, 0.99)
    fw = path_lib.fw_path(
        Xt, y, deltas, FWConfig(delta=1.0, kappa=kappa, max_iters=20000, tol=1e-3)
    )

    lines = ["solver,point,reg," + ",".join(f"c{i}" for i in top10)]
    for sname, res in (("cd", cd), ("fw", fw)):
        for j, pt in enumerate(res.points):
            a = _dense(pt, p)
            vals = ",".join(f"{a[i]:.6g}" for i in top10)
            lines.append(f"{sname},{j},{pt.reg:.6g},{vals}")
    out = OUT / f"coeff_paths_{dataset}.csv"
    out.write_text("\n".join(lines))

    # agreement metric: sign+support overlap of top10 at the densest point
    a_cd = _dense(cd.points[-1], p)[top10]
    a_fw = _dense(fw.points[-1], p)[top10]
    agree = float(np.mean(np.sign(a_cd) == np.sign(a_fw)))
    dt = time.perf_counter() - t0
    csv.emit(
        f"fig12/{dataset}", dt * 1e6,
        f"kappa={kappa};s_hat={s_hat};top10_sign_agreement={agree:.2f};csv={out.name}",
    )


if __name__ == "__main__":
    run(CSV())
