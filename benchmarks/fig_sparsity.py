"""Paper Fig. 4: active-feature growth along the path (FW vs CD vs FISTA).
Emits CSV curves under experiments/figures/."""
from __future__ import annotations

import time
from pathlib import Path

from benchmarks.common import CSV, SCALE, load_dataset, path_grids
from repro.core import CDConfig, FISTAConfig, FWConfig, path as path_lib
from repro.core.sampling import kappa_fraction

N_POINTS = 20 if SCALE == "ci" else 100
OUT = Path(__file__).resolve().parents[1] / "experiments" / "figures"


def run(csv: CSV, dataset: str = "e2006-tfidf"):
    OUT.mkdir(parents=True, exist_ok=True)
    Xt, y, ds = load_dataset(dataset)
    p, m = Xt.shape
    lams, deltas = path_grids(Xt, y, N_POINTS)

    t0 = time.perf_counter()
    curves = {
        "fw": path_lib.fw_path(
            Xt, y, deltas,
            FWConfig(delta=1.0, kappa=kappa_fraction(p, 0.02), max_iters=20000, tol=1e-3),
        ),
        "cd": path_lib.cd_path(Xt, y, lams, CDConfig(lam=0.0, max_sweeps=200, tol=1e-3)),
        "fista_const": path_lib.fista_path(
            Xt, y, deltas, FISTAConfig(constrained=True, max_iters=300, tol=1e-3)
        ),
    }
    lines = ["solver,reg,l1,active,objective"]
    for sname, res in curves.items():
        for pt in res.points:
            lines.append(f"{sname},{pt.reg:.6g},{pt.l1:.6g},{pt.active},{pt.objective:.6g}")
    out = OUT / f"sparsity_{dataset}.csv"
    out.write_text("\n".join(lines))
    dt = time.perf_counter() - t0
    mean = {k: v.mean_active for k, v in curves.items()}
    csv.emit(
        f"fig4/{dataset}", dt * 1e6,
        f"m={m};p={p};mean_active_fw={mean['fw']:.0f};mean_active_cd={mean['cd']:.0f};"
        f"mean_active_fista={mean['fista_const']:.0f};csv={out.name}",
    )


if __name__ == "__main__":
    run(CSV())
