"""Paper Figs. 3/5/6: train/test MSE along the path (FW vs CD).
Validates: both solvers find the same best model / same error minimum."""
from __future__ import annotations

import time
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from benchmarks.common import CSV, SCALE, load_dataset, path_grids
from repro.core import CDConfig, FWConfig, path as path_lib
from repro.core.sampling import kappa_fraction

N_POINTS = 20 if SCALE == "ci" else 100
OUT = Path(__file__).resolve().parents[1] / "experiments" / "figures"


def _mse(ds, idx, val, test=False):
    X = ds.X_test if test else ds.X
    y = ds.y_test if test else ds.y
    if X is None:
        return float("nan")
    pred = X[:, idx] @ val
    return float(np.mean((pred - y) ** 2))


def run(csv: CSV, dataset: str = "synthetic-10000"):
    OUT.mkdir(parents=True, exist_ok=True)
    Xt, y, ds = load_dataset(dataset)
    p, m = Xt.shape
    lams, deltas = path_grids(Xt, y, N_POINTS)

    t0 = time.perf_counter()
    fw = path_lib.fw_path(
        Xt, y, deltas,
        FWConfig(delta=1.0, kappa=kappa_fraction(p, 0.03), max_iters=20000, tol=1e-3),
    )
    cd = path_lib.cd_path(Xt, y, lams, CDConfig(lam=0.0, max_sweeps=200, tol=1e-3))
    lines = ["solver,l1,train_mse,test_mse"]
    best = {}
    for sname, res in (("fw", fw), ("cd", cd)):
        tests = []
        for pt in res.points:
            tr = _mse(ds, pt.alpha_nnz_idx, pt.alpha_nnz_val, test=False)
            te = _mse(ds, pt.alpha_nnz_idx, pt.alpha_nnz_val, test=True)
            tests.append(te)
            lines.append(f"{sname},{pt.l1:.6g},{tr:.6g},{te:.6g}")
        best[sname] = float(np.nanmin(tests)) if tests else float("nan")
    out = OUT / f"error_curves_{dataset}.csv"
    out.write_text("\n".join(lines))
    dt = time.perf_counter() - t0
    rel = abs(best["fw"] - best["cd"]) / max(abs(best["cd"]), 1e-12)
    csv.emit(
        f"fig_err/{dataset}", dt * 1e6,
        f"best_test_mse_fw={best['fw']:.5g};best_test_mse_cd={best['cd']:.5g};"
        f"rel_gap={rel:.3f};csv={out.name}",
    )


if __name__ == "__main__":
    run(CSV())
