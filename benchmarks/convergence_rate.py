"""Convergence-rate benchmarks.

Two sections:

* Proposition 2 validation — E[f(a_k)] - f* vs the 4C~_f/(k+2) bound
  (``run``, the historical section registered in benchmarks.run).
* Step-rule comparison — certified-gap-vs-n_dots curves for every
  ``FWConfig.step_rule`` (classic / away / pairwise / partan / lazy) on a
  pinned correlated design (``run_step_rules``). Correlated columns are
  where the rule zoo separates: classic FW zig-zags between near-parallel
  atoms while away/pairwise prune them, so the curves make the per-rule
  trade-off (progress per gradient dot) visible and diffable across PRs.

Both sections mirror their records into BENCH_convergence.json
(common.BenchJSON) — CI uploads that file as an artifact.
"""
from __future__ import annotations

import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import CSV, BenchJSON, load_dataset
from repro.core import FISTAConfig, FWConfig, LASSO, baselines, engine, fw_solve_with_history

OUT = Path(__file__).resolve().parents[1] / "experiments" / "figures"

# ---------------------------------------------------------------------------
# step-rule section: pinned correlated design (AR(1) columns, strong
# signals, delta well inside ||coef||_1 — the regime tests/test_step_rules.py
# certifies acceptance on)
STEP_RULES = ("classic", "away", "pairwise", "partan", "lazy")
RULE_DELTA = 40.0
RULE_BUDGETS = (16, 32, 64, 128, 256, 512, 1024)


def _corr_design(m=300, p=120, rho=0.6, k=10, scale=50.0, seed=11):
    rng = np.random.default_rng(seed)
    Z = rng.standard_normal((m, p)).astype(np.float32)
    X = np.empty_like(Z)
    X[:, 0] = Z[:, 0]
    for j in range(1, p):
        X[:, j] = rho * X[:, j - 1] + np.sqrt(1.0 - rho**2) * Z[:, j]
    coef = np.zeros(p, np.float32)
    coef[rng.choice(p, k, replace=False)] = (
        rng.standard_normal(k).astype(np.float32) * scale
    )
    y = X @ coef + rng.standard_normal(m).astype(np.float32)
    return jnp.asarray(X.T.copy()), jnp.asarray(y.astype(np.float32))


def _rule_cfg(rule: str, max_iters: int, tol: float, patience: int) -> FWConfig:
    return FWConfig(
        delta=RULE_DELTA, kappa=48, sampling="uniform", step_rule=rule,
        max_iters=max_iters, tol=tol, patience=patience,
    )


def run_step_rules(csv: CSV, js: BenchJSON | None = None):
    """Gap-vs-n_dots curve per step rule + a solve-to-tolerance summary."""
    own_js = js is None
    if own_js:
        js = BenchJSON("BENCH_convergence.json")
    Xt, y = _corr_design()
    key = jax.random.PRNGKey(1)
    for rule in STEP_RULES:
        t0 = time.perf_counter()
        # fixed-budget curve: tol=0 so every point runs its full budget
        curve = []
        for budget in RULE_BUDGETS:
            res = engine.solve(
                LASSO, Xt, y, _rule_cfg(rule, budget, 0.0, 10**9), key
            )
            gap = float(LASSO.gap(Xt, y, res.alpha, RULE_DELTA, None))
            curve.append(
                {"iters": int(res.iterations), "n_dots": int(res.n_dots),
                 "gap": gap, "objective": float(res.objective)}
            )
        # solve-to-tolerance summary (the §Stopping rule the tests pin)
        res = engine.solve(LASSO, Xt, y, _rule_cfg(rule, 1500, 1e-4, 20), key)
        gap = float(LASSO.gap(Xt, y, res.alpha, RULE_DELTA, None))
        dt = time.perf_counter() - t0
        csv.emit(
            f"convergence/step_rule/{rule}", dt * 1e6,
            f"iters={int(res.iterations)};n_dots={int(res.n_dots)};"
            f"gap={gap:.4g};converged={bool(res.converged)}",
        )
        js.add(
            f"convergence/step_rule/{rule}",
            rule=rule, delta=RULE_DELTA, shape=list(Xt.shape),
            curve=curve, iterations=int(res.iterations),
            n_dots=int(res.n_dots), gap=gap,
            objective=float(res.objective), converged=bool(res.converged),
        )
    if own_js:
        js.write()


def run(csv: CSV, dataset: str = "synthetic-10000", n_iters: int = 400, n_seeds: int = 5):
    OUT.mkdir(parents=True, exist_ok=True)
    js = BenchJSON("BENCH_convergence.json")
    Xt, y, _ = load_dataset(dataset)
    p, m = Xt.shape
    delta = 50.0

    t0 = time.perf_counter()
    ref = baselines.fista_solve(
        Xt, y, FISTAConfig(delta=delta, constrained=True, max_iters=20000, tol=1e-12),
        jax.random.PRNGKey(0),
    )
    fstar = float(ref.objective)

    cfg = FWConfig(delta=delta, kappa=max(p // 100, 64), sampling="uniform",
                   max_iters=10**6, tol=0.0, patience=10**9)
    hists = []
    for seed in range(n_seeds):
        _, h = fw_solve_with_history(Xt, y, cfg, jax.random.PRNGKey(seed), n_iters)
        hists.append(np.asarray(h))
    mean_h = np.mean(hists, 0) - fstar

    L = float(np.linalg.norm(np.asarray(Xt), 2) ** 2)
    Cf = 0.5 * (2 * delta) ** 2 * L
    ks = np.arange(1, n_iters + 1)
    bound = 4 * Cf / (ks + 2)
    lines = ["k,mean_gap,bound"] + [
        f"{k},{g:.6g},{b:.6g}" for k, g, b in zip(ks, mean_h, bound)
    ]
    (OUT / f"convergence_{dataset}.csv").write_text("\n".join(lines))
    frac_below = float(np.mean(mean_h[5:] <= bound[5:]))
    # empirical rate exponent: fit gap ~ k^alpha on the tail
    tail = slice(n_iters // 4, None)
    pos = mean_h[tail] > 1e-12
    alpha = (
        np.polyfit(np.log(ks[tail][pos]), np.log(mean_h[tail][pos]), 1)[0]
        if pos.sum() > 10 else float("nan")
    )
    dt = time.perf_counter() - t0
    csv.emit(
        f"prop2/{dataset}", dt * 1e6,
        f"frac_under_bound={frac_below:.3f};empirical_rate_k^{alpha:.2f};Cf={Cf:.3g}",
    )
    js.add(
        f"prop2/{dataset}",
        dataset=dataset, n_iters=n_iters, n_seeds=n_seeds,
        frac_under_bound=frac_below, empirical_rate=float(alpha), Cf=Cf,
    )
    run_step_rules(csv, js)
    js.write()


if __name__ == "__main__":
    run_step_rules(CSV())
