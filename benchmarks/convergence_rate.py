"""Proposition 2 validation: E[f(a_k)] - f* vs the 4C~_f/(k+2) bound."""
from __future__ import annotations

import time
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import CSV, load_dataset
from repro.core import FISTAConfig, FWConfig, baselines, fw_solve_with_history

OUT = Path(__file__).resolve().parents[1] / "experiments" / "figures"


def run(csv: CSV, dataset: str = "synthetic-10000", n_iters: int = 400, n_seeds: int = 5):
    OUT.mkdir(parents=True, exist_ok=True)
    Xt, y, _ = load_dataset(dataset)
    p, m = Xt.shape
    delta = 50.0

    t0 = time.perf_counter()
    ref = baselines.fista_solve(
        Xt, y, FISTAConfig(delta=delta, constrained=True, max_iters=20000, tol=1e-12),
        jax.random.PRNGKey(0),
    )
    fstar = float(ref.objective)

    cfg = FWConfig(delta=delta, kappa=max(p // 100, 64), sampling="uniform",
                   max_iters=10**6, tol=0.0, patience=10**9)
    hists = []
    for seed in range(n_seeds):
        _, h = fw_solve_with_history(Xt, y, cfg, jax.random.PRNGKey(seed), n_iters)
        hists.append(np.asarray(h))
    mean_h = np.mean(hists, 0) - fstar

    L = float(np.linalg.norm(np.asarray(Xt), 2) ** 2)
    Cf = 0.5 * (2 * delta) ** 2 * L
    ks = np.arange(1, n_iters + 1)
    bound = 4 * Cf / (ks + 2)
    lines = ["k,mean_gap,bound"] + [
        f"{k},{g:.6g},{b:.6g}" for k, g, b in zip(ks, mean_h, bound)
    ]
    (OUT / f"convergence_{dataset}.csv").write_text("\n".join(lines))
    frac_below = float(np.mean(mean_h[5:] <= bound[5:]))
    # empirical rate exponent: fit gap ~ k^alpha on the tail
    tail = slice(n_iters // 4, None)
    pos = mean_h[tail] > 1e-12
    alpha = (
        np.polyfit(np.log(ks[tail][pos]), np.log(mean_h[tail][pos]), 1)[0]
        if pos.sum() > 10 else float("nan")
    )
    dt = time.perf_counter() - t0
    csv.emit(
        f"prop2/{dataset}", dt * 1e6,
        f"frac_under_bound={frac_below:.3f};empirical_rate_k^{alpha:.2f};Cf={Cf:.3g}",
    )


if __name__ == "__main__":
    run(CSV())
