"""Aggregate the dry-run JSONs into the §Roofline table (and CSV)."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import CSV

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def collect():
    rows = []
    for f in sorted(DRYRUN.glob("*.json")):
        rec = json.loads(f.read_text())
        rows.append(rec)
    return rows


def run(csv: CSV):
    rows = collect()
    ok = [r for r in rows if r.get("status") == "ok"]
    skipped = [r for r in rows if r.get("status") == "skipped"]
    errors = [r for r in rows if r.get("status") == "error"]
    for r in ok:
        rf = r["roofline"]
        bound = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        frac = rf["compute_s"] / bound if bound else 0.0
        csv.emit(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            bound * 1e6,
            f"dominant={rf['dominant']};compute_s={rf['compute_s']:.4g};"
            f"memory_s={rf['memory_s']:.4g};collective_s={rf['collective_s']:.4g};"
            f"compute_frac_of_bound={frac:.3f};useful={r['useful_flops_ratio']:.3f};"
            f"hbm_gb={r['hbm_per_device_gb']:.1f}",
        )
    csv.emit(
        "roofline/summary", 0.0,
        f"ok={len(ok)};skipped={len(skipped)};errors={len(errors)}",
    )


if __name__ == "__main__":
    run(CSV())
