"""Kernel micro-benchmarks: interpret-mode correctness timing vs the jnp
reference path (wall-time here is CPU; the BlockSpec geometry + VMEM
footprint per grid step are the TPU-relevant numbers reported).

The sparse section times the block-ELL sampled-gradient against the dense
XLA gather at the paper's text-dataset densities — the acceptance number
for the sparse subsystem (sparse wins whenever col_density <= 0.01).

All rows are mirrored into BENCH_kernels.json (BenchJSON) so the perf
trajectory is machine-diffable across PRs.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import CSV, BenchJSON
from repro.kernels import colstats, residual_update, sampled_scores, sparse_sampled_scores
from repro.kernels.fw_grad.ref import sampled_scores_ref
from repro.kernels.sparse_grad.ref import sparse_sampled_scores_ref
from repro.sparse import SparseBlockMatrix


def _time(fn, *args, n=5, **kw):
    fn(*args, **kw)  # warm/compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / n


def _sparse_rows(p, m, density, rng):
    """Feature-major matrix with exactly density*m nonzeros per feature."""
    k = max(1, int(density * m))
    Xt = np.zeros((p, m), np.float32)
    for i in range(p):
        idx = rng.choice(m, size=k, replace=False)
        Xt[i, idx] = rng.standard_normal(k).astype(np.float32)
    return Xt, k


def run(csv: CSV):
    js = BenchJSON("BENCH_kernels.json")
    rng = np.random.default_rng(0)
    p, m, bs = 4096, 512, 256
    Xt = jnp.asarray(rng.standard_normal((p, m)).astype(np.float32))
    r = jnp.asarray(rng.standard_normal(m).astype(np.float32))
    blk = jnp.asarray([0, 5, 9, 2], jnp.int32)

    t_ref = _time(lambda: sampled_scores_ref(Xt, r, blk, bs)[0])
    t_int = _time(
        lambda: sampled_scores(Xt, r, blk, block_size=bs, m_tile=256, interpret=True)
    )
    vmem_kb = (bs * 256 * 4 + 256 * 4 + bs * 4) / 1024  # per grid step
    csv.emit(
        "kernel/fw_grad", t_int * 1e6,
        f"ref_us={t_ref*1e6:.0f};interpret_us={t_int*1e6:.0f};"
        f"vmem_per_step_kb={vmem_kb:.0f};grid=(nb,m/mt)",
    )
    js.add("kernel/fw_grad", p=p, m=m, block_size=bs,
           ref_us=t_ref * 1e6, interpret_us=t_int * 1e6, vmem_per_step_kb=vmem_kb)

    y = jnp.asarray(rng.standard_normal(m).astype(np.float32))
    t_ref2 = _time(lambda: (Xt @ y, jnp.sum(Xt * Xt, axis=1)))
    t_int2 = _time(lambda: colstats(Xt, y, p_tile=256, m_tile=256, interpret=True))
    csv.emit(
        "kernel/colstats", t_int2 * 1e6,
        f"ref_us={t_ref2*1e6:.0f};one_pass_fused=zty+znorm2",
    )
    js.add("kernel/colstats", p=p, m=m, ref_us=t_ref2 * 1e6, interpret_us=t_int2 * 1e6)

    z = jnp.asarray(rng.standard_normal(m).astype(np.float32))
    t_int3 = _time(
        lambda: residual_update(r, y, z, jnp.asarray(0.3), jnp.asarray(1.0), interpret=True)
    )
    csv.emit("kernel/residual_update", t_int3 * 1e6, "fused_3read_1write")
    js.add("kernel/residual_update", m=m, interpret_us=t_int3 * 1e6)

    # padded-tail geometry (p % block_size != 0 — DESIGN.md §Padding);
    # the sampled blocks must include the partially-zero tail brick
    Xt_pad = jnp.asarray(rng.standard_normal((p + 100, m)).astype(np.float32))
    tail = -(-(p + 100) // bs) - 1
    blk_pad = jnp.asarray([0, 5, 9, tail], jnp.int32)
    t_pad = _time(
        lambda: sampled_scores(Xt_pad, r, blk_pad, block_size=bs, m_tile=256, interpret=True)
    )
    csv.emit(
        "kernel/fw_grad_padded", t_pad * 1e6,
        f"p={p+100};pad_to={-(-(p+100)//bs)*bs};interpret_us={t_pad*1e6:.0f}",
    )
    js.add("kernel/fw_grad_padded", p=p + 100, m=m, block_size=bs,
           interpret_us=t_pad * 1e6)

    # -- sparse sampled-gradient vs dense XLA gather (ISSUE 2 acceptance) --
    # The dense gather reads nb*bs full length-m rows; the block-ELL op
    # reads nb*bs*nnz_max slots. At the paper's text densities the sparse
    # op must win on the same sampled blocks.
    ps, ms, bss = 4096, 2048, 256
    rng_s = np.random.default_rng(7)
    rs = jnp.asarray(rng_s.standard_normal(ms).astype(np.float32))
    blk_s = jnp.asarray([0, 3, 7, 11, 2, 9, 14, 5], jnp.int32)
    dense_gather = jax.jit(lambda X, r, b: sampled_scores_ref(X, r, b, bss)[0])
    sparse_ref = jax.jit(sparse_sampled_scores_ref)
    for density in (0.01, 0.002):
        Xts, k = _sparse_rows(ps, ms, density, rng_s)
        mat = SparseBlockMatrix.from_dense(Xts, block_size=bss)
        Xts_j = jnp.asarray(Xts)
        t_dense = _time(dense_gather, Xts_j, rs, blk_s, n=20)
        t_sparse = _time(sparse_ref, mat.values, mat.rows, rs, blk_s, n=20)
        t_kernel = _time(
            lambda: sparse_sampled_scores(mat.values, mat.rows, rs, blk_s, interpret=True)
        )
        # correctness cross-check on the same draw
        np.testing.assert_allclose(
            np.asarray(sparse_ref(mat.values, mat.rows, rs, blk_s)),
            np.asarray(dense_gather(Xts_j, rs, blk_s)),
            rtol=2e-5, atol=2e-4,
        )
        tag = f"kernel/sparse_grad_density{density:g}"
        csv.emit(
            tag, t_sparse * 1e6,
            f"p={ps};m={ms};nnz_max={mat.nnz_max};dense_gather_us={t_dense*1e6:.0f};"
            f"sparse_xla_us={t_sparse*1e6:.0f};sparse_interpret_us={t_kernel*1e6:.0f};"
            f"speedup_vs_dense={t_dense/t_sparse:.1f}x",
        )
        js.add(tag, p=ps, m=ms, block_size=bss, col_density=density,
               nnz_max=mat.nnz_max, dense_gather_us=t_dense * 1e6,
               sparse_xla_us=t_sparse * 1e6, sparse_interpret_us=t_kernel * 1e6,
               speedup_vs_dense=t_dense / t_sparse)

    # end-to-end solver step: all three backends on the SAME fixed-iteration
    # run (sparse solves the block-ELL conversion of the same dense problem)
    from repro.core import FWConfig, fw_solve

    rng2 = np.random.default_rng(1)
    p2, m2 = 2048, 256
    Xt2_np = rng2.standard_normal((p2, m2)).astype(np.float32)
    Xt2_np[rng2.random((p2, m2)) > 0.01] = 0.0  # text-like density for sparse
    Xt2 = jnp.asarray(Xt2_np)
    mat2 = SparseBlockMatrix.from_dense(Xt2_np, block_size=128)
    y2 = jnp.asarray(rng2.standard_normal(m2).astype(np.float32))
    key = jax.random.PRNGKey(0)
    times = {}
    for backend in ("xla", "pallas", "sparse"):
        cfg = FWConfig(
            delta=25.0, sampling="block", kappa=256, block_size=128,
            max_iters=200, tol=0.0, patience=10**9, backend=backend,
        )
        A = mat2 if backend == "sparse" else Xt2
        times[backend] = _time(lambda cfg=cfg, A=A: fw_solve(A, y2, cfg, key).alpha, n=3)
        mode = "interpret" if backend == "pallas" else "native"
        csv.emit(
            f"solver/fw_solve_{backend}", times[backend] * 1e6 / 200,
            f"m={m2};p={p2};kappa=256;iters=200;mode={mode}",
        )
        js.add(f"solver/fw_solve_{backend}", m=m2, p=p2, kappa=256, iters=200,
               backend=backend, us_per_iter=times[backend] * 1e6 / 200, mode=mode)
    csv.emit(
        "solver/backend_ratio", times["pallas"] / times["xla"] * 100,
        "pallas_over_xla_pct (interpret-mode CPU; TPU geometry is the target)",
    )
    csv.emit(
        "solver/sparse_vs_xla_ratio", times["sparse"] / times["xla"] * 100,
        "sparse_over_xla_pct (same block-sampled problem at density 0.01)",
    )
    js.add("solver/backend_ratios",
           pallas_over_xla=times["pallas"] / times["xla"],
           sparse_over_xla=times["sparse"] / times["xla"])

    # -- fused multi-step hot loop (ISSUE 5): iterations/sec at K=1/8/32 --
    # One fixed-iteration uniform-lasso run per (backend, fuse_steps):
    # K=1 is the per-dispatch baseline (dense-xla-ref / sparse-xla-ref),
    # K>1 the chunked driver (fori-of-step on these CPU executors; the
    # megakernel itself is TPU-targeted and timed by its parity tests in
    # interpret mode). Records land in BENCH_kernels.json as
    # hotloop/fused_k{K}_{backend} so the perf trajectory is diffable.
    pf, mf, kf, iters_f = 2048, 256, 128, 192
    rng_f = np.random.default_rng(3)
    Xf_np = rng_f.standard_normal((pf, mf)).astype(np.float32)
    Xf_sp = Xf_np.copy()
    Xf_sp[rng_f.random((pf, mf)) > 0.01] = 0.0
    arms_f = {
        "xla": jnp.asarray(Xf_np),
        "sparse": SparseBlockMatrix.from_dense(Xf_sp, block_size=128),
    }
    yf = jnp.asarray(rng_f.standard_normal(mf).astype(np.float32))
    keyf = jax.random.PRNGKey(2)
    base_f = {}
    for backend, A in arms_f.items():
        for K in (1, 8, 32):
            cfg = FWConfig(
                delta=25.0, sampling="uniform", kappa=kf, max_iters=iters_f,
                tol=0.0, patience=10**9, backend=backend, fuse_steps=K,
            )
            t = _time(lambda cfg=cfg, A=A: fw_solve(A, yf, cfg, keyf).alpha, n=3)
            ips = iters_f / t
            if K == 1:
                base_f[backend] = t
            tag = f"hotloop/fused_k{K}_{backend}"
            csv.emit(
                tag, t * 1e6 / iters_f,
                f"p={pf};m={mf};kappa={kf};iters={iters_f};"
                f"iters_per_sec={ips:.0f};speedup_vs_k1={base_f[backend]/t:.2f}x",
            )
            js.add(tag, p=pf, m=mf, kappa=kf, iters=iters_f, backend=backend,
                   fuse_steps=K, seconds=t, us_per_iter=t * 1e6 / iters_f,
                   iters_per_sec=ips, speedup_vs_k1=base_f[backend] / t)
    js.write()


if __name__ == "__main__":
    run(CSV())
