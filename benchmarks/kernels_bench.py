"""Kernel micro-benchmarks: interpret-mode correctness timing vs the jnp
reference path (wall-time here is CPU; the BlockSpec geometry + VMEM
footprint per grid step are the TPU-relevant numbers reported)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import CSV
from repro.kernels import colstats, residual_update, sampled_scores
from repro.kernels.fw_grad.ref import sampled_scores_ref


def _time(fn, *args, n=5, **kw):
    fn(*args, **kw)  # warm/compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / n


def run(csv: CSV):
    rng = np.random.default_rng(0)
    p, m, bs = 4096, 512, 256
    Xt = jnp.asarray(rng.standard_normal((p, m)).astype(np.float32))
    r = jnp.asarray(rng.standard_normal(m).astype(np.float32))
    blk = jnp.asarray([0, 5, 9, 2], jnp.int32)

    t_ref = _time(lambda: sampled_scores_ref(Xt, r, blk, bs)[0])
    t_int = _time(
        lambda: sampled_scores(Xt, r, blk, block_size=bs, m_tile=256, interpret=True)
    )
    vmem_kb = (bs * 256 * 4 + 256 * 4 + bs * 4) / 1024  # per grid step
    csv.emit(
        "kernel/fw_grad", t_int * 1e6,
        f"ref_us={t_ref*1e6:.0f};interpret_us={t_int*1e6:.0f};"
        f"vmem_per_step_kb={vmem_kb:.0f};grid=(nb,m/mt)",
    )

    y = jnp.asarray(rng.standard_normal(m).astype(np.float32))
    t_ref2 = _time(lambda: (Xt @ y, jnp.sum(Xt * Xt, axis=1)))
    t_int2 = _time(lambda: colstats(Xt, y, p_tile=256, m_tile=256, interpret=True))
    csv.emit(
        "kernel/colstats", t_int2 * 1e6,
        f"ref_us={t_ref2*1e6:.0f};one_pass_fused=zty+znorm2",
    )

    z = jnp.asarray(rng.standard_normal(m).astype(np.float32))
    t_int3 = _time(
        lambda: residual_update(r, y, z, jnp.asarray(0.3), jnp.asarray(1.0), interpret=True)
    )
    csv.emit("kernel/residual_update", t_int3 * 1e6, "fused_3read_1write")

    # padded-tail geometry (p % block_size != 0 — DESIGN.md §Padding);
    # the sampled blocks must include the partially-zero tail brick
    Xt_pad = jnp.asarray(rng.standard_normal((p + 100, m)).astype(np.float32))
    tail = -(-(p + 100) // bs) - 1
    blk_pad = jnp.asarray([0, 5, 9, tail], jnp.int32)
    t_pad = _time(
        lambda: sampled_scores(Xt_pad, r, blk_pad, block_size=bs, m_tile=256, interpret=True)
    )
    csv.emit(
        "kernel/fw_grad_padded", t_pad * 1e6,
        f"p={p+100};pad_to={-(-(p+100)//bs)*bs};interpret_us={t_pad*1e6:.0f}",
    )

    # end-to-end solver step: both backends on the SAME fixed-iteration run
    from repro.core import FWConfig, fw_solve

    rng2 = np.random.default_rng(1)
    p2, m2 = 2048, 256
    Xt2 = jnp.asarray(rng2.standard_normal((p2, m2)).astype(np.float32))
    y2 = jnp.asarray(rng2.standard_normal(m2).astype(np.float32))
    key = jax.random.PRNGKey(0)
    times = {}
    for backend in ("xla", "pallas"):
        cfg = FWConfig(
            delta=25.0, sampling="block", kappa=256, block_size=128,
            max_iters=200, tol=0.0, patience=10**9, backend=backend,
        )
        times[backend] = _time(lambda cfg=cfg: fw_solve(Xt2, y2, cfg, key).alpha, n=3)
        csv.emit(
            f"solver/fw_solve_{backend}", times[backend] * 1e6 / 200,
            f"m={m2};p={p2};kappa=256;iters=200;"
            f"mode={'interpret' if backend == 'pallas' else 'native'}",
        )
    csv.emit(
        "solver/backend_ratio", times["pallas"] / times["xla"] * 100,
        "pallas_over_xla_pct (interpret-mode CPU; TPU geometry is the target)",
    )


if __name__ == "__main__":
    run(CSV())
