"""Paper Table 5: stochastic FW at |S| = 1%, 2%, 3% of p over the path —
time, speedup vs CD, iterations, dot products, mean active features.

Both path drivers are timed per sampling fraction: the sequential
``fw_path`` and the batched-lane ``fw_path_batched`` (DESIGN.md §Path),
with the batched row recording its speedup over sequential AND the
lane-iterations pruned by the per-lane early exit (``saved_iters``).
The sparse section runs the SAME path protocol with ``backend='sparse'``
on the sparse-native text dataset (real converted shards when
scripts/fetch_libsvm.py has run, proxy otherwise) vs the dense XLA
backend on its densified equivalent (feasible at bench scale only —
which is the point). The solver-family section times the logistic and
elastic-net oracles through the same engine on both backends
(DESIGN.md §Engine).

All rows are mirrored into BENCH_table5.json (BenchJSON).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    CSV, CI_DATASETS, SCALE, BenchJSON, load_dataset, load_sparse_dataset, path_grids,
)
from repro.core import CDConfig, FWConfig, LOGISTIC, ENOracle, engine, path as path_lib
from repro.core.sampling import kappa_fraction
from repro.utils.timing import Timer, timed

N_POINTS = 20 if SCALE == "ci" else 100
SPARSE_BENCH_DATASET = "e2006-tfidf"


def run(csv: CSV, datasets=None):
    js = BenchJSON("BENCH_table5.json")
    datasets = datasets or CI_DATASETS
    for name in datasets:
        Xt, y, ds = load_dataset(name)
        p, m = Xt.shape
        lams, deltas = path_grids(Xt, y, N_POINTS)

        # CD reference time for the speedup column
        t0 = time.perf_counter()
        cd_res = path_lib.cd_path(Xt, y, lams, CDConfig(lam=0.0, max_sweeps=200, tol=1e-3))
        cd_time = time.perf_counter() - t0
        csv.emit(
            f"table5/{name}/cd_ref", cd_time * 1e6 / N_POINTS,
            f"m={m};p={p};dots={cd_res.total_dots};mean_active={cd_res.mean_active:.1f}",
        )
        js.add(f"table5/{name}/cd_ref", m=m, p=p, n_points=N_POINTS,
               seconds=cd_time, dots=cd_res.total_dots,
               mean_active=cd_res.mean_active)

        for frac in (0.01, 0.02, 0.03):
            kappa = kappa_fraction(p, frac)
            cfg = FWConfig(
                delta=1.0, kappa=kappa, sampling="uniform",
                max_iters=20_000, tol=1e-3,
            )
            t0 = time.perf_counter()
            res = path_lib.fw_path(Xt, y, deltas, cfg)
            dt = time.perf_counter() - t0
            csv.emit(
                f"table5/{name}/fw_{int(frac*100)}pct",
                dt * 1e6 / N_POINTS,
                f"m={m};p={p};kappa={kappa};speedup_vs_cd={cd_time/dt:.1f}x;"
                f"iters={res.total_iters};dots={res.total_dots};"
                f"mean_active={res.mean_active:.1f};"
                f"dots_vs_cd={cd_res.total_dots / max(res.total_dots,1):.1f}x",
            )
            js.add(f"table5/{name}/fw_{int(frac*100)}pct", m=m, p=p, kappa=kappa,
                   n_points=N_POINTS, seconds=dt, iters=res.total_iters,
                   dots=res.total_dots, mean_active=res.mean_active,
                   speedup_vs_cd=cd_time / dt)

            lane_width = max(1, -(-N_POINTS // 8))
            t0 = time.perf_counter()
            res_b = path_lib.fw_path_batched(Xt, y, deltas, cfg, lane_width=lane_width)
            dt_b = time.perf_counter() - t0
            csv.emit(
                f"table5/{name}/fw_{int(frac*100)}pct_batched",
                dt_b * 1e6 / N_POINTS,
                f"m={m};p={p};kappa={kappa};lane_width={lane_width};"
                f"chunks={-(-N_POINTS // lane_width)};"
                f"speedup_vs_seq={dt/dt_b:.1f}x;speedup_vs_cd={cd_time/dt_b:.1f}x;"
                f"iters={res_b.total_iters};dots={res_b.total_dots};"
                f"saved_iters={res_b.saved_iters};"
                f"mean_active={res_b.mean_active:.1f}",
            )
            js.add(f"table5/{name}/fw_{int(frac*100)}pct_batched", m=m, p=p,
                   kappa=kappa, lane_width=lane_width, n_points=N_POINTS,
                   seconds=dt_b, iters=res_b.total_iters, dots=res_b.total_dots,
                   saved_iters=res_b.saved_iters,
                   mean_active=res_b.mean_active, speedup_vs_seq=dt / dt_b,
                   speedup_vs_cd=cd_time / dt_b)

    _run_sparse_section(csv, js)
    _run_family_section(csv, js)
    _run_fused_section(csv, js)
    _run_distributed_section(csv, js)
    js.write()


def _sparse_delta_max(mat, y, ds) -> float:
    """l1 budget for the delta grid. Proxies expose their generating
    coefficients; real datasets (coef=None) fall back to the analytic
    ratio y^T y / ||X^T y||_inf — the l1 scale at which the best single
    predictor would explain the targets — as a dense-solver-free stand-in
    for the paper's CD-derived "sparsity budget"."""
    if ds.coef is not None:
        return 0.5 * float(np.abs(np.asarray(ds.coef)).sum())
    xty = np.abs(np.asarray(path_lib._xty(mat, jnp.asarray(y))))
    # y^T y over the null-solution threshold ||X^T y||_inf: the l1 scale at
    # which the best single predictor would explain the targets
    return float(np.dot(y, y) / max(xty.max(), 1e-12))


def _run_sparse_section(csv: CSV, js: BenchJSON):
    """backend='sparse' vs dense XLA on the same text dataset (real
    converted shards when present, proxy otherwise)."""
    mat, y, ds = load_sparse_dataset(SPARSE_BENCH_DATASET)
    p, m = mat.shape
    deltas = path_lib.delta_grid(_sparse_delta_max(mat, y, ds), n_points=N_POINTS)
    kappa = kappa_fraction(p, 0.01)
    timers = {}
    results = {}
    arms = [("sparse", mat)]
    if 4 * p * m < 2 << 30:  # densified arm only when it fits (proxies do;
        arms.insert(0, ("xla", mat.to_dense()))  # the real sizes do not)
    for backend, A in arms:
        cfg = FWConfig(
            delta=1.0, kappa=kappa, sampling="uniform",
            max_iters=20_000, tol=1e-3, backend=backend,
        )
        t = timers.setdefault(backend, Timer())
        with timed(f"table5/sparse/fw_path_{backend}", sink=t):
            res = path_lib.fw_path(A, y, deltas, cfg)
        results[backend] = res
        csv.emit(
            f"table5/{SPARSE_BENCH_DATASET}-sparse/fw_1pct_{backend}",
            t.total * 1e6 / N_POINTS,
            f"m={m};p={p};kappa={kappa};nnz_max={mat.nnz_max};"
            f"iters={res.total_iters};dots={res.total_dots};"
            f"mean_active={res.mean_active:.1f}",
        )
        js.add(f"table5/{SPARSE_BENCH_DATASET}-sparse/fw_1pct_{backend}",
               m=m, p=p, kappa=kappa, nnz_max=mat.nnz_max, backend=backend,
               n_points=N_POINTS, seconds=t.total,
               iters=res.total_iters, dots=res.total_dots,
               mean_active=res.mean_active)
    if "xla" in results:
        obj_rel = abs(
            results["sparse"].points[-1].objective - results["xla"].points[-1].objective
        ) / max(abs(results["xla"].points[-1].objective), 1e-12)
        csv.emit(
            f"table5/{SPARSE_BENCH_DATASET}-sparse/speedup",
            timers["xla"].total / timers["sparse"].total * 100,
            f"sparse_vs_dense={timers['xla'].total/timers['sparse'].total:.1f}x;"
            f"final_obj_rel_diff={obj_rel:.2e}",
        )
        js.add(f"table5/{SPARSE_BENCH_DATASET}-sparse/speedup",
               sparse_vs_dense=timers["xla"].total / timers["sparse"].total,
               final_obj_rel_diff=obj_rel)
    section = Timer()
    for t in timers.values():
        section.merge(t)
    js.add(f"table5/{SPARSE_BENCH_DATASET}-sparse/section_total",
           seconds=section.total, paths=section.count)


def _run_family_section(csv: CSV, js: BenchJSON):
    """Logistic / elastic-net oracles through the SAME engine paths
    (DESIGN.md §Engine): per-oracle sparse-vs-dense solve times plus a
    batched logistic path with lane pruning."""
    mat, y_reg, ds = load_sparse_dataset(SPARSE_BENCH_DATASET, prefer_real=False)
    p, m = mat.shape
    Xt_dense = mat.to_dense()
    y_cls = jnp.sign(y_reg) + (y_reg == 0)  # {-1,+1} labels for logistic
    kappa = kappa_fraction(p, 0.01)
    delta = _sparse_delta_max(mat, np.asarray(y_reg), ds)
    oracles = {
        "logistic": (LOGISTIC, y_cls),
        "elasticnet": (ENOracle(l2=1.0), y_reg),
    }
    for oname, (oracle, y) in oracles.items():
        for backend, A in (("xla", Xt_dense), ("sparse", mat)):
            cfg = FWConfig(
                delta=delta, kappa=kappa, sampling="uniform",
                max_iters=2_000, tol=1e-4, backend=backend,
            )
            key = jax.random.PRNGKey(0)
            res = engine.solve(oracle, A, y, cfg, key)  # compile
            res.alpha.block_until_ready()
            t0 = time.perf_counter()
            res = engine.solve(oracle, A, y, cfg, key)
            res.alpha.block_until_ready()
            dt = time.perf_counter() - t0
            csv.emit(
                f"table5/family/{oname}_{backend}",
                dt * 1e6,
                f"m={m};p={p};kappa={kappa};iters={int(res.iterations)};"
                f"dots={int(res.n_dots)};obj={float(res.objective):.4g};"
                f"active={int(res.active)}",
            )
            js.add(f"table5/family/{oname}_{backend}", m=m, p=p, kappa=kappa,
                   backend=backend, seconds=dt, iters=int(res.iterations),
                   dots=int(res.n_dots), objective=float(res.objective),
                   active=int(res.active))

    # batched logistic path: the pruned-lane driver over the family oracle
    deltas = path_lib.delta_grid(delta, n_points=max(4, N_POINTS // 4))
    cfg = FWConfig(delta=1.0, kappa=kappa, sampling="uniform",
                   max_iters=2_000, tol=1e-4, backend="sparse")
    lane_width = min(4, len(deltas))  # multi-lane chunks so pruning can fire
    t0 = time.perf_counter()
    res_b = path_lib.fw_path_batched(mat, y_cls, deltas, cfg,
                                     lane_width=lane_width, oracle=LOGISTIC)
    dt_b = time.perf_counter() - t0
    csv.emit(
        "table5/family/logistic_sparse_path_batched",
        dt_b * 1e6 / len(deltas),
        f"m={m};p={p};n_points={len(deltas)};lane_width={lane_width};"
        f"iters={res_b.total_iters};saved_iters={res_b.saved_iters}",
    )
    js.add("table5/family/logistic_sparse_path_batched", m=m, p=p,
           n_points=len(deltas), lane_width=lane_width, seconds=dt_b,
           iters=res_b.total_iters, saved_iters=res_b.saved_iters)


def _run_fused_section(csv: CSV, js: BenchJSON):
    """Fused-vs-unfused (FWConfig.fuse_steps, ISSUE 5) wall time for the
    SAME regularization path: one sequential ``fw_path`` per K on the
    dense synthetic dataset and on the sparse text proxy, so the bench
    trajectory records what K iterations per dispatch buys end to end
    (chunked stopping may spend up to K-1 extra iterations per grid
    point — both the time and the iteration counts land in the JSON)."""
    arms = []
    Xt, y, _ = load_dataset("synthetic-10000")
    arms.append(("xla", Xt, y))
    mat, ys, _ = load_sparse_dataset(SPARSE_BENCH_DATASET, prefer_real=False)
    arms.append(("sparse", mat, ys))
    n_pts = max(4, N_POINTS // 4)
    for backend, A, yv in arms:
        p, m = A.shape
        deltas = path_lib.delta_grid(
            float(jnp.max(jnp.abs(path_lib._xty(A, yv)))) * 0.02, n_points=n_pts
        )
        kappa = kappa_fraction(p, 0.01)
        base = {}
        for K in (1, 8):
            cfg = FWConfig(delta=1.0, kappa=kappa, sampling="uniform",
                           max_iters=20_000, tol=1e-3, backend=backend,
                           fuse_steps=K)
            t0 = time.perf_counter()
            res = path_lib.fw_path(A, yv, deltas, cfg)
            dt = time.perf_counter() - t0
            base.setdefault("t", dt)
            base.setdefault("obj", res.points[-1].objective)
            obj_rel = abs(res.points[-1].objective - base["obj"]) / max(
                abs(base["obj"]), 1e-12
            )
            tag = f"table5/fused/path_{backend}_k{K}"
            csv.emit(
                tag, dt * 1e6 / n_pts,
                f"m={m};p={p};kappa={kappa};n_points={n_pts};"
                f"iters={res.total_iters};speedup_vs_k1={base['t']/dt:.2f}x;"
                f"final_obj_rel_vs_k1={obj_rel:.2e}",
            )
            js.add(tag, m=m, p=p, kappa=kappa, n_points=n_pts, backend=backend,
                   fuse_steps=K, seconds=dt, iters=res.total_iters,
                   speedup_vs_k1=base["t"] / dt, final_obj_rel_vs_k1=obj_rel)


_DIST_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ["JAX_PLATFORMS"] = "cpu"
import json, time
import numpy as np, jax, jax.numpy as jnp
from repro.core import FWConfig, LASSO, engine
from repro import distributed as dist
from repro.data import make_regression, standardize
from repro.sparse.matrix import SparseBlockMatrix

m, p, n_iters, kappa = %(m)d, %(p)d, %(n_iters)d, %(kappa)d
ds = standardize(make_regression(m=m, p=p, n_informative=20, noise=0.5, seed=0))
Xs = np.asarray(ds.X.T, np.float32).copy()
Xs[np.abs(Xs) < 0.04] = 0.0
mat = SparseBlockMatrix.from_dense(Xs, block_size=128)
y = np.asarray(ds.y)
cfg = FWConfig(delta=100.0, sampling="uniform", kappa=kappa,
               max_iters=n_iters, tol=0.0, patience=10**9)
key = jax.random.PRNGKey(0)

def timed(fn):
    fn().alpha.block_until_ready()            # compile
    t0 = time.perf_counter()
    fn().alpha.block_until_ready()
    return time.perf_counter() - t0

scfg = FWConfig(**{**cfg.__dict__, "backend": "sparse"})
t_single = timed(lambda: engine.solve(LASSO, mat, jnp.asarray(y), scfg, key))
rows = {"single_device": {"seconds_per_iter": t_single / n_iters}}
for n_data, n_model in ((1, 4), (2, 2)):
    mesh = dist.fw_mesh(n_data, n_model)
    op = dist.shard_sparse(mat, y, mesh)
    t_dist = timed(lambda: dist.solve(LASSO, op, cfg, key))
    # analytic per-iteration comm budget (DESIGN.md SDistributed): one
    # |S| score psum over both axes, one (m_local,) column psum over
    # "model", and the O(1) scalar psums of the oracle recursions
    comm = 4 * (kappa + op.m_local + 8)
    local = 8 * kappa * op.nnz_max + 4 * 4 * op.m_local
    rows["mesh_%%dx%%d" %% (n_data, n_model)] = {
        "seconds_per_iter": t_dist / n_iters,
        "vs_single": t_single / t_dist,
        "comm_bytes_per_iter": comm,
        "local_bytes_per_iter": local,
        "comm_fraction": comm / (comm + local),
    }
print("DISTRESULT" + json.dumps(rows))
"""


def _run_distributed_section(csv: CSV, js: BenchJSON):
    """Distributed-vs-single-device per-iteration time + analytic comm
    fraction on a forced 4-device CPU mesh. Runs in a subprocess so this
    process keeps 1 device (DESIGN.md rule); skips gracefully when the
    subprocess cannot run (constrained sandboxes)."""
    import json as json_mod
    import os
    import subprocess
    import sys

    params = dict(m=256, p=4096, n_iters=300, kappa=64)
    if SCALE == "ci":
        params = dict(m=128, p=1024, n_iters=150, kappa=32)
    try:
        proc = subprocess.run(
            [sys.executable, "-c", _DIST_SCRIPT % params],
            capture_output=True, text=True, timeout=1200,
            env={**os.environ, "PYTHONPATH": os.pathsep.join(sys.path)},
        )
        lines = [l for l in proc.stdout.splitlines()
                 if l.startswith("DISTRESULT")]
        if proc.returncode != 0 or not lines:
            raise RuntimeError(proc.stderr[-500:])
    except Exception as exc:  # noqa: BLE001 - bench must not die here
        csv.emit("table5/distributed/skipped", 0.0, f"reason={exc}")
        return
    rows = json_mod.loads(lines[0][len("DISTRESULT"):])
    for name, row in rows.items():
        csv.emit(
            f"table5/distributed/{name}",
            row["seconds_per_iter"] * 1e6,
            ";".join(f"{k}={v:.4g}" for k, v in row.items()),
        )
        js.add(f"table5/distributed/{name}", **params, **row)


if __name__ == "__main__":
    run(CSV())
