"""Paper Table 5: stochastic FW at |S| = 1%, 2%, 3% of p over the path —
time, speedup vs CD, iterations, dot products, mean active features.

Both path drivers are timed per sampling fraction: the sequential
``fw_path`` and the batched-lane ``fw_path_batched`` (DESIGN.md §Path),
with the batched row recording its speedup over sequential. The sparse
section runs the SAME path protocol with ``backend='sparse'`` on the
sparse-native text-dataset proxy vs the dense XLA backend on its
densified equivalent (feasible at bench scale only — which is the point).

All rows are mirrored into BENCH_table5.json (BenchJSON).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (
    CSV, CI_DATASETS, SCALE, BenchJSON, load_dataset, load_sparse_dataset, path_grids,
)
from repro.core import CDConfig, FWConfig, path as path_lib
from repro.core.sampling import kappa_fraction

N_POINTS = 20 if SCALE == "ci" else 100
SPARSE_BENCH_DATASET = "e2006-tfidf"


def run(csv: CSV, datasets=None):
    js = BenchJSON("BENCH_table5.json")
    datasets = datasets or CI_DATASETS
    for name in datasets:
        Xt, y, ds = load_dataset(name)
        p, m = Xt.shape
        lams, deltas = path_grids(Xt, y, N_POINTS)

        # CD reference time for the speedup column
        t0 = time.perf_counter()
        cd_res = path_lib.cd_path(Xt, y, lams, CDConfig(lam=0.0, max_sweeps=200, tol=1e-3))
        cd_time = time.perf_counter() - t0
        csv.emit(
            f"table5/{name}/cd_ref", cd_time * 1e6 / N_POINTS,
            f"m={m};p={p};dots={cd_res.total_dots};mean_active={cd_res.mean_active:.1f}",
        )
        js.add(f"table5/{name}/cd_ref", m=m, p=p, n_points=N_POINTS,
               seconds=cd_time, dots=cd_res.total_dots,
               mean_active=cd_res.mean_active)

        for frac in (0.01, 0.02, 0.03):
            kappa = kappa_fraction(p, frac)
            cfg = FWConfig(
                delta=1.0, kappa=kappa, sampling="uniform",
                max_iters=20_000, tol=1e-3,
            )
            t0 = time.perf_counter()
            res = path_lib.fw_path(Xt, y, deltas, cfg)
            dt = time.perf_counter() - t0
            csv.emit(
                f"table5/{name}/fw_{int(frac*100)}pct",
                dt * 1e6 / N_POINTS,
                f"m={m};p={p};kappa={kappa};speedup_vs_cd={cd_time/dt:.1f}x;"
                f"iters={res.total_iters};dots={res.total_dots};"
                f"mean_active={res.mean_active:.1f};"
                f"dots_vs_cd={cd_res.total_dots / max(res.total_dots,1):.1f}x",
            )
            js.add(f"table5/{name}/fw_{int(frac*100)}pct", m=m, p=p, kappa=kappa,
                   n_points=N_POINTS, seconds=dt, iters=res.total_iters,
                   dots=res.total_dots, mean_active=res.mean_active,
                   speedup_vs_cd=cd_time / dt)

            lane_width = max(1, -(-N_POINTS // 8))
            t0 = time.perf_counter()
            res_b = path_lib.fw_path_batched(Xt, y, deltas, cfg, lane_width=lane_width)
            dt_b = time.perf_counter() - t0
            csv.emit(
                f"table5/{name}/fw_{int(frac*100)}pct_batched",
                dt_b * 1e6 / N_POINTS,
                f"m={m};p={p};kappa={kappa};lane_width={lane_width};"
                f"chunks={-(-N_POINTS // lane_width)};"
                f"speedup_vs_seq={dt/dt_b:.1f}x;speedup_vs_cd={cd_time/dt_b:.1f}x;"
                f"iters={res_b.total_iters};dots={res_b.total_dots};"
                f"mean_active={res_b.mean_active:.1f}",
            )
            js.add(f"table5/{name}/fw_{int(frac*100)}pct_batched", m=m, p=p,
                   kappa=kappa, lane_width=lane_width, n_points=N_POINTS,
                   seconds=dt_b, iters=res_b.total_iters, dots=res_b.total_dots,
                   mean_active=res_b.mean_active, speedup_vs_seq=dt / dt_b,
                   speedup_vs_cd=cd_time / dt_b)

    _run_sparse_section(csv, js)
    js.write()


def _run_sparse_section(csv: CSV, js: BenchJSON):
    """backend='sparse' vs dense XLA on the same text-dataset proxy."""
    mat, y, ds = load_sparse_dataset(SPARSE_BENCH_DATASET)
    p, m = mat.shape
    Xt_dense = mat.to_dense()  # feasible at bench scale; the real sizes are not
    deltas = path_lib.delta_grid(
        0.5 * float(np.abs(np.asarray(ds.coef)).sum()), n_points=N_POINTS
    )
    kappa = kappa_fraction(p, 0.01)
    times = {}
    results = {}
    for backend, A in (("xla", Xt_dense), ("sparse", mat)):
        cfg = FWConfig(
            delta=1.0, kappa=kappa, sampling="uniform",
            max_iters=20_000, tol=1e-3, backend=backend,
        )
        t0 = time.perf_counter()
        res = path_lib.fw_path(A, y, deltas, cfg)
        times[backend] = time.perf_counter() - t0
        results[backend] = res
        csv.emit(
            f"table5/{SPARSE_BENCH_DATASET}-sparse/fw_1pct_{backend}",
            times[backend] * 1e6 / N_POINTS,
            f"m={m};p={p};kappa={kappa};nnz_max={mat.nnz_max};"
            f"iters={res.total_iters};dots={res.total_dots};"
            f"mean_active={res.mean_active:.1f}",
        )
        js.add(f"table5/{SPARSE_BENCH_DATASET}-sparse/fw_1pct_{backend}",
               m=m, p=p, kappa=kappa, nnz_max=mat.nnz_max, backend=backend,
               n_points=N_POINTS, seconds=times[backend],
               iters=res.total_iters, dots=res.total_dots,
               mean_active=res.mean_active)
    obj_rel = abs(
        results["sparse"].points[-1].objective - results["xla"].points[-1].objective
    ) / max(abs(results["xla"].points[-1].objective), 1e-12)
    csv.emit(
        f"table5/{SPARSE_BENCH_DATASET}-sparse/speedup",
        times["xla"] / times["sparse"] * 100,
        f"sparse_vs_dense={times['xla']/times['sparse']:.1f}x;"
        f"final_obj_rel_diff={obj_rel:.2e}",
    )
    js.add(f"table5/{SPARSE_BENCH_DATASET}-sparse/speedup",
           sparse_vs_dense=times["xla"] / times["sparse"],
           final_obj_rel_diff=obj_rel)


if __name__ == "__main__":
    run(CSV())
