"""Shared benchmark plumbing: dataset registry, solver runners, CSV sink.

Scale control: REPRO_BENCH_SCALE (default "ci") picks dataset sizes.
  ci    — minutes on one CPU core (sweep-friendly); sizes recorded in output
  paper — the paper's published sizes where RAM allows (Table 1)
All emitted rows carry the actual (m, p) used.
"""
from __future__ import annotations

import dataclasses
import json
import os
import platform
import time
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CDConfig, FISTAConfig, FWConfig, baselines, fw_solve, path as path_lib
from repro.core.sampling import kappa_fraction
from repro.data import make_proxy, make_sparse_proxy, standardize
from repro.data.synthetic import paper_synthetic

SCALE = os.environ.get("REPRO_BENCH_SCALE", "ci")

# dataset name -> loader() -> Dataset (feature-major conversion done here)
def _synth(p, n_inf):
    def load():
        return paper_synthetic(p, n_inf, seed=0)
    return load


def _proxy(name, scale_ci, scale_paper):
    def load():
        return make_proxy(name, scale=scale_ci if SCALE == "ci" else scale_paper, seed=0)
    return load


DATASETS: Dict[str, Callable] = {
    "synthetic-10000": _synth(10_000, 100),
    "synthetic-50000": _synth(50_000, 158),
    "pyrim": _proxy("pyrim", 0.05, 1.0),
    "triazines": _proxy("triazines", 0.02, 1.0),
    "e2006-tfidf": _proxy("e2006-tfidf", 0.02, 0.15),
    "e2006-log1p": _proxy("e2006-log1p", 0.005, 0.05),
}

CI_DATASETS = ["synthetic-10000", "pyrim", "e2006-tfidf"]


def load_dataset(name: str):
    ds = DATASETS[name]()
    Xt = jnp.asarray(np.ascontiguousarray(ds.X.T))
    y = jnp.asarray(ds.y)
    return Xt, y, ds


# text datasets only: name -> (scale_ci, scale_paper), matching DATASETS
SPARSE_DATASETS = {"e2006-tfidf": (0.02, 0.15), "e2006-log1p": (0.005, 0.05)}

# real converted shards (scripts/fetch_libsvm.py) live here; when a
# dataset's manifest exists, benchmarks prefer it over the proxy
REPRO_DATA_DIR = os.environ.get("REPRO_DATA_DIR", "data/libsvm")


def real_shard_dir(name: str):
    """Path of the converted real dataset, or None when not fetched."""
    d = os.path.join(REPRO_DATA_DIR, name)
    return d if os.path.exists(os.path.join(d, "manifest.json")) else None


def load_sparse_dataset(name: str, prefer_real: bool = True):
    """Sparse-native dataset (block-ELL matrix, no densification).

    Real converted shards (scripts/fetch_libsvm.py) are preferred when
    present — the returned dataset then has ``coef=None`` (no generating
    coefficients) and a ``-real`` suffix on its name; otherwise the
    deterministic synthetic proxy at the configured REPRO_BENCH_SCALE.
    """
    shard_dir = real_shard_dir(name) if prefer_real else None
    if shard_dir is not None:
        from repro.data.proxies import SparseDataset
        from repro.sparse.io import load_shards_as_matrix

        mat, y = load_shards_as_matrix(shard_dir)
        y = np.asarray(y, np.float32)
        y = y - y.mean()  # same targets contract as the proxies
        ds = SparseDataset(mat=mat, y=y, coef=None, name=f"{name}-real")
        return ds.mat, jnp.asarray(ds.y), ds
    scale_ci, scale_paper = SPARSE_DATASETS[name]
    ds = make_sparse_proxy(name, scale=scale_ci if SCALE == "ci" else scale_paper, seed=0)
    return ds.mat, jnp.asarray(ds.y), ds


def path_grids(Xt, y, n_points: int):
    """The paper's protocol: lambda grid from ||X^T y||_inf; delta grid from
    a high-precision CD solve at lambda_min (same sparsity budget)."""
    lams = path_lib.lambda_grid(Xt, y, n_points=n_points)
    cd_ref = baselines.cd_solve(
        Xt, y, CDConfig(lam=float(lams[-1]), max_sweeps=300, tol=1e-6),
        jax.random.PRNGKey(0),
    )
    delta_max = float(jnp.sum(jnp.abs(cd_ref.alpha)))
    deltas = path_lib.delta_grid(delta_max, n_points=n_points)
    return lams, deltas


class CSV:
    def __init__(self):
        self.rows: List[str] = []

    def emit(self, name: str, us_per_call: float, derived: str = ""):
        row = f"{name},{us_per_call:.1f},{derived}"
        self.rows.append(row)
        print(row, flush=True)


def _git_sha() -> str:
    """Repo HEAD sha, "unknown" outside a work tree / without git. The
    subprocess is guarded — provenance must never fail a benchmark."""
    import subprocess

    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        )
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except Exception:  # noqa: BLE001 - best-effort metadata
        return "unknown"


def bench_provenance() -> dict:
    """Run provenance stamped into every BenchJSON artifact (and reused
    by the solver-report CLI): git sha, jax + device identity, UTC
    timestamp — enough to answer "what produced this number" when two
    BENCH files disagree across PRs."""
    devices = jax.devices()
    return {
        "git_sha": _git_sha(),
        "jax_version": jax.__version__,
        "device_kind": devices[0].device_kind if devices else "none",
        "device_count": len(devices),
        "timestamp_utc": time.strftime(
            "%Y-%m-%dT%H:%M:%SZ", time.gmtime()
        ),
    }


class BenchJSON:
    """Machine-readable benchmark sink: one BENCH_*.json per section so the
    perf trajectory (per-backend wall-clock, shapes, iteration counts) is
    diffable across PRs. Output dir: $REPRO_BENCH_JSON_DIR (default cwd).
    Every payload carries ``bench_provenance()`` metadata."""

    def __init__(self, filename: str):
        out_dir = os.environ.get("REPRO_BENCH_JSON_DIR", ".")
        self.path = os.path.join(out_dir, filename)
        self.records: List[dict] = []

    def add(self, name: str, **fields):
        self.records.append({"name": name, **fields})

    def write(self):
        payload = {
            "scale": SCALE,
            "jax_backend": jax.default_backend(),
            "platform": platform.platform(),
            "provenance": bench_provenance(),
            "records": self.records,
        }
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        with open(self.path, "wt") as fh:
            json.dump(payload, fh, indent=2)
        print(f"# wrote {self.path} ({len(self.records)} records)", flush=True)
        # perf trajectory: every run also appends one line to
        # BENCH_history.jsonl (benchmarks/history.py), keyed by the
        # provenance git sha — the bench gate's rolling baseline.
        # Guarded: history must never fail a benchmark.
        try:
            from benchmarks import history as bench_history

            if bench_history.history_enabled():
                hp = bench_history.append_run(
                    payload, os.path.basename(self.path)
                )
                print(f"# appended to {hp}", flush=True)
        except Exception as exc:  # noqa: BLE001 - best-effort trajectory
            print(f"# history append skipped: {exc}", flush=True)
        return self.path
