"""Benchmark entry point — one section per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV. Scale via REPRO_BENCH_SCALE
(ci|paper); 'ci' keeps single-core runtime in minutes and records the
reduced (m, p) in every row.
"""
from __future__ import annotations

import sys
import traceback

from benchmarks.common import CSV


def main() -> None:
    csv = CSV()
    from benchmarks import (
        convergence_rate,
        fig_coeff_paths,
        fig_error_curves,
        fig_sparsity,
        kernels_bench,
        roofline_report,
        table4_baselines,
        table5_fw,
    )

    sections = [
        ("table4", table4_baselines.run),
        ("table5", table5_fw.run),
        ("fig12_coeff_paths", fig_coeff_paths.run),
        ("fig4_sparsity", fig_sparsity.run),
        ("fig_error_curves", fig_error_curves.run),
        ("prop2_convergence", convergence_rate.run),
        ("kernels", kernels_bench.run),
        ("roofline", roofline_report.run),
    ]
    failures = 0
    for name, fn in sections:
        print(f"# --- {name} ---", flush=True)
        try:
            fn(csv)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},0,ERROR={type(e).__name__}:{e}", flush=True)
            traceback.print_exc()
    print(f"# done: {len(csv.rows)} rows, {failures} section failures", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
