"""§Perf hillclimb driver: measure optimized variants of the three chosen
cells against their recorded baselines (hypothesis -> change -> measure).

Variants are expressed through config flags / sharding rules so the
baseline lowering path is untouched (EXPERIMENTS.md §Perf).

    PYTHONPATH=src python scripts/hillclimb.py --cell kimi_train [--variant N]
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses as dc
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import roofline as rf
from repro.configs import get_config
from repro.launch import cells as cell_lib
from repro.launch.dryrun import _dp_axes, _opt_shardings
from repro.launch.mesh import make_production_mesh
from repro.models import sharding as sh
from repro.training.train_step import make_serve_step, make_train_step

ROOT = Path(__file__).resolve().parents[1]
OUT = ROOT / "experiments" / "perf"


def measure_train(arch, cfg, mesh, rules, mb, accum_dtype, probe_depths=(2, 4)):
    """Compile production (memory) + two-point probe (flops/collectives)."""
    shape = cell_lib.SHAPES["train_4k"]

    def lower(cfg_l, microbatches):
        params_spec = cell_lib.params_spec_for(cfg_l)
        pshard = sh.param_shardings(params_spec, mesh, fsdp=True, rules=rules)
        opt_spec = cell_lib.opt_spec_for(cfg_l, params_spec)
        oshard = _opt_shardings(opt_spec, params_spec, mesh, fsdp=True, rules=rules)
        batch_spec = cell_lib.batch_specs_for(cfg_l, shape)
        bshard = jax.tree.map(
            lambda s: NamedSharding(mesh, s), sh.batch_specs(batch_spec, mesh)
        )
        step = make_train_step(
            cfg_l, microbatches=microbatches, dp_axes=_dp_axes(mesh),
            accum_dtype=accum_dtype,
        )
        return jax.jit(
            step, in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, None), donate_argnums=(0, 1),
        ).lower(params_spec, opt_spec, batch_spec)

    act_rules = rules or sh.DEFAULT_RULES
    with mesh, sh.activation_mesh(mesh, act_rules):
        t0 = time.time()
        compiled = lower(cfg, mb).compile()
        mem = compiled.memory_analysis()
        compile_s = time.time() - t0

        # two-point probe
        prefix = cfg.first_k_dense if cfg.n_experts else 0
        L_main = cfg.n_layers - prefix
        fs, tallies_pair = [], []
        for Lk in probe_depths:
            cfg_k = dc.replace(cfg, n_layers=Lk + prefix, scan_layers=False)
            c = lower(cfg_k, 1).compile()
            cost = c.cost_analysis()
            fs.append(
                (float(cost.get("flops", 0)), float(cost.get("bytes accessed", 0)))
            )
            tallies_pair.append(rf.parse_collectives(c.as_text()))
    (f1, b1), (f2, b2) = fs
    L1, L2 = probe_depths
    scale = (L_main - L2) / (L2 - L1)
    flops = f2 + (f2 - f1) * scale
    bts = b2 + (b2 - b1) * scale
    tallies = {
        kind: {
            k: tallies_pair[1][kind][k]
            + (tallies_pair[1][kind][k] - tallies_pair[0][kind][k]) * scale
            for k in tallies_pair[1][kind]
        }
        for kind in tallies_pair[1]
    }
    wire = sum(v["wire_bytes"] for v in tallies.values())
    return {
        "hbm_gb": (mem.argument_size_in_bytes + mem.temp_size_in_bytes) / 1e9,
        "temp_gb": mem.temp_size_in_bytes / 1e9,
        "compile_s": round(compile_s, 1),
        "compute_s": flops / rf.V5E["peak_flops"],
        "memory_s": bts / rf.V5E["hbm_bw"],
        "collective_s": wire / rf.V5E["ici_bw"],
        "wire_gb": wire / 1e9,
        "collectives": tallies,
    }


def measure_prefill(arch, cfg, mesh, rules, shape_name="prefill_32k"):
    shape = cell_lib.SHAPES[shape_name]
    from repro.training.train_step import make_prefill_step

    def lower(cfg_l):
        params_spec = cell_lib.params_spec_for(cfg_l)
        pshard = sh.param_shardings(params_spec, mesh, fsdp=False, rules=rules)
        batch_spec = cell_lib.batch_specs_for(cfg_l, shape)
        bshard = jax.tree.map(
            lambda s: NamedSharding(mesh, s), sh.batch_specs(batch_spec, mesh)
        )
        step = make_prefill_step(cfg_l, max_seq=shape.seq_len)
        return jax.jit(step, in_shardings=(pshard, bshard)).lower(
            params_spec, batch_spec
        )

    act_rules = rules or sh.DEFAULT_RULES
    with mesh, sh.activation_mesh(mesh, act_rules):
        t0 = time.time()
        compiled = lower(cfg).compile()
        mem = compiled.memory_analysis()
        compile_s = time.time() - t0
        prefix = cfg.first_k_dense if cfg.n_experts else 0
        L_main = cfg.n_layers - prefix
        fs, tallies_pair = [], []
        for Lk in (2, 4):
            cfg_k = dc.replace(cfg, n_layers=Lk + prefix, scan_layers=False)
            c = lower(cfg_k).compile()
            cost = c.cost_analysis()
            fs.append((float(cost.get("flops", 0)), float(cost.get("bytes accessed", 0))))
            tallies_pair.append(rf.parse_collectives(c.as_text()))
    (f1, b1), (f2, b2) = fs
    scale = (L_main - 4) / 2
    flops = f2 + (f2 - f1) * scale
    bts = b2 + (b2 - b1) * scale
    wire = sum(
        t2["wire_bytes"] + (t2["wire_bytes"] - t1["wire_bytes"]) * scale
        for t1, t2 in zip(tallies_pair[0].values(), tallies_pair[1].values())
    )
    return {
        "hbm_gb": (mem.argument_size_in_bytes + mem.temp_size_in_bytes) / 1e9,
        "temp_gb": mem.temp_size_in_bytes / 1e9,
        "compile_s": round(compile_s, 1),
        "compute_s": flops / rf.V5E["peak_flops"],
        "memory_s": bts / rf.V5E["hbm_bw"],
        "collective_s": wire / rf.V5E["ici_bw"],
        "wire_gb": wire / 1e9,
    }


def measure_decode(arch, cfg, mesh, rules, shape_name="decode_32k"):
    shape = cell_lib.SHAPES[shape_name]

    def lower(cfg_l):
        params_spec = cell_lib.params_spec_for(cfg_l)
        pshard = sh.param_shardings(params_spec, mesh, fsdp=False, rules=rules)
        tokens_spec, cache_spec = cell_lib.decode_inputs_for(cfg_l, shape)
        cshard = jax.tree.map(
            lambda s: NamedSharding(mesh, s), sh.cache_specs(cache_spec, mesh)
        )
        tspec = sh.spec_for(tokens_spec.shape, ("batch", None), mesh, sh.DEFAULT_RULES)
        step = make_serve_step(cfg_l)
        return jax.jit(
            step,
            in_shardings=(pshard, NamedSharding(mesh, tspec), cshard),
            out_shardings=(None, None, cshard),
            donate_argnums=(2,),
        ).lower(params_spec, tokens_spec, cache_spec)

    act_rules = rules or sh.DEFAULT_RULES
    with mesh, sh.activation_mesh(mesh, act_rules):
        t0 = time.time()
        compiled = lower(cfg).compile()
        mem = compiled.memory_analysis()
        compile_s = time.time() - t0
        prefix = cfg.first_k_dense if cfg.n_experts else 0
        L_main = cfg.n_layers - prefix
        fs, tallies_pair = [], []
        for Lk in (2, 4):
            cfg_k = dc.replace(cfg, n_layers=Lk + prefix, scan_layers=False)
            c = lower(cfg_k).compile()
            cost = c.cost_analysis()
            fs.append((float(cost.get("flops", 0)), float(cost.get("bytes accessed", 0))))
            tallies_pair.append(rf.parse_collectives(c.as_text()))
    (f1, b1), (f2, b2) = fs
    scale = (L_main - 4) / 2
    flops = f2 + (f2 - f1) * scale
    bts = b2 + (b2 - b1) * scale
    tallies = {
        kind: {
            k: tallies_pair[1][kind][k]
            + (tallies_pair[1][kind][k] - tallies_pair[0][kind][k]) * scale
            for k in tallies_pair[1][kind]
        }
        for kind in tallies_pair[1]
    }
    wire = sum(v["wire_bytes"] for v in tallies.values())
    return {
        "hbm_gb": (mem.argument_size_in_bytes + mem.temp_size_in_bytes) / 1e9,
        "temp_gb": mem.temp_size_in_bytes / 1e9,
        "compile_s": round(compile_s, 1),
        "compute_s": flops / rf.V5E["peak_flops"],
        "memory_s": bts / rf.V5E["hbm_bw"],
        "collective_s": wire / rf.V5E["ici_bw"],
        "wire_gb": wire / 1e9,
    }


def cell_kimi_train(variant: str):
    mesh = make_production_mesh()
    cfg = get_config("kimi_k2_1t_a32b")
    if variant == "baseline":
        return measure_train("kimi", cfg, mesh, None, 16, jnp.float32)
    if variant == "accum_bf16":
        return measure_train("kimi", cfg, mesh, None, 16, jnp.bfloat16)
    if variant == "weight_stationary":
        rules = sh.weight_stationary_moe_rules()
        return measure_train("kimi", cfg, mesh, rules, 16, jnp.float32)
    if variant == "combined":
        rules = sh.weight_stationary_moe_rules()
        return measure_train("kimi", cfg, mesh, rules, 16, jnp.bfloat16)
    raise ValueError(variant)


def cell_qwen_decode(variant: str):
    mesh = make_production_mesh()
    cfg = get_config("qwen2_72b")
    if variant == "baseline":
        return measure_decode("qwen", cfg, mesh, None)
    if variant == "uniform_dus":
        return measure_decode("qwen", dc.replace(cfg, ragged_decode=False), mesh, None)
    if variant == "uniform_dus_mlpdata":
        rules = dict(sh.DEFAULT_RULES)
        rules["mlp"] = "data"
        return measure_decode(
            "qwen", dc.replace(cfg, ragged_decode=False), mesh, rules
        )
    raise ValueError(variant)


def cell_hymba_prefill(variant: str):
    mesh = make_production_mesh()
    cfg = get_config("hymba_1_5b")
    if variant == "baseline":
        return measure_prefill("hymba", cfg, mesh, None)
    if variant == "streaming":
        return measure_prefill(
            "hymba", dc.replace(cfg, streaming_attn_threshold=8192), mesh, None
        )
    if variant == "streaming_seqshard":
        rules = dict(sh.DEFAULT_RULES)
        rules["seq"] = "model"  # sequence-parallel activations (25 heads
        # don't shard 16 ways; the seq dim does)
        return measure_prefill(
            "hymba", dc.replace(cfg, streaming_attn_threshold=8192), mesh, rules
        )
    raise ValueError(variant)


def cell_qwen_prefill(variant: str):
    mesh = make_production_mesh()
    cfg = get_config("qwen2_72b")
    if variant == "streaming":
        return measure_prefill(
            "qwen", dc.replace(cfg, streaming_attn_threshold=8192), mesh, None
        )
    raise ValueError(variant)


CELLS = {
    "kimi_train": (cell_kimi_train,
                   ["baseline", "accum_bf16", "weight_stationary", "combined"]),
    "qwen_decode": (cell_qwen_decode,
                    ["baseline", "uniform_dus", "uniform_dus_mlpdata"]),
    "hymba_prefill": (cell_hymba_prefill,
                      ["baseline", "streaming", "streaming_seqshard"]),
    "qwen_prefill": (cell_qwen_prefill, ["streaming"]),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(CELLS), required=True)
    ap.add_argument("--variant", default=None)
    args = ap.parse_args()
    OUT.mkdir(parents=True, exist_ok=True)
    fn, variants = CELLS[args.cell]
    todo = [args.variant] if args.variant else variants
    for v in todo:
        out = OUT / f"{args.cell}__{v}.json"
        if out.exists():
            print(f"[skip] {out.name}")
            continue
        print(f"[hillclimb] {args.cell} / {v} ...", flush=True)
        t0 = time.time()
        try:
            res = fn(v)
            res["variant"] = v
            res["wall_s"] = round(time.time() - t0, 1)
            out.write_text(json.dumps(res, indent=2, default=str))
            print(
                f"[hillclimb] {args.cell}/{v}: hbm={res['hbm_gb']:.1f}GB "
                f"coll={res['collective_s']:.3g}s mem={res['memory_s']:.3g}s "
                f"comp={res['compute_s']:.3g}s", flush=True,
            )
        except Exception as e:  # noqa: BLE001
            import traceback
            print(f"[hillclimb] {args.cell}/{v} FAILED: {e}")
            traceback.print_exc()


if __name__ == "__main__":
    main()
