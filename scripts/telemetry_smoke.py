"""CI telemetry smoke (DESIGN.md §Observability).

Five gates, in order:

  1. Artifact gate — run ``scripts/solver_report.py`` with a distributed
     (4 virtual CPU device) run included; it fails non-zero if the trace
     does not validate against the Perfetto trace_event schema subset.
  2. Schema re-check — load the written ``solver_trace.json`` and
     ``solver_report.json`` back from disk and validate them
     independently (what the upload step actually ships).
  3. Overhead gate — time the kernels-bench-style hotloop (xla backend,
     p=2048, m=256, kappa=128, fixed 400 iterations) with telemetry off
     vs ON (default ring, per-step objectives), min-of-N wall clock, and
     fail if telemetry-on exceeds the budget:
     $REPRO_TELEMETRY_OVERHEAD_PCT (default 10).
  4. Exposition gate — run an instrumented solve with a metrics registry
     installed, scrape the live ``/metrics`` HTTP endpoint, and fail
     unless the OpenMetrics text passes ``validate_openmetrics`` and
     contains the solve-latency histogram + quantile samples the serving
     layer depends on (the written ``metrics.txt`` ships as an artifact).
  5. Metrics-bridge overhead gate — same hotloop, registry installed vs
     not (telemetry OFF both sides: this isolates the host-side shim),
     same budget env var. The shim is one host timer + a handful of dict
     updates per dispatch, so this also catches accidental per-iteration
     work sneaking into the bridge.

Usage: PYTHONPATH=src python scripts/telemetry_smoke.py --out-dir reports
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_REPO_ROOT, os.path.join(_REPO_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

OVERHEAD_PCT = float(os.environ.get("REPRO_TELEMETRY_OVERHEAD_PCT", "10"))
METRICS_OVERHEAD_PCT = float(
    os.environ.get("REPRO_METRICS_OVERHEAD_PCT", str(OVERHEAD_PCT))
)


def overhead_gate(repeats: int = 5) -> float:
    """Telemetry-on vs -off hotloop wall clock; returns overhead in %."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import FWConfig, LASSO, engine
    from repro.data import make_regression, standardize
    from repro.obs import TelemetrySpec

    ds = standardize(
        make_regression(m=256, p=2048, n_informative=20, noise=0.5, seed=0)
    )
    Xt = jnp.asarray(np.asarray(ds.X.T, np.float32))
    y = jnp.asarray(np.asarray(ds.y, np.float32))
    key = jax.random.PRNGKey(0)
    base = dict(delta=100.0, kappa=128, sampling="uniform",
                max_iters=400, tol=0.0, patience=10**9)

    def best_of(cfg) -> float:
        engine.solve(LASSO, Xt, y, cfg, key).alpha.block_until_ready()  # compile
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            engine.solve(LASSO, Xt, y, cfg, key).alpha.block_until_ready()
            best = min(best, time.perf_counter() - t0)
        return best

    t_off = best_of(FWConfig(**base))
    t_on = best_of(FWConfig(**base, telemetry=TelemetrySpec(capacity=256)))
    return (t_on / t_off - 1.0) * 100.0


def exposition_gate(out_dir: str) -> int:
    """Scrape a live ``/metrics`` during instrumented solves; 0 on pass.

    Installs a registry, runs a plain solve plus a short batched sparse
    path (so lane-freeze counters populate), scrapes the HTTP endpoint,
    validates the OpenMetrics text, and requires the families the
    dashboards key on. The scraped text is written to
    ``<out_dir>/metrics.txt`` and the JSON snapshot next to it.
    """
    import math

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import FWConfig, LASSO, engine, path as fw_path_mod
    from repro.data import make_regression, standardize
    from repro.obs import (
        MetricsRegistry, MetricsServer, scrape, snapshot_json,
        use_registry, validate_openmetrics,
    )
    from repro.sparse.matrix import SparseBlockMatrix

    ds = standardize(
        make_regression(m=128, p=512, n_informative=10, noise=0.5, seed=1)
    )
    Xt = jnp.asarray(np.asarray(ds.X.T, np.float32))
    y = jnp.asarray(np.asarray(ds.y, np.float32))
    Xs = np.asarray(ds.X.T, np.float32)
    Xs[np.abs(Xs) < 1.0] = 0.0
    Xt_sparse = SparseBlockMatrix.from_dense(jnp.asarray(Xs), block_size=128)
    key = jax.random.PRNGKey(0)
    cfg = FWConfig(delta=50.0, kappa=64, max_iters=120, tol=0.0,
                   patience=10**9)

    reg = MetricsRegistry()
    with use_registry(reg):
        engine.solve(LASSO, Xt, y, cfg, key)
        fw_path_mod.fw_path_batched(
            Xt_sparse, y, [2.0, 5.0, 10.0, 25.0],
            FWConfig(delta=1.0, kappa=64, max_iters=200, tol=1e-4,
                     backend="sparse"),
            lane_width=4,
        )
        with MetricsServer(registry=reg, port=0) as srv:
            text = scrape(srv.url)

    problems = validate_openmetrics(text)
    if problems:
        print("FAIL: /metrics exposition invalid:", *problems, sep="\n  ")
        return 1

    snap = snapshot_json(reg)
    fams = set(snap)
    want = {"fw_solves", "fw_iterations", "fw_solve_latency_seconds",
            "fw_lanes_admitted", "fw_lane_freezes"}
    if not want <= fams:
        print(f"FAIL: /metrics missing families: {sorted(want - fams)}")
        return 1
    lat = reg.get("fw_solve_latency_seconds")
    quants = [lat.quantile(q, **dict(key))
              for key, _snap in lat.series() for q in (0.5, 0.99)]
    if not quants or any(math.isnan(v) for v in quants):
        print("FAIL: solve-latency p50/p99 quantiles empty or NaN")
        return 1

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "metrics.txt"), "w") as fh:
        fh.write(text)
    with open(os.path.join(out_dir, "metrics.json"), "w") as fh:
        json.dump(snap, fh, indent=1, sort_keys=True)
    print(f"# /metrics scrape valid: {len(fams)} families, "
          f"p50/p99 solve latency populated")
    return 0


def bridge_overhead_gate(repeats: int = 5) -> float:
    """Registry-installed vs bare hotloop wall clock; returns overhead %.

    Telemetry stays OFF on both sides so this isolates the host-side
    metrics shim (one perf_counter pair + a few dict updates per solve).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import FWConfig, LASSO, engine
    from repro.data import make_regression, standardize
    from repro.obs import MetricsRegistry, use_registry

    ds = standardize(
        make_regression(m=256, p=2048, n_informative=20, noise=0.5, seed=0)
    )
    Xt = jnp.asarray(np.asarray(ds.X.T, np.float32))
    y = jnp.asarray(np.asarray(ds.y, np.float32))
    key = jax.random.PRNGKey(0)
    cfg = FWConfig(delta=100.0, kappa=128, sampling="uniform",
                   max_iters=400, tol=0.0, patience=10**9)

    def best_of(registry) -> float:
        def run():
            if registry is None:
                engine.solve(LASSO, Xt, y, cfg, key).alpha.block_until_ready()
            else:
                with use_registry(registry):
                    engine.solve(
                        LASSO, Xt, y, cfg, key
                    ).alpha.block_until_ready()
        run()  # compile
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            run()
            best = min(best, time.perf_counter() - t0)
        return best

    t_off = best_of(None)
    t_on = best_of(MetricsRegistry())
    return (t_on / t_off - 1.0) * 100.0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out-dir", default="reports")
    ap.add_argument("--skip-distributed", action="store_true",
                    help="drop the 4-device subprocess run (constrained "
                         "sandboxes)")
    args = ap.parse_args(argv)

    import scripts.solver_report as solver_report
    from repro.obs import validate_chrome_trace

    # 1. traced solves -> report + trace artifacts (validates internally)
    report_args = ["--out-dir", args.out_dir, "--backends", "xla,sparse",
                   "--iters", "150", "--p", "512", "--m", "128"]
    if not args.skip_distributed:
        report_args.append("--distributed")
    rc = solver_report.main(report_args)
    if rc != 0:
        print("FAIL: solver_report did not produce a valid trace")
        return rc

    # 2. the on-disk artifacts must load and validate standalone
    trace_path = os.path.join(args.out_dir, "solver_trace.json")
    with open(trace_path) as fh:
        errors = validate_chrome_trace(fh.read())
    if errors:
        print("FAIL: written trace invalid:", *errors, sep="\n  ")
        return 1
    with open(os.path.join(args.out_dir, "solver_report.json")) as fh:
        report = json.load(fh)
    backends = {run.get("backend") for run in report.get("runs", [])}
    want = {"xla", "sparse"} | (
        set() if args.skip_distributed else {"distributed"}
    )
    if not want <= backends:
        print(f"FAIL: report missing backends: {sorted(want - backends)}")
        return 1
    print(f"# trace + report artifacts valid ({sorted(backends)})")

    # 3. hotloop overhead budget
    pct = overhead_gate()
    print(f"# telemetry-on hotloop overhead: {pct:+.1f}% "
          f"(budget {OVERHEAD_PCT:.0f}%)")
    if pct > OVERHEAD_PCT:
        print("FAIL: telemetry overhead exceeds budget")
        return 1

    # 4. OpenMetrics exposition over a live /metrics scrape
    rc = exposition_gate(args.out_dir)
    if rc != 0:
        return rc

    # 5. metrics-bridge overhead budget (registry on vs off)
    pct = bridge_overhead_gate()
    print(f"# metrics-bridge hotloop overhead: {pct:+.1f}% "
          f"(budget {METRICS_OVERHEAD_PCT:.0f}%)")
    if pct > METRICS_OVERHEAD_PCT:
        print("FAIL: metrics-bridge overhead exceeds budget")
        return 1
    print("# telemetry smoke PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
