"""CI telemetry smoke (DESIGN.md §Observability).

Three gates, in order:

  1. Artifact gate — run ``scripts/solver_report.py`` with a distributed
     (4 virtual CPU device) run included; it fails non-zero if the trace
     does not validate against the Perfetto trace_event schema subset.
  2. Schema re-check — load the written ``solver_trace.json`` and
     ``solver_report.json`` back from disk and validate them
     independently (what the upload step actually ships).
  3. Overhead gate — time the kernels-bench-style hotloop (xla backend,
     p=2048, m=256, kappa=128, fixed 400 iterations) with telemetry off
     vs ON (default ring, per-step objectives), min-of-N wall clock, and
     fail if telemetry-on exceeds the budget:
     $REPRO_TELEMETRY_OVERHEAD_PCT (default 10).

Usage: PYTHONPATH=src python scripts/telemetry_smoke.py --out-dir reports
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_REPO_ROOT, os.path.join(_REPO_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

OVERHEAD_PCT = float(os.environ.get("REPRO_TELEMETRY_OVERHEAD_PCT", "10"))


def overhead_gate(repeats: int = 5) -> float:
    """Telemetry-on vs -off hotloop wall clock; returns overhead in %."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import FWConfig, LASSO, engine
    from repro.data import make_regression, standardize
    from repro.obs import TelemetrySpec

    ds = standardize(
        make_regression(m=256, p=2048, n_informative=20, noise=0.5, seed=0)
    )
    Xt = jnp.asarray(np.asarray(ds.X.T, np.float32))
    y = jnp.asarray(np.asarray(ds.y, np.float32))
    key = jax.random.PRNGKey(0)
    base = dict(delta=100.0, kappa=128, sampling="uniform",
                max_iters=400, tol=0.0, patience=10**9)

    def best_of(cfg) -> float:
        engine.solve(LASSO, Xt, y, cfg, key).alpha.block_until_ready()  # compile
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            engine.solve(LASSO, Xt, y, cfg, key).alpha.block_until_ready()
            best = min(best, time.perf_counter() - t0)
        return best

    t_off = best_of(FWConfig(**base))
    t_on = best_of(FWConfig(**base, telemetry=TelemetrySpec(capacity=256)))
    return (t_on / t_off - 1.0) * 100.0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out-dir", default="reports")
    ap.add_argument("--skip-distributed", action="store_true",
                    help="drop the 4-device subprocess run (constrained "
                         "sandboxes)")
    args = ap.parse_args(argv)

    import scripts.solver_report as solver_report
    from repro.obs import validate_chrome_trace

    # 1. traced solves -> report + trace artifacts (validates internally)
    report_args = ["--out-dir", args.out_dir, "--backends", "xla,sparse",
                   "--iters", "150", "--p", "512", "--m", "128"]
    if not args.skip_distributed:
        report_args.append("--distributed")
    rc = solver_report.main(report_args)
    if rc != 0:
        print("FAIL: solver_report did not produce a valid trace")
        return rc

    # 2. the on-disk artifacts must load and validate standalone
    trace_path = os.path.join(args.out_dir, "solver_trace.json")
    with open(trace_path) as fh:
        errors = validate_chrome_trace(fh.read())
    if errors:
        print("FAIL: written trace invalid:", *errors, sep="\n  ")
        return 1
    with open(os.path.join(args.out_dir, "solver_report.json")) as fh:
        report = json.load(fh)
    backends = {run.get("backend") for run in report.get("runs", [])}
    want = {"xla", "sparse"} | (
        set() if args.skip_distributed else {"distributed"}
    )
    if not want <= backends:
        print(f"FAIL: report missing backends: {sorted(want - backends)}")
        return 1
    print(f"# trace + report artifacts valid ({sorted(backends)})")

    # 3. hotloop overhead budget
    pct = overhead_gate()
    print(f"# telemetry-on hotloop overhead: {pct:+.1f}% "
          f"(budget {OVERHEAD_PCT:.0f}%)")
    if pct > OVERHEAD_PCT:
        print("FAIL: telemetry overhead exceeds budget")
        return 1
    print("# telemetry smoke PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
