"""Programmatic profiler capture around one FW solve.

Wraps a representative solve in a ``jax.profiler`` trace capture
(TensorBoard/XProf format) AND the repo's own ``obs.trace.Tracer``
(Chrome ``trace_event`` JSON), so a bench-gate regression comes with a
profile artifact whose device timeline can be correlated with the
solver's host-side span names: both captures bracket the same dispatch,
and the Tracer spans (``profile/solve``, ``profile/solve/warmup``, ...)
carry the wall-clock window to look at in the XProf trace.

The capture is best-effort by design: ``jax.profiler`` needs a working
``tensorflow``/``tensorboard_plugin_profile`` backend in some
environments — when ``start_trace`` raises, the script still emits the
Chrome trace + timing summary and says so, exit code 0 (a profile
artifact must never fail CI by itself; the GATE fails CI, this explains
the failure).

Usage:
  python scripts/profile_capture.py --out reports/profile
  python scripts/profile_capture.py --backend sparse --fuse-steps 8
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_REPO_ROOT, os.path.join(_REPO_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import engine  # noqa: E402
from repro.core.fw_lasso import LASSO  # noqa: E402
from repro.core.solver_config import FWConfig  # noqa: E402
from repro.obs import trace as obs_trace  # noqa: E402
from repro.sparse.matrix import SparseBlockMatrix  # noqa: E402


def build_problem(p: int, m: int, backend: str, seed: int = 0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(p, m)).astype(np.float32)
    coef = np.zeros(p, np.float32)
    nz = rng.choice(p, size=max(1, p // 100), replace=False)
    coef[nz] = rng.normal(size=nz.size).astype(np.float32)
    y = X.T @ coef + 0.1 * rng.normal(size=m).astype(np.float32)
    Xt = jnp.asarray(X)
    if backend == "sparse":
        X[np.abs(X) < 1.0] = 0.0  # ~32% density — keep the gather busy
        Xt = SparseBlockMatrix.from_dense(X, block_size=128)
    return Xt, jnp.asarray(y)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="reports/profile",
                    help="artifact dir (XProf trace + chrome_trace.json)")
    ap.add_argument("--backend", default="xla",
                    choices=("xla", "pallas", "sparse"))
    ap.add_argument("--step-rule", default="classic")
    ap.add_argument("--fuse-steps", type=int, default=1)
    ap.add_argument("--p", type=int, default=20_000)
    ap.add_argument("--m", type=int, default=512)
    ap.add_argument("--kappa", type=int, default=256)
    ap.add_argument("--iters", type=int, default=300)
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    Xt, y = build_problem(args.p, args.m, args.backend, seed=0)
    cfg = FWConfig(
        delta=10.0, kappa=args.kappa, max_iters=args.iters, tol=0.0,
        patience=10**9, backend=args.backend, step_rule=args.step_rule,
        fuse_steps=args.fuse_steps,
    )
    key = jax.random.PRNGKey(0)

    tracer = obs_trace.Tracer()
    with obs_trace.use_tracer(tracer):
        with tracer.span("profile/solve/warmup", cat="profile"):
            engine.solve(LASSO, Xt, y, cfg, key).alpha.block_until_ready()

        profiler_ok, profiler_err = True, None
        try:
            jax.profiler.start_trace(args.out)
        except Exception as exc:  # noqa: BLE001 - backend-dependent
            profiler_ok, profiler_err = False, str(exc)
        t0 = time.perf_counter()
        with tracer.span(
            "profile/solve", cat="profile", backend=args.backend,
            rule=args.step_rule, fuse_steps=args.fuse_steps,
            p=args.p, m=args.m,
        ):
            res = engine.solve(LASSO, Xt, y, cfg, key)
            res.alpha.block_until_ready()
        elapsed = time.perf_counter() - t0
        if profiler_ok:
            try:
                jax.profiler.stop_trace()
            except Exception as exc:  # noqa: BLE001
                profiler_ok, profiler_err = False, str(exc)

    chrome_path = os.path.join(args.out, "chrome_trace.json")
    tracer.save(chrome_path)
    summary = {
        "profiler_trace": args.out if profiler_ok else None,
        "profiler_error": profiler_err,
        "chrome_trace": chrome_path,
        "span_table": tracer.span_table(),
        "config": {
            "backend": args.backend, "step_rule": args.step_rule,
            "fuse_steps": args.fuse_steps, "p": args.p, "m": args.m,
            "kappa": args.kappa, "iters": args.iters,
        },
        "solve_seconds": elapsed,
        "us_per_iter": elapsed * 1e6 / max(1, int(res.iterations)),
        "iterations": int(res.iterations),
    }
    summary_path = os.path.join(args.out, "profile_summary.json")
    with open(summary_path, "wt") as fh:
        json.dump(summary, fh, indent=2)
    status = "captured" if profiler_ok else f"SKIPPED ({profiler_err})"
    print(f"profile_capture: jax.profiler {status}")
    print(f"profile_capture: chrome trace + summary in {args.out} "
          f"({elapsed:.3f}s solve, "
          f"{summary['us_per_iter']:.1f} us/iter)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
