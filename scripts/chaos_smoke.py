"""Chaos smoke: inject the recovery matrix's faults and verify healing.

The CI chaos step's entry point (DESIGN.md §Resilience). Runs small
solver problems under every fault family with ``REPRO_FAULT_SEED``
pinned, checks each one healed (or resumed) correctly, and writes the
resulting metrics-registry snapshot — injected-fault counts, guard
trips/recoveries, shard retry counters, path checkpoint events — as a
JSON artifact for the CI upload.

Scenarios (all on CPU-sized problems, one process):
  * co-state NaN  -> rung-1 rebuild heals; objective matches clean run;
  * beta NaN      -> rung-2 chunk retry heals BIT-identically;
  * shard byte corruption -> manifest sha256 + retry heals the read;
  * mid-path kill -> checkpoint/resume replays bit-identically;
  * no-fault resilient run == plain engine run bit-for-bit.

Exit 0 when every scenario healed; 1 otherwise (fails the CI step).

Usage:
  PYTHONPATH=src python scripts/chaos_smoke.py [--out reports/chaos_metrics.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

import numpy as np

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import engine, fw_lasso, path as path_lib  # noqa: E402
from repro.core.solver_config import FWConfig  # noqa: E402
from repro.obs import export as obs_export  # noqa: E402
from repro.obs import metrics as obs_metrics  # noqa: E402
from repro.resilience import faults, guards  # noqa: E402
from repro.sparse import io as sio  # noqa: E402


def _problem(seed=0, p=60, m=40):
    rng = np.random.default_rng(seed)
    Xd = (rng.normal(size=(m, p)) * (rng.random(size=(m, p)) < 0.4)
          ).astype(np.float32)
    y = rng.normal(size=m).astype(np.float32)
    return Xd, y


def run_scenarios(seed: int) -> dict:
    """Returns {scenario: bool} under the ambient metrics registry."""
    results = {}
    Xd, y = _problem(6)
    Xt, yj = jnp.asarray(Xd.T), jnp.asarray(y)
    key = jax.random.PRNGKey(0)
    cfg = FWConfig(max_iters=200, delta=2.0, tol=0.0, patience=10**9,
                   fuse_steps=8)
    ref = engine.solve(fw_lasso.LASSO, Xt, yj, cfg, key)

    # no-fault parity
    res = guards.solve_resilient(fw_lasso.LASSO, Xt, yj, cfg, key)
    results["no_fault_parity"] = bool(
        np.array_equal(np.asarray(ref.alpha), np.asarray(res.alpha)))

    # co_nan -> rung-1 rebuild
    plan = faults.FaultPlan([faults.FaultSpec(kind="co_nan", at=1)],
                            seed=seed)
    with faults.inject(plan):
        res = guards.solve_resilient(fw_lasso.LASSO, Xt, yj, cfg, key)
    results["co_nan_healed"] = bool(
        plan.fired("co_nan")
        and np.isfinite(float(res.objective))
        and abs(float(res.objective) - float(ref.objective))
        <= 1e-4 * abs(float(ref.objective)))

    # beta_nan -> rung-2 retry, bit-identical
    plan = faults.FaultPlan([faults.FaultSpec(kind="beta_nan", at=1)],
                            seed=seed)
    with faults.inject(plan):
        res = guards.solve_resilient(fw_lasso.LASSO, Xt, yj, cfg, key)
    results["beta_nan_bitident"] = bool(
        plan.fired("beta_nan")
        and np.array_equal(np.asarray(ref.alpha), np.asarray(res.alpha)))

    # shard corruption -> checksum + retry heal
    with tempfile.TemporaryDirectory() as d:
        r, c = np.nonzero(Xd)
        coo = sio.COOData(r.astype(np.int64), c.astype(np.int64),
                          Xd[r, c].astype(np.float32), y, Xd.shape)
        sio.write_shards(d, coo, rows_per_shard=16)
        mf = sio.read_manifest(d)
        clean = sio.load_shards(d)
        plan = faults.FaultPlan(
            [faults.FaultSpec(kind="shard_corrupt", site=mf["shards"][0])],
            seed=seed)
        with faults.inject(plan):
            healed = sio.load_shards(d)
        results["shard_corrupt_healed"] = bool(
            plan.fired("shard_corrupt")
            and np.array_equal(clean.vals, healed.vals))

    # mid-path kill -> checkpoint/resume bit-identical
    deltas = np.geomspace(0.5, 3.0, 6)
    pcfg = FWConfig(max_iters=100, delta=1.0, tol=0.0, patience=10**9,
                    fuse_steps=4)
    clean_path = path_lib.fw_path(Xt, yj, deltas, pcfg, seed=5)
    with tempfile.TemporaryDirectory() as ck:
        plan = faults.FaultPlan([faults.FaultSpec(kind="kill", at=3)],
                                seed=seed)
        killed = False
        try:
            with faults.inject(plan):
                path_lib.fw_path(Xt, yj, deltas, pcfg, seed=5,
                                 checkpoint_dir=ck)
        except faults.InjectedKill:
            killed = True
        resumed = path_lib.fw_path(Xt, yj, deltas, pcfg, seed=5,
                                   checkpoint_dir=ck, resume_from=ck)
    results["kill_resume_bitident"] = bool(
        killed
        and len(resumed.points) == len(clean_path.points)
        and all(
            np.array_equal(a.alpha_nnz_val, b.alpha_nnz_val)
            and np.array_equal(a.alpha_nnz_idx, b.alpha_nnz_idx)
            and a.n_dots == b.n_dots
            for a, b in zip(clean_path.points, resumed.points)))
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="reports/chaos_metrics.json",
                    help="metrics snapshot artifact path")
    args = ap.parse_args(argv)

    seed = int(os.environ.get(faults.ENV_SEED, "0"))
    reg = obs_metrics.MetricsRegistry()
    with obs_metrics.use_registry(reg):
        results = run_scenarios(seed)

    snapshot = obs_export.snapshot_json(reg)
    payload = {
        "fault_seed": seed,
        "scenarios": results,
        "all_healed": all(results.values()),
        "metrics": snapshot,
    }
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "wt") as fh:
        json.dump(payload, fh, indent=2)

    for name, ok in sorted(results.items()):
        print(f"  {'PASS' if ok else 'FAIL'}  {name}")
    print(f"chaos smoke: {'all healed' if payload['all_healed'] else 'FAILURES'}"
          f" (seed={seed}) -> {args.out}")
    return 0 if payload["all_healed"] else 1


if __name__ == "__main__":
    sys.exit(main())
