"""Solver run-report CLI (DESIGN.md §Observability).

Runs a small traced lasso solve per requested backend with the
telemetry ring on, then renders the artifacts:

    <out-dir>/solver_report.md      human-facing markdown report
    <out-dir>/solver_report.json    the same data, machine-readable
    <out-dir>/solver_trace.json     Chrome/Perfetto trace_event JSON

Usage (from the repo root):

    PYTHONPATH=src python scripts/solver_report.py --out-dir reports
    PYTHONPATH=src python scripts/solver_report.py --backends xla,sparse \
        --distributed --iters 300

``--distributed`` re-runs the solve on a forced 4-virtual-CPU-device
(1, 4) mesh in a subprocess (this process keeps its device count) and
adds the run — including the analytic per-iteration comm fraction — to
the same report.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_REPO_ROOT, os.path.join(_REPO_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def build_problem(m: int, p: int, seed: int = 0):
    import numpy as np

    from repro.data import make_regression, standardize

    ds = standardize(
        make_regression(m=m, p=p, n_informative=20, noise=0.5, seed=seed)
    )
    Xs = np.asarray(ds.X.T, np.float32).copy()
    y = np.asarray(ds.y, np.float32)
    return Xs, y


def _cfg(args, backend: str):
    from repro.core import FWConfig
    from repro.obs import TelemetrySpec

    return FWConfig(
        delta=args.delta,
        kappa=args.kappa,
        sampling="uniform",
        max_iters=args.iters,
        tol=0.0,
        patience=10**9,
        backend=backend,
        step_rule=args.rule,
        telemetry=TelemetrySpec(capacity=args.iters),
    )


def run_backend(backend: str, Xs, y, args) -> dict:
    """One traced, telemetry-on solve; returns a report ``runs`` entry."""
    import jax
    import jax.numpy as jnp

    from repro.core import LASSO, engine
    from repro.obs import ring_to_records, trace as obs_trace
    from repro.sparse.matrix import SparseBlockMatrix

    if backend == "sparse":
        import numpy as np

        Xsp = Xs.copy()
        Xsp[np.abs(Xsp) < 0.04] = 0.0
        A = SparseBlockMatrix.from_dense(Xsp, block_size=32)
    else:
        A = jnp.asarray(Xs)
    cfg = _cfg(args, backend)
    key = jax.random.PRNGKey(args.seed)
    yj = jnp.asarray(y)
    tracer = obs_trace.get_tracer()
    with tracer.span(f"report/compile_{backend}", cat="report"):
        res = engine.solve(LASSO, A, yj, cfg, key)
        res.alpha.block_until_ready()
    t0 = time.perf_counter()
    with tracer.span(f"report/solve_{backend}", cat="report"):
        res = engine.solve(LASSO, A, yj, cfg, key)
        res.alpha.block_until_ready()
    dt = time.perf_counter() - t0
    records = ring_to_records(res.telemetry)
    return {
        "name": f"lasso_{backend}",
        "backend": backend,
        "iterations": int(res.iterations),
        "n_dots": int(res.n_dots),
        "objective": float(res.objective),
        "seconds": dt,
        "ring": {k: v.tolist() for k, v in records.items()},
    }


# -- distributed subprocess -------------------------------------------------

_DIST_CHILD_FLAG = "--_dist-child"


def _dist_child(args) -> None:
    """Child body: forced 4-device mesh, one traced distributed solve,
    run entry printed as JSON on stdout (REPORTRESULT line)."""
    import jax
    import numpy as np

    from repro import distributed as dist
    from repro.core import LASSO
    from repro.obs import ring_to_records
    from repro.sparse.matrix import SparseBlockMatrix

    Xs, y = build_problem(args.m, args.p, args.seed)
    Xs[np.abs(Xs) < 0.04] = 0.0
    mat = SparseBlockMatrix.from_dense(Xs, block_size=32)
    mesh = dist.fw_mesh(1, 4)
    op = dist.shard_sparse(mat, y, mesh)
    cfg = _cfg(args, "xla")  # driver swaps in backend='distributed'
    key = jax.random.PRNGKey(args.seed)
    res = dist.solve(LASSO, op, cfg, key)
    res.alpha.block_until_ready()
    t0 = time.perf_counter()
    res = dist.solve(LASSO, op, cfg, key)
    res.alpha.block_until_ready()
    dt = time.perf_counter() - t0
    # analytic per-iteration comm budget (DESIGN.md §Distributed): the
    # |S| score psum over both axes, the (m_local,) column psum over
    # "model", and the O(1) scalar psums of the oracle recursions
    comm = 4 * (args.kappa + op.m_local + 8)
    local = 8 * args.kappa * op.nnz_max + 4 * 4 * op.m_local
    entry = {
        "name": "lasso_distributed_1x4",
        "backend": "distributed",
        "iterations": int(res.iterations),
        "n_dots": int(res.n_dots),
        "objective": float(res.objective),
        "seconds": dt,
        "comm_fraction": comm / (comm + local),
        "ring": {
            k: v.tolist() for k, v in ring_to_records(res.telemetry).items()
        },
    }
    print("REPORTRESULT" + json.dumps(entry), flush=True)


def run_distributed(args):
    env = {
        **os.environ,
        "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
        "JAX_PLATFORMS": "cpu",
        "PYTHONPATH": os.pathsep.join(sys.path),
    }
    cmd = [sys.executable, os.path.abspath(__file__), _DIST_CHILD_FLAG,
           "--m", str(args.m), "--p", str(args.p), "--iters", str(args.iters),
           "--kappa", str(args.kappa), "--delta", str(args.delta),
           "--rule", args.rule, "--seed", str(args.seed)]
    proc = subprocess.run(
        cmd, capture_output=True, text=True,
        timeout=int(os.environ.get("REPRO_SUBPROC_TIMEOUT", "900")), env=env,
    )
    lines = [l for l in proc.stdout.splitlines()
             if l.startswith("REPORTRESULT")]
    if proc.returncode != 0 or not lines:
        raise RuntimeError(
            f"distributed child failed (rc={proc.returncode}): "
            f"{proc.stderr[-800:]}"
        )
    return json.loads(lines[0][len("REPORTRESULT"):])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out-dir", default="reports")
    ap.add_argument("--backends", default="xla,pallas,sparse",
                    help="comma-separated: xla,pallas,sparse")
    ap.add_argument("--distributed", action="store_true",
                    help="add a 4-virtual-device (1,4)-mesh run (subprocess)")
    ap.add_argument("--m", type=int, default=128)
    ap.add_argument("--p", type=int, default=512)
    ap.add_argument("--iters", type=int, default=200)
    ap.add_argument("--kappa", type=int, default=48)
    ap.add_argument("--delta", type=float, default=100.0)
    ap.add_argument("--rule", default="classic")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(_DIST_CHILD_FLAG, action="store_true",
                    help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if getattr(args, "_dist_child"):
        _dist_child(args)
        return 0

    from benchmarks.common import bench_provenance
    from repro.obs import build_report, trace as obs_trace, write_report

    tracer = obs_trace.Tracer("solver-report")
    runs = []
    with obs_trace.use_tracer(tracer):
        Xs, y = build_problem(args.m, args.p, args.seed)
        for backend in [b for b in args.backends.split(",") if b]:
            print(f"# running {backend} ...", flush=True)
            runs.append(run_backend(backend, Xs, y, args))
        if args.distributed:
            print("# running distributed (1,4) mesh ...", flush=True)
            runs.append(run_distributed(args))

    meta = bench_provenance()
    meta.update(m=args.m, p=args.p, iters=args.iters, kappa=args.kappa,
                rule=args.rule)
    report = build_report(meta=meta, runs=runs, tracer=tracer)
    paths = write_report(args.out_dir, report)
    trace_path = tracer.save(os.path.join(args.out_dir, "solver_trace.json"))
    errors = obs_trace.validate_chrome_trace(tracer.to_chrome())
    if errors:
        print("trace validation FAILED:", *errors, sep="\n  ")
        return 1
    print(f"# wrote {paths['markdown']}")
    print(f"# wrote {paths['json']}")
    print(f"# wrote {trace_path} (Perfetto-loadable)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
