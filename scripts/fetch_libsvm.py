"""Fetch the paper's real LIBSVM datasets and convert them to shards.

Downloads E2006-tfidf / E2006-log1p (the paper's Table 1 text datasets)
from the LIBSVM regression repository, streams the bz2 text straight
into the ``coo-npz-v1`` shard layout via
``repro.sparse.io.convert_svmlight_to_shards`` (never holding more than
one shard of rows in memory), and verifies the converted (m, p) against
the published sizes. Benchmarks automatically prefer the converted
shards over synthetic proxies once they exist (benchmarks/common.py
checks ``$REPRO_DATA_DIR``, default ``data/libsvm``).

Usage:
    PYTHONPATH=src python scripts/fetch_libsvm.py [--dataset NAME] \
        [--out-dir data/libsvm] [--rows-per-shard 4096]

No network (or a partial download) is not an error for the other
datasets: each dataset is fetched independently and failures are
reported at the end. Nothing here densifies — the 4.27M-feature log1p
set converts on shard-sized RAM.
"""
from __future__ import annotations

import argparse
import bz2
import os
import shutil
import sys
import tempfile
import urllib.error
import urllib.request

from repro.sparse.io import (
    convert_svmlight_to_shards,
    read_manifest,
    verify_shards,
)

LIBSVM_BASE = "https://www.csie.ntu.edu.tw/~cjlin/libsvmtools/datasets/regression"

# name -> (url file, published (m, p) of the training split, 1-based cols)
DATASETS = {
    "e2006-tfidf": (f"{LIBSVM_BASE}/E2006.train.bz2", (16_087, 150_360)),
    "e2006-log1p": (f"{LIBSVM_BASE}/log1p.E2006.train.bz2", (16_087, 4_272_227)),
}

_CHUNK = 1 << 20  # 1 MiB streaming copy blocks


def _download_and_decompress(url: str, txt_path: str, timeout: float) -> None:
    """Stream url -> bz2-decode -> text file, never holding the file in RAM."""
    with urllib.request.urlopen(url, timeout=timeout) as resp:
        decomp = bz2.BZ2Decompressor()
        with open(txt_path, "wb") as out:
            while True:
                block = resp.read(_CHUNK)
                if not block:
                    break
                out.write(decomp.decompress(block))


def fetch_one(
    name: str,
    out_dir: str,
    rows_per_shard: int,
    timeout: float,
    force: bool = False,
) -> str:
    """Download + convert + verify one dataset; returns the shard dir."""
    url, (m_pub, p_pub) = DATASETS[name]
    shard_dir = os.path.join(out_dir, name)
    manifest_path = os.path.join(shard_dir, "manifest.json")
    if os.path.exists(manifest_path) and not force:
        manifest = read_manifest(shard_dir)
        bad = verify_shards(shard_dir, manifest=manifest)
        if not bad:
            print(f"[{name}] shards already present "
                  f"({manifest['m']} x {manifest['p']})")
            return shard_dir
        print(f"[{name}] {len(bad)} shard(s) failed their manifest sha256 "
              f"({', '.join(bad[:3])}{'...' if len(bad) > 3 else ''}) — "
              "re-fetching", file=sys.stderr)

    tmp_dir = tempfile.mkdtemp(prefix=f"{name}-")
    txt_path = os.path.join(tmp_dir, f"{name}.svmlight")
    try:
        print(f"[{name}] downloading {url} ...")
        _download_and_decompress(url, txt_path, timeout)
        size_mb = os.path.getsize(txt_path) / 1e6
        print(f"[{name}] decompressed {size_mb:.1f} MB, converting to shards ...")
        # published p counts features 1..p of the 1-based LIBSVM convention;
        # stating n_features pads features absent from the training split
        convert_svmlight_to_shards(
            txt_path,
            shard_dir,
            rows_per_shard=rows_per_shard,
            zero_based=False,
            n_features=p_pub,
        )
        manifest = read_manifest(shard_dir)
        m, p = manifest["m"], manifest["p"]
        if (m, p) != (m_pub, p_pub):
            raise RuntimeError(
                f"{name}: converted shape ({m}, {p}) does not match the "
                f"published ({m_pub}, {p_pub}) — refusing to keep bad shards"
            )
        bad = verify_shards(shard_dir, manifest=manifest)
        if bad:
            # write-then-read damage (flaky disk): one re-convert from the
            # already-downloaded text, then give up loudly
            print(f"[{name}] {len(bad)} fresh shard(s) failed their sha256 "
                  "— re-converting once", file=sys.stderr)
            shutil.rmtree(shard_dir, ignore_errors=True)
            convert_svmlight_to_shards(
                txt_path,
                shard_dir,
                rows_per_shard=rows_per_shard,
                zero_based=False,
                n_features=p_pub,
            )
            bad = verify_shards(shard_dir)
            if bad:
                raise RuntimeError(
                    f"{name}: shards still fail their manifest sha256 after "
                    f"re-conversion ({', '.join(bad[:3])}) — bad disk?"
                )
        print(f"[{name}] OK: {m} samples x {p} features -> {shard_dir}")
        return shard_dir
    except Exception:
        # never leave a half-written shard dir that benchmarks would trust
        shutil.rmtree(shard_dir, ignore_errors=True)
        raise
    finally:
        shutil.rmtree(tmp_dir, ignore_errors=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dataset", choices=sorted(DATASETS), default=None,
                    help="fetch one dataset (default: all)")
    ap.add_argument("--out-dir",
                    default=os.environ.get("REPRO_DATA_DIR", "data/libsvm"))
    ap.add_argument("--rows-per-shard", type=int, default=4096)
    ap.add_argument("--timeout", type=float, default=60.0,
                    help="per-connection timeout in seconds")
    ap.add_argument("--force", action="store_true",
                    help="re-download even if a manifest already exists")
    args = ap.parse_args(argv)

    names = [args.dataset] if args.dataset else sorted(DATASETS)
    failures = []
    for name in names:
        try:
            fetch_one(name, args.out_dir, args.rows_per_shard,
                      args.timeout, force=args.force)
        except (urllib.error.URLError, TimeoutError, ConnectionError, OSError) as e:
            print(f"[{name}] SKIPPED (network unavailable?): {e}", file=sys.stderr)
            failures.append(name)
        except (RuntimeError, ValueError) as e:
            print(f"[{name}] FAILED: {e}", file=sys.stderr)
            failures.append(name)
    if failures:
        print(f"incomplete: {', '.join(failures)} — benchmarks will keep "
              "using the synthetic proxies for these", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
