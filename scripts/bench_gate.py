"""CI perf-regression gate over the BENCH_history.jsonl trajectory.

Compares the hot-loop metrics of the current BENCH_*.json artifacts
against a rolling baseline built from prior ``BENCH_history.jsonl``
lines (``benchmarks/history.py``), with noise-aware thresholds:

  * baseline  = min over the last ``--window`` historical values
    (min-of-k: the fastest the code has provably run — robust to the
    one-sided noise of shared CI machines, where runs get slower, not
    faster, by accident);
  * band      = baseline * rel_tol  +  mad_mult * MAD(window)
    (a relative floor plus a median-absolute-deviation term that widens
    the band exactly when the trajectory itself is noisy).

A metric regresses when ``current > baseline + band``. Gated metrics
are the hot-loop rows: records carrying ``us_per_iter``
(``hotloop/fused_k*`` and ``solver/fw_solve_*`` from kernels_bench,
plus anything else that opts in by emitting the field). Whole-path
``seconds`` rows ride the history for trend plots but are NOT gated —
CI-scale end-to-end paths are compile-noise-dominated.

With fewer than ``--min-runs`` historical runs for a metric the gate
passes (warming up) — a fresh branch never fails on an empty baseline.

Corrupt or truncated history lines (a bench run killed mid-append, a
hand-edit gone wrong) are skipped with a stderr warning by the loader
(``benchmarks.history.load_history``) — a damaged trajectory file
degrades the baseline window, it never crashes the gate.

Exit codes: 0 = pass, 1 = regression, 2 = usage/IO error.

Usage (CI):
  python scripts/bench_gate.py --current BENCH_kernels.json
  python scripts/bench_gate.py --history BENCH_history.jsonl \
      --current BENCH_kernels.json BENCH_table5.json --rel-tol 0.5
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _REPO_ROOT not in sys.path:
    sys.path.insert(0, _REPO_ROOT)

from benchmarks import history as bench_history  # noqa: E402

GATE_FIELDS = ("us_per_iter",)


def median(values: Sequence[float]) -> float:
    vs = sorted(values)
    n = len(vs)
    if n == 0:
        return float("nan")
    mid = n // 2
    return vs[mid] if n % 2 else 0.5 * (vs[mid - 1] + vs[mid])


def mad(values: Sequence[float]) -> float:
    """Median absolute deviation — the robust spread estimate the band
    uses (one slow outlier run must not widen the gate forever)."""
    m = median(values)
    return median([abs(v - m) for v in values])


@dataclass
class GateResult:
    metric: str
    current: float
    baseline: float  # NaN while warming up
    band: float
    n_history: int
    regressed: bool
    warming_up: bool

    def describe(self) -> str:
        if self.warming_up:
            return (
                f"WARMUP  {self.metric}: {self.current:.1f} "
                f"({self.n_history} historical runs, gate needs more)"
            )
        verdict = "REGRESS" if self.regressed else "ok"
        ratio = self.current / self.baseline if self.baseline else float("inf")
        return (
            f"{verdict:7s} {self.metric}: {self.current:.1f} vs "
            f"baseline {self.baseline:.1f} (+band {self.band:.1f}, "
            f"{ratio:.2f}x, n={self.n_history})"
        )


def check_metric(
    metric: str,
    current: float,
    history_values: Sequence[float],
    *,
    min_runs: int = 3,
    window: int = 10,
    rel_tol: float = 0.5,
    mad_mult: float = 5.0,
) -> GateResult:
    """Gate one metric against its history (pure — unit-testable with
    synthetic trajectories)."""
    if len(history_values) < min_runs:
        return GateResult(
            metric, current, float("nan"), float("nan"),
            len(history_values), regressed=False, warming_up=True,
        )
    win = list(history_values[-window:])
    baseline = min(win)
    band = baseline * rel_tol + mad_mult * mad(win)
    return GateResult(
        metric, current, baseline, band, len(history_values),
        regressed=current > baseline + band, warming_up=False,
    )


def check_run(
    current_metrics: Dict[str, float],
    history_series: Dict[str, List[float]],
    **kw,
) -> List[GateResult]:
    """Gate every current hot-loop metric; metrics with no history at
    all come back warming-up."""
    return [
        check_metric(metric, value, history_series.get(metric, []), **kw)
        for metric, value in sorted(current_metrics.items())
    ]


def _drop_own_line(runs: List[dict], payload: dict, source: str) -> List[dict]:
    """Remove the history line the current artifact itself appended
    (BenchJSON.write appends BEFORE the gate runs — a run must not serve
    as its own baseline). Exact-identity match on provenance + records,
    newest first, at most one line — same-second sibling runs with
    different numbers stay in the baseline."""
    for i in range(len(runs) - 1, -1, -1):
        run = runs[i]
        if (
            run.get("source") == source
            and run.get("provenance") == payload.get("provenance")
            and run.get("records") == payload.get("records")
        ):
            return runs[:i] + runs[i + 1:]
    return runs


def gate_files(
    current_paths: Sequence[str],
    history_file: Optional[str] = None,
    **kw,
) -> List[GateResult]:
    """Load current BENCH_*.json artifacts + the history file, exclude
    the current runs' own history lines, and gate."""
    runs = bench_history.load_history(history_file)
    current_metrics: Dict[str, float] = {}
    for path in current_paths:
        with open(path, "rt") as fh:
            payload = json.load(fh)
        source = os.path.basename(path)
        runs = _drop_own_line(runs, payload, source)
        current_metrics.update(
            bench_history.run_metrics(
                {"source": source, **payload}, GATE_FIELDS
            )
        )
    series = bench_history.metric_series(runs, GATE_FIELDS)
    return check_run(current_metrics, series, **kw)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", nargs="+", required=True,
                    help="BENCH_*.json artifacts of the run under test")
    ap.add_argument("--history", default=None,
                    help="BENCH_history.jsonl (default: benchmarks/"
                         "history.history_path())")
    ap.add_argument("--min-runs", type=int, default=3)
    ap.add_argument("--window", type=int, default=10)
    ap.add_argument("--rel-tol", type=float, default=0.5,
                    help="relative band floor over the min-of-window "
                         "baseline (default 0.5 — CI CPU timing noise)")
    ap.add_argument("--mad-mult", type=float, default=5.0,
                    help="MAD multiplier added to the band")
    args = ap.parse_args(argv)

    for path in args.current:
        if not os.path.exists(path):
            print(f"bench_gate: missing artifact {path}", file=sys.stderr)
            return 2
    results = gate_files(
        args.current, args.history, min_runs=args.min_runs,
        window=args.window, rel_tol=args.rel_tol, mad_mult=args.mad_mult,
    )
    if not results:
        print("bench_gate: no gated metrics in current artifacts "
              f"(fields: {', '.join(GATE_FIELDS)})")
        return 0
    regressions = [r for r in results if r.regressed]
    for r in results:
        print(r.describe())
    print(
        f"bench_gate: {len(results)} metrics, "
        f"{sum(r.warming_up for r in results)} warming up, "
        f"{len(regressions)} regressions"
    )
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
