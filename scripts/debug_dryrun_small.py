"""Small-scale shakeout of the dry-run path: 8 host devices, reduced
configs, tiny shapes — exercises the exact lower+compile code path."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch import cells as cell_lib
from repro.launch.cells import ShapeSpec
from repro.models import sharding as sh
from repro.training.train_step import make_prefill_step, make_serve_step, make_train_step
from repro.launch.dryrun import _opt_shardings
from repro.analysis import roofline as rf

mesh = jax.make_mesh((2, 4), ("data", "model"))

SMALL_SHAPES = {
    "train": ShapeSpec("train", "train", 64, 8),
    "prefill": ShapeSpec("prefill", "prefill", 128, 8),
    "decode": ShapeSpec("decode", "decode", 128, 8),
}

fails = 0
for arch in ARCH_IDS:
    cfg = get_config(arch).reduced(ssm_chunk=16)
    for sname, shape in SMALL_SHAPES.items():
        t0 = time.time()
        try:
            params_spec = cell_lib.params_spec_for(cfg)
            with mesh:
                if shape.kind == "train":
                    pshard = sh.param_shardings(params_spec, mesh, fsdp=True)
                    opt_spec = cell_lib.opt_spec_for(cfg, params_spec)
                    oshard = _opt_shardings(opt_spec, params_spec, mesh, fsdp=True)
                    batch_spec = cell_lib.batch_specs_for(cfg, shape)
                    bshard = jax.tree.map(lambda s: NamedSharding(mesh, s), sh.batch_specs(batch_spec, mesh))
                    step = make_train_step(cfg, microbatches=2)
                    lowered = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                                      out_shardings=(pshard, oshard, None),
                                      donate_argnums=(0, 1)).lower(params_spec, opt_spec, batch_spec)
                elif shape.kind == "prefill":
                    pshard = sh.param_shardings(params_spec, mesh, fsdp=False)
                    batch_spec = cell_lib.batch_specs_for(cfg, shape)
                    bshard = jax.tree.map(lambda s: NamedSharding(mesh, s), sh.batch_specs(batch_spec, mesh))
                    step = make_prefill_step(cfg, max_seq=shape.seq_len)
                    lowered = jax.jit(step, in_shardings=(pshard, bshard)).lower(params_spec, batch_spec)
                else:
                    pshard = sh.param_shardings(params_spec, mesh, fsdp=False)
                    tokens_spec, cache_spec = cell_lib.decode_inputs_for(cfg, shape)
                    cshard = jax.tree.map(lambda s: NamedSharding(mesh, s), sh.cache_specs(cache_spec, mesh))
                    tshard = NamedSharding(mesh, P("data", None))
                    step = make_serve_step(cfg)
                    lowered = jax.jit(step, in_shardings=(pshard, tshard, cshard),
                                      out_shardings=(None, None, cshard),
                                      donate_argnums=(2,)).lower(params_spec, tokens_spec, cache_spec)
                compiled = lowered.compile()
                cost = compiled.cost_analysis()
                hlo = compiled.as_text()
                terms = rf.roofline_terms(cost, hlo)
                print(f"OK   {arch:22s} {sname:8s} {time.time()-t0:5.1f}s "
                      f"flops/dev={terms.flops_per_device:.2e} wire={terms.wire_bytes_per_device:.2e}")
        except Exception as e:
            fails += 1
            print(f"FAIL {arch:22s} {sname:8s} {type(e).__name__}: {str(e)[:300]}")
            traceback.print_exc(limit=3)

print(f"\n{fails} failures")
sys.exit(1 if fails else 0)
