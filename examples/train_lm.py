"""End-to-end LM training driver: data pipeline -> train_step -> checkpoints
-> resume, on a CPU-runnable model from the assigned-arch families.

    PYTHONPATH=src python examples/train_lm.py --arch mamba2_130m --steps 120
    # kill it mid-run and re-run: it resumes from the latest checkpoint.

~20M params by default; --d-model/--layers scale it up (the dry-run covers
the full-size configs).
"""
import argparse
import dataclasses
import time

import jax

from repro.configs import ARCH_IDS, get_config
from repro.data.lm_pipeline import PrefetchingLoader, batch_at_step
from repro.runtime import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="mamba2_130m")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--ckpt-dir", default="checkpoints/example_lm")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(
        d_model=args.d_model,
        n_layers=args.layers,
        d_ff=args.d_model * 3 if get_config(args.arch).d_ff else 0,
        vocab_size=4096,
        head_dim=64,
    )

    def data_fn(step):
        return batch_at_step(cfg, step, batch=args.batch, seq_len=args.seq, seed=0)

    trainer = Trainer(
        cfg,
        TrainerConfig(
            total_steps=args.steps,
            checkpoint_every=max(args.steps // 4, 10),
            checkpoint_dir=f"{args.ckpt_dir}/{args.arch}",
            base_lr=args.lr,
            async_checkpoint=True,
        ),
        data_fn,
    )
    _, _, start = trainer.init_or_restore()
    from repro.utils import tree_param_count
    params, _, _ = trainer.init_or_restore()[0], None, None
    print(f"[train_lm] arch={args.arch} params={tree_param_count(params)/1e6:.1f}M "
          f"start_step={start}")
    t0 = time.time()
    trainer.run()
    n = len(trainer.history)
    dt = time.time() - t0
    print(f"[train_lm] {n} steps in {dt:.1f}s ({dt/max(n,1)*1000:.0f} ms/step)")
    print(f"[train_lm] loss: {trainer.history[0]:.3f} -> {trainer.history[-1]:.3f} "
          f"(copy-motif data is learnable; expect a clear drop)")
    print(f"[train_lm] stragglers flagged: {len(trainer.monitor.stragglers)}; "
          f"checkpoints: {trainer.ckpt.save_count} (async)")


if __name__ == "__main__":
    main()
