"""Batched serving example: prefill a batch of prompts, then decode with
the one-token serve_step (greedy) against the preallocated KV cache.

    PYTHONPATH=src python examples/serve_lm.py --arch deepseek_7b --tokens 24
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import model as M
from repro.training import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="deepseek_7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.n_prefix_embeds:
        batch["patches"] = jax.random.normal(key, (args.batch, cfg.n_prefix_embeds, cfg.d_model))
    if cfg.n_enc_layers:
        batch["frames"] = jax.random.normal(key, (args.batch, 16, cfg.d_model))

    max_seq = args.prompt_len + args.tokens + cfg.n_prefix_embeds + 8
    t0 = time.perf_counter()
    logits, cache = M.prefill(params, batch, cfg, max_seq=max_seq)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"[serve] prefill {args.batch}x{args.prompt_len}: {t_prefill*1000:.1f} ms")

    serve = jax.jit(make_serve_step(cfg), donate_argnums=(2,))
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.tokens - 1):
        tok, _, cache = serve(params, tok, cache)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    total = args.batch * (args.tokens - 1)
    print(f"[serve] decoded {total} tokens in {dt:.2f}s "
          f"({dt / max(args.tokens-1,1) * 1000:.1f} ms/step, "
          f"{total/dt:.0f} tok/s batched)")
    gen = jnp.concatenate(out, axis=1)
    print(f"[serve] sample generations (token ids): {gen[0, :10].tolist()} ...")


if __name__ == "__main__":
    main()
