"""Quickstart: stochastic Frank-Wolfe Lasso vs coordinate descent.

Solves one constrained Lasso problem and a small regularization path on
synthetic data (paper §5.1 setup), printing objective / sparsity / dot
products for each solver.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CDConfig, FISTAConfig, FWConfig, baselines, fw_solve
from repro.core import path as path_lib
from repro.core.sampling import kappa_confidence, kappa_percentile
from repro.data.synthetic import paper_synthetic


def main():
    print("== data: synthetic, m=200, p=10000, 100 informative (paper §5.1)")
    ds = paper_synthetic(10_000, 100, seed=0)
    Xt = jnp.asarray(np.ascontiguousarray(ds.X.T))
    y = jnp.asarray(ds.y)
    p, m = Xt.shape
    key = jax.random.PRNGKey(0)

    # --- single problem at a mid-path delta -------------------------------
    lam_grid = path_lib.lambda_grid(Xt, y, n_points=10)
    cd = baselines.cd_solve(Xt, y, CDConfig(lam=float(lam_grid[3]), max_sweeps=300, tol=1e-6), key)
    delta = float(jnp.sum(jnp.abs(cd.alpha)))
    print(f"   CD at lam={lam_grid[3]:.1f}: obj={float(cd.objective):.4f} "
          f"active={int(cd.active)} -> equivalent delta={delta:.2f}")

    kappa = kappa_percentile(0.02, 0.98)  # the paper's 194
    print(f"   kappa (top-2%, 98% confidence): {kappa}")
    for sampling, label in (("full", "deterministic FW"), ("uniform", f"stochastic FW k={kappa}")):
        cfg = FWConfig(delta=delta, kappa=kappa, sampling=sampling, max_iters=50_000, tol=1e-4)
        t0 = time.perf_counter()
        res = fw_solve(Xt, y, cfg, key)
        dt = time.perf_counter() - t0
        print(f"   {label:28s} obj={float(res.objective):.4f} active={int(res.active):4d} "
              f"iters={int(res.iterations):5d} dots={int(res.n_dots):9d} time={dt:.2f}s")

    # --- short path with warm starts ---------------------------------------
    print("== regularization path (10 points, paper protocol)")
    deltas = path_lib.delta_grid(delta, n_points=10)
    t0 = time.perf_counter()
    fw_path = path_lib.fw_path(Xt, y, deltas, FWConfig(delta=1.0, kappa=kappa, max_iters=50_000, tol=1e-3))
    print(f"   FW path: {time.perf_counter()-t0:.2f}s  mean_active={fw_path.mean_active:.1f} "
          f"dots={fw_path.total_dots}")
    t0 = time.perf_counter()
    cd_path = path_lib.cd_path(Xt, y, lam_grid, CDConfig(lam=0.0, max_sweeps=200, tol=1e-3))
    print(f"   CD path: {time.perf_counter()-t0:.2f}s  mean_active={cd_path.mean_active:.1f} "
          f"dots={cd_path.total_dots}")
    print(f"   dot-product advantage FW vs CD: "
          f"{cd_path.total_dots / max(fw_path.total_dots, 1):.1f}x")


if __name__ == "__main__":
    main()
