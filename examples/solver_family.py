"""Solver family on one engine: lasso / logistic / elastic-net, dense
and sparse, through the shared backend-dispatched FW hot loop
(DESIGN.md §Engine).

The paper (§6) presents logistic regression and the elastic-net as
"easily obtained" extensions of Algorithm 2 — same randomized
linear-minimization oracle, same O(m) state recursions, different
gradient-vs-state and line search. This example shows exactly that:
each solver is the same engine under a different problem oracle, so the
block-ELL sparse backend and the batched multi-delta path driver (with
converged-lane pruning) work for all three without per-solver code.

    PYTHONPATH=src python examples/solver_family.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ENOracle, FWConfig, LOGISTIC, engine, fw_solve
from repro.core import path as path_lib
from repro.core.fw_elasticnet import en_solve
from repro.core.fw_logistic import logistic_solve
from repro.data import make_sparse_proxy


def main():
    print("== data: sparse-native e2006-tfidf proxy (block-ELL, no dense X)")
    ds = make_sparse_proxy("e2006-tfidf", scale=0.02, seed=0)
    mat, y = ds.mat, jnp.asarray(ds.y)
    p, m = mat.shape
    print(f"   p={p} features, m={m} samples, nnz_max={mat.nnz_max}, "
          f"storage={mat.nbytes/1e6:.1f} MB (dense would be {4*p*m/1e6:.1f} MB)")
    Xt_dense = mat.to_dense()  # feasible at example scale, for comparison only
    y_cls = jnp.sign(y) + (y == 0)  # {-1,+1} labels for the logistic oracle
    key = jax.random.PRNGKey(0)
    delta = 0.5 * float(np.abs(np.asarray(ds.coef)).sum())

    # --- one engine, three oracles, two backends each ---------------------
    base = dict(delta=delta, kappa=max(64, p // 100), sampling="uniform",
                max_iters=10_000, tol=1e-4)
    runs = [
        ("lasso", lambda A, cfg: fw_solve(A, y, cfg, key)),
        ("logistic", lambda A, cfg: logistic_solve(A, y_cls, cfg, key)),
        ("elastic-net l2=1", lambda A, cfg: en_solve(A, y, cfg, 1.0, key)),
    ]
    for name, solve in runs:
        for backend, A in (("xla", Xt_dense), ("sparse", mat)):
            cfg = FWConfig(backend=backend, **base)
            res = solve(A, cfg)  # compile
            t0 = time.perf_counter()
            res = solve(A, cfg)
            res.alpha.block_until_ready()
            dt = time.perf_counter() - t0
            print(f"   {name:16s} {backend:6s}: obj={float(res.objective):12.4f} "
                  f"active={int(res.active):4d} iters={int(res.iterations):5d} "
                  f"{dt*1e3:7.1f} ms")

    # --- family regularization paths on the batched pruned driver ---------
    print("== batched multi-delta paths (converged lanes pruned early)")
    deltas = path_lib.delta_grid(delta, n_points=8)
    cfg = FWConfig(delta=1.0, kappa=max(64, p // 100), sampling="uniform",
                   max_iters=10_000, tol=1e-4, backend="sparse")
    for name, oracle, yy in (
        ("lasso", None, y),
        ("logistic", LOGISTIC, y_cls),
        ("elastic-net", ENOracle(l2=1.0), y),
    ):
        res = path_lib.fw_path_batched(mat, yy, deltas, cfg, lane_width=4,
                                       oracle=oracle)
        objs = [pt.objective for pt in res.points]
        print(f"   {name:12s}: {len(res.points)} grid points in "
              f"{res.total_seconds:.2f}s, saved {res.saved_iters} lane-iters, "
              f"obj {objs[0]:.3g} -> {objs[-1]:.3g}")

    # --- fused sparse colstats kernel (setup pass) ------------------------
    from repro.sparse import ops as sops

    zty_k, zn2_k = sops.sparse_colstats(mat, y, use_kernel=True, interpret=True)
    zty_r, zn2_r = sops.sparse_colstats(mat, y)
    print("== fused sparse colstats kernel max |diff| vs XLA sweep:",
          float(jnp.max(jnp.abs(zty_k - zty_r))),
          float(jnp.max(jnp.abs(zn2_k - zn2_r))))


if __name__ == "__main__":
    main()
