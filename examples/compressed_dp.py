"""Data-parallel training with top-k gradient compression + error feedback
under shard_map — the psum really does see the sparse values, so the wire
bytes drop by ~1/ratio on a bandwidth-limited DP fabric (DESIGN.md §6).

Runs on 4 forced host devices (separate process recommended):

    PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        python examples/compressed_dp.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.compression import compress_decompress, init_compression
from repro.compression.topk import wire_bytes_saved


def main():
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    rng = np.random.default_rng(0)
    d_in, d_out, n = 64, 8, 512
    W_true = rng.standard_normal((d_in, d_out)).astype(np.float32)
    X = rng.standard_normal((n, d_in)).astype(np.float32)
    Y = X @ W_true

    params = {"w": jnp.zeros((d_in, d_out))}
    comp_state = init_compression(params)

    def local_grad(w, x, y):
        pred = x @ w
        return x.T @ (pred - y) / x.shape[0]

    def step(params, comp_state, x, y):
        def body(w, err, x_l, y_l):
            g = {"w": local_grad(w, x_l, y_l)}
            sparse, new_state = compress_decompress(
                g, type(comp_state)(error={"w": err}), ratio=0.05, min_k=4
            )
            # the all-reduce happens on the SPARSE tensor
            g_avg = jax.lax.pmean(sparse["w"], "data")
            return g_avg, new_state.error["w"]

        g_avg, new_err = shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(), P("data"), P("data")),
            out_specs=(P(), P()),
        )(params["w"], comp_state.error["w"], x, y)
        params = {"w": params["w"] - 0.3 * g_avg}
        return params, type(comp_state)(error={"w": new_err})

    step = jax.jit(step)
    for t in range(600):
        params, comp_state = step(params, comp_state, X, Y)
        if t % 150 == 149:
            err = float(jnp.linalg.norm(params["w"] - W_true) / np.linalg.norm(W_true))
            print(f"[compressed_dp] step {t+1}: rel_err={err:.4f}")

    dense, comp = wire_bytes_saved({"w": params["w"]}, 0.05)
    print(f"[compressed_dp] wire bytes/step: dense={dense} compressed~={comp} "
          f"({dense/comp:.0f}x reduction), devices={jax.device_count()}")


if __name__ == "__main__":
    main()
