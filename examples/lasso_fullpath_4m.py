"""The paper's headline experiment (abstract): the COMPLETE regularization
path on a problem with millions of variables in about a minute.

E2006-log1p-like proxy at full feature count (p = 4,272,227). Two builds:

* dense — reduced sample count (m) so the (m, p) matrix fits RAM; the
  per-iteration cost of stochastic FW is O(kappa * m), so the scaling
  story is faithful.
* ``--backend sparse`` — the block-ELL sparse build (DESIGN.md §Sparse)
  at the dataset's TRUE column density: storage is O(nnz), so the
  paper-size problem needs ~100s of MB instead of ~18 GB and the
  per-iteration cost drops to O(kappa * nnz_max).

    PYTHONPATH=src python examples/lasso_fullpath_4m.py            # p=1M default
    PYTHONPATH=src python examples/lasso_fullpath_4m.py --paper-size  # p=4.27M (needs ~18GB RAM)
    PYTHONPATH=src python examples/lasso_fullpath_4m.py --paper-size --backend sparse  # fits anywhere
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FWConfig, path as path_lib
from repro.core.sampling import kappa_fraction
from repro.data.proxies import make_sparse_coo
from repro.data.synthetic import Dataset, standardize
from repro.sparse import SparseBlockMatrix


def make_wide_problem(p: int, m: int, n_rel: int, seed: int = 0) -> Dataset:
    """fp32 end-to-end (the generic standardize() upcasts to f64 — too slow
    at gigabyte scale); columns come out zero-mean unit-norm directly."""
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((m, p), dtype=np.float32)
    X -= X.mean(axis=0, dtype=np.float32)
    X /= np.sqrt((X * X).sum(axis=0, dtype=np.float32)) + 1e-12
    coef = np.zeros(p, np.float32)
    support = rng.choice(p, n_rel, replace=False)
    coef[support] = rng.standard_normal(n_rel).astype(np.float32) * 10
    y = X @ coef + 0.05 * rng.standard_normal(m).astype(np.float32)
    y -= y.mean()
    return Dataset(X, y.astype(np.float32), None, None, coef, f"wide-{p}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper-size", action="store_true", help="p=4,272,227")
    ap.add_argument("--p", type=int, default=500_000)
    ap.add_argument("--m", type=int, default=800)
    ap.add_argument("--points", type=int, default=100)
    ap.add_argument("--frac", type=float, default=0.01, help="|S| as fraction of p")
    ap.add_argument("--driver", choices=("sequential", "batched"), default="batched",
                    help="fw_path (one delta at a time) or fw_path_batched lanes")
    ap.add_argument("--backend", choices=("xla", "pallas", "sparse"), default="xla",
                    help="iteration engine; 'pallas' uses the fused TPU kernels, "
                         "'sparse' the block-ELL subsystem (no dense build)")
    ap.add_argument("--density", type=float, default=0.002,
                    help="column density for --backend sparse (E2006-log1p: 0.002)")
    args = ap.parse_args()
    p = 4_272_227 if args.paper_size else args.p

    t0 = time.perf_counter()
    if args.backend == "sparse":
        print(f"== generating SPARSE wide problem p={p:,} m={args.m} "
              f"density={args.density:g} (dense would be "
              f"{p * args.m * 4 / 1e9:.1f} GB)")
        rows, cols, vals, y_np, coef = make_sparse_coo(
            args.m, p, args.density, n_relevant=300, seed=0
        )
        Xt = SparseBlockMatrix.from_coo(rows, cols, vals, (args.m, p), block_size=256)
        y = jnp.asarray(y_np)
        print(f"   built in {time.perf_counter()-t0:.1f}s "
              f"({Xt.nbytes / 1e9:.2f} GB block-ELL, nnz_max={Xt.nnz_max})")
    else:
        print(f"== generating wide problem p={p:,} m={args.m} "
              f"({p * args.m * 4 / 1e9:.1f} GB design matrix)")
        ds = make_wide_problem(p, args.m, n_rel=300)
        Xt = jnp.asarray(np.ascontiguousarray(ds.X.T))
        y = jnp.asarray(ds.y)
        coef = ds.coef
        print(f"   built in {time.perf_counter()-t0:.1f}s")

    kappa = kappa_fraction(p, args.frac)
    # delta_max: the generator's true coefficients give an oracle l1 budget.
    # 0.5x keeps the path in the sparse regime where FW shines (the paper's
    # use case); the loose/dense end is FW's known slow regime (EXPERIMENTS
    # §Perf). A CD reference solve (the paper's protocol) is exercised at
    # smaller scale in benchmarks/ — too expensive at p~10^6 for a demo.
    delta_max = 0.5 * float(np.abs(coef).sum())
    deltas = path_lib.delta_grid(delta_max, n_points=args.points)
    # pallas wants aligned blocks (uniform degrades to width-1 bricks that
    # leave the MXU idle — DESIGN.md §4.5); block sampling preserves Lemma 1
    sampling = "block" if args.backend == "pallas" else "uniform"
    cfg = FWConfig(delta=1.0, kappa=kappa, sampling=sampling,
                   max_iters=5000, tol=1e-3, backend=args.backend)

    print(f"== full path: {args.points} points, kappa={kappa:,} ({args.frac:.0%} of p), "
          f"driver={args.driver}, backend={args.backend}")
    t0 = time.perf_counter()
    if args.driver == "batched":
        res = path_lib.fw_path_batched(Xt, y, deltas, cfg)
    else:
        res = path_lib.fw_path(Xt, y, deltas, cfg)
    dt = time.perf_counter() - t0
    print(f"   PATH DONE in {dt:.1f}s  ({dt/args.points*1000:.0f} ms/point)")
    print(f"   total iters={res.total_iters} dots={res.total_dots:,} "
          f"mean_active={res.mean_active:.1f}")
    last = res.points[-1]
    print(f"   densest point: active={last.active} obj={last.objective:.4f}")


if __name__ == "__main__":
    main()
