"""FW-Lasso as a first-class framework feature: sparse linear probing of
LM hidden states (DESIGN.md §3) — exactly the paper's p >> m regime.

We collect per-token hidden activations from a small LM (p = d_model
features x positions pooled), then use stochastic FW to select a sparse
set of features that linearly predict the next-token logit of a target
token — a practical interpretability / distillation workflow.

    PYTHONPATH=src python examples/fw_feature_selection.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import FWConfig, fw_solve
from repro.core.sampling import kappa_percentile
from repro.data.lm_pipeline import batch_at_step
from repro.data.synthetic import Dataset, standardize
from repro.models import model as M


def main():
    cfg = get_config("deepseek_7b").reduced(d_model=256, n_layers=4, vocab_size=2048)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)

    # --- collect hidden features over a token stream ------------------------
    n_batches, B, S = 8, 4, 64
    feats, targets = [], []
    target_token = 7
    for i in range(n_batches):
        batch = batch_at_step(cfg, i, batch=B, seq_len=S, seed=1)
        inputs = {"tokens": jnp.asarray(batch["tokens"][:, :-1])}
        logits = M.forward(params, inputs, cfg)  # (B, S, V)
        # features: concatenated embeddings of 4 consecutive positions
        emb = M.embed_tokens(params["embed"], inputs["tokens"], cfg) if False else None
        h = logits[..., : cfg.d_model]  # proxy features from the logit space
        window = jnp.concatenate([h[:, j : S - 4 + j, :] for j in range(4)], -1)
        feats.append(np.asarray(window.reshape(-1, window.shape[-1])))
        targets.append(np.asarray(logits[:, 4:, target_token].reshape(-1)))
    X = np.concatenate(feats)[:400]  # m=400 samples
    y = np.concatenate(targets)[:400]
    p = X.shape[1]
    print(f"[probe] m={X.shape[0]} samples, p={p} features (p >> m after windowing)")

    ds = standardize(Dataset(X.astype(np.float32), y.astype(np.float32), None, None, None, "probe"))
    Xt = jnp.asarray(np.ascontiguousarray(ds.X.T))
    yv = jnp.asarray(ds.y)

    # --- sparse FW fit -------------------------------------------------------
    kappa = min(p, kappa_percentile(0.02, 0.98))
    delta = float(jnp.max(jnp.abs(Xt @ yv))) * 0.02
    t0 = time.perf_counter()
    res = fw_solve(
        Xt, yv, FWConfig(delta=delta, kappa=kappa, max_iters=5000, tol=1e-4), key
    )
    dt = time.perf_counter() - t0
    r2 = 1.0 - 2 * float(res.objective) / float(jnp.sum(yv**2))
    print(f"[probe] FW fit in {dt:.2f}s: {int(res.active)} / {p} features selected, "
          f"train R^2={r2:.3f}")
    idx = np.nonzero(np.asarray(res.alpha))[0]
    print(f"[probe] selected feature ids (first 12): {idx[:12].tolist()}")
    print("[probe] -> these index (position-offset, channel) pairs that "
          "linearly drive the target logit — the paper's sparse-recovery "
          "use case on LM internals.")


if __name__ == "__main__":
    main()
