"""Sampling-size rules (paper §4.5) + property tests of solver invariants."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st  # skips @given tests when hypothesis is missing

from repro.core import FWConfig, fw_solve
from repro.core.fw_lasso import _sample_indices
from repro.core.sampling import (
    kappa_blocks,
    kappa_confidence,
    kappa_fraction,
    kappa_percentile,
)


class TestKappaRules:
    def test_paper_percentile_example(self):
        """Paper: kappa ~= 194 gives top-2% w.p. 0.98, independent of p."""
        assert kappa_percentile(0.02, 0.98) == 194

    def test_confidence_rule_examples(self):
        # paper §5.1: p=10000, s=32 relevant, rho=0.99 -> ~1437? They report
        # 372 for avg active ~ |S*| estimated from the path; just check math.
        k = kappa_confidence(10_000, 124, 0.99)
        expected = math.ceil(math.log(0.01) / math.log(1 - 124 / 10_000))
        assert k == expected

    def test_confidence_monotonic_in_rho(self):
        ks = [kappa_confidence(50_000, 100, r) for r in (0.5, 0.9, 0.99)]
        assert ks == sorted(ks)

    def test_confidence_worst_case_linear_in_p(self):
        """Eq. (13): for fixed s, kappa grows ~ linearly with p."""
        k1 = kappa_confidence(10_000, 10, 0.95)
        k2 = kappa_confidence(20_000, 10, 0.95)
        assert 1.8 <= k2 / k1 <= 2.2

    def test_fraction(self):
        assert kappa_fraction(4_272_227, 0.01) == 42_723

    def test_blocks_rounding(self):
        assert kappa_blocks(100, 128) == 128
        assert kappa_blocks(129, 128) == 256

    def test_blocks_clamped_to_p(self):
        """Regression: without the clamp a kappa request > p implied more
        blocks than exist — inconsistent with the solver's nblocks clamp
        (fw_lasso._sample_block_starts) and a replace=False crash."""
        assert kappa_blocks(1000, 128, p=300) == 384  # ceil(300/128)*128
        assert kappa_blocks(1000, 128, p=128) == 128
        assert kappa_blocks(64, 128, p=2000) == 128  # clamp only binds above p
        assert kappa_blocks(257, 128, p=2000) == 384
        with pytest.raises(ValueError):
            kappa_blocks(64, 128, p=0)


class TestSamplingDistribution:
    def test_uniform_marginal(self):
        """Lemma 1 requirement: P(i in S) uniform across coordinates."""
        p, kappa, iters = 64, 16, 2000
        counts = np.zeros(p)
        cfg = FWConfig(delta=1.0, kappa=kappa, sampling="uniform")
        key = jax.random.PRNGKey(0)
        for _ in range(iters):
            key, sub = jax.random.split(key)
            idx = np.asarray(_sample_indices(sub, p, cfg))
            counts[idx] += 1
        freq = counts / counts.sum()
        np.testing.assert_allclose(freq, 1.0 / p, atol=3e-3)

    def test_block_marginal(self):
        p, iters = 128, 2000
        counts = np.zeros(p)
        cfg = FWConfig(delta=1.0, kappa=64, sampling="block", block_size=32)
        key = jax.random.PRNGKey(1)
        for _ in range(iters):
            key, sub = jax.random.split(key)
            idx = np.asarray(_sample_indices(sub, p, cfg))
            counts[idx] += 1
        freq = counts / counts.sum()
        np.testing.assert_allclose(freq, 1.0 / p, atol=3e-3)

    def test_block_indices_in_range(self):
        cfg = FWConfig(delta=1.0, kappa=96, sampling="block", block_size=32)
        idx = np.asarray(_sample_indices(jax.random.PRNGKey(2), 1000, cfg))
        assert idx.min() >= 0 and idx.max() < 1000


@st.composite
def _problems(draw):
    m = draw(st.integers(min_value=8, max_value=40))
    p = draw(st.integers(min_value=4, max_value=120))
    seed = draw(st.integers(min_value=0, max_value=2**16))
    delta = draw(st.floats(min_value=0.5, max_value=100.0))
    return m, p, seed, delta


class TestSolverProperties:
    @settings(max_examples=15, deadline=None)
    @given(_problems())
    def test_invariants_random_problems(self, prob):
        """Hypothesis sweep: feasibility + objective never above f(0)."""
        m, p, seed, delta = prob
        rng = np.random.default_rng(seed)
        Xt = jnp.asarray(rng.standard_normal((p, m)).astype(np.float32))
        y = jnp.asarray(rng.standard_normal(m).astype(np.float32))
        cfg = FWConfig(delta=delta, sampling="uniform",
                       kappa=min(p, 16), max_iters=300, tol=1e-5)
        res = fw_solve(Xt, y, cfg, jax.random.PRNGKey(seed))
        assert bool(jnp.isfinite(res.objective))
        assert float(jnp.sum(jnp.abs(res.alpha))) <= delta * (1 + 1e-4)
        f0 = 0.5 * float(y @ y)
        assert float(res.objective) <= f0 * (1 + 1e-5) + 1e-4
