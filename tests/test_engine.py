"""Engine refactor coverage (ISSUE 3 tentpole).

Four layers, none requiring hypothesis (these run in the minimal CI
image):
  * trajectory regression: the engine replays the PRE-refactor solvers'
    uniform-sampling runs exactly — goldens (selected coordinates,
    iteration/dot counts, objectives) were captured from the monolithic
    fw_lasso/fw_logistic/fw_elasticnet loops at the commit before the
    engine existed;
  * solver-family sparse-vs-dense parity: logistic and elastic-net on
    ``backend='sparse'`` replay the dense-XLA index stream (mirroring
    test_backend_parity for the lasso);
  * batched-vs-sequential path equivalence with converged-lane pruning
    on, for the lasso AND the extension oracles;
  * structural acceptance: the three solver modules define oracles only —
    no while_loop / sampling code of their own.
"""
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ENOracle,
    FWConfig,
    LOGISTIC,
    engine,
    fw_solve,
    path as path_lib,
)
from repro.core.fw_elasticnet import en_solve
from repro.core.fw_logistic import logistic_solve
from repro.sparse import ops as sops
from repro.sparse.matrix import SparseBlockMatrix

DELTA = 150.0


def _sparsified(Xt, threshold=0.7, block_size=64):
    Xs = np.asarray(Xt).copy()
    Xs[np.abs(Xs) < threshold] = 0.0
    return jnp.asarray(Xs), SparseBlockMatrix.from_dense(Xs, block_size=block_size)


def _logistic_data(m=120, p=80, seed=0, sparse_threshold=None):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((m, p)).astype(np.float32)
    if sparse_threshold is not None:
        X[np.abs(X) < sparse_threshold] = 0.0
    w = np.zeros(p, np.float32)
    w[:5] = rng.standard_normal(5) * 2
    y = np.sign(X @ w + 0.1 * rng.standard_normal(m)).astype(np.float32)
    y[y == 0] = 1.0
    return jnp.asarray(X.T.copy()), jnp.asarray(y)


class TestPreRefactorGoldens:
    """The engine must replay the pre-refactor trajectories exactly.

    Golden values captured from the monolithic solver loops (commit
    faae249, PYTHONPATH=src on the CI CPU image) immediately before the
    engine extraction. Integer trajectory facts (iterations, dot counts,
    selected support) are asserted exactly — any deviation in the index
    stream, argmax, or stopping rule changes them; float objectives use
    a 1e-6 relative tolerance to stay robust to BLAS build differences.
    """

    def test_lasso_uniform_fixed_iterations(self, small_problem, rng_key):
        Xt, y, _ = small_problem
        cfg = FWConfig(delta=DELTA, sampling="uniform", kappa=60,
                       max_iters=300, tol=0.0, patience=10**9)
        res = fw_solve(Xt, y, cfg, rng_key)
        assert int(res.iterations) == 300
        assert int(res.n_dots) == 18000
        a = np.asarray(res.alpha)
        assert np.nonzero(a)[0].tolist() == [70, 272]
        np.testing.assert_allclose(float(res.objective), 751729.4375, rtol=1e-6)
        # coefficient values re-pinned in ISSUE 5: the fused-einsum
        # znorm2 in precompute_colstats rounds ~1 ulp differently from
        # the old sum(Xt*Xt, axis=1) sweep, shifting the line-search
        # denominators ~3e-6 relatively; support/iterations/dots/objective
        # are unchanged
        np.testing.assert_allclose(
            a[[70, 272]], [98.5285415649414, 51.47145080566406], rtol=1e-6
        )

    def test_lasso_uniform_converging_run(self, small_problem, rng_key):
        cfg = FWConfig(delta=DELTA, sampling="uniform", kappa=60,
                       max_iters=5000, tol=1e-4)
        Xt, y, _ = small_problem
        res = fw_solve(Xt, y, cfg, rng_key)
        assert int(res.iterations) == 25
        assert int(res.n_dots) == 1500
        assert bool(res.converged)
        np.testing.assert_allclose(float(res.objective), 751729.4375, rtol=1e-6)

    def test_lasso_sparse_backend_golden(self, small_problem, rng_key):
        Xt, y, _ = small_problem
        mat = SparseBlockMatrix.from_dense(np.asarray(Xt), block_size=64)
        cfg = FWConfig(delta=DELTA, sampling="uniform", kappa=60,
                       max_iters=300, tol=0.0, patience=10**9, backend="sparse")
        res = fw_solve(mat, y, cfg, rng_key)
        assert int(res.iterations) == 300
        np.testing.assert_allclose(float(res.objective), 751729.375, rtol=1e-6)

    def test_logistic_uniform_golden(self, rng_key):
        Xt, y = _logistic_data()
        cfg = FWConfig(delta=20.0, sampling="uniform", kappa=40,
                       max_iters=500, tol=0.0, patience=10**9)
        res = logistic_solve(Xt, y, cfg, rng_key)
        assert int(res.iterations) == 500
        # 40 sampled + 20 bisect + 2 endpoint + 1 gap-stall dot per step
        # (pre-refactor golden was 31000 before the sampled-gap stall
        # statistic added its O(m) dot in PR 4)
        assert int(res.n_dots) == 31500
        assert int(res.active) == 37
        np.testing.assert_allclose(float(res.objective), 3.0054101943969727, rtol=1e-6)

    def test_elasticnet_uniform_golden(self, small_problem, rng_key):
        Xt, y, _ = small_problem
        cfg = FWConfig(delta=30.0, sampling="uniform", kappa=60,
                       max_iters=800, tol=0.0, patience=10**9)
        res = en_solve(Xt, y, cfg, 1.0, rng_key)
        assert int(res.iterations) == 800
        assert int(res.n_dots) == 48000
        assert int(res.active) == 2
        np.testing.assert_allclose(float(res.objective), 828006.375, rtol=1e-6)


class TestSolverFamilySparseParity:
    """logistic_solve / en_solve accept a SparseBlockMatrix with
    FWConfig(backend='sparse') and agree with their dense-XLA results
    ('uniform' replays the same index stream, so runs are comparable
    step for step)."""

    def test_elasticnet_sparse_matches_dense(self, small_problem, rng_key):
        Xt, y, _ = small_problem
        Xd, mat = _sparsified(Xt)
        base = dict(delta=30.0, sampling="uniform", kappa=60,
                    max_iters=2000, tol=1e-5)
        res_d = en_solve(Xd, y, FWConfig(**base), 1.0, rng_key)
        res_s = en_solve(mat, y, FWConfig(backend="sparse", **base), 1.0, rng_key)
        assert int(res_s.iterations) == int(res_d.iterations)
        rel = abs(float(res_s.objective) - float(res_d.objective)) / abs(
            float(res_d.objective)
        )
        assert rel < 1e-4
        assert float(jnp.sum(jnp.abs(res_s.alpha))) <= 30.0 * (1 + 1e-4)

    def test_logistic_sparse_matches_dense(self, rng_key):
        Xt, y = _logistic_data(sparse_threshold=0.7)
        mat = SparseBlockMatrix.from_dense(np.asarray(Xt), block_size=32)
        base = dict(delta=20.0, sampling="uniform", kappa=40,
                    max_iters=1500, tol=1e-6)
        res_d = logistic_solve(Xt, y, FWConfig(**base), rng_key)
        res_s = logistic_solve(mat, y, FWConfig(backend="sparse", **base), rng_key)
        rel = abs(float(res_s.objective) - float(res_d.objective)) / max(
            abs(float(res_d.objective)), 1e-9
        )
        assert rel < 1e-3
        assert float(jnp.sum(jnp.abs(res_s.alpha))) <= 20.0 * (1 + 1e-4)

    def test_logistic_sparse_block_sampling_converges(self, rng_key):
        """Block mode drives whole aligned ELL blocks (kernel-dispatchable)."""
        Xt, y = _logistic_data(sparse_threshold=0.7)
        mat = SparseBlockMatrix.from_dense(np.asarray(Xt), block_size=32)
        cfg = FWConfig(delta=20.0, sampling="block", kappa=64,
                       max_iters=2000, tol=1e-6, backend="sparse")
        res = logistic_solve(mat, y, cfg, rng_key)
        chance = y.shape[0] * np.log(2.0)
        assert float(res.objective) < 0.5 * chance

    def test_elasticnet_pallas_matches_xla(self, small_problem, rng_key):
        """The extra-term (+l2*a) score path through the Pallas sampled-
        scores kernel agrees with the XLA gather."""
        Xt, y, _ = small_problem
        base = dict(delta=30.0, sampling="block", kappa=64, block_size=32,
                    max_iters=2000, tol=1e-5)
        res_x = en_solve(Xt, y, FWConfig(**base), 1.0, rng_key)
        res_p = en_solve(Xt, y, FWConfig(backend="pallas", **base), 1.0, rng_key)
        rel = abs(float(res_p.objective) - float(res_x.objective)) / abs(
            float(res_x.objective)
        )
        assert rel < 1e-4

    def test_logistic_pallas_matches_xla(self, rng_key):
        """'uniform' replays the XLA index stream through the width-1
        kernel path; 'full' is deterministic modulo tail padding."""
        Xt, y = _logistic_data(p=300)
        for sampling, kw, tol in (
            ("uniform", dict(kappa=40), 1e-6),
            ("full", dict(block_size=128), 1e-4),
        ):
            base = dict(delta=10.0, sampling=sampling, max_iters=800,
                        tol=1e-6, **kw)
            res_x = logistic_solve(Xt, y, FWConfig(**base), rng_key)
            res_p = logistic_solve(Xt, y, FWConfig(backend="pallas", **base),
                                   rng_key)
            rel = abs(float(res_p.objective) - float(res_x.objective)) / max(
                abs(float(res_x.objective)), 1e-9
            )
            assert rel < tol, (sampling, rel)

    def test_logistic_delta_override_traced(self, rng_key):
        """One compiled logistic solver serves multiple deltas."""
        Xt, y = _logistic_data()
        cfg = FWConfig(delta=1.0, sampling="uniform", kappa=40,
                       max_iters=500, tol=1e-5)
        objs = [
            float(logistic_solve(Xt, y, cfg, rng_key, delta=d).objective)
            for d in (2.0, 8.0, 20.0)
        ]
        assert objs[0] >= objs[1] >= objs[2]  # larger budget, lower loss


class TestSparseColstatsKernel:
    def test_fused_kernel_matches_xla_sweep(self, rng_key):
        rng = np.random.default_rng(3)
        Xs = rng.standard_normal((130, 70)).astype(np.float32)  # p not | bs
        Xs[np.abs(Xs) < 1.0] = 0.0
        mat = SparseBlockMatrix.from_dense(Xs, block_size=32)
        y = jnp.asarray(rng.standard_normal(70).astype(np.float32))
        zty_k, zn2_k = sops.sparse_colstats(mat, y, use_kernel=True, interpret=True)
        zty_r, zn2_r = sops.sparse_colstats(mat, y)
        assert zty_k.shape == (130,) and zn2_k.shape == (130,)
        np.testing.assert_allclose(np.asarray(zty_k), np.asarray(zty_r),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(zn2_k), np.asarray(zn2_r),
                                   rtol=2e-5, atol=2e-5)

    def test_solver_end_to_end_with_kernel_colstats(self, small_problem, rng_key):
        """sparse_kernel=True routes BOTH the gradient and the colstats
        through the Pallas twins (interpret off-TPU)."""
        Xt, y, _ = small_problem
        _, mat = _sparsified(Xt)
        cfg = FWConfig(delta=DELTA, sampling="block", kappa=128,
                       max_iters=2000, tol=1e-5, backend="sparse",
                       sparse_kernel=True, interpret=True)
        ref = FWConfig(delta=DELTA, sampling="block", kappa=128,
                       max_iters=2000, tol=1e-5, backend="sparse",
                       sparse_kernel=False)
        res_k = fw_solve(mat, y, cfg, rng_key)
        res_r = fw_solve(mat, y, ref, rng_key)
        rel = abs(float(res_k.objective) - float(res_r.objective)) / abs(
            float(res_r.objective)
        )
        assert rel < 1e-4


class TestBatchedPathPruning:
    def test_lasso_batched_matches_sequential_with_pruning(self, small_problem):
        Xt, y, _ = small_problem
        deltas = path_lib.delta_grid(100.0, n_points=8)
        cfg = FWConfig(delta=1.0, kappa=60, max_iters=20000, tol=1e-4)
        seq = path_lib.fw_path(Xt, y, deltas, cfg)
        bat = path_lib.fw_path_batched(Xt, y, deltas, cfg, lane_width=4)
        assert seq.saved_iters == 0  # sequential driver never prunes
        # lanes converge at different iterations, so pruning must fire
        assert bat.saved_iters > 0
        for s, b in zip(seq.points, bat.points):
            rel = abs(b.objective - s.objective) / abs(s.objective)
            assert rel < 1e-3, (s.reg, rel)

    def test_elasticnet_batched_matches_sequential(self, small_problem):
        Xt, y, _ = small_problem
        oracle = ENOracle(l2=1.0)
        deltas = np.geomspace(3.0, 30.0, 6)
        cfg = FWConfig(delta=1.0, sampling="uniform", kappa=60,
                       max_iters=5000, tol=1e-5)
        seq = path_lib.fw_path(Xt, y, deltas, cfg, oracle=oracle)
        bat = path_lib.fw_path_batched(Xt, y, deltas, cfg, lane_width=3,
                                       oracle=oracle)
        for s, b in zip(seq.points, bat.points):
            rel = abs(b.objective - s.objective) / max(abs(s.objective), 1e-9)
            assert rel < 1e-3, (s.reg, rel)
        assert bat.saved_iters >= 0

    def test_logistic_path_objective_monotone(self, rng_key):
        Xt, y = _logistic_data()
        cfg = FWConfig(delta=1.0, sampling="uniform", kappa=40,
                       max_iters=1500, tol=1e-6)
        deltas = np.geomspace(1.0, 20.0, 4)
        res = path_lib.fw_path(Xt, y, deltas, cfg, oracle=LOGISTIC)
        objs = [pt.objective for pt in res.points]
        assert objs == sorted(objs, reverse=True)  # loss falls as delta grows


class TestOracleGap:
    """The oracle ``gap()`` protocol (ISSUE 4): certified duality gaps
    with each oracle's OWN gradient, replacing the lasso-only
    ``duality_gap`` special case."""

    def test_lasso_gap_matches_legacy_duality_gap(self, small_problem, rng_key):
        from repro.core import fw_lasso
        from repro.core.fw_lasso import LASSO as lasso_oracle

        Xt, y, _ = small_problem
        cfg = FWConfig(delta=DELTA, kappa=60, max_iters=2000, tol=1e-4)
        res = fw_solve(Xt, y, cfg, rng_key)
        state = fw_lasso.init_state(Xt, y, rng_key, alpha0=res.alpha)
        legacy = float(fw_lasso.duality_gap(Xt, state, DELTA))
        new = float(lasso_oracle.gap(Xt, y, res.alpha, DELTA))
        assert abs(new - legacy) <= 1e-6 * max(abs(legacy), 1.0)

    @pytest.mark.parametrize("which", ["logistic", "elasticnet"])
    def test_extension_gap_bounds_suboptimality(self, small_problem, rng_key, which):
        """FW duality: f(alpha) - f* <= g(alpha). A long high-accuracy run
        approximates f*; a short run's certified gap must cover its own
        suboptimality (each oracle's own gradient — the lasso formula
        would be wrong here)."""
        if which == "logistic":
            Xt, y = _logistic_data()
            oracle, delta = LOGISTIC, 8.0
            solve = lambda it, a0=None: logistic_solve(
                Xt, y, FWConfig(delta=delta, kappa=40, max_iters=it,
                                tol=0.0, patience=10**9), rng_key, alpha0=a0)
        else:
            Xt, y, _ = small_problem
            oracle, delta = ENOracle(l2=1.0), 30.0
            solve = lambda it, a0=None: en_solve(
                Xt, y, FWConfig(delta=delta, kappa=60, max_iters=it,
                                tol=0.0, patience=10**9), 1.0, rng_key, alpha0=a0)
        rough = solve(60)
        ref = solve(6000)
        gap = float(oracle.gap(Xt, y, rough.alpha, delta))
        subopt = float(rough.objective) - float(ref.objective)
        assert gap >= subopt - 1e-5 * max(abs(float(ref.objective)), 1.0)
        assert gap >= 0.0

    def test_report_gap_rides_solve_and_path(self, small_problem):
        """FWConfig.report_gap surfaces SolveResult.gap / PathPoint.gap."""
        Xt, y, _ = small_problem
        cfg = FWConfig(delta=DELTA, kappa=60, max_iters=2000, tol=1e-4,
                       report_gap=True)
        res = fw_solve(Xt, y, cfg, jax.random.PRNGKey(0))
        assert res.gap is not None and np.isfinite(float(res.gap))
        deltas = path_lib.delta_grid(100.0, n_points=4)
        for driver in (path_lib.fw_path, path_lib.fw_path_batched):
            pts = driver(Xt, y, deltas, cfg).points
            assert all(np.isfinite(pt.gap) for pt in pts)
            # converged grid points certify a noise-level gap
            assert all(abs(pt.gap) < 1e-4 * abs(pt.objective) for pt in pts)
        off = FWConfig(delta=DELTA, kappa=60, max_iters=200, tol=1e-4)
        assert fw_solve(Xt, y, off, jax.random.PRNGKey(0)).gap is None


class TestGapStall:
    """The gap_rtol noise-floor stall wired into the logistic and
    elastic-net line searches (ISSUE 4 satellite): a warm start from a
    converged iterate terminates in ~patience iterations instead of
    micro-oscillating to max_iters."""

    def test_elasticnet_warm_restart_stalls_immediately(self, small_problem, rng_key):
        Xt, y, _ = small_problem
        cfg = FWConfig(delta=30.0, sampling="uniform", kappa=60,
                       max_iters=4000, tol=1e-6)
        base = en_solve(Xt, y, cfg, 1.0, rng_key)
        assert bool(base.converged)
        warm = en_solve(Xt, y, cfg, 1.0, rng_key, alpha0=base.alpha)
        assert bool(warm.converged)
        # a handful of genuine refinement steps (the restart recomputes
        # the S/F scalars exactly) + the patience-long stall tail — far
        # from max_iters=4000
        assert int(warm.iterations) <= 3 * cfg.patience

    def test_logistic_warm_restart_stalls(self, rng_key):
        rng = np.random.default_rng(0)
        X = rng.standard_normal((60, 40)).astype(np.float32)
        w0 = np.zeros(40, np.float32)
        w0[:3] = rng.standard_normal(3) * 2
        y = np.sign(X @ w0 + 0.05 * rng.standard_normal(60)).astype(np.float32)
        y[y == 0] = 1.0
        Xt, yj = jnp.asarray(X.T.copy()), jnp.asarray(y)
        cfg = FWConfig(delta=2.0, sampling="uniform", kappa=20,
                       max_iters=6000, tol=1e-4, gap_rtol=1e-3)
        base = logistic_solve(Xt, yj, cfg, rng_key)
        assert bool(base.converged)
        warm = logistic_solve(Xt, yj, cfg, rng_key, alpha0=base.alpha)
        assert bool(warm.converged)
        assert int(warm.iterations) <= int(base.iterations) // 4


class TestFusedChunk:
    """ISSUE 5 tentpole: ``FWConfig.fuse_steps`` chunked drivers + the
    ``kernels/fused_step`` megakernel.

    Acceptance: fuse_steps=8 reproduces the fuse_steps=1 uniform-lasso
    trajectory BIT-IDENTICALLY on alpha (fixed-iteration runs, where the
    stopping rule never fires) with equal iteration/dot counts, on all
    three backends; converging runs may overshoot stall stops by at most
    K-1 iterations (stopping checked between chunks, DESIGN.md
    §Stopping). EN runs through the alpha-space ledger (rounding-level
    parity on the megakernel, bit-exact on the fori-of-step executor);
    logistic falls back to the per-step loop exactly.
    """

    FIXED = dict(delta=DELTA, sampling="uniform", kappa=60,
                 max_iters=300, tol=0.0, patience=10**9)

    def test_lasso_xla_bit_identical(self, small_problem, rng_key):
        # max_iters=300 is NOT a multiple of K=8: the trailing chunk's
        # masked steps must leave the trajectory and counters exact
        Xt, y, _ = small_problem
        r1 = fw_solve(Xt, y, FWConfig(**self.FIXED), rng_key)
        r8 = fw_solve(Xt, y, FWConfig(fuse_steps=8, **self.FIXED), rng_key)
        assert int(r8.iterations) == int(r1.iterations) == 300
        assert float(r8.n_dots) == float(r1.n_dots) == 18000
        np.testing.assert_array_equal(np.asarray(r8.alpha), np.asarray(r1.alpha))
        assert np.nonzero(np.asarray(r8.alpha))[0].tolist() == [70, 272]

    def test_lasso_pallas_megakernel_bit_identical(self, small_problem, rng_key):
        Xt, y, _ = small_problem
        base = dict(self.FIXED, max_iters=120, backend="pallas")
        r1 = fw_solve(Xt, y, FWConfig(**base), rng_key)
        r8 = fw_solve(Xt, y, FWConfig(fuse_steps=8, **base), rng_key)
        assert int(r8.iterations) == int(r1.iterations) == 120
        assert float(r8.n_dots) == float(r1.n_dots)
        np.testing.assert_array_equal(np.asarray(r8.alpha), np.asarray(r1.alpha))

    def test_lasso_sparse_bit_identical_both_executors(self, small_problem, rng_key):
        Xt, y, _ = small_problem
        mat = SparseBlockMatrix.from_dense(np.asarray(Xt), block_size=64)
        base = dict(self.FIXED, max_iters=120, backend="sparse")
        r1 = fw_solve(mat, y, FWConfig(**base), rng_key)
        # the default executor (XLA-gather sparse path) chunks through the
        # fori-of-step executor: bit-identical
        r8 = fw_solve(mat, y, FWConfig(fuse_steps=8, **base), rng_key)
        np.testing.assert_array_equal(np.asarray(r8.alpha), np.asarray(r1.alpha))
        # forced kernel dispatch drives the sparse megakernel (interpret).
        # Selections/step records replay exactly (same iterations, dots,
        # support); the in-kernel eq.-10 recursion may round 1 ulp apart
        # from the XLA sparse path (program-level FMA fusion — the same
        # caveat DESIGN.md documents for the distributed objective), so
        # alpha parity is rounding-level here.
        rk = fw_solve(
            mat, y,
            FWConfig(fuse_steps=8, sparse_kernel=True, interpret=True, **base),
            rng_key,
        )
        assert int(rk.iterations) == int(r1.iterations) == 120
        assert float(rk.n_dots) == float(r1.n_dots)
        a1, ak = np.asarray(r1.alpha), np.asarray(rk.alpha)
        assert np.nonzero(a1)[0].tolist() == np.nonzero(ak)[0].tolist()
        np.testing.assert_allclose(ak, a1, rtol=1e-5, atol=1e-5)

    def test_converging_overshoot_bounded(self, small_problem, rng_key):
        Xt, y, _ = small_problem
        base = dict(delta=DELTA, sampling="uniform", kappa=60,
                    max_iters=5000, tol=1e-4)
        r1 = fw_solve(Xt, y, FWConfig(**base), rng_key)
        r8 = fw_solve(Xt, y, FWConfig(fuse_steps=8, **base), rng_key)
        assert bool(r1.converged) and bool(r8.converged)
        assert int(r1.iterations) <= int(r8.iterations) <= int(r1.iterations) + 7
        rel = abs(float(r8.objective) - float(r1.objective)) / abs(
            float(r1.objective)
        )
        assert rel < 1e-6

    def test_elasticnet_fused_parity(self, small_problem, rng_key):
        Xt, y, _ = small_problem
        base = dict(delta=30.0, sampling="uniform", kappa=60,
                    max_iters=200, tol=0.0, patience=10**9)
        # fori-of-step executor: bit-exact
        e1 = en_solve(Xt, y, FWConfig(**base), 1.0, rng_key)
        e8 = en_solve(Xt, y, FWConfig(fuse_steps=8, **base), 1.0, rng_key)
        np.testing.assert_array_equal(np.asarray(e8.alpha), np.asarray(e1.alpha))
        # megakernel: the alpha-space score reconstruction reassociates
        # scale*beta, so parity is rounding-level, not bitwise
        p1 = en_solve(Xt, y, FWConfig(backend="pallas", **base), 1.0, rng_key)
        p8 = en_solve(
            Xt, y, FWConfig(backend="pallas", fuse_steps=8, **base), 1.0, rng_key
        )
        assert int(p8.iterations) == int(p1.iterations)
        rel = abs(float(p8.objective) - float(p1.objective)) / abs(
            float(p1.objective)
        )
        assert rel < 1e-5
        np.testing.assert_allclose(
            np.asarray(p8.alpha), np.asarray(p1.alpha), rtol=5e-4, atol=5e-4
        )

    def test_logistic_falls_back_bit_identical(self, rng_key):
        Xt, y = _logistic_data()
        base = dict(delta=20.0, sampling="uniform", kappa=40,
                    max_iters=200, tol=0.0, patience=10**9)
        l1 = logistic_solve(Xt, y, FWConfig(**base), rng_key)
        l8 = logistic_solve(Xt, y, FWConfig(fuse_steps=8, **base), rng_key)
        # no fused form (bisection line search): identical per-step loop,
        # no chunk overshoot anywhere
        assert int(l8.iterations) == int(l1.iterations)
        np.testing.assert_array_equal(np.asarray(l8.alpha), np.asarray(l1.alpha))

    def test_batched_path_fused_matches_sequential(self, small_problem):
        Xt, y, _ = small_problem
        deltas = path_lib.delta_grid(100.0, n_points=6)
        cfg1 = FWConfig(delta=1.0, kappa=60, max_iters=20000, tol=1e-4)
        cfg8 = FWConfig(delta=1.0, kappa=60, max_iters=20000, tol=1e-4,
                        fuse_steps=8)
        seq = path_lib.fw_path(Xt, y, deltas, cfg1)
        bat = path_lib.fw_path_batched(Xt, y, deltas, cfg8, lane_width=3)
        for s, b in zip(seq.points, bat.points):
            rel = abs(b.objective - s.objective) / abs(s.objective)
            assert rel < 1e-3, (s.reg, rel)
            # chunked lanes may overshoot their stall stop by <= K-1
            assert b.iterations <= s.iterations + 7

    def test_megakernel_matches_xla_ref(self, small_problem, rng_key):
        """kernels/fused_step kernel vs its pure-XLA mirror on the same
        pregenerated streams (dense + sparse layouts)."""
        from repro.core.fw_lasso import LASSO
        from repro.kernels import fused_step as fs

        Xt, y, _ = small_problem
        p, m = Xt.shape
        K, kappa = 8, 32
        rng = np.random.default_rng(5)
        resid = jnp.asarray(rng.standard_normal(m).astype(np.float32))
        idx = jnp.asarray(rng.integers(0, p, (K, kappa)), jnp.int32)
        stats = engine.precompute_colstats(Xt, y)
        zty_s = jnp.take(stats.zty, idx).astype(jnp.float32)
        zn2_s = jnp.take(stats.znorm2, idx).astype(jnp.float32)
        scal = (jnp.float32(3.0), jnp.float32(1.5), jnp.float32(0.0))
        kw = dict(oracle=LASSO, eps_den=1e-12, gap_rtol=1e-6,
                  refresh_every=64, max_iters=10**6)
        args = (y, resid, scal, idx, zty_s, zn2_s, None,
                jnp.int32(0), jnp.float32(40.0))
        def check(got, want):
            # selected coordinates + stall flags exact; float records and
            # the final residual/scalars to gather-order rounding
            np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
            np.testing.assert_array_equal(np.asarray(got[3]), np.asarray(want[3]))
            for g, w in ((got[1], want[1]), (got[2], want[2]), (got[4], want[4])):
                np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                           rtol=1e-5, atol=1e-5)
            for g, w in zip(got[5], want[5]):
                np.testing.assert_allclose(float(g), float(w), rtol=1e-4)

        got = fs.dense_fused_chunk(Xt, *args, interpret=True, **kw)
        want = fs.dense_fused_chunk_ref(Xt, *args, **kw)
        check(got, want)

        mat = SparseBlockMatrix.from_dense(np.asarray(Xt), block_size=64)
        got_s = fs.sparse_fused_chunk(mat.values, mat.rows, *args,
                                      interpret=True, **kw)
        want_s = fs.sparse_fused_chunk_ref(mat.values, mat.rows, *args, **kw)
        check(got_s, want_s)

    def test_n_dots_accounting_is_overflow_safe(self, small_problem, rng_key):
        """ISSUE 5 satellite: the dot counter no longer wraps int32 (p=4M
        full sampling overflows after ~500 iterations). Without x64 the
        counter is f32 — exact for every pinned golden, monotone and
        positive far past 2^31."""
        assert engine.dot_dtype() in (jnp.int64, jnp.float32)
        Xt, y, _ = small_problem
        cfg = FWConfig(delta=DELTA, sampling="full", max_iters=10, tol=0.0,
                       patience=10**9)
        res = fw_solve(Xt, y, cfg, rng_key)
        # full sampling scores every real coordinate once per iteration
        # (patience=1 under 'full', so the run may stop before max_iters)
        assert float(res.n_dots) == int(res.iterations) * Xt.shape[0]
        big = jnp.zeros((), engine.dot_dtype()) + 2.0**31
        stepped = big + 4_000_000
        assert float(stepped) > float(big) > 0  # int32 would have wrapped


class TestEngineStructure:
    """Acceptance: ONE hot loop — the solver modules define oracles only."""

    @pytest.mark.parametrize(
        "module", ["fw_lasso", "fw_logistic", "fw_elasticnet"]
    )
    def test_solver_modules_have_no_loop_or_sampling(self, module):
        import importlib

        src = inspect.getsource(importlib.import_module(f"repro.core.{module}"))
        assert "while_loop" not in src
        assert "random.randint" not in src and "random.choice" not in src

    def test_one_shared_engine_loop(self):
        src = inspect.getsource(engine)
        assert src.count("jax.lax.while_loop") == 2  # solve + solve_batched

    def test_oracles_are_static_jit_keys(self):
        assert hash(ENOracle(l2=0.5)) == hash(ENOracle(l2=0.5))
        assert ENOracle(l2=0.5) == ENOracle(l2=0.5)
        assert ENOracle(l2=0.5) != ENOracle(l2=1.0)
