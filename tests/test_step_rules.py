"""Step-rule protocol coverage (ISSUE 6 tentpole, DESIGN.md §StepRule).

Layers:
  * config validation: ``FWConfig`` rejects unknown ``backend`` /
    ``step_rule`` values at construction with the valid choices listed
    (ISSUE 6 satellite);
  * classic parity: ``step_rule='classic'`` is bit-identical to the
    default config (the rule IS ``engine.step`` — no trajectory change
    rides the refactor; the goldens in test_engine.py pin the absolute
    trajectory);
  * acceptance on a pinned correlated design (AR(1) rho=0.6 columns,
    strong sparse signal, delta well inside the unconstrained l1): away
    and pairwise reach the certified-gap tolerance in <= classic's
    iterations on BOTH single-device backends — away converges two
    orders of magnitude faster in iterations (the zig-zag fix the
    away/pairwise literature promises); partan and lazy also certify,
    lazy on a fraction of classic's dot budget (the cached LMO);
  * drop-step semantics: an away step that hits g_max zeroes the away
    coordinate EXACTLY (no float dust keeping the atom alive);
  * fused fallback: non-classic rules under ``fuse_steps > 1`` fall back
    to per-step execution with a one-time warning — never silently —
    and ``SolveResult.effective_fuse_steps`` reports what actually ran;
  * chunk-boundary stall semantics in ``engine.batched_loop``: lanes
    freeze at chunk granularity under ``fuse_steps=K``, matching the
    sequential fused solver per lane, iteration overshoot <= K-1
    (ISSUE 6 satellite).
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ENOracle, FWConfig, LASSO, LOGISTIC, engine, vertex
from repro.core import step_rule as step_rule_lib
from repro.core.solver_config import VALID_BACKENDS, VALID_STEP_RULES
from repro.sparse.matrix import SparseBlockMatrix

DELTA = 40.0
GAP_REL_TOL = 1e-4  # certified-gap acceptance: gap <= tol * objective


def _corr_design(m=300, p=120, rho=0.6, k=10, scale=50.0, seed=11):
    """Pinned correlated design: AR(1) columns (corr rho^|i-j|), strong
    sparse ground truth — the regime where classic FW zig-zags between
    correlated atoms and away/pairwise shine."""
    rng = np.random.default_rng(seed)
    Z = rng.standard_normal((m, p)).astype(np.float32)
    X = np.empty_like(Z)
    X[:, 0] = Z[:, 0]
    for j in range(1, p):
        X[:, j] = rho * X[:, j - 1] + np.sqrt(1 - rho**2) * Z[:, j]
    coef = np.zeros(p, np.float32)
    coef[rng.choice(p, k, replace=False)] = (
        rng.standard_normal(k).astype(np.float32) * scale
    )
    y = X @ coef + 1.0 * rng.standard_normal(m).astype(np.float32)
    return X.T.copy(), y.astype(np.float32)


@pytest.fixture(scope="module")
def corr():
    Xt, y = _corr_design()
    return Xt, y


def _rule_cfg(rule, backend="xla", **kw):
    base = dict(
        delta=DELTA, kappa=48, sampling="uniform", max_iters=1500,
        tol=1e-4, patience=20, step_rule=rule, backend=backend,
    )
    base.update(kw)
    return FWConfig(**base)


def _solve_rule(Xt, y, rule, backend="xla", **kw):
    cfg = _rule_cfg(rule, backend, **kw)
    op = (
        SparseBlockMatrix.from_dense(Xt, block_size=32)
        if backend == "sparse"
        else jnp.asarray(Xt)
    )
    res = engine.solve(LASSO, op, jnp.asarray(y), cfg, jax.random.PRNGKey(1))
    gap = float(LASSO.gap(op, jnp.asarray(y), res.alpha, DELTA, cfg))
    return res, gap


class TestConfigValidation:
    def test_bad_backend_raises_with_choices(self):
        with pytest.raises(ValueError) as ei:
            FWConfig(delta=1.0, backend="gpu")
        msg = str(ei.value)
        assert "backend" in msg and "'gpu'" in msg
        for b in VALID_BACKENDS:
            assert b in msg

    def test_bad_step_rule_raises_with_choices(self):
        with pytest.raises(ValueError) as ei:
            FWConfig(delta=1.0, step_rule="awaystep")
        msg = str(ei.value)
        assert "step_rule" in msg and "'awaystep'" in msg
        for r in VALID_STEP_RULES:
            assert r in msg

    @pytest.mark.parametrize("rule", VALID_STEP_RULES)
    def test_every_registered_rule_constructs_and_resolves(self, rule):
        cfg = FWConfig(delta=1.0, step_rule=rule)
        assert step_rule_lib.get_rule(cfg).name == rule


class TestClassicParity:
    def test_classic_rule_bit_identical_to_default(self, corr):
        Xt, y = corr
        r_default, _ = _solve_rule(Xt, y, "classic", max_iters=300)
        # same cfg leaves except the (default-valued) step_rule knob --
        # the rule dispatch layer must not perturb the trajectory
        cfg = _rule_cfg("classic", max_iters=300)
        assert cfg.step_rule == "classic"
        r_again = engine.solve(
            LASSO, jnp.asarray(Xt), jnp.asarray(y), cfg, jax.random.PRNGKey(1)
        )
        assert np.array_equal(np.asarray(r_default.alpha),
                              np.asarray(r_again.alpha))
        assert int(r_default.iterations) == int(r_again.iterations)
        assert int(r_default.n_dots) == int(r_again.n_dots)

    def test_rule_state_slot_defaults_empty(self):
        # back-compat: EngineState constructions without a rule slot get
        # the empty pytree, so pre-rule callers (kernels, drivers) are
        # untouched
        st = engine.EngineState(
            beta=jnp.zeros(4), scale=jnp.ones(()), co=None,
            maxabs=jnp.zeros(()), step_inf=jnp.zeros(()),
            stall=jnp.zeros((), jnp.int32), n_dots=jnp.zeros(()),
            k=jnp.zeros((), jnp.int32), key=jax.random.PRNGKey(0),
        )
        assert st.rule == ()


class TestRuleAcceptance:
    """ISSUE 6 acceptance: away/pairwise reach certified-gap tolerance in
    <= classic's iterations on the pinned correlated design, both
    single-device backends. (The distributed backend's away/pairwise
    parity vs single-device sparse is pinned in test_distributed.py.)"""

    @pytest.mark.parametrize("backend", ["xla", "sparse"])
    def test_away_and_pairwise_beat_classic(self, corr, backend):
        Xt, y = corr
        r_classic, gap_c = _solve_rule(Xt, y, "classic", backend)
        obj_c = float(r_classic.objective)
        for rule in ("away", "pairwise"):
            r, gap = _solve_rule(Xt, y, rule, backend)
            assert int(r.iterations) <= int(r_classic.iterations), rule
            assert gap <= GAP_REL_TOL * float(r.objective), (rule, gap)
            assert float(jnp.sum(jnp.abs(r.alpha))) <= DELTA * (1 + 1e-4)
            # same optimum basin as classic
            assert abs(float(r.objective) - obj_c) / obj_c < 1e-3, rule

    @pytest.mark.parametrize("backend", ["xla", "sparse"])
    def test_away_converges_several_times_faster(self, corr, backend):
        Xt, y = corr
        r_classic, gap_c = _solve_rule(Xt, y, "classic", backend)
        r_away, gap_a = _solve_rule(Xt, y, "away", backend)
        assert bool(r_away.converged)
        assert int(r_away.iterations) * 4 < int(r_classic.iterations)
        assert gap_a < gap_c

    @pytest.mark.parametrize("rule", ["partan", "lazy"])
    def test_partan_and_lazy_certify(self, corr, rule):
        Xt, y = corr
        r, gap = _solve_rule(Xt, y, rule)
        assert gap <= GAP_REL_TOL * float(r.objective), (rule, gap)
        # reported objective is consistent with the iterate (the partan
        # extrapolation recursion must not drift from alpha)
        true_obj = 0.5 * float(
            jnp.sum((jnp.asarray(Xt).T @ r.alpha - jnp.asarray(y)) ** 2)
        )
        assert abs(float(r.objective) - true_obj) / true_obj < 1e-3

    def test_lazy_saves_dots(self, corr):
        Xt, y = corr
        r_classic, _ = _solve_rule(Xt, y, "classic")
        r_lazy, _ = _solve_rule(Xt, y, "lazy")
        per_c = float(r_classic.n_dots) / float(r_classic.iterations)
        per_l = float(r_lazy.n_dots) / float(r_lazy.iterations)
        # cache hits skip the kappa-draw: well under classic's per-step
        # dot budget on average
        assert per_l < 0.6 * per_c, (per_l, per_c)


class TestDropStep:
    def test_away_drop_zeroes_coordinate_exactly(self):
        cfg = FWConfig(delta=10.0)
        beta = jnp.asarray([3.0, 0.7, -2.0])
        ds = step_rule_lib.DirStep(
            t=jnp.asarray(1.0),
            df=jnp.asarray(0.0),
            da=jnp.asarray(-10.0),
            i_f=jnp.asarray(0),
            i_a=jnp.asarray(1),
            a_f=jnp.asarray(3.0),
            a_a=jnp.asarray(0.7),
            sel_f=jnp.asarray(1.0),
            sel_a=jnp.asarray(1.0),
            same=jnp.asarray(0.0),
            g_max=jnp.asarray(0.7 / 9.3),
        )
        g = ds.g_max  # line search hit the clip: drop step
        beta2, scale2, _, _, _ = step_rule_lib.apply_dir_update(
            beta, jnp.ones(()), jnp.asarray(3.0), jnp.zeros((), jnp.int32),
            ds, g, jnp.asarray(False), cfg,
        )
        assert float(beta2[1]) == 0.0  # exact zero, not dust
        # the surviving coordinates scaled UP by (1 + g)
        assert float(scale2) == pytest.approx(1.0 + float(g), rel=1e-6)

    def test_away_run_prunes_support(self, corr):
        Xt, y = corr
        r_classic, _ = _solve_rule(Xt, y, "classic")
        r_away, _ = _solve_rule(Xt, y, "away")
        assert int(r_away.active) <= int(r_classic.active)


class TestFusedFallback:
    def test_classic_fuses(self, corr):
        Xt, y = corr
        r, _ = _solve_rule(Xt, y, "classic", max_iters=256, fuse_steps=8)
        assert int(r.effective_fuse_steps) == 8

    def test_non_classic_rule_warns_once_and_falls_back(self, corr):
        Xt, y = corr
        vertex._warned_unfused_rules.discard("away")
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            r, _ = _solve_rule(Xt, y, "away", max_iters=64, fuse_steps=8)
            r2, _ = _solve_rule(Xt, y, "away", max_iters=64, fuse_steps=8)
        msgs = [str(w.message) for w in caught
                if "does not compose" in str(w.message)]
        assert len(msgs) == 1  # one-time, not per-solve
        assert "away" in msgs[0] and "falling back" in msgs[0]
        assert int(r.effective_fuse_steps) == 1

    def test_logistic_oracle_reports_unfused(self, corr):
        # non-fusable oracle: effective_fuse_steps == 1 regardless of rule
        Xt, y = corr
        ylog = np.sign(y).astype(np.float32)
        cfg = _rule_cfg("classic", max_iters=64, fuse_steps=8, delta=5.0)
        res = engine.solve(
            LOGISTIC, jnp.asarray(Xt), jnp.asarray(ylog), cfg,
            jax.random.PRNGKey(0),
        )
        assert int(res.effective_fuse_steps) == 1


class TestBatchedChunkBoundaries:
    """ISSUE 6 satellite: chunk-boundary stall / patience overshoot in
    ``engine.batched_loop`` — lanes freeze at chunk granularity and every
    lane's result equals its own sequential fused solve."""

    def _lanes(self, corr, fuse_steps, max_iters=256, patience=7):
        Xt, y = corr
        cfg = FWConfig(
            delta=1.0, kappa=48, sampling="uniform", max_iters=max_iters,
            tol=1e-3, patience=patience, fuse_steps=fuse_steps,
        )
        deltas = jnp.asarray([10.0, 25.0, 40.0], jnp.float32)
        keys = jnp.stack([jax.random.PRNGKey(1)] * 3)
        alpha0s = jnp.zeros((3, Xt.shape[0]), jnp.float32)
        bat, _saved = engine.solve_batched(
            LASSO, jnp.asarray(Xt), jnp.asarray(y), cfg, keys, alpha0s, deltas
        )
        seqs = [
            engine.solve(LASSO, jnp.asarray(Xt), jnp.asarray(y), cfg,
                         jax.random.PRNGKey(1), None, d)
            for d in deltas
        ]
        return cfg, bat, seqs

    def test_lanes_match_sequential_with_patience_overshoot(self, corr):
        # patience=7 with K=4 chunks: lanes cross the patience threshold
        # MID-chunk and keep stepping to the boundary — the sequential
        # fused solver overshoots identically, so per-lane iteration /
        # dot counters agree exactly (same PRNG stream, same chunking).
        # Coefficients only to tolerance: the vmapped lane step compiles
        # to batched matmuls whose rounding differs from the scalar
        # solver's at the ulp level, and that accumulates over the run.
        cfg, bat, seqs = self._lanes(corr, fuse_steps=4)
        for lane, seq in enumerate(seqs):
            assert int(bat.iterations[lane]) == int(seq.iterations), lane
            assert int(bat.n_dots[lane]) == int(seq.n_dots), lane
            np.testing.assert_allclose(
                np.asarray(bat.alpha[lane]), np.asarray(seq.alpha),
                rtol=5e-3, atol=1e-2, err_msg=f"lane {lane}"
            )
            assert bool(bat.converged[lane]) == bool(seq.converged)

    def test_overshoot_bounded_by_chunk(self, corr):
        # a converged lane's iteration count exceeds the unfused stop
        # point by at most K-1 (trailing steps of the final chunk)
        K = 4
        cfg_f, bat_f, _ = self._lanes(corr, fuse_steps=K)
        cfg_1, bat_1, _ = self._lanes(corr, fuse_steps=1)
        for lane in range(3):
            if bool(bat_f.converged[lane]) and bool(bat_1.converged[lane]):
                over = int(bat_f.iterations[lane]) - int(bat_1.iterations[lane])
                assert 0 <= over <= K - 1, (lane, over)

    def test_chunked_lanes_report_effective_fuse_steps(self, corr):
        _, bat, _ = self._lanes(corr, fuse_steps=4, max_iters=64)
        assert int(bat.effective_fuse_steps) == 4
