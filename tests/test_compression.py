"""Top-k gradient compression with error feedback: invariants + training."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compression import compress_decompress, init_compression


def _grads(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w1": jnp.asarray(rng.standard_normal((32, 64)).astype(np.float32)),
        "w2": jnp.asarray(rng.standard_normal((128,)).astype(np.float32)),
    }


class TestCompression:
    def test_sparsity(self):
        g = _grads()
        state = init_compression(g)
        sparse, _ = compress_decompress(g, state, ratio=0.05)
        for leaf in jax.tree.leaves(sparse):
            nnz = int(jnp.sum(leaf != 0))
            assert nnz <= max(int(0.05 * leaf.size), 16) + 1

    def test_error_feedback_conserves_mass(self):
        """sent + error == grad + prev_error exactly (per leaf)."""
        g = _grads(1)
        state = init_compression(g)
        sparse, new_state = compress_decompress(g, state, ratio=0.1)
        for gg, s, e in zip(
            jax.tree.leaves(g), jax.tree.leaves(sparse), jax.tree.leaves(new_state.error)
        ):
            np.testing.assert_allclose(
                np.asarray(s + e), np.asarray(gg), rtol=1e-6, atol=1e-6
            )

    def test_error_drains_over_steps(self):
        """Repeatedly compressing the same gradient transmits everything
        eventually (error feedback drains)."""
        g = _grads(2)
        state = init_compression(g)
        total_sent = jax.tree.map(jnp.zeros_like, g)
        for _ in range(60):
            sparse, state = compress_decompress(g, state, ratio=0.05)
            total_sent = jax.tree.map(lambda t, s: t + s, total_sent, sparse)
        # after T rounds, cumulative sent ~ T * g (each coordinate eventually flows)
        err_norm = sum(
            float(jnp.linalg.norm(e)) for e in jax.tree.leaves(state.error)
        )
        g_norm = sum(float(jnp.linalg.norm(x)) for x in jax.tree.leaves(g))
        # EF steady-state error is O(||g|| / ratio) (Stich et al. 2018):
        # bounded, not growing linearly with the 60 rounds
        assert err_norm <= g_norm / 0.05 * 1.5

    def test_topk_selects_largest(self):
        x = {"w": jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05, 0.0])}
        state = init_compression(x)
        sparse, _ = compress_decompress(x, state, ratio=0.34, min_k=2)
        w = np.asarray(sparse["w"])
        assert w[1] == -5.0 and w[3] == 3.0
        assert np.count_nonzero(w) == 2

    def test_compressed_sgd_still_converges(self):
        """Least-squares SGD with 5% compression + EF reaches the solution."""
        rng = np.random.default_rng(3)
        A = jnp.asarray(rng.standard_normal((64, 32)).astype(np.float32))
        x_true = jnp.asarray(rng.standard_normal(32).astype(np.float32))
        b = A @ x_true

        def grad(x):
            return {"x": A.T @ (A @ x["x"] - b) / 64}

        x = {"x": jnp.zeros(32)}
        state = init_compression(grad(x))
        # EF introduces delayed spiky corrections: the stable lr is smaller
        # than the dense-SGD limit (documented in compression/topk.py).
        for t in range(2000):
            g = grad(x)
            sparse, state = compress_decompress(g, state, ratio=0.1, min_k=2)
            x = jax.tree.map(lambda p, s: p - 0.2 * s, x, sparse)
        err = float(jnp.linalg.norm(x["x"] - x_true) / jnp.linalg.norm(x_true))
        assert err < 1e-3, err
