"""Dry-run machinery integration tests (single process, 1 device):
roofline HLO parsing, model_flops accounting, cell plan coverage."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import roofline as rf
from repro.configs import ARCH_IDS, get_config
from repro.launch import cells as cell_lib


class TestHLOParsing:
    def test_parse_collectives_counts_and_bytes(self):
        hlo = """
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups={}
  %ag = bf16[64,512]{1,0} all-gather(%y), dimensions={0}
  %rs = f32[32]{0} reduce-scatter(%z), dimensions={0}
  %a2a = (f32[16,16]{1,0}, f32[16,16]{1,0}) all-to-all(%a, %b)
  %cp = u32[8]{0} collective-permute(%c), source_target_pairs={{0,1}}
  %dot = f32[128,128]{1,0} dot(%p, %q)
"""
        t = rf.parse_collectives(hlo)
        assert t["all-reduce"]["count"] == 1
        assert t["all-reduce"]["result_bytes"] == 128 * 256 * 4
        assert t["all-reduce"]["wire_bytes"] == 2 * 128 * 256 * 4
        assert t["all-gather"]["count"] == 1
        assert t["all-gather"]["wire_bytes"] == 64 * 512 * 2
        assert t["reduce-scatter"]["count"] == 1
        assert t["all-to-all"]["result_bytes"] == 2 * 16 * 16 * 4
        assert t["collective-permute"]["wire_bytes"] == 8 * 4

    def test_async_start_variants_counted(self):
        hlo = "%ar = f32[64]{0} all-reduce-start(%x)\n"
        t = rf.parse_collectives(hlo)
        assert t["all-reduce"]["count"] == 1

    def test_non_collective_lines_ignored(self):
        hlo = "%d = f32[1024,1024]{1,0} dot(%a, %b)\n%c = f32[4]{0} constant({1,2,3,4})\n"
        t = rf.parse_collectives(hlo)
        assert all(v["count"] == 0 for v in t.values())


class TestModelFlops:
    def test_train_flops_formula(self):
        cfg = get_config("deepseek_7b")
        f = rf.model_flops(cfg, "train", 4096, 256)
        n = rf.active_params(cfg)
        assert f == pytest.approx(6 * n * 4096 * 256)

    def test_decode_flops_per_token(self):
        cfg = get_config("qwen2_72b")
        f = rf.model_flops(cfg, "decode", 32768, 128)
        n = rf.active_params(cfg)
        assert f == pytest.approx(2 * n * 128)


class TestCellPlan:
    def test_40_cells(self):
        cells = list(cell_lib.iter_cells())
        assert len(cells) == 40

    def test_skips_match_design(self):
        skipped = {(a, s) for a, s, r in cell_lib.iter_cells() if r}
        assert ("mamba2_130m", "long_500k") not in skipped
        assert ("hymba_1_5b", "long_500k") not in skipped
        assert ("qwen2_72b", "long_500k") in skipped
        assert ("gemma2_9b", "long_500k") in skipped  # global layers quadratic
        assert len(skipped) == 8

    def test_input_specs_cover_all_inputs(self):
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            batch = cell_lib.batch_specs_for(cfg, cell_lib.SHAPES["train_4k"])
            assert "tokens" in batch
            if cfg.n_prefix_embeds:
                assert "patches" in batch
            if cfg.n_enc_layers:
                assert "frames" in batch
            toks, cache = cell_lib.decode_inputs_for(cfg, cell_lib.SHAPES["decode_32k"])
            assert toks.shape == (128, 1)
            assert "len" in cache

    def test_microbatches_defined_for_all(self):
        for arch in ARCH_IDS:
            assert arch in cell_lib.TRAIN_MICROBATCHES
