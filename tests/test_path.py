"""Regularization-path protocol tests (paper §5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CDConfig, FWConfig, path as path_lib


class TestGrids:
    def test_lambda_max_gives_null_solution(self, small_problem, rng_key):
        Xt, y, _ = small_problem
        lams = path_lib.lambda_grid(Xt, y, n_points=5)
        from repro.core import baselines

        res = baselines.cd_solve(
            Xt, y, CDConfig(lam=float(lams[0]) * (1 + 1e-6), max_sweeps=50, tol=1e-10),
            rng_key,
        )
        assert int(res.active) == 0

    def test_grid_is_log_spaced(self, small_problem):
        Xt, y, _ = small_problem
        lams = path_lib.lambda_grid(Xt, y, n_points=10)
        ratios = lams[:-1] / lams[1:]
        np.testing.assert_allclose(ratios, ratios[0], rtol=1e-6)
        assert lams[0] / lams[-1] == pytest.approx(100.0, rel=1e-6)


class TestFWPath:
    def test_path_outputs_monotone_sparsity_trend(self, small_problem):
        """Looser delta => denser solutions (trend, not strict per-point)."""
        Xt, y, _ = small_problem
        deltas = path_lib.delta_grid(100.0, n_points=8)
        res = path_lib.fw_path(
            Xt, y, deltas,
            FWConfig(delta=1.0, kappa=60, max_iters=20000, tol=1e-4),
        )
        active = [pt.active for pt in res.points]
        assert active[0] <= max(active[-3:]) + 1

    def test_objective_decreases_with_delta(self, small_problem):
        Xt, y, _ = small_problem
        deltas = path_lib.delta_grid(100.0, n_points=6)
        res = path_lib.fw_path(
            Xt, y, deltas, FWConfig(delta=1.0, kappa=60, max_iters=20000, tol=1e-5)
        )
        objs = [pt.objective for pt in res.points]
        assert objs[-1] <= objs[0] * (1 + 1e-6)

    def test_l1_budget_respected_along_path(self, small_problem):
        Xt, y, _ = small_problem
        deltas = path_lib.delta_grid(50.0, n_points=6)
        res = path_lib.fw_path(
            Xt, y, deltas, FWConfig(delta=1.0, kappa=60, max_iters=5000, tol=1e-4)
        )
        for pt, d in zip(res.points, deltas):
            assert pt.l1 <= d * (1 + 1e-4)

    def test_sparse_storage_roundtrip(self, small_problem):
        Xt, y, _ = small_problem
        deltas = path_lib.delta_grid(50.0, n_points=3)
        res = path_lib.fw_path(
            Xt, y, deltas, FWConfig(delta=1.0, kappa=60, max_iters=3000, tol=1e-4)
        )
        pt = res.points[-1]
        assert len(pt.alpha_nnz_idx) == pt.active
        assert np.all(pt.alpha_nnz_val != 0)


class TestPathAgreement:
    def test_fw_and_cd_agree_on_fit_quality(self, small_problem):
        """Paper Figs 5-6: at matched l1 budgets the training objective of
        FW is within a few percent of CD's."""
        Xt, y, _ = small_problem
        lams = path_lib.lambda_grid(Xt, y, n_points=8)
        cd = path_lib.cd_path(Xt, y, lams, CDConfig(lam=0.0, max_sweeps=300, tol=1e-6))
        # match deltas to the CD path's realized l1 norms
        deltas = np.array([max(pt.l1, 1e-3) for pt in cd.points[::-1]])
        fw = path_lib.fw_path(
            Xt, y, deltas, FWConfig(delta=1.0, kappa=100, max_iters=50000, tol=1e-5)
        )
        f0 = 0.5 * float(jnp.dot(y, y))  # null-solution objective
        for fw_pt, cd_pt in zip(fw.points, cd.points[::-1]):
            if cd_pt.l1 < 1e-3:
                continue
            # paper Figs 5-6 claim: the MSE curves coincide visually, i.e.
            # the gap is small relative to the overall error scale (FW's
            # sublinear tail at the unregularized end is expected)
            assert fw_pt.objective - cd_pt.objective <= 0.01 * f0
