"""Checkpoint / fault-tolerance tests: atomicity, rotation, crash-resume
equivalence, corrupt-checkpoint skip, async save, elastic re-mesh."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_latest, save_checkpoint
from repro.configs import get_config
from repro.data.lm_pipeline import batch_at_step
from repro.runtime import Trainer, TrainerConfig


def _tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16)},
    }


class TestCheckpointCore:
    def test_roundtrip(self, tmp_path):
        state = {"params": _tree()}
        save_checkpoint(tmp_path, 7, state)
        step, restored = load_latest(tmp_path, {"params": _tree()})
        assert step == 7
        for a, b in zip(jax.tree.leaves(state["params"]), jax.tree.leaves(restored["params"])):
            np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
            assert a.dtype == b.dtype

    def test_corrupt_checkpoint_skipped(self, tmp_path):
        save_checkpoint(tmp_path, 1, {"params": _tree()})
        save_checkpoint(tmp_path, 2, {"params": _tree()})
        # corrupt the newest
        newest = sorted(tmp_path.iterdir())[-1]
        npz = next(newest.glob("*.npz"))
        npz.write_bytes(b"garbage")
        step, _ = load_latest(tmp_path, {"params": _tree()})
        assert step == 1  # fell back to the previous valid checkpoint

    def test_partial_checkpoint_invisible(self, tmp_path):
        """A crash mid-save leaves only a temp dir — never a visible ckpt."""
        save_checkpoint(tmp_path, 1, {"params": _tree()})
        tmp = tmp_path / ".tmp_ckpt_crashed"
        tmp.mkdir()
        (tmp / "params.npz").write_bytes(b"partial")
        step, _ = load_latest(tmp_path, {"params": _tree()})
        assert step == 1

    def test_rotation(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, {"params": _tree()})
        mgr.wait()
        names = sorted(d.name for d in tmp_path.iterdir() if d.name.startswith("step_"))
        assert names == ["step_0000000003", "step_0000000004"]

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=3, async_save=True)
        mgr.save(5, {"params": _tree()})
        mgr.wait()
        step, _ = load_latest(tmp_path, {"params": _tree()})
        assert step == 5


class TestCrashResume:
    @pytest.fixture()
    def setup(self, tmp_path):
        cfg = get_config("deepseek_7b").reduced(n_layers=2)
        def data_fn(step):
            return batch_at_step(cfg, step, batch=4, seq_len=32, seed=9)
        return cfg, data_fn, tmp_path

    def test_resume_equivalence(self, setup):
        """train(10) == train(5) + crash + resume(10): bitwise final params."""
        cfg, data_fn, tmp = setup

        t1 = Trainer(cfg, TrainerConfig(
            total_steps=10, checkpoint_every=5, checkpoint_dir=str(tmp / "a"),
            async_checkpoint=False), data_fn)
        p1, _, _ = t1.run()

        t2 = Trainer(cfg, TrainerConfig(
            total_steps=10, checkpoint_every=5, checkpoint_dir=str(tmp / "b"),
            async_checkpoint=False), data_fn)
        with pytest.raises(RuntimeError, match="simulated crash"):
            t2.run(crash_at=7)  # crashes after ckpt at step 5
        t3 = Trainer(cfg, TrainerConfig(
            total_steps=10, checkpoint_every=5, checkpoint_dir=str(tmp / "b"),
            async_checkpoint=False), data_fn)
        p3, _, step3 = t3.run()
        assert step3 == 10

        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p3)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=0, atol=0,
            )

    def test_loss_decreases(self, setup):
        cfg, data_fn, tmp = setup
        t = Trainer(cfg, TrainerConfig(
            total_steps=30, checkpoint_every=100, checkpoint_dir=str(tmp / "c"),
            base_lr=1e-3, async_checkpoint=False), data_fn)
        t.run()
        first = np.mean(t.history[:5])
        last = np.mean(t.history[-5:])
        assert last < first, (first, last)


class TestElasticRemesh:
    def test_checkpoint_restores_across_device_counts(self, tmp_path):
        """Checkpoints are mesh-agnostic: save on N devices, restore on 1.

        (Cross-process: the 8-device save happens in a subprocess.)
        """
        import subprocess, sys, textwrap
        script = textwrap.dedent(f"""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.checkpoint import save_checkpoint
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
            w = jax.device_put(w, NamedSharding(mesh, P("data", "model")))
            save_checkpoint(r"{tmp_path}", 3, {{"params": {{"w": w}}}})
        """)
        env = {"PYTHONPATH": str(Path(__file__).resolve().parents[1] / "src"),
               "PATH": "/usr/bin:/bin",
               # stripped env: pin the backend or PJRT plugin discovery can hang
               "JAX_PLATFORMS": "cpu"}
        import os
        # slow shared CI runners need headroom (overridden in ci.yml)
        limit = int(os.environ.get("REPRO_SUBPROC_TIMEOUT", "300"))
        proc = subprocess.run([sys.executable, "-c", script],
                              capture_output=True, text=True, timeout=limit, env=env)
        assert proc.returncode == 0, proc.stderr[-2000:]
        # restore in THIS single-device process
        step, state = load_latest(tmp_path, {"params": {"w": jnp.zeros((8, 8))}})
        assert step == 3
        np.testing.assert_array_equal(
            np.asarray(state["params"]["w"]),
            np.arange(64, dtype=np.float32).reshape(8, 8),
        )
