"""Import-or-stub hypothesis so collection never hard-fails.

When hypothesis is installed the real API is re-exported. When it is
missing, only the @given property tests skip — the plain tests in the
same module keep running (the container's minimal image has no
hypothesis; see requirements-dev.txt).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Absorbs any decoration-time strategy construction without
        crashing — st.integers(...), @st.composite, composite calls — the
        decorated test is skipped anyway. Every attribute access and call
        returns the stub itself."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _StrategyStub()

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        return lambda fn: fn
