"""Sparse column-block subsystem (ISSUE 2 tentpole): storage format,
ops-vs-dense oracles, the sparse_grad Pallas kernel, and end-to-end
solver/path parity of ``backend='sparse'`` against the dense XLA path.

Shapes are deliberately NON-DIVISIBLE (p % block_size != 0) so the padded
tail block is always exercised, and the kernel tests run both dtypes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import FWConfig, fw_solve, path as path_lib
from repro.core.fw_lasso import duality_gap
from repro.kernels.sparse_grad.ref import sparse_sampled_scores_ref
from repro.kernels.sparse_grad.sparse_grad import sparse_sampled_scores
from repro.sparse import SparseBlockMatrix
from repro.sparse import ops as sops

DELTA = 150.0


def _sparse_dense_pair(p, m, density, seed, block_size=128, dtype=np.float32):
    """(dense Xt, SparseBlockMatrix, residual) with column-sparse structure."""
    rng = np.random.default_rng(seed)
    Xt = rng.standard_normal((p, m)).astype(dtype)
    Xt[rng.random((p, m)) > density] = 0.0
    mat = SparseBlockMatrix.from_dense(Xt, block_size=block_size)
    r = rng.standard_normal(m).astype(dtype)
    return Xt, mat, r


@pytest.fixture(scope="module")
def sparse_problem(small_problem):
    """The session small_problem (p=300, m=80) sparsified at density 0.05
    and converted; p=300 is NOT divisible by block_size=128."""
    rng = np.random.default_rng(7)
    Xt = np.asarray(small_problem[0]).copy()
    Xt[rng.random(Xt.shape) > 0.05] = 0.0
    # renormalize columns so the solver sees the §4.1 conditioning contract
    norms = np.sqrt((Xt * Xt).sum(axis=1, keepdims=True))
    norms[norms < 1e-12] = 1.0
    Xt = (Xt / norms).astype(np.float32)
    mat = SparseBlockMatrix.from_dense(Xt, block_size=128)
    return jnp.asarray(Xt), mat, small_problem[1]


class TestMatrixFormat:
    @pytest.mark.parametrize("p,m,bs", [(300, 80, 128), (777, 50, 256), (64, 33, 64)])
    def test_dense_roundtrip_nondivisible(self, p, m, bs):
        Xt, mat, _ = _sparse_dense_pair(p, m, 0.07, seed=p)
        assert mat.shape == (p, m)
        assert mat.p_padded % bs == 0 or mat.block_size != bs
        np.testing.assert_allclose(np.asarray(mat.to_dense()), Xt, atol=1e-7)

    def test_from_coo_matches_from_dense(self):
        Xt, mat, _ = _sparse_dense_pair(130, 40, 0.1, seed=1)
        feat, samp = np.nonzero(Xt)
        mat2 = SparseBlockMatrix.from_coo(
            samp, feat, Xt[feat, samp], (40, 130), block_size=128
        )
        np.testing.assert_array_equal(np.asarray(mat.values), np.asarray(mat2.values))
        np.testing.assert_array_equal(np.asarray(mat.rows), np.asarray(mat2.rows))

    def test_nnz_budget_too_small_raises(self):
        Xt, _, _ = _sparse_dense_pair(64, 32, 0.5, seed=2, block_size=64)
        required = int((np.asarray(Xt) != 0).sum(axis=1).max())
        with pytest.raises(ValueError, match="nnz budget"):
            SparseBlockMatrix.from_dense(Xt, block_size=64, nnz_max=required - 1)
        # exactly-sufficient budget is accepted
        mat = SparseBlockMatrix.from_dense(Xt, block_size=64, nnz_max=required)
        assert mat.nnz_max == required

    def test_index_out_of_range_raises(self):
        with pytest.raises(ValueError, match="out of range"):
            SparseBlockMatrix.from_coo([5], [0], [1.0], (4, 8))
        with pytest.raises(ValueError, match="out of range"):
            SparseBlockMatrix.from_coo([0], [9], [1.0], (4, 8))

    def test_pytree_roundtrip(self):
        """jit/vmap compatibility: the matrix flattens with static geometry."""
        _, mat, _ = _sparse_dense_pair(70, 20, 0.2, seed=3, block_size=32)
        leaves, treedef = jax.tree_util.tree_flatten(mat)
        assert len(leaves) == 2
        mat2 = jax.tree_util.tree_unflatten(treedef, leaves)
        assert mat2.shape == mat.shape and mat2.block_size == mat.block_size

    @settings(max_examples=20, deadline=None)
    @given(st.data())
    def test_coo_roundtrip_property(self, data):
        """Any duplicate-free COO set survives blocking + densification."""
        m = data.draw(st.integers(min_value=1, max_value=30), label="m")
        p = data.draw(st.integers(min_value=1, max_value=200), label="p")
        bs = data.draw(st.sampled_from([8, 32, 128]), label="bs")
        n_entries = data.draw(st.integers(min_value=0, max_value=min(150, m * p)))
        seed = data.draw(st.integers(min_value=0, max_value=2**16))
        rng = np.random.default_rng(seed)
        flat = rng.choice(m * p, size=n_entries, replace=False)
        rows, cols = flat // p, flat % p
        vals = rng.standard_normal(n_entries).astype(np.float32)
        vals[vals == 0.0] = 1.0
        mat = SparseBlockMatrix.from_coo(rows, cols, vals, (m, p), block_size=bs)
        dense = np.zeros((p, m), np.float32)
        dense[cols, rows] = vals
        np.testing.assert_allclose(np.asarray(mat.to_dense()), dense, atol=1e-7)


class TestOpsVsDense:
    def test_block_scores_with_padded_tail(self):
        Xt, mat, r = _sparse_dense_pair(300, 80, 0.1, seed=4)
        blk = jnp.asarray([0, 2], jnp.int32)  # block 2 = rows 256..299 + pad
        got = sops.sparse_block_scores(mat, jnp.asarray(r), blk)
        idx = (np.asarray(blk)[:, None] * 128 + np.arange(128)).reshape(-1)
        valid = idx < 300
        want = -(Xt[idx[valid]] @ r)
        np.testing.assert_allclose(np.asarray(got)[valid], want, rtol=2e-5, atol=2e-4)
        np.testing.assert_array_equal(np.asarray(got)[~valid], 0.0)

    def test_fw_vertex_masks_padded_features(self):
        Xt, mat, r = _sparse_dense_pair(130, 64, 0.3, seed=5)
        blk = jnp.arange(mat.nblocks, dtype=jnp.int32)  # 126 padded features
        i_star, g_star = sops.sparse_fw_vertex(mat, jnp.asarray(r), blk)
        assert int(i_star) < 130
        grad = -(Xt @ r)
        assert int(i_star) == int(np.argmax(np.abs(grad)))
        np.testing.assert_allclose(float(g_star), grad[int(i_star)], rtol=2e-5, atol=2e-4)

    def test_gather_vertex_uniform_indices(self):
        Xt, mat, r = _sparse_dense_pair(300, 40, 0.1, seed=6)
        idx = jnp.asarray([3, 77, 130, 299, 5], jnp.int32)
        i_star, g_star = sops.sparse_gather_vertex(mat, jnp.asarray(r), idx)
        scores = -(Xt[np.asarray(idx)] @ r)
        j = int(np.argmax(np.abs(scores)))
        assert int(i_star) == int(idx[j])
        np.testing.assert_allclose(float(g_star), scores[j], rtol=2e-5, atol=2e-4)

    def test_colstats_and_matvecs(self):
        Xt, mat, _ = _sparse_dense_pair(300, 80, 0.1, seed=8)
        rng = np.random.default_rng(0)
        y = rng.standard_normal(80).astype(np.float32)
        beta = rng.standard_normal(300).astype(np.float32)
        zty, zn2 = sops.sparse_colstats(mat, jnp.asarray(y))
        np.testing.assert_allclose(np.asarray(zty), Xt @ y, rtol=2e-5, atol=2e-4)
        np.testing.assert_allclose(np.asarray(zn2), (Xt * Xt).sum(1), rtol=2e-5, atol=2e-4)
        np.testing.assert_allclose(
            np.asarray(sops.sparse_matvec(mat, jnp.asarray(beta))),
            beta @ Xt, rtol=2e-4, atol=2e-3,
        )
        np.testing.assert_allclose(
            np.asarray(sops.sparse_transpose_matvec(mat, jnp.asarray(y))),
            Xt @ y, rtol=2e-5, atol=2e-4,
        )

    def test_residual_update_scatter(self):
        Xt, mat, r = _sparse_dense_pair(300, 80, 0.1, seed=9)
        rng = np.random.default_rng(1)
        y = rng.standard_normal(80).astype(np.float32)
        i = 137
        cv, cr = sops.sparse_column(mat, jnp.asarray(i))
        got = sops.sparse_residual_update(
            jnp.asarray(r), jnp.asarray(y), cv, cr,
            jnp.asarray(0.25), jnp.asarray(-1.5),
        )
        want = (1 - 0.25) * r + 0.25 * (y - (-1.5) * Xt[i])
        np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-4)


class TestSparseKernel:
    """kernels/sparse_grad interpret-mode vs the XLA oracle."""

    @pytest.mark.parametrize("p,m,bs", [(300, 80, 128), (777, 300, 256)])
    def test_kernel_matches_ref_nondivisible(self, p, m, bs):
        _, mat, r = _sparse_dense_pair(p, m, 0.05, seed=p, block_size=bs)
        blk = jnp.arange(mat.nblocks, dtype=jnp.int32)
        got = sparse_sampled_scores(mat.values, mat.rows, jnp.asarray(r), blk,
                                    interpret=True)
        want = sparse_sampled_scores_ref(mat.values, mat.rows, jnp.asarray(r), blk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-4)

    @pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
    def test_kernel_dtypes(self, dtype):
        _, mat, r = _sparse_dense_pair(300, 96, 0.1, seed=11)
        mat = mat.astype(dtype)
        r = jnp.asarray(r).astype(dtype)
        blk = jnp.asarray([0, 2], jnp.int32)
        got = sparse_sampled_scores(mat.values, mat.rows, r, blk, interpret=True)
        want = sparse_sampled_scores_ref(mat.values, mat.rows, r, blk)
        tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=tol, atol=tol * 10)
        assert got.dtype == jnp.float32  # f32 accumulation contract


class TestGatherMode:
    """gather_mode='onehot' — the one-hot matmul fallback for TPUs where
    the in-kernel VMEM ``jnp.take`` fails to lower (ISSUE 4 satellite) —
    must agree with the 'take' gather in BOTH sparse kernels."""

    @pytest.mark.parametrize("p,m,bs", [(300, 80, 128), (130, 70, 32)])
    def test_sampled_scores_take_vs_onehot(self, p, m, bs):
        _, mat, r = _sparse_dense_pair(p, m, 0.05, seed=p, block_size=bs)
        blk = jnp.arange(mat.nblocks, dtype=jnp.int32)
        take = sparse_sampled_scores(mat.values, mat.rows, jnp.asarray(r),
                                     blk, interpret=True, gather_mode="take")
        onehot = sparse_sampled_scores(mat.values, mat.rows, jnp.asarray(r),
                                       blk, interpret=True, gather_mode="onehot")
        np.testing.assert_allclose(np.asarray(take), np.asarray(onehot),
                                   rtol=1e-6, atol=1e-5)

    def test_colstats_take_vs_onehot(self):
        _, mat, r = _sparse_dense_pair(130, 70, 0.1, seed=9, block_size=32)
        y = jnp.asarray(r)
        z_t, n_t = sops.sparse_colstats(mat, y, use_kernel=True,
                                        interpret=True, gather_mode="take")
        z_o, n_o = sops.sparse_colstats(mat, y, use_kernel=True,
                                        interpret=True, gather_mode="onehot")
        np.testing.assert_allclose(np.asarray(z_t), np.asarray(z_o),
                                   rtol=1e-6, atol=1e-5)
        np.testing.assert_allclose(np.asarray(n_t), np.asarray(n_o),
                                   rtol=1e-6, atol=1e-5)

    def test_solver_end_to_end_onehot(self, sparse_problem, rng_key):
        """FWConfig.gather_mode plumbs through the solver hot loop."""
        _, mat, y = sparse_problem
        base = dict(delta=DELTA, sampling="block", kappa=128, max_iters=1500,
                    tol=1e-4, backend="sparse", sparse_kernel=True,
                    interpret=True)
        res_t = fw_solve(mat, y, FWConfig(gather_mode="take", **base), rng_key)
        res_o = fw_solve(mat, y, FWConfig(gather_mode="onehot", **base), rng_key)
        rel = abs(float(res_o.objective) - float(res_t.objective)) / abs(
            float(res_t.objective)
        )
        assert rel < 1e-4

    def test_unknown_mode_rejected(self):
        _, mat, r = _sparse_dense_pair(64, 32, 0.2, seed=1, block_size=32)
        with pytest.raises(ValueError, match="gather_mode"):
            sparse_sampled_scores(mat.values, mat.rows, jnp.asarray(r),
                                  jnp.asarray([0], jnp.int32),
                                  interpret=True, gather_mode="bogus")


class TestSolverParity:
    """fw_solve(backend='sparse') == fw_solve(backend='xla') end to end on
    the SAME (sparsified) problem. p=300 is not block-divisible, so the
    padded tail block is always in play."""

    @pytest.mark.parametrize(
        "sampling,kw",
        [
            ("uniform", dict(kappa=60)),
            ("block", dict(kappa=256)),
            ("full", dict()),
        ],
    )
    def test_objective_parity(self, sparse_problem, rng_key, sampling, kw):
        Xt, mat, y = sparse_problem
        base = dict(delta=DELTA, sampling=sampling, max_iters=5000, tol=1e-6)
        res_x = fw_solve(Xt, y, FWConfig(block_size=128, **base, **kw), rng_key)
        res_s = fw_solve(mat, y, FWConfig(backend="sparse", **base, **kw), rng_key)
        rel = abs(float(res_s.objective) - float(res_x.objective)) / abs(
            float(res_x.objective)
        )
        assert rel < 1e-4, (sampling, rel)
        assert float(jnp.sum(jnp.abs(res_s.alpha))) <= DELTA * (1 + 1e-5)

    def test_uniform_sampling_identical_trajectory(self, sparse_problem, rng_key):
        """'uniform' replays the exact index stream of the dense XLA path,
        so iteration/dot counts agree exactly."""
        Xt, mat, y = sparse_problem
        base = dict(delta=DELTA, sampling="uniform", kappa=60, max_iters=2000, tol=1e-6)
        res_x = fw_solve(Xt, y, FWConfig(**base), rng_key)
        res_s = fw_solve(mat, y, FWConfig(backend="sparse", **base), rng_key)
        assert int(res_x.iterations) == int(res_s.iterations)
        assert int(res_x.n_dots) == int(res_s.n_dots)

    def test_sparse_kernel_backend_matches_ref_backend(self, sparse_problem, rng_key):
        """Forcing the Pallas sparse_grad kernel (interpret mode) must
        reproduce the XLA-gather sparse backend bit-for-bit."""
        _, mat, y = sparse_problem
        base = dict(delta=DELTA, sampling="block", kappa=256, max_iters=800, tol=1e-6)
        res_a = fw_solve(mat, y, FWConfig(backend="sparse", sparse_kernel=False, **base), rng_key)
        res_b = fw_solve(
            mat, y,
            FWConfig(backend="sparse", sparse_kernel=True, interpret=True, **base),
            rng_key,
        )
        assert float(res_a.objective) == float(res_b.objective)
        assert int(res_a.iterations) == int(res_b.iterations)

    def test_warm_start_and_duality_gap(self, sparse_problem, rng_key):
        Xt, mat, y = sparse_problem
        cfg = FWConfig(delta=DELTA, backend="sparse", sampling="uniform",
                       kappa=60, max_iters=5000, tol=1e-6)
        res = fw_solve(mat, y, cfg, rng_key)
        # warm start from the solution terminates quickly and stays feasible
        res2 = fw_solve(mat, y, cfg, rng_key, alpha0=res.alpha)
        assert int(res2.iterations) <= int(res.iterations)
        assert float(jnp.sum(jnp.abs(res2.alpha))) <= DELTA * (1 + 1e-5)
        # sparse duality gap agrees with the dense computation
        from repro.core.fw_lasso import init_state

        state = init_state(mat, y, rng_key, alpha0=res.alpha)
        gap_s = float(duality_gap(mat, state, DELTA))
        state_d = init_state(Xt, y, rng_key, alpha0=res.alpha)
        gap_d = float(duality_gap(Xt, state_d, DELTA))
        assert gap_s == pytest.approx(gap_d, rel=1e-3, abs=1e-2)

    def test_backend_matrix_mismatch_raises(self, sparse_problem, rng_key):
        Xt, mat, y = sparse_problem
        with pytest.raises(ValueError, match="SparseBlockMatrix"):
            fw_solve(Xt, y, FWConfig(delta=1.0, backend="sparse"), rng_key)
        with pytest.raises(ValueError, match="backend='sparse'"):
            fw_solve(mat, y, FWConfig(delta=1.0, backend="xla"), rng_key)

    @pytest.mark.parametrize("dtype,tol", [(np.float32, 1e-4), (jnp.bfloat16, 5e-2)])
    def test_solver_dtypes(self, sparse_problem, rng_key, dtype, tol):
        """The sparse backend runs (and stays feasible) in both storage
        dtypes; f32 additionally matches the dense objective tightly."""
        Xt, mat, y = sparse_problem
        cfg = FWConfig(delta=DELTA, backend="sparse", sampling="uniform",
                       kappa=60, max_iters=1500, tol=1e-6)
        res = fw_solve(mat.astype(dtype), y.astype(dtype), cfg, rng_key)
        assert bool(jnp.isfinite(res.objective))
        assert float(jnp.sum(jnp.abs(res.alpha.astype(jnp.float32)))) <= DELTA * (1 + tol)
        if dtype == np.float32:
            res_x = fw_solve(Xt, y, FWConfig(delta=DELTA, sampling="uniform",
                                             kappa=60, max_iters=1500, tol=1e-6), rng_key)
            rel = abs(float(res.objective) - float(res_x.objective)) / abs(
                float(res_x.objective)
            )
            assert rel < tol


class TestSparsePath:
    def test_paths_match_dense(self, sparse_problem):
        Xt, mat, y = sparse_problem
        deltas = path_lib.delta_grid(100.0, n_points=6)
        base = dict(delta=1.0, kappa=60, max_iters=8000, tol=1e-4)
        seq_d = path_lib.fw_path(Xt, y, deltas, FWConfig(**base))
        seq_s = path_lib.fw_path(mat, y, deltas, FWConfig(backend="sparse", **base))
        for d, s in zip(seq_d.points, seq_s.points):
            rel = abs(s.objective - d.objective) / max(abs(d.objective), 1e-9)
            assert rel < 1e-3, (d.reg, rel)
            assert s.l1 <= d.reg * (1 + 1e-4)

    def test_batched_path_on_sparse_matrix(self, sparse_problem):
        _, mat, y = sparse_problem
        deltas = path_lib.delta_grid(100.0, n_points=7)
        cfg = FWConfig(delta=1.0, kappa=60, max_iters=8000, tol=1e-4, backend="sparse")
        seq = path_lib.fw_path(mat, y, deltas, cfg)
        bat = path_lib.fw_path_batched(mat, y, deltas, cfg, lane_width=3)
        assert len(bat.points) == 7
        for s, b in zip(seq.points, bat.points):
            rel = abs(b.objective - s.objective) / max(abs(s.objective), 1e-9)
            assert rel < 1e-3, (s.reg, rel)

    def test_lambda_grid_sparse(self, sparse_problem):
        Xt, mat, y = sparse_problem
        lams_d = path_lib.lambda_grid(Xt, y, n_points=5)
        lams_s = path_lib.lambda_grid(mat, y, n_points=5)
        np.testing.assert_allclose(lams_s, lams_d, rtol=1e-5)
