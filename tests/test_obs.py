"""Observability subsystem coverage (ISSUE 7 tentpole).

Four layers:
  * telemetry-off bit-identity: ``FWConfig(telemetry=None)`` (the
    default every pinned golden runs under) and ``telemetry=...`` must
    produce bitwise-identical trajectories on every backend and step
    rule — the ring is an observer, never a participant;
  * ring contents: the per-iteration records must agree with
    ``solve_with_history`` (which is itself now implemented ON the
    ring), wrap correctly, and carry the right step-rule event codes;
  * host plumbing: streaming sinks receive every record exactly once,
    the tracer emits Perfetto-loadable trace_event JSON (validated by
    the schema checker, including rejection cases), ``timed`` stays off
    stdout, and the monitors detect injected stragglers without sleeps;
  * report rendering: ring + tracer -> markdown/JSON artifacts.
"""
import importlib
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ENOracle, FWConfig, LOGISTIC, engine
from repro.core.fw_lasso import LASSO
from repro.obs import (
    EVENT_AWAY,
    EVENT_DROP,
    EVENT_FW,
    EVENT_LAZY_HIT,
    EVENT_PAIRWISE,
    EVENT_PARTAN,
    LaneProgressMonitor,
    StepMonitor,
    TelemetrySpec,
    Tracer,
    build_report,
    get_tracer,
    register_sink,
    render_markdown,
    ring_to_records,
    unregister_sink,
    use_tracer,
    validate_chrome_trace,
    write_report,
)
from repro.sparse.matrix import SparseBlockMatrix
from repro.utils.timing import Timer, timed

DELTA = 150.0


def _base_cfg(**kw):
    base = dict(delta=DELTA, kappa=40, sampling="uniform", max_iters=120,
                tol=0.0, patience=10**9)
    base.update(kw)
    return FWConfig(**base)


def _sparse_mat(Xt, threshold=0.7, block_size=64):
    Xs = np.asarray(Xt).copy()
    Xs[np.abs(Xs) < threshold] = 0.0
    return SparseBlockMatrix.from_dense(Xs, block_size=block_size)


class TestTelemetryOffBitIdentity:
    """The telemetry ring must be invisible to the trajectory: same
    alpha, iterations, and dot counts bit for bit, ring on or off.
    (The pinned goldens in test_engine/test_step_rules/test_distributed
    all run with the default ``telemetry=None`` — those pin the OFF
    program; these pin ON == OFF.)"""

    @pytest.mark.parametrize("backend", ["xla", "pallas", "sparse"])
    def test_backends_identical(self, small_problem, rng_key, backend):
        Xt, y, _ = small_problem
        A = _sparse_mat(Xt) if backend == "sparse" else Xt
        kw = dict(backend=backend)
        if backend == "pallas":
            kw["interpret"] = True
        off = engine.solve(LASSO, A, y, _base_cfg(**kw), rng_key)
        on = engine.solve(
            LASSO, A, y,
            _base_cfg(**kw, telemetry=TelemetrySpec(capacity=64)), rng_key,
        )
        np.testing.assert_array_equal(np.asarray(off.alpha), np.asarray(on.alpha))
        assert int(off.iterations) == int(on.iterations)
        assert int(off.n_dots) == int(on.n_dots)
        assert off.telemetry is None and on.telemetry is not None

    @pytest.mark.parametrize("rule", ["away", "pairwise", "partan", "lazy"])
    def test_step_rules_identical(self, small_problem, rng_key, rule):
        Xt, y, _ = small_problem
        off = engine.solve(LASSO, Xt, y, _base_cfg(step_rule=rule), rng_key)
        on = engine.solve(
            LASSO, Xt, y,
            _base_cfg(step_rule=rule, telemetry=TelemetrySpec(capacity=64)),
            rng_key,
        )
        np.testing.assert_array_equal(np.asarray(off.alpha), np.asarray(on.alpha))
        assert int(off.n_dots) == int(on.n_dots)

    @pytest.mark.parametrize("oracle", [LOGISTIC, ENOracle(l2=0.7)],
                             ids=["logistic", "elasticnet"])
    def test_family_identical(self, small_problem, rng_key, oracle):
        Xt, y, _ = small_problem
        yv = jnp.sign(y) + (y == 0) if oracle is LOGISTIC else y
        off = engine.solve(oracle, Xt, yv, _base_cfg(max_iters=60), rng_key)
        on = engine.solve(
            oracle, Xt, yv,
            _base_cfg(max_iters=60, telemetry=TelemetrySpec(capacity=64)),
            rng_key,
        )
        np.testing.assert_array_equal(np.asarray(off.alpha), np.asarray(on.alpha))

    def test_fused_solve_identical(self, small_problem, rng_key):
        """telemetry-on with record_objective forces the bit-identical
        fori-of-step executor — the fused trajectory must not move."""
        Xt, y, _ = small_problem
        mat = _sparse_mat(Xt)
        kw = dict(backend="sparse", sparse_kernel=True, interpret=True,
                  fuse_steps=8)
        off = engine.solve(LASSO, mat, y, _base_cfg(**kw), rng_key)
        on = engine.solve(
            LASSO, mat, y,
            _base_cfg(**kw, telemetry=TelemetrySpec(capacity=64)), rng_key,
        )
        np.testing.assert_array_equal(np.asarray(off.alpha), np.asarray(on.alpha))


class TestRingContents:
    def test_ring_matches_history(self, small_problem, rng_key):
        """The ring's objective column IS the solve_with_history curve."""
        Xt, y, _ = small_problem
        cfg = _base_cfg()
        n = 100
        res_h, hist = engine.solve_with_history(LASSO, Xt, y, cfg, rng_key, n)
        ring = engine.solve(
            LASSO, Xt, y,
            _base_cfg(max_iters=n, telemetry=TelemetrySpec(capacity=n)),
            rng_key,
        ).telemetry
        assert hist.shape == (n,)
        np.testing.assert_array_equal(np.asarray(hist), np.asarray(ring.objective))
        # history's own result surfaces its ring too
        assert res_h.telemetry is not None
        np.testing.assert_array_equal(
            np.asarray(res_h.telemetry.objective[:n]), np.asarray(hist)
        )

    def test_fused_history_matches_unfused(self, small_problem, rng_key):
        """fuse_steps=K history == K=1 history (the old scan always ran
        per-step; the ring-based version must keep that contract)."""
        Xt, y, _ = small_problem
        mat = _sparse_mat(Xt)
        _, h1 = engine.solve_with_history(
            LASSO, mat, y, _base_cfg(backend="sparse", sparse_kernel=True,
                                     interpret=True), rng_key, 60,
        )
        _, h8 = engine.solve_with_history(
            LASSO, mat, y, _base_cfg(backend="sparse", sparse_kernel=True,
                                     interpret=True, fuse_steps=8),
            rng_key, 60,
        )
        np.testing.assert_array_equal(np.asarray(h1), np.asarray(h8))

    def test_wrap_keeps_last_records(self, small_problem, rng_key):
        Xt, y, _ = small_problem
        res = engine.solve(
            LASSO, Xt, y,
            _base_cfg(max_iters=100, telemetry=TelemetrySpec(capacity=32)),
            rng_key,
        )
        ring = res.telemetry
        assert int(ring.cursor) == 100  # true count survives the wrap
        rec = ring_to_records(ring)
        np.testing.assert_array_equal(rec["k"], np.arange(68, 100))
        np.testing.assert_array_equal(rec["record_index"], np.arange(68, 100))
        assert np.all(np.diff(rec["n_dots"]) > 0)  # cumulative

    def test_kernel_chunk_records(self, small_problem, rng_key):
        """record_objective=False keeps the megakernel chunk executor;
        its replayed records must agree with the per-step run on the
        step facts the kernel emits (i_star, lam, k, n_dots), with the
        unrecorded objective/gap columns NaN."""
        Xt, y, _ = small_problem
        mat = _sparse_mat(Xt)
        spec = TelemetrySpec(capacity=64, record_objective=False)
        fused = engine.solve(
            LASSO, mat, y,
            _base_cfg(backend="sparse", sparse_kernel=True, interpret=True,
                      fuse_steps=8, telemetry=spec),
            rng_key,
        )
        ref = engine.solve(
            LASSO, mat, y,
            _base_cfg(backend="sparse", sparse_kernel=True, interpret=True,
                      telemetry=spec),
            rng_key,
        )
        a, b = ring_to_records(fused.telemetry), ring_to_records(ref.telemetry)
        for field in ("k", "i_star", "lam", "n_dots", "event"):
            np.testing.assert_array_equal(a[field], b[field], err_msg=field)
        assert np.all(np.isnan(a["objective"])) and np.all(np.isnan(a["gap"]))

    def test_objective_gap_recorded(self, small_problem, rng_key):
        Xt, y, _ = small_problem
        res = engine.solve(
            LASSO, Xt, y, _base_cfg(telemetry=TelemetrySpec(capacity=200)),
            rng_key,
        )
        rec = ring_to_records(res.telemetry)
        assert not np.any(np.isnan(rec["objective"]))
        assert not np.any(np.isnan(rec["gap"]))
        # final recorded objective is the result objective
        np.testing.assert_allclose(
            rec["objective"][-1], float(res.objective), rtol=1e-6
        )

    def test_batched_lane_rings(self, small_problem):
        """solve_batched carries one ring per lane; frozen lanes stop
        recording, so each lane's cursor equals its iteration count."""
        Xt, y, _ = small_problem
        cfg = FWConfig(delta=1.0, kappa=40, sampling="uniform",
                       max_iters=400, tol=1e-3, patience=10,
                       telemetry=TelemetrySpec(capacity=32))
        keys = jax.random.split(jax.random.PRNGKey(0), 3)
        deltas = jnp.asarray([20.0, 80.0, 150.0], Xt.dtype)
        alpha0s = jnp.zeros((3, Xt.shape[0]), Xt.dtype)
        res, _ = engine.solve_batched(LASSO, Xt, y, cfg, keys, alpha0s, deltas)
        assert res.telemetry is not None
        np.testing.assert_array_equal(
            np.asarray(res.telemetry.cursor), np.asarray(res.iterations)
        )


class TestStepRuleEvents:
    def _events(self, Xt, y, key, rule, **kw):
        res = engine.solve(
            LASSO, Xt, y,
            _base_cfg(step_rule=rule, telemetry=TelemetrySpec(capacity=256),
                      **kw),
            key,
        )
        return ring_to_records(res.telemetry), res

    def test_away_codes(self, small_problem, rng_key):
        rec, res = self._events(*small_problem[:2], rng_key, "away")
        ev = set(rec["event"].tolist())
        assert EVENT_AWAY in ev  # away steps actually fired
        assert ev <= {EVENT_FW, EVENT_AWAY, EVENT_DROP}

    def test_pairwise_codes(self, small_problem, rng_key):
        rec, _ = self._events(*small_problem[:2], rng_key, "pairwise")
        ev = set(rec["event"].tolist())
        assert EVENT_PAIRWISE in ev
        assert ev <= {EVENT_FW, EVENT_PAIRWISE, EVENT_DROP}

    def test_partan_one_record_per_iteration(self, small_problem, rng_key):
        rec, res = self._events(*small_problem[:2], rng_key, "partan")
        # the classic half-step's record is AMENDED, not duplicated
        assert len(rec["k"]) == int(res.iterations)
        assert set(rec["event"].tolist()) == {EVENT_PARTAN}
        np.testing.assert_array_equal(rec["k"], np.arange(int(res.iterations)))

    def test_lazy_hits_recorded(self, small_problem, rng_key):
        rec, res = self._events(*small_problem[:2], rng_key, "lazy")
        ev = set(rec["event"].tolist())
        assert EVENT_LAZY_HIT in ev
        assert ev <= {EVENT_FW, EVENT_LAZY_HIT}
        assert not np.any(np.isnan(rec["gap"]))


class TestStreaming:
    def test_sink_receives_every_record_once(self, small_problem, rng_key):
        Xt, y, _ = small_problem
        batches = []
        register_sink("test-sink", batches.append)
        try:
            engine.solve(
                LASSO, Xt, y,
                _base_cfg(max_iters=50,
                          telemetry=TelemetrySpec(capacity=16,
                                                  stream_to="test-sink")),
                rng_key,
            ).alpha.block_until_ready()
            jax.effects_barrier()
        finally:
            unregister_sink("test-sink")
        idx = np.concatenate([b["record_index"] for b in batches])
        np.testing.assert_array_equal(np.sort(idx), np.arange(50))
        assert len(batches) >= 2  # wrap flushes + the final flush
        ks = np.concatenate([b["k"] for b in batches])
        np.testing.assert_array_equal(np.sort(ks), np.arange(50))

    def test_unregistered_sink_is_noop(self, small_problem, rng_key):
        Xt, y, _ = small_problem
        res = engine.solve(
            LASSO, Xt, y,
            _base_cfg(max_iters=20,
                      telemetry=TelemetrySpec(capacity=8,
                                              stream_to="nobody-home")),
            rng_key,
        )
        assert int(res.telemetry.cursor) == 20


class TestStreamingWrapAndFreeze:
    """ISSUE 9 satellite: wrapped-ring ``stream_to`` flush cadence and
    the ring invariants the batched driver's lane freezing must keep."""

    def _stream(self, Xt, y, key, cfg):
        batches = []
        register_sink("wrap-test", batches.append)
        try:
            res = engine.solve(LASSO, Xt, y, cfg, key)
            res.alpha.block_until_ready()
            jax.effects_barrier()
        finally:
            unregister_sink("wrap-test")
        return res, batches

    def test_wrap_boundary_batches_are_full_rings(self, small_problem,
                                                  rng_key):
        """Non-final flushes fire exactly at wrap boundaries, so every
        one delivers a full ring; the leftover drains in one partial
        final flush."""
        Xt, y, _ = small_problem
        _, batches = self._stream(
            Xt, y, rng_key,
            _base_cfg(max_iters=50,
                      telemetry=TelemetrySpec(capacity=16,
                                              stream_to="wrap-test")),
        )
        assert [len(b["record_index"]) for b in batches] == [16, 16, 16, 2]
        idx = np.concatenate([b["record_index"] for b in batches])
        np.testing.assert_array_equal(idx, np.arange(50))  # in order, no gaps

    def test_exact_multiple_skips_empty_final_flush(self, small_problem,
                                                    rng_key):
        """iterations % capacity == 0: the last wrap flush already
        drained everything and the final flush must not deliver an empty
        batch."""
        Xt, y, _ = small_problem
        _, batches = self._stream(
            Xt, y, rng_key,
            _base_cfg(max_iters=32,
                      telemetry=TelemetrySpec(capacity=16,
                                              stream_to="wrap-test")),
        )
        assert [len(b["record_index"]) for b in batches] == [16, 16]

    def test_partial_final_flush_on_early_stop(self, small_problem, rng_key):
        """A patience stop mid-ring drains exactly the recorded
        remainder: streamed records == cursor == iterations."""
        Xt, y, _ = small_problem
        res, batches = self._stream(
            Xt, y, rng_key,
            _base_cfg(delta=20.0, tol=1e-3, patience=10, max_iters=400,
                      telemetry=TelemetrySpec(capacity=64,
                                              stream_to="wrap-test")),
        )
        it = int(res.iterations)
        assert it < 400  # the stop actually fired early
        assert int(res.telemetry.cursor) == it
        assert int(res.telemetry.flushed) == it
        idx = np.concatenate([b["record_index"] for b in batches])
        np.testing.assert_array_equal(idx, np.arange(it))
        assert len(batches[-1]["record_index"]) == it % 64 or it % 64 == 0

    def _batched(self, Xt, y, capacity, **cfg_kw):
        base = dict(delta=1.0, kappa=40, sampling="uniform", max_iters=400,
                    tol=1e-3, patience=10,
                    telemetry=TelemetrySpec(capacity=capacity))
        base.update(cfg_kw)
        cfg = FWConfig(**base)
        keys = jax.random.split(jax.random.PRNGKey(0), 3)
        deltas = jnp.asarray([20.0, 80.0, 150.0], Xt.dtype)
        alpha0s = jnp.zeros((3, Xt.shape[0]), Xt.dtype)
        return engine.solve_batched(LASSO, Xt, y, cfg, keys, alpha0s, deltas)

    def test_frozen_lane_rings_stop_recording(self, small_problem):
        """capacity > iterations: each lane's ring holds exactly its own
        iterations — frozen lanes write nothing while the slowest lane
        keeps going, and the slots past a lane's freeze stay empty."""
        Xt, y, _ = small_problem
        res, _ = self._batched(Xt, y, capacity=400)
        iters = np.asarray(res.iterations)
        assert len(set(iters.tolist())) > 1  # lanes genuinely froze apart
        for lane in range(3):
            ring = jax.tree_util.tree_map(lambda a: a[lane], res.telemetry)
            it = int(iters[lane])
            assert int(ring.cursor) == it
            rec = ring_to_records(ring)
            np.testing.assert_array_equal(rec["k"], np.arange(it))
            assert np.all(np.asarray(ring.k)[it:] == -1)  # untouched slots

    def test_frozen_lane_wrapped_rings_keep_tail(self, small_problem):
        """capacity < iterations: a wrapped lane ring still reports the
        true per-lane count through ``cursor`` and surfaces the LAST
        ``capacity`` records of that lane — not the slowest lane's."""
        Xt, y, _ = small_problem
        res, _ = self._batched(Xt, y, capacity=32)
        iters = np.asarray(res.iterations)
        for lane in range(3):
            ring = jax.tree_util.tree_map(lambda a: a[lane], res.telemetry)
            it = int(iters[lane])
            assert int(ring.cursor) == it
            rec = ring_to_records(ring)
            n = min(it, 32)
            np.testing.assert_array_equal(rec["k"], np.arange(it - n, it))
            np.testing.assert_array_equal(
                rec["record_index"], np.arange(it - n, it)
            )
            assert np.all(np.diff(rec["n_dots"]) > 0)

    def test_fused_chunks_freeze_mid_chunk_exact_cursor(self, small_problem):
        """fuse_steps=K batched lanes stop on their own iteration, not a
        chunk boundary: in-chunk masking must keep cursor == iterations
        even when the freeze lands mid-chunk."""
        Xt, y, _ = small_problem
        mat = _sparse_mat(Xt)
        res, _ = self._batched(
            mat, y, capacity=64,
            backend="sparse", sparse_kernel=True, interpret=True,
            fuse_steps=8,
            telemetry=TelemetrySpec(capacity=64, record_objective=False),
        )
        iters = np.asarray(res.iterations)
        np.testing.assert_array_equal(
            np.asarray(res.telemetry.cursor), iters
        )
        for lane in range(3):
            ring = jax.tree_util.tree_map(lambda a: a[lane], res.telemetry)
            rec = ring_to_records(ring)
            it = int(iters[lane])
            n = min(it, 64)
            np.testing.assert_array_equal(rec["k"], np.arange(it - n, it))


class TestTracer:
    def test_spans_counters_and_validation(self):
        tr = Tracer("t")
        with tr.span("outer", cat="x", detail=1):
            with tr.span("inner"):
                pass
            tr.counter("widgets", 2)
            tr.counter("widgets", 3)
            tr.instant("mark", note="hi")
        assert tr.counter_table() == {"widgets": 5.0}
        table = tr.span_table()
        assert table["outer"]["count"] == 1 and table["inner"]["count"] == 1
        assert validate_chrome_trace(tr.to_chrome()) == []
        assert validate_chrome_trace(json.dumps(tr.to_chrome())) == []

    def test_use_tracer_stacks(self):
        tr = Tracer("scoped")
        default = get_tracer()
        with use_tracer(tr):
            assert get_tracer() is tr
        assert get_tracer() is default

    def test_validator_rejects_bad_traces(self):
        assert validate_chrome_trace("not json")
        assert validate_chrome_trace({"nope": 1})
        assert validate_chrome_trace(
            {"traceEvents": [{"ph": "Z", "name": "x", "ts": 0}]}
        )
        assert validate_chrome_trace(
            {"traceEvents": [{"ph": "X", "name": "x", "ts": 0}]}
        )  # X without dur
        # unbalanced B/E on one track
        errs = validate_chrome_trace(
            {"traceEvents": [
                {"ph": "B", "name": "a", "ts": 0, "pid": 1, "tid": 1}
            ]}
        )
        assert any("unclosed" in e for e in errs)

    def test_save_roundtrip(self, tmp_path):
        tr = Tracer("t")
        with tr.span("s"):
            pass
        path = tr.save(tmp_path / "trace.json")
        with open(path) as fh:
            assert validate_chrome_trace(fh.read()) == []


class TestTimed:
    def test_no_stdout_by_default(self, capsys):
        tr = Tracer("t")
        with use_tracer(tr):
            with timed("quiet-block"):
                pass
        assert capsys.readouterr().out == ""
        assert tr.span_table()["quiet-block"]["count"] == 1

    def test_dict_and_timer_sinks(self):
        d = {}
        t = Timer()
        with use_tracer(Tracer("t")):
            with timed("x", sink=d):
                pass
            with timed("x", sink=d):
                pass
            with timed("y", sink=t):
                pass
        assert d["x"] > 0 and len(d) == 1
        assert t.count == 1 and t.total > 0

    def test_timer_merge(self):
        a = Timer(total=1.0, count=2)
        b = Timer(total=0.5, count=3)
        a.merge(b)
        assert a.total == 1.5 and a.count == 5
        assert a.mean == pytest.approx(0.3)

    def test_verbose_opt_in(self, capsys):
        with use_tracer(Tracer("t")):
            with timed("loud", verbose=True):
                pass
        assert "[timed] loud:" in capsys.readouterr().out


class TestMonitors:
    def test_straggler_detection_fake_clock(self):
        """Injected clock: steps of 1.0s with one 10x outlier — no real
        sleeps needed."""
        times = iter([0.0, 1.0,  # step 1 (seeds the EWMA)
                      2.0, 3.0,  # step 2
                      4.0, 14.0,  # step 3: 10s straggler
                      15.0, 16.0])  # step 4: recovered
        mon = StepMonitor(clock=lambda: next(times))
        flags = []
        for _ in range(4):
            mon.begin()
            flags.append(mon.end())
        assert flags == [False, False, True, False]
        assert mon.stragglers == [3]

    def test_heartbeat_json(self, tmp_path):
        times = iter([0.0, 1.0, 2.0, 12.0])
        hb = tmp_path / "hb.json"
        mon = StepMonitor(heartbeat_path=hb, clock=lambda: next(times))
        mon.begin(); mon.end()
        mon.begin()
        assert mon.end() is True
        data = json.loads(hb.read_text())
        assert data["step"] == 2
        assert data["straggler"] is True
        assert data["stragglers"] == [2]
        assert data["step_time"] == pytest.approx(10.0)

    def test_runtime_shim_is_gone(self):
        # PR 7's repro.runtime.monitor deprecation shim is retired:
        # the one import path is repro.obs.monitor
        with pytest.raises(ImportError):
            importlib.import_module("repro.runtime.monitor")

    def test_lane_progress_monitor(self):
        times = iter([0.0, 1.0, 2.0, 3.0])
        mon = LaneProgressMonitor(
            max_iters=100, chunk_monitor=StepMonitor(clock=lambda: next(times))
        )
        tr = Tracer("t")
        with use_tracer(tr):
            mon.begin_chunk()
            rec = mon.end_chunk(0, [1.0, 2.0], [30, 50], 20, [True, True])
        assert rec["lane_saved"] == [20, 0]
        assert rec["freeze_at"] == [30, None]
        s = mon.summary()
        assert s["saved_iters"] == 20 and s["frozen_lanes"] == 1
        assert tr.counter_table()["path/saved_iters"] == 20.0
        assert validate_chrome_trace(tr.to_chrome()) == []


class TestReport:
    def test_build_and_render(self, small_problem, rng_key, tmp_path):
        Xt, y, _ = small_problem
        tr = Tracer("report-test")
        with use_tracer(tr):
            with tr.span("solve"):
                res = engine.solve(
                    LASSO, Xt, y,
                    _base_cfg(max_iters=40,
                              telemetry=TelemetrySpec(capacity=40)),
                    rng_key,
                )
                res.alpha.block_until_ready()
        report = build_report(
            meta={"git_sha": "deadbeef", "purpose": "test"},
            runs=[{
                "name": "lasso_xla", "backend": "xla",
                "iterations": int(res.iterations),
                "n_dots": int(res.n_dots),
                "objective": float(res.objective),
                "ring": res.telemetry,
            }],
            tracer=tr,
        )
        assert report["runs"][0]["event_counts"] == {"fw": 40}
        md = render_markdown(report)
        assert "deadbeef" in md
        assert "Convergence curve — lasso_xla" in md
        assert "| solve |" in md
        paths = write_report(tmp_path, report)
        with open(paths["json"]) as fh:
            loaded = json.load(fh)
        assert loaded["runs"][0]["records"]["k"][0] == 0
        assert (tmp_path / "solver_report.md").exists()
