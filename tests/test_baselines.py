"""Baseline solver correctness (CD / SCD / FISTA / projections)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CDConfig, FISTAConfig, FWConfig, baselines, fw_solve
from repro.core.projections import project_l1_ball, soft_threshold


def _orthogonal_problem(m=64, p=32, seed=0):
    """Design with orthonormal columns: closed-form Lasso solution."""
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((m, p))
    Q, _ = np.linalg.qr(A)  # (m, p) orthonormal columns
    coef = np.zeros(p)
    coef[: p // 4] = rng.uniform(1.0, 5.0, p // 4)
    y = Q @ coef + 0.01 * rng.standard_normal(m)
    return jnp.asarray(Q.T, jnp.float32), jnp.asarray(y, jnp.float32)


class TestCoordinateDescent:
    def test_orthogonal_closed_form(self, rng_key):
        Xt, y = _orthogonal_problem()
        lam = 0.5
        res = baselines.cd_solve(Xt, y, CDConfig(lam=lam, max_sweeps=200, tol=1e-10), rng_key)
        expected = soft_threshold(Xt @ y, lam)  # X^T X = I
        np.testing.assert_allclose(np.asarray(res.alpha), np.asarray(expected), atol=1e-5)

    def test_stochastic_matches_cyclic(self, small_problem, rng_key):
        Xt, y, _ = small_problem
        lam = float(jnp.max(jnp.abs(Xt @ y))) / 20
        cyc = baselines.cd_solve(Xt, y, CDConfig(lam=lam, max_sweeps=500, tol=1e-8), rng_key)
        sto = baselines.cd_solve(
            Xt, y, CDConfig(lam=lam, max_sweeps=500, tol=1e-8, stochastic=True), rng_key
        )
        pen_c = float(cyc.objective) + lam * float(jnp.sum(jnp.abs(cyc.alpha)))
        pen_s = float(sto.objective) + lam * float(jnp.sum(jnp.abs(sto.alpha)))
        np.testing.assert_allclose(pen_s, pen_c, rtol=1e-3)

    def test_null_solution_above_lambda_max(self, small_problem, rng_key):
        """Paper §2.1: lam > ||X^T y||_inf => alpha* = 0."""
        Xt, y, _ = small_problem
        lam = float(jnp.max(jnp.abs(Xt @ y))) * 1.01
        res = baselines.cd_solve(Xt, y, CDConfig(lam=lam, max_sweeps=50, tol=1e-10), rng_key)
        assert int(res.active) == 0


class TestFISTA:
    def test_penalized_matches_cd(self, small_problem, rng_key):
        Xt, y, _ = small_problem
        lam = float(jnp.max(jnp.abs(Xt @ y))) / 10
        cd = baselines.cd_solve(Xt, y, CDConfig(lam=lam, max_sweeps=1000, tol=1e-9), rng_key)
        fi = baselines.fista_solve(
            Xt, y, FISTAConfig(lam=lam, max_iters=5000, tol=1e-9), rng_key
        )
        pen_cd = float(cd.objective) + lam * float(jnp.sum(jnp.abs(cd.alpha)))
        pen_fi = float(fi.objective) + lam * float(jnp.sum(jnp.abs(fi.alpha)))
        np.testing.assert_allclose(pen_fi, pen_cd, rtol=1e-3)

    def test_constrained_feasible(self, small_problem, rng_key):
        Xt, y, _ = small_problem
        delta = 30.0
        res = baselines.fista_solve(
            Xt, y, FISTAConfig(delta=delta, constrained=True, max_iters=2000, tol=1e-8),
            rng_key,
        )
        assert float(jnp.sum(jnp.abs(res.alpha))) <= delta * (1 + 1e-4)

    def test_lipschitz_estimate(self, rng_key):
        rng = np.random.default_rng(3)
        X = rng.standard_normal((40, 60)).astype(np.float32)
        L_true = np.linalg.norm(X, 2) ** 2
        L_est = float(baselines.estimate_lipschitz(jnp.asarray(X.T), 100, rng_key))
        np.testing.assert_allclose(L_est, L_true, rtol=1e-3)


class TestFormEquivalence:
    def test_fw_matches_cd_at_equivalent_budget(self, small_problem, rng_key):
        """Paper §2.1: solving (1) at delta = ||alpha*(lam)||_1 recovers the
        same objective as the penalized solution."""
        Xt, y, _ = small_problem
        lam = float(jnp.max(jnp.abs(Xt @ y))) / 10
        cd = baselines.cd_solve(Xt, y, CDConfig(lam=lam, max_sweeps=1000, tol=1e-10), rng_key)
        delta = float(jnp.sum(jnp.abs(cd.alpha)))
        fw = fw_solve(
            Xt, y,
            FWConfig(delta=delta, sampling="full", max_iters=100000, tol=1e-8),
            rng_key,
        )
        assert float(fw.objective) <= float(cd.objective) * 1.02 + 1e-3


class TestProjection:
    def test_inside_ball_unchanged(self):
        v = jnp.asarray([0.5, -0.25, 0.1])
        out = project_l1_ball(v, 2.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(v))

    def test_projection_norm(self):
        rng = np.random.default_rng(0)
        for _ in range(10):
            v = jnp.asarray(rng.standard_normal(50).astype(np.float32) * 10)
            out = project_l1_ball(v, 3.0)
            assert float(jnp.sum(jnp.abs(out))) <= 3.0 * (1 + 1e-5)

    def test_projection_optimality_small(self):
        """Brute-force check in 2-D: projection is the closest feasible point."""
        rng = np.random.default_rng(1)
        for _ in range(20):
            v = rng.standard_normal(2) * 4
            proj = np.asarray(project_l1_ball(jnp.asarray(v, jnp.float32), 1.0))
            # dense grid over the l1 ball boundary + interior
            ts = np.linspace(-1, 1, 401)
            xx, yy = np.meshgrid(ts, ts)
            mask = np.abs(xx) + np.abs(yy) <= 1.0
            pts = np.stack([xx[mask], yy[mask]], -1)
            d_grid = np.min(((pts - v) ** 2).sum(-1))
            d_proj = ((proj - v) ** 2).sum()
            assert d_proj <= d_grid + 1e-3
