"""Shared fixtures. NOTE: device count stays 1 here — only launch/dryrun.py
sets XLA_FLAGS=--xla_force_host_platform_device_count (per DESIGN.md)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data import make_regression, standardize


@pytest.fixture(scope="session")
def small_problem():
    """Standardized regression problem, feature-major design matrix."""
    ds = standardize(make_regression(m=80, p=300, n_informative=10, noise=0.5, seed=0))
    return jnp.asarray(ds.X.T.copy()), jnp.asarray(ds.y), ds


@pytest.fixture(scope="session")
def medium_problem():
    ds = standardize(
        make_regression(m=150, p=2000, n_informative=40, noise=1.0, seed=1)
    )
    return jnp.asarray(ds.X.T.copy()), jnp.asarray(ds.y), ds


@pytest.fixture()
def rng_key():
    return jax.random.PRNGKey(42)
