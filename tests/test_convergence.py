"""Empirical validation of the paper's convergence theory.

Proposition 1/2: f(alpha_k) - f* <= 4 C_f / (k+2) (deterministic; in
expectation for the stochastic rule). We fit the bound on small problems
where f* is computable to high precision.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FWConfig, FISTAConfig, baselines, fw_solve_with_history


def _fstar(Xt, y, delta, key):
    res = baselines.fista_solve(
        Xt, y, FISTAConfig(delta=delta, constrained=True, max_iters=20000, tol=1e-12),
        key,
    )
    return float(res.objective)


def _curvature_upper(Xt, delta):
    """C_f <= diam^2 * L / 2 with diam_2(l1-ball) = 2*delta, L = ||X||_2^2.

    (Jaggi 2013, for quadratics: C_f <= sup ||y-x||_H^2 over the ball.)
    """
    L = float(np.linalg.norm(np.asarray(Xt), 2) ** 2)
    return 0.5 * (2 * delta) ** 2 * L


class TestConvergenceRate:
    def test_deterministic_rate(self, small_problem, rng_key):
        Xt, y, _ = small_problem
        delta = 100.0
        fstar = _fstar(Xt, y, delta, rng_key)
        cfg = FWConfig(delta=delta, sampling="full", max_iters=10**6, tol=0.0,
                       patience=10**9)
        _, hist = fw_solve_with_history(Xt, y, cfg, rng_key, n_iters=400)
        h = np.asarray(hist) - fstar
        Cf = _curvature_upper(Xt, delta)
        ks = np.arange(1, len(h) + 1)
        bound = 4 * Cf / (ks + 2)
        assert np.all(h[5:] <= bound[5:] + 1e-2), (
            f"max violation {np.max(h[5:] - bound[5:])}"
        )

    def test_stochastic_rate_in_expectation(self, small_problem):
        """Average over seeds approximates E[f(a_k)] - f* <= 4 C~_f/(k+2)."""
        Xt, y, _ = small_problem
        delta = 100.0
        fstar = _fstar(Xt, y, delta, jax.random.PRNGKey(0))
        cfg = FWConfig(delta=delta, sampling="uniform", kappa=60, max_iters=10**6,
                       tol=0.0, patience=10**9)
        hists = []
        for seed in range(8):
            _, hist = fw_solve_with_history(
                Xt, y, cfg, jax.random.PRNGKey(seed), n_iters=400
            )
            hists.append(np.asarray(hist))
        mean_h = np.mean(hists, axis=0) - fstar
        Cf = _curvature_upper(Xt, delta)
        ks = np.arange(1, len(mean_h) + 1)
        bound = 4 * Cf / (ks + 2)
        assert np.all(mean_h[5:] <= bound[5:] + 1e-2)

    def test_rate_is_sublinear_not_stalled(self, small_problem, rng_key):
        """h_k must actually decrease ~1/k: check h_{4k} < h_k/2 roughly."""
        Xt, y, _ = small_problem
        delta = 100.0
        fstar = _fstar(Xt, y, delta, rng_key)
        cfg = FWConfig(delta=delta, sampling="uniform", kappa=60, max_iters=10**6,
                       tol=0.0, patience=10**9)
        _, hist = fw_solve_with_history(Xt, y, cfg, rng_key, n_iters=512)
        h = np.asarray(hist) - fstar
        floor = 1e-6 * float(0.5 * jnp.dot(y, y))
        h = np.maximum(h, floor)
        # either strictly decreasing in the 1/k regime, or already at floor
        assert h[400] < h[100] or h[400] <= floor
        assert h[-1] < 0.25 * h[10] or h[-1] <= floor
