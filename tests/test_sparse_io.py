"""Sparse IO layer (ISSUE 2): svmlight round-trip, .npz shard streaming
equivalence, and the data/proxies.py densification guard + sparse-native
proxy builder.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FWConfig, fw_solve
from repro.data import dense_proxy_bytes, make_proxy, make_sparse_proxy
from repro.data.proxies import make_sparse_coo
from repro.sparse import (
    COOData,
    SparseBlockMatrix,
    io as sio,
)


def _coo(seed=0, m=57, p=301, density=0.03):
    rows, cols, vals, y, _ = make_sparse_coo(m, p, density, 10, seed=seed)
    return sio.COOData(rows, cols, vals, y, (m, p))


def _canon(d: COOData):
    order = np.lexsort((d.cols, d.rows))
    return d.rows[order], d.cols[order], d.vals[order]


class TestSvmlight:
    @pytest.mark.parametrize("zero_based", [False, True])
    def test_roundtrip(self, tmp_path, zero_based):
        data = _coo()
        path = tmp_path / "t.svm"
        sio.save_svmlight(path, data, zero_based=zero_based)
        # explicit base on load: auto-detection cannot distinguish a
        # 0-based file with an empty feature 0 from a 1-based file
        back = sio.load_svmlight(path, n_features=data.shape[1], zero_based=zero_based)
        assert back.shape == data.shape
        for a, b in zip(_canon(data), _canon(back)):
            np.testing.assert_allclose(a, b, rtol=1e-6)
        np.testing.assert_allclose(back.y, data.y, rtol=1e-6)

    def test_auto_base_detection_one_based(self, tmp_path):
        path = tmp_path / "one.svm"
        path.write_text("1.5 1:2.0 7:3.0\n-0.5 2:1.0\n")
        back = sio.load_svmlight(path)
        assert back.shape == (2, 7)  # max index 7, 1-based -> p=7
        assert set(back.cols.tolist()) == {0, 1, 6}

    def test_comments_and_qid_ignored(self, tmp_path):
        path = tmp_path / "q.svm"
        path.write_text("# header\n2.0 qid:4 1:1.0 # trailing\n\n3.0 2:5.0\n")
        back = sio.load_svmlight(path)
        assert back.shape[0] == 2
        np.testing.assert_allclose(back.y, [2.0, 3.0])

    def test_n_features_too_small_raises(self, tmp_path):
        path = tmp_path / "s.svm"
        path.write_text("1.0 5:1.0\n")
        with pytest.raises(ValueError, match="n_features"):
            sio.load_svmlight(path, n_features=2)

    def test_svmlight_to_solver(self, tmp_path):
        """Full text -> matrix -> solve pipeline."""
        data = _coo(seed=5, m=40, p=260)
        path = tmp_path / "full.svm"
        sio.save_svmlight(path, data)
        back = sio.load_svmlight(path, n_features=260)
        mat = SparseBlockMatrix.from_coo(
            back.rows, back.cols, back.vals, back.shape, block_size=128
        )
        res = fw_solve(
            mat, jnp.asarray(back.y),
            FWConfig(delta=5.0, backend="sparse", kappa=32, max_iters=300, tol=1e-4),
            jax.random.PRNGKey(0),
        )
        assert bool(jnp.isfinite(res.objective))


class TestSvmlightStreaming:
    def test_streaming_conversion_equals_in_memory(self, tmp_path):
        """convert_svmlight_to_shards == load_svmlight + write_shards."""
        data = _coo(seed=8)
        svm = tmp_path / "d.svm"
        sio.save_svmlight(svm, data)  # 1-based
        stream_dir = tmp_path / "stream"
        mem_dir = tmp_path / "mem"
        sio.convert_svmlight_to_shards(svm, stream_dir, rows_per_shard=11)
        sio.write_shards(
            mem_dir, sio.load_svmlight(svm, zero_based=False), rows_per_shard=11
        )
        a = sio.load_shards(stream_dir)
        b = sio.load_shards(mem_dir)
        assert a.shape == b.shape
        for x, y in zip(_canon(a), _canon(b)):
            np.testing.assert_allclose(x, y, rtol=1e-6)
        np.testing.assert_allclose(a.y, b.y, rtol=1e-6)
        # and the streamed shards assemble into the same matrix
        mat_a, _ = sio.load_shards_as_matrix(stream_dir, block_size=64)
        mat_b, _ = sio.load_shards_as_matrix(mem_dir, block_size=64)
        np.testing.assert_allclose(
            np.asarray(mat_a.to_dense()), np.asarray(mat_b.to_dense()), atol=1e-7
        )

    def test_streaming_n_features_and_empty_rows(self, tmp_path):
        svm = tmp_path / "e.svm"
        svm.write_text("1.0 3:2.0\n0.5\n-1.0 1:1.0 7:4.0\n")
        out = tmp_path / "out"
        sio.convert_svmlight_to_shards(svm, out, rows_per_shard=2, n_features=10)
        man = sio.read_manifest(out)
        assert man["m"] == 3 and man["p"] == 10 and len(man["shards"]) == 2
        back = sio.load_shards(out)
        np.testing.assert_allclose(back.y, [1.0, 0.5, -1.0])
        assert set(back.cols.tolist()) == {0, 2, 6}  # 1-based shifted down


class TestShards:
    def test_roundtrip_nondivisible_rows(self, tmp_path):
        data = _coo()
        sio.write_shards(tmp_path, data, rows_per_shard=13)  # 57 % 13 != 0
        man = sio.read_manifest(tmp_path)
        assert man["m"] == 57 and man["p"] == 301 and len(man["shards"]) == 5
        back = sio.load_shards(tmp_path)
        for a, b in zip(_canon(data), _canon(back)):
            np.testing.assert_allclose(a, b, rtol=1e-6)
        np.testing.assert_allclose(back.y, data.y)

    def test_streaming_assembly_equals_direct(self, tmp_path):
        data = _coo(seed=3)
        sio.write_shards(tmp_path, data, rows_per_shard=10)
        mat_s, y_s = sio.load_shards_as_matrix(tmp_path, block_size=64)
        mat_d = SparseBlockMatrix.from_coo(
            data.rows, data.cols, data.vals, data.shape, block_size=64
        )
        np.testing.assert_allclose(
            np.asarray(mat_s.to_dense()), np.asarray(mat_d.to_dense()), atol=1e-7
        )
        np.testing.assert_allclose(y_s, data.y)

    def test_shard_iteration_is_bounded(self, tmp_path):
        """Each yielded chunk only spans its own row range (out-of-core
        contract: one shard in memory at a time)."""
        data = _coo(seed=4)
        sio.write_shards(tmp_path, data, rows_per_shard=20)
        for chunk, off in sio.iter_shards(tmp_path):
            assert chunk.y.shape[0] <= 20
            if chunk.rows.size:
                assert chunk.rows.min() >= off
                assert chunk.rows.max() < off + 20

    def test_budget_too_small_raises(self, tmp_path):
        data = _coo(seed=6)
        sio.write_shards(tmp_path, data, rows_per_shard=30)
        with pytest.raises(ValueError, match="nnz budget"):
            sio.load_shards_as_matrix(tmp_path, block_size=64, nnz_max=1)

    def test_unknown_format_raises(self, tmp_path):
        (tmp_path / sio.MANIFEST_NAME).write_text('{"format": "bogus"}')
        with pytest.raises(ValueError, match="unknown shard format"):
            sio.read_manifest(tmp_path)


class TestProxyGuard:
    def test_dense_build_over_budget_raises_with_estimate(self):
        est = dense_proxy_bytes("e2006-log1p", 0.1)
        with pytest.raises(MemoryError) as ei:
            make_proxy("e2006-log1p", scale=0.1, max_dense_bytes=64 << 20)
        msg = str(ei.value)
        assert f"{est:,}" in msg  # the estimate is in the error
        assert "make_sparse_proxy" in msg  # and so is the sparse escape hatch

    def test_env_budget(self, monkeypatch):
        monkeypatch.setenv("REPRO_DENSE_BUDGET_BYTES", "1000")
        with pytest.raises(MemoryError):
            make_proxy("e2006-tfidf", scale=0.01)

    def test_under_budget_builds(self):
        ds = make_proxy("e2006-tfidf", scale=0.005, max_dense_bytes=1 << 30)
        assert ds.X.shape[0] >= 32

    def test_sparse_proxy_of_dense_dataset_raises(self):
        with pytest.raises(ValueError, match="dense"):
            make_sparse_proxy("pyrim", scale=0.01)

    def test_sparse_proxy_beyond_dense_budget_solves(self):
        """ISSUE 2 acceptance: a scale whose DENSE build exceeds the budget
        must still build sparsely and solve with backend='sparse'."""
        scale = 0.02
        budget = 32 << 20  # dense would need ~130 MB at this scale
        assert dense_proxy_bytes("e2006-log1p", scale) > budget
        with pytest.raises(MemoryError):
            make_proxy("e2006-log1p", scale=scale, max_dense_bytes=budget)
        ds = make_sparse_proxy("e2006-log1p", scale=scale, seed=0)
        assert ds.mat.nbytes < budget  # sparse build fits where dense cannot
        p = ds.mat.shape[0]
        res = fw_solve(
            ds.mat, jnp.asarray(ds.y),
            FWConfig(delta=25.0, backend="sparse", sampling="uniform",
                     kappa=max(64, p // 100), max_iters=400, tol=1e-4),
            jax.random.PRNGKey(0),
        )
        assert bool(jnp.isfinite(res.objective))
        assert float(jnp.sum(jnp.abs(res.alpha))) <= 25.0 * (1 + 1e-5)
        assert int(res.active) > 0

    def test_sparse_proxy_conditioning(self):
        """Unit column norms + centered y (the §4.1 contract, uncentered X)."""
        ds = make_sparse_proxy("e2006-tfidf", scale=0.01, seed=1)
        _, zn2 = __import__("repro.sparse.ops", fromlist=["ops"]).sparse_colstats(
            ds.mat, jnp.zeros(ds.mat.m)
        )
        nz = np.asarray(zn2) > 0
        np.testing.assert_allclose(np.asarray(zn2)[nz], 1.0, rtol=1e-4)
        assert abs(float(ds.y.mean())) < 1e-4


class TestFetchLibsvm:
    """scripts/fetch_libsvm.py conversion + verification path, exercised
    against a local file:// "download" (no network in CI)."""

    def _serve_bz2(self, tmp_path, data):
        import bz2

        svm = tmp_path / "local.svm"
        sio.save_svmlight(svm, data, zero_based=False)
        packed = tmp_path / "local.svm.bz2"
        packed.write_bytes(bz2.compress(svm.read_bytes()))
        return f"file://{packed}"

    def _load_script(self):
        import importlib.util, pathlib

        root = pathlib.Path(__file__).resolve().parents[1]
        spec = importlib.util.spec_from_file_location(
            "fetch_libsvm", root / "scripts" / "fetch_libsvm.py"
        )
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        return mod

    def test_fetch_converts_and_verifies(self, tmp_path, monkeypatch):
        mod = self._load_script()
        data = _coo(seed=3, m=40, p=90)
        url = self._serve_bz2(tmp_path, data)
        monkeypatch.setitem(mod.DATASETS, "e2006-tfidf", (url, data.shape))
        out = tmp_path / "shards"
        shard_dir = mod.fetch_one("e2006-tfidf", str(out), 16, timeout=5.0)
        manifest = sio.read_manifest(shard_dir)
        assert (manifest["m"], manifest["p"]) == data.shape
        mat, y = sio.load_shards_as_matrix(shard_dir)
        np.testing.assert_allclose(np.asarray(y), data.y, rtol=1e-6)
        got = np.asarray(mat.to_dense())  # feature-major (p, m)
        want = np.zeros(data.shape, np.float32)
        want[data.rows, data.cols] = data.vals
        np.testing.assert_allclose(got, want.T, rtol=1e-6)
        # idempotent: a second call reuses the manifest
        assert mod.fetch_one("e2006-tfidf", str(out), 16, timeout=5.0) == shard_dir

    def test_fetch_shape_mismatch_removes_shards(self, tmp_path, monkeypatch):
        mod = self._load_script()
        data = _coo(seed=4, m=40, p=90)
        url = self._serve_bz2(tmp_path, data)
        out = tmp_path / "shards"
        # wrong sample count: must refuse the shards
        monkeypatch.setitem(mod.DATASETS, "e2006-tfidf", (url, (41, 90)))
        with pytest.raises(RuntimeError, match="published"):
            mod.fetch_one("e2006-tfidf", str(out), 16, timeout=5.0)
        assert not (out / "e2006-tfidf" / "manifest.json").exists()
        # published p SMALLER than the file's max feature index: the
        # converter itself refuses (indices out of the stated range)
        monkeypatch.setitem(mod.DATASETS, "e2006-tfidf", (url, (40, 50)))
        with pytest.raises(ValueError):
            mod.fetch_one("e2006-tfidf", str(out), 16, timeout=5.0)
        assert not (out / "e2006-tfidf" / "manifest.json").exists()
        # published p LARGER is benign: trailing features absent from the
        # training split are padded to the published width
        monkeypatch.setitem(mod.DATASETS, "e2006-tfidf", (url, (40, 95)))
        shard_dir = mod.fetch_one("e2006-tfidf", str(out), 16, timeout=5.0)
        assert sio.read_manifest(shard_dir)["p"] == 95

    def test_benchmarks_prefer_real_shards(self, tmp_path, monkeypatch):
        """benchmarks/common.load_sparse_dataset picks up converted shards
        from $REPRO_DATA_DIR and falls back to the proxy otherwise."""
        import sys, pathlib

        root = pathlib.Path(__file__).resolve().parents[1]
        monkeypatch.syspath_prepend(str(root))
        data = _coo(seed=5, m=40, p=90)
        shard_dir = tmp_path / "e2006-tfidf"
        sio.write_shards(shard_dir, data, rows_per_shard=16)
        import benchmarks.common as common

        monkeypatch.setattr(common, "REPRO_DATA_DIR", str(tmp_path))
        mat, y, ds = common.load_sparse_dataset("e2006-tfidf")
        assert ds.name.endswith("-real") and ds.coef is None
        assert mat.shape == (90, 40)
        assert abs(float(np.asarray(y).mean())) < 1e-6  # centered targets
        mat2, _, ds2 = common.load_sparse_dataset("e2006-tfidf", prefer_real=False)
        assert ds2.coef is not None  # proxy still available on demand
