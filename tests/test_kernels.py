"""Per-kernel interpret-mode validation against pure-jnp oracles,
with hypothesis shape/dtype sweeps (brief deliverable (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st  # skips @given tests when hypothesis is missing

from repro.kernels import colstats, fw_vertex, residual_update, sampled_scores
from repro.kernels.colstats.ref import colstats_ref
from repro.kernels.fw_grad.ref import sampled_argmax_ref, sampled_scores_ref
from repro.kernels.residual_update.ref import residual_update_ref

I = dict(interpret=True)


def _problem(p, m, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    Xt = jnp.asarray(rng.standard_normal((p, m)).astype(dtype))
    r = jnp.asarray(rng.standard_normal(m).astype(dtype))
    return Xt, r


class TestFWGradKernel:
    def test_matches_ref_basic(self):
        Xt, r = _problem(1024, 512, 0)
        blk = jnp.asarray([0, 3, 1], jnp.int32)
        got = sampled_scores(Xt, r, blk, block_size=256, m_tile=256, **I)
        want, _ = sampled_scores_ref(Xt, r, blk, 256)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-4)

    def test_vertex_matches_ref(self):
        Xt, r = _problem(2048, 128, 1)
        blk = jnp.asarray([7, 0, 5, 2], jnp.int32)
        i_k, g_k = fw_vertex(Xt, r, blk, block_size=256, m_tile=128, **I)
        i_r, g_r = sampled_argmax_ref(Xt, r, blk, 256)
        assert int(i_k) == int(i_r)
        np.testing.assert_allclose(float(g_k), float(g_r), rtol=2e-5, atol=2e-4)

    def test_single_mtile_fallback(self):
        Xt, r = _problem(512, 300, 2)  # m=300 not divisible by default tile
        blk = jnp.asarray([1, 0], jnp.int32)
        got = sampled_scores(Xt, r, blk, block_size=256, **I)
        want, _ = sampled_scores_ref(Xt, r, blk, 256)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-4)

    @settings(max_examples=10, deadline=None)
    @given(
        nb=st.integers(1, 6),
        mt_pow=st.integers(5, 8),
        seed=st.integers(0, 100),
        bs=st.sampled_from([128, 256]),
    )
    def test_hypothesis_shape_sweep(self, nb, mt_pow, seed, bs):
        m = 2**mt_pow
        p = bs * 16
        Xt, r = _problem(p, m, seed)
        rng = np.random.default_rng(seed)
        blk = jnp.asarray(rng.choice(p // bs, nb, replace=False).astype(np.int32))
        got = sampled_scores(Xt, r, blk, block_size=bs, m_tile=min(m, 512), **I)
        want, _ = sampled_scores_ref(Xt, r, blk, bs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-4)

    @pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        Xt, r = _problem(512, 256, 3, dtype=np.float32)
        Xt = Xt.astype(dtype)
        r = r.astype(dtype)
        blk = jnp.asarray([0, 1], jnp.int32)
        got = sampled_scores(Xt, r, blk, block_size=256, m_tile=256, **I)
        want, _ = sampled_scores_ref(Xt.astype(jnp.float32), r.astype(jnp.float32), blk, 256)
        tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol * 10)


class TestResidualUpdateKernel:
    def test_matches_ref(self):
        rng = np.random.default_rng(0)
        m = 4096
        r, y, z = (jnp.asarray(rng.standard_normal(m).astype(np.float32)) for _ in range(3))
        lam = jnp.asarray(0.37)
        dt = jnp.asarray(-2.5)
        got = residual_update(r, y, z, lam, dt, **I)
        want = residual_update_ref(r, y, z, lam, dt)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)

    @settings(max_examples=10, deadline=None)
    @given(
        m=st.sampled_from([128, 512, 777, 2048, 5000]),
        lam=st.floats(0.0, 1.0),
        seed=st.integers(0, 50),
    )
    def test_hypothesis_sweep(self, m, lam, seed):
        rng = np.random.default_rng(seed)
        r, y, z = (jnp.asarray(rng.standard_normal(m).astype(np.float32)) for _ in range(3))
        got = residual_update(r, y, z, jnp.asarray(lam), jnp.asarray(1.5), **I)
        want = residual_update_ref(r, y, z, lam, 1.5)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


class TestColstatsKernel:
    def test_matches_ref(self):
        Xt, y = _problem(1024, 512, 4)
        zty, zn2 = colstats(Xt, y, p_tile=256, m_tile=256, **I)
        zty_r, zn2_r = colstats_ref(Xt, y)
        np.testing.assert_allclose(np.asarray(zty), np.asarray(zty_r), rtol=2e-5, atol=2e-4)
        np.testing.assert_allclose(np.asarray(zn2), np.asarray(zn2_r), rtol=2e-5, atol=2e-4)

    @settings(max_examples=8, deadline=None)
    @given(
        pt=st.sampled_from([128, 256]),
        m=st.sampled_from([64, 500, 1024]),
        seed=st.integers(0, 50),
    )
    def test_hypothesis_sweep(self, pt, m, seed):
        p = pt * 4
        Xt, y = _problem(p, m, seed)
        zty, zn2 = colstats(Xt, y, p_tile=pt, m_tile=512, **I)
        zty_r, zn2_r = colstats_ref(Xt, y)
        np.testing.assert_allclose(np.asarray(zty), np.asarray(zty_r), rtol=2e-5, atol=2e-4)
        np.testing.assert_allclose(np.asarray(zn2), np.asarray(zn2_r), rtol=2e-5, atol=2e-4)


class TestKernelSolverIntegration:
    def test_kernel_vertex_equals_solver_scores(self):
        """The kernel's vertex choice must match the solver's jnp gather path."""
        from repro.data import make_regression, standardize

        ds = standardize(make_regression(m=64, p=1024, n_informative=8, seed=5))
        Xt = jnp.asarray(ds.X.T.copy())
        r = jnp.asarray(ds.y)  # residual at alpha=0 is y
        blk = jnp.asarray([0, 2, 3], jnp.int32)
        i_k, g_k = fw_vertex(Xt, r, blk, block_size=256, m_tile=64, **I)
        idx = (blk[:, None] * 256 + jnp.arange(256)[None, :]).reshape(-1)
        grad_s = -(jnp.take(Xt, idx, axis=0) @ r)
        j = jnp.argmax(jnp.abs(grad_s))
        assert int(i_k) == int(idx[j])
        np.testing.assert_allclose(float(g_k), float(grad_s[j]), rtol=2e-5, atol=1e-4)
