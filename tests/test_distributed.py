"""Distributed FW subsystem (repro.distributed) == single-device engine,
run on 4 virtual CPU devices in a subprocess so the main test process
keeps 1 device (DESIGN.md rule).

Coverage (ISSUE 4 acceptance):
  * uniform-sampling sparse lasso on a (1, 4) mesh is BIT-IDENTICAL to
    the single-device sparse engine in its trajectory (alpha, iteration
    and dot counts); the reported objective matches to 1 ulp (the final
    scalar formula may compile with different FMA fusion in the two
    programs — the trajectory itself carries no tolerance);
  * dense lasso on (1, 4) is bit-identical too;
  * all three oracles (lasso / logistic / elastic-net) solve through the
    distributed backend on a (2, 2) mesh with SPARSE inputs, matching
    the single-device engine to tolerance (the data axis splits fp sums);
  * the sharded batched path driver equals the sharded sequential driver
    under lane pruning, and reports certified duality gaps (oracle
    ``gap()``) at every grid point;
  * the coo-npz-v1 manifest loader places the same operand as the
    in-memory shard placement;
  * away/pairwise step rules (DESIGN.md §StepRule) solve through the
    distributed backend, matching single-device sparse to tolerance on a
    (1, 4) mesh (the away-step arithmetic picks up different FMA fusion
    under shard_map, so unlike the classic rule the parity is fp-level,
    not bitwise — the index streams and step kinds still agree);
  * a non-default ``fuse_steps`` warns once and the forced value is
    surfaced on ``SolveResult.effective_fuse_steps``;
  * (ISSUE 7) the telemetry ring rides the sharded driver: telemetry off
    vs on is bit-identical, the ring's step facts (k, i_star, event,
    n_dots) match the single-device sparse ring bitwise, its objective
    column matches to 1 ulp, and ``solve_with_history`` returns exactly
    the ring's objective column.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json, tempfile, warnings
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core import FWConfig, LASSO, LOGISTIC, ENOracle, engine
    from repro import distributed as dist
    from repro.data import make_regression, standardize
    from repro.sparse import io as sio
    from repro.sparse.matrix import SparseBlockMatrix

    out = {}
    ds = standardize(make_regression(m=96, p=300, n_informative=10,
                                     noise=0.5, seed=3))
    y = np.asarray(ds.y)
    yj = jnp.asarray(y)
    Xd = np.asarray(ds.X.T, np.float32).copy()
    Xs = Xd.copy()
    Xs[np.abs(Xs) < 0.05] = 0.0   # standardized unit-norm cols: |x| ~ 0.1
    mat = SparseBlockMatrix.from_dense(Xs, block_size=32)
    key = jax.random.PRNGKey(0)
    cfg = FWConfig(delta=120.0, sampling="uniform", kappa=60,
                   max_iters=400, tol=0.0, patience=10**9)
    as_sparse = lambda c: FWConfig(**{**c.__dict__, "backend": "sparse"})

    # ---- bit-identity: sparse lasso, uniform sampling, (1, 4) mesh ----
    mesh14 = dist.fw_mesh(n_data=1, n_model=4)
    op14 = dist.shard_sparse(mat, y, mesh14)
    r_d = dist.solve(LASSO, op14, cfg, key)
    r_s = engine.solve(LASSO, mat, yj, as_sparse(cfg), key)
    out["sp14_alpha_bitident"] = bool(
        (np.asarray(r_d.alpha) == np.asarray(r_s.alpha)).all())
    out["sp14_counts"] = [int(r_d.iterations), int(r_s.iterations),
                          int(r_d.n_dots), int(r_s.n_dots)]
    out["sp14_obj"] = [float(r_d.objective), float(r_s.objective)]

    # ---- bit-identity: dense lasso on (1, 4) ----
    opd = dist.shard_dense(Xd, y, mesh14)
    rd_d = dist.solve(LASSO, opd, cfg, key)
    rd_s = engine.solve(LASSO, jnp.asarray(Xd), yj, cfg, key)
    out["dn14_alpha_bitident"] = bool(
        (np.asarray(rd_d.alpha) == np.asarray(rd_s.alpha)).all())

    # ---- (2, 2) mesh, sparse inputs, all three oracles ----
    mesh22 = dist.fw_mesh(n_data=2, n_model=2)
    op22 = dist.shard_sparse(mat, y, mesh22)
    fam = {}
    r22 = dist.solve(LASSO, op22, cfg, key)
    fam["lasso"] = [float(r22.objective), float(r_s.objective),
                    float(jnp.sum(jnp.abs(r22.alpha))), cfg.delta]

    en = ENOracle(l2=1.0)
    cfg_en = FWConfig(delta=30.0, sampling="uniform", kappa=60,
                      max_iters=1500, tol=1e-5)
    e_d = dist.solve(en, op22, cfg_en, key)
    e_s = engine.solve(en, mat, yj, as_sparse(cfg_en), key)
    fam["elasticnet"] = [float(e_d.objective), float(e_s.objective),
                         float(jnp.sum(jnp.abs(e_d.alpha))), cfg_en.delta]

    rng = np.random.default_rng(0)
    Xl = rng.standard_normal((120, 80)).astype(np.float32)
    Xl[np.abs(Xl) < 0.7] = 0.0
    w0 = np.zeros(80, np.float32); w0[:5] = rng.standard_normal(5) * 2
    yl = np.sign(Xl @ w0 + 0.1 * rng.standard_normal(120)).astype(np.float32)
    yl[yl == 0] = 1.0
    mat_l = SparseBlockMatrix.from_dense(Xl.T.copy(), block_size=16)
    cfg_lg = FWConfig(delta=20.0, sampling="uniform", kappa=40,
                      max_iters=800, tol=1e-6)
    l_d = dist.solve(LOGISTIC, dist.shard_sparse(mat_l, yl, mesh22),
                     cfg_lg, key)
    l_s = engine.solve(LOGISTIC, mat_l, jnp.asarray(yl), as_sparse(cfg_lg), key)
    fam["logistic"] = [float(l_d.objective), float(l_s.objective),
                       float(jnp.sum(jnp.abs(l_d.alpha))), cfg_lg.delta]

    # dense layout, same (2, 2) mesh, all three oracles
    opd22 = dist.shard_dense(Xd, y, mesh22)
    rd = dist.solve(LASSO, opd22, cfg, key)
    rs = engine.solve(LASSO, jnp.asarray(Xd), yj, cfg, key)
    fam["lasso_dense"] = [float(rd.objective), float(rs.objective),
                          float(jnp.sum(jnp.abs(rd.alpha))), cfg.delta]
    ed = dist.solve(en, opd22, cfg_en, key)
    es = engine.solve(en, jnp.asarray(Xd), yj, cfg_en, key)
    fam["elasticnet_dense"] = [float(ed.objective), float(es.objective),
                               float(jnp.sum(jnp.abs(ed.alpha))), cfg_en.delta]
    Xld = Xl.T.copy()
    ld = dist.solve(LOGISTIC, dist.shard_dense(Xld, yl, mesh22), cfg_lg, key)
    ls = engine.solve(LOGISTIC, jnp.asarray(Xld), jnp.asarray(yl), cfg_lg, key)
    fam["logistic_dense"] = [float(ld.objective), float(ls.objective),
                             float(jnp.sum(jnp.abs(ld.alpha))), cfg_lg.delta]
    out["family"] = fam

    # ---- block sampling rides the sparse kernel path on the mesh ----
    cfg_blk = FWConfig(delta=120.0, sampling="block", kappa=64,
                       max_iters=800, tol=1e-5)
    b_d = dist.solve(LASSO, op22, cfg_blk, key)
    b_s = engine.solve(LASSO, mat, yj, as_sparse(cfg_blk), key)
    out["block"] = [float(b_d.objective), float(b_s.objective)]

    # ---- sharded path drivers: batched == sequential, certified gaps ----
    deltas = np.geomspace(12.0, 120.0, 6)
    cfg_p = FWConfig(delta=1.0, sampling="uniform", kappa=60,
                     max_iters=5000, tol=1e-4)
    seq = dist.fw_path(op14, deltas, cfg_p)
    bat = dist.fw_path_batched(op14, deltas, cfg_p, lane_width=3)
    out["path_objs"] = [[p.objective for p in seq.points],
                        [p.objective for p in bat.points]]
    out["path_gaps"] = [p.gap for p in seq.points]
    out["path_gap_scale"] = [abs(p.objective) for p in seq.points]
    out["path_saved"] = int(bat.saved_iters)

    # ---- history driver: per-step objectives match single device ----
    hr_d, hist_d = dist.solve_with_history(LASSO, op14, cfg, key, 50)
    hr_s, hist_s = engine.solve_with_history(LASSO, mat, yj, as_sparse(cfg),
                                             key, 50)
    out["history"] = [np.asarray(hist_d).tolist(), np.asarray(hist_s).tolist()]

    # ---- telemetry ring through the distributed driver (ISSUE 7) ----
    from repro.obs import TelemetrySpec, ring_to_records
    cfg_t = FWConfig(**{**cfg.__dict__, "max_iters": 60,
                        "telemetry": TelemetrySpec(capacity=60)})
    cfg_t_off = FWConfig(**{**cfg.__dict__, "max_iters": 60})
    t_d = dist.solve(LASSO, op14, cfg_t, key)
    t_off = dist.solve(LASSO, op14, cfg_t_off, key)
    t_s = engine.solve(LASSO, mat, yj, as_sparse(cfg_t), key)
    rec_d = ring_to_records(t_d.telemetry)
    rec_s = ring_to_records(t_s.telemetry)
    out["tel"] = {
        # ring on/off must not move the sharded trajectory
        "off_bitident": bool(
            (np.asarray(t_d.alpha) == np.asarray(t_off.alpha)).all()),
        # step facts match the single-device sparse ring bit for bit
        "ring_bitident": {
            f: bool((rec_d[f] == rec_s[f]).all())
            for f in ("k", "i_star", "event", "n_dots", "record_index")
        },
        # scalar columns may pick up shard_map FMA fusion: ulp-level
        "obj_curve": [np.asarray(rec_d["objective"]).tolist(),
                      np.asarray(rec_s["objective"]).tolist()],
        # solve_with_history IS the ring now; its result surfaces it
        "hist_equals_ring": bool(
            (np.asarray(hist_d)
             == np.asarray(hr_d.telemetry.objective[:50])).all()),
    }

    # ---- standalone certified gap: mesh == single device ----
    g_d = float(dist.certified_gap(LASSO, op14, r_d.alpha, 120.0, cfg))
    g_s = float(LASSO.gap(mat, yj, r_s.alpha, 120.0))
    out["gap"] = [g_d, g_s, float(r_s.objective)]

    # ---- coo-npz-v1 manifest -> mesh loader parity ----
    feat, samp = np.nonzero(Xs)
    coo = sio.COOData(samp, feat, Xs[feat, samp], y, (96, 300))
    with tempfile.TemporaryDirectory() as td:
        sio.write_shards(td, coo, rows_per_shard=17)
        man = sio.read_manifest(td)
        out["rowplan"] = sio.shards_for_rows(man, 48, 96)
        op_ld = dist.load_sharded_matrix(td, mesh22, block_size=32)
    r_ld = dist.solve(LASSO, op_ld, cfg_blk, key)
    out["loader_obj"] = [float(r_ld.objective), float(b_d.objective)]

    # ---- step rules through the distributed backend (§StepRule) ----
    rules = {}
    for rule in ("away", "pairwise"):
        cfg_r = FWConfig(**{**cfg.__dict__, "step_rule": rule})
        rr_d = dist.solve(LASSO, op14, cfg_r, key)
        rr_s = engine.solve(LASSO, mat, yj, as_sparse(cfg_r), key)
        rules[rule] = {
            "objs": [float(rr_d.objective), float(rr_s.objective)],
            "l1": float(jnp.sum(jnp.abs(rr_d.alpha))),
            "active": [int(jnp.sum(rr_d.alpha != 0)),
                       int(jnp.sum(rr_s.alpha != 0))],
        }
    out["rules"] = rules

    # ---- forced fuse_steps=1: warns once, surfaced on the result ----
    cfg_f = FWConfig(**{**cfg.__dict__, "fuse_steps": 4})
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        rf = dist.solve(LASSO, op14, cfg_f, key)
        rf2 = dist.solve(LASSO, op14, cfg_f, key)
    out["fuse"] = {
        "n_warn": sum("fuse_steps" in str(w.message) for w in caught),
        "effective": int(rf.effective_fuse_steps),
    }

    print("RESULT" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def dist_result():
    import os
    limit = max(900, int(os.environ.get("REPRO_SUBPROC_TIMEOUT", "0")))
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=limit,
        env={"PYTHONPATH": str(Path(__file__).resolve().parents[1] / "src"),
             "PATH": "/usr/bin:/bin",
             # stripped env: pin the backend or PJRT plugin discovery can hang
             "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def _ulp_close(a, b):
    return abs(a - b) <= 2 * np.spacing(np.float32(max(abs(a), abs(b))))


class TestBitIdentity:
    def test_sparse_lasso_uniform_trajectory_bit_identical(self, dist_result):
        r = dist_result
        assert r["sp14_alpha_bitident"]
        it_d, it_s, nd_d, nd_s = r["sp14_counts"]
        assert (it_d, nd_d) == (it_s, nd_s)

    def test_sparse_lasso_objective_one_ulp(self, dist_result):
        o_d, o_s = dist_result["sp14_obj"]
        assert _ulp_close(o_d, o_s), (o_d, o_s)

    def test_dense_lasso_bit_identical(self, dist_result):
        assert dist_result["dn14_alpha_bitident"]


class TestSolverFamilyOnMesh:
    @pytest.mark.parametrize("oracle", [
        "lasso", "logistic", "elasticnet",
        "lasso_dense", "logistic_dense", "elasticnet_dense",
    ])
    def test_oracle_matches_single_device(self, dist_result, oracle):
        obj_d, obj_s, l1, delta = dist_result["family"][oracle]
        rel = abs(obj_d - obj_s) / max(abs(obj_s), 1e-9)
        assert rel < 1e-4, (oracle, rel)
        assert l1 <= delta * (1 + 1e-4)

    def test_block_sampling_parity(self, dist_result):
        obj_d, obj_s = dist_result["block"]
        assert abs(obj_d - obj_s) / abs(obj_s) < 1e-4


class TestShardedPathDrivers:
    def test_batched_equals_sequential_with_pruning(self, dist_result):
        seq, bat = dist_result["path_objs"]
        for s, b in zip(seq, bat):
            assert abs(b - s) / abs(s) < 1e-3
        assert dist_result["path_saved"] >= 0

    def test_certified_gaps_reported_and_small(self, dist_result):
        gaps = dist_result["path_gaps"]
        scales = dist_result["path_gap_scale"]
        assert len(gaps) == 6
        for g, s in zip(gaps, scales):
            assert np.isfinite(g)
            # converged points: certified gap is noise-level vs objective
            assert abs(g) < 1e-4 * s, (g, s)

    def test_history_driver_matches_single_device(self, dist_result):
        h_d, h_s = dist_result["history"]
        assert len(h_d) == 50
        np.testing.assert_allclose(h_d, h_s, rtol=1e-6)

    def test_standalone_gap_matches_single_device(self, dist_result):
        g_d, g_s, scale = dist_result["gap"]
        assert abs(g_d - g_s) <= 1e-6 * scale


class TestShardIO:
    def test_row_plan_reads_only_overlapping_shards(self, dist_result):
        # rows [48, 96) at 17 rows/shard -> shards 2..5 only
        assert dist_result["rowplan"] == [
            "shard_00002.npz", "shard_00003.npz",
            "shard_00004.npz", "shard_00005.npz",
        ]

    def test_manifest_loader_matches_in_memory_placement(self, dist_result):
        o_ld, o_mem = dist_result["loader_obj"]
        assert o_ld == o_mem


class TestStepRulesOnMesh:
    @pytest.mark.parametrize("rule", ["away", "pairwise"])
    def test_rule_matches_single_device(self, dist_result, rule):
        r = dist_result["rules"][rule]
        obj_d, obj_s = r["objs"]
        assert abs(obj_d - obj_s) / max(abs(obj_s), 1e-9) < 1e-4, r
        assert r["l1"] <= 120.0 * (1 + 1e-4)
        # same sparsity structure: the rules agree on which atoms live
        assert r["active"][0] == r["active"][1], r


class TestTelemetryOnMesh:
    def test_telemetry_off_trajectory_unchanged(self, dist_result):
        """Ring on vs off on the (1, 4) mesh: alpha bit-identical."""
        assert dist_result["tel"]["off_bitident"]

    def test_ring_step_facts_match_single_device(self, dist_result):
        bitident = dist_result["tel"]["ring_bitident"]
        assert all(bitident.values()), bitident

    def test_ring_objective_curve_ulp_close(self, dist_result):
        d, s = dist_result["tel"]["obj_curve"]
        assert len(d) == len(s) == 60
        for a, b in zip(d, s):
            assert _ulp_close(a, b), (a, b)

    def test_history_driver_is_the_ring(self, dist_result):
        assert dist_result["tel"]["hist_equals_ring"]


class TestForcedFuseSteps:
    def test_warns_once_and_surfaces_effective_value(self, dist_result):
        assert dist_result["fuse"]["n_warn"] == 1
        assert dist_result["fuse"]["effective"] == 1
