"""Distributed FW (shard_map) == single-device FW, run on 8 host devices
in a subprocess so the main test process keeps 1 device (DESIGN.md rule)."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import FWConfig, fw_solve
    from repro.core.distributed import make_distributed_solver
    from repro.data import make_regression, standardize

    ds = standardize(make_regression(m=96, p=512, n_informative=10, noise=0.5, seed=3))
    Xt = jnp.asarray(ds.X.T.copy()); y = jnp.asarray(ds.y)
    delta = 120.0
    cfg = FWConfig(delta=delta, sampling="uniform", kappa=64, max_iters=600,
                   tol=0.0, patience=10**9)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    solver = make_distributed_solver(mesh, cfg, n_iters=600)
    with mesh:
        alpha_d, obj_d, dots_d = solver(Xt, y, jax.random.PRNGKey(0))
    obj_direct = 0.5 * float(jnp.sum((jnp.asarray(alpha_d) @ Xt - y) ** 2))

    ref = fw_solve(Xt, y, cfg, jax.random.PRNGKey(0))
    out = {
        "obj_dist": float(obj_d),
        "obj_direct": obj_direct,
        "obj_ref": float(ref.objective),
        "l1": float(jnp.sum(jnp.abs(alpha_d))),
        "delta": delta,
        "active": int(jnp.sum(jnp.asarray(alpha_d) != 0)),
    }
    print("RESULT" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def dist_result():
    import os
    limit = max(600, int(os.environ.get("REPRO_SUBPROC_TIMEOUT", "0")))
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=limit,
        env={"PYTHONPATH": str(Path(__file__).resolve().parents[1] / "src"),
             "PATH": "/usr/bin:/bin",
               # stripped env: pin the backend or PJRT plugin discovery can hang
               "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


class TestDistributedFW:
    def test_objective_recursion_consistent(self, dist_result):
        r = dist_result
        assert abs(r["obj_dist"] - r["obj_direct"]) / max(r["obj_direct"], 1) < 1e-3

    def test_matches_single_device_quality(self, dist_result):
        r = dist_result
        # same kappa/iteration budget => same optimization quality band
        assert r["obj_dist"] <= r["obj_ref"] * 1.05 + 1e-3

    def test_feasible(self, dist_result):
        r = dist_result
        assert r["l1"] <= r["delta"] * (1 + 1e-4)

    def test_sparse_iterates(self, dist_result):
        assert dist_result["active"] <= 601
