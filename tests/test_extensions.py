"""ElasticNet-FW and logistic-FW extensions (paper §6)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FISTAConfig, FWConfig, baselines
from repro.core.fw_elasticnet import en_solve
from repro.core.fw_logistic import logistic_solve


class TestElasticNetFW:
    def _augmented_reference(self, Xt, y, delta, l2, key):
        """ElasticNet == Lasso on the augmented design [X; sqrt(l2) I]."""
        p, m = Xt.shape
        aug = jnp.concatenate(
            [Xt, jnp.sqrt(l2) * jnp.eye(p, dtype=Xt.dtype)], axis=1
        )  # (p, m+p) feature-major
        y_aug = jnp.concatenate([y, jnp.zeros((p,), y.dtype)])
        cfg = FISTAConfig(delta=delta, constrained=True, max_iters=8000, tol=1e-10)
        return baselines.fista_solve(aug, y_aug, cfg, key)

    def test_matches_augmented_fista(self, small_problem, rng_key):
        Xt, y, _ = small_problem
        delta, l2 = 50.0, 0.5
        ref = self._augmented_reference(Xt, y, delta, l2, rng_key)
        res = en_solve(
            Xt, y,
            FWConfig(delta=delta, sampling="full", max_iters=30000, tol=1e-7),
            l2, rng_key,
        )
        ref_obj = float(ref.objective)  # 1/2||aug a - y_aug||^2 == EN objective
        assert float(res.objective) <= ref_obj * 1.02 + 1e-3

    def test_l2_shrinks_solution(self, small_problem, rng_key):
        Xt, y, _ = small_problem
        cfg = FWConfig(delta=100.0, sampling="full", max_iters=20000, tol=1e-6)
        weak = en_solve(Xt, y, cfg, 1e-6, rng_key)
        strong = en_solve(Xt, y, cfg, 50.0, rng_key)
        assert float(jnp.max(jnp.abs(strong.alpha))) < float(jnp.max(jnp.abs(weak.alpha)))

    def test_reduces_to_lasso_at_zero_l2(self, small_problem, rng_key):
        from repro.core import fw_solve

        Xt, y, _ = small_problem
        cfg = FWConfig(delta=80.0, sampling="full", max_iters=20000, tol=1e-7)
        en = en_solve(Xt, y, cfg, 0.0, rng_key)
        fw = fw_solve(Xt, y, cfg, rng_key)
        np.testing.assert_allclose(
            float(en.objective), float(fw.objective), rtol=1e-4
        )

    def test_stochastic_feasible(self, small_problem, rng_key):
        Xt, y, _ = small_problem
        cfg = FWConfig(delta=30.0, sampling="uniform", kappa=60, max_iters=5000, tol=1e-5)
        res = en_solve(Xt, y, cfg, 1.0, rng_key)
        assert float(jnp.sum(jnp.abs(res.alpha))) <= 30.0 * (1 + 1e-4)
        assert bool(jnp.isfinite(res.objective))


class TestLogisticFW:
    def _data(self, m=120, p=80, seed=0):
        rng = np.random.default_rng(seed)
        X = rng.standard_normal((m, p)).astype(np.float32)
        w = np.zeros(p, np.float32)
        w[:5] = rng.standard_normal(5) * 2
        y = np.sign(X @ w + 0.1 * rng.standard_normal(m)).astype(np.float32)
        y[y == 0] = 1.0
        return jnp.asarray(X.T), jnp.asarray(y)

    def test_loss_decreases_below_chance(self, rng_key):
        Xt, y = self._data()
        m = y.shape[0]
        cfg = FWConfig(delta=20.0, sampling="full", max_iters=3000, tol=1e-7)
        res = logistic_solve(Xt, y, cfg, rng_key)
        chance = m * np.log(2.0)
        assert float(res.objective) < 0.5 * chance

    def test_matches_projected_gradient_reference(self, rng_key):
        """FW reaches the same constrained optimum as slow projected GD."""
        from repro.core.projections import project_l1_ball

        Xt, y = self._data(seed=1)
        delta = 5.0
        cfg = FWConfig(delta=delta, sampling="full", max_iters=5000, tol=1e-9)
        res = logistic_solve(Xt, y, cfg, rng_key)

        def loss(a):
            return jnp.sum(jnp.logaddexp(0.0, -y * (a @ Xt)))

        a = jnp.zeros(Xt.shape[0])
        g = jax.grad(loss)
        for _ in range(3000):
            a = project_l1_ball(a - 0.01 * g(a), delta)
        ref = float(loss(a))
        assert float(res.objective) <= ref * 1.02 + 1e-2

    def test_classification_accuracy(self, rng_key):
        Xt, y = self._data(seed=2)
        cfg = FWConfig(delta=20.0, sampling="uniform", kappa=40, max_iters=4000, tol=1e-7)
        res = logistic_solve(Xt, y, cfg, rng_key)
        pred = jnp.sign(res.alpha @ Xt)
        acc = float(jnp.mean(pred == y))
        assert acc > 0.9

    def test_sparsity_and_feasibility(self, rng_key):
        Xt, y = self._data(seed=3)
        cfg = FWConfig(delta=3.0, sampling="uniform", kappa=40, max_iters=200,
                       tol=0.0, patience=10**9)
        res = logistic_solve(Xt, y, cfg, rng_key)
        assert float(jnp.sum(jnp.abs(res.alpha))) <= 3.0 * (1 + 1e-4)
        assert int(res.active) <= 201
