"""Data pipeline / monitor / optimizer unit tests."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.lm_pipeline import PrefetchingLoader, batch_at_step
from repro.obs.monitor import StepMonitor
from repro.training import optimizers as opt


class TestDataPipeline:
    def test_step_addressable_determinism(self):
        cfg = get_config("deepseek_7b").reduced()
        a = batch_at_step(cfg, 7, batch=4, seq_len=32, seed=3)
        b = batch_at_step(cfg, 7, batch=4, seq_len=32, seed=3)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_different_steps_differ(self):
        cfg = get_config("deepseek_7b").reduced()
        a = batch_at_step(cfg, 1, batch=4, seq_len=32, seed=3)
        b = batch_at_step(cfg, 2, batch=4, seq_len=32, seed=3)
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_tokens_in_vocab(self):
        cfg = get_config("gemma2_9b").reduced()
        b = batch_at_step(cfg, 0, batch=8, seq_len=64, seed=0)
        assert b["tokens"].min() >= 0
        assert b["tokens"].max() < cfg.vocab_size

    def test_multimodal_keys(self):
        vlm = get_config("internvl2_76b").reduced()
        b = batch_at_step(vlm, 0, batch=2, seq_len=16, seed=0)
        assert "patches" in b
        audio = get_config("seamless_m4t_medium").reduced()
        b = batch_at_step(audio, 0, batch=2, seq_len=16, seed=0)
        assert "frames" in b

    def test_prefetching_loader_order(self):
        cfg = get_config("deepseek_7b").reduced()
        loader = PrefetchingLoader(cfg, batch=2, seq_len=16, seed=1, start_step=5)
        try:
            s0, b0 = next(loader)
            s1, b1 = next(loader)
            assert (s0, s1) == (5, 6)
            ref = batch_at_step(cfg, 5, batch=2, seq_len=16, seed=1)
            np.testing.assert_array_equal(b0["tokens"], ref["tokens"])
        finally:
            loader.close()


class TestMonitor:
    def test_straggler_detection(self):
        mon = StepMonitor(ewma_alpha=0.5, straggler_factor=2.0)
        for _ in range(5):
            mon.begin()
            time.sleep(0.01)
            assert not mon.end()
        mon.begin()
        time.sleep(0.08)
        assert mon.end()  # 8x the EWMA -> flagged
        assert mon.stragglers == [6]

    def test_heartbeat(self, tmp_path):
        hb = tmp_path / "hb.json"
        mon = StepMonitor(heartbeat_path=hb)
        mon.begin()
        mon.end()
        import json

        data = json.loads(hb.read_text())
        assert data["step"] == 1


class TestOptimizers:
    def test_adamw_moves_toward_gradient(self):
        params = {"w": jnp.ones((4,), jnp.bfloat16)}
        state = opt.adamw_init(params)
        grads = {"w": jnp.ones((4,), jnp.bfloat16)}
        new, state = opt.adamw_update(grads, state, params, lr=0.1, weight_decay=0.0)
        assert float(new["w"][0]) < 1.0

    def test_adamw_fp32_master_used_for_bf16(self):
        params = {"w": jnp.ones((4,), jnp.bfloat16)}
        state = opt.adamw_init(params)
        assert state.inner["w"].master.shape == (4,)
        params32 = {"w": jnp.ones((4,), jnp.float32)}
        state32 = opt.adamw_init(params32)
        assert state32.inner["w"].master.shape == (1,)  # placeholder

    def test_adafactor_factored_shapes(self):
        params = {"w": jnp.ones((8, 16)), "b": jnp.ones((16,))}
        state = opt.adafactor_init(params)
        assert state.inner["w"].v_row.shape == (8,)
        assert state.inner["w"].v_col.shape == (16,)
        assert state.inner["b"].v_full.shape == (16,)

    def test_adafactor_descends_quadratic(self):
        A = jnp.asarray(np.random.default_rng(0).standard_normal((16, 8)), jnp.float32)
        x_true = jnp.ones((8, 4))

        params = {"w": jnp.zeros((8, 4))}
        state = opt.adafactor_init(params)
        losses = []
        for _ in range(200):
            def loss_fn(p):
                return jnp.mean((A @ p["w"] - A @ x_true) ** 2)
            loss, g = jax.value_and_grad(loss_fn)(params)
            params, state = opt.adafactor_update(g, state, params, lr=0.05)
            losses.append(float(loss))
        assert losses[-1] < 0.05 * losses[0]

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((4,), 10.0)}
        clipped, norm = opt.clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(20.0)
        assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)

    def test_cosine_schedule_shape(self):
        lr0 = float(opt.cosine_schedule(jnp.asarray(1), base_lr=1.0, warmup=10, total=100))
        lr_mid = float(opt.cosine_schedule(jnp.asarray(50), base_lr=1.0, warmup=10, total=100))
        lr_end = float(opt.cosine_schedule(jnp.asarray(100), base_lr=1.0, warmup=10, total=100))
        assert lr0 == pytest.approx(0.1)
        assert 0.1 < lr_end < lr_mid < 1.0
