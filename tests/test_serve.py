"""Serving correctness: prefill + decode == full forward, per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M

# one representative per attention/cache mechanism
SERVE_ARCHS = ["deepseek_7b", "gemma2_9b", "mamba2_130m", "hymba_1_5b",
               "seamless_m4t_medium", "internvl2_76b", "kimi_k2_1t_a32b"]


def _setup(arch, B=2, S=24):
    cfg = get_config(arch).reduced(ssm_chunk=8)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.n_prefix_embeds:
        batch["patches"] = jax.random.normal(key, (B, cfg.n_prefix_embeds, cfg.d_model))
    if cfg.n_enc_layers:
        batch["frames"] = jax.random.normal(key, (B, 16, cfg.d_model))
    return cfg, params, batch


@pytest.mark.parametrize("arch", SERVE_ARCHS)
class TestPrefillDecodeEquivalence:
    def test_incremental_equals_full(self, arch):
        """Prefill S tokens, decode 3 more: logits at each decoded position
        must match the full-sequence forward pass."""
        cfg, params, batch = _setup(arch)
        B, S = batch["tokens"].shape
        extra = 3
        key = jax.random.PRNGKey(7)
        next_toks = jax.random.randint(key, (B, extra), 0, cfg.vocab_size)
        full_tokens = jnp.concatenate([batch["tokens"], next_toks], axis=1)

        # full forward over S+extra tokens
        full_inputs = dict(batch)
        full_inputs["tokens"] = full_tokens
        full_logits = M.forward(params, full_inputs, cfg)  # (B, S+extra, V)

        # prefill S, then decode the extra tokens one by one
        logits_p, cache = M.prefill(params, batch, cfg, max_seq=S + extra + 8)
        np.testing.assert_allclose(
            np.asarray(logits_p[:, 0]), np.asarray(full_logits[:, S - 1]),
            rtol=2e-2, atol=2e-3,
        )
        for t in range(extra):
            logits_d, cache = M.decode_step(params, next_toks[:, t : t + 1], cache, cfg)
            np.testing.assert_allclose(
                np.asarray(logits_d[:, 0]),
                np.asarray(full_logits[:, S + t]),
                rtol=2e-2, atol=2e-3,
                err_msg=f"{arch} decode step {t}",
            )

    def test_cache_len_advances(self, arch):
        cfg, params, batch = _setup(arch)
        B, S = batch["tokens"].shape
        _, cache = M.prefill(params, batch, cfg, max_seq=S + 8)
        start = int(cache["len"][0])
        _, cache = M.decode_step(params, batch["tokens"][:, :1], cache, cfg)
        assert int(cache["len"][0]) == start + 1


class TestServeStep:
    def test_greedy_serve_step(self):
        from repro.training import make_serve_step

        cfg, params, batch = _setup("deepseek_7b")
        B, S = batch["tokens"].shape
        _, cache = M.prefill(params, batch, cfg, max_seq=S + 8)
        serve = jax.jit(make_serve_step(cfg))
        toks, logits, cache = serve(params, batch["tokens"][:, -1:], cache)
        assert toks.shape == (B, 1)
        assert int(toks.min()) >= 0 and int(toks.max()) < cfg.vocab_size
