"""Resilient solver runtime (repro.resilience) — ISSUE 10 acceptance.

Recovery matrix: (shard corruption, NaN co-state, NaN beta, mid-path
kill + resume) x (xla, sparse, distributed), each healed with the
ladder trip visible in the metrics registry, and the healed/resumed
results bit-identical (kill+resume, beta_nan retry, no-fault parity)
or ulp/tolerance-level (co rebuild) to the clean run.

The distributed column runs on 4 virtual CPU devices in a subprocess so
the main test process keeps 1 device (same harness as
tests/test_distributed.py).
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import engine, fw_lasso, path as path_lib
from repro.core.solver_config import FWConfig
from repro.obs import metrics as obs_metrics
from repro.resilience import checkpoint as path_ckpt
from repro.resilience import faults, guards, validate
from repro.sparse import io as sio
from repro.sparse.matrix import SparseBlockMatrix

LASSO = fw_lasso.LASSO


def _problem(seed=0, p=60, m=40, density=0.4):
    rng = np.random.default_rng(seed)
    Xd = rng.normal(size=(m, p)) * (rng.random(size=(m, p)) < density)
    y = rng.normal(size=m).astype(np.float32)
    return Xd.astype(np.float32), y


def _coo(Xd, y):
    r, c = np.nonzero(Xd)
    return sio.COOData(r.astype(np.int64), c.astype(np.int64),
                       Xd[r, c].astype(np.float32), y, Xd.shape)


def _bitwise(a, b) -> bool:
    return np.array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# Fault-injection harness
# --------------------------------------------------------------------------


class TestFaultHarness:
    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            faults.FaultSpec(kind="cosmic_ray")

    def test_no_plan_hooks_are_noops(self):
        data = b"abc123"
        assert faults.maybe_corrupt_bytes("s", data) is data
        faults.check_kill("path_point", 0)  # no raise
        faults.maybe_delay("dist_dispatch")
        assert faults.active_plan() is None

    def test_one_shot_spec_fires_once(self):
        plan = faults.FaultPlan(
            [faults.FaultSpec(kind="kill", at=-1)], seed=1
        )
        with faults.inject(plan):
            with pytest.raises(faults.InjectedKill):
                faults.check_kill("path_point", 0)
            faults.check_kill("path_point", 1)  # spec spent: no raise
        assert len(plan.fired("kill")) == 1

    def test_occurrence_index_targets_one_call(self):
        plan = faults.FaultPlan(
            [faults.FaultSpec(kind="kill", at=2)], seed=1
        )
        with faults.inject(plan):
            faults.check_kill("path_point", 0)
            faults.check_kill("path_point", 1)
            with pytest.raises(faults.InjectedKill):
                faults.check_kill("path_point", 2)

    def test_site_filter(self):
        plan = faults.FaultPlan(
            [faults.FaultSpec(kind="kill", site="path_chunk", at=-1)], seed=1
        )
        with faults.inject(plan):
            faults.check_kill("path_point", 0)  # other site: no raise
            with pytest.raises(faults.InjectedKill):
                faults.check_kill("path_chunk", 0)

    def test_byte_corruption_deterministic_per_seed(self):
        data = bytes(range(256)) * 8
        out = []
        for _ in range(2):
            plan = faults.FaultPlan(
                [faults.FaultSpec(kind="shard_corrupt")], seed=42
            )
            with faults.inject(plan):
                out.append(faults.maybe_corrupt_bytes("f.npz", data))
        assert out[0] == out[1] and out[0] != data

    def test_injections_counted_in_registry(self):
        reg = obs_metrics.MetricsRegistry()
        plan = faults.FaultPlan(
            [faults.FaultSpec(kind="delay", seconds=0.0)], seed=1
        )
        with obs_metrics.use_registry(reg), faults.inject(plan):
            faults.maybe_delay("dist_dispatch")
        assert reg.get("fw_faults_injected").value(
            kind="delay", site="dist_dispatch") == 1.0


# --------------------------------------------------------------------------
# Input validation (satellite b)
# --------------------------------------------------------------------------


class TestInputValidation:
    def test_dense_nan_raises_before_solve(self):
        Xd, y = _problem(1)
        Xt = jnp.asarray(Xd.T).at[2, 3].set(jnp.nan)
        cfg = FWConfig(max_iters=50, delta=1.0)
        with pytest.raises(ValueError, match="non-finite values"):
            engine.solve(LASSO, Xt, jnp.asarray(y), cfg, jax.random.PRNGKey(0))

    def test_y_inf_raises_with_counts(self):
        Xd, y = _problem(1)
        yb = jnp.asarray(y).at[0].set(jnp.inf)
        cfg = FWConfig(max_iters=50, delta=1.0)
        with pytest.raises(ValueError, match=r"y: 0 NaN / 1 Inf"):
            engine.solve(LASSO, jnp.asarray(Xd.T), yb, cfg,
                         jax.random.PRNGKey(0))

    def test_sparse_matrix_values_checked(self):
        import dataclasses

        Xd, y = _problem(2)
        mat = SparseBlockMatrix.from_dense(Xd.T.copy(), block_size=16)
        bad = dataclasses.replace(
            mat, values=mat.values.at[0, 0, 0].set(jnp.nan)
        )
        cfg = FWConfig(max_iters=50, delta=1.0, backend="sparse")
        with pytest.raises(ValueError, match="X.values"):
            engine.solve(LASSO, bad, jnp.asarray(y), cfg,
                         jax.random.PRNGKey(0))

    def test_clean_inputs_pass_and_solve(self):
        Xd, y = _problem(3)
        cfg = FWConfig(max_iters=50, delta=1.0)
        res = engine.solve(LASSO, jnp.asarray(Xd.T), jnp.asarray(y), cfg,
                           jax.random.PRNGKey(0))
        assert np.isfinite(float(res.objective))

    def test_env_skip_disables_check(self, monkeypatch):
        monkeypatch.setenv(validate.ENV_SKIP, "1")
        yb = jnp.asarray(np.array([np.nan, 1.0], np.float32))
        validate.validate_inputs(None, yb)  # no raise


# --------------------------------------------------------------------------
# Shard checksums + retry healing (tentpole layer 4 / satellite f)
# --------------------------------------------------------------------------


class TestShardChecksums:
    @pytest.fixture()
    def shard_dir(self, tmp_path):
        Xd, y = _problem(4, p=50, m=64)
        sio.write_shards(str(tmp_path), _coo(Xd, y), rows_per_shard=16)
        return str(tmp_path)

    def test_manifest_carries_checksums(self, shard_dir):
        mf = sio.read_manifest(shard_dir)
        assert set(mf["checksums"]) == set(mf["shards"])
        assert sio.verify_shards(shard_dir) == []

    def test_verify_flags_damaged_file(self, shard_dir):
        mf = sio.read_manifest(shard_dir)
        victim = os.path.join(shard_dir, mf["shards"][1])
        blob = bytearray(Path(victim).read_bytes())
        blob[len(blob) // 2] ^= 0xFF
        Path(victim).write_bytes(bytes(blob))
        assert sio.verify_shards(shard_dir) == [mf["shards"][1]]

    def test_transient_corruption_heals_with_retry(self, shard_dir):
        mf = sio.read_manifest(shard_dir)
        clean = sio.load_shards(shard_dir)
        reg = obs_metrics.MetricsRegistry()
        plan = faults.FaultPlan(
            [faults.FaultSpec(kind="shard_corrupt", site=mf["shards"][0])],
            seed=5,
        )
        with obs_metrics.use_registry(reg), faults.inject(plan):
            healed = sio.load_shards(shard_dir)
        assert plan.fired("shard_corrupt")
        assert _bitwise(clean.vals, healed.vals)
        assert _bitwise(clean.y, healed.y)
        assert reg.get("fw_shard_checksum_failures").value(
            shard=mf["shards"][0]) >= 1.0
        assert reg.get("fw_shard_retries").value(
            shard=mf["shards"][0]) >= 1.0

    def test_persistent_corruption_raises(self, shard_dir):
        mf = sio.read_manifest(shard_dir)
        plan = faults.FaultPlan(
            [faults.FaultSpec(kind="shard_corrupt", site=mf["shards"][0],
                              at=-1, count=10**6)],
            seed=5,
        )
        with faults.inject(plan):
            with pytest.raises(sio.ShardIntegrityError, match="sha256"):
                sio.load_shards(shard_dir)

    def test_legacy_manifest_without_checksums_loads(self, shard_dir):
        mf = sio.read_manifest(shard_dir)
        del mf["checksums"]
        Path(shard_dir, sio.MANIFEST_NAME).write_text(json.dumps(mf))
        assert sio.verify_shards(shard_dir) == []
        data = sio.load_shards(shard_dir)
        assert data.shape == (64, 50)


# --------------------------------------------------------------------------
# Watchdog + degradation ladder (single-device)
# --------------------------------------------------------------------------


def _cfg(**kw):
    base = dict(max_iters=200, delta=2.0, tol=0.0, patience=10**9)
    base.update(kw)
    return FWConfig(**base)


class TestGuardedSolve:
    @pytest.fixture()
    def prob(self):
        Xd, y = _problem(6)
        return jnp.asarray(Xd.T), jnp.asarray(y), jax.random.PRNGKey(0)

    @pytest.mark.parametrize("fuse", [1, 8])
    def test_no_fault_bitwise_parity_xla(self, prob, fuse):
        Xt, y, key = prob
        cfg = _cfg(backend="xla", fuse_steps=fuse)
        ref = engine.solve(LASSO, Xt, y, cfg, key)
        res = guards.solve_resilient(LASSO, Xt, y, cfg, key)
        assert _bitwise(ref.alpha, res.alpha)
        assert int(ref.iterations) == int(res.iterations)
        assert int(ref.n_dots) == int(res.n_dots)
        # the trajectory is bit-identical; the objective scalar is
        # recomputed in a separately compiled epilogue whose reduction
        # may fuse differently inside engine.solve's one program —
        # last-ulp float32 roundoff only
        np.testing.assert_allclose(
            float(ref.objective), float(res.objective), rtol=1e-6)

    def test_no_fault_bitwise_parity_sparse(self, prob):
        Xd, y = _problem(6)
        mat = SparseBlockMatrix.from_dense(Xd.T.copy(), block_size=16)
        cfg = _cfg(backend="sparse", fuse_steps=8)
        key = jax.random.PRNGKey(0)
        yj = jnp.asarray(y)
        ref = engine.solve(LASSO, mat, yj, cfg, key)
        res = guards.solve_resilient(LASSO, mat, yj, cfg, key)
        assert _bitwise(ref.alpha, res.alpha)
        assert int(ref.n_dots) == int(res.n_dots)

    @pytest.mark.parametrize("backend", ["xla", "sparse"])
    def test_co_nan_heals_via_rebuild(self, backend):
        Xd, y = _problem(6)
        Xt = (SparseBlockMatrix.from_dense(Xd.T.copy(), block_size=16)
              if backend == "sparse" else jnp.asarray(Xd.T))
        cfg = _cfg(backend=backend, fuse_steps=8)
        key = jax.random.PRNGKey(0)
        yj = jnp.asarray(y)
        ref = engine.solve(LASSO, Xt, yj, cfg, key)
        reg = obs_metrics.MetricsRegistry()
        plan = faults.FaultPlan(
            [faults.FaultSpec(kind="co_nan", at=1)], seed=7
        )
        with obs_metrics.use_registry(reg), faults.inject(plan):
            res = guards.solve_resilient(LASSO, Xt, yj, cfg, key)
        assert plan.fired("co_nan")
        # the exact-matvec rebuild restores the co-state to ulp level:
        # the healed run lands on the clean objective to fp tolerance
        assert float(res.objective) == pytest.approx(
            float(ref.objective), rel=1e-4)
        assert reg.get("fw_guard_trips").value(
            backend=backend, reason="nonfinite_co") >= 1.0
        assert reg.get("fw_guard_recoveries").value(
            backend=backend, rung="rebuild_co") >= 1.0

    def test_beta_nan_heals_bitwise_via_chunk_retry(self, prob):
        Xt, y, key = prob
        cfg = _cfg(backend="xla", fuse_steps=8)
        ref = engine.solve(LASSO, Xt, y, cfg, key)
        reg = obs_metrics.MetricsRegistry()
        plan = faults.FaultPlan(
            [faults.FaultSpec(kind="beta_nan", at=1)], seed=7
        )
        with obs_metrics.use_registry(reg), faults.inject(plan):
            res = guards.solve_resilient(LASSO, Xt, y, cfg, key)
        # the corrupt chunk is discarded and replayed through the per-step
        # reference executor — bit-identical to the clean trajectory
        # (objective: separately compiled epilogue, ulp-level only)
        assert _bitwise(ref.alpha, res.alpha)
        np.testing.assert_allclose(
            float(ref.objective), float(res.objective), rtol=1e-6)
        assert reg.get("fw_guard_recoveries").value(
            backend="xla", rung="retry_chunk") >= 1.0

    def test_unrecoverable_fault_raises(self, prob):
        Xt, y, key = prob
        cfg = _cfg(backend="xla", fuse_steps=8)
        # poison EVERY chunk: retry sees a fresh fault each time and the
        # trip budget exhausts
        plan = faults.FaultPlan(
            [faults.FaultSpec(kind="beta_nan", at=-1, count=10**6)], seed=7
        )
        with faults.inject(plan):
            with pytest.raises(guards.UnrecoverableFaultError):
                guards.solve_resilient(
                    LASSO, Xt, y, cfg, key,
                    guard=guards.GuardSpec(max_trips=3),
                )

    def test_fallback_config_ladder(self):
        assert guards.fallback_config(_cfg(backend="xla")) is None
        fb = guards.fallback_config(_cfg(backend="pallas"))
        assert fb is not None and fb.backend == "xla"

    def test_distributed_backend_rejected(self, prob):
        Xt, y, key = prob
        with pytest.raises(ValueError, match="solve_resilient_sharded"):
            guards.solve_resilient(
                LASSO, Xt, y, _cfg(backend="distributed"), key
            )


# --------------------------------------------------------------------------
# Path checkpoint / resume (tentpole layer 3)
# --------------------------------------------------------------------------


def _points_bitwise(a: path_lib.PathResult, b: path_lib.PathResult) -> bool:
    if len(a.points) != len(b.points):
        return False
    for pa, pb in zip(a.points, b.points):
        if not (
            _bitwise(pa.alpha_nnz_idx, pb.alpha_nnz_idx)
            and _bitwise(pa.alpha_nnz_val, pb.alpha_nnz_val)
            and pa.n_dots == pb.n_dots
            and pa.iterations == pb.iterations
            and pa.objective == pb.objective
            and (pa.gap == pb.gap or (np.isnan(pa.gap) and np.isnan(pb.gap)))
        ):
            return False
    return True


class TestPathCheckpointResume:
    @pytest.fixture()
    def prob(self):
        Xd, y = _problem(8, p=70, m=48)
        return jnp.asarray(Xd.T), jnp.asarray(y), np.geomspace(0.5, 3.0, 7)

    def test_pack_unpack_roundtrip_preserves_dtype(self):
        pts = [
            path_lib.PathPoint(
                reg=0.5, objective=1.25, l1=0.5, active=2, iterations=10,
                n_dots=400, seconds=0.1,
                alpha_nnz_idx=np.array([3, 17], np.int64),
                alpha_nnz_val=np.array([0.25, -0.25], np.float32),
                gap=1e-3,
            ),
            path_lib.PathPoint(
                reg=1.0, objective=1.0, l1=1.0, active=1, iterations=20,
                n_dots=800, seconds=0.2,
                alpha_nnz_idx=np.array([5], np.int64),
                alpha_nnz_val=np.array([1.0], np.float32),
                gap=float("nan"),
            ),
        ]
        out = path_ckpt.unpack_points(path_ckpt.pack_points(pts))
        assert len(out) == 2
        assert out[0].alpha_nnz_val.dtype == np.float32
        assert _bitwise(out[0].alpha_nnz_val, pts[0].alpha_nnz_val)
        assert _bitwise(out[1].alpha_nnz_idx, pts[1].alpha_nnz_idx)
        assert out[1].n_dots == 800 and np.isnan(out[1].gap)

    @pytest.mark.parametrize("kill_at", [1, 4])
    def test_fw_path_kill_resume_bit_identical(self, prob, tmp_path, kill_at):
        Xt, y, deltas = prob
        cfg = _cfg(max_iters=100, fuse_steps=4, backend="xla")
        clean = path_lib.fw_path(Xt, y, deltas, cfg, seed=5)
        ck = str(tmp_path)
        plan = faults.FaultPlan(
            [faults.FaultSpec(kind="kill", at=kill_at)], seed=0
        )
        with faults.inject(plan):
            with pytest.raises(faults.InjectedKill):
                path_lib.fw_path(Xt, y, deltas, cfg, seed=5,
                                 checkpoint_dir=ck)
        resumed = path_lib.fw_path(Xt, y, deltas, cfg, seed=5,
                                   checkpoint_dir=ck, resume_from=ck)
        assert _points_bitwise(clean, resumed)
        assert clean.total_dots == resumed.total_dots
        assert clean.total_iters == resumed.total_iters

    def test_fw_path_kill_resume_sparse(self, tmp_path):
        Xd, y = _problem(8, p=70, m=48)
        mat = SparseBlockMatrix.from_dense(Xd.T.copy(), block_size=16)
        yj = jnp.asarray(y)
        deltas = np.geomspace(0.5, 3.0, 6)
        cfg = _cfg(max_iters=100, fuse_steps=4, backend="sparse")
        clean = path_lib.fw_path(mat, yj, deltas, cfg, seed=5)
        ck = str(tmp_path)
        plan = faults.FaultPlan([faults.FaultSpec(kind="kill", at=3)], seed=0)
        with faults.inject(plan):
            with pytest.raises(faults.InjectedKill):
                path_lib.fw_path(mat, yj, deltas, cfg, seed=5,
                                 checkpoint_dir=ck)
        resumed = path_lib.fw_path(mat, yj, deltas, cfg, seed=5,
                                   checkpoint_dir=ck, resume_from=ck)
        assert _points_bitwise(clean, resumed)

    def test_fw_path_batched_kill_resume_bit_identical(self, prob, tmp_path):
        Xt, y, deltas = prob
        cfg = _cfg(max_iters=100, fuse_steps=4, backend="xla")
        clean = path_lib.fw_path_batched(Xt, y, deltas, cfg, seed=5,
                                         lane_width=3)
        ck = str(tmp_path)
        plan = faults.FaultPlan([faults.FaultSpec(kind="kill", at=2)], seed=0)
        with faults.inject(plan):
            with pytest.raises(faults.InjectedKill):
                path_lib.fw_path_batched(Xt, y, deltas, cfg, seed=5,
                                         lane_width=3, checkpoint_dir=ck)
        resumed = path_lib.fw_path_batched(Xt, y, deltas, cfg, seed=5,
                                           lane_width=3, checkpoint_dir=ck,
                                           resume_from=ck)
        assert _points_bitwise(clean, resumed)
        assert clean.saved_iters == resumed.saved_iters

    def test_resume_without_checkpoint_starts_fresh(self, prob, tmp_path):
        Xt, y, deltas = prob
        cfg = _cfg(max_iters=60, fuse_steps=4, backend="xla")
        clean = path_lib.fw_path(Xt, y, deltas, cfg, seed=5)
        res = path_lib.fw_path(Xt, y, deltas, cfg, seed=5,
                               resume_from=str(tmp_path / "empty"))
        assert _points_bitwise(clean, res)

    def test_checkpoints_pruned(self, prob, tmp_path):
        Xt, y, deltas = prob
        cfg = _cfg(max_iters=60, fuse_steps=4, backend="xla")
        path_lib.fw_path(Xt, y, deltas, cfg, seed=5,
                         checkpoint_dir=str(tmp_path))
        kept = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
        assert 0 < len(kept) <= 3


# --------------------------------------------------------------------------
# Distributed recovery column (subprocess, 4 virtual devices)
# --------------------------------------------------------------------------


DIST_SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core import FWConfig, LASSO
    from repro import distributed as dist
    from repro.distributed import driver as ddriver
    from repro.obs import metrics as obs_metrics
    from repro.resilience import faults, guards
    from repro.sparse import io as sio

    out = {}
    rng = np.random.default_rng(2)
    p, m = 64, 32
    Xd = (rng.normal(size=(m, p)) * (rng.random(size=(m, p)) < 0.5)
          ).astype(np.float32)
    y = rng.normal(size=m).astype(np.float32)
    r_, c_ = np.nonzero(Xd)
    coo = sio.COOData(r_.astype(np.int64), c_.astype(np.int64),
                      Xd[r_, c_].astype(np.float32), y, (m, p))
    shard_dir = tempfile.mkdtemp()
    sio.write_shards(shard_dir, coo, rows_per_shard=8)
    mf = sio.read_manifest(shard_dir)

    mesh = dist.fw_mesh(n_data=2, n_model=2)
    cfg = FWConfig(max_iters=120, delta=2.0, tol=0.0, patience=10**9)
    key = jax.random.PRNGKey(0)

    # --- shard-corruption heal THROUGH the mesh loader ---
    reg = obs_metrics.MetricsRegistry()
    plan = faults.FaultPlan(
        [faults.FaultSpec(kind="shard_corrupt", site=mf["shards"][0])],
        seed=3)
    with obs_metrics.use_registry(reg), faults.inject(plan):
        op = dist.load_sharded_matrix(shard_dir, mesh, block_size=16)
    clean_op = dist.load_sharded_matrix(shard_dir, mesh, block_size=16)
    out["shard_heal_fired"] = len(plan.fired("shard_corrupt"))
    out["shard_heal_bitident"] = bool(
        (np.asarray(op.values) == np.asarray(clean_op.values)).all())
    out["shard_retry_count"] = reg.get("fw_shard_retries").value(
        shard=mf["shards"][0])

    # --- no-fault resilient parity on the mesh ---
    ref = ddriver.solve(LASSO, op, cfg, key)
    res = guards.solve_resilient_sharded(LASSO, op, cfg, key)
    out["parity_bitident"] = bool(
        (np.asarray(ref.alpha) == np.asarray(res.alpha)).all())
    out["parity_counts"] = [int(ref.iterations), int(res.iterations),
                            int(ref.n_dots), int(res.n_dots)]

    # --- co_nan heal on the mesh (rrebuild program) ---
    reg2 = obs_metrics.MetricsRegistry()
    plan = faults.FaultPlan([faults.FaultSpec(kind="co_nan", at=1)], seed=7)
    with obs_metrics.use_registry(reg2), faults.inject(plan):
        resf = guards.solve_resilient_sharded(LASSO, op, cfg, key)
    out["conan_fired"] = len(plan.fired("co_nan"))
    out["conan_obj"] = [float(resf.objective), float(ref.objective)]
    out["conan_recoveries"] = reg2.get("fw_guard_recoveries").value(
        backend="distributed", rung="rebuild_co")

    # --- kill + resume of the sharded sequential path ---
    deltas = np.geomspace(0.5, 3.0, 5)
    pcfg = FWConfig(max_iters=80, delta=1.0, tol=0.0, patience=10**9)
    clean = ddriver.fw_path(op, deltas, pcfg, seed=5)
    ck = tempfile.mkdtemp()
    plan = faults.FaultPlan([faults.FaultSpec(kind="kill", at=2)], seed=0)
    killed = False
    try:
        with faults.inject(plan):
            ddriver.fw_path(op, deltas, pcfg, seed=5, checkpoint_dir=ck)
    except faults.InjectedKill:
        killed = True
    resumed = ddriver.fw_path(op, deltas, pcfg, seed=5,
                              checkpoint_dir=ck, resume_from=ck)
    ok = killed and len(resumed.points) == len(clean.points)
    for a, b in zip(clean.points, resumed.points):
        ok = ok and bool(np.array_equal(a.alpha_nnz_val, b.alpha_nnz_val)
                         and np.array_equal(a.alpha_nnz_idx, b.alpha_nnz_idx)
                         and a.n_dots == b.n_dots
                         and a.iterations == b.iterations)
    out["path_resume_bitident"] = ok
    out["path_totals_match"] = bool(
        clean.total_dots == resumed.total_dots
        and clean.total_iters == resumed.total_iters)

    # --- injected straggler delay + timeout re-dispatch ---
    reg3 = obs_metrics.MetricsRegistry()
    plan = faults.FaultPlan(
        [faults.FaultSpec(kind="delay", seconds=30.0)], seed=0)
    with obs_metrics.use_registry(reg3), faults.inject(plan):
        with ddriver.dispatch_policy(timeout_s=5.0, retries=1):
            r2 = ddriver.solve(LASSO, op, cfg, key)
    out["redispatch_bitident"] = bool(
        (np.asarray(ref.alpha) == np.asarray(r2.alpha)).all())
    out["redispatch_count"] = reg3.get("fw_dist_redispatches").value(
        entry="solve")
    out["delay_fired"] = len(plan.fired("delay"))

    print("RESULT" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def dist_result():
    limit = max(900, int(os.environ.get("REPRO_SUBPROC_TIMEOUT", "0")))
    proc = subprocess.run(
        [sys.executable, "-c", DIST_SCRIPT],
        capture_output=True, text=True, timeout=limit,
        env={"PYTHONPATH": str(Path(__file__).resolve().parents[1] / "src"),
             "PATH": "/usr/bin:/bin",
             # stripped env: pin the backend or PJRT plugin discovery can hang
             "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


class TestDistributedRecovery:
    def test_shard_corruption_heals_through_mesh_loader(self, dist_result):
        assert dist_result["shard_heal_fired"] >= 1
        assert dist_result["shard_heal_bitident"]
        assert dist_result["shard_retry_count"] >= 1.0

    def test_no_fault_resilient_parity(self, dist_result):
        assert dist_result["parity_bitident"]
        it_r, it_g, nd_r, nd_g = dist_result["parity_counts"]
        assert (it_r, nd_r) == (it_g, nd_g)

    def test_co_nan_heals_on_mesh(self, dist_result):
        assert dist_result["conan_fired"] >= 1
        healed, clean = dist_result["conan_obj"]
        assert healed == pytest.approx(clean, rel=1e-4)
        assert dist_result["conan_recoveries"] >= 1.0

    def test_path_kill_resume_bit_identical(self, dist_result):
        assert dist_result["path_resume_bitident"]
        assert dist_result["path_totals_match"]

    def test_delay_triggers_redispatch(self, dist_result):
        assert dist_result["delay_fired"] >= 1
        assert dist_result["redispatch_count"] >= 1.0
        assert dist_result["redispatch_bitident"]
