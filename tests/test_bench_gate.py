"""Perf-regression observatory coverage (ISSUE 9 tentpole).

The ISSUE's two gate acceptance criteria live here as unit tests:
  * a synthetically injected 2x hot-loop slowdown MUST fail the gate;
  * three consecutive re-runs drawn from realistic CI noise MUST all
    pass (no false positives).
Plus the plumbing around them: history append/load round-trips, the
current run's own history line is excluded from its baseline (at most
one line, exact identity), and fresh metrics warm up instead of failing.
"""
import json
import sys
import os

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import history as bench_history  # noqa: E402
from scripts import bench_gate  # noqa: E402


def _payload(us_per_iter, sha="abc1234", extra_record=None):
    """Minimal kernels_bench-shaped BenchJSON payload."""
    records = [
        {"name": "hotloop/fused_k8_sparse", "us_per_iter": us_per_iter,
         "seconds": us_per_iter * 400 / 1e6, "iters_per_sec": 1e6 / us_per_iter},
    ]
    if extra_record is not None:
        records.append(extra_record)
    return {
        "provenance": {"git_sha": sha, "timestamp_utc": "2026-08-09T00:00:00Z",
                       "scale": "ci"},
        "records": records,
    }


class TestHistoryFile:
    def test_append_load_roundtrip(self, tmp_path):
        hp = str(tmp_path / "BENCH_history.jsonl")
        for i, v in enumerate([100.0, 101.0, 99.0]):
            bench_history.append_run(_payload(v, sha=f"sha{i}"),
                                     "BENCH_kernels.json", path=hp)
        runs = bench_history.load_history(hp)
        assert len(runs) == 3
        assert [r["provenance"]["git_sha"] for r in runs] == [
            "sha0", "sha1", "sha2"
        ]  # oldest first
        series = bench_history.metric_series(runs)
        key = "BENCH_kernels.json:hotloop/fused_k8_sparse:us_per_iter"
        assert series[key] == [100.0, 101.0, 99.0]

    def test_truncated_line_skipped(self, tmp_path):
        hp = tmp_path / "BENCH_history.jsonl"
        bench_history.append_run(_payload(100.0), "BENCH_kernels.json",
                                 path=str(hp))
        with open(hp, "at") as fh:
            fh.write('{"source": "BENCH_kern')  # killed mid-append
        assert len(bench_history.load_history(str(hp))) == 1

    def test_truncated_line_warns_on_stderr(self, tmp_path, capsys):
        hp = tmp_path / "BENCH_history.jsonl"
        bench_history.append_run(_payload(100.0), "BENCH_kernels.json",
                                 path=str(hp))
        with open(hp, "at") as fh:
            fh.write('{"source": "BENCH_kern')
        bench_history.load_history(str(hp))
        err = capsys.readouterr().err
        assert "skipping corrupt/truncated history line" in err
        assert str(hp) in err

    def test_non_object_json_line_skipped_with_warning(self, tmp_path, capsys):
        hp = tmp_path / "BENCH_history.jsonl"
        bench_history.append_run(_payload(100.0), "BENCH_kernels.json",
                                 path=str(hp))
        with open(hp, "at") as fh:
            fh.write("null\n42\n[1, 2]\n")  # valid JSON, not history runs
        runs = bench_history.load_history(str(hp))
        assert len(runs) == 1
        assert "skipping non-object history line" in capsys.readouterr().err

    def test_run_metrics_tolerates_malformed_records(self):
        assert bench_history.run_metrics({"records": "oops"}) == {}
        assert bench_history.run_metrics(
            {"source": "s", "records": [17, {"name": "ok", "us_per_iter": 2}]}
        ) == {"s:ok:us_per_iter": 2.0}

    def test_source_filter(self, tmp_path):
        hp = str(tmp_path / "h.jsonl")
        bench_history.append_run(_payload(1.0), "BENCH_a.json", path=hp)
        bench_history.append_run(_payload(2.0), "BENCH_b.json", path=hp)
        assert len(bench_history.load_history(hp, source="BENCH_a.json")) == 1

    def test_non_numeric_fields_skipped(self):
        run = {"source": "s.json",
               "records": [{"name": "r", "us_per_iter": "fast"},
                           {"name": "q", "us_per_iter": True},
                           {"name": "ok", "us_per_iter": 3}]}
        assert bench_history.run_metrics(run) == {"s.json:ok:us_per_iter": 3.0}


class TestCheckMetric:
    def test_injected_2x_slowdown_caught(self):
        """ISSUE 9 acceptance: a synthetic 2x hot-loop regression fails
        the gate at the default thresholds."""
        history = [100.0, 103.0, 98.0, 101.0, 99.0]
        r = bench_gate.check_metric("hotloop", 2 * min(history), history)
        assert r.regressed and not r.warming_up
        assert "REGRESS" in r.describe()

    def test_no_false_positive_on_noisy_reruns(self):
        """ISSUE 9 acceptance: consecutive re-runs drawn from realistic
        CI jitter (~±15% around the same code) all pass."""
        history = [100.0, 112.0, 97.0, 104.0, 118.0, 101.0]
        for rerun in (99.0, 115.0, 108.0):  # 3 consecutive re-runs
            r = bench_gate.check_metric("hotloop", rerun, history)
            assert not r.regressed, r.describe()
            history = history + [rerun]  # each run lands in history

    def test_warming_up_below_min_runs(self):
        r = bench_gate.check_metric("m", 500.0, [100.0, 101.0], min_runs=3)
        assert r.warming_up and not r.regressed
        assert "WARMUP" in r.describe()

    def test_min_of_window_baseline(self):
        """Baseline is the min of the trailing window — old slow runs
        outside the window don't inflate it, old FAST runs inside do
        anchor it."""
        history = [50.0] + [100.0] * 10  # the 50 has scrolled out (window=10)
        r = bench_gate.check_metric("m", 140.0, history, window=10,
                                    rel_tol=0.5, mad_mult=5.0)
        assert r.baseline == 100.0
        assert not r.regressed  # 140 < 100 + 50
        r = bench_gate.check_metric("m", 160.0, history, window=10,
                                    rel_tol=0.5, mad_mult=5.0)
        assert r.regressed

    def test_mad_widens_band_for_noisy_series(self):
        quiet = [100.0, 100.0, 100.0, 100.0]
        noisy = [100.0, 130.0, 100.0, 130.0]
        r_q = bench_gate.check_metric("m", 152.0, quiet)
        r_n = bench_gate.check_metric("m", 152.0, noisy)
        assert r_q.regressed  # quiet trajectory: tight band, 1.52x fails
        assert not r_n.regressed  # MAD term absorbs the same ratio

    def test_check_run_covers_new_metrics(self):
        results = bench_gate.check_run(
            {"a": 100.0, "brand_new": 1.0}, {"a": [90.0, 91.0, 92.0]}
        )
        by_name = {r.metric: r for r in results}
        assert not by_name["a"].regressed
        assert by_name["brand_new"].warming_up


class TestGateFiles:
    def _write_current(self, tmp_path, payload, name="BENCH_kernels.json"):
        p = tmp_path / name
        p.write_text(json.dumps(payload))
        return str(p)

    def test_end_to_end_regression_exit_code(self, tmp_path):
        hp = str(tmp_path / "h.jsonl")
        for i in range(4):
            bench_history.append_run(_payload(100.0 + i, sha=f"s{i}"),
                                     "BENCH_kernels.json", path=hp)
        slow = _payload(206.0, sha="s-slow")
        bench_history.append_run(slow, "BENCH_kernels.json", path=hp)
        cur = self._write_current(tmp_path, slow)
        assert bench_gate.main(["--current", cur, "--history", hp]) == 1
        fast = _payload(103.0, sha="s-ok")
        bench_history.append_run(fast, "BENCH_kernels.json", path=hp)
        cur = self._write_current(tmp_path, fast)
        assert bench_gate.main(["--current", cur, "--history", hp]) == 0

    def test_own_line_excluded_from_baseline(self, tmp_path):
        """BenchJSON.write appends before the gate runs — the gate must
        not let a regressed run vouch for itself, and with ONLY its own
        line the metric warms up rather than passing on a fake baseline."""
        hp = str(tmp_path / "h.jsonl")
        mine = _payload(206.0, sha="me")
        bench_history.append_run(mine, "BENCH_kernels.json", path=hp)
        results = bench_gate.gate_files(
            [self._write_current(tmp_path, mine)], hp
        )
        assert all(r.warming_up for r in results)  # own line dropped

    def test_drop_own_line_exact_and_single(self):
        mine = {"source": "BENCH_kernels.json", **_payload(100.0, sha="x")}
        sibling = {"source": "BENCH_kernels.json", **_payload(101.0, sha="x")}
        twin = json.loads(json.dumps(mine))
        runs = [sibling, twin, json.loads(json.dumps(mine))]
        kept = bench_gate._drop_own_line(runs, _payload(100.0, sha="x"),
                                         "BENCH_kernels.json")
        # exactly one identical line dropped (newest), sibling + twin stay
        assert len(kept) == 2
        assert kept[0] is sibling
        other = bench_gate._drop_own_line(runs, _payload(999.0, sha="x"),
                                          "BENCH_kernels.json")
        assert len(other) == 3  # no identity match -> nothing dropped

    def test_missing_artifact_is_usage_error(self, tmp_path):
        assert bench_gate.main(
            ["--current", str(tmp_path / "nope.json")]
        ) == 2

    def test_seconds_only_records_not_gated(self, tmp_path):
        """table5_fw rows carry seconds but no us_per_iter — they ride
        the history for trends but must not produce gate results."""
        hp = str(tmp_path / "h.jsonl")
        payload = {
            "provenance": {"git_sha": "t", "scale": "ci"},
            "records": [{"name": "table5/path", "seconds": 12.0,
                         "iters": 4000, "dots": 1e6}],
        }
        cur = self._write_current(tmp_path, payload, "BENCH_table5.json")
        assert bench_gate.gate_files([cur], hp) == []
        assert bench_gate.main(["--current", cur, "--history", hp]) == 0

    def test_median_and_mad(self):
        assert bench_gate.median([3.0, 1.0, 2.0]) == 2.0
        assert bench_gate.median([1.0, 2.0, 3.0, 4.0]) == 2.5
        assert bench_gate.mad([1.0, 1.0, 1.0]) == 0.0
        assert bench_gate.mad([1.0, 2.0, 9.0]) == 1.0
