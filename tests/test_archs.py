"""Per-architecture smoke tests (brief deliverable (f)).

Each assigned arch instantiates a REDUCED same-family config and runs one
forward + one train step on CPU, asserting output shapes and no NaNs.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.roofline import active_params, total_params
from repro.configs import ARCH_IDS, all_configs, get_config
from repro.data.lm_pipeline import batch_at_step
from repro.models import model as M
from repro.training import init_train_state, make_train_step

B, S = 2, 64


def _batch(cfg, seed=0):
    return jax.tree.map(
        jnp.asarray, batch_at_step(cfg, seed, batch=B, seq_len=S, seed=seed)
    )


@pytest.fixture(params=ARCH_IDS)
def arch(request):
    return request.param


class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_config(arch).reduced()
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        batch = _batch(cfg)
        inputs = dict(batch)
        inputs["tokens"] = batch["tokens"][:, :-1]
        logits = M.forward(params, inputs, cfg)
        assert logits.shape == (B, S, cfg.vocab_size)
        assert logits.dtype == jnp.float32
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_one_train_step(self, arch):
        cfg = get_config(arch).reduced()
        params, opt_state = init_train_state(jax.random.PRNGKey(1), cfg)
        step = jax.jit(make_train_step(cfg, microbatches=2))
        new_params, new_opt, metrics = step(params, opt_state, _batch(cfg))
        assert bool(jnp.isfinite(metrics["loss"]))
        assert int(new_opt.step) == 1
        # params actually changed
        diffs = [
            float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
        ]
        assert max(diffs) > 0.0

    def test_loss_decreases_three_steps(self, arch):
        cfg = get_config(arch).reduced()
        params, opt_state = init_train_state(jax.random.PRNGKey(2), cfg)
        step = jax.jit(make_train_step(cfg, base_lr=5e-3, warmup=1))
        batch = _batch(cfg)  # same batch: loss must drop
        losses = []
        for _ in range(3):
            params, opt_state, metrics = step(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0], losses


class TestConfigIntegrity:
    """The full (unreduced) configs match the assigned parameter sheet."""

    EXPECTED = {
        "mamba2_130m": dict(n_layers=24, d_model=768, d_ff=0, vocab_size=50280, ssm_state=128),
        "internlm2_20b": dict(n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384, vocab_size=92544),
        "deepseek_7b": dict(n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32, d_ff=11008, vocab_size=102400),
        "gemma2_9b": dict(n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, d_ff=14336, vocab_size=256000),
        "qwen2_72b": dict(n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568, vocab_size=152064, qkv_bias=True),
        "internvl2_76b": dict(n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672, vocab_size=128256),
        "arctic_480b": dict(n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864, vocab_size=32000, n_experts=128, experts_per_token=2),
        "kimi_k2_1t_a32b": dict(n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, moe_d_ff=2048, vocab_size=163840, n_experts=384, experts_per_token=8),
        "hymba_1_5b": dict(n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504, vocab_size=32001, ssm_state=16),
        "seamless_m4t_medium": dict(n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096, vocab_size=256206, n_enc_layers=12),
    }

    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_assigned_hyperparams(self, arch):
        cfg = get_config(arch)
        for k, v in self.EXPECTED[arch].items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)

    def test_param_counts_in_band(self):
        """Analytic totals land near the advertised model sizes."""
        expect = {
            "mamba2_130m": (0.10e9, 0.16e9),
            "internlm2_20b": (17e9, 23e9),
            "deepseek_7b": (6e9, 8e9),
            "gemma2_9b": (8e9, 11e9),
            "qwen2_72b": (65e9, 80e9),
            "internvl2_76b": (63e9, 80e9),  # backbone only (ViT is a stub)
            "arctic_480b": (430e9, 520e9),
            "kimi_k2_1t_a32b": (0.95e12, 1.1e12),
            "hymba_1_5b": (1.2e9, 1.9e9),
            "seamless_m4t_medium": (0.8e9, 1.4e9),
        }
        for arch, (lo, hi) in expect.items():
            n = total_params(get_config(arch))
            assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"

    def test_active_lt_total_for_moe(self):
        for arch in ("arctic_480b", "kimi_k2_1t_a32b"):
            cfg = get_config(arch)
            assert active_params(cfg) < 0.25 * total_params(cfg)

    def test_kimi_active_about_32b(self):
        n = active_params(get_config("kimi_k2_1t_a32b"))
        assert 25e9 <= n <= 40e9, n / 1e9
