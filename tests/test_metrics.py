"""Metrics plane coverage (ISSUE 9 tentpole).

Four layers:
  * primitives: Counter/Gauge/Histogram semantics — monotonicity, label
    validation, fixed-bucket invariants, interpolated quantiles;
  * registry: get-or-create families, kind/label/bucket conflict
    detection, install plumbing (OFF by default, scoped installs);
  * export: OpenMetrics text round-trips through the exposition checker
    (including rejection cases), JSON snapshots, the live ``/metrics``
    HTTP endpoint;
  * solver bridges: registry-off dispatch is bitwise identical to
    registry-on (the metrics-off contract), instrumented solves populate
    the solve/latency/gap families, ring flushes and tracer spans fold in
    exactly once, and a batched SPARSE path scraped mid-registry carries
    non-NaN p50/p99 solve latency plus lane-freeze counters — the
    acceptance scrape.
"""
import json
import math

import numpy as np
import pytest

import jax

from repro.core import FWConfig, engine
from repro.core import path as path_lib
from repro.core.fw_lasso import LASSO
from repro.obs import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    MetricsServer,
    TelemetrySpec,
    Tracer,
    get_registry,
    install_registry,
    install_ring_sink,
    render_openmetrics,
    ring_batch_to_registry,
    scrape,
    snapshot_json,
    tracer_to_registry,
    unregister_sink,
    use_registry,
    validate_openmetrics,
)
from repro.obs.metrics import GAP_BUCKETS
from repro.sparse.matrix import SparseBlockMatrix

DELTA = 150.0


def _base_cfg(**kw):
    base = dict(delta=DELTA, kappa=40, sampling="uniform", max_iters=120,
                tol=0.0, patience=10**9)
    base.update(kw)
    return FWConfig(**base)


def _sparse_mat(Xt, threshold=0.7, block_size=64):
    Xs = np.asarray(Xt).copy()
    Xs[np.abs(Xs) < threshold] = 0.0
    return SparseBlockMatrix.from_dense(Xs, block_size=block_size)


class TestPrimitives:
    def test_counter_monotone(self):
        c = Counter("c", "help")
        c.inc()
        c.inc(2.5)
        assert c.value() == 3.5
        with pytest.raises(ValueError):
            c.inc(-1.0)

    def test_counter_labels(self):
        c = Counter("c", "help", ("backend",))
        c.inc(1, backend="xla")
        c.inc(2, backend="sparse")
        assert c.value(backend="xla") == 1
        assert c.value(backend="sparse") == 2
        assert [dict(k)["backend"] for k, _ in c.series()] == ["sparse", "xla"]
        with pytest.raises(ValueError):
            c.inc(1)  # missing label
        with pytest.raises(ValueError):
            c.inc(1, backend="xla", extra="nope")

    def test_gauge_set_add(self):
        g = Gauge("g", "help")
        g.set(4.0)
        g.set(2.0)  # last write wins
        assert g.value() == 2.0
        g.add(0.5)
        assert g.value() == 2.5

    def test_histogram_buckets_and_quantiles(self):
        h = Histogram("h", "help", buckets=(1.0, 2.0, 4.0))
        for v in (0.5, 1.5, 1.5, 3.0, 100.0):
            h.observe(v)
        snap = h.snapshot()
        assert snap["count"] == 5
        assert snap["sum"] == pytest.approx(106.5)
        # cumulative per le bound, +Inf implicit
        assert snap["buckets"] == [(1.0, 1), (2.0, 3), (4.0, 4),
                                   (math.inf, 5)]
        # p50: target 2.5 falls in (1, 2], interpolated 3/4 through it
        assert h.quantile(0.5) == pytest.approx(1.75)
        # quantile landing in +Inf clamps to the top finite bound
        assert h.quantile(0.99) == 4.0

    def test_histogram_empty_is_nan(self):
        h = Histogram("h", "help", buckets=(1.0,))
        assert math.isnan(h.quantile(0.5))
        assert h.snapshot() is None

    def test_histogram_bucket_validation(self):
        with pytest.raises(ValueError):
            Histogram("h", "help", buckets=())
        with pytest.raises(ValueError):
            Histogram("h", "help", buckets=(2.0, 1.0))
        with pytest.raises(ValueError):
            Histogram("h", "help", buckets=(1.0, 1.0))
        # a trailing +Inf is legal but implicit
        h = Histogram("h", "help", buckets=(1.0, math.inf))
        assert h.buckets == (1.0,)

    def test_exact_bucket_boundary_counts_le(self):
        h = Histogram("h", "help", buckets=(1.0, 2.0))
        h.observe(1.0)
        assert h.snapshot()["buckets"][0] == (1.0, 1)  # le is inclusive


class TestRegistry:
    def test_get_or_create_returns_same_family(self):
        reg = MetricsRegistry()
        a = reg.counter("fw_x", "first", ("l",))
        b = reg.counter("fw_x", "redeclared-help-ignored", ("l",))
        assert a is b

    def test_kind_and_label_conflicts_raise(self):
        reg = MetricsRegistry()
        reg.counter("fw_x", "", ("l",))
        with pytest.raises(ValueError):
            reg.gauge("fw_x", "")
        with pytest.raises(ValueError):
            reg.counter("fw_x", "", ("other",))

    def test_bucket_conflict_raises(self):
        reg = MetricsRegistry()
        reg.histogram("fw_h", "", buckets=(1.0, 2.0))
        with pytest.raises(ValueError):
            reg.histogram("fw_h", "", buckets=(1.0, 3.0))
        # re-declaring identical buckets is fine
        assert reg.histogram("fw_h", "", buckets=(1.0, 2.0)) is reg.get("fw_h")

    def test_collect_sorted(self):
        reg = MetricsRegistry()
        reg.counter("fw_b", "")
        reg.counter("fw_a", "")
        assert [m.name for m in reg.collect()] == ["fw_a", "fw_b"]

    def test_off_by_default_and_scoped_install(self):
        assert get_registry() is None
        reg = MetricsRegistry()
        with use_registry(reg):
            assert get_registry() is reg
            inner = MetricsRegistry()
            with use_registry(inner):
                assert get_registry() is inner
            assert get_registry() is reg
        assert get_registry() is None

    def test_process_install_uninstall(self):
        reg = MetricsRegistry()
        prev = install_registry(reg)
        try:
            assert prev is None
            assert get_registry() is reg
        finally:
            install_registry(None)
        assert get_registry() is None


class TestExport:
    def _populated(self):
        reg = MetricsRegistry()
        reg.counter("fw_things", "things seen", ("kind",)).inc(3, kind="a")
        reg.gauge("fw_depth", "queue depth").set(2.0)
        h = reg.histogram("fw_lat_seconds", "latency", buckets=(0.1, 1.0))
        h.observe(0.05)
        h.observe(0.5)
        h.observe(5.0)
        return reg

    def test_render_validates_clean(self):
        text = render_openmetrics(self._populated())
        assert validate_openmetrics(text) == []
        assert text.endswith("# EOF\n")
        assert 'fw_things_total{kind="a"} 3' in text
        assert 'fw_lat_seconds_bucket{le="+Inf"} 3' in text
        assert 'quantile="0.5"' in text

    def test_validator_rejects_bad_exposition(self):
        assert validate_openmetrics("")  # no EOF
        assert validate_openmetrics("junk line !!\n# EOF\n")
        # counter sample without the _total suffix
        bad = ("# TYPE fw_c counter\nfw_c 1\n# EOF\n")
        assert any("_total" in p for p in validate_openmetrics(bad))
        # histogram with non-cumulative buckets
        bad = (
            "# TYPE fw_h histogram\n"
            'fw_h_bucket{le="1.0"} 5\n'
            'fw_h_bucket{le="+Inf"} 3\n'
            "fw_h_sum 1\nfw_h_count 3\n# EOF\n"
        )
        assert validate_openmetrics(bad)

    def test_snapshot_json(self):
        snap = snapshot_json(self._populated())
        assert set(snap) == {"fw_things", "fw_depth", "fw_lat_seconds"}
        lat = snap["fw_lat_seconds"]
        assert lat["kind"] == "histogram"
        (series,) = lat["series"]
        assert series["count"] == 3
        assert series["bucket_counts"] == [1, 2, 3]  # cumulative, le-ordered
        assert set(series["quantiles"]) == {"0.5", "0.95", "0.99"}
        assert json.dumps(snap)  # JSON-serializable end to end

    def test_http_endpoint_scrape(self):
        reg = self._populated()
        with MetricsServer(registry=reg, port=0) as srv:
            text = scrape(srv.url)
            assert validate_openmetrics(text) == []
            assert "fw_things_total" in text
            js = json.loads(scrape(srv.url + ".json"))
        assert "fw_lat_seconds" in js

    def test_server_follows_live_registry(self):
        """Constructed with registry=None the server serves whatever is
        installed at scrape time — the long-running-process shape."""
        with MetricsServer(port=0) as srv:
            reg = MetricsRegistry()
            reg.counter("fw_live", "").inc(7)
            with use_registry(reg):
                assert "fw_live_total 7" in scrape(srv.url)
            # registry popped -> empty (but valid) exposition
            assert validate_openmetrics(scrape(srv.url)) == []


class TestSolverBridges:
    def test_registry_on_is_bitwise_identical(self, small_problem, rng_key):
        """The metrics shim must never touch the trajectory: alpha,
        iterations, and dot counts agree bit for bit with the registry
        installed vs not (same contract as telemetry-off)."""
        Xt, y, _ = small_problem
        cfg = _base_cfg()
        off = engine.solve(LASSO, Xt, y, cfg, rng_key)
        with use_registry(MetricsRegistry()):
            on = engine.solve(LASSO, Xt, y, cfg, rng_key)
        np.testing.assert_array_equal(np.asarray(off.alpha), np.asarray(on.alpha))
        assert int(off.iterations) == int(on.iterations)
        assert int(off.n_dots) == int(on.n_dots)

    def test_solve_families_populated(self, small_problem, rng_key):
        Xt, y, _ = small_problem
        reg = MetricsRegistry()
        with use_registry(reg):
            res = engine.solve(LASSO, Xt, y, _base_cfg(report_gap=True),
                               rng_key)
        lbl = dict(entry="solve", backend="xla", step_rule="classic")
        assert reg.get("fw_solves").value(**lbl) == 1
        assert reg.get("fw_iterations").value(**lbl) == int(res.iterations)
        assert reg.get("fw_n_dots").value(**lbl) == int(res.n_dots)
        lat = reg.get("fw_solve_latency_seconds")
        assert lat.snapshot(**lbl)["count"] == 1
        assert not math.isnan(lat.quantile(0.5, **lbl))
        gap = reg.get("fw_certified_gap")
        assert gap.buckets == GAP_BUCKETS
        assert gap.snapshot(**lbl)["count"] == 1

    def test_no_registry_records_nothing(self, small_problem, rng_key):
        """OFF state: entry points pass straight through (nothing to
        observe, no registry to fill)."""
        Xt, y, _ = small_problem
        engine.solve(LASSO, Xt, y, _base_cfg(), rng_key)
        assert get_registry() is None

    def test_jit_attribute_forwarding(self):
        """The shim forwards jit bookkeeping — the path driver's cache
        accounting reads through it."""
        assert isinstance(engine.solve_batched._cache_size(), int)
        assert engine.solve.__name__ == "solve"

    def test_ring_batch_bridge(self):
        reg = MetricsRegistry()
        batch = {
            "k": np.arange(6),
            "event": np.asarray([0, 0, 1, 2, 0, 5]),
            "gap": np.asarray([1.0, 0.5, np.nan, -1.0, 10.0, 2.0]),
        }
        ring_batch_to_registry(batch, reg, backend="xla")
        assert reg.get("fw_ring_iterations_total").value(backend="xla") == 6
        ev = reg.get("fw_step_events_total")
        assert ev.value(backend="xla", event="fw") == 3
        assert ev.value(backend="xla", event="away") == 1
        assert ev.value(backend="xla", event="partan") == 1
        # only finite positive gaps land in the histogram
        assert reg.get("fw_sampled_gap").snapshot(backend="xla")["count"] == 4

    def test_ring_sink_streams_into_registry(self, small_problem, rng_key):
        """TelemetrySpec(stream_to=install_ring_sink()) folds every ring
        flush into the live registry: iteration totals match the solve."""
        Xt, y, _ = small_problem
        reg = MetricsRegistry()
        name = install_ring_sink()
        try:
            with use_registry(reg):
                res = engine.solve(
                    LASSO, Xt, y,
                    _base_cfg(max_iters=50,
                              telemetry=TelemetrySpec(capacity=16,
                                                      stream_to=name)),
                    rng_key,
                )
                res.alpha.block_until_ready()
                jax.effects_barrier()
        finally:
            unregister_sink(name)
        assert reg.get("fw_ring_iterations_total").value() == 50
        assert reg.get("fw_step_events_total").value(event="fw") == 50

    def test_tracer_bridge_is_incremental(self):
        tr = Tracer("t")
        reg = MetricsRegistry()
        with tr.span("load"):
            pass
        tr.counter("widgets", 2)
        tracer_to_registry(tr, reg)
        tracer_to_registry(tr, reg)  # idempotent on the same events
        assert reg.get("fw_span_seconds").snapshot(span="load")["count"] == 1
        assert reg.get("fw_trace_counter").value(counter="widgets") == 2
        with tr.span("load"):
            pass
        tr.counter("widgets", 3)
        tracer_to_registry(tr, reg)  # only the delta lands
        assert reg.get("fw_span_seconds").snapshot(span="load")["count"] == 2
        assert reg.get("fw_trace_counter").value(counter="widgets") == 5


class TestAcceptanceScrape:
    def test_batched_sparse_path_scrape(self, small_problem):
        """ISSUE 9 acceptance: scrape a live ``/metrics`` during a
        batched sparse path solve; the exposition must validate and carry
        non-empty p50/p99 solve-latency quantiles + lane-freeze
        counters."""
        Xt, y, _ = small_problem
        mat = _sparse_mat(Xt)
        cfg = FWConfig(delta=1.0, kappa=40, sampling="uniform",
                       max_iters=300, tol=1e-4, patience=20,
                       backend="sparse")
        reg = MetricsRegistry()
        with use_registry(reg), MetricsServer(registry=reg, port=0) as srv:
            path_lib.fw_path_batched(
                mat, y, [5.0, 20.0, 60.0, DELTA], cfg, lane_width=4
            )
            text = scrape(srv.url)
        assert validate_openmetrics(text) == []
        assert "fw_lane_freezes_total" in text
        assert reg.get("fw_lanes_admitted").value(backend="sparse") == 4
        assert reg.get("fw_lane_freezes").value(backend="sparse") >= 1
        lat = reg.get("fw_solve_latency_seconds")
        lbl = dict(entry="solve_batched", backend="sparse",
                   step_rule="classic")
        for q in (0.5, 0.99):
            assert not math.isnan(lat.quantile(q, **lbl))
        # the per-point histogram saw all four path points
        pts = reg.get("fw_path_point_seconds")
        snap = pts.snapshot(driver="batched", backend="sparse")
        assert snap["count"] == 4

    def test_sequential_path_points_observed(self, small_problem):
        Xt, y, _ = small_problem
        cfg = _base_cfg(max_iters=60)
        reg = MetricsRegistry()
        with use_registry(reg):
            path_lib.fw_path(Xt, y, [20.0, DELTA], cfg)
        snap = reg.get("fw_path_point_seconds").snapshot(
            driver="sequential", backend="xla"
        )
        assert snap["count"] == 2
        # the path tracer's spans were folded in on completion
        assert reg.get("fw_span_seconds") is not None
