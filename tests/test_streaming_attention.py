"""Streaming (flash-style) attention == dense masked attention."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M

ARCHS = ["qwen2_72b", "hymba_1_5b", "gemma2_9b", "deepseek_7b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_streaming_equals_dense(arch):
    base = get_config(arch)
    cfg = base.reduced(
        ssm_chunk=16, sliding_window=32 if base.sliding_window else 0
    )
    cfg_s = dataclasses.replace(cfg, streaming_attn_threshold=64, streaming_chunk=32)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 128), 0, cfg.vocab_size)
    }
    dense = M.forward(params, batch, cfg)
    stream = M.forward(params, batch, cfg_s)
    np.testing.assert_allclose(
        np.asarray(stream), np.asarray(dense), rtol=2e-2, atol=2e-4
    )


def test_streaming_band_matches_full_scan_for_local():
    """For window == chunk, the static 2-chunk band must equal dense local
    attention exactly (including the qi=0 double-count cancellation)."""
    base = get_config("hymba_1_5b")
    cfg = base.reduced(ssm_chunk=16, sliding_window=32)
    cfg_s = dataclasses.replace(cfg, streaming_attn_threshold=64, streaming_chunk=32)
    params = M.init_params(jax.random.PRNGKey(2), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(3), (1, 96), 0, cfg.vocab_size)
    dense = M.forward(params, {"tokens": tokens}, cfg)
    stream = M.forward(params, {"tokens": tokens}, cfg_s)
    np.testing.assert_allclose(
        np.asarray(stream), np.asarray(dense), rtol=2e-2, atol=2e-4
    )


def test_streaming_gradients_finite():
    cfg = dataclasses.replace(
        get_config("deepseek_7b").reduced(),
        streaming_attn_threshold=64, streaming_chunk=32,
    )
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 65), 0, cfg.vocab_size)}
    (loss, _), grads = jax.value_and_grad(M.loss_fn, has_aux=True)(params, batch, cfg)
    assert bool(jnp.isfinite(loss))
    for g in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(g)))
