"""Pipeline parallelism == sequential execution (forward and gradients).
Runs on 4 forced host devices in a subprocess (device-count isolation)."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.parallel import make_pipeline_fn

    n_stages, n_micro, mb, d = 4, 8, 2, 16
    mesh = jax.make_mesh((n_stages,), ("stage",))
    key = jax.random.PRNGKey(0)
    Ws = jax.random.normal(key, (n_stages, d, d)) / jnp.sqrt(d)
    params = {"w": Ws}
    xs = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"])

    pipe = make_pipeline_fn(mesh, stage_fn, n_stages)
    with mesh:
        ys = jax.jit(pipe)(params, xs)

    # sequential reference
    ref = xs
    for s in range(n_stages):
        ref = jnp.tanh(ref @ Ws[s])
    fwd_err = float(jnp.max(jnp.abs(ys - ref)))

    # gradient equivalence
    tgt = jax.random.normal(jax.random.PRNGKey(2), ys.shape)
    def loss_pipe(params):
        with mesh:
            return jnp.mean((pipe(params, xs) - tgt) ** 2)
    def loss_seq(params):
        h = xs
        for s in range(n_stages):
            h = jnp.tanh(h @ params["w"][s])
        return jnp.mean((h - tgt) ** 2)
    g_pipe = jax.grad(loss_pipe)(params)["w"]
    g_seq = jax.grad(loss_seq)(params)["w"]
    grad_err = float(jnp.max(jnp.abs(g_pipe - g_seq)))
    print("RESULT" + json.dumps({"fwd_err": fwd_err, "grad_err": grad_err}))
""")


@pytest.fixture(scope="module")
def pipe_result():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": str(Path(__file__).resolve().parents[1] / "src"),
             "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def test_pipeline_forward_matches_sequential(pipe_result):
    assert pipe_result["fwd_err"] < 1e-5


def test_pipeline_gradients_match_sequential(pipe_result):
    assert pipe_result["grad_err"] < 1e-5
