"""Unit tests for model internals: MoE routing, SSD math, RoPE, masks,
sharding rules — the invariants the integration tests rely on."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st  # skips @given tests when hypothesis is missing

from repro.configs import get_config
from repro.models import attention as A
from repro.models import moe as moe_lib
from repro.models import sharding as sh
from repro.models import ssm as ssm_lib
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope


def _moe_cfg(**kw):
    base = dict(
        name="t", family="moe", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab_size=128, n_experts=8, experts_per_token=2, moe_d_ff=48,
        dtype="float32", min_capacity=4,
    )
    base.update(kw)
    return ModelConfig(**base)


class TestMoE:
    def test_generous_capacity_equals_dense_computation(self):
        """With capacity >= tokens, MoE output == explicit per-token expert mix."""
        cfg = _moe_cfg(capacity_factor=8.0, min_capacity=64)
        key = jax.random.PRNGKey(0)
        params = moe_lib.init_moe(key, cfg)
        x = jax.random.normal(key, (2, 16, cfg.d_model))
        out = moe_lib.apply_moe(params, x, cfg)

        # reference: route each token through its top-k experts explicitly
        logits = x @ params["router"]
        probs = jax.nn.softmax(logits, -1)
        gates, eidx = jax.lax.top_k(probs, cfg.experts_per_token)
        gates = gates / gates.sum(-1, keepdims=True)
        ref = jnp.zeros_like(x)
        act = jax.nn.silu
        for b in range(2):
            for s in range(16):
                acc = jnp.zeros(cfg.d_model)
                for k in range(cfg.experts_per_token):
                    e = int(eidx[b, s, k])
                    h = act(x[b, s] @ params["w_gate"][e]) * (x[b, s] @ params["w_up"][e])
                    acc = acc + gates[b, s, k] * (h @ params["w_down"][e])
                ref = ref.at[b, s].set(acc)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)

    def test_capacity_drops_tokens_not_crashes(self):
        cfg = _moe_cfg(capacity_factor=0.25, min_capacity=1)
        key = jax.random.PRNGKey(1)
        params = moe_lib.init_moe(key, cfg)
        x = jax.random.normal(key, (1, 32, cfg.d_model))
        out = moe_lib.apply_moe(params, x, cfg)
        assert bool(jnp.all(jnp.isfinite(out)))

    def test_shared_and_dense_branches(self):
        cfg = _moe_cfg(n_shared_experts=1, moe_dense_residual=True)
        params = moe_lib.init_moe(jax.random.PRNGKey(2), cfg)
        assert "shared" in params and "dense" in params
        x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, cfg.d_model))
        out = moe_lib.apply_moe(params, x, cfg)
        assert out.shape == x.shape

    def test_decode_single_token_no_drop(self):
        """S=1 decode grouping never drops (min_capacity >= top_k)."""
        cfg = _moe_cfg(min_capacity=4)
        params = moe_lib.init_moe(jax.random.PRNGKey(4), cfg)
        x = jax.random.normal(jax.random.PRNGKey(5), (16, 1, cfg.d_model))
        out = moe_lib.apply_moe(params, x, cfg)
        # compare against generous-capacity reference
        cfg2 = dataclasses.replace(cfg, capacity_factor=100.0, min_capacity=64)
        out2 = moe_lib.apply_moe(params, x, cfg2)
        np.testing.assert_allclose(np.asarray(out), np.asarray(out2), rtol=1e-5, atol=1e-5)


class TestSSD:
    def _cfg(self, chunk=16):
        return ModelConfig(
            name="s", family="ssm", n_layers=1, d_model=32, n_heads=0, n_kv_heads=0,
            d_ff=0, vocab_size=64, ssm_state=8, ssm_head_dim=16, ssm_chunk=chunk,
            dtype="float32",
        )

    def test_chunked_equals_sequential(self):
        """Chunked SSD == naive per-step recurrence (the SSM<->attention
        duality), for several chunk sizes."""
        cfg = self._cfg()
        B, S, H, P, N = 2, 48, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((B, S, H, P)).astype(np.float32)) * 0.5
        dA = -jnp.abs(jnp.asarray(rng.standard_normal((B, S, H)).astype(np.float32))) * 0.3
        Bm = jnp.asarray(rng.standard_normal((B, S, N)).astype(np.float32)) * 0.5
        Cm = jnp.asarray(rng.standard_normal((B, S, N)).astype(np.float32)) * 0.5

        # sequential reference
        state = np.zeros((B, H, P, N), np.float32)
        y_ref = np.zeros((B, S, H, P), np.float32)
        for t in range(S):
            decay = np.exp(np.asarray(dA[:, t]))[:, :, None, None]
            state = state * decay + np.einsum(
                "bn,bhp->bhpn", np.asarray(Bm[:, t]), np.asarray(x[:, t])
            )
            y_ref[:, t] = np.einsum("bhpn,bn->bhp", state, np.asarray(Cm[:, t]))

        for chunk in (8, 16, 48):
            y, final = ssm_lib.ssd_scan(x, dA, Bm, Cm, chunk)
            np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
            np.testing.assert_allclose(np.asarray(final), state, rtol=2e-4, atol=2e-4)

    def test_decode_continues_prefill_state(self):
        """decode_ssm from the prefill state == running the full sequence."""
        cfg = self._cfg(chunk=8)
        params = ssm_lib.init_ssm(jax.random.PRNGKey(0), cfg)
        x_full = jax.random.normal(jax.random.PRNGKey(1), (1, 17, cfg.d_model)) * 0.5
        y_full, _ = ssm_lib.apply_ssm_with_state(params, x_full, cfg)

        y_pre, state = ssm_lib.apply_ssm_with_state(params, x_full[:, :16], cfg)
        zxbcdt = x_full[:, :16] @ params["in_proj"]
        _, xbc, _ = ssm_lib._split_in_proj(zxbcdt, cfg)
        cache = ssm_lib.SSMCache(conv=xbc[:, -3:, :], state=state)
        y_dec, _ = ssm_lib.decode_ssm(params, x_full[:, 16:17], cache, cfg)
        np.testing.assert_allclose(
            np.asarray(y_dec[:, 0]), np.asarray(y_full[:, 16]), rtol=2e-3, atol=2e-3
        )


class TestAttentionUnits:
    def test_rope_preserves_norm_and_relativity(self):
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (1, 8, 2, 16))
        pos = jnp.arange(8)[None, :]
        out = apply_rope(x, pos, 1e4)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(out), axis=-1),
            np.linalg.norm(np.asarray(x), axis=-1),
            rtol=1e-5,
        )
        # relative property: <R(p)q, R(p+d)k> depends only on d
        q = jax.random.normal(key, (1, 1, 1, 16))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))
        dots = []
        for p0 in (0, 5, 11):
            qr = apply_rope(q, jnp.asarray([[p0]]), 1e4)
            kr = apply_rope(k, jnp.asarray([[p0 + 3]]), 1e4)
            dots.append(float(jnp.sum(qr * kr)))
        np.testing.assert_allclose(dots, dots[0], rtol=1e-4)

    def test_sliding_window_mask(self):
        m = A.causal_mask(6, 6, window=3)[0, 0]
        assert bool(m[5, 5]) and bool(m[5, 3])
        assert not bool(m[5, 2])  # outside window
        assert not bool(m[2, 4])  # future

    def test_gqa_repeat_matches_grouped_reference(self):
        """Repeat-KV _sdpa == explicit per-group attention."""
        cfg = ModelConfig(name="a", family="dense", n_layers=1, d_model=32,
                          n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                          head_dim=8, dtype="float32")
        key = jax.random.PRNGKey(0)
        B, S = 1, 6
        q = jax.random.normal(key, (B, S, 4, 8))
        k = jax.random.normal(jax.random.PRNGKey(1), (B, S, 2, 8))
        v = jax.random.normal(jax.random.PRNGKey(2), (B, S, 2, 8))
        mask = A.causal_mask(S, S)
        out = A._sdpa(q, k, v, mask, cfg)
        # reference: head h attends kv head h//2
        ref = np.zeros((B, S, 4, 8), np.float32)
        for h in range(4):
            kv = h // 2
            sc = np.einsum("bqd,bsd->bqs", np.asarray(q[:, :, h]), np.asarray(k[:, :, kv])) / np.sqrt(8)
            sc = np.where(np.asarray(mask[0, 0]), sc, -1e30)
            w = np.exp(sc - sc.max(-1, keepdims=True))
            w = w / w.sum(-1, keepdims=True)
            ref[:, :, h] = np.einsum("bqs,bsd->bqd", w, np.asarray(v[:, :, kv]))
        np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-3, atol=2e-3)


class TestShardingRules:
    def test_param_axes_cover_all_archs(self):
        """Every param leaf in every arch gets a rank-matching axis tuple."""
        from repro.launch.cells import params_spec_for

        for arch in ("deepseek_7b", "kimi_k2_1t_a32b", "hymba_1_5b",
                     "seamless_m4t_medium", "mamba2_130m"):
            cfg = get_config(arch).reduced()
            spec = params_spec_for(cfg)
            axes = sh.logical_axes(spec)
            for (pa, leaf), (_, ax) in zip(
                jax.tree_util.tree_flatten_with_path(spec)[0],
                jax.tree_util.tree_flatten_with_path(axes, is_leaf=lambda x: isinstance(x, tuple))[0],
            ):
                assert len(ax) == leaf.ndim

    def test_divisibility_fallback(self):
        """25 heads on a 16-way axis must fall back to replication."""
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        spec = sh.spec_for((25, 64), ("heads", "embed"), mesh, sh.DEFAULT_RULES)
        assert spec == jax.sharding.PartitionSpec(None, None)

    def test_constrain_noop_without_mesh(self):
        x = jnp.ones((4, 4))
        out = sh.constrain(x, "batch", "embed")
        assert out is x
