"""Kernel-backed solver backend vs the plain-XLA path (ISSUE 1 tentpole).

Three layers of parity, none requiring hypothesis (these must run in the
minimal CI image):
  * interpret-mode kernels vs their pure-jnp oracles on NON-DIVISIBLE
    shapes (p % block_size != 0, m % m_tile != 0) and both dtypes;
  * fw_solve(backend='pallas') vs fw_solve(backend='xla') end to end;
  * fw_path_batched vs sequential fw_path, compiling the lane solver
    exactly once.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FWConfig, fw_solve, path as path_lib
from repro.core.fw_lasso import _sample_indices
from repro.kernels import colstats, fw_vertex, residual_update, sampled_scores
from repro.kernels.colstats.ref import colstats_ref
from repro.kernels.fw_grad.ref import sampled_scores_ref
from repro.kernels.residual_update.ref import residual_update_ref

I = dict(interpret=True)
DELTA = 150.0


def _problem(p, m, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    Xt = jnp.asarray(rng.standard_normal((p, m)).astype(dtype))
    r = jnp.asarray(rng.standard_normal(m).astype(dtype))
    return Xt, r


class TestKernelPaddingParity:
    """p % block_size != 0 and m % m_tile != 0 must not hit asserts."""

    @pytest.mark.parametrize("p,m,bs,mt", [(300, 80, 128, 512), (777, 300, 256, 128)])
    def test_sampled_scores_nondivisible(self, p, m, bs, mt):
        Xt, r = _problem(p, m, 0)
        nb_total = -(-p // bs)
        blk = jnp.arange(nb_total, dtype=jnp.int32)  # includes the padded tail
        got = sampled_scores(Xt, r, blk, block_size=bs, m_tile=mt, **I)
        idx = np.asarray(blk)[:, None] * bs + np.arange(bs)[None, :]
        idx = idx.reshape(-1)
        valid = idx < p
        want = -(np.take(np.asarray(Xt), idx[valid], axis=0) @ np.asarray(r))
        np.testing.assert_allclose(
            np.asarray(got)[valid], want, rtol=2e-5, atol=2e-4
        )
        # padded coordinates score exactly zero
        np.testing.assert_array_equal(np.asarray(got)[~valid], 0.0)

    @pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
    def test_sampled_scores_dtypes_padded(self, dtype):
        Xt, r = _problem(300, 96, 1)
        Xt, r = Xt.astype(dtype), r.astype(dtype)
        blk = jnp.asarray([0, 2], jnp.int32)  # block 2 covers rows 256..299 + pad
        got = sampled_scores(Xt, r, blk, block_size=128, m_tile=96, **I)
        want, idx = sampled_scores_ref(
            Xt.astype(jnp.float32), r.astype(jnp.float32), blk, 128
        )
        valid = np.asarray(idx) < 300
        tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(
            np.asarray(got)[valid], np.asarray(want)[valid], rtol=tol, atol=tol * 10
        )

    def test_fw_vertex_masks_padded_coordinates(self):
        p, m = 130, 64
        Xt, r = _problem(p, m, 2)
        blk = jnp.arange(-(-p // 128), dtype=jnp.int32)  # 2 blocks, 126 padded rows
        i_star, g_star = fw_vertex(Xt, r, blk, block_size=128, m_tile=m, p_valid=p, **I)
        assert int(i_star) < p
        grad = -(np.asarray(Xt) @ np.asarray(r))
        assert int(i_star) == int(np.argmax(np.abs(grad)))
        np.testing.assert_allclose(float(g_star), grad[int(i_star)], rtol=2e-5, atol=2e-4)

    @pytest.mark.parametrize("p,m,pt,mt", [(300, 80, 256, 512), (777, 130, 128, 64)])
    def test_colstats_nondivisible(self, p, m, pt, mt):
        Xt, y = _problem(p, m, 3)
        zty, zn2 = colstats(Xt, y, p_tile=pt, m_tile=mt, **I)
        assert zty.shape == (p,) and zn2.shape == (p,)
        zty_r, zn2_r = colstats_ref(Xt, y)
        np.testing.assert_allclose(np.asarray(zty), np.asarray(zty_r), rtol=2e-5, atol=2e-4)
        np.testing.assert_allclose(np.asarray(zn2), np.asarray(zn2_r), rtol=2e-5, atol=2e-4)

    @pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
    def test_residual_update_nondivisible_dtypes(self, dtype):
        rng = np.random.default_rng(4)
        m = 777  # not divisible by any default tile
        r, y, z = (
            jnp.asarray(rng.standard_normal(m).astype(np.float32)).astype(dtype)
            for _ in range(3)
        )
        got = residual_update(r, y, z, jnp.asarray(0.25), jnp.asarray(-1.5), **I)
        want = residual_update_ref(
            r.astype(jnp.float32), y.astype(jnp.float32), z.astype(jnp.float32),
            0.25, -1.5,
        )
        tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want), rtol=tol, atol=tol
        )


class TestBackendEquivalence:
    """fw_solve(backend='pallas') == fw_solve(backend='xla') end to end.

    small_problem has p=300: NOT divisible by block_size=128, so the
    padded-kernel path is what's exercised.
    """

    @pytest.mark.parametrize(
        "sampling,kw",
        [
            ("uniform", dict(kappa=60)),
            ("block", dict(kappa=64, block_size=32)),
            ("full", dict(block_size=128)),
        ],
    )
    def test_objective_parity(self, small_problem, rng_key, sampling, kw):
        Xt, y, _ = small_problem
        base = dict(delta=DELTA, sampling=sampling, max_iters=5000, tol=1e-6, **kw)
        res_x = fw_solve(Xt, y, FWConfig(**base), rng_key)
        res_p = fw_solve(Xt, y, FWConfig(backend="pallas", **base), rng_key)
        rel = abs(float(res_p.objective) - float(res_x.objective)) / abs(
            float(res_x.objective)
        )
        assert rel < 1e-4, (sampling, rel)
        assert float(jnp.sum(jnp.abs(res_p.alpha))) <= DELTA * (1 + 1e-5)

    def test_uniform_sampling_identical_trajectory(self, small_problem, rng_key):
        """Width-1 blocks replay the exact same index stream as the XLA
        gather, so uniform-sampling runs are bit-for-bit comparable."""
        Xt, y, _ = small_problem
        base = dict(delta=DELTA, sampling="uniform", kappa=60, max_iters=2000, tol=1e-6)
        res_x = fw_solve(Xt, y, FWConfig(**base), rng_key)
        res_p = fw_solve(Xt, y, FWConfig(backend="pallas", **base), rng_key)
        assert int(res_x.iterations) == int(res_p.iterations)
        assert int(res_x.n_dots) == int(res_p.n_dots)


class TestBlockSamplingClamp:
    def test_more_blocks_requested_than_available(self, rng_key):
        """kappa // block_size > ceil(p / block_size) used to crash
        jax.random.choice(replace=False); the count is now clamped."""
        p = 64
        cfg = FWConfig(delta=10.0, sampling="block", kappa=128, block_size=32)
        idx = _sample_indices(rng_key, p, cfg)
        assert idx.shape == (64,)  # clamped to ceil(64/32)=2 blocks
        assert int(idx.min()) >= 0 and int(idx.max()) < p
        assert len(set(np.asarray(idx).tolist())) == p  # all blocks, no dupes

    def test_tail_wrap_stays_in_range(self, rng_key):
        p = 300
        cfg = FWConfig(delta=10.0, sampling="block", kappa=256, block_size=128)
        for s in range(5):
            idx = _sample_indices(jax.random.PRNGKey(s), p, cfg)
            assert int(idx.max()) < p and int(idx.min()) >= 0

    def test_oversampled_block_solve_runs(self, rng_key):
        rng = np.random.default_rng(0)
        Xt = jnp.asarray(rng.standard_normal((64, 40)).astype(np.float32))
        y = jnp.asarray(rng.standard_normal(40).astype(np.float32))
        cfg = FWConfig(delta=5.0, sampling="block", kappa=128, block_size=32,
                       max_iters=500, tol=1e-5)
        res = fw_solve(Xt, y, cfg, rng_key)
        assert bool(jnp.isfinite(res.objective))


class TestBatchedPath:
    def test_matches_sequential_and_compiles_once(self, small_problem):
        Xt, y, _ = small_problem
        deltas = path_lib.delta_grid(100.0, n_points=20)
        cfg = FWConfig(delta=1.0, kappa=60, max_iters=20000, tol=1e-4)
        seq = path_lib.fw_path(Xt, y, deltas, cfg)
        path_lib.clear_batched_solver_cache()
        bat = path_lib.fw_path_batched(Xt, y, deltas, cfg)
        assert path_lib.batched_solver_cache_size() == 1  # ONE compile, 3 chunks
        assert len(bat.points) == len(seq.points) == 20
        for s, b in zip(seq.points, bat.points):
            assert b.reg == pytest.approx(s.reg, rel=1e-12)
            rel = abs(b.objective - s.objective) / abs(s.objective)
            assert rel < 1e-3, (s.reg, rel)
            assert b.l1 <= s.reg * (1 + 1e-4)

    def test_lane_width_one_degenerates_to_sequential(self, small_problem):
        Xt, y, _ = small_problem
        deltas = path_lib.delta_grid(50.0, n_points=3)
        cfg = FWConfig(delta=1.0, kappa=60, max_iters=3000, tol=1e-4)
        res = path_lib.fw_path_batched(Xt, y, deltas, cfg, lane_width=1)
        assert len(res.points) == 3
        objs = [pt.objective for pt in res.points]
        assert objs[-1] <= objs[0] * (1 + 1e-6)

    def test_ragged_final_chunk_padding(self, small_problem):
        """n_points not divisible by lane_width: padded lanes are dropped."""
        Xt, y, _ = small_problem
        deltas = path_lib.delta_grid(50.0, n_points=7)
        cfg = FWConfig(delta=1.0, kappa=60, max_iters=3000, tol=1e-4)
        res = path_lib.fw_path_batched(Xt, y, deltas, cfg, lane_width=3)
        assert len(res.points) == 7
        assert [pt.reg for pt in res.points] == pytest.approx(list(deltas))
