"""Unit tests for the stochastic Frank-Wolfe Lasso solver (paper Alg. 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FWConfig,
    baselines,
    duality_gap,
    fw_lasso,
    fw_solve,
    fw_solve_with_history,
)
from repro.core.solver_config import FISTAConfig

DELTA = 150.0


def _fista_ref(Xt, y, delta, key):
    cfg = FISTAConfig(delta=delta, constrained=True, max_iters=5000, tol=1e-9)
    return baselines.fista_solve(Xt, y, cfg, key)


class TestFWSolve:
    def test_feasibility(self, small_problem, rng_key):
        Xt, y, _ = small_problem
        for sampling in ("full", "uniform", "block"):
            cfg = FWConfig(
                delta=DELTA, sampling=sampling, kappa=60, block_size=30,
                max_iters=5000, tol=1e-6,
            )
            res = fw_solve(Xt, y, cfg, rng_key)
            assert float(jnp.sum(jnp.abs(res.alpha))) <= DELTA * (1 + 1e-5)

    def test_matches_fista_constrained(self, small_problem, rng_key):
        Xt, y, _ = small_problem
        ref = _fista_ref(Xt, y, DELTA, rng_key)
        cfg = FWConfig(delta=DELTA, sampling="full", max_iters=20000, tol=1e-7)
        res = fw_solve(Xt, y, cfg, rng_key)
        assert res.objective <= ref.objective * 1.01 + 1e-3

    def test_stochastic_matches_deterministic(self, small_problem, rng_key):
        Xt, y, _ = small_problem
        det = fw_solve(
            Xt, y, FWConfig(delta=DELTA, sampling="full", max_iters=20000, tol=1e-7),
            rng_key,
        )
        sto = fw_solve(
            Xt, y,
            FWConfig(delta=DELTA, sampling="uniform", kappa=100, max_iters=40000,
                     tol=1e-7),
            rng_key,
        )
        assert float(sto.objective) <= float(det.objective) * 1.02 + 1e-3

    def test_objective_recursion_consistency(self, small_problem, rng_key):
        """The S/F recursion objective must equal the direct residual norm."""
        Xt, y, _ = small_problem
        cfg = FWConfig(delta=DELTA, sampling="uniform", kappa=64, max_iters=500,
                       tol=0.0, patience=10**9)
        res, _ = fw_solve_with_history(Xt, y, cfg, rng_key, n_iters=500)
        direct = 0.5 * jnp.sum((res.alpha @ Xt - y) ** 2)
        np.testing.assert_allclose(
            float(res.objective), float(direct), rtol=1e-4, atol=1e-2
        )

    def test_monotone_decrease_full_sampling(self, small_problem, rng_key):
        """Exact line search + full sampling => nonincreasing objective."""
        Xt, y, _ = small_problem
        cfg = FWConfig(delta=DELTA, sampling="full", max_iters=200, tol=0.0,
                       patience=10**9)
        _, hist = fw_solve_with_history(Xt, y, cfg, rng_key, n_iters=200)
        hist = np.asarray(hist)
        assert np.all(hist[1:] <= hist[:-1] * (1 + 1e-5) + 1e-3)

    def test_sparsity_bound(self, medium_problem, rng_key):
        """FW iterates have at most k+1 active coordinates after k steps (§3.1)."""
        Xt, y, _ = medium_problem
        for k in (5, 17, 49):
            cfg = FWConfig(delta=80.0, sampling="uniform", kappa=128,
                           max_iters=k, tol=0.0, patience=10**9)
            res = fw_solve(Xt, y, cfg, rng_key)
            assert int(res.active) <= k + 1

    def test_duality_gap_bounds_suboptimality(self, small_problem, rng_key):
        Xt, y, _ = small_problem
        cfg = FWConfig(delta=DELTA, sampling="full", max_iters=3000, tol=1e-7)
        res = fw_solve(Xt, y, cfg, rng_key)
        state = fw_lasso.init_state(Xt, y, rng_key, res.alpha)
        gap = float(duality_gap(Xt, state, DELTA))
        ref = _fista_ref(Xt, y, DELTA, rng_key)
        subopt = float(res.objective - ref.objective)
        assert gap >= subopt - 1e-2  # gap upper-bounds primal suboptimality
        assert gap >= -1e-3  # gap is nonnegative

    def test_warm_start_from_solution_terminates_fast(self, small_problem, rng_key):
        """Restarting from the solution must stop almost immediately."""
        Xt, y, _ = small_problem
        cfg = FWConfig(delta=DELTA, sampling="full", max_iters=20000, tol=1e-6)
        cold = fw_solve(Xt, y, cfg, rng_key)
        warm = fw_solve(Xt, y, cfg, rng_key, cold.alpha)
        assert int(warm.iterations) <= int(cold.iterations) + 5
        assert float(warm.objective) <= float(cold.objective) * (1 + 1e-5)

    def test_line_search_optimal(self, small_problem, rng_key):
        """lambda from eq. (8) must be a 1-D minimizer along the FW segment."""
        Xt, y, _ = small_problem
        stats = fw_lasso.precompute_colstats(Xt, y)
        state = fw_lasso.init_state(Xt, y, rng_key)
        cfg = FWConfig(delta=DELTA, sampling="full", max_iters=10, tol=0.0)
        # take a few steps, then verify stationarity numerically
        for _ in range(5):
            state = fw_lasso.fw_step(Xt, y, stats, state, cfg)
        alpha = state.scale * state.beta

        def f(a):
            return 0.5 * jnp.sum((a @ Xt - y) ** 2)

        # recompute the FW vertex and optimal lambda at this iterate
        grad = -(Xt @ state.resid)
        i_star = int(jnp.argmax(jnp.abs(grad)))
        d_t = -DELTA * float(jnp.sign(grad[i_star]))
        direction = -alpha
        direction = direction.at[i_star].add(d_t)
        lam_grid = jnp.linspace(0.0, 1.0, 101)
        vals = jax.vmap(lambda l: f(alpha + l * direction))(lam_grid)
        lam_best = lam_grid[int(jnp.argmin(vals))]
        # closed-form lambda
        g_lin = grad[i_star] + stats.zty[i_star]
        num = state.s_quad - d_t * grad[i_star] - state.f_lin
        den = state.s_quad - 2 * d_t * g_lin + d_t**2 * stats.znorm2[i_star]
        lam_cf = float(jnp.clip(num / den, 0.0, 1.0))
        assert abs(lam_cf - float(lam_best)) <= 0.02  # grid resolution

    def test_block_sampling_nondivisible(self, rng_key):
        """Tail-wrapping block sampling stays in range and converges."""
        from repro.data import make_regression, standardize

        ds = standardize(make_regression(m=50, p=307, n_informative=5, seed=2))
        Xt = jnp.asarray(ds.X.T.copy())
        y = jnp.asarray(ds.y)
        cfg = FWConfig(delta=50.0, sampling="block", kappa=64, block_size=32,
                       max_iters=3000, tol=1e-6)
        res = fw_solve(Xt, y, cfg, rng_key)
        assert bool(jnp.isfinite(res.objective))
        assert float(jnp.sum(jnp.abs(res.alpha))) <= 50.0 * (1 + 1e-5)

    def test_dot_product_accounting(self, small_problem, rng_key):
        Xt, y, _ = small_problem
        kappa = 64
        n_iters = 100
        cfg = FWConfig(delta=DELTA, sampling="uniform", kappa=kappa,
                       max_iters=n_iters, tol=0.0, patience=10**9)
        res = fw_solve(Xt, y, cfg, rng_key)
        assert int(res.n_dots) == kappa * n_iters
