from repro.training import optimizers
from repro.training.train_step import (
    init_train_state,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)

__all__ = [
    "optimizers",
    "init_train_state",
    "make_prefill_step",
    "make_serve_step",
    "make_train_step",
]
