"""Jittable train/serve steps.

train_step: microbatched gradient accumulation (lax.scan) -> clip ->
AdamW/Adafactor update with cosine schedule. Microbatching bounds the
scan-over-layers carry memory at large (batch x seq); counts are chosen
per (arch x shape) in launch/cells.py.

serve_step: one-token decode against the preallocated cache.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.training import optimizers as opt_lib


def _split_microbatches(batch: Dict, n: int) -> Dict:
    """(B, ...) -> (n, B//n, ...) for every leaf."""
    return jax.tree.map(lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)


def make_train_step(
    cfg: ModelConfig,
    *,
    microbatches: int = 1,
    dp_axes: Tuple[str, ...] | None = None,
    accum_dtype=jnp.float32,  # bf16 halves the accumulator HBM (1T configs)
    base_lr: float = 3e-4,
    warmup: int = 100,
    total_steps: int = 10_000,
    max_grad_norm: float = 1.0,
    weight_decay: float = 0.1,
):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``dp_axes``: mesh axes carrying the batch shard. Required when
    microbatching under pjit — the (B,) -> (mb, B/mb) reshape cannot keep
    the shard on the new batch dim without an explicit constraint (GSPMD
    falls back to full replication otherwise).
    """

    def loss_and_grad(params, mb):
        (loss, metrics), grads = jax.value_and_grad(
            model_lib.loss_fn, has_aux=True
        )(params, mb, cfg)
        return loss, metrics, grads

    def train_step(params, opt_state: opt_lib.OptState, batch: Dict):
        if microbatches > 1:
            mbs = _split_microbatches(batch, microbatches)
            if dp_axes:
                from jax.sharding import PartitionSpec as P

                def constrain(x):
                    spec = P(None, dp_axes, *([None] * (x.ndim - 2)))
                    return jax.lax.with_sharding_constraint(x, spec)

                mbs = jax.tree.map(constrain, mbs)

            def accum(carry, mb):
                gsum, lsum = carry
                loss, _, grads = loss_and_grad(params, mb)
                gsum = jax.tree.map(
                    lambda a, g: a + g.astype(accum_dtype), gsum, grads
                )
                return (gsum, lsum + loss), None

            gsum0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params
            )
            (gsum, lsum), _ = jax.lax.scan(accum, (gsum0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = {"loss": loss}
        else:
            loss, metrics, grads = loss_and_grad(params, batch)

        grads, gnorm = opt_lib.clip_by_global_norm(grads, max_grad_norm)
        # schedule uses the post-increment step (step 0 would give lr=0)
        lr = opt_lib.cosine_schedule(
            opt_state.step + 1, base_lr=base_lr, warmup=warmup, total=total_steps
        )
        params, opt_state = opt_lib.apply_optimizer(
            cfg.optimizer, grads, opt_state, params, lr
        )
        metrics = dict(metrics)
        metrics.update({"grad_norm": gnorm, "lr": lr, "step": opt_state.step})
        return params, opt_state, metrics

    return train_step


def make_serve_step(cfg: ModelConfig):
    """serve_step(params, tokens (B,1), cache) -> (next_tokens, logits, cache)."""

    def serve_step(params, tokens, cache):
        logits, cache = model_lib.decode_step(params, tokens, cache, cfg)
        next_tokens = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tokens[:, None], logits, cache

    return serve_step


def make_prefill_step(cfg: ModelConfig, max_seq: int):
    def prefill_step(params, batch):
        return model_lib.prefill(params, batch, cfg, max_seq)

    return prefill_step


def init_train_state(key, cfg: ModelConfig):
    params = model_lib.init_params(key, cfg)
    opt_state = opt_lib.init_optimizer(cfg.optimizer, params)
    return params, opt_state
