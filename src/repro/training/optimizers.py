"""Optimizers from scratch: AdamW (fp32 state) and Adafactor (factored).

AdamW keeps fp32 m/v plus an fp32 master copy when params are low
precision — the production recipe for <=80B configs. Adafactor keeps
factored second moments and no master copy, which is what lets the
0.5T-1T configs (arctic, kimi) fit 16GB/chip HBM (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    inner: Any  # per-leaf state pytree


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


class AdamLeaf(NamedTuple):
    m: jax.Array  # fp32
    v: jax.Array  # fp32
    master: jax.Array  # fp32 master weights ((1,) placeholder for fp32 params
    # — they are their own master; avoids a redundant copy and buffer aliasing)


def adamw_init(params) -> OptState:
    def leaf(p):
        z = jnp.zeros(p.shape, jnp.float32)
        if p.dtype == jnp.float32:
            master = jnp.zeros((1,), jnp.float32)  # placeholder
        else:
            master = p.astype(jnp.float32)
        return AdamLeaf(m=z, v=jnp.zeros(p.shape, jnp.float32), master=master)

    return OptState(step=jnp.zeros((), jnp.int32), inner=jax.tree.map(leaf, params))


def adamw_update(
    grads,
    state: OptState,
    params,
    lr: float | jax.Array,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Tuple[Any, OptState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1**t
    bc2 = 1.0 - b2**t

    def leaf(g, s: AdamLeaf, p):
        gf = g.astype(jnp.float32)
        m = b1 * s.m + (1 - b1) * gf
        v = b2 * s.v + (1 - b2) * gf * gf
        update = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        placeholder = s.master.shape != p.shape
        master = p.astype(jnp.float32) if placeholder else s.master
        master = master - lr * (update + weight_decay * master)
        new_s = AdamLeaf(m=m, v=v, master=s.master if placeholder else master)
        return master.astype(p.dtype), new_s

    flat_g, treedef = jax.tree.flatten(grads)
    flat_s = treedef.flatten_up_to(state.inner)
    flat_p = treedef.flatten_up_to(params)
    outs = [leaf(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_inner = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_params, OptState(step=step, inner=new_inner)


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018), simplified: factored v, no master copy
# ---------------------------------------------------------------------------


class FactorLeaf(NamedTuple):
    v_row: jax.Array  # fp32, shape without last dim
    v_col: jax.Array  # fp32, shape without second-to-last dim
    v_full: jax.Array  # fp32 scalar-shaped fallback for rank<2 leaves


def _factored(p) -> bool:
    return p.ndim >= 2


def adafactor_init(params) -> OptState:
    def leaf(p):
        if _factored(p):
            return FactorLeaf(
                v_row=jnp.zeros(p.shape[:-1], jnp.float32),
                v_col=jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                v_full=jnp.zeros((1,), jnp.float32),
            )
        return FactorLeaf(
            v_row=jnp.zeros((1,), jnp.float32),
            v_col=jnp.zeros((1,), jnp.float32),
            v_full=jnp.zeros(p.shape, jnp.float32),
        )

    return OptState(step=jnp.zeros((), jnp.int32), inner=jax.tree.map(leaf, params))


def adafactor_update(
    grads,
    state: OptState,
    params,
    lr: float | jax.Array,
    *,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> Tuple[Any, OptState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t**-decay  # Adafactor schedule

    def leaf(g, s: FactorLeaf, p):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + eps
        if _factored(p):
            v_row = beta2 * s.v_row + (1 - beta2) * jnp.mean(g2, axis=-1)
            v_col = beta2 * s.v_col + (1 - beta2) * jnp.mean(g2, axis=-2)
            row_mean = jnp.mean(v_row, axis=-1, keepdims=True)
            update = gf * jax.lax.rsqrt(v_row / jnp.maximum(row_mean, eps))[..., None]
            update = update * jax.lax.rsqrt(v_col)[..., None, :]
            new_s = FactorLeaf(v_row=v_row, v_col=v_col, v_full=s.v_full)
        else:
            v = beta2 * s.v_full + (1 - beta2) * g2
            update = gf * jax.lax.rsqrt(v)
            new_s = FactorLeaf(v_row=s.v_row, v_col=s.v_col, v_full=v)
        # update clipping by RMS
        rms = jnp.sqrt(jnp.mean(update * update) + 1e-30)
        update = update / jnp.maximum(1.0, rms / clip_threshold)
        pf = p.astype(jnp.float32)
        new_p = pf - lr * (update + weight_decay * pf)
        return new_p.astype(p.dtype), new_s

    flat_g, treedef = jax.tree.flatten(grads)
    flat_s = treedef.flatten_up_to(state.inner)
    flat_p = treedef.flatten_up_to(params)
    outs = [leaf(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_inner = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_params, OptState(step=step, inner=new_inner)


# ---------------------------------------------------------------------------
# Common utilities
# ---------------------------------------------------------------------------


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def cosine_schedule(step, *, base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    t = step.astype(jnp.float32)
    warm = base_lr * t / max(warmup, 1)
    progress = jnp.clip((t - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * progress)))
    return jnp.where(t < warmup, warm, cos)


def init_optimizer(name: str, params) -> OptState:
    return {"adamw": adamw_init, "adafactor": adafactor_init}[name](params)


def apply_optimizer(name: str, grads, state, params, lr):
    fn = {"adamw": adamw_update, "adafactor": adafactor_update}[name]
    return fn(grads, state, params, lr)
