"""Hashable solver configs (static args to jitted solver entry points)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class FWConfig:
    """Configuration of the stochastic Frank-Wolfe Lasso solver.

    Attributes:
      delta: l1-ball radius (constrained formulation, paper eq. 1).
      kappa: sampling-set size |S| (paper §4.5).
      sampling: 'uniform' (paper), 'block' (TPU-native, DESIGN.md §4),
        or 'full' (deterministic FW).
      block_size: aligned block width for 'block' sampling.
      max_iters / tol: the paper's ||alpha^{k+1}-alpha^k||_inf <= eps rule.
      gap_rtol: a step whose sampled duality gap (the line-search numerator,
        DESIGN.md §Stopping) is below gap_rtol * the gap's own fp32 scale is
        counted as a stall — it is indistinguishable from rounding noise.
      backend: 'xla' (plain jnp gathers), 'pallas' (the fused kernels in
        repro.kernels drive the hot loop; interpret mode off-TPU), or
        'sparse' (block-ELL SparseBlockMatrix design matrix — the solver
        expects ``Xt`` to be a repro.sparse.SparseBlockMatrix and the
        three O(kappa*m) primitives drop to O(kappa*nnz_max); block
        geometry comes from the MATRIX, so ``block_size`` is ignored).
      sparse_kernel: 'sparse' backend only — None = auto (Pallas
        kernels/sparse_grad on TPU, pure-XLA gather elsewhere), True/False
        forces the choice (tests force True + interpret).
      m_tile: sample-dimension tile for the Pallas kernels.
      interpret: force Pallas interpret mode; None = auto (interpret
        everywhere except on real TPU devices).
    """

    delta: float
    kappa: int = 194  # paper's top-2%/98% confidence default
    sampling: str = "uniform"
    block_size: int = 128
    max_iters: int = 50_000
    tol: float = 1e-3
    patience: int = 20  # consecutive sub-tol steps before stopping (stochastic)
    refresh_every: int = 64  # recompute S/F from residuals (fp32 drift control)
    eps_den: float = 1e-12
    renorm_threshold: float = 1e-6
    gap_rtol: float = 1e-6
    backend: str = "xla"
    sparse_kernel: Optional[bool] = None
    m_tile: int = 512
    interpret: Optional[bool] = None


@dataclass(frozen=True)
class CDConfig:
    """Cyclic / stochastic coordinate descent (penalized form, Glmnet-style)."""

    lam: float
    max_sweeps: int = 1000
    tol: float = 1e-3
    stochastic: bool = False


@dataclass(frozen=True)
class FISTAConfig:
    """FISTA on the penalized form; 'constrained' switches to l1-ball projection."""

    lam: float = 0.0
    delta: float = 0.0
    constrained: bool = False
    max_iters: int = 2000
    tol: float = 1e-3
    power_iters: int = 50  # Lipschitz estimation
