"""Hashable solver configs (static args to jitted solver entry points)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

# obs.telemetry is import-clean of repro.core, so the spec can live with
# the observability layer while riding inside the static config key here
from repro.obs.telemetry import TelemetrySpec


@dataclass(frozen=True)
class DistSpec:
    """Static sharding vocabulary of the distributed backend (DESIGN.md
    §Distributed). Lives inside FWConfig so the jitted entry points see
    the mesh geometry as part of their static config key; the axis names
    are the shard_map axes the collectives reduce over.

    The convention shared by ``repro.distributed``: the design matrix is
    sharded feature-blocks over ``model_axis`` and samples over
    ``data_axis``; the residual/margin co-state and targets live as
    per-``data``-slice vectors; beta and the column statistics are
    REPLICATED (O(p) per host — ~17 MB at the paper's p = 4.2M, against
    the O(nnz)/O(p*m) matrix that sharding must split).
    """

    n_data: int = 1
    n_model: int = 1
    data_axis: str = "data"
    model_axis: str = "model"


VALID_BACKENDS = ("xla", "pallas", "sparse", "distributed")
VALID_STEP_RULES = ("classic", "away", "pairwise", "partan", "lazy")


@dataclass(frozen=True)
class FWConfig:
    """Configuration of the stochastic Frank-Wolfe Lasso solver.

    Attributes:
      delta: l1-ball radius (constrained formulation, paper eq. 1).
      kappa: sampling-set size |S| (paper §4.5).
      sampling: 'uniform' (paper), 'block' (TPU-native, DESIGN.md §4),
        or 'full' (deterministic FW).
      block_size: aligned block width for 'block' sampling.
      max_iters / tol: the paper's ||alpha^{k+1}-alpha^k||_inf <= eps rule.
      gap_rtol: a step whose sampled duality gap (the line-search numerator,
        DESIGN.md §Stopping) is below gap_rtol * the gap's own fp32 scale is
        counted as a stall — it is indistinguishable from rounding noise.
      backend: 'xla' (plain jnp gathers), 'pallas' (the fused kernels in
        repro.kernels drive the hot loop; interpret mode off-TPU), or
        'sparse' (block-ELL SparseBlockMatrix design matrix — the solver
        expects ``Xt`` to be a repro.sparse.SparseBlockMatrix and the
        three O(kappa*m) primitives drop to O(kappa*nnz_max); block
        geometry comes from the MATRIX, so ``block_size`` is ignored).
        'distributed' is the mesh-sharded variant of both layouts — it
        only runs inside ``repro.distributed.driver``'s shard_map (which
        sets it, together with ``dist``, from the operand's mesh); the
        plain entry points reject it.
      sparse_kernel: 'sparse' backend only — None = auto (Pallas
        kernels/sparse_grad on TPU, pure-XLA gather elsewhere), True/False
        forces the choice (tests force True + interpret).
      gather_mode: how the sparse Pallas kernels read the VMEM-resident
        residual/targets at the stored row indices: 'take' (in-kernel
        jnp.take gather), 'onehot' (one-hot matmul fallback for TPUs where
        the VMEM gather fails to lower — MXU-friendly, O(slots * m)
        compute), or 'auto' (currently 'take'; the knob exists so a
        failing lowering can be routed around without a code change).
      fuse_steps: K consecutive FW iterations per dispatch (DESIGN.md
        §Perf). 1 (default) is today's one-launch-per-iteration loop.
        K > 1 switches ``engine.run_loop``/``batched_loop`` to a chunked
        driver: the co-state and scalar recursions stay device-resident
        across K steps (the ``kernels/fused_step`` Pallas megakernel on
        the 'pallas' and kernel-dispatched 'sparse' backends, a fori_loop
        over the engine step elsewhere) and the §Stopping rule is checked
        BETWEEN chunks, so stall/patience stops may overshoot by at most
        K-1 iterations (max_iters is still exact — trailing chunk steps
        are masked). Fusion engages for the closed-form line-search
        oracles (lasso / elastic-net) under 'uniform' sampling, where the
        K x kappa index stream is a pure function of (key, cfg, p) and
        can be pregenerated; the logistic oracle's bisection and the
        other sampling modes fall back to fuse_steps=1 semantics, and the
        distributed driver forces fuse_steps=1 (single-device-only for
        now).
      report_gap: compute the certified FW duality gap
        g(alpha) = alpha^T grad + delta*||grad||_inf (oracle ``gap()``
        gradients) at the END of each solve — one O(nnz)/O(p*m) full
        gradient pass, surfaced as ``SolveResult.gap`` and
        ``PathPoint.gap``. Off by default: certification is not hot-loop
        work.
      m_tile: sample-dimension tile for the Pallas kernels.
      interpret: force Pallas interpret mode; None = auto (interpret
        everywhere except on real TPU devices).
      dist: static mesh vocabulary when ``backend == 'distributed'``
        (set by ``repro.distributed``; plain solves leave it None).
      step_rule: which FW step variant drives each iteration (DESIGN.md
        §StepRule). 'classic' (default) is the paper's Algorithm-2 step,
        bit-identical to the pre-refactor trajectory; 'away' adds
        away-steps over a tracked active set; 'pairwise' moves mass from
        the away atom straight onto the FW atom; 'partan' extrapolates
        each FW step against the previous iterate; 'lazy' re-scores a
        small cache of recent winners before paying a fresh sampled draw.
        All rules run on every backend, including 'distributed'.
      active_set_size: tracked active-set capacity for 'away'/'pairwise'
        (a fixed-size index buffer; weakest-|beta| slot is evicted when
        a new FW atom enters a full buffer).
      lazy_cache: winner-cache capacity for the 'lazy' LMO wrapper.
      telemetry: device-side metric-ring spec (DESIGN.md §Observability;
        ``repro.obs.TelemetrySpec``). None (default) means telemetry is
        OFF and every recording site is absent from the compiled
        program, so default trajectories stay bit-identical to the
        pre-telemetry engine. When set, ``EngineState`` carries a
        per-iteration ring surfaced on ``SolveResult.telemetry``; with
        ``record_objective`` the fused megakernel chunk falls back to
        the bit-identical fori-of-step executor (the kernel has no
        per-step objective output).
    """

    delta: float
    kappa: int = 194  # paper's top-2%/98% confidence default
    sampling: str = "uniform"
    block_size: int = 128
    max_iters: int = 50_000
    tol: float = 1e-3
    patience: int = 20  # consecutive sub-tol steps before stopping (stochastic)
    refresh_every: int = 64  # recompute S/F from residuals (fp32 drift control)
    eps_den: float = 1e-12
    renorm_threshold: float = 1e-6
    gap_rtol: float = 1e-6
    backend: str = "xla"
    fuse_steps: int = 1
    sparse_kernel: Optional[bool] = None
    gather_mode: str = "auto"
    report_gap: bool = False
    m_tile: int = 512
    interpret: Optional[bool] = None
    dist: Optional[DistSpec] = None
    step_rule: str = "classic"
    active_set_size: int = 32
    lazy_cache: int = 16
    telemetry: Optional[TelemetrySpec] = None

    def __post_init__(self):
        # fail at construction with the valid vocabulary, not deep in
        # backend dispatch with a KeyError-shaped stack
        if self.backend not in VALID_BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; valid choices: "
                f"{', '.join(VALID_BACKENDS)}"
            )
        if self.step_rule not in VALID_STEP_RULES:
            raise ValueError(
                f"unknown step_rule {self.step_rule!r}; valid choices: "
                f"{', '.join(VALID_STEP_RULES)}"
            )


@dataclass(frozen=True)
class CDConfig:
    """Cyclic / stochastic coordinate descent (penalized form, Glmnet-style)."""

    lam: float
    max_sweeps: int = 1000
    tol: float = 1e-3
    stochastic: bool = False


@dataclass(frozen=True)
class FISTAConfig:
    """FISTA on the penalized form; 'constrained' switches to l1-ball projection."""

    lam: float = 0.0
    delta: float = 0.0
    constrained: bool = False
    max_iters: int = 2000
    tol: float = 1e-3
    power_iters: int = 50  # Lipschitz estimation
