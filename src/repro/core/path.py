"""Regularization-path drivers (paper §5 protocol), oracle-generic.

Protocol reproduced from the paper:
  * 100-point grid in log scale;
  * penalized solvers (CD/SCD/FISTA-reg) sweep lam_max -> lam_min with
    lam_max = ||X^T y||_inf (the null-solution threshold) and
    lam_min = lam_max / 100, warm-starting each problem from the previous;
  * constrained solvers (FW, projected accelerated gradient) sweep
    delta_min -> delta_max with delta_max = ||alpha(lam_min)||_1 (taken from
    a high-precision CD solve, as the paper does to give every solver the
    same "sparsity budget") and delta_min = delta_max / 100;
  * FW warm start uses the paper's rescaling heuristic: the previous
    solution is scaled so its l1 norm equals the next delta (the solution
    is known to lie on the boundary when delta < ||alpha_LS||_1).

Both FW drivers take an optional problem ``oracle`` (DESIGN.md §Engine;
default lasso), so the same path protocol — including the batched
multi-delta lane driver with converged-lane pruning — serves the whole
solver family (lasso / logistic / elastic-net) on every backend.
``FWConfig.step_rule`` (DESIGN.md §StepRule) rides through both drivers
unchanged: the rule's extra state is part of ``EngineState.rule``, so
warm starts re-init it per grid point and the batched lanes carry it
per lane; non-classic rules simply run the path per-step
(``vertex.fused_supported`` gates ``fuse_steps`` off with one warning).
"""
from __future__ import annotations

import time
from typing import Callable, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, engine, fw_lasso
from repro.core.solver_config import CDConfig, FISTAConfig, FWConfig
from repro.obs import metrics as obs_metrics
from repro.obs import monitor as obs_monitor
from repro.obs import trace as obs_trace
from repro.resilience import checkpoint as path_ckpt
from repro.resilience import faults as _faults
from repro.sparse import ops as sparse_ops
from repro.sparse.matrix import SparseBlockMatrix


class PathPoint(NamedTuple):
    reg: float  # lam or delta
    objective: float  # the oracle's objective at this grid point
    l1: float
    active: int
    iterations: int
    n_dots: int
    seconds: float
    alpha_nnz_idx: np.ndarray
    alpha_nnz_val: np.ndarray
    # certified FW duality gap (oracle gap(), FWConfig.report_gap); NaN off
    gap: float = float("nan")


class PathResult(NamedTuple):
    points: List[PathPoint]
    total_seconds: float
    total_dots: int
    total_iters: int
    # lane-iterations pruned by the batched driver's per-lane early exit
    # (0 for the sequential drivers)
    saved_iters: int = 0

    @property
    def mean_active(self) -> float:
        return float(np.mean([pt.active for pt in self.points]))


def _xty(Xt, y):
    """X^T y for either matrix layout (both path drivers accept a dense
    feature-major array OR a SparseBlockMatrix)."""
    if isinstance(Xt, SparseBlockMatrix):
        return sparse_ops.sparse_transpose_matvec(Xt, y)
    return Xt @ y


def lambda_grid(Xt, y, n_points: int = 100, ratio: float = 100.0) -> np.ndarray:
    """Glmnet-style grid: lam_max = ||X^T y||_inf, descending log scale."""
    lam_max = float(jnp.max(jnp.abs(_xty(Xt, y))))
    lam_min = lam_max / ratio
    return np.geomspace(lam_max, lam_min, n_points)


def delta_grid(delta_max: float, n_points: int = 100, ratio: float = 100.0) -> np.ndarray:
    """Constrained-form grid: delta_min -> delta_max, ascending log scale."""
    return np.geomspace(delta_max / ratio, delta_max, n_points)


def _sparsify(alpha: jax.Array):
    a = np.asarray(alpha)
    idx = np.nonzero(a)[0]
    return idx, a[idx]


def _point_gap(gap, lane=None) -> float:
    """PathPoint.gap from SolveResult.gap (None when report_gap is off)."""
    if gap is None:
        return float("nan")
    return float(gap if lane is None else gap[lane])


def _observe_point(reg, driver: str, cfg: FWConfig, seconds: float) -> None:
    """Per-grid-point latency into the metrics plane (no-op when the
    registry is None — the metrics-off default)."""
    if reg is None:
        return
    reg.histogram(
        "fw_path_point_seconds",
        "wall time per regularization-path grid point (batched lanes "
        "amortize their chunk dispatch)",
        ("driver", "backend"),
    ).observe(seconds, driver=driver, backend=cfg.backend)


def _finish_path(reg, tracer) -> None:
    """End-of-path bridge: fold the tracer's spans/counters accumulated
    during this path (incl. the distributed backend's trace-time
    collective counters) into the registry."""
    if reg is not None:
        obs_metrics.tracer_to_registry(tracer, reg)


def fw_path(
    Xt,
    y,
    deltas: np.ndarray,
    base_cfg: FWConfig,
    seed: int = 0,
    oracle=None,
    *,
    solve_fn=None,
    checkpoint_dir=None,
    checkpoint_every: int = 1,
    resume_from=None,
) -> PathResult:
    """Stochastic-FW path with the paper's l1-rescaling warm start.

    ``oracle`` selects the objective (default ``fw_lasso.LASSO``; pass
    ``fw_logistic.LOGISTIC`` or an ``ENOracle(l2)`` for the extensions).
    ``solve_fn`` overrides the engine entry point — the distributed
    driver injects its shard_map solver here so the SAME path protocol
    (and ``PathPoint.gap`` certification when ``cfg.report_gap``) runs on
    a mesh. Signature: ``solve_fn(oracle, Xt, y, cfg, key, alpha0,
    delta) -> SolveResult``.

    Checkpoint/resume (DESIGN.md §Resilience): with ``checkpoint_dir``
    set, the loop state (completed points, post-split PRNG key, warm
    start) snapshots atomically every ``checkpoint_every`` grid points;
    ``resume_from=<dir>`` restores the newest valid snapshot and replays
    ONLY the remaining points — bit-identical to the uninterrupted run
    (each point's index stream is a pure function of the key at its
    boundary and the carried alpha).
    """
    oracle = fw_lasso.LASSO if oracle is None else oracle
    if solve_fn is None:
        solve_fn = lambda o, X, yv, c, k, a0, d: engine.solve(
            o, X, yv, c, k, a0, delta=d
        )
    key = jax.random.PRNGKey(seed)
    alpha = None
    points = []
    start = 0
    if resume_from is not None:
        loaded = path_ckpt.load_path_checkpoint(resume_from)
        if loaded is not None:
            start, key, alpha, points, _ = loaded
    tracer = obs_trace.get_tracer()
    reg = obs_metrics.get_registry()
    mon = obs_monitor.StepMonitor()
    t_total = time.perf_counter()
    total_dots = sum(pt.n_dots for pt in points)
    total_iters = sum(pt.iterations for pt in points)
    n = len(deltas)
    cfg = base_cfg  # delta passes as a traced arg: ONE compile per path
    with tracer.span("fw_path", cat="path", n_points=n,
                     backend=cfg.backend, rule=cfg.step_rule):
        for g in range(start, n):
            d = deltas[g]
            _faults.check_kill("path_point", g)
            if alpha is not None:
                l1 = float(jnp.sum(jnp.abs(alpha)))
                if l1 > 1e-12:
                    alpha = alpha * (float(d) / l1)  # paper's rescaling heuristic
            key, sub = jax.random.split(key)
            mon.begin()
            t0 = time.perf_counter()
            with tracer.span("fw_path/point", cat="path", delta=float(d)):
                res = solve_fn(oracle, Xt, y, cfg, sub, alpha, float(d))
                res.alpha.block_until_ready()
            dt = time.perf_counter() - t0
            # the first grid point pays the path's one compile; EWMA
            # straggler detection flags anything else that stalls
            if mon.end() and mon.step > 1:
                tracer.instant("fw_path/straggler_point", cat="path",
                               point=mon.step, seconds=dt)
            _observe_point(reg, "sequential", cfg, dt)
            alpha = res.alpha
            idx, val = _sparsify(alpha)
            points.append(
                PathPoint(
                    reg=float(d),
                    objective=float(res.objective),
                    l1=float(jnp.sum(jnp.abs(alpha))),
                    active=int(res.active),
                    iterations=int(res.iterations),
                    n_dots=int(res.n_dots),
                    seconds=dt,
                    alpha_nnz_idx=idx,
                    alpha_nnz_val=val,
                    gap=_point_gap(res.gap),
                )
            )
            total_dots += int(res.n_dots)
            total_iters += int(res.iterations)
            if checkpoint_dir is not None and (
                (g + 1) % checkpoint_every == 0 or g == n - 1
            ):
                path_ckpt.save_path_checkpoint(
                    checkpoint_dir, g + 1, key, alpha, points
                )
    _finish_path(reg, tracer)
    return PathResult(points, time.perf_counter() - t_total, total_dots, total_iters)


def batched_solver_cache_size() -> int:
    """Distinct compilations of the batched lane solver (see tests)."""
    return engine.solve_batched._cache_size()


def clear_batched_solver_cache() -> None:
    engine.solve_batched.clear_cache()


def fw_path_batched(
    Xt,
    y,
    deltas: np.ndarray,
    base_cfg: FWConfig,
    seed: int = 0,
    lane_width: Optional[int] = None,
    oracle=None,
    *,
    solve_batched_fn=None,
    checkpoint_dir=None,
    checkpoint_every: int = 1,
    resume_from=None,
) -> PathResult:
    """Stochastic-FW path solved in parallel delta lanes (DESIGN.md §Path).

    The ascending delta grid is cut into chunks of ``lane_width`` deltas;
    each chunk is solved by ONE invocation of the batched engine loop, so
    a 100-point grid runs as ~8 batched solves instead of 100 sequential
    ones. Warm start keeps the paper's rescaling heuristic per lane: every
    lane starts from the previous chunk's densest solution scaled so its l1
    norm equals the lane's delta. The final (ragged) chunk is padded by
    repeating the last delta so every chunk shares one compiled program.
    Lanes that converge early are frozen by the engine's masked update;
    the skipped lane-iterations are summed into ``PathResult.saved_iters``.
    ``solve_batched_fn`` overrides ``engine.solve_batched`` (same
    signature) — the distributed driver's injection point.

    Checkpoint/resume works at lane-chunk granularity:
    ``checkpoint_every`` counts CHUNKS here, and ``resume_from=``
    replays only the remaining chunks bit-identically (the per-chunk key
    split and densest-solution carry fully determine the continuation).
    """
    oracle = fw_lasso.LASSO if oracle is None else oracle
    if solve_batched_fn is None:
        solve_batched_fn = engine.solve_batched
    deltas = np.asarray(deltas, dtype=np.float64)
    n = len(deltas)
    if lane_width is None:
        lane_width = max(1, -(-n // 8))  # ~8 sequential batched solves
    n_chunks = -(-n // lane_width)
    pad = n_chunks * lane_width - n
    padded = np.concatenate([deltas, np.repeat(deltas[-1:], pad)])

    key = jax.random.PRNGKey(seed)
    p = Xt.shape[0]
    carry = jnp.zeros((p,), Xt.dtype)  # densest solution seen so far
    points: List[Optional[PathPoint]] = [None] * n
    start_chunk = 0
    total_saved = 0
    if resume_from is not None:
        loaded = path_ckpt.load_path_checkpoint(resume_from)
        if loaded is not None:
            start_chunk, key, carry, done_points, total_saved = loaded
            for i, pt in enumerate(done_points):
                points[i] = pt
    tracer = obs_trace.get_tracer()
    reg = obs_metrics.get_registry()
    lanes_mon = obs_monitor.LaneProgressMonitor(max_iters=base_cfg.max_iters)
    t_total = time.perf_counter()
    total_dots = sum(pt.n_dots for pt in points if pt is not None)
    total_iters = sum(pt.iterations for pt in points if pt is not None)
    with tracer.span("fw_path_batched", cat="path", n_points=n,
                     lane_width=lane_width, n_chunks=n_chunks,
                     backend=base_cfg.backend):
        for c in range(start_chunk, n_chunks):
            _faults.check_kill("path_chunk", c)
            chunk = padded[c * lane_width : (c + 1) * lane_width]
            d_arr = jnp.asarray(chunk, Xt.dtype)
            l1 = jnp.sum(jnp.abs(carry))
            # per-lane rescaling warm start; carry == 0 (first chunk) stays 0
            alpha0s = carry[None, :] * (d_arr / jnp.maximum(l1, 1e-12))[:, None]
            key, *subs = jax.random.split(key, lane_width + 1)
            lanes_mon.begin_chunk()
            t0 = time.perf_counter()
            with tracer.span("fw_path_batched/chunk", cat="path", chunk=c):
                res, _ = solve_batched_fn(
                    oracle, Xt, y, base_cfg, jnp.stack(subs), alpha0s, d_arr
                )
                res.alpha.block_until_ready()
            dt = time.perf_counter() - t0
            carry = res.alpha[-1]
            alphas = np.asarray(res.alpha)
            real_lanes = min(lane_width, n - c * lane_width)  # ragged final chunk
            # pruning win for the REAL lanes only: iterations each was spared
            # while the chunk's while_loop kept running for slower lanes (the
            # engine's own count would also include the phantom padded lanes)
            iters = np.asarray(res.iterations)
            chunk_saved = int(np.sum(iters.max() - iters[:real_lanes]))
            total_saved += chunk_saved
            conv = np.asarray(res.converged)[:real_lanes]
            lanes_mon.end_chunk(
                c, chunk[:real_lanes], iters[:real_lanes], chunk_saved, conv
            )
            if reg is not None:
                lbl = dict(backend=base_cfg.backend)
                reg.counter(
                    "fw_lanes_admitted",
                    "delta lanes admitted to batched path chunks",
                    ("backend",),
                ).inc(real_lanes, **lbl)
                reg.counter(
                    "fw_lane_freezes",
                    "lanes frozen by per-lane early exit (converged before "
                    "the chunk's while_loop drained)",
                    ("backend",),
                ).inc(int(conv.sum()), **lbl)
                reg.counter(
                    "fw_lane_saved_iterations",
                    "lane-iterations pruned vs running every lane to the "
                    "slowest lane's stop",
                    ("backend",),
                ).inc(chunk_saved, **lbl)
                reg.histogram(
                    "fw_path_chunk_seconds",
                    "wall time per batched lane-chunk dispatch",
                    ("backend",),
                ).observe(dt, **lbl)
                # one latency sample per REAL grid point (amortized over
                # the chunk dispatch) — sample counts line up with the
                # sequential driver's, so the two are comparable
                for _ in range(real_lanes):
                    _observe_point(reg, "batched", base_cfg, dt / real_lanes)
            for i in range(real_lanes):
                g = c * lane_width + i
                idx, val = _sparsify(alphas[i])
                points[g] = PathPoint(
                    reg=float(chunk[i]),
                    objective=float(res.objective[i]),
                    l1=float(np.sum(np.abs(alphas[i]))),
                    active=int(res.active[i]),
                    iterations=int(res.iterations[i]),
                    n_dots=int(res.n_dots[i]),
                    seconds=dt / real_lanes,
                    alpha_nnz_idx=idx,
                    alpha_nnz_val=val,
                    gap=_point_gap(res.gap, i),
                )
                total_dots += int(res.n_dots[i])
                total_iters += int(res.iterations[i])
            if checkpoint_dir is not None and (
                (c + 1) % checkpoint_every == 0 or c == n_chunks - 1
            ):
                n_done = min((c + 1) * lane_width, n)
                path_ckpt.save_path_checkpoint(
                    checkpoint_dir, c + 1, key, carry, points[:n_done],
                    saved_iters=total_saved,
                )
    _finish_path(reg, tracer)
    return PathResult(
        points,
        time.perf_counter() - t_total,
        total_dots,
        total_iters,
        saved_iters=total_saved,
    )


def _penalized_path(solve_fn, Xt, y, lams, seed: int) -> PathResult:
    key = jax.random.PRNGKey(seed)
    alpha = None
    points = []
    t_total = time.perf_counter()
    total_dots = 0
    total_iters = 0
    for lam in lams:
        key, sub = jax.random.split(key)
        t0 = time.perf_counter()
        res = solve_fn(Xt, y, float(lam), sub, alpha)
        res.alpha.block_until_ready()
        dt = time.perf_counter() - t0
        alpha = res.alpha
        idx, val = _sparsify(alpha)
        points.append(
            PathPoint(
                reg=float(lam),
                objective=float(res.objective),
                l1=float(jnp.sum(jnp.abs(alpha))),
                active=int(res.active),
                iterations=int(res.iterations),
                n_dots=int(res.n_dots),
                seconds=dt,
                alpha_nnz_idx=idx,
                alpha_nnz_val=val,
            )
        )
        total_dots += int(res.n_dots)
        total_iters += int(res.iterations)
    return PathResult(points, time.perf_counter() - t_total, total_dots, total_iters)


def cd_path(Xt, y, lams, base_cfg: CDConfig, seed: int = 0) -> PathResult:
    def solve(Xt, y, lam, key, alpha0):
        return baselines.cd_solve(Xt, y, base_cfg, key, alpha0, lam=lam)

    return _penalized_path(solve, Xt, y, lams, seed)


def fista_path(Xt, y, regs, base_cfg: FISTAConfig, seed: int = 0) -> PathResult:
    def solve(Xt, y, reg, key, alpha0):
        return baselines.fista_solve(Xt, y, base_cfg, key, alpha0, reg=reg)

    # constrained sweeps ascending (sparse -> dense), penalized descending.
    return _penalized_path(solve, Xt, y, regs, seed)
