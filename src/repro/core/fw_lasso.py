"""Stochastic Frank-Wolfe for the constrained Lasso (paper Algorithm 2).

Implements the randomized FW iteration of Frandi et al. (2015):

    min_alpha f(alpha) = 1/2 ||X alpha - y||^2   s.t.  ||alpha||_1 <= delta

Key paper mechanics reproduced faithfully:
  * method of residuals (eq. 7): sampled gradient coords are -z_i^T R,
  * closed-form exact line search (eq. 8) with the S/F scalar recursions,
  * residual update (eq. 10),
  * uniform random coordinate sampling (Lemma 1 / Prop. 2),
  * per-iteration cost O(kappa * m), independent of p.

Implementation notes (beyond the paper, recorded in DESIGN.md):
  * the design matrix is stored FEATURE-MAJOR: ``Xt`` has shape (p, m), so
    one predictor z_i = Xt[i] is a contiguous row and the sampled-gradient
    gather touches kappa contiguous stripes (this is also the layout the
    TPU kernel tiles over);
  * the iterate is stored as ``alpha = scale * beta`` so the (1-lambda)
    shrink of every coordinate is O(1) instead of O(p);
  * block sampling (contiguous aligned blocks of coordinates) is provided
    as the TPU-native sampling mode — Lemma 1 only needs P(i in S) = kappa/p,
    which uniform aligned-block sampling preserves when bs | p;
  * a running upper bound on ||alpha||_inf gives the paper's
    ||alpha^{k+1} - alpha^k||_inf <= eps stopping rule without O(p) work.
    Because a sampled iteration can legitimately produce lambda = 0 (the
    sample contained no descent vertex), the rule only fires after
    ``patience`` consecutive sub-tolerance steps. A step whose sampled
    duality gap sits below the fp32 noise floor of its own terms also
    counts as a stall (``gap_rtol``, DESIGN.md §Stopping) so warm starts
    from a converged iterate terminate immediately;
  * ``cfg.backend`` selects the iteration engine: 'xla' (jnp gathers),
    'pallas' (the fused TPU kernels under repro.kernels; interpret mode
    off-TPU), with zero-padded feature tails for non-divisible shapes, or
    'sparse' (``Xt`` is a repro.sparse.SparseBlockMatrix; the sampled
    gradient, residual update, and colstats all run over the block-ELL
    slots — O(kappa * nnz_max) per step instead of O(kappa * m)).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.solver_config import FWConfig
from repro.kernels.colstats.colstats import colstats as _colstats_kernel
from repro.kernels.fw_grad.ops import fw_vertex as _fw_vertex_kernel
from repro.kernels.padding import pad_rows as _pad_features
from repro.kernels.residual_update.residual_update import (
    residual_update as _residual_update_kernel,
)
from repro.sparse import ops as sparse_ops
from repro.sparse.matrix import SparseBlockMatrix


def _use_interpret(cfg: FWConfig) -> bool:
    """Pallas kernels compile natively on TPU, interpret everywhere else."""
    if cfg.interpret is not None:
        return cfg.interpret
    return jax.default_backend() != "tpu"


def _use_sparse_kernel(cfg: FWConfig) -> bool:
    """'sparse' backend: Pallas prefetch kernel on TPU, XLA gather elsewhere
    (the XLA path is the production CPU path, not a test stub)."""
    if cfg.sparse_kernel is not None:
        return cfg.sparse_kernel
    return jax.default_backend() == "tpu"


def _check_matrix_backend(Xt, cfg: FWConfig) -> None:
    """Trace-time guard: the matrix layout and the backend must agree."""
    is_sparse = isinstance(Xt, SparseBlockMatrix)
    if is_sparse and cfg.backend != "sparse":
        raise ValueError(
            f"Xt is a SparseBlockMatrix but cfg.backend={cfg.backend!r}; "
            "use FWConfig(backend='sparse')"
        )
    if cfg.backend == "sparse" and not is_sparse:
        raise ValueError(
            "cfg.backend='sparse' needs a repro.sparse.SparseBlockMatrix "
            "design matrix (build one with SparseBlockMatrix.from_dense / "
            "from_coo or repro.data.make_sparse_proxy)"
        )


class ColStats(NamedTuple):
    """Per-column statistics precomputed once before the iterations (§4.2)."""

    zty: jax.Array  # (p,)  z_i^T y
    znorm2: jax.Array  # (p,)  ||z_i||^2
    yty: jax.Array  # ()    y^T y


class FWState(NamedTuple):
    """Loop state. ``alpha = scale * beta`` (scaled representation)."""

    beta: jax.Array  # (p,) unscaled coefficients
    scale: jax.Array  # ()  multiplicative scale
    resid: jax.Array  # (m,) R = y - X alpha
    s_quad: jax.Array  # ()  S^k = ||X alpha||^2
    f_lin: jax.Array  # ()  F^k = (X alpha)^T y
    maxabs: jax.Array  # ()  running upper bound on ||alpha||_inf
    step_inf: jax.Array  # ()  ||alpha^{k+1} - alpha^k||_inf (bound)
    stall: jax.Array  # ()  consecutive sub-tolerance steps
    n_dots: jax.Array  # ()  length-m dot products consumed so far
    k: jax.Array  # ()  iteration counter
    key: jax.Array  # PRNG key


class FWResult(NamedTuple):
    alpha: jax.Array
    objective: jax.Array
    iterations: jax.Array
    n_dots: jax.Array
    active: jax.Array  # () number of nonzero coefficients
    converged: jax.Array


def precompute_colstats(
    Xt: jax.Array, y: jax.Array, cfg: Optional[FWConfig] = None
) -> ColStats:
    """One full pass over X: z_i^T y and ||z_i||^2 for every column (§4.2).

    With ``cfg.backend == 'pallas'`` the fused single-sweep kernel
    (repro.kernels.colstats) computes both statistics in one HBM pass.
    A SparseBlockMatrix sweeps its stored slots only — O(nnz), not O(p*m).
    """
    if isinstance(Xt, SparseBlockMatrix):
        zty, znorm2 = sparse_ops.sparse_colstats(Xt, y)
        return ColStats(zty=zty, znorm2=znorm2, yty=jnp.dot(y, y))
    if cfg is not None and cfg.backend == "pallas":
        zty, znorm2 = _colstats_kernel(
            Xt, y, m_tile=cfg.m_tile, interpret=_use_interpret(cfg)
        )
    else:
        zty = Xt @ y
        znorm2 = jnp.sum(Xt * Xt, axis=1)
    return ColStats(zty=zty, znorm2=znorm2, yty=jnp.dot(y, y))


def init_state(
    Xt: jax.Array,
    y: jax.Array,
    key: jax.Array,
    alpha0: Optional[jax.Array] = None,
) -> FWState:
    """Start from the null solution, or warm-start from ``alpha0``."""
    p = Xt.shape[0]
    if alpha0 is None:
        beta = jnp.zeros((p,), Xt.dtype)
        resid = y.astype(Xt.dtype)
        s_quad = jnp.zeros((), Xt.dtype)
        f_lin = jnp.zeros((), Xt.dtype)
        maxabs = jnp.zeros((), Xt.dtype)
    else:
        beta = alpha0.astype(Xt.dtype)
        if isinstance(Xt, SparseBlockMatrix):
            v = sparse_ops.sparse_matvec(Xt, beta)  # X alpha, O(nnz)
        else:
            v = beta @ Xt  # X alpha
        resid = y - v
        s_quad = jnp.dot(v, v)
        f_lin = jnp.dot(v, y)
        maxabs = jnp.max(jnp.abs(beta))
    return FWState(
        beta=beta,
        scale=jnp.ones((), Xt.dtype),
        resid=resid,
        s_quad=s_quad,
        f_lin=f_lin,
        maxabs=maxabs,
        step_inf=jnp.full((), jnp.inf, Xt.dtype),
        stall=jnp.zeros((), jnp.int32),
        n_dots=jnp.zeros((), jnp.int32),
        k=jnp.zeros((), jnp.int32),
        key=key,
    )


def _sample_block_starts(key: jax.Array, p: int, cfg: FWConfig) -> jax.Array:
    """Aligned block starts for 'block' sampling, clamped so the number of
    requested blocks never exceeds the number of available blocks (choice
    without replacement would otherwise error for kappa//bs > ceil(p/bs))."""
    bs = cfg.block_size
    total = -(-p // bs)  # ceil
    nblocks = min(max(cfg.kappa // bs, 1), total)
    return jax.random.choice(key, total, (nblocks,), replace=False).astype(jnp.int32)


def _sample_indices(key: jax.Array, p: int, cfg: FWConfig) -> jax.Array:
    """Draw the sampling set S (paper §4.1 / §4.5).

    'uniform': kappa i.i.d. uniform draws (with replacement — O(kappa), the
       large-p-friendly reading of the paper's uniform kappa-subsets).
    'block':   kappa/block aligned blocks without replacement (TPU-native).
    'full':    deterministic FW (S = {1..p}).
    """
    if cfg.sampling == "full":
        return jnp.arange(p)
    if cfg.sampling == "uniform":
        return jax.random.randint(key, (cfg.kappa,), 0, p)
    if cfg.sampling == "block":
        starts = _sample_block_starts(key, p, cfg)
        idx = starts[:, None] * cfg.block_size + jnp.arange(cfg.block_size)[None, :]
        return idx.reshape(-1) % p  # tail block wraps (documented in DESIGN.md)
    raise ValueError(f"unknown sampling mode {cfg.sampling!r}")


def _kernel_vertex(
    Xt: jax.Array, resid: jax.Array, key: jax.Array, p: int, cfg: FWConfig
):
    """Sampled FW vertex via the Pallas scalar-prefetch gather kernel.

    'block'/'full' drive block_size-wide aligned bricks; 'uniform' degrades
    to width-1 blocks (same index stream as the XLA gather path). Returns
    (i_star, g_star, n_scored). ``Xt`` may carry zero-padded trailing rows
    (p_valid masks them out of the argmax).
    """
    if cfg.sampling == "uniform":
        # same draw as the XLA path: the backends replay one index stream
        blk = _sample_indices(key, p, cfg).astype(jnp.int32)
        bs = 1
    elif cfg.sampling == "block":
        blk = _sample_block_starts(key, p, cfg)
        bs = cfg.block_size
    elif cfg.sampling == "full":
        bs = cfg.block_size
        blk = jnp.arange(-(-p // bs), dtype=jnp.int32)
    else:
        raise ValueError(f"unknown sampling mode {cfg.sampling!r}")
    i_star, g_star = _fw_vertex_kernel(
        Xt,
        resid,
        blk,
        block_size=bs,
        m_tile=cfg.m_tile,
        interpret=_use_interpret(cfg),
        p_valid=p,
    )
    # dot-product accounting parity with the XLA path: 'full' scores every
    # REAL coordinate once (padded rows are free zeros, not sampled work);
    # 'block' counts nblocks*bs either way (the XLA path's wrapped tail
    # duplicates coords just as the kernel path's tail pads them).
    n_scored = p if cfg.sampling == "full" else blk.shape[0] * bs
    return i_star, g_star, n_scored


def _sample_sparse_blocks(key: jax.Array, mat: SparseBlockMatrix, cfg: FWConfig):
    """Aligned block starts for the sparse backend. Block geometry comes
    from the MATRIX (cfg.block_size is a dense-kernel knob); the requested
    count is clamped to the available blocks like _sample_block_starts."""
    nblocks = min(max(cfg.kappa // mat.block_size, 1), mat.nblocks)
    return jax.random.choice(key, mat.nblocks, (nblocks,), replace=False).astype(
        jnp.int32
    )


def _sparse_vertex(
    mat: SparseBlockMatrix, resid: jax.Array, key: jax.Array, cfg: FWConfig
):
    """Sampled FW vertex over the block-ELL matrix.

    'block'/'full' drive whole aligned blocks (kernel-dispatchable, the
    tail block is zero-padded at construction — no modulo wrap, so exact
    Lemma 1 uniformity holds for every p); 'uniform' is a width-1 XLA
    gather replaying the exact index stream of the dense XLA path.
    Returns (i_star, g_star, n_scored).
    """
    if cfg.sampling == "uniform":
        idx = _sample_indices(key, mat.p, cfg)
        i_star, g_star = sparse_ops.sparse_gather_vertex(mat, resid, idx)
        return i_star, g_star, idx.shape[0]
    if cfg.sampling == "block":
        blk = _sample_sparse_blocks(key, mat, cfg)
        n_scored = blk.shape[0] * mat.block_size
    elif cfg.sampling == "full":
        blk = jnp.arange(mat.nblocks, dtype=jnp.int32)
        n_scored = mat.p
    else:
        raise ValueError(f"unknown sampling mode {cfg.sampling!r}")
    i_star, g_star = sparse_ops.sparse_fw_vertex(
        mat,
        resid,
        blk,
        use_kernel=_use_sparse_kernel(cfg),
        interpret=_use_interpret(cfg),
    )
    return i_star, g_star, n_scored


def fw_step(
    Xt: jax.Array,
    y: jax.Array,
    stats: ColStats,
    state: FWState,
    cfg: FWConfig,
    delta=None,
) -> FWState:
    """One randomized Frank-Wolfe step (paper Algorithm 2).

    ``delta`` may be a traced array: the l1 radius enters the math only
    through scalar formulas, so keeping it dynamic lets a whole
    regularization path reuse ONE compiled solver (§Perf).

    ``Xt`` may be feature-padded (``_pad_features``) when
    ``cfg.backend == 'pallas'``; all other state stays at the true p,
    which is read off ``stats``.
    """
    p = stats.zty.shape[0]
    delta = cfg.delta if delta is None else delta
    key, sub = jax.random.split(state.key)

    # -- step 2: method of residuals on the sampled coordinates (eq. 7) ----
    if cfg.backend == "sparse":
        i_star, g_star, n_scored = _sparse_vertex(Xt, state.resid, sub, cfg)
    elif cfg.backend == "pallas":
        i_star, g_star, n_scored = _kernel_vertex(Xt, state.resid, sub, p, cfg)
    else:
        idx = _sample_indices(sub, p, cfg)
        rows = jnp.take(Xt, idx, axis=0)  # (|S|, m) contiguous row gather
        grad_s = -(rows @ state.resid)  # (|S|,)
        j = jnp.argmax(jnp.abs(grad_s))
        i_star = idx[j]
        g_star = grad_s[j]
        n_scored = idx.shape[0]

    # -- step 3: FW vertex sign (eq. 6) -------------------------------------
    delta_t = -delta * jnp.sign(g_star)  # delta-tilde

    # -- step 4: closed-form exact line search (eq. 8) ----------------------
    g_lin = g_star + stats.zty[i_star]  # G_{i*} = z_{i*}^T (X alpha)
    num = state.s_quad - delta_t * g_star - state.f_lin
    den = state.s_quad - 2.0 * delta_t * g_lin + delta_t**2 * stats.znorm2[i_star]
    lam = jnp.clip(num / jnp.maximum(den, cfg.eps_den), 0.0, 1.0)

    # -- step 5: coefficient update in scaled representation ---------------
    one_m = 1.0 - lam
    alpha_istar_old = state.scale * state.beta[i_star]
    new_scale = state.scale * one_m
    # renormalize when the scale underflows (rare O(p) event)
    need_renorm = new_scale < cfg.renorm_threshold
    beta, scale = jax.lax.cond(
        need_renorm,
        lambda b, s: (b * s, jnp.ones((), Xt.dtype)),
        lambda b, s: (b, s),
        state.beta,
        new_scale,
    )
    beta = beta.at[i_star].add(delta_t * lam / jnp.maximum(scale, cfg.eps_den))

    # -- step 6: residual update (eq. 10) -----------------------------------
    if cfg.backend == "sparse":
        col_vals, col_rows = sparse_ops.sparse_column(Xt, i_star)
        resid = sparse_ops.sparse_residual_update(
            state.resid, y, col_vals, col_rows, lam, delta_t
        )
    elif cfg.backend == "pallas":
        z_star = jax.lax.dynamic_slice_in_dim(Xt, i_star, 1, axis=0)[0]
        resid = _residual_update_kernel(
            state.resid, y, z_star, lam, delta_t,
            m_tile=cfg.m_tile, interpret=_use_interpret(cfg),
        )
    else:
        z_star = jax.lax.dynamic_slice_in_dim(Xt, i_star, 1, axis=0)[0]
        resid = one_m * state.resid + lam * (y - delta_t * z_star)

    # -- S/F scalar recursions (paper, below eq. 8) --------------------------
    s_quad = (
        one_m**2 * state.s_quad
        + 2.0 * delta_t * lam * one_m * g_lin
        + delta_t**2 * lam**2 * stats.znorm2[i_star]
    )
    f_lin = one_m * state.f_lin + delta_t * lam * stats.zty[i_star]

    # fp32-drift control: periodically recompute S/F exactly from the
    # residual (v = y - R), an O(m) refresh — see DESIGN.md.
    refresh = (state.k % cfg.refresh_every) == (cfg.refresh_every - 1)
    v = y - resid
    s_quad = jnp.where(refresh, jnp.dot(v, v), s_quad)
    f_lin = jnp.where(refresh, jnp.dot(v, y), f_lin)

    # -- stopping statistic: ||alpha_{k+1} - alpha_k||_inf upper bound ------
    alpha_istar_new = scale * beta[i_star]
    step_inf = lam * jnp.maximum(state.maxabs, jnp.abs(delta_t - alpha_istar_old))
    maxabs = jnp.maximum(one_m * state.maxabs, jnp.abs(alpha_istar_new))
    # ``num`` is the sampled FW duality gap g_S = alpha^T grad + delta |g*|
    # (exact gap under full sampling). A step whose gap is below the fp32
    # rounding floor of its own terms cannot make real progress, but its
    # micro step can still exceed ``tol`` through the maxabs-inflated bound
    # above — warm starts from a converged iterate would otherwise
    # micro-oscillate for many iterations (DESIGN.md §Stopping).
    gap_scale = state.s_quad + jnp.abs(state.f_lin) + jnp.abs(delta_t * g_star)
    no_progress = num <= cfg.gap_rtol * gap_scale
    stall = jnp.where((step_inf <= cfg.tol) | no_progress, state.stall + 1, 0)

    return FWState(
        beta=beta,
        scale=scale,
        resid=resid,
        s_quad=s_quad,
        f_lin=f_lin,
        maxabs=maxabs,
        step_inf=step_inf,
        stall=stall,
        n_dots=state.n_dots + n_scored,
        k=state.k + 1,
        key=key,
    )


def objective(stats: ColStats, state: FWState) -> jax.Array:
    """f(alpha^k) = 1/2 y^T y + 1/2 S^k - F^k (paper eq. 8 block)."""
    return 0.5 * stats.yty + 0.5 * state.s_quad - state.f_lin


def duality_gap(Xt: jax.Array, state: FWState, delta: float) -> jax.Array:
    """Exact FW duality gap g(alpha) = alpha^T grad + delta*||grad||_inf.

    O(m p) dense, O(nnz) sparse — certification / tests, not the hot loop.
    """
    alpha = state.scale * state.beta
    if isinstance(Xt, SparseBlockMatrix):
        grad = -sparse_ops.sparse_transpose_matvec(Xt, state.resid)
    else:
        grad = -(Xt @ state.resid)
    return jnp.dot(alpha, grad) + delta * jnp.max(jnp.abs(grad))


def _patience(cfg: FWConfig) -> int:
    return cfg.patience if cfg.sampling != "full" else 1


@functools.partial(jax.jit, static_argnames=("cfg",))
def fw_solve(
    Xt: jax.Array,
    y: jax.Array,
    cfg: FWConfig,
    key: jax.Array,
    alpha0: Optional[jax.Array] = None,
    delta=None,
) -> FWResult:
    """Run Algorithm 2 until ||alpha_{k+1}-alpha_k||_inf <= tol for
    ``patience`` consecutive iterations, or max_iters. ``delta`` (traced)
    overrides cfg.delta — one compile serves the whole path."""
    _check_matrix_backend(Xt, cfg)
    delta = jnp.asarray(cfg.delta if delta is None else delta)
    stats = precompute_colstats(Xt, y, cfg)
    state0 = init_state(Xt, y, key, alpha0)
    patience = _patience(cfg)
    if cfg.backend == "pallas" and cfg.sampling != "uniform":
        Xt = _pad_features(Xt, cfg.block_size)  # once, outside the hot loop

    def cond(state: FWState):
        return (state.k < cfg.max_iters) & (state.stall < patience)

    def body(state: FWState):
        return fw_step(Xt, y, stats, state, cfg, delta)

    final = jax.lax.while_loop(cond, body, state0)
    alpha = final.scale * final.beta
    return FWResult(
        alpha=alpha,
        objective=objective(stats, final),
        iterations=final.k,
        n_dots=final.n_dots,
        active=jnp.sum(alpha != 0.0),
        converged=final.stall >= patience,
    )


@functools.partial(jax.jit, static_argnames=("cfg", "n_iters"))
def fw_solve_with_history(
    Xt: jax.Array,
    y: jax.Array,
    cfg: FWConfig,
    key: jax.Array,
    n_iters: int,
    alpha0: Optional[jax.Array] = None,
):
    """Fixed-iteration run recording f(alpha^k) per step (convergence plots).

    Returns (result, objective_history[n_iters]).
    """
    _check_matrix_backend(Xt, cfg)
    stats = precompute_colstats(Xt, y, cfg)
    state0 = init_state(Xt, y, key, alpha0)
    if cfg.backend == "pallas" and cfg.sampling != "uniform":
        Xt = _pad_features(Xt, cfg.block_size)

    def body(state, _):
        new = fw_step(Xt, y, stats, state, cfg, jnp.asarray(cfg.delta))
        return new, objective(stats, new)

    final, hist = jax.lax.scan(body, state0, None, length=n_iters)
    alpha = final.scale * final.beta
    res = FWResult(
        alpha=alpha,
        objective=objective(stats, final),
        iterations=final.k,
        n_dots=final.n_dots,
        active=jnp.sum(alpha != 0.0),
        converged=final.stall >= _patience(cfg),
    )
    return res, hist
