"""Lasso problem oracle for the stochastic FW engine (paper Algorithm 2).

Implements the randomized FW iteration of Frandi et al. (2015):

    min_alpha f(alpha) = 1/2 ||X alpha - y||^2   s.t.  ||alpha||_1 <= delta

Key paper mechanics reproduced faithfully:
  * method of residuals (eq. 7): sampled gradient coords are -z_i^T R,
  * closed-form exact line search (eq. 8) with the S/F scalar recursions,
  * residual update (eq. 10),
  * uniform random coordinate sampling (Lemma 1 / Prop. 2),
  * per-iteration cost O(kappa * m), independent of p.

Since the engine refactor (DESIGN.md §Engine) this module holds ONLY the
lasso-specific pieces — the residual co-state, the closed-form line
search with its sampled-duality-gap stall test, and the S/F recursions —
packaged as ``LassoOracle`` for ``repro.core.engine``. The iteration
skeleton (sampling, backend dispatch over 'xla' | 'pallas' | 'sparse',
scaled-iterate update, stopping rule, loop/scan/batched drivers) lives
in ``engine.py`` + ``vertex.py`` and is shared with the logistic
and elastic-net oracles. The public API (``fw_solve``,
``fw_solve_with_history``, ``fw_step``, ``init_state``, ``FWState``) is
preserved as thin wrappers; the uniform-sampling trajectory is
bit-identical to the pre-engine solver (tests/test_engine.py pins it).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import engine, vertex
from repro.core.engine import ColStats, EngineState, precompute_colstats
from repro.core.solver_config import FWConfig

# Back-compat aliases: these helpers moved to core.vertex in the engine
# refactor; tests and downstream code keep importing them from here.
_sample_indices = vertex.sample_indices
_sample_block_starts = vertex.sample_block_starts
_use_interpret = vertex.use_interpret
_use_sparse_kernel = vertex.use_sparse_kernel
_check_matrix_backend = vertex.check_matrix_backend

FWResult = engine.SolveResult


class LassoCo(NamedTuple):
    """Lasso co-state: the residual and the paper's scalar recursions."""

    resid: jax.Array  # (m,) R = y - X alpha
    s_quad: jax.Array  # ()  S^k = ||X alpha||^2
    f_lin: jax.Array  # ()  F^k = (X alpha)^T y


def ls_closed_form(s_quad, f_lin, g_sel, g_lin, delta_t, zn2_i, eps_den, gap_rtol):
    """The closed-form exact line search (eq. 8) as pure scalar algebra —
    the kernel-composable form the fused multi-step megakernel
    (``kernels/fused_step``) executes with VMEM-resident scalars. The
    unfused ``LassoOracle.line_search`` routes through the SAME function
    so the two paths share one jaxpr for the step-size math (the fused
    bit-exactness contract, DESIGN.md §Perf). Returns
    ``(lam, no_progress, num)``; ``num`` is the sampled duality gap."""
    num = s_quad - delta_t * g_sel - f_lin
    den = s_quad - 2.0 * delta_t * g_lin + delta_t**2 * zn2_i
    lam = jnp.clip(num / jnp.maximum(den, eps_den), 0.0, 1.0)
    gap_scale = s_quad + jnp.abs(f_lin) + jnp.abs(delta_t * g_sel)
    no_progress = num <= gap_rtol * gap_scale
    return lam, no_progress, num


def sf_recursion(s_quad, f_lin, g_lin, lam, delta_t, zty_i, zn2_i):
    """The O(1) S/F scalar recursions (paper, below eq. 8) on bare
    per-coordinate statistics — shared verbatim by ``sf_update`` (the
    unfused oracles) and the fused megakernel's in-VMEM recursion."""
    one_m = 1.0 - lam
    s_quad = (
        one_m**2 * s_quad
        + 2.0 * delta_t * lam * one_m * g_lin
        + delta_t**2 * lam**2 * zn2_i
    )
    f_lin = one_m * f_lin + delta_t * lam * zty_i
    return s_quad, f_lin


def sf_update(stats, s_quad, f_lin, resid, y, i_star, lam, delta_t, g_lin, k, cfg):
    """S/F scalar recursions (paper, below eq. 8) + the periodic exact
    O(m) refresh from the residual (fp32-drift control, DESIGN.md).

    Shared by the lasso and elastic-net oracles — the elastic-net layers
    its Q recursion on top. Returns (s_quad, f_lin, refresh) so callers
    can refresh their own extra state on the same cadence. The refresh
    dots run through ``vertex.mdot`` so the recursion completes across
    the "data" mesh axis under the distributed backend.
    """
    s_quad, f_lin = sf_recursion(
        s_quad, f_lin, g_lin, lam, delta_t,
        stats.zty[i_star], stats.znorm2[i_star],
    )
    refresh = (k % cfg.refresh_every) == (cfg.refresh_every - 1)
    v = y - resid
    s_quad = jnp.where(refresh, vertex.mdot(v, v, cfg), s_quad)
    f_lin = jnp.where(refresh, vertex.mdot(v, y, cfg), f_lin)
    return s_quad, f_lin, refresh


@dataclasses.dataclass(frozen=True)
class LassoOracle:
    """Problem oracle: 1/2 ||X alpha - y||^2 over the l1 ball."""

    needs_stats = True
    extra_dots = 0
    # fused multi-step protocol (DESIGN.md §Perf): the closed-form line
    # search makes K-step chunks kernel-composable; the lasso scores need
    # no per-coordinate alpha values inside the chunk.
    fused_kind = "lasso"
    fused_needs_alpha = False

    def init_co(self, y, v, beta, dtype, cfg=None) -> LassoCo:
        if v is None:
            return LassoCo(
                resid=y.astype(dtype),
                s_quad=jnp.zeros((), dtype),
                f_lin=jnp.zeros((), dtype),
            )
        return LassoCo(
            resid=y - v,
            s_quad=vertex.mdot(v, v, cfg),
            f_lin=vertex.mdot(v, y, cfg),
        )

    def cograd(self, co: LassoCo, y):
        """Sampled scores are -z_i^T R (method of residuals, eq. 7)."""
        return co.resid

    def score_extra(self, beta, scale):
        return None

    def line_search(
        self, Xt, y, stats, co: LassoCo, i_star, g_raw, g_sel, a_star, delta_t, cfg
    ):
        """Closed-form exact line search (eq. 8).

        ``num`` is the sampled FW duality gap g_S = alpha^T grad +
        delta |g*| (exact gap under full sampling). A step whose gap is
        below the fp32 rounding floor of its own terms cannot make real
        progress, but its micro step can still exceed ``tol`` through
        the maxabs-inflated stopping bound — warm starts from a
        converged iterate would otherwise micro-oscillate for many
        iterations (``gap_rtol``, DESIGN.md §Stopping).
        """
        g_lin = g_raw + stats.zty[i_star]  # G_{i*} = z_{i*}^T (X alpha)
        lam, no_progress, _ = ls_closed_form(
            co.s_quad, co.f_lin, g_sel, g_lin, delta_t,
            stats.znorm2[i_star], cfg.eps_den, cfg.gap_rtol,
        )
        return lam, no_progress, g_lin

    def update_co(
        self, Xt, y, stats, co: LassoCo, beta, scale, i_star, a_star, lam,
        delta_t, k, cfg, aux,
    ) -> LassoCo:
        # residual update (eq. 10), backend-dispatched
        resid = vertex.apply_column_update(Xt, co.resid, y, i_star, lam, delta_t, cfg)
        s_quad, f_lin, _ = sf_update(
            stats, co.s_quad, co.f_lin, resid, y, i_star, lam, delta_t,
            aux, k, cfg,
        )
        return LassoCo(resid=resid, s_quad=s_quad, f_lin=f_lin)

    # ---- generalized direction protocol (DESIGN.md §StepRule) ----------
    # The away/pairwise step rules move along d = t*alpha + df*e_f +
    # da*e_a (classic FW is t=-1/da=0, away t=+1/df=0, pairwise t=0).
    # The line search stays closed-form: with u = df*z_f + da*z_a the
    # direction's image is X d = t*(X alpha) + u, so the quadratic
    # num/den needs only the tracked S/F scalars plus O(m) dots on u
    # (``vertex.mdot`` — distributed-correct by construction).

    def co_linpred(self, co: LassoCo, y):
        """X alpha from the co-state (O(m), no matvec)."""
        return y - co.resid

    def grad_dot_alpha(self, co: LassoCo, stats, y, beta, scale, cfg):
        """<grad, alpha> = S - F for grad = -X^T R."""
        return co.s_quad - co.f_lin

    def dir_line_search(self, y, stats, co: LassoCo, ds, u_lin, cfg):
        """Exact step along the generalized direction: minimize
        1/2 ||X(alpha + g d) - y||^2 over g in [0, g_max]. ``num`` is
        -<grad, d>, the directional FW gap (== eq. 8's numerator on the
        classic direction); the gap_rtol noise-floor stall rule carries
        over unchanged (DESIGN.md §Stopping)."""
        v = y - co.resid
        vu = vertex.mdot(v, u_lin, cfg)
        uu = vertex.mdot(u_lin, u_lin, cfg)
        ga = co.s_quad - co.f_lin
        num = -(ds.t * ga + ds.df * ds.sel_f + ds.da * ds.sel_a)
        den = ds.t**2 * co.s_quad + 2.0 * ds.t * vu + uu
        g = jnp.clip(num / jnp.maximum(den, cfg.eps_den), 0.0, ds.g_max)
        gap_scale = (
            jnp.abs(ds.t) * (co.s_quad + jnp.abs(co.f_lin))
            + jnp.abs(ds.df * ds.sel_f)
            + jnp.abs(ds.da * ds.sel_a)
        )
        no_progress = num <= cfg.gap_rtol * gap_scale
        return g, no_progress, (vu, uu)

    def dir_update_co(
        self, Xt, y, stats, co: LassoCo, beta, scale, ds, g, u_lin, k, cfg, aux
    ) -> LassoCo:
        """R' = (1+gt) R - gt y - g u and the S/F recursions for the
        generalized step, with the classic periodic exact refresh."""
        vu, uu = aux
        gt = g * ds.t
        one_gt = 1.0 + gt
        resid = one_gt * co.resid - gt * y - g * u_lin
        s_quad = one_gt**2 * co.s_quad + 2.0 * one_gt * g * vu + g**2 * uu
        f_lin = one_gt * co.f_lin + g * vertex.mdot(u_lin, y, cfg)
        refresh = (k % cfg.refresh_every) == (cfg.refresh_every - 1)
        v = y - resid
        s_quad = jnp.where(refresh, vertex.mdot(v, v, cfg), s_quad)
        f_lin = jnp.where(refresh, vertex.mdot(v, y, cfg), f_lin)
        return LassoCo(resid=resid, s_quad=s_quad, f_lin=f_lin)

    # ---- PARTAN extrapolation protocol (DESIGN.md §StepRule) -----------

    def partan_mu(self, y, stats, co: LassoCo, u_m, a_mid, dp, mu_max, cfg):
        """Closed-form extrapolation step: minimize
        1/2 ||mu u - R_mid||^2 (u = X dp) over mu in [0, mu_max]."""
        num = vertex.mdot(co.resid, u_m, cfg)
        den = vertex.mdot(u_m, u_m, cfg)
        return jnp.clip(num / jnp.maximum(den, cfg.eps_den), 0.0, mu_max)

    def partan_update_co(self, y, stats, co: LassoCo, a_new, mu, u_m, cfg):
        """R' = R_mid - mu u; S/F recomputed exactly (two O(m) dots per
        step — PARTAN is already O(p) per step, recursions buy nothing)."""
        resid = co.resid - mu * u_m
        v = y - resid
        return LassoCo(
            resid=resid,
            s_quad=vertex.mdot(v, v, cfg),
            f_lin=vertex.mdot(v, y, cfg),
        )

    # ---- fused multi-step chunk protocol (DESIGN.md §Perf) -------------
    # The megakernel (kernels/fused_step) carries the co-state as
    # (resid, (S, F, Q)) with Q unused by the lasso; the scalar algebra
    # below is the SAME jaxpr the unfused step runs, so a fused chunk
    # replays the unfused trajectory bit-identically.

    def fused_score_shift(self, alpha_i):
        """Per-coordinate selected-score shift from the live alpha value
        (None: lasso scores are purely linear)."""
        return None

    def fused_line_search(
        self, scal, g_raw, g_sel, a_star, delta_t, zty_i, zn2_i, eps_den, gap_rtol
    ):
        s_quad, f_lin, _ = scal
        g_lin = g_raw + zty_i
        lam, no_progress, _ = ls_closed_form(
            s_quad, f_lin, g_sel, g_lin, delta_t, zn2_i, eps_den, gap_rtol
        )
        return lam, no_progress, g_lin

    def fused_scalar_update(self, scal, g_lin, a_star, lam, delta_t, zty_i, zn2_i):
        """Pre-refresh recursions on the (S, F, Q) triple; the chunk
        driver applies the periodic exact S/F refresh on the unfused
        cadence from the VMEM-resident residual."""
        s_quad, f_lin = sf_recursion(
            scal[0], scal[1], g_lin, lam, delta_t, zty_i, zn2_i
        )
        return (s_quad, f_lin, scal[2])

    def fused_pack_co(self, co: LassoCo):
        return co.resid, (co.s_quad, co.f_lin, jnp.zeros_like(co.s_quad))

    def fused_unpack_co(self, resid, scal) -> LassoCo:
        d = resid.dtype
        return LassoCo(
            resid=resid, s_quad=scal[0].astype(d), f_lin=scal[1].astype(d)
        )

    def objective(self, y, stats, co: LassoCo, cfg=None):
        """f(alpha^k) = 1/2 y^T y + 1/2 S^k - F^k (paper eq. 8 block)."""
        return 0.5 * stats.yty + 0.5 * co.s_quad - co.f_lin

    def gap(self, Xt, y, alpha, delta, cfg=None):
        """Certified FW duality gap alpha^T grad + delta*||grad||_inf with
        grad = -X^T (y - X alpha) — one O(nnz) pass (oracle protocol)."""
        return engine.oracle_gap(self, Xt, y, alpha, delta, cfg)


LASSO = LassoOracle()


# --------------------------------------------------------------------------
# Back-compat state surface (tests drive fw_step / init_state directly)
# --------------------------------------------------------------------------


class FWState(NamedTuple):
    """Flat lasso loop state. ``alpha = scale * beta`` (scaled repr)."""

    beta: jax.Array
    scale: jax.Array
    resid: jax.Array
    s_quad: jax.Array
    f_lin: jax.Array
    maxabs: jax.Array
    step_inf: jax.Array
    stall: jax.Array
    n_dots: jax.Array
    k: jax.Array
    key: jax.Array


def _to_engine(state: FWState) -> EngineState:
    return EngineState(
        beta=state.beta,
        scale=state.scale,
        co=LassoCo(resid=state.resid, s_quad=state.s_quad, f_lin=state.f_lin),
        maxabs=state.maxabs,
        step_inf=state.step_inf,
        stall=state.stall,
        n_dots=state.n_dots,
        k=state.k,
        key=state.key,
    )


def _from_engine(es: EngineState) -> FWState:
    return FWState(
        beta=es.beta,
        scale=es.scale,
        resid=es.co.resid,
        s_quad=es.co.s_quad,
        f_lin=es.co.f_lin,
        maxabs=es.maxabs,
        step_inf=es.step_inf,
        stall=es.stall,
        n_dots=es.n_dots,
        k=es.k,
        key=es.key,
    )


def init_state(
    Xt,
    y: jax.Array,
    key: jax.Array,
    alpha0: Optional[jax.Array] = None,
) -> FWState:
    """Start from the null solution, or warm-start from ``alpha0``."""
    return _from_engine(engine.init_state(LASSO, Xt, y, key, alpha0))


def fw_step(
    Xt,
    y: jax.Array,
    stats: ColStats,
    state: FWState,
    cfg: FWConfig,
    delta=None,
) -> FWState:
    """One randomized Frank-Wolfe step (paper Algorithm 2) — the engine
    step under the lasso oracle. ``Xt`` must already be feature-padded
    when ``cfg.backend == 'pallas'`` with block sampling (``fw_solve``
    does this once, outside the hot loop)."""
    delta = jnp.asarray(cfg.delta if delta is None else delta)
    return _from_engine(
        engine.step(LASSO, Xt, y, stats, _to_engine(state), cfg, delta)
    )


def objective(stats: ColStats, state) -> jax.Array:
    """f(alpha^k) = 1/2 y^T y + 1/2 S^k - F^k (paper eq. 8 block)."""
    return 0.5 * stats.yty + 0.5 * state.s_quad - state.f_lin


def duality_gap(Xt, state, delta: float) -> jax.Array:
    """Exact FW duality gap g(alpha) = alpha^T grad + delta*||grad||_inf.

    O(m p) dense, O(nnz) sparse — certification / tests, not the hot loop.
    Legacy lasso-only surface: the oracle-generic form is ``gap()`` on
    every oracle (``engine.oracle_gap``); this wrapper reads the gradient
    off a live ``FWState`` residual instead of recomputing it.
    """
    alpha = state.scale * state.beta
    grad = vertex.grad_full(Xt, state.resid)[: alpha.shape[0]]
    return jnp.dot(alpha, grad) + delta * jnp.max(jnp.abs(grad))


def fw_solve(
    Xt,
    y: jax.Array,
    cfg: FWConfig,
    key: jax.Array,
    alpha0: Optional[jax.Array] = None,
    delta=None,
) -> FWResult:
    """Run Algorithm 2 until ||alpha_{k+1}-alpha_k||_inf <= tol for
    ``patience`` consecutive iterations, or max_iters. ``delta`` (traced)
    overrides cfg.delta — one compile serves the whole path."""
    return engine.solve(LASSO, Xt, y, cfg, key, alpha0, delta)


def fw_solve_with_history(
    Xt,
    y: jax.Array,
    cfg: FWConfig,
    key: jax.Array,
    n_iters: int,
    alpha0: Optional[jax.Array] = None,
):
    """Fixed-iteration run recording f(alpha^k) per step (convergence
    plots). Returns (result, objective_history[n_iters])."""
    return engine.solve_with_history(LASSO, Xt, y, cfg, key, n_iters, alpha0)
