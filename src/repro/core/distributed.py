"""Distributed stochastic Frank-Wolfe (DESIGN.md §4.3) via shard_map.

Not in the paper (single-node C++): this is the cluster-scale layer.
The design matrix is sharded over a 2-D mesh:

    Xt (p, m):  features over the "model" axis, samples over "data"
    y, R (m,):  sharded over "data" (replicated over "model")
    beta (p,):  sharded over "model" (replicated over "data")

Per iteration:
  1. every model-shard samples kappa/n_model local coordinates and
     computes LOCAL partial dots against its residual shard,
  2. psum over "data" completes the sampled gradient coordinates,
  3. argmax over the sample within each model shard, then a global
     argmax across "model" (pmax + masked index exchange),
  4. the winning shard broadcasts its column contribution via masked
     psum; every shard updates its residual slice (eq. 10) and the
     owner updates beta[i*].

Per-iteration comm: one f32[kappa_local] psum over data, two scalar
psums, one f32[m/d_data] psum — tiny vs. the O(kappa m) local compute,
which is exactly the paper's scalability story at cluster scale.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.solver_config import FWConfig


class DistFWState(NamedTuple):
    beta: jax.Array  # (p_local,) per model shard
    scale: jax.Array  # ()
    resid: jax.Array  # (m_local,) per data shard
    s_quad: jax.Array
    f_lin: jax.Array
    n_dots: jax.Array
    k: jax.Array
    key: jax.Array


def _fw_shard_step(
    Xt_l, y_l, zty_l, zn2_l, state: DistFWState, cfg: FWConfig, n_model: int
):
    """Body executed per (data, model) shard under shard_map.

    ``n_model`` is the static "model"-axis size, passed down from the mesh:
    it sizes the per-shard sample, so it must be a Python int at trace time
    (the pinned JAX has no ``jax.lax.axis_size``; ``psum(1, axis)`` would be
    traced and could not shape ``idx``).
    """
    p_local = Xt_l.shape[0]
    model_idx = jax.lax.axis_index("model")

    key = jax.random.fold_in(state.key, state.k)
    # every model shard uses a distinct sampling stream
    key = jax.random.fold_in(key, model_idx)
    kappa_local = max(cfg.kappa // n_model, 1)
    idx = jax.random.randint(key, (kappa_local,), 0, p_local)

    # 1-2. sampled gradient coords: partial dot on the local sample shard,
    # completed by a psum over "data"
    rows = jnp.take(Xt_l, idx, axis=0)  # (kappa_local, m_local)
    partial = rows @ state.resid
    grad_s = -jax.lax.psum(partial, "data")  # (kappa_local,)

    # 3. local argmax -> global argmax over "model"
    j = jnp.argmax(jnp.abs(grad_s))
    local_best = jnp.abs(grad_s[j])
    best_val = jax.lax.pmax(local_best, "model")
    am_owner = local_best >= best_val  # ties: multiple owners possible; break below
    owner_rank = jax.lax.pmax(jnp.where(am_owner, model_idx, -1), "model")
    is_owner = model_idx == owner_rank

    i_local = idx[j]
    g_star = jax.lax.psum(jnp.where(is_owner, grad_s[j], 0.0), "model")
    zty_star = jax.lax.psum(jnp.where(is_owner, zty_l[i_local], 0.0), "model")
    zn2_star = jax.lax.psum(jnp.where(is_owner, zn2_l[i_local], 0.0), "model")

    # 4. line search (eq. 8) — identical scalars on every shard
    delta_t = -cfg.delta * jnp.sign(g_star)
    g_lin = g_star + zty_star
    num = state.s_quad - delta_t * g_star - state.f_lin
    den = state.s_quad - 2.0 * delta_t * g_lin + delta_t**2 * zn2_star
    lam = jnp.clip(num / jnp.maximum(den, cfg.eps_den), 0.0, 1.0)
    one_m = 1.0 - lam

    # owner broadcasts its column slice (masked psum over "model")
    z_col_local = jnp.where(
        is_owner, jax.lax.dynamic_slice_in_dim(Xt_l, i_local, 1, axis=0)[0], 0.0
    )
    z_col = jax.lax.psum(z_col_local, "model")  # (m_local,)

    resid = one_m * state.resid + lam * (y_l - delta_t * z_col)

    # scaled-representation coefficient update (owner only)
    new_scale = state.scale * one_m
    do_renorm = new_scale < cfg.renorm_threshold
    beta, scale = jax.lax.cond(
        do_renorm,
        lambda b, s: (b * s, jnp.ones((), b.dtype)),
        lambda b, s: (b, s),
        state.beta,
        new_scale,
    )
    upd = delta_t * lam / jnp.maximum(scale, cfg.eps_den)
    beta = jnp.where(
        (jnp.arange(p_local) == i_local) & is_owner, beta + upd, beta
    )

    s_quad = (
        one_m**2 * state.s_quad
        + 2.0 * delta_t * lam * one_m * g_lin
        + delta_t**2 * lam**2 * zn2_star
    )
    f_lin = one_m * state.f_lin + delta_t * lam * zty_star

    # periodic refresh from the (sharded) residual
    refresh = (state.k % cfg.refresh_every) == (cfg.refresh_every - 1)
    v_l = y_l - resid
    s_exact = jax.lax.psum(jnp.dot(v_l, v_l), "data")
    f_exact = jax.lax.psum(jnp.dot(v_l, y_l), "data")
    s_quad = jnp.where(refresh, s_exact, s_quad)
    f_lin = jnp.where(refresh, f_exact, f_lin)

    return DistFWState(
        beta=beta,
        scale=scale,
        resid=resid,
        s_quad=s_quad,
        f_lin=f_lin,
        n_dots=state.n_dots + kappa_local * n_model,
        k=state.k + 1,
        key=state.key,
    )


def make_distributed_solver(mesh: Mesh, cfg: FWConfig, n_iters: int):
    """Build a jitted distributed FW solver over the given 2-D mesh.

    Returns solve(Xt, y, key) -> (alpha, objective, n_dots). Arrays are
    accepted unsharded and placed via device_put by the caller or here.
    """
    from jax.experimental.shard_map import shard_map

    n_model = int(mesh.shape["model"])

    def shard_body(Xt_l, y_l, key):
        p_local = Xt_l.shape[0]
        zty_l = jax.lax.psum(Xt_l @ y_l, "data")  # full z^T y, local features
        zn2_l = jax.lax.psum(jnp.sum(Xt_l * Xt_l, axis=1), "data")
        yty = jax.lax.psum(jnp.dot(y_l, y_l), "data")

        state = DistFWState(
            beta=jnp.zeros((p_local,), Xt_l.dtype),
            scale=jnp.ones((), Xt_l.dtype),
            resid=y_l,
            s_quad=jnp.zeros((), Xt_l.dtype),
            f_lin=jnp.zeros((), Xt_l.dtype),
            n_dots=jnp.zeros((), jnp.int32),
            k=jnp.zeros((), jnp.int32),
            key=key,
        )

        def body(s, _):
            return _fw_shard_step(Xt_l, y_l, zty_l, zn2_l, s, cfg, n_model), None

        state, _ = jax.lax.scan(body, state, None, length=n_iters)
        alpha_l = state.scale * state.beta
        obj = 0.5 * yty + 0.5 * state.s_quad - state.f_lin
        return alpha_l, obj, state.n_dots

    mapped = shard_map(
        shard_body,
        mesh=mesh,
        in_specs=(P("model", "data"), P("data"), P()),
        out_specs=(P("model"), P(), P()),
        check_rep=False,
    )
    return jax.jit(mapped)
