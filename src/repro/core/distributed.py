"""DEPRECATED shim — the distributed FW layer moved to ``repro.distributed``.

The 185-line dense-only, lasso-only shard_map loop that lived here
through PR 3 is retired: ``repro.distributed`` shards BOTH matrix
layouts (dense tiles and block-ELL sparse cells) over a (data, model)
mesh and runs the SAME engine hot loop for the whole solver family
(DESIGN.md §Distributed). ``make_distributed_solver`` survives with its
old signature, delegating to the new subsystem.
"""
from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core.solver_config import FWConfig


def make_distributed_solver(mesh: Mesh, cfg: FWConfig, n_iters: int):
    """Deprecated: use ``repro.distributed`` (shard_dense/shard_sparse +
    driver.solve) directly.

    Returns solve(Xt, y, key) -> (alpha, objective, n_dots) like the
    retired loop: a fixed-iteration dense lasso run on the given mesh.
    Note the dot-product accounting now counts the GLOBAL sample size
    kappa per iteration (the engine convention) instead of the old
    kappa_local * n_model rounding.
    """
    warnings.warn(
        "repro.core.distributed is deprecated; use repro.distributed "
        "(shard_dense / shard_sparse + driver.solve) instead",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.core.fw_lasso import LASSO
    from repro.distributed import driver, shard

    run_cfg = dataclasses.replace(
        cfg, max_iters=n_iters, tol=0.0, patience=n_iters + 1
    )

    def solve(Xt, y, key):
        op = shard.shard_dense(jnp.asarray(Xt), jnp.asarray(y), mesh)
        res = driver.solve(LASSO, op, run_cfg, key)
        return res.alpha, res.objective, res.n_dots

    return solve
