"""Sampling-size rules from paper §4.5.

Two regimes:
  * percentile rule (Theorem 1, Schölkopf & Smola 6.33): kappa independent
    of p — e.g. kappa = 194 gives a top-2% vertex w.p. >= 0.98;
  * confidence rule (eq. 12): kappa >= ln(1-rho)/ln(1-s/p) guarantees the
    sample hits the optimal active set S* w.p. >= rho. For s/p -> 0 this
    degrades to kappa ~ (-ln(1-rho)/s) * p (eq. 13).
"""
from __future__ import annotations

import math


def kappa_percentile(top_fraction: float, confidence: float) -> int:
    """Smallest kappa s.t. max of the sample is in the top ``top_fraction``
    of all p values with probability >= ``confidence`` (independent of p)."""
    if not (0.0 < top_fraction < 1.0 and 0.0 < confidence < 1.0):
        raise ValueError("top_fraction and confidence must lie in (0, 1)")
    return int(math.ceil(math.log(1.0 - confidence) / math.log(1.0 - top_fraction)))


def kappa_confidence(p: int, n_relevant: int, rho: float) -> int:
    """Paper eq. (12): sample hits at least one of the ``n_relevant`` optimal
    features with probability >= rho."""
    if n_relevant <= 0:
        raise ValueError("n_relevant must be positive")
    if n_relevant >= p:
        return 1
    kappa = math.log(1.0 - rho) / math.log(1.0 - n_relevant / p)
    return max(1, min(p, int(math.ceil(kappa))))


def kappa_fraction(p: int, fraction: float) -> int:
    """The paper's large-scale default (§5.2, Table 3): |S| = fraction * p."""
    return max(1, int(math.ceil(fraction * p)))


def kappa_blocks(kappa: int, block_size: int, p: int | None = None) -> int:
    """Round a target kappa up to a whole number of aligned blocks.

    When ``p`` is given the count is clamped to the ceil(p / block_size)
    blocks that actually exist — the same clamp the solver applies before
    choice-without-replacement (`fw_lasso._sample_block_starts`), so a
    kappa request larger than p can never imply more blocks than exist.
    """
    nblocks = max(1, math.ceil(kappa / block_size))
    if p is not None:
        if p <= 0:
            raise ValueError("p must be positive")
        nblocks = min(nblocks, math.ceil(p / block_size))
    return nblocks * block_size
