"""Projection / proximal operators used by the baseline solvers.

These are the building blocks of the paper's Table-2 competitors
(FISTA / projected accelerated gradient), implemented in pure JAX.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def soft_threshold(x: jax.Array, thr) -> jax.Array:
    """Prox of ``thr * ||.||_1``: sign(x) * max(|x| - thr, 0)."""
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - thr, 0.0)


def project_l1_ball(v: jax.Array, radius) -> jax.Array:
    """Euclidean projection of ``v`` onto the l1 ball of the given radius.

    Duchi et al. (2008) sort-based algorithm, O(p log p). Returns ``v``
    unchanged when it is already inside the ball.
    """
    abs_v = jnp.abs(v)
    inside = jnp.sum(abs_v) <= radius

    u = jnp.sort(abs_v)[::-1]
    css = jnp.cumsum(u)
    k = jnp.arange(1, v.shape[0] + 1, dtype=v.dtype)
    cond = u * k > (css - radius)
    # rho = last index where cond holds (guaranteed >= 1 when outside ball)
    rho = jnp.max(jnp.where(cond, k, 0.0))
    rho = jnp.maximum(rho, 1.0)
    theta = (jnp.sum(jnp.where(cond, u, 0.0)) - radius) / rho
    projected = soft_threshold(v, jnp.maximum(theta, 0.0))
    return jnp.where(inside, v, projected)
