"""Pluggable FW step rules (DESIGN.md §StepRule).

The engine's iteration skeleton is rule-agnostic: ``engine.rule_step``
dispatches each iteration to a StepRule that owns (a) direction
selection — the classic FW vertex, an away vertex from a tracked active
set, a pairwise/PARTAN combination, or a lazily re-scored cached winner
— (b) the step-size clip (``g_max`` for away/pairwise, ``mu_max`` for
PARTAN), and (c) whatever extra state it carries between iterations,
threaded through ``EngineState.rule`` as a rule-owned pytree slot.

Rule protocol::

    name: str              registry key == FWConfig.step_rule
    fused_ok: bool         composes with the kernels/fused_step chunk?
                           (classic only; False rules fall back to
                           per-step EXPLICITLY — vertex.fused_supported
                           warns once, never silently)
    init_state(oracle, cfg, beta, co, y) -> pytree
    step(oracle, Xt, y, stats, state, cfg, delta) -> EngineState

The away/pairwise machinery leans on one structural fact of the l1 ball:
with atom set {+-delta e_i} u {0}, the CANONICAL convex decomposition of
any feasible alpha is w_i = |alpha_i|/delta on the sign-matched atoms
(w_0 = 1 - ||alpha||_1/delta on the zero atom), and classic/away/
pairwise steps all PRESERVE that form — so active-set *weights* are
implicit in the iterate and only a fixed-size active-*index* buffer is
carried. Stale buffer entries are safe: ``g_max`` is recomputed from the
live (beta, scale) every step, so feasibility never depends on the
buffer's freshness, and zero-weight slots are masked out of the away
argmax. The zero atom is never selected as an away atom (skipping it
avoids O(p) ||alpha||_1 tracking; moving away from 0 is a pure radial
inflation the FW direction already provides).

Generalized direction (oracles' ``dir_line_search``/``dir_update_co``):

    alpha(g) = (1 + g t) alpha + g (df e_f + da e_a),  g in [0, g_max]

    classic FW:  t = -1, df = delta_t,        da = 0,              g_max = 1
    away:        t = +1, df = 0,              da = -sigma_a delta, g_max = w_a/(1-w_a)
    pairwise:    t =  0, df = delta_t,        da = -sigma_a delta, g_max = w_a

with sigma_a = sign(alpha_a) and w_a = |alpha_a|/delta. The away-vs-FW
choice is the textbook gap comparison: take the away direction iff
-<grad, alpha - v_a> > -<grad, v_f - alpha> (both computable from the
selected scores plus the oracle's <grad, alpha> scalar). A step that
hits ``g_max`` on an away direction is a DROP step: the away coordinate
is zeroed exactly (float cancellation must not leave dust that keeps
the atom alive).

PARTAN (arxiv 1502.01563) extrapolates each classic FW step against the
previous iterate: after the FW half-step to alpha_mid, move along
dp = alpha_mid - alpha_prev with mu in [0, mu_max] where the
conservative mu_max = (delta - ||alpha_mid||_1) / (||alpha_mid||_1 +
||alpha_prev||_1) keeps l1 feasibility by the triangle inequality. The
rule state carries (alpha_prev, X alpha_prev) so every line-search
quantity stays O(m) via X dp = X alpha_mid - X alpha_prev.

The lazy LMO wrapper (arxiv 1803.07348's cache-and-threshold idea,
adapted to the sampled oracle) re-scores a small ring buffer of recent
winners through ``vertex.score_indices`` first; a cached vertex whose
DIRECTIONAL FW GAP ``<grad, alpha> + delta |sel|`` beats the threshold
phi skips the fresh kappa-draw entirely (lax.cond — the saved dots show
up in ``n_dots``), a miss pays the classic draw, halves phi when even
the fresh winner missed it, and inserts the fresh winner into the
cache. The criterion must be the gap, not the raw score: an exact line
search zeroes the DIRECTIONAL derivative of the atom it just stepped
on, so its gap collapses and the cache cannot serve the same atom into
a stall — raw |grad_i| stays large after the step and would.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import engine, vertex
from repro.core.engine import EngineState
from repro.core.solver_config import FWConfig
from repro.obs import telemetry as obs_telemetry

# approximate per-step O(m)-work surcharge of the generalized-direction
# rules (two column materializations + the u-vector dots), in length-m
# dot-product units for the n_dots accounting
DIR_EXTRA_DOTS = 5
# PARTAN surcharge: the extrapolation dots + exact S/F recompute
PARTAN_EXTRA_DOTS = 4
# PARTAN extrapolation cap: the line search runs on [0, MU_CAP] and the
# result is only kept when ||a_mid + mu dp||_1 stays inside the ball
PARTAN_MU_CAP = 8.0
# PARTAN co-state drift odometer limit: the extrapolation recursion
# co' = co - mu u_m amplifies fp32 error by ~(1 + 2 mu) per step, so a
# fixed refresh cadence cannot bound the drift — the rule integrates the
# amplification product and rebuilds the co-state from an exact matvec
# when it crosses this limit (rel error ~ eps_f32 * limit ~ 6e-5)
PARTAN_DRIFT_LIMIT = 1024.0


class DirStep(NamedTuple):
    """One generalized FW direction d = t*alpha + df*e_{i_f} + da*e_{i_a}
    (every leaf a replicated scalar under the distributed backend)."""

    t: jax.Array  # alpha coefficient: -1 classic, +1 away, 0 pairwise
    df: jax.Array  # FW-atom coefficient (delta_t, or 0 on away steps)
    da: jax.Array  # away-atom coefficient (-sigma_a * delta, or 0)
    i_f: jax.Array  # FW vertex coordinate
    i_a: jax.Array  # away vertex coordinate (safe dummy when da == 0)
    a_f: jax.Array  # alpha[i_f]
    a_a: jax.Array  # alpha[i_a]
    sel_f: jax.Array  # selected (gradient) score at i_f
    sel_a: jax.Array  # selected (gradient) score at i_a
    same: jax.Array  # 1.0 when i_f == i_a else 0.0
    g_max: jax.Array  # step-size clip


def apply_dir_update(beta, scale, maxabs, stall, ds: DirStep, g, no_progress,
                     cfg: FWConfig):
    """Generalized-direction twin of ``engine.apply_coeff_update``:
    the scaled-iterate coefficient update for alpha(g), the exact zero
    on away drop steps, and the §Stopping statistics. Returns
    ``(beta, scale, maxabs, step_inf, stall)``."""
    gt = g * ds.t
    one_gt = 1.0 + gt
    new_scale = scale * one_gt
    # renormalize on underflow (classic parity; away steps GROW the scale)
    need_renorm = new_scale < cfg.renorm_threshold
    beta, scale = jax.lax.cond(
        need_renorm,
        lambda b, s: (b * s, jnp.ones((), b.dtype)),
        lambda b, s: (b, s),
        beta,
        new_scale,
    )
    denom = jnp.maximum(scale, cfg.eps_den)
    beta = beta.at[ds.i_f].add(g * ds.df / denom)
    beta = beta.at[ds.i_a].add(g * ds.da / denom)
    # drop step: the away atom leaves the decomposition EXACTLY
    drop = (ds.da != 0.0) & (g >= ds.g_max) & (ds.same == 0.0)
    beta = beta.at[ds.i_a].set(jnp.where(drop, 0.0, beta[ds.i_a]))
    # ||alpha' - alpha||_inf upper bound: |t| maxabs off the atoms, the
    # exact per-atom movement on them (same-coordinate terms folded in)
    d_f = ds.t * ds.a_f + ds.df + ds.same * ds.da
    d_a = ds.t * ds.a_a + ds.da + ds.same * ds.df
    step_inf = g * jnp.maximum(
        jnp.abs(ds.t) * maxabs, jnp.maximum(jnp.abs(d_f), jnp.abs(d_a))
    )
    maxabs = jnp.maximum(
        jnp.abs(one_gt) * maxabs,
        jnp.maximum(jnp.abs(scale * beta[ds.i_f]), jnp.abs(scale * beta[ds.i_a])),
    )
    stall = jnp.where((step_inf <= cfg.tol) | no_progress, stall + 1, 0)
    return beta, scale, maxabs, step_inf, stall


# --------------------------------------------------------------------------
# Active-set index buffer (away / pairwise)
# --------------------------------------------------------------------------


def init_active_set(beta, cfg: FWConfig) -> jax.Array:
    """Fixed-size (active_set_size,) int32 index buffer: the largest-|beta|
    support coordinates for warm starts, -1 (empty) elsewhere. A support
    wider than the buffer just means some atoms are invisible to away
    steps — the algorithm stays correct, only less eager to drop them."""
    cap = cfg.active_set_size
    p = beta.shape[0]
    k_eff = min(cap, p)
    vals, idx = jax.lax.top_k(jnp.abs(beta), k_eff)
    idx = jnp.where(vals > 0, idx, -1).astype(jnp.int32)
    if k_eff < cap:
        idx = jnp.concatenate([idx, jnp.full((cap - k_eff,), -1, jnp.int32)])
    return idx


def insert_active(buf: jax.Array, i_new, beta) -> jax.Array:
    """Track ``i_new``: no-op when present, else evict the weakest-|beta|
    slot (empty slots first). Eviction cannot break feasibility — weights
    live in (beta, scale), the buffer only limits away candidates."""
    p = beta.shape[0]
    present = jnp.any(buf == i_new)
    w = jnp.where(
        buf >= 0, jnp.abs(jnp.take(beta, jnp.clip(buf, 0, p - 1))), -1.0
    )
    slot = jnp.argmin(w)
    inserted = buf.at[slot].set(i_new.astype(buf.dtype))
    return jnp.where(present, buf, inserted)


def _select_away(oracle, Xt, w, buf, beta, scale, delta, p, cfg):
    """Away-vertex argmax over the tracked active set: re-score the buffer
    coordinates (``vertex.score_indices`` — the sampled-argmax machinery
    restricted to the active set; one extended psum distributed), mask
    empty/zero-weight slots, and pick the atom the gradient most wants to
    LEAVE: argmax_i <grad, sigma_i delta e_i>."""
    extra_fn = oracle.score_extra(beta, scale)
    _, sel_b = vertex.score_indices(Xt, w, buf, p, cfg, extra_fn)
    a_b = scale * jnp.take(beta, jnp.clip(buf, 0, p - 1))
    valid = (buf >= 0) & (a_b != 0.0)
    sigma = jnp.sign(a_b)
    score = jnp.where(valid, sigma * sel_b, -jnp.inf)
    j = jnp.argmax(score)
    any_valid = jnp.any(valid)
    i_a = jnp.where(any_valid, jnp.clip(buf[j], 0, p - 1), 0)
    return i_a, sel_b[j], a_b[j], sigma[j], any_valid


# --------------------------------------------------------------------------
# The rules
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ClassicRule:
    """The paper's Algorithm-2 step — ``engine.step`` itself, so the
    trajectory (and jaxpr) is bit-identical to the pre-refactor engine."""

    name = "classic"
    fused_ok = True

    def init_state(self, oracle, cfg, beta, co, y):
        return ()

    def step(self, oracle, Xt, y, stats, state, cfg, delta) -> EngineState:
        return engine.step(oracle, Xt, y, stats, state, cfg, delta)


@dataclasses.dataclass(frozen=True)
class DirRule:
    """Away-steps (``pairwise=False``) / pairwise (``pairwise=True``) FW
    over the sampled oracle. Rule state: the active-set index buffer."""

    pairwise: bool

    fused_ok = False

    @property
    def name(self):
        return "pairwise" if self.pairwise else "away"

    def init_state(self, oracle, cfg, beta, co, y):
        return init_active_set(beta, cfg)

    def step(self, oracle, Xt, y, stats, state: EngineState, cfg: FWConfig,
             delta) -> EngineState:
        p = state.beta.shape[0]
        buf = state.rule
        key, sub = jax.random.split(state.key)

        w = oracle.cograd(state.co, y)
        extra_fn = oracle.score_extra(state.beta, state.scale)
        i_f, _, sel_f, n_scored = vertex.sample_vertex(
            Xt, w, sub, p, cfg, extra_fn
        )
        i_a, sel_a, a_a, sigma_a, any_valid = _select_away(
            oracle, Xt, w, buf, state.beta, state.scale, delta, p, cfg
        )

        df_fw = -delta * jnp.sign(sel_f)
        a_f = state.scale * state.beta[i_f]
        w_a = jnp.abs(a_a) / jnp.maximum(delta, cfg.eps_den)
        usable = any_valid & (w_a > 0.0)
        if self.pairwise:
            # pairwise when an away atom exists AND the paired direction
            # descends: its gap delta (|sel_f| + sigma_a sel_a) must be
            # positive — with only stale buffer candidates the best
            # "away" atom's leave-score can be negative enough to cancel
            # the FW term, which would ratchet the stall counter through
            # a <= 0 gap numerator; fall back to classic FW instead
            use_alt = usable & (jnp.abs(sel_f) + sigma_a * sel_a > 0.0)
            t = jnp.where(use_alt, 0.0, -1.0)
            df = df_fw
            g_max = jnp.where(use_alt, w_a, 1.0)
        else:
            # away iff its directional gap beats the FW direction's
            ga = oracle.grad_dot_alpha(
                state.co, stats, y, state.beta, state.scale, cfg
            )
            fw_gap = ga - df_fw * sel_f
            away_gap = sigma_a * delta * sel_a - ga
            use_alt = usable & (away_gap > fw_gap)
            t = jnp.where(use_alt, 1.0, -1.0)
            df = jnp.where(use_alt, 0.0, df_fw)
            g_max = jnp.where(
                use_alt,
                jnp.minimum(w_a / jnp.maximum(1.0 - w_a, cfg.eps_den), 1e3),
                1.0,
            )
        da = jnp.where(use_alt, -sigma_a * delta, 0.0)

        # direction image X d = t (X alpha) + u_lin, u_lin = df z_f + da z_a
        z_f = vertex.column_dense(Xt, i_f, cfg)
        z_a = vertex.column_dense(Xt, i_a, cfg)
        u_lin = df * z_f + da * z_a

        ds = DirStep(
            t=t, df=df, da=da, i_f=i_f, i_a=i_a, a_f=a_f, a_a=a_a,
            sel_f=sel_f, sel_a=sel_a,
            same=(i_f == i_a).astype(state.beta.dtype),
            g_max=g_max,
        )
        g, no_progress, aux = oracle.dir_line_search(
            y, stats, state.co, ds, u_lin, cfg
        )
        beta, scale, maxabs, step_inf, stall = apply_dir_update(
            state.beta, state.scale, state.maxabs, state.stall, ds, g,
            no_progress, cfg,
        )
        co = oracle.dir_update_co(
            Xt, y, stats, state.co, beta, scale, ds, g, u_lin, state.k, cfg,
            aux,
        )
        # the FW atom enters the active set whenever it gained weight
        took_fw = (df != 0.0) & (g > 0.0)
        buf = jnp.where(took_fw, insert_active(buf, i_f, beta), buf)

        n_dots = (
            state.n_dots
            + n_scored
            + buf.shape[0]
            + DIR_EXTRA_DOTS
            + oracle.extra_dots
        )
        tel = state.tel
        if cfg.telemetry is not None:
            drop = (da != 0.0) & (g >= ds.g_max) & (ds.same == 0.0)
            alt = (
                obs_telemetry.EVENT_PAIRWISE
                if self.pairwise
                else obs_telemetry.EVENT_AWAY
            )
            event = jnp.where(
                drop,
                obs_telemetry.EVENT_DROP,
                jnp.where(use_alt, alt, obs_telemetry.EVENT_FW),
            )
            if cfg.telemetry.record_objective:
                if self.pairwise:
                    # away computes ga above; pairwise only pays for it
                    # when the ring wants the gap
                    ga = oracle.grad_dot_alpha(
                        state.co, stats, y, state.beta, state.scale, cfg
                    )
                # the classic sampled FW duality gap — the rules' common
                # convergence yardstick regardless of direction taken
                gap = ga - df_fw * sel_f
                objective = oracle.objective(y, stats, co, cfg)
            else:
                gap = objective = jnp.nan
            tel = obs_telemetry.record(
                tel,
                k=state.k,
                i_star=jnp.where(use_alt, i_a, i_f),
                event=event,
                lam=g,
                gap=gap,
                objective=objective,
                step_inf=step_inf,
                stall=stall,
                n_dots=n_dots,
            )

        return EngineState(
            beta=beta,
            scale=scale,
            co=co,
            maxabs=maxabs,
            step_inf=step_inf,
            stall=stall,
            n_dots=n_dots,
            k=state.k + 1,
            key=key,
            rule=buf,
            tel=tel,
        )


@dataclasses.dataclass(frozen=True)
class PartanRule:
    """PARTAN-accelerated FW: a classic engine step to alpha_mid, then an
    extrapolation along alpha_mid - alpha_prev (arxiv 1502.01563). Rule
    state: (alpha_prev, X alpha_prev, drift odometer). O(p) per step by
    construction — the extrapolation touches every coordinate."""

    name = "partan"
    fused_ok = False

    def init_state(self, oracle, cfg, beta, co, y):
        return (beta, oracle.co_linpred(co, y), jnp.zeros((), jnp.float32))

    def step(self, oracle, Xt, y, stats, state: EngineState, cfg: FWConfig,
             delta) -> EngineState:
        a_prev, v_prev, drift = state.rule
        alpha_old = state.scale * state.beta
        mid = engine.step(oracle, Xt, y, stats, state, cfg, delta)
        no_prog_mid = mid.stall > state.stall

        a_mid = mid.scale * mid.beta
        v_mid = oracle.co_linpred(mid.co, y)
        dp = a_mid - a_prev
        u_m = v_mid - v_prev  # X dp on the local sample slice
        # optimistic clip: line-search on [0, PARTAN_MU_CAP] first — dp
        # usually runs ALONG the l1 sphere (consecutive FW iterates share
        # sign pattern), so the optimum is typically feasible as-is. Only
        # when the exact ||.||_1 check fails fall back to the triangle-
        # inequality bound mu <= (delta - ||a_mid||_1) / (||a_mid||_1 +
        # ||a_prev||_1), which is safe but collapses to 0 on the sphere.
        mu_opt = oracle.partan_mu(
            y, stats, mid.co, u_m, a_mid, dp, jnp.asarray(PARTAN_MU_CAP), cfg
        )
        s_mid = jnp.sum(jnp.abs(a_mid))
        s_prev = jnp.sum(jnp.abs(a_prev))
        l1_try = jnp.sum(jnp.abs(a_mid + mu_opt * dp))
        mu_cons = jnp.maximum(delta - s_mid, 0.0) / jnp.maximum(
            s_mid + s_prev, cfg.eps_den
        )
        # any mu in [0, mu_opt] still descends (convex line objective)
        mu = jnp.where(
            l1_try <= delta * (1.0 + 1e-6),
            mu_opt,
            jnp.minimum(mu_opt, mu_cons),
        )
        a_new = a_mid + mu * dp
        co = oracle.partan_update_co(y, stats, mid.co, a_new, mu, u_m, cfg)
        # drift-triggered EXACT co-state rebuild: each extrapolation
        # amplifies the recursion's fp32 error by ~(1 + 2 mu), so a fixed
        # cadence cannot bound the drift — integrate the amplification
        # product and rebuild co from an exact X a_new matvec when it
        # crosses PARTAN_DRIFT_LIMIT (cheap when mu ~ 0, eager when the
        # extrapolation is actually firing)
        drift = (1.0 + 2.0 * jnp.abs(mu).astype(jnp.float32)) * drift + 1.0
        refresh = drift > PARTAN_DRIFT_LIMIT
        co = jax.lax.cond(
            refresh,
            lambda: oracle.init_co(
                y, vertex.matvec(Xt, a_new, cfg), a_new, a_new.dtype, cfg
            ),
            lambda: co,
        )
        drift = jnp.where(refresh, 0.0, drift)
        # carry the OUTER iterate as the next step's extrapolation anchor
        # (textbook PARTAN pairs x_mid with x_{k-1}); reading v through
        # the refreshed co means a rebuild also hands the next step an
        # exact v_prev, not one carrying the pre-refresh drift
        v_new = oracle.co_linpred(co, y)
        # exact stopping statistics — PARTAN is O(p) anyway
        step_inf = jnp.max(jnp.abs(a_new - alpha_old))
        stall = jnp.where(
            (step_inf <= cfg.tol) | no_prog_mid, state.stall + 1, 0
        )
        n_dots = (
            mid.n_dots
            + PARTAN_EXTRA_DOTS
            + jnp.where(refresh, a_new.shape[0], 0)
        )
        tel = mid.tel
        if cfg.telemetry is not None:
            # the classic half-step already pushed this iteration's
            # record inside engine.step — amend it in place (the ring
            # stays one record per iteration) with the post-extrapolation
            # truth; gap stays the mid-step's sampled FW gap
            fields = dict(
                event=obs_telemetry.EVENT_PARTAN,
                step_inf=step_inf,
                stall=stall,
                n_dots=n_dots,
            )
            if cfg.telemetry.record_objective:
                fields["objective"] = oracle.objective(y, stats, co, cfg)
            tel = obs_telemetry.amend_last(tel, **fields)
        return EngineState(
            beta=a_new,
            scale=jnp.ones((), a_new.dtype),
            co=co,
            maxabs=jnp.max(jnp.abs(a_new)),
            step_inf=step_inf,
            stall=stall,
            n_dots=n_dots,
            k=mid.k,
            key=mid.key,
            rule=(a_new, v_new, drift),
            tel=tel,
        )


@dataclasses.dataclass(frozen=True)
class LazyRule:
    """Lazy LMO wrapper around the classic step: re-score a ring buffer of
    recent winners first; a cached vertex with directional FW gap >= phi
    skips the fresh sampled draw (lax.cond — the skipped kappa dots are
    real savings, visible in ``n_dots``). Rule state: (cache indices,
    phi gap threshold)."""

    name = "lazy"
    fused_ok = False

    def init_state(self, oracle, cfg, beta, co, y):
        return (
            jnp.full((cfg.lazy_cache,), -1, jnp.int32),
            jnp.full((), jnp.inf, jnp.float32),
        )

    def step(self, oracle, Xt, y, stats, state: EngineState, cfg: FWConfig,
             delta) -> EngineState:
        p = state.beta.shape[0]
        cache, phi = state.rule
        cap = cache.shape[0]
        key, sub = jax.random.split(state.key)

        w = oracle.cograd(state.co, y)
        extra_fn = oracle.score_extra(state.beta, state.scale)
        # directional FW gap of vertex -delta sign(sel) e_i is
        # <grad, alpha> + delta |sel_i| — the lazy acceptance currency
        ga = oracle.grad_dot_alpha(
            state.co, stats, y, state.beta, state.scale, cfg
        )
        raw_c, sel_c = vertex.score_indices(Xt, w, cache, p, cfg, extra_fn)
        gap_c = jnp.where(
            cache >= 0,
            (ga + delta * jnp.abs(sel_c)).astype(jnp.float32),
            -jnp.inf,
        )
        j = jnp.argmax(gap_c)
        hit = gap_c[j] >= phi
        nd = engine.dot_dtype()

        def cached(_):
            return (
                jnp.clip(cache[j], 0, p - 1),
                raw_c[j],
                sel_c[j],
                jnp.asarray(cap, nd),
                phi,
                cache,
            )

        def fresh(_):
            i2, raw2, sel2, ns2 = vertex.sample_vertex(
                Xt, w, sub, p, cfg, extra_fn
            )
            gap2 = (ga + delta * jnp.abs(sel2)).astype(jnp.float32)
            # first fresh draw seeds phi at half its gap; later draws
            # whose gap misses phi halve it (Braun et al.'s Phi update)
            phi2 = jnp.where(
                jnp.isinf(phi),
                0.5 * gap2,
                jnp.where(gap2 < phi, 0.5 * phi, phi),
            )
            cache2 = cache.at[state.k % cap].set(i2.astype(jnp.int32))
            return (i2, raw2, sel2, jnp.asarray(cap + ns2, nd), phi2, cache2)

        i_star, g_raw, g_sel, n_scored, phi_new, cache_new = jax.lax.cond(
            hit, cached, fresh, None
        )

        # classic tail on the chosen vertex (same op sequence as
        # engine.step past selection)
        delta_t = -delta * jnp.sign(g_sel)
        a_star = state.scale * state.beta[i_star]
        lam, no_progress, aux = oracle.line_search(
            Xt, y, stats, state.co, i_star, g_raw, g_sel, a_star, delta_t, cfg
        )
        beta, scale, maxabs, step_inf, stall = engine.apply_coeff_update(
            state.beta, state.scale, state.maxabs, state.stall, a_star,
            i_star, lam, delta_t, no_progress, cfg,
        )
        co = oracle.update_co(
            Xt, y, stats, state.co, beta, scale, i_star, a_star, lam,
            delta_t, state.k, cfg, aux,
        )
        n_dots = state.n_dots + n_scored + 1 + oracle.extra_dots
        tel = state.tel
        if cfg.telemetry is not None:
            objective = (
                oracle.objective(y, stats, co, cfg)
                if cfg.telemetry.record_objective
                else jnp.nan
            )
            tel = obs_telemetry.record(
                tel,
                k=state.k,
                i_star=i_star,
                event=jnp.where(
                    hit,
                    obs_telemetry.EVENT_LAZY_HIT,
                    obs_telemetry.EVENT_FW,
                ),
                lam=lam,
                # == ga - delta_t * g_sel: the classic record's gap
                # formula, which here is also the lazy acceptance
                # currency (free — ga is always computed by this rule)
                gap=ga + delta * jnp.abs(g_sel),
                objective=objective,
                step_inf=step_inf,
                stall=stall,
                n_dots=n_dots,
            )
        return EngineState(
            beta=beta,
            scale=scale,
            co=co,
            maxabs=maxabs,
            step_inf=step_inf,
            stall=stall,
            n_dots=n_dots,
            k=state.k + 1,
            key=key,
            rule=(cache_new, phi_new),
            tel=tel,
        )


_RULES = {
    "classic": ClassicRule(),
    "away": DirRule(pairwise=False),
    "pairwise": DirRule(pairwise=True),
    "partan": PartanRule(),
    "lazy": LazyRule(),
}


def get_rule(cfg) -> Any:
    """The StepRule for ``cfg.step_rule`` (classic when cfg is None —
    the back-compat entry points predate the rule knob)."""
    if cfg is None:
        return _RULES["classic"]
    return _RULES[cfg.step_rule]
