"""Logistic problem oracle for the stochastic FW engine (paper §6:
"an extension of the algorithm to solve l1-regularized logistic
regression problems ... can be easily obtained").

    min_a  sum_i log(1 + exp(-y_i * x_i^T a))   s.t.  ||a||_1 <= delta
    (y in {-1, +1})

Mechanics mirror Algorithm 2 with two changes, both of which live here
(everything else — sampling, backend dispatch, stopping, loop drivers —
is the shared engine, DESIGN.md §Engine):
  * the "residual" becomes the margin vector m = X a, updated by the same
    O(m) recursion m <- (1-l) m + l dt z_i* (the FW step is linear); the
    engine's co-gradient is w = -grad_margin, so the sampled linear
    scores -z_i^T w equal z_i^T grad_margin bitwise;
  * the exact line search has no closed form; phi'(l) is monotone
    (convexity), so a fixed number of bisection steps on phi'(l) = 0
    gives the step size with O(m) work per probe.

Because the oracle rides the engine, the logistic solver now runs on all
three backends — including ``FWConfig(backend='sparse')`` over a
``SparseBlockMatrix`` (the bisection direction vector is materialized by
the margin-scatter op ``sparse.ops.sparse_column_dense``) — and through
both regularization-path drivers in ``core.path``.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import engine, vertex
from repro.core.solver_config import FWConfig

LogisticResult = engine.SolveResult


def _loss(margin, y, cfg=None):
    # padded samples (distributed m-padding) carry y == 0 — real labels
    # are +-1 — and must not contribute their log(2) rest loss
    per = jnp.logaddexp(0.0, -y * margin)
    return vertex.msum(jnp.where(y != 0, per, 0.0), cfg)


class LogisticCo(NamedTuple):
    """Logistic co-state: just the margin vector X a."""

    margin: jax.Array  # (m,)


@dataclasses.dataclass(frozen=True)
class LogisticOracle:
    """Problem oracle: l1-constrained logistic loss (labels in {-1,+1})."""

    n_bisect: int = 20

    needs_stats = False
    # no closed-form line search: the O(m)-per-probe bisection cannot run
    # as fused scalar algebra, so ``FWConfig.fuse_steps`` falls back to
    # the per-step loop for this oracle (DESIGN.md §Perf).
    fused_kind = None
    fused_needs_alpha = False

    @property
    def extra_dots(self) -> int:
        # each bisection probe is one O(m) dot, plus the two endpoint
        # tests and the sampled-gap stall statistic
        return self.n_bisect + 3

    def init_co(self, y, v, beta, dtype, cfg=None) -> LogisticCo:
        return LogisticCo(margin=jnp.zeros_like(y) if v is None else v)

    def cograd(self, co: LogisticCo, y):
        """gradient wrt margin is -y * sigmoid(-y * m); the engine scores
        -z_i^T w, so pass w = -grad (negation is IEEE-exact)."""
        return y * jax.nn.sigmoid(-y * co.margin)

    def score_extra(self, beta, scale):
        return None

    def line_search(
        self, Xt, y, stats, co: LogisticCo, i_star, g_raw, g_sel, a_star, delta_t, cfg
    ):
        z_star = vertex.column_dense(Xt, i_star, cfg)
        # margin along the segment: m(l) = (1-l) m + l dt z
        dm = delta_t * z_star - co.margin  # (m,)

        def phi_prime(lam):
            mg = co.margin + lam * dm
            return vertex.mdot(-y * jax.nn.sigmoid(-y * mg), dm, cfg)

        # bisection on [0, 1]; phi' monotone increasing (convexity)
        def body(_, ab):
            a, b = ab
            mid = 0.5 * (a + b)
            going_up = phi_prime(mid) > 0
            return jnp.where(going_up, a, mid), jnp.where(going_up, mid, b)

        # if phi'(1) <= 0 the minimizer is lam=1; if phi'(0) >= 0 it's 0
        a, b = jax.lax.fori_loop(0, self.n_bisect, body, (jnp.zeros(()), jnp.ones(())))
        lam = 0.5 * (a + b)
        lam = jnp.where(phi_prime(jnp.ones(())) <= 0, 1.0, lam)
        lam = jnp.where(phi_prime(jnp.zeros(())) >= 0, 0.0, lam)

        # sampled FW duality gap g_S = alpha^T grad + delta |grad_{i*}|:
        # alpha^T grad_alpha = margin^T grad_margin (grad_alpha = X^T g_m),
        # so the gap statistic is O(m) — no full-gradient pass. A gap
        # below the fp32 rounding floor of its own terms cannot make real
        # progress (gap_rtol noise-floor stall, DESIGN.md §Stopping);
        # counting it lets warm-started paths terminate immediately.
        grad_m = -y * jax.nn.sigmoid(-y * co.margin)
        a_grad = vertex.mdot(co.margin, grad_m, cfg)
        gap_s = a_grad + jnp.abs(delta_t * g_sel)
        gap_scale = jnp.abs(a_grad) + jnp.abs(delta_t * g_sel)
        no_progress = gap_s <= cfg.gap_rtol * gap_scale
        return lam, no_progress, dm

    def update_co(
        self, Xt, y, stats, co: LogisticCo, beta, scale, i_star, a_star, lam,
        delta_t, k, cfg, aux,
    ) -> LogisticCo:
        return LogisticCo(margin=co.margin + lam * aux)

    # ---- generalized direction protocol (DESIGN.md §StepRule) ----------
    # Along d = t*alpha + df*e_f + da*e_a the margin moves on the RAY
    # m(g) = m + g*u with u = t*m + df*z_f + da*z_a fixed, so the same
    # monotone-phi' bisection runs on [0, g_max] (away/pairwise clip)
    # instead of the classic [0, 1] segment.

    def co_linpred(self, co: LogisticCo, y):
        return co.margin

    def grad_dot_alpha(self, co: LogisticCo, stats, y, beta, scale, cfg):
        """alpha^T grad_alpha = margin^T grad_margin (grad_alpha = X^T g_m)
        — one O(m) dot, no full-gradient pass."""
        grad_m = -y * jax.nn.sigmoid(-y * co.margin)
        return vertex.mdot(co.margin, grad_m, cfg)

    def _bisect_ray(self, y, m0, u, g_max, cfg):
        """Monotone bisection for argmin_g sum log(1+exp(-y (m0 + g u)))
        on [0, g_max] (phi'(g) = <grad_m(m0 + g u), u> is increasing)."""

        def phi_prime(g):
            mg = m0 + g * u
            return vertex.mdot(-y * jax.nn.sigmoid(-y * mg), u, cfg)

        def body(_, ab):
            a, b = ab
            mid = 0.5 * (a + b)
            going_up = phi_prime(mid) > 0
            return jnp.where(going_up, a, mid), jnp.where(going_up, mid, b)

        a, b = jax.lax.fori_loop(
            0, self.n_bisect, body, (jnp.zeros(()), g_max * jnp.ones(()))
        )
        g = 0.5 * (a + b)
        g = jnp.where(phi_prime(g_max) <= 0, g_max, g)
        g = jnp.where(phi_prime(jnp.zeros(())) >= 0, 0.0, g)
        return g

    def dir_line_search(self, y, stats, co: LogisticCo, ds, u_lin, cfg):
        u = ds.t * co.margin + u_lin
        g = self._bisect_ray(y, co.margin, u, ds.g_max, cfg)
        # directional FW gap -<grad, d> = -<grad_m, u> at g = 0; below
        # the fp32 noise floor of its own terms the step is a stall
        # (gap_rtol rule, DESIGN.md §Stopping)
        grad_m = -y * jax.nn.sigmoid(-y * co.margin)
        num = -vertex.mdot(grad_m, u, cfg)
        a_grad = vertex.mdot(co.margin, grad_m, cfg)
        gap_scale = (
            jnp.abs(ds.t) * jnp.abs(a_grad)
            + jnp.abs(ds.df * ds.sel_f)
            + jnp.abs(ds.da * ds.sel_a)
        )
        no_progress = num <= cfg.gap_rtol * gap_scale
        return g, no_progress, u

    def dir_update_co(
        self, Xt, y, stats, co: LogisticCo, beta, scale, ds, g, u_lin, k, cfg, aux
    ) -> LogisticCo:
        return LogisticCo(margin=co.margin + g * aux)

    # ---- PARTAN extrapolation protocol (DESIGN.md §StepRule) -----------

    def partan_mu(self, y, stats, co: LogisticCo, u_m, a_mid, dp, mu_max, cfg):
        return self._bisect_ray(y, co.margin, u_m, mu_max, cfg)

    def partan_update_co(self, y, stats, co: LogisticCo, a_new, mu, u_m, cfg):
        return LogisticCo(margin=co.margin + mu * u_m)

    def objective(self, y, stats, co: LogisticCo, cfg=None):
        return _loss(co.margin, y, cfg)

    def gap(self, Xt, y, alpha, delta, cfg=None):
        """Certified FW duality gap with the LOGISTIC gradient
        X^T (-y sigmoid(-y m)) — oracle protocol (§Stopping)."""
        return engine.oracle_gap(self, Xt, y, alpha, delta, cfg)


LOGISTIC = LogisticOracle()


def logistic_solve(
    Xt,
    y: jax.Array,  # labels in {-1, +1}
    cfg: FWConfig,
    key: jax.Array,
    alpha0: Optional[jax.Array] = None,
    delta=None,
) -> LogisticResult:
    """l1-constrained logistic FW on any backend ('xla'|'pallas'|'sparse').

    ``delta`` (traced) overrides cfg.delta — one compile per path."""
    return engine.solve(LOGISTIC, Xt, y, cfg, key, alpha0, delta)
