"""Stochastic Frank-Wolfe for l1-constrained logistic regression
(paper §6: "an extension of the algorithm to solve l1-regularized
logistic regression problems ... can be easily obtained").

    min_a  sum_i log(1 + exp(-y_i * x_i^T a))   s.t.  ||a||_1 <= delta
    (y in {-1, +1})

Mechanics mirror Algorithm 2 with two changes:
  * the "residual" becomes the margin vector m = X a, updated by the same
    O(m) recursion m <- (1-l) m + l dt z_i* (the FW step is linear);
  * the exact line search has no closed form; phi'(l) is monotone
    (convexity), so a fixed number of bisection steps on phi'(l) = 0
    gives the step size with O(m) work per probe.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.fw_lasso import _sample_indices
from repro.core.solver_config import FWConfig


class LogisticState(NamedTuple):
    beta: jax.Array
    scale: jax.Array
    margin: jax.Array  # (m,) X a
    maxabs: jax.Array
    step_inf: jax.Array
    stall: jax.Array
    n_dots: jax.Array
    k: jax.Array
    key: jax.Array


class LogisticResult(NamedTuple):
    alpha: jax.Array
    objective: jax.Array
    iterations: jax.Array
    n_dots: jax.Array
    active: jax.Array
    converged: jax.Array


def _loss(margin, y):
    return jnp.sum(jnp.logaddexp(0.0, -y * margin))


def logistic_step(Xt, y, state: LogisticState, cfg: FWConfig, n_bisect: int = 20):
    p = Xt.shape[0]
    key, sub = jax.random.split(state.key)
    idx = _sample_indices(sub, p, cfg)

    # gradient wrt margin: -y * sigmoid(-y * m)
    gm = -y * jax.nn.sigmoid(-y * state.margin)  # (m,)
    rows = jnp.take(Xt, idx, axis=0)
    grad_s = rows @ gm  # sampled gradient coords

    j = jnp.argmax(jnp.abs(grad_s))
    i_star = idx[j]
    g_star = grad_s[j]
    delta_t = -cfg.delta * jnp.sign(g_star)

    z_star = jax.lax.dynamic_slice_in_dim(Xt, i_star, 1, axis=0)[0]
    # margin along the segment: m(l) = (1-l) m + l dt z
    dm = delta_t * z_star - state.margin  # (m,)

    def phi_prime(lam):
        mg = state.margin + lam * dm
        return jnp.dot(-y * jax.nn.sigmoid(-y * mg), dm)

    # bisection on [0, 1]; phi' monotone increasing (convexity)
    def body(_, ab):
        a, b = ab
        mid = 0.5 * (a + b)
        going_up = phi_prime(mid) > 0
        return jnp.where(going_up, a, mid), jnp.where(going_up, mid, b)

    # if phi'(1) <= 0 the minimizer is lam=1; if phi'(0) >= 0 it's 0
    a0 = jnp.zeros(())
    b0 = jnp.ones(())
    a, b = jax.lax.fori_loop(0, n_bisect, body, (a0, b0))
    lam = 0.5 * (a + b)
    lam = jnp.where(phi_prime(jnp.ones(())) <= 0, 1.0, lam)
    lam = jnp.where(phi_prime(jnp.zeros(())) >= 0, 0.0, lam)

    one_m = 1.0 - lam
    alpha_istar_old = state.scale * state.beta[i_star]
    new_scale = state.scale * one_m
    need_renorm = new_scale < cfg.renorm_threshold
    beta, scale = jax.lax.cond(
        need_renorm,
        lambda bb, ss: (bb * ss, jnp.ones((), Xt.dtype)),
        lambda bb, ss: (bb, ss),
        state.beta,
        new_scale,
    )
    beta = beta.at[i_star].add(delta_t * lam / jnp.maximum(scale, cfg.eps_den))
    margin = state.margin + lam * dm

    alpha_new = scale * beta[i_star]
    step_inf = lam * jnp.maximum(state.maxabs, jnp.abs(delta_t - alpha_istar_old))
    maxabs = jnp.maximum(one_m * state.maxabs, jnp.abs(alpha_new))
    stall = jnp.where(step_inf <= cfg.tol, state.stall + 1, 0)

    return LogisticState(
        beta=beta, scale=scale, margin=margin, maxabs=maxabs,
        step_inf=step_inf, stall=stall,
        n_dots=state.n_dots + idx.shape[0] + n_bisect + 2,
        k=state.k + 1, key=key,
    )


@functools.partial(jax.jit, static_argnames=("cfg",))
def logistic_solve(
    Xt: jax.Array,
    y: jax.Array,  # labels in {-1, +1}
    cfg: FWConfig,
    key: jax.Array,
    alpha0: Optional[jax.Array] = None,
) -> LogisticResult:
    p = Xt.shape[0]
    if alpha0 is None:
        beta = jnp.zeros((p,), Xt.dtype)
        margin = jnp.zeros_like(y)
        maxabs = jnp.zeros((), Xt.dtype)
    else:
        beta = alpha0.astype(Xt.dtype)
        margin = beta @ Xt
        maxabs = jnp.max(jnp.abs(beta))
    state0 = LogisticState(
        beta=beta, scale=jnp.ones((), Xt.dtype), margin=margin, maxabs=maxabs,
        step_inf=jnp.full((), jnp.inf, Xt.dtype), stall=jnp.zeros((), jnp.int32),
        n_dots=jnp.zeros((), jnp.int32), k=jnp.zeros((), jnp.int32), key=key,
    )
    patience = cfg.patience if cfg.sampling != "full" else 1

    def cond(s):
        return (s.k < cfg.max_iters) & (s.stall < patience)

    final = jax.lax.while_loop(cond, lambda s: logistic_step(Xt, y, s, cfg), state0)
    alpha = final.scale * final.beta
    return LogisticResult(
        alpha=alpha,
        objective=_loss(final.margin, y),
        iterations=final.k,
        n_dots=final.n_dots,
        active=jnp.sum(alpha != 0.0),
        converged=final.stall >= patience,
    )
