"""Backend-dispatched stochastic Frank-Wolfe engine (DESIGN.md §Engine).

ONE hot loop serves the whole solver family (lasso / logistic /
elastic-net) on all three backends ('xla' | 'pallas' | 'sparse'). The
paper presents the extensions as "easily obtained" from Algorithm 2 —
the randomized linear-minimization oracle and the O(m) state recursions
are identical; only the gradient-with-respect-to-state and the line
search change — and the engine encodes exactly that split:

* the ENGINE owns the iteration skeleton: PRNG stream, sampled-vertex
  selection (delegated to ``core.vertex``), the scaled-iterate
  beta/scale update with underflow renormalization, the
  ||alpha^{k+1}-alpha^k||_inf stopping statistic with patience, and the
  while_loop / scan / batched-lane drivers;
* a PROBLEM ORACLE supplies the objective-specific pieces through a
  small protocol (see below). Oracles are hashable frozen dataclasses,
  passed statically into the jitted entry points, so each
  (oracle, cfg) pair compiles exactly once and a traced ``delta``
  serves a whole regularization path per compile.

Oracle protocol — what a new objective must provide:

    needs_stats: bool          class attr; True to precompute ColStats
    extra_dots: int            per-step dot-product surcharge (accounting)
    init_co(y, v, beta, dtype, cfg)
                               co-state from X@alpha0 (``v``; None = cold)
    cograd(co, y) -> (m,)      w with sampled linear scores = -z_i^T w
    score_extra(beta, scale)   optional per-coordinate score shift
                               (idx-array -> addend), e.g. EN's +l2*a_i
    line_search(...)           -> (lam, no_progress, aux); ``no_progress``
                               feeds the stall counter (gap_rtol rule),
                               ``aux`` is forwarded to update_co
    update_co(...) -> co       the O(m)/O(1) state recursions + refresh
    objective(y, stats, co, cfg)
                               final objective value
    gap(Xt, y, alpha, delta, cfg)
                               certified FW duality gap at ``alpha``
                               (alpha^T grad + delta*||grad||_inf with the
                               oracle's OWN gradient — one full O(nnz)
                               pass; delegates to ``oracle_gap`` below)

``cfg`` reaches every reduction over the sample axis so one oracle
definition serves the single-device backends AND the mesh-sharded
'distributed' backend (repro.distributed): oracles touch the m axis only
through ``vertex.mdot`` / ``vertex.msum``, which psum over the "data"
mesh axis exactly when cfg says the distributed backend is active.

What the engine guarantees to oracles: the index stream is a pure
function of (key, cfg, p) shared by every backend ('uniform' replays
bit-identically across backends); padded coordinates (dense-kernel tail
rows, sparse tail features, padded ELL slots) score exactly zero and are
masked out of the argmax, so ``i_star < p`` always; ``beta``, ``stats``
and results stay at the true p regardless of backend padding.

``FWConfig.fuse_steps = K > 1`` turns both loop drivers into CHUNKED
drivers (DESIGN.md §Perf/§Stopping): each while_loop turn advances K
iterations in one dispatch — through the ``kernels/fused_step`` Pallas
megakernel (co-state and scalar recursions VMEM-resident across all K
steps) on the kernel backends, or a fori_loop over the unfused ``step``
elsewhere — and the stall/patience stopping rule is checked between
chunks (overshoot <= K-1; max_iters stays exact via in-chunk masking).
The megakernel emits per-step records that ``_fused_replay`` turns into
the O(p) coefficient updates with the unfused op sequence, keeping the
fused uniform-lasso trajectory bit-identical to fuse_steps=1.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import vertex
from repro.core.solver_config import FWConfig
from repro.obs import metrics as obs_metrics
from repro.obs import telemetry as obs_telemetry
from repro.resilience import validate as _validate
from repro.kernels.colstats.colstats import colstats as _colstats_kernel
from repro.sparse import ops as sparse_ops
from repro.sparse.matrix import SparseBlockMatrix


class ColStats(NamedTuple):
    """Per-column statistics precomputed once before the iterations (§4.2)."""

    zty: jax.Array  # (p,)  z_i^T y
    znorm2: jax.Array  # (p,)  ||z_i||^2
    yty: jax.Array  # ()    y^T y


class EngineState(NamedTuple):
    """Loop state shared by every oracle. ``alpha = scale * beta``; ``co``
    is the oracle's co-state pytree (residual/margin + scalar recursions)."""

    beta: jax.Array  # (p,) unscaled coefficients
    scale: jax.Array  # ()  multiplicative scale
    co: Any  # oracle co-state (NamedTuple pytree)
    maxabs: jax.Array  # ()  running upper bound on ||alpha||_inf
    step_inf: jax.Array  # ()  ||alpha^{k+1} - alpha^k||_inf (bound)
    stall: jax.Array  # ()  consecutive sub-tolerance steps
    n_dots: jax.Array  # ()  length-m dot products consumed so far
    k: jax.Array  # ()  iteration counter
    key: jax.Array  # PRNG key
    # step-rule-owned pytree slot (DESIGN.md §StepRule): () for classic,
    # the active-set buffer for away/pairwise, (alpha_prev, X alpha_prev)
    # for partan, (winner cache, phi) for lazy
    rule: Any = ()
    # telemetry ring (DESIGN.md §Observability): () when
    # cfg.telemetry is None — a leafless pytree, so the default loop
    # carry (and jaxpr) is unchanged — else an obs.TelemetryRing filled
    # per iteration by the step / step rules / fused replay
    tel: Any = ()


class SolveResult(NamedTuple):
    alpha: jax.Array
    objective: jax.Array
    iterations: jax.Array
    n_dots: jax.Array
    active: jax.Array  # () number of nonzero coefficients
    converged: jax.Array
    # certified FW duality gap at alpha (cfg.report_gap; None otherwise)
    gap: Optional[jax.Array] = None
    # iterations actually advanced per dispatch: cfg.fuse_steps when the
    # fused chunk engaged, else 1 (the distributed driver forces 1, and
    # non-classic step rules / non-fusable oracles fall back) — callers
    # can tell what actually ran without re-deriving the gating
    effective_fuse_steps: Optional[jax.Array] = None
    # the final telemetry ring when cfg.telemetry is set (None otherwise);
    # lane-axis-batched from solve_batched. Decode on the host with
    # obs.telemetry.ring_to_records
    telemetry: Optional[Any] = None


def precompute_colstats(
    Xt, y: jax.Array, cfg: Optional[FWConfig] = None
) -> ColStats:
    """One full pass over X: z_i^T y and ||z_i||^2 for every column (§4.2).

    With ``cfg.backend == 'pallas'`` the fused single-sweep kernel
    (repro.kernels.colstats) computes both statistics in one HBM pass.
    A SparseBlockMatrix sweeps its stored slots only — O(nnz), not
    O(p*m) — through the fused ``kernels/sparse_colstats`` Pallas twin
    when the sparse-kernel dispatch is on (TPU auto / forced by cfg).
    """
    if isinstance(Xt, SparseBlockMatrix):
        if cfg is not None:
            zty, znorm2 = sparse_ops.sparse_colstats(
                Xt,
                y,
                use_kernel=vertex.use_sparse_kernel(cfg),
                interpret=vertex.use_interpret(cfg),
                gather_mode=vertex.resolve_gather_mode(cfg),
            )
        else:
            zty, znorm2 = sparse_ops.sparse_colstats(Xt, y)
        return ColStats(zty=zty, znorm2=znorm2, yty=jnp.dot(y, y))
    if cfg is not None and cfg.backend == "pallas":
        zty, znorm2 = _colstats_kernel(
            Xt, y, m_tile=cfg.m_tile, interpret=vertex.use_interpret(cfg)
        )
    else:
        zty = Xt @ y
        # fused row-norm contraction: XLA lowers the einsum to a reduce
        # without materializing the O(p*m) squared temporary that
        # ``jnp.sum(Xt * Xt, axis=1)`` pays on the non-pallas path
        znorm2 = jnp.einsum("pm,pm->p", Xt, Xt)
    return ColStats(zty=zty, znorm2=znorm2, yty=jnp.dot(y, y))


def _patience(cfg: FWConfig) -> int:
    return cfg.patience if cfg.sampling != "full" else 1


def dot_dtype():
    """Accounting dtype of the ``n_dots`` counter. int32 overflows at the
    paper's scale (p = 4M with ``sampling='full'`` wraps after ~500
    iterations), so the counter is widened: exact int64 when the host
    enables x64, float32 otherwise — overflow-free and monotone, exact up
    to 2^24 and magnitude-correct beyond (JAX silently demotes 64-bit
    dtypes without the x64 flag, so requesting int64 unconditionally
    would quietly hand back the int32 this replaces)."""
    return jnp.int64 if jax.config.jax_enable_x64 else jnp.float32


def init_state(oracle, Xt, y, key, alpha0=None, cfg=None, p=None) -> EngineState:
    """Start from the null solution, or warm-start from ``alpha0``.

    ``p`` overrides the feature count read off ``Xt`` — the distributed
    driver passes the GLOBAL p while ``Xt`` is a local shard; ``cfg``
    reaches the warm-start matvec and the oracle co-state init so their
    sample-axis reductions complete across the mesh.
    """
    p = Xt.shape[0] if p is None else p
    dtype = Xt.dtype
    if alpha0 is None:
        beta = jnp.zeros((p,), dtype)
        v = None
        maxabs = jnp.zeros((), dtype)
    else:
        beta = alpha0.astype(dtype)
        v = vertex.matvec(Xt, beta, cfg)  # X alpha, O(nnz) sparse
        maxabs = jnp.max(jnp.abs(beta))
    co = oracle.init_co(y, v, beta, dtype, cfg)
    rule_state: Any = ()
    if cfg is not None and cfg.step_rule != "classic":
        # lazy import: the rules layer on top of the engine (§StepRule)
        from repro.core import step_rule as step_rule_lib

        rule_state = step_rule_lib.get_rule(cfg).init_state(
            oracle, cfg, beta, co, y
        )
    tel: Any = ()
    if cfg is not None and cfg.telemetry is not None:
        tel = obs_telemetry.init_ring(cfg.telemetry)
    return EngineState(
        beta=beta,
        scale=jnp.ones((), dtype),
        co=co,
        maxabs=maxabs,
        step_inf=jnp.full((), jnp.inf, dtype),
        stall=jnp.zeros((), jnp.int32),
        n_dots=jnp.zeros((), dot_dtype()),
        k=jnp.zeros((), jnp.int32),
        key=key,
        rule=rule_state,
        tel=tel,
    )


def apply_coeff_update(beta, scale, maxabs, stall, a_star, i_star, lam,
                       delta_t, no_progress, cfg: FWConfig):
    """Steps 5 + stopping statistics of the FW iteration: the scaled-
    iterate coefficient update with underflow renorm, and the
    ||alpha^{k+1}-alpha^k||_inf bound / stall bookkeeping (§Stopping).

    ONE definition shared by the unfused ``step`` and the fused chunk's
    ``_fused_replay`` — the fused bit-identity contract (DESIGN.md
    §Perf) depends on the two paths executing this exact op sequence.
    Returns ``(beta, scale, maxabs, step_inf, stall)``.
    """
    one_m = 1.0 - lam
    new_scale = scale * one_m
    # renormalize when the scale underflows (rare O(p) event)
    need_renorm = new_scale < cfg.renorm_threshold
    beta, scale = jax.lax.cond(
        need_renorm,
        lambda b, s: (b * s, jnp.ones((), b.dtype)),
        lambda b, s: (b, s),
        beta,
        new_scale,
    )
    beta = beta.at[i_star].add(delta_t * lam / jnp.maximum(scale, cfg.eps_den))
    # stopping statistic: ||alpha_{k+1} - alpha_k||_inf upper bound
    alpha_istar_new = scale * beta[i_star]
    step_inf = lam * jnp.maximum(maxabs, jnp.abs(delta_t - a_star))
    maxabs = jnp.maximum(one_m * maxabs, jnp.abs(alpha_istar_new))
    stall = jnp.where((step_inf <= cfg.tol) | no_progress, stall + 1, 0)
    return beta, scale, maxabs, step_inf, stall


def step(oracle, Xt, y, stats, state: EngineState, cfg: FWConfig, delta) -> EngineState:
    """One randomized Frank-Wolfe step (paper Algorithm 2, any oracle).

    ``delta`` may be a traced array: the l1 radius enters the math only
    through scalar formulas, so keeping it dynamic lets a whole
    regularization path reuse ONE compiled solver (§Perf). ``Xt`` may be
    feature-padded (``vertex.pad_backend_matrix``); ``beta`` and
    ``stats`` stay at the true p.
    """
    p = state.beta.shape[0]
    key, sub = jax.random.split(state.key)

    # -- step 2: score the sampled coordinates against the co-gradient ------
    w = oracle.cograd(state.co, y)
    extra_fn = oracle.score_extra(state.beta, state.scale)
    i_star, g_raw, g_sel, n_scored = vertex.sample_vertex(
        Xt, w, sub, p, cfg, extra_fn
    )

    # -- step 3: FW vertex sign (eq. 6) -------------------------------------
    delta_t = -delta * jnp.sign(g_sel)  # delta-tilde

    # -- step 4: oracle line search (closed-form eq. 8, or bisection) -------
    a_star = state.scale * state.beta[i_star]
    lam, no_progress, aux = oracle.line_search(
        Xt, y, stats, state.co, i_star, g_raw, g_sel, a_star, delta_t, cfg
    )

    # -- step 5 + §Stopping statistics (shared with the fused replay) ------
    beta, scale, maxabs, step_inf, stall = apply_coeff_update(
        state.beta, state.scale, state.maxabs, state.stall, a_star, i_star,
        lam, delta_t, no_progress, cfg,
    )

    # -- step 6: oracle state recursions (eq. 10 / margin + S/F/Q + refresh)
    co = oracle.update_co(
        Xt, y, stats, state.co, beta, scale, i_star, a_star, lam, delta_t,
        state.k, cfg, aux,
    )

    n_dots = state.n_dots + n_scored + oracle.extra_dots
    tel = state.tel
    if cfg is not None and cfg.telemetry is not None:
        # sampled FW duality gap -<grad, delta_t e_i - alpha> =
        # <grad, alpha> - delta_t * sel_i — for the closed-form oracles
        # this IS the line-search numerator (O(1) scalars; logistic pays
        # one O(m) reduction per recorded objective)
        if cfg.telemetry.record_objective:
            gap = (
                oracle.grad_dot_alpha(
                    state.co, stats, y, state.beta, state.scale, cfg
                )
                - delta_t * g_sel
            )
            objective = oracle.objective(y, stats, co, cfg)
        else:
            gap = objective = jnp.nan
        tel = obs_telemetry.record(
            tel, k=state.k, i_star=i_star, event=obs_telemetry.EVENT_FW,
            lam=lam, gap=gap, objective=objective, step_inf=step_inf,
            stall=stall, n_dots=n_dots,
        )

    return EngineState(
        beta=beta,
        scale=scale,
        co=co,
        maxabs=maxabs,
        step_inf=step_inf,
        stall=stall,
        n_dots=n_dots,
        k=state.k + 1,
        key=key,
        rule=state.rule,
        tel=tel,
    )


def rule_step(oracle, Xt, y, stats, state: EngineState, cfg: FWConfig,
              delta) -> EngineState:
    """One iteration under the configured step rule (DESIGN.md §StepRule).

    ``classic`` IS ``step`` — same function, same jaxpr, so the default
    trajectory stays bit-identical to the pre-rule engine. The other
    rules dispatch through ``core.step_rule`` (lazy import: the rules
    layer on top of the engine and would otherwise cycle)."""
    if cfg is None or cfg.step_rule == "classic":
        return step(oracle, Xt, y, stats, state, cfg, delta)
    from repro.core import step_rule as step_rule_lib

    return step_rule_lib.get_rule(cfg).step(
        oracle, Xt, y, stats, state, cfg, delta
    )


# --------------------------------------------------------------------------
# Fused multi-step chunks (FWConfig.fuse_steps > 1, DESIGN.md §Perf)
# --------------------------------------------------------------------------


def _fused_streams(oracle, stats, state: EngineState, cfg: FWConfig, p: int):
    """Pregenerate the chunk's K x kappa uniform index stream — replaying
    the unfused per-step (split, randint) chain exactly, so the stream
    stays the same pure function of (key, cfg, p) on every path — plus
    the pregathered per-coordinate column statistics and (for oracles
    whose line search needs live alpha values) the chunk-start alpha at
    the sampled coordinates."""

    def draw(key, _):
        key, sub = jax.random.split(key)
        return key, jax.random.randint(sub, (cfg.kappa,), 0, p)

    key_new, idx = jax.lax.scan(draw, state.key, None, length=cfg.fuse_steps)
    zty_s = jnp.take(stats.zty, idx).astype(jnp.float32)
    zn2_s = jnp.take(stats.znorm2, idx).astype(jnp.float32)
    alpha_s = None
    if oracle.fused_needs_alpha:
        alpha_s = (state.scale * jnp.take(state.beta, idx)).astype(jnp.float32)
    return key_new, idx, zty_s, zn2_s, alpha_s


def _fused_replay(oracle, state: EngineState, cfg: FWConfig, i_stars, lams,
                  delta_ts, no_progs):
    """Replay the kernel's per-step records into the O(p) coefficient
    updates and the stopping statistics — through the SAME
    ``apply_coeff_update`` the unfused step runs, which is what keeps
    the fused lasso trajectory bit-identical to fuse_steps=1. Steps at
    k >= max_iters are skipped (max_iters never overshoots).

    With telemetry on, the replay is also where the megakernel's
    per-step records are plumbed into the ring (one record per live
    step; objective/gap are NaN here — the kernel emits no per-step
    objective, which is why ``record_objective`` routes the chunk to the
    fori-of-step executor instead)."""
    telemetry_on = cfg.telemetry is not None
    per_step_dots = cfg.kappa + oracle.extra_dots

    def apply(c, t):
        beta, scale, maxabs, step_inf, stall, k, tel = c
        i_star, lam, delta_t = i_stars[t], lams[t], delta_ts[t]
        a_star = scale * beta[i_star]
        beta, scale, maxabs, step_inf, stall = apply_coeff_update(
            beta, scale, maxabs, stall, a_star, i_star, lam, delta_t,
            no_progs[t], cfg,
        )
        if telemetry_on:
            tel = obs_telemetry.record(
                tel, k=k, i_star=i_star, event=obs_telemetry.EVENT_FW,
                lam=lam, gap=jnp.nan, objective=jnp.nan, step_inf=step_inf,
                stall=stall,
                n_dots=state.n_dots + (k + 1 - state.k) * per_step_dots,
            )
        return beta, scale, maxabs, step_inf, stall, k + 1, tel

    def body(t, c):
        return jax.lax.cond(c[5] < cfg.max_iters, lambda: apply(c, t), lambda: c)

    init = (state.beta, state.scale, state.maxabs, state.step_inf,
            state.stall, state.k, state.tel)
    return jax.lax.fori_loop(0, cfg.fuse_steps, body, init)


def _fused_kernel_chunk(oracle, Xt_run, y, stats, state: EngineState,
                        cfg: FWConfig, delta) -> EngineState:
    """One K-step chunk through the ``kernels/fused_step`` megakernel:
    pregenerate/pregather the streams, run the K fused iterations with
    the co-state VMEM-resident, then replay the emitted step records
    into the coefficient/stopping state."""
    p = state.beta.shape[0]
    key_new, idx, zty_s, zn2_s, alpha_s = _fused_streams(
        oracle, stats, state, cfg, p
    )
    resid0, scal0 = oracle.fused_pack_co(state.co)
    i_stars, lams, delta_ts, no_progs, resid_out, scal_out = (
        vertex.run_fused_kernel(
            oracle, Xt_run, y, resid0, scal0, idx, zty_s, zn2_s, alpha_s,
            state.k, delta, cfg,
        )
    )
    beta, scale, maxabs, step_inf, stall, k_new, tel = _fused_replay(
        oracle, state, cfg, i_stars, lams, delta_ts, no_progs
    )
    co = oracle.fused_unpack_co(resid_out.astype(resid0.dtype), scal_out)
    if oracle.fused_needs_alpha:
        # the in-kernel Q recursion has no beta for the periodic exact
        # refresh; reconcile it at chunk granularity when the chunk
        # crossed a refresh boundary (drift window <= refresh_every + K)
        steps = state.k + jnp.arange(cfg.fuse_steps)
        hit = jnp.any(
            ((steps % cfg.refresh_every) == cfg.refresh_every - 1)
            & (steps < cfg.max_iters)
        )
        q_exact = jnp.dot(beta, beta) * scale**2
        co = co._replace(
            q_norm=jnp.where(hit, q_exact, co.q_norm).astype(co.q_norm.dtype)
        )
    n_active = k_new - state.k
    n_dots = state.n_dots + (
        n_active * (cfg.kappa + oracle.extra_dots)
    ).astype(state.n_dots.dtype)
    return EngineState(
        beta=beta,
        scale=scale,
        co=co,
        maxabs=maxabs,
        step_inf=step_inf,
        stall=stall,
        n_dots=n_dots,
        k=k_new,
        key=key_new,
        rule=state.rule,
        tel=tel,
    )


def _fused_ref_chunk(oracle, Xt_run, y, stats, state: EngineState,
                     cfg: FWConfig, delta) -> EngineState:
    """The non-kernel chunk executor: K unfused engine steps under one
    fori_loop — bit-exact vs fuse_steps=1 by construction. Steps past
    max_iters are skipped; the §Stopping check is the caller's (between
    chunks)."""

    def body(t, s):
        return jax.lax.cond(
            s.k < cfg.max_iters,
            lambda st: step(oracle, Xt_run, y, stats, st, cfg, delta),
            lambda st: st,
            s,
        )

    return jax.lax.fori_loop(0, cfg.fuse_steps, body, state)


def fused_chunk(oracle, Xt_run, y, stats, state: EngineState, cfg: FWConfig,
                delta) -> EngineState:
    """Advance K = cfg.fuse_steps iterations in one dispatch (megakernel
    on the kernel backends, fori_loop of ``step`` elsewhere).

    ``telemetry.record_objective`` routes kernel backends to the
    fori-of-step executor too: the megakernel's per-step records carry
    (i_star, lam, stall) but no objective/gap scalars, and the ref
    executor is bit-identical by construction — chunked dispatch (and
    its K-fold stopping-check savings) is preserved either way."""
    needs_per_step = cfg.telemetry is not None and cfg.telemetry.record_objective
    if vertex.use_fused_kernel(cfg) and not needs_per_step:
        return _fused_kernel_chunk(oracle, Xt_run, y, stats, state, cfg, delta)
    return _fused_ref_chunk(oracle, Xt_run, y, stats, state, cfg, delta)


def certified_gap(oracle, Xt, y, co, beta, scale, delta, cfg=None) -> jax.Array:
    """Exact FW duality gap g(alpha) = alpha^T grad + delta*||grad||_inf
    from a live co-state — one full-gradient pass (O(nnz) sparse,
    O(p*m) dense), certification only, never the hot loop.

    Oracle-generic: the gradient is the linear part -X^T w (w = the
    oracle's co-gradient) plus its ``score_extra`` shift over every
    coordinate (the elastic-net's +l2*alpha). Under the distributed
    backend the gradient assembles via psum/all_gather and the returned
    scalar is replicated on every shard.
    """
    p = beta.shape[0]
    w = oracle.cograd(co, y)
    grad = vertex.grad_full(Xt, w, cfg)[:p]  # Xt may be backend-padded
    extra_fn = oracle.score_extra(beta, scale)
    if extra_fn is not None:
        grad = grad + extra_fn(jnp.arange(p))
    alpha = scale * beta
    return jnp.dot(alpha, grad) + delta * jnp.max(jnp.abs(grad))


def oracle_gap(oracle, Xt, y, alpha, delta, cfg=None) -> jax.Array:
    """Certified duality gap at a bare coefficient vector: rebuild the
    oracle co-state from X alpha, then ``certified_gap``. This is the
    shared implementation behind every oracle's ``gap()`` protocol
    method (replaces the lasso-only ``duality_gap`` special case)."""
    v = vertex.matvec(Xt, alpha, cfg)
    co = oracle.init_co(y, v, alpha, alpha.dtype, cfg)
    return certified_gap(
        oracle, Xt, y, co, alpha, jnp.ones((), alpha.dtype), delta, cfg
    )


def run_loop(oracle, Xt_run, y, stats, state0, cfg, delta, patience):
    """The sequential while_loop shared by ``solve`` and the distributed
    driver: step until the §Stopping rule fires or max_iters.

    With ``cfg.fuse_steps = K > 1`` (and a fusable oracle/sampling mode,
    ``vertex.fused_supported``) each loop turn advances a K-step fused
    chunk and the stall/patience rule is only checked BETWEEN chunks, so
    convergence stops may overshoot by at most K-1 iterations (max_iters
    stays exact — trailing chunk steps are masked; DESIGN.md §Stopping).
    """
    fused = vertex.fused_supported(oracle, cfg)
    spec = cfg.telemetry if cfg is not None else None
    # host streaming is a sequential-single-device feature: the batched
    # driver keeps lane rings device-resident, and under shard_map a
    # callback would fire per mesh cell
    stream = (
        spec is not None
        and spec.stream_to is not None
        and cfg.backend != "distributed"
    )

    def cond(state: EngineState):
        return (state.k < cfg.max_iters) & (state.stall < patience)

    def body(state: EngineState):
        if fused:
            new = fused_chunk(oracle, Xt_run, y, stats, state, cfg, delta)
        else:
            new = rule_step(oracle, Xt_run, y, stats, state, cfg, delta)
        if stream:
            # chunk-boundary flush (fires only when the ring would wrap;
            # jax.debug.callback — no blocking host sync in the loop)
            new = new._replace(
                tel=obs_telemetry.stream_flush(new.tel, spec, final=False)
            )
        return new

    return jax.lax.while_loop(cond, body, state0)


def history_patience(n_iters: int) -> int:
    """The patience ``solve_with_history`` runs the loop with: stall can
    reach at most n_iters, so n_iters + 1 never stops early — the run
    executes exactly n_iters steps (the old fixed-length scan's
    semantics) while still going through the ONE shared ``run_loop``."""
    return int(n_iters) + 1


def _effective_fuse_steps(oracle, cfg) -> int:
    """What one loop dispatch actually advances: cfg.fuse_steps when the
    fused chunk engages (``vertex.fused_supported``), else 1 — surfaced
    on SolveResult so callers can tell what ran (the distributed driver
    forces 1; non-classic rules / bisection oracles fall back)."""
    if cfg is None:
        return 1
    return cfg.fuse_steps if vertex.fused_supported(oracle, cfg) else 1


def _result(
    oracle, Xt, y, stats, final: EngineState, patience: int, cfg, delta
) -> SolveResult:
    alpha = final.scale * final.beta
    gap = None
    if cfg is not None and cfg.report_gap:
        gap = certified_gap(
            oracle, Xt, y, final.co, final.beta, final.scale, delta, cfg
        )
    tel = None
    if cfg is not None and cfg.telemetry is not None:
        tel = final.tel
        if (
            cfg.telemetry.stream_to is not None
            and cfg.backend != "distributed"
        ):
            # drain whatever the chunk-boundary flushes haven't shipped
            tel = obs_telemetry.stream_flush(tel, cfg.telemetry, final=True)
    return SolveResult(
        alpha=alpha,
        objective=oracle.objective(y, stats, final.co, cfg),
        iterations=final.k,
        n_dots=final.n_dots,
        active=jnp.sum(alpha != 0.0),
        converged=final.stall >= patience,
        gap=gap,
        effective_fuse_steps=jnp.asarray(
            _effective_fuse_steps(oracle, cfg), jnp.int32
        ),
        telemetry=tel,
    )


@functools.partial(jax.jit, static_argnames=("oracle", "cfg"))
def solve(
    oracle,
    Xt,
    y: jax.Array,
    cfg: FWConfig,
    key: jax.Array,
    alpha0: Optional[jax.Array] = None,
    delta=None,
) -> SolveResult:
    """Run the oracle's Algorithm-2 analogue until
    ||alpha_{k+1}-alpha_k||_inf <= tol for ``patience`` consecutive
    iterations, or max_iters. ``delta`` (traced) overrides cfg.delta —
    one compile serves the whole path."""
    vertex.check_matrix_backend(Xt, cfg)
    delta = jnp.asarray(cfg.delta if delta is None else delta)
    stats = precompute_colstats(Xt, y, cfg) if oracle.needs_stats else None
    state0 = init_state(oracle, Xt, y, key, alpha0, cfg)
    patience = _patience(cfg)
    Xt = vertex.pad_backend_matrix(Xt, cfg)  # once, outside the hot loop
    final = run_loop(oracle, Xt, y, stats, state0, cfg, delta, patience)
    return _result(oracle, Xt, y, stats, final, patience, cfg, delta)


@functools.partial(jax.jit, static_argnames=("oracle", "cfg", "n_iters"))
def solve_with_history(
    oracle,
    Xt,
    y: jax.Array,
    cfg: FWConfig,
    key: jax.Array,
    n_iters: int,
    alpha0: Optional[jax.Array] = None,
):
    """Fixed-iteration run recording the objective per step (convergence
    plots). Returns (result, objective_history[n_iters]).

    Implemented ON the telemetry ring (DESIGN.md §Observability): the
    run is ``run_loop`` with a capacity-``n_iters`` history ring and
    ``history_patience`` (never stops early), so the step sequence is
    the regular solver's — fused chunks included, via the bit-identical
    fori-of-step executor that ``record_objective`` forces — and the
    history is ``telemetry.objective`` in iteration order (capacity ==
    n_iters means the ring never wraps: slot t is iteration t)."""
    hcfg = dataclasses.replace(
        cfg,
        max_iters=n_iters,
        telemetry=obs_telemetry.history_spec(cfg.telemetry, n_iters),
    )
    vertex.check_matrix_backend(Xt, hcfg)
    stats = precompute_colstats(Xt, y, hcfg) if oracle.needs_stats else None
    state0 = init_state(oracle, Xt, y, key, alpha0, hcfg)
    Xt_run = vertex.pad_backend_matrix(Xt, hcfg)
    delta = jnp.asarray(cfg.delta)
    final = run_loop(
        oracle, Xt_run, y, stats, state0, hcfg, delta, history_patience(n_iters)
    )
    hist = final.tel.objective[:n_iters]
    res = _result(oracle, Xt_run, y, stats, final, _patience(cfg), hcfg, delta)
    return res, hist


def _lane_mask(active: jax.Array, leaf: jax.Array) -> jax.Array:
    """Broadcast a (lanes,) bool against a (lanes, ...) state leaf."""
    return active.reshape(active.shape + (1,) * (leaf.ndim - 1))


def batched_loop(oracle, Xt_run, y, stats, states0, cfg, deltas, patience):
    """The lane-pruned while_loop shared by ``solve_batched`` and the
    distributed driver (repro.distributed.driver runs it inside its
    shard_map with per-shard operands). Returns (final states, saved).

    Under ``cfg.fuse_steps = K > 1`` every loop turn advances each active
    lane by one K-step chunk (through the XLA reference executor — the
    lanes already vmap the per-step backend kernels, and chunking them
    keeps that unchanged while cutting the lane-sync/stopping checks by
    K); converged lanes freeze at chunk granularity, so per-lane results
    equal the sequential fused solver's, overshoot <= K-1 included.
    """
    fused = vertex.fused_supported(oracle, cfg)
    chunk_len = cfg.fuse_steps if fused else 1

    def advance(s, d):
        if fused:
            return _fused_ref_chunk(oracle, Xt_run, y, stats, s, cfg, d)
        return rule_step(oracle, Xt_run, y, stats, s, cfg, d)

    def lane_active(states):
        return (states.k < cfg.max_iters) & (states.stall < patience)

    def cond(carry):
        states, _ = carry
        return jnp.any(lane_active(states))

    def body(carry):
        states, saved = carry
        active = lane_active(states)
        stepped = jax.vmap(advance)(states, deltas)
        merged = jax.tree_util.tree_map(
            lambda n, o: jnp.where(_lane_mask(active, n), n, o), stepped, states
        )
        return merged, saved + jnp.sum((~active).astype(jnp.int32)) * chunk_len

    return jax.lax.while_loop(cond, body, (states0, jnp.zeros((), jnp.int32)))


def batched_result(oracle, Xt_run, y, stats, final, patience, cfg, deltas):
    """Assemble the per-lane SolveResult (shared with the distributed
    driver); certified per-lane gaps when ``cfg.report_gap``."""
    alpha = final.scale[:, None] * final.beta
    objective = jax.vmap(lambda co: oracle.objective(y, stats, co, cfg))(final.co)
    gap = None
    if cfg.report_gap:
        gap = jax.vmap(
            lambda co, b, s, d: certified_gap(oracle, Xt_run, y, co, b, s, d, cfg)
        )(final.co, final.beta, final.scale, deltas)
    return SolveResult(
        alpha=alpha,
        objective=objective,
        iterations=final.k,
        n_dots=final.n_dots,
        active=jnp.sum(alpha != 0.0, axis=1),
        converged=final.stall >= patience,
        gap=gap,
        effective_fuse_steps=jnp.asarray(
            _effective_fuse_steps(oracle, cfg), jnp.int32
        ),
        # lane-stacked rings (leading lane axis on every field)
        telemetry=final.tel if cfg.telemetry is not None else None,
    )


@functools.partial(jax.jit, static_argnames=("oracle", "cfg"))
def solve_batched(
    oracle,
    Xt,
    y: jax.Array,
    cfg: FWConfig,
    keys: jax.Array,
    alpha0s: jax.Array,
    deltas: jax.Array,
):
    """Solve a batch of lanes (one delta / key / warm start each) in ONE
    while_loop with per-lane early exit (DESIGN.md §Path).

    Unlike a plain vmap-of-while_loop, the lane states are batched
    explicitly: column statistics and init run once outside the lane
    axis, the loop condition is ``any(lane active)``, and converged lanes
    are frozen by a masked update — their PRNG stream, counters, and
    co-state stop advancing, so each lane's result is exactly what the
    sequential solver would produce. Returns ``(batched SolveResult,
    saved_iters)`` where ``saved_iters`` counts the lane-iterations NOT
    spent past each lane's own convergence (the pruning win vs running
    every lane to the slowest lane's stop).
    """
    vertex.check_matrix_backend(Xt, cfg)
    stats = precompute_colstats(Xt, y, cfg) if oracle.needs_stats else None
    states0 = jax.vmap(lambda k, a0: init_state(oracle, Xt, y, k, a0, cfg))(
        keys, alpha0s
    )
    patience = _patience(cfg)
    Xt_run = vertex.pad_backend_matrix(Xt, cfg)
    final, saved = batched_loop(
        oracle, Xt_run, y, stats, states0, cfg, deltas, patience
    )
    res = batched_result(oracle, Xt_run, y, stats, final, patience, cfg, deltas)
    return res, saved


# --------------------------------------------------------------------------
# Metrics-plane host shims (DESIGN.md §Observability)
# --------------------------------------------------------------------------


def _observe_solve(reg, entry: str, cfg: FWConfig, res: SolveResult,
                   elapsed_s: float) -> None:
    """Fold one finished entry-point dispatch into the metrics registry.

    Host-side only — runs AFTER the dispatch completes, never inside the
    jitted program, so installing a registry changes zero compiled bytes.
    Batched results count each lane individually in the totals; latency
    is per DISPATCH (the quantity the path driver amortizes)."""
    labels = dict(entry=entry, backend=cfg.backend, step_rule=cfg.step_rule)
    names = ("entry", "backend", "step_rule")
    iters = np.asarray(res.iterations, np.float64).reshape(-1)
    lanes = iters.size
    reg.counter(
        "fw_solves",
        "solver entry-point completions (batched lanes count individually)",
        names,
    ).inc(lanes, **labels)
    reg.counter(
        "fw_iterations", "FW iterations consumed across all solves", names
    ).inc(float(iters.sum()), **labels)
    reg.counter(
        "fw_n_dots", "length-m dot products consumed (paper's cost unit)",
        names,
    ).inc(float(np.asarray(res.n_dots, np.float64).sum()), **labels)
    n_conv = int(np.asarray(res.converged).reshape(-1).sum())
    outcomes = reg.counter(
        "fw_lane_outcomes",
        "lane stop reason: §Stopping rule ('converged') vs max_iters",
        names + ("outcome",),
    )
    if n_conv:
        outcomes.inc(n_conv, outcome="converged", **labels)
    if lanes - n_conv:
        outcomes.inc(lanes - n_conv, outcome="max_iters", **labels)
    reg.histogram(
        "fw_solve_latency_seconds",
        "wall time per entry-point dispatch, host-observed to completion",
        names,
    ).observe(elapsed_s, **labels)
    eff = 1
    if res.effective_fuse_steps is not None:
        eff = int(np.asarray(res.effective_fuse_steps).reshape(-1)[0])
    if cfg.fuse_steps > 1 and eff == 1:
        reg.counter(
            "fw_fused_fallback",
            "dispatches where fuse_steps>1 fell back to per-step loops "
            "(non-fusable oracle/sampling/rule)",
            names,
        ).inc(lanes, **labels)
    elif eff > 1:
        reg.counter(
            "fw_fused_chunks",
            "K-step fused chunks dispatched (lane-iterations / "
            "effective_fuse_steps)",
            names,
        ).inc(float(np.ceil(iters / eff).sum()), **labels)
    if res.gap is not None:
        gaps = np.asarray(res.gap, np.float64).reshape(-1)
        gaps = np.abs(gaps[np.isfinite(gaps)])
        if gaps.size:
            hist = reg.histogram(
                "fw_certified_gap",
                "certified FW duality gap at the returned iterate "
                "(cfg.report_gap)",
                names,
                buckets=obs_metrics.GAP_BUCKETS,
            )
            for g in gaps:
                hist.observe(float(g), **labels)


class _MetricsEntry:
    """Host shim over a jitted solver entry point.

    With no registry installed (the default) this is a straight
    pass-through — the compiled program and its dispatch path are
    untouched, which is what keeps the metrics-off contract as strong as
    the telemetry-off one. With a registry installed it times the
    dispatch to completion (``block_until_ready`` — jit calls return
    asynchronously) and folds totals/latency/gap into the registry.
    jit attributes (``_cache_size``, ``clear_cache``, ``lower``, ...)
    forward to the wrapped function, so cache bookkeeping like
    ``path.batched_solver_cache_size`` keeps working."""

    def __init__(self, fn, entry: str):
        self._fn = fn
        self._entry = entry
        self.__name__ = entry
        self.__doc__ = fn.__doc__
        self.__wrapped__ = fn

    def __call__(self, oracle, Xt, y, cfg, *args, **kwargs):
        # fail fast on NaN/Inf operands BEFORE tracing/compiling — a
        # poisoned matrix otherwise burns a silent max_iters run
        # (resilience/validate.py; REPRO_SKIP_INPUT_VALIDATION=1 opts out)
        _validate.validate_inputs(Xt, y)
        reg = obs_metrics.get_registry()
        if reg is None:
            return self._fn(oracle, Xt, y, cfg, *args, **kwargs)
        t0 = time.perf_counter()
        out = self._fn(oracle, Xt, y, cfg, *args, **kwargs)
        # solve returns a bare SolveResult; the history/batched entries
        # return (SolveResult, extra) — and SolveResult is itself a tuple
        res = out if isinstance(out, SolveResult) else out[0]
        jax.block_until_ready(res)
        _observe_solve(reg, self._entry, cfg, res, time.perf_counter() - t0)
        return out

    def __getattr__(self, name):
        return getattr(self._fn, name)


solve = _MetricsEntry(solve, "solve")
solve_with_history = _MetricsEntry(solve_with_history, "solve_with_history")
solve_batched = _MetricsEntry(solve_batched, "solve_batched")
