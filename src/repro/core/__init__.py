"""Core: the paper's contribution — stochastic Frank-Wolfe for the Lasso,
grown into a pluggable-oracle engine serving the whole solver family
(lasso / logistic / elastic-net) on every backend (DESIGN.md §Engine)."""
from repro.core.engine import ColStats, EngineState, SolveResult, precompute_colstats
from repro.core.fw_lasso import (
    LASSO,
    FWResult,
    FWState,
    LassoOracle,
    duality_gap,
    fw_solve,
    fw_solve_with_history,
    fw_step,
    init_state,
    objective,
)
from repro.core.fw_logistic import LOGISTIC, LogisticOracle, logistic_solve
from repro.core.fw_elasticnet import ENOracle, en_solve
from repro.core.solver_config import CDConfig, FISTAConfig, FWConfig
from repro.core import (
    baselines,
    engine,
    path,
    projections,
    sampling,
    step_rule,
    vertex,
)

__all__ = [
    "ColStats",
    "EngineState",
    "SolveResult",
    "FWResult",
    "FWState",
    "FWConfig",
    "CDConfig",
    "FISTAConfig",
    "LASSO",
    "LOGISTIC",
    "LassoOracle",
    "LogisticOracle",
    "ENOracle",
    "duality_gap",
    "fw_solve",
    "fw_solve_with_history",
    "fw_step",
    "init_state",
    "objective",
    "logistic_solve",
    "en_solve",
    "precompute_colstats",
    "baselines",
    "engine",
    "path",
    "projections",
    "sampling",
    "step_rule",
    "vertex",
]
