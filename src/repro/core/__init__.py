"""Core: the paper's contribution — stochastic Frank-Wolfe for the Lasso."""
from repro.core.fw_lasso import (
    ColStats,
    FWResult,
    FWState,
    duality_gap,
    fw_solve,
    fw_solve_with_history,
    fw_step,
    init_state,
    objective,
    precompute_colstats,
)
from repro.core.solver_config import CDConfig, FISTAConfig, FWConfig
from repro.core import baselines, path, projections, sampling

__all__ = [
    "ColStats",
    "FWResult",
    "FWState",
    "FWConfig",
    "CDConfig",
    "FISTAConfig",
    "duality_gap",
    "fw_solve",
    "fw_solve_with_history",
    "fw_step",
    "init_state",
    "objective",
    "precompute_colstats",
    "baselines",
    "path",
    "projections",
    "sampling",
]
