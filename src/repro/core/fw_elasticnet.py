"""ElasticNet problem oracle for the stochastic FW engine (paper §6).

    min_alpha  1/2 ||X a - y||^2 + (l2/2) ||a||^2   s.t.  ||a||_1 <= delta

The paper notes the extension is straightforward: the gradient gains a
+l2*a term and the exact line search stays closed-form. We keep the O(1)
scalar recursions by additionally tracking Q^k = ||a^k||^2:

    grad_i   = -z_i^T R + l2 * a_i
    d        = dt*e_i - a
    num      = -(grad^T d) = S - dt*g_x - F + l2*(Q - dt*a_i)     [g_x = X-part]
    den      = ||X d||^2 + l2*||d||^2
             = (S - 2 dt G + dt^2 ||z||^2) + l2*(Q - 2 dt a_i + dt^2)
    Q_{k+1}  = (1-l)^2 Q + 2 l (1-l) dt a_i + l^2 dt^2

The ``+l2 * a_i`` gradient term rides the engine's per-coordinate score
shift (``score_extra``), so the sampled-vertex dispatch — including the
Pallas kernels and the block-ELL sparse backend — is shared untouched
with the other oracles (DESIGN.md §Engine). Validated against FISTA on
the augmented design [X; sqrt(l2) I] (tests/test_extensions.py).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import engine, fw_lasso, vertex
from repro.core.solver_config import FWConfig

ENResult = engine.SolveResult


class ENCo(NamedTuple):
    """Elastic-net co-state: lasso recursions plus Q = ||a||^2."""

    resid: jax.Array  # (m,)
    s_quad: jax.Array  # ||X a||^2
    f_lin: jax.Array  # (X a)^T y
    q_norm: jax.Array  # ||a||^2


def en_ls_closed_form(
    l2, s_quad, f_lin, q_norm, g_x, g_lin, a_star, delta_t, zn2_i, eps_den, gap_rtol
):
    """The elastic-net closed-form line search as pure scalar algebra
    (kernel-composable, see ``fw_lasso.ls_closed_form``): shared by the
    unfused ``ENOracle.line_search`` and the fused megakernel. Returns
    ``(lam, no_progress)``; ``num`` is the sampled EN duality gap."""
    num = s_quad - delta_t * g_x - f_lin + l2 * (q_norm - delta_t * a_star)
    den = (
        s_quad - 2.0 * delta_t * g_lin + delta_t**2 * zn2_i
        + l2 * (q_norm - 2.0 * delta_t * a_star + delta_t**2)
    )
    lam = jnp.clip(num / jnp.maximum(den, eps_den), 0.0, 1.0)
    gap_scale = (
        s_quad + jnp.abs(f_lin) + jnp.abs(delta_t * g_x)
        + l2 * (q_norm + jnp.abs(delta_t * a_star))
    )
    no_progress = num <= gap_rtol * gap_scale
    return lam, no_progress


def q_recursion(q_norm, lam, delta_t, a_star):
    """The O(1) Q = ||a||^2 recursion — shared by ``ENOracle.update_co``
    and the fused megakernel's in-VMEM scalar update."""
    one_m = 1.0 - lam
    return (
        one_m**2 * q_norm
        + 2.0 * lam * one_m * delta_t * a_star
        + lam**2 * delta_t**2
    )


@dataclasses.dataclass(frozen=True)
class ENOracle:
    """Problem oracle: elastic-net over the l1 ball, l2 penalty strength
    baked in statically (it shapes the compiled line search)."""

    l2: float

    needs_stats = True
    extra_dots = 0
    # fused multi-step protocol: closed-form line search, but the score
    # shift / line search need live per-coordinate alpha values, which the
    # fused chunk reconstructs in alpha space (pregathered chunk-start
    # values + an in-VMEM correction ledger — DESIGN.md §Perf).
    fused_kind = "en"
    fused_needs_alpha = True

    def init_co(self, y, v, beta, dtype, cfg=None) -> ENCo:
        if v is None:
            zero = jnp.zeros((), dtype)
            return ENCo(resid=y.astype(dtype), s_quad=zero, f_lin=zero, q_norm=zero)
        return ENCo(
            resid=y - v,
            s_quad=vertex.mdot(v, v, cfg),
            f_lin=vertex.mdot(v, y, cfg),
            # beta is replicated under the distributed backend: plain dot
            q_norm=jnp.dot(beta, beta),
        )

    def cograd(self, co: ENCo, y):
        return co.resid

    def score_extra(self, beta, scale):
        """The +l2 * a_i gradient shift at the sampled coordinates."""
        return lambda idx: self.l2 * (scale * jnp.take(beta, idx))

    def line_search(
        self, Xt, y, stats, co: ENCo, i_star, g_raw, g_sel, a_star, delta_t, cfg
    ):
        g_x = g_raw  # X-part of the selected gradient coordinate
        g_lin = g_x + stats.zty[i_star]
        # ``num`` = -(grad^T d) IS the sampled FW duality gap for the
        # elastic-net objective; below the fp32 rounding floor of its own
        # terms the step is noise (gap_rtol stall, DESIGN.md §Stopping) —
        # this is what lets warm-started EN paths stop immediately.
        lam, no_progress = en_ls_closed_form(
            self.l2, co.s_quad, co.f_lin, co.q_norm, g_x, g_lin, a_star,
            delta_t, stats.znorm2[i_star], cfg.eps_den, cfg.gap_rtol,
        )
        return lam, no_progress, g_lin

    def update_co(
        self, Xt, y, stats, co: ENCo, beta, scale, i_star, a_star, lam,
        delta_t, k, cfg, aux,
    ) -> ENCo:
        resid = vertex.apply_column_update(Xt, co.resid, y, i_star, lam, delta_t, cfg)
        s_quad, f_lin, refresh = fw_lasso.sf_update(
            stats, co.s_quad, co.f_lin, resid, y, i_star, lam, delta_t,
            aux, k, cfg,
        )
        q_norm = q_recursion(co.q_norm, lam, delta_t, a_star)
        q_exact = jnp.dot(beta, beta) * scale**2
        q_norm = jnp.where(refresh, q_exact, q_norm)
        return ENCo(resid=resid, s_quad=s_quad, f_lin=f_lin, q_norm=q_norm)

    # ---- generalized direction protocol (DESIGN.md §StepRule) ----------
    # Same structure as the lasso's (d = t*alpha + df*e_f + da*e_a; see
    # fw_lasso) with the l2 terms layered on: <grad, alpha> gains +l2*Q,
    # the denominator gains l2*||d||^2 (pure scalar algebra in Q and the
    # per-coordinate alpha values carried on the DirStep), and Q gets the
    # generalized recursion. The selected scores already include the
    # +l2*a_i shift (score_extra / score_indices), so num needs no extra
    # l2 bookkeeping beyond the alpha-quadratic term.

    def co_linpred(self, co: ENCo, y):
        return y - co.resid

    def grad_dot_alpha(self, co: ENCo, stats, y, beta, scale, cfg):
        return co.s_quad - co.f_lin + self.l2 * co.q_norm

    def dir_line_search(self, y, stats, co: ENCo, ds, u_lin, cfg):
        v = y - co.resid
        vu = vertex.mdot(v, u_lin, cfg)
        uu = vertex.mdot(u_lin, u_lin, cfg)
        ga = co.s_quad - co.f_lin + self.l2 * co.q_norm
        num = -(ds.t * ga + ds.df * ds.sel_f + ds.da * ds.sel_a)
        # ||d||^2 = t^2 Q + 2t(df a_f + da a_a) + df^2 + da^2 + 2 df da [f==a]
        d2 = (
            ds.t**2 * co.q_norm
            + 2.0 * ds.t * (ds.df * ds.a_f + ds.da * ds.a_a)
            + ds.df**2 + ds.da**2 + 2.0 * ds.df * ds.da * ds.same
        )
        den = ds.t**2 * co.s_quad + 2.0 * ds.t * vu + uu + self.l2 * d2
        g = jnp.clip(num / jnp.maximum(den, cfg.eps_den), 0.0, ds.g_max)
        gap_scale = (
            jnp.abs(ds.t) * (co.s_quad + jnp.abs(co.f_lin) + self.l2 * co.q_norm)
            + jnp.abs(ds.df * ds.sel_f)
            + jnp.abs(ds.da * ds.sel_a)
        )
        no_progress = num <= cfg.gap_rtol * gap_scale
        return g, no_progress, (vu, uu)

    def dir_update_co(
        self, Xt, y, stats, co: ENCo, beta, scale, ds, g, u_lin, k, cfg, aux
    ) -> ENCo:
        vu, uu = aux
        gt = g * ds.t
        one_gt = 1.0 + gt
        resid = one_gt * co.resid - gt * y - g * u_lin
        s_quad = one_gt**2 * co.s_quad + 2.0 * one_gt * g * vu + g**2 * uu
        f_lin = one_gt * co.f_lin + g * vertex.mdot(u_lin, y, cfg)
        atom2 = ds.df**2 + ds.da**2 + 2.0 * ds.df * ds.da * ds.same
        q_norm = (
            one_gt**2 * co.q_norm
            + 2.0 * one_gt * g * (ds.df * ds.a_f + ds.da * ds.a_a)
            + g**2 * atom2
        )
        refresh = (k % cfg.refresh_every) == (cfg.refresh_every - 1)
        v = y - resid
        s_quad = jnp.where(refresh, vertex.mdot(v, v, cfg), s_quad)
        f_lin = jnp.where(refresh, vertex.mdot(v, y, cfg), f_lin)
        q_norm = jnp.where(refresh, jnp.dot(beta, beta) * scale**2, q_norm)
        return ENCo(resid=resid, s_quad=s_quad, f_lin=f_lin, q_norm=q_norm)

    # ---- PARTAN extrapolation protocol (DESIGN.md §StepRule) -----------

    def partan_mu(self, y, stats, co: ENCo, u_m, a_mid, dp, mu_max, cfg):
        """mu* = (<R,u> - l2 <a_mid, dp>) / (||u||^2 + l2 ||dp||^2)."""
        num = vertex.mdot(co.resid, u_m, cfg) - self.l2 * jnp.dot(a_mid, dp)
        den = vertex.mdot(u_m, u_m, cfg) + self.l2 * jnp.dot(dp, dp)
        return jnp.clip(num / jnp.maximum(den, cfg.eps_den), 0.0, mu_max)

    def partan_update_co(self, y, stats, co: ENCo, a_new, mu, u_m, cfg):
        resid = co.resid - mu * u_m
        v = y - resid
        return ENCo(
            resid=resid,
            s_quad=vertex.mdot(v, v, cfg),
            f_lin=vertex.mdot(v, y, cfg),
            q_norm=jnp.dot(a_new, a_new),
        )

    # ---- fused multi-step chunk protocol (DESIGN.md §Perf) -------------

    def fused_score_shift(self, alpha_i):
        """The +l2 * a_i gradient shift from the reconstructed alpha."""
        return self.l2 * alpha_i

    def fused_line_search(
        self, scal, g_raw, g_sel, a_star, delta_t, zty_i, zn2_i, eps_den, gap_rtol
    ):
        s_quad, f_lin, q_norm = scal
        g_lin = g_raw + zty_i
        lam, no_progress = en_ls_closed_form(
            self.l2, s_quad, f_lin, q_norm, g_raw, g_lin, a_star,
            delta_t, zn2_i, eps_den, gap_rtol,
        )
        return lam, no_progress, g_lin

    def fused_scalar_update(self, scal, g_lin, a_star, lam, delta_t, zty_i, zn2_i):
        s_quad, f_lin = fw_lasso.sf_recursion(
            scal[0], scal[1], g_lin, lam, delta_t, zty_i, zn2_i
        )
        return (s_quad, f_lin, q_recursion(scal[2], lam, delta_t, a_star))

    def fused_pack_co(self, co: ENCo):
        return co.resid, (co.s_quad, co.f_lin, co.q_norm)

    def fused_unpack_co(self, resid, scal) -> ENCo:
        d = resid.dtype
        return ENCo(
            resid=resid,
            s_quad=scal[0].astype(d),
            f_lin=scal[1].astype(d),
            q_norm=scal[2].astype(d),
        )

    def objective(self, y, stats, co: ENCo, cfg=None):
        return (
            0.5 * stats.yty + 0.5 * co.s_quad - co.f_lin
            + 0.5 * self.l2 * co.q_norm
        )

    def gap(self, Xt, y, alpha, delta, cfg=None):
        """Certified FW duality gap with the ELASTIC-NET gradient
        -X^T R + l2*alpha (the +l2 term rides score_extra) — oracle
        protocol."""
        return engine.oracle_gap(self, Xt, y, alpha, delta, cfg)


def en_solve(
    Xt,
    y: jax.Array,
    cfg: FWConfig,
    l2: float,
    key: jax.Array,
    alpha0: Optional[jax.Array] = None,
    delta=None,
) -> ENResult:
    """Elastic-net FW on any backend ('xla'|'pallas'|'sparse'). ``l2`` is
    static (one compile per strength); ``delta`` (traced) overrides
    cfg.delta so one compile serves a whole regularization path."""
    return engine.solve(ENOracle(l2=float(l2)), Xt, y, cfg, key, alpha0, delta)
