"""Stochastic Frank-Wolfe for the ElasticNet (paper §6 extension).

    min_alpha  1/2 ||X a - y||^2 + (l2/2) ||a||^2   s.t.  ||a||_1 <= delta

The paper notes the extension is straightforward: the gradient gains a
+l2*a term and the exact line search stays closed-form. We keep the O(1)
scalar recursions by additionally tracking Q^k = ||a^k||^2:

    grad_i   = -z_i^T R + l2 * a_i
    d        = dt*e_i - a
    num      = -(grad^T d) = S - dt*g_x - F + l2*(Q - dt*a_i)     [g_x = X-part]
    den      = ||X d||^2 + l2*||d||^2
             = (S - 2 dt G + dt^2 ||z||^2) + l2*(Q - 2 dt a_i + dt^2)
    Q_{k+1}  = (1-l)^2 Q + 2 l (1-l) dt a_i + l^2 dt^2

Validated against FISTA on the augmented design [X; sqrt(l2) I]
(tests/test_elasticnet.py).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.fw_lasso import ColStats, precompute_colstats, _sample_indices
from repro.core.solver_config import FWConfig


class ENState(NamedTuple):
    beta: jax.Array
    scale: jax.Array
    resid: jax.Array
    s_quad: jax.Array  # ||X a||^2
    f_lin: jax.Array  # (X a)^T y
    q_norm: jax.Array  # ||a||^2
    maxabs: jax.Array
    step_inf: jax.Array
    stall: jax.Array
    n_dots: jax.Array
    k: jax.Array
    key: jax.Array


class ENResult(NamedTuple):
    alpha: jax.Array
    objective: jax.Array  # full elastic-net objective
    iterations: jax.Array
    n_dots: jax.Array
    active: jax.Array
    converged: jax.Array


def en_step(Xt, y, stats: ColStats, state: ENState, cfg: FWConfig, l2: float) -> ENState:
    p = Xt.shape[0]
    key, sub = jax.random.split(state.key)
    idx = _sample_indices(sub, p, cfg)

    rows = jnp.take(Xt, idx, axis=0)
    alpha_idx = state.scale * jnp.take(state.beta, idx)
    grad_x = -(rows @ state.resid)  # X-part of gradient
    grad_s = grad_x + l2 * alpha_idx

    j = jnp.argmax(jnp.abs(grad_s))
    i_star = idx[j]
    g_star = grad_s[j]
    g_x = grad_x[j]
    a_star = alpha_idx[j]

    delta_t = -cfg.delta * jnp.sign(g_star)

    g_lin = g_x + stats.zty[i_star]
    num = (
        state.s_quad - delta_t * g_x - state.f_lin
        + l2 * (state.q_norm - delta_t * a_star)
    )
    den = (
        state.s_quad - 2.0 * delta_t * g_lin + delta_t**2 * stats.znorm2[i_star]
        + l2 * (state.q_norm - 2.0 * delta_t * a_star + delta_t**2)
    )
    lam = jnp.clip(num / jnp.maximum(den, cfg.eps_den), 0.0, 1.0)
    one_m = 1.0 - lam

    new_scale = state.scale * one_m
    need_renorm = new_scale < cfg.renorm_threshold
    beta, scale = jax.lax.cond(
        need_renorm,
        lambda b, s: (b * s, jnp.ones((), Xt.dtype)),
        lambda b, s: (b, s),
        state.beta,
        new_scale,
    )
    beta = beta.at[i_star].add(delta_t * lam / jnp.maximum(scale, cfg.eps_den))

    z_star = jax.lax.dynamic_slice_in_dim(Xt, i_star, 1, axis=0)[0]
    resid = one_m * state.resid + lam * (y - delta_t * z_star)

    s_quad = (
        one_m**2 * state.s_quad
        + 2.0 * delta_t * lam * one_m * g_lin
        + delta_t**2 * lam**2 * stats.znorm2[i_star]
    )
    f_lin = one_m * state.f_lin + delta_t * lam * stats.zty[i_star]
    q_norm = (
        one_m**2 * state.q_norm
        + 2.0 * lam * one_m * delta_t * a_star
        + lam**2 * delta_t**2
    )

    refresh = (state.k % cfg.refresh_every) == (cfg.refresh_every - 1)
    v = y - resid
    s_quad = jnp.where(refresh, jnp.dot(v, v), s_quad)
    f_lin = jnp.where(refresh, jnp.dot(v, y), f_lin)
    q_exact = jnp.dot(beta, beta) * scale**2
    q_norm = jnp.where(refresh, q_exact, q_norm)

    alpha_new = scale * beta[i_star]
    step_inf = lam * jnp.maximum(state.maxabs, jnp.abs(delta_t - a_star))
    maxabs = jnp.maximum(one_m * state.maxabs, jnp.abs(alpha_new))
    stall = jnp.where(step_inf <= cfg.tol, state.stall + 1, 0)

    return ENState(
        beta=beta, scale=scale, resid=resid, s_quad=s_quad, f_lin=f_lin,
        q_norm=q_norm, maxabs=maxabs, step_inf=step_inf, stall=stall,
        n_dots=state.n_dots + idx.shape[0], k=state.k + 1, key=key,
    )


@functools.partial(jax.jit, static_argnames=("cfg", "l2"))
def en_solve(
    Xt: jax.Array,
    y: jax.Array,
    cfg: FWConfig,
    l2: float,
    key: jax.Array,
    alpha0: Optional[jax.Array] = None,
) -> ENResult:
    p = Xt.shape[0]
    stats = precompute_colstats(Xt, y)
    if alpha0 is None:
        beta = jnp.zeros((p,), Xt.dtype)
        resid = y.astype(Xt.dtype)
        s_quad = f_lin = q_norm = maxabs = jnp.zeros((), Xt.dtype)
    else:
        beta = alpha0.astype(Xt.dtype)
        v = beta @ Xt
        resid = y - v
        s_quad = jnp.dot(v, v)
        f_lin = jnp.dot(v, y)
        q_norm = jnp.dot(beta, beta)
        maxabs = jnp.max(jnp.abs(beta))
    state0 = ENState(
        beta=beta, scale=jnp.ones((), Xt.dtype), resid=resid, s_quad=s_quad,
        f_lin=f_lin, q_norm=q_norm, maxabs=maxabs,
        step_inf=jnp.full((), jnp.inf, Xt.dtype), stall=jnp.zeros((), jnp.int32),
        n_dots=jnp.zeros((), jnp.int32), k=jnp.zeros((), jnp.int32), key=key,
    )
    patience = cfg.patience if cfg.sampling != "full" else 1

    def cond(s):
        return (s.k < cfg.max_iters) & (s.stall < patience)

    final = jax.lax.while_loop(cond, lambda s: en_step(Xt, y, stats, s, cfg, l2), state0)
    alpha = final.scale * final.beta
    obj = 0.5 * stats.yty + 0.5 * final.s_quad - final.f_lin + 0.5 * l2 * final.q_norm
    return ENResult(
        alpha=alpha, objective=obj, iterations=final.k, n_dots=final.n_dots,
        active=jnp.sum(alpha != 0.0), converged=final.stall >= patience,
    )
