"""Baseline Lasso solvers the paper compares against (Table 2 / §5).

  * Cyclic Coordinate Descent (Glmnet-style, Friedman et al. 2010) on the
    penalized form  1/2 ||X a - y||^2 + lam ||a||_1.
  * Stochastic Coordinate Descent (Shalev-Shwartz & Tewari 2011).
  * FISTA (accelerated proximal gradient) on the penalized form, and
    projected accelerated gradient on the constrained form (the SLEP pair).

All solvers take the design matrix FEATURE-MAJOR (``Xt``: (p, m), predictor
z_i = Xt[i]), maintain residuals, are fully jitted (lax loops), count
"requested dot products" in the paper's currency (length-m predictor dots;
a dense (m,p) matvec counts as p unit dots), and stop on the paper's
``||alpha_{t+1} - alpha_t||_inf <= eps`` rule.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.projections import project_l1_ball, soft_threshold
from repro.core.solver_config import CDConfig, FISTAConfig


class SolveResult(NamedTuple):
    alpha: jax.Array
    objective: jax.Array  # 1/2||Xa-y||^2 (fit term only, comparable across forms)
    iterations: jax.Array  # sweeps (CD), iters (FISTA)
    n_dots: jax.Array
    active: jax.Array
    converged: jax.Array


# ---------------------------------------------------------------------------
# Coordinate descent (cyclic + stochastic)
# ---------------------------------------------------------------------------


class _CDState(NamedTuple):
    alpha: jax.Array
    resid: jax.Array
    max_delta: jax.Array  # ||alpha_new - alpha_old||_inf within current sweep
    n_dots: jax.Array
    sweep: jax.Array
    key: jax.Array


@functools.partial(jax.jit, static_argnames=("cfg",))
def cd_solve(
    Xt: jax.Array,
    y: jax.Array,
    cfg: CDConfig,
    key: jax.Array,
    alpha0: Optional[jax.Array] = None,
    lam=None,
) -> SolveResult:
    """Glmnet-style coordinate descent with maintained residuals.

    Update (unit-norm columns not assumed):
        a_j <- S_lam( z_j^T R + a_j ||z_j||^2 ) / ||z_j||^2
    """
    p, m = Xt.shape
    lam = jnp.asarray(cfg.lam if lam is None else lam)  # traced: one compile per path
    znorm2 = jnp.sum(Xt * Xt, axis=1)
    alpha0 = jnp.zeros((p,), Xt.dtype) if alpha0 is None else alpha0.astype(Xt.dtype)
    resid0 = y - alpha0 @ Xt

    def coord_update(j, carry):
        alpha, resid, max_delta, n_dots = carry
        zj = Xt[j]
        aj = alpha[j]
        rho = jnp.dot(zj, resid) + aj * znorm2[j]
        aj_new = soft_threshold(rho, lam) / jnp.maximum(znorm2[j], 1e-12)
        d = aj_new - aj
        resid = resid - d * zj
        alpha = alpha.at[j].set(aj_new)
        max_delta = jnp.maximum(max_delta, jnp.abs(d))
        return alpha, resid, max_delta, n_dots + 1

    def sweep_body(state: _CDState) -> _CDState:
        key, sub = jax.random.split(state.key)
        if cfg.stochastic:
            order = jax.random.randint(sub, (p,), 0, p)
        else:
            order = jnp.arange(p)

        def body(t, carry):
            return coord_update(order[t], carry)

        alpha, resid, max_delta, n_dots = jax.lax.fori_loop(
            0, p, body, (state.alpha, state.resid, jnp.zeros((), Xt.dtype), state.n_dots)
        )
        return _CDState(alpha, resid, max_delta, n_dots, state.sweep + 1, key)

    def cond(state: _CDState):
        return (state.sweep < cfg.max_sweeps) & (state.max_delta > cfg.tol)

    init = _CDState(
        alpha=alpha0,
        resid=resid0,
        max_delta=jnp.full((), jnp.inf, Xt.dtype),
        n_dots=jnp.zeros((), jnp.int32),
        sweep=jnp.zeros((), jnp.int32),
        key=key,
    )
    final = jax.lax.while_loop(cond, sweep_body, init)
    return SolveResult(
        alpha=final.alpha,
        objective=0.5 * jnp.dot(final.resid, final.resid),
        iterations=final.sweep,
        n_dots=final.n_dots,
        active=jnp.sum(final.alpha != 0.0),
        converged=final.max_delta <= cfg.tol,
    )


# ---------------------------------------------------------------------------
# FISTA / projected accelerated gradient (the SLEP pair)
# ---------------------------------------------------------------------------


def estimate_lipschitz(Xt: jax.Array, iters: int, key: jax.Array) -> jax.Array:
    """Power iteration for L = ||X||_2^2 (largest eigenvalue of X^T X)."""
    p, m = Xt.shape
    v = jax.random.normal(key, (p,), Xt.dtype)

    def body(_, v):
        w = Xt @ (v @ Xt)  # X^T (X v)
        return w / jnp.maximum(jnp.linalg.norm(w), 1e-30)

    v = jax.lax.fori_loop(0, iters, body, v / jnp.linalg.norm(v))
    w = v @ Xt  # X v
    return jnp.dot(w, w)  # Rayleigh quotient with unit v


class _FistaState(NamedTuple):
    alpha: jax.Array
    z: jax.Array  # extrapolation point
    t: jax.Array
    step_inf: jax.Array
    n_dots: jax.Array
    k: jax.Array


@functools.partial(jax.jit, static_argnames=("cfg",))
def fista_solve(
    Xt: jax.Array,
    y: jax.Array,
    cfg: FISTAConfig,
    key: jax.Array,
    alpha0: Optional[jax.Array] = None,
    reg=None,
) -> SolveResult:
    """FISTA: prox = soft-threshold (penalized) or l1-ball projection.
    ``reg`` (traced) overrides cfg.lam / cfg.delta for path reuse."""
    p, m = Xt.shape
    reg = jnp.asarray((cfg.delta if cfg.constrained else cfg.lam) if reg is None else reg)
    L = estimate_lipschitz(Xt, cfg.power_iters, key) * 1.05  # safety margin
    alpha0 = jnp.zeros((p,), Xt.dtype) if alpha0 is None else alpha0.astype(Xt.dtype)

    def prox(v):
        if cfg.constrained:
            return project_l1_ball(v, reg)
        return soft_threshold(v, reg / L)

    def body(state: _FistaState) -> _FistaState:
        grad = Xt @ (state.z @ Xt - y)  # X^T (X z - y): 2 matvecs = 2p unit dots
        alpha_new = prox(state.z - grad / L)
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * state.t**2))
        z_new = alpha_new + ((state.t - 1.0) / t_new) * (alpha_new - state.alpha)
        step_inf = jnp.max(jnp.abs(alpha_new - state.alpha))
        return _FistaState(
            alpha=alpha_new,
            z=z_new,
            t=t_new,
            step_inf=step_inf,
            n_dots=state.n_dots + 2 * p,
            k=state.k + 1,
        )

    def cond(state: _FistaState):
        return (state.k < cfg.max_iters) & (state.step_inf > cfg.tol)

    init = _FistaState(
        alpha=alpha0,
        z=alpha0,
        t=jnp.ones((), Xt.dtype),
        step_inf=jnp.full((), jnp.inf, Xt.dtype),
        n_dots=jnp.asarray(2 * p * cfg.power_iters, jnp.int32),
        k=jnp.zeros((), jnp.int32),
    )
    final = jax.lax.while_loop(cond, body, init)
    resid = y - final.alpha @ Xt
    return SolveResult(
        alpha=final.alpha,
        objective=0.5 * jnp.dot(resid, resid),
        iterations=final.k,
        n_dots=final.n_dots,
        active=jnp.sum(final.alpha != 0.0),
        converged=final.step_inf <= cfg.tol,
    )
