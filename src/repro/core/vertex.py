"""Sampled-vertex dispatch shared by the whole FW solver family.

This module owns everything between "the oracle handed us an (m,)
co-gradient vector" and "here is the winning FW vertex": drawing the
sampling set S (paper §4.1/§4.5), scoring the sampled coordinates on the
selected backend ('xla' | 'pallas' | 'sparse'), and reducing to the
argmax. It is objective-agnostic: every score is the LINEAR form

    raw_i = -z_i^T w        (w = the oracle's co-gradient vector)

optionally shifted by a per-coordinate additive term ``extra_fn(idx)``
(the elastic-net's ``+l2 * alpha_i``). The lasso passes ``w = R`` and no
extra term, which replays the exact op sequence (and index stream) of
the pre-engine solver — see tests/test_engine.py for the bit-identity
regression. The logistic oracle passes ``w = -grad_margin`` (negation is
exact in IEEE, so scores equal ``z_i^T grad_margin`` bitwise).

Also here: the backend-dispatched O(m) column recursions every oracle's
state update needs (eq. 10 and its margin analogue), and the dense
column accessor the logistic bisection line search uses.

The fourth backend, 'distributed', routes every primitive to
``repro.distributed.backend`` (lazy import — that package sits ABOVE the
core in the layering): the same engine step then runs unchanged inside a
shard_map over a (data, model) mesh, with the matrix shard-local, beta
and the column statistics replicated, and the residual/margin sliced
over "data". Oracles reach the sample axis only through ``mdot`` /
``msum`` here, which psum over ``cfg.dist.data_axis`` exactly when the
distributed backend is active — single-device solves compile to the
plain reductions.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.solver_config import FWConfig
from repro.kernels.fused_step import fused_step as _fused_step
from repro.kernels.fw_grad.fw_grad import sampled_scores as _sampled_scores_kernel
from repro.kernels.fw_grad.ops import fw_vertex as _fw_vertex_kernel
from repro.kernels.padding import pad_rows as _pad_features
from repro.kernels.residual_update.residual_update import (
    residual_update as _residual_update_kernel,
)
from repro.sparse import ops as sparse_ops
from repro.sparse.matrix import SparseBlockMatrix

ExtraFn = Callable[[jax.Array], jax.Array]


def use_interpret(cfg: FWConfig) -> bool:
    """Pallas kernels compile natively on TPU, interpret everywhere else."""
    if cfg.interpret is not None:
        return cfg.interpret
    return jax.default_backend() != "tpu"


def use_sparse_kernel(cfg: FWConfig) -> bool:
    """'sparse' backend: Pallas prefetch kernel on TPU, XLA gather elsewhere
    (the XLA path is the production CPU path, not a test stub)."""
    if cfg.sparse_kernel is not None:
        return cfg.sparse_kernel
    return jax.default_backend() == "tpu"


def resolve_gather_mode(cfg: FWConfig) -> str:
    """In-kernel VMEM read for the sparse Pallas kernels. 'auto' resolves
    to the direct 'take' gather; 'onehot' is the explicit matmul fallback
    for TPU targets where the gather fails to lower (ROADMAP item)."""
    if cfg.gather_mode == "auto":
        return "take"
    if cfg.gather_mode not in ("take", "onehot"):
        raise ValueError(
            f"unknown gather_mode {cfg.gather_mode!r} (take|onehot|auto)"
        )
    return cfg.gather_mode


def dist_spec(cfg: Optional[FWConfig]):
    """The active DistSpec, or None outside the distributed backend."""
    if cfg is not None and cfg.backend == "distributed":
        if cfg.dist is None:
            raise ValueError(
                "backend='distributed' needs cfg.dist (built by "
                "repro.distributed.driver from the operand's mesh)"
            )
        return cfg.dist
    return None


def mdot(a: jax.Array, b: jax.Array, cfg: Optional[FWConfig] = None) -> jax.Array:
    """Sample-axis dot product, psum-completed over the "data" mesh axis
    when the distributed backend is active. Oracles MUST use this (and
    ``msum``) for any reduction over the m axis so their recursions stay
    correct when the residual/margin is a per-shard slice."""
    d = jnp.dot(a, b)
    spec = dist_spec(cfg)
    return jax.lax.psum(d, spec.data_axis) if spec is not None else d


def msum(x: jax.Array, cfg: Optional[FWConfig] = None) -> jax.Array:
    """Sample-axis sum — the ``mdot`` analogue for elementwise losses."""
    s = jnp.sum(x)
    spec = dist_spec(cfg)
    return jax.lax.psum(s, spec.data_axis) if spec is not None else s


def check_matrix_backend(Xt, cfg: FWConfig) -> None:
    """Trace-time guard: the matrix layout and the backend must agree."""
    if cfg.backend == "distributed":
        raise ValueError(
            "backend='distributed' only runs inside the shard_map built by "
            "repro.distributed.driver (solve / solve_batched / fw_path*); "
            "the single-device entry points cannot place mesh shards"
        )
    is_sparse = isinstance(Xt, SparseBlockMatrix)
    if is_sparse and cfg.backend != "sparse":
        raise ValueError(
            f"Xt is a SparseBlockMatrix but cfg.backend={cfg.backend!r}; "
            "use FWConfig(backend='sparse')"
        )
    if cfg.backend == "sparse" and not is_sparse:
        raise ValueError(
            "cfg.backend='sparse' needs a repro.sparse.SparseBlockMatrix "
            "design matrix (build one with SparseBlockMatrix.from_dense / "
            "from_coo or repro.data.make_sparse_proxy)"
        )


def pad_backend_matrix(Xt, cfg: FWConfig):
    """Zero-pad trailing feature rows for the dense kernel grids — once per
    solve, OUTSIDE the hot loop (DESIGN.md §Padding). No-op for the other
    backends ('sparse' pads at construction, 'xla' wraps modulo p)."""
    if cfg.backend == "pallas" and cfg.sampling != "uniform":
        return _pad_features(Xt, cfg.block_size)
    return Xt


# --------------------------------------------------------------------------
# Sampling-set draws (paper §4.1 / §4.5)
# --------------------------------------------------------------------------


def sample_blocks(
    key: jax.Array, nblocks: int, block_size: int, cfg: FWConfig
) -> jax.Array:
    """THE aligned-block draw every backend shares: kappa//block_size
    blocks without replacement, clamped so the request never exceeds the
    available blocks (choice would otherwise error). Single source of
    the clamp + draw so the index stream cannot drift between the
    single-device and distributed backends (engine contract)."""
    nb = min(max(cfg.kappa // block_size, 1), nblocks)
    return jax.random.choice(key, nblocks, (nb,), replace=False).astype(jnp.int32)


def sample_block_starts(key: jax.Array, p: int, cfg: FWConfig) -> jax.Array:
    """Aligned block starts for 'block' sampling over a dense feature
    axis of true size p (geometry from cfg.block_size)."""
    return sample_blocks(key, -(-p // cfg.block_size), cfg.block_size, cfg)


def sample_indices(key: jax.Array, p: int, cfg: FWConfig) -> jax.Array:
    """Draw the sampling set S (paper §4.1 / §4.5).

    'uniform': kappa i.i.d. uniform draws (with replacement — O(kappa), the
       large-p-friendly reading of the paper's uniform kappa-subsets).
    'block':   kappa/block aligned blocks without replacement (TPU-native).
    'full':    deterministic FW (S = {1..p}).
    """
    if cfg.sampling == "full":
        return jnp.arange(p)
    if cfg.sampling == "uniform":
        return jax.random.randint(key, (cfg.kappa,), 0, p)
    if cfg.sampling == "block":
        starts = sample_block_starts(key, p, cfg)
        idx = starts[:, None] * cfg.block_size + jnp.arange(cfg.block_size)[None, :]
        return idx.reshape(-1) % p  # tail block wraps (documented in DESIGN.md)
    raise ValueError(f"unknown sampling mode {cfg.sampling!r}")


def sample_sparse_blocks(key: jax.Array, mat: SparseBlockMatrix, cfg: FWConfig):
    """Aligned block starts for the sparse backend. Block geometry comes
    from the MATRIX (cfg.block_size is a dense-kernel knob); same shared
    clamp + draw as every other backend."""
    return sample_blocks(key, mat.nblocks, mat.block_size, cfg)


# --------------------------------------------------------------------------
# Backend-dispatched vertex selection
# --------------------------------------------------------------------------


def _xla_vertex(Xt, w, key, p, cfg, extra_fn):
    idx = sample_indices(key, p, cfg)
    rows = jnp.take(Xt, idx, axis=0)  # (|S|, m) contiguous row gather
    raw = -(rows @ w)  # (|S|,) linear scores
    sel = raw if extra_fn is None else raw + extra_fn(idx)
    j = jnp.argmax(jnp.abs(sel))
    return idx[j], raw[j], sel[j], idx.shape[0]


def _kernel_vertex(Xt, w, key, p, cfg, extra_fn):
    """Sampled FW vertex via the Pallas scalar-prefetch gather kernel.

    'block'/'full' drive block_size-wide aligned bricks; 'uniform' degrades
    to width-1 blocks (same index stream as the XLA gather path). ``Xt``
    may carry zero-padded trailing rows (indices >= p are masked out of
    the argmax). Without an extra term the fused kernel argmax runs; with
    one, the per-coordinate scores come back and the shift + argmax run
    in XLA (the kernel reduction cannot see the extra term).
    """
    if cfg.sampling == "uniform":
        # same draw as the XLA path: the backends replay one index stream
        blk = sample_indices(key, p, cfg).astype(jnp.int32)
        bs = 1
    elif cfg.sampling == "block":
        blk = sample_block_starts(key, p, cfg)
        bs = cfg.block_size
    elif cfg.sampling == "full":
        bs = cfg.block_size
        blk = jnp.arange(-(-p // bs), dtype=jnp.int32)
    else:
        raise ValueError(f"unknown sampling mode {cfg.sampling!r}")
    # dot-product accounting parity with the XLA path: 'full' scores every
    # REAL coordinate once (padded rows are free zeros, not sampled work);
    # 'block' counts nblocks*bs either way (the XLA path's wrapped tail
    # duplicates coords just as the kernel path's tail pads them).
    n_scored = p if cfg.sampling == "full" else blk.shape[0] * bs
    if extra_fn is None:
        i_star, g_star = _fw_vertex_kernel(
            Xt,
            w,
            blk,
            block_size=bs,
            m_tile=cfg.m_tile,
            interpret=use_interpret(cfg),
            p_valid=p,
        )
        return i_star, g_star, g_star, n_scored
    raw = _sampled_scores_kernel(
        Xt, w, blk, block_size=bs, m_tile=cfg.m_tile, interpret=use_interpret(cfg)
    )
    idx = (blk[:, None] * bs + jnp.arange(bs)[None, :]).reshape(-1)
    sel = raw + extra_fn(idx)
    mag = jnp.where(idx < p, jnp.abs(sel), -1.0)
    j = jnp.argmax(mag)
    return idx[j], raw[j], sel[j], n_scored


def _sparse_vertex(mat: SparseBlockMatrix, w, key, cfg, extra_fn):
    """Sampled FW vertex over the block-ELL matrix.

    'block'/'full' drive whole aligned blocks (kernel-dispatchable, the
    tail block is zero-padded at construction — no modulo wrap, so exact
    Lemma 1 uniformity holds for every p); 'uniform' is a width-1 XLA
    gather replaying the exact index stream of the dense XLA path.
    """
    if cfg.sampling == "uniform":
        idx = sample_indices(key, mat.p, cfg)
        i_star, g_raw, g_sel = sparse_ops.sparse_gather_vertex_general(
            mat, w, idx, extra_fn=extra_fn
        )
        return i_star, g_raw, g_sel, idx.shape[0]
    if cfg.sampling == "block":
        blk = sample_sparse_blocks(key, mat, cfg)
        n_scored = blk.shape[0] * mat.block_size
    elif cfg.sampling == "full":
        blk = jnp.arange(mat.nblocks, dtype=jnp.int32)
        n_scored = mat.p
    else:
        raise ValueError(f"unknown sampling mode {cfg.sampling!r}")
    i_star, g_raw, g_sel = sparse_ops.sparse_fw_vertex_general(
        mat,
        w,
        blk,
        use_kernel=use_sparse_kernel(cfg),
        interpret=use_interpret(cfg),
        extra_fn=extra_fn,
        gather_mode=resolve_gather_mode(cfg),
    )
    return i_star, g_raw, g_sel, n_scored


def score_indices(
    Xt,
    w: jax.Array,
    idx: jax.Array,
    p: int,
    cfg: FWConfig,
    extra_fn: Optional[ExtraFn] = None,
):
    """Linear scores ``raw_i = -z_i^T w`` at CALLER-CHOSEN global
    coordinates ``idx`` — the re-scoring primitive behind the away-vertex
    argmin (active-set buffer) and the lazy-LMO winner cache (DESIGN.md
    §StepRule). Backend-dispatched like ``sample_vertex`` but with no
    draw and no argmax: the step rule owns the masking/reduction.

    Negative or out-of-range indices are the rules' "empty slot" markers;
    they come back with an arbitrary score and MUST be masked by the
    caller (``idx >= 0 & idx < p``). Returns ``(raw, sel)`` with
    ``sel = raw + extra_fn(idx)`` (same array when ``extra_fn is None``).
    """
    safe = jnp.clip(idx, 0, p - 1).astype(jnp.int32)
    if cfg.backend == "distributed":
        from repro.distributed import backend as dist_backend

        raw = dist_backend.dist_score_indices(Xt, w, safe, cfg)
    elif cfg.backend == "sparse":
        raw = sparse_ops.sparse_gather_scores(Xt, w, safe).astype(Xt.dtype)
    elif cfg.backend == "pallas":
        raw = _sampled_scores_kernel(
            Xt, w, safe, block_size=1, m_tile=cfg.m_tile,
            interpret=use_interpret(cfg),
        )
    else:
        rows = jnp.take(Xt, safe, axis=0)  # (|idx|, m) row gather
        raw = -(rows @ w)
    sel = raw if extra_fn is None else raw + extra_fn(safe)
    return raw, sel


def sample_vertex(
    Xt,
    w: jax.Array,
    key: jax.Array,
    p: int,
    cfg: FWConfig,
    extra_fn: Optional[ExtraFn] = None,
):
    """Draw S and return the winning vertex on the configured backend.

    Returns ``(i_star, g_raw, g_sel, n_scored)``: the selected global
    coordinate, its LINEAR score ``-z^T w``, its selected (extra-shifted)
    score, and how many length-m dot products were consumed. With
    ``extra_fn is None`` the two scores are the same array.
    """
    if cfg.backend == "distributed":
        from repro.distributed import backend as dist_backend

        return dist_backend.dist_sample_vertex(Xt, w, key, p, cfg, extra_fn)
    if cfg.backend == "sparse":
        return _sparse_vertex(Xt, w, key, cfg, extra_fn)
    if cfg.backend == "pallas":
        return _kernel_vertex(Xt, w, key, p, cfg, extra_fn)
    return _xla_vertex(Xt, w, key, p, cfg, extra_fn)


# --------------------------------------------------------------------------
# Fused multi-step chunk dispatch (kernels/fused_step, DESIGN.md §Perf)
# --------------------------------------------------------------------------


_warned_unfused_rules: set = set()


def fused_supported(oracle, cfg: FWConfig) -> bool:
    """Trace-time gate for the chunked K-steps-per-dispatch hot loop.

    Fusion needs (a) ``cfg.fuse_steps > 1``, (b) an oracle with a
    closed-form line search exposed through the ``fused_*`` protocol
    (lasso / elastic-net; the logistic bisection falls back to the
    per-step loop), (c) 'uniform' sampling — the K x kappa index stream
    must be pregenerable as a pure function of (key, cfg, p) — (d) a
    single-device backend (the distributed driver forces fuse_steps=1
    for now), and (e) a step rule that composes with the megakernel's
    per-step records (``classic`` only: the other rules' direction
    selection reads live iterate state the chunk cannot pregather, so
    they declare ``fused_ok=False`` and fall back to per-step with a
    one-time warning — explicitly, never silently; DESIGN.md §StepRule).
    """
    base = (
        cfg.fuse_steps > 1
        and cfg.sampling == "uniform"
        and getattr(oracle, "fused_kind", None) is not None
        and cfg.backend != "distributed"
    )
    if not base:
        return False
    if cfg.step_rule != "classic":
        from repro.core import step_rule as step_rule_lib

        rule = step_rule_lib.get_rule(cfg)
        if not rule.fused_ok:
            if cfg.step_rule not in _warned_unfused_rules:
                _warned_unfused_rules.add(cfg.step_rule)
                import warnings

                warnings.warn(
                    f"step_rule={cfg.step_rule!r} does not compose with "
                    f"the fused multi-step chunk (fuse_steps="
                    f"{cfg.fuse_steps}); falling back to per-step "
                    "execution (fuse_steps=1 semantics)",
                    stacklevel=2,
                )
            return False
    return True


def use_fused_kernel(cfg: FWConfig) -> bool:
    """Chunk executor choice: the Pallas megakernel drives the 'pallas'
    backend and the kernel-dispatched 'sparse' backend; 'xla' and the
    XLA-gather sparse path chunk through a fori_loop over the unfused
    engine step (bit-exact by construction)."""
    if cfg.backend == "pallas":
        return True
    return cfg.backend == "sparse" and use_sparse_kernel(cfg)


def run_fused_kernel(
    oracle, Xt, y, resid, scal, idx, zty_s, zn2_s, alpha_s, k0, delta,
    cfg: FWConfig,
):
    """Invoke the fused megakernel on the configured layout. Returns
    ``(i_star, lam, delta_t, no_progress, resid_out, (S, F, Q))`` — the
    per-step records the engine replays into beta/scale/stopping state."""
    kw = dict(
        oracle=oracle,
        eps_den=cfg.eps_den,
        gap_rtol=cfg.gap_rtol,
        refresh_every=cfg.refresh_every,
        max_iters=cfg.max_iters,
        interpret=use_interpret(cfg),
    )
    if isinstance(Xt, SparseBlockMatrix):
        return _fused_step.sparse_fused_chunk(
            Xt.values, Xt.rows, y, resid, scal, idx, zty_s, zn2_s, alpha_s,
            k0, delta, gather_mode=resolve_gather_mode(cfg), **kw,
        )
    return _fused_step.dense_fused_chunk(
        Xt, y, resid, scal, idx, zty_s, zn2_s, alpha_s, k0, delta, **kw
    )


# --------------------------------------------------------------------------
# Backend-dispatched O(m) column recursions
# --------------------------------------------------------------------------


def apply_column_update(Xt, v, y_vec, i_star, lam, delta_t, cfg: FWConfig):
    """v <- (1-lam) v + lam (y_vec - delta_t * z_star), backend-dispatched.

    This is eq. 10 with ``v = R, y_vec = y``; with ``v = margin,
    y_vec = 0, delta_t -> -delta_t`` it is the logistic margin recursion
    m <- (1-lam) m + lam delta_t z_star.
    """
    if cfg.backend == "distributed":
        from repro.distributed import backend as dist_backend

        return dist_backend.dist_column_update(
            Xt, v, y_vec, i_star, lam, delta_t, cfg
        )
    if cfg.backend == "sparse":
        col_vals, col_rows = sparse_ops.sparse_column(Xt, i_star)
        return sparse_ops.sparse_residual_update(
            v, y_vec, col_vals, col_rows, lam, delta_t
        )
    z_star = jax.lax.dynamic_slice_in_dim(Xt, i_star, 1, axis=0)[0]
    if cfg.backend == "pallas":
        return _residual_update_kernel(
            v, y_vec, z_star, lam, delta_t,
            m_tile=cfg.m_tile, interpret=use_interpret(cfg),
        )
    return (1.0 - lam) * v + lam * (y_vec - delta_t * z_star)


def column_dense(Xt, i_star, cfg: FWConfig) -> jax.Array:
    """Dense (m,) column z_star — the logistic bisection needs the whole
    direction vector. Sparse backend scatters the ELL slots (O(nnz_max) +
    one O(m) zeros init, amortized against the O(m) bisection probes).
    Distributed: each shard gets its own "data"-slice of the column."""
    if cfg.backend == "distributed":
        from repro.distributed import backend as dist_backend

        return dist_backend.dist_column_dense(Xt, i_star, cfg)
    if cfg.backend == "sparse":
        return sparse_ops.sparse_column_dense(Xt, i_star)
    return jax.lax.dynamic_slice_in_dim(Xt, i_star, 1, axis=0)[0]


def matvec(Xt, beta: jax.Array, cfg: Optional[FWConfig] = None) -> jax.Array:
    """X @ alpha for warm-start initialization, either matrix layout.
    Distributed: the replicated beta hits the local shard and a psum over
    "model" completes the local sample-slice of X alpha."""
    if dist_spec(cfg) is not None:
        from repro.distributed import backend as dist_backend

        return dist_backend.dist_matvec(Xt, beta, cfg)
    if isinstance(Xt, SparseBlockMatrix):
        return sparse_ops.sparse_matvec(Xt, beta)
    return beta @ Xt


def grad_full(Xt, w: jax.Array, cfg: Optional[FWConfig] = None) -> jax.Array:
    """Full LINEAR gradient -X^T w over every feature — the O(nnz)/O(p*m)
    certification pass behind the oracle ``gap()`` protocol, never the hot
    loop. Distributed: local features psum over "data", all_gather over
    "model" — replicated on every shard. May return backend-padded length;
    callers slice [:p]."""
    if dist_spec(cfg) is not None:
        from repro.distributed import backend as dist_backend

        return dist_backend.dist_grad_full(Xt, w, cfg)
    if isinstance(Xt, SparseBlockMatrix):
        return -sparse_ops.sparse_transpose_matvec(Xt, w)
    return -(Xt @ w)
