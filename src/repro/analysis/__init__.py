from repro.analysis.roofline import (
    RooflineTerms,
    collective_bytes,
    parse_collectives,
    roofline_terms,
    V5E,
)

__all__ = [
    "RooflineTerms",
    "collective_bytes",
    "parse_collectives",
    "roofline_terms",
    "V5E",
]
