"""Roofline-term derivation from compiled (dry-run) artifacts.

Three terms per (arch x mesh), in seconds (brief §ROOFLINE):
  compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
  memory     = HLO_bytes_per_device / HBM_bandwidth
  collective = wire_bytes_per_device / ICI_link_bandwidth

HLO_FLOPs and HLO_bytes come from compiled.cost_analysis() (the SPMD
program is per-device). Collective bytes are parsed from the optimized
HLO text with ring-algorithm wire-cost factors:
  all-reduce      2 x operand bytes
  all-gather      ~output bytes (gathered size) x (N-1)/N  ≈ output bytes
  reduce-scatter  ~input bytes x (N-1)/N                   ≈ input bytes
  all-to-all      ~operand bytes
  collective-permute  operand bytes
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

# TPU v5e hardware constants (brief)
V5E = {
    "peak_flops": 197e12,  # bf16 FLOP/s per chip
    "hbm_bw": 819e9,  # bytes/s
    "ici_bw": 50e9,  # bytes/s per link
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _shape_bytes(type_str: str) -> int:
    """Sum byte sizes of every shape literal in an HLO result-type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-op-kind tallies: {kind: {count, result_bytes, wire_bytes}}.

    Works on the optimized (post-SPMD) module: shapes are per-device.
    """
    out: Dict[str, Dict[str, float]] = {
        k: {"count": 0, "result_bytes": 0.0, "wire_bytes": 0.0}
        for k in _COLLECTIVE_OPS
    }
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+)$", stripped)
        if not m:
            continue
        rhs = m.group(1)
        for kind in _COLLECTIVE_OPS:
            # match ` <type> kind(` — avoid -start/-done fusion suffixes
            opm = re.match(rf"^(\(?[\w\[\],\s{{}}]+\)?)\s+{kind}(-start)?\(", rhs)
            if opm:
                rbytes = _shape_bytes(opm.group(1))
                if kind == "all-reduce":
                    wire = 2.0 * rbytes
                elif kind == "all-gather":
                    wire = float(rbytes)  # result is the gathered size
                elif kind == "reduce-scatter":
                    # result is the scattered shard; input ~ result * N.
                    # ring cost ~ input bytes: approximate with result*N is
                    # unavailable without group size; use result bytes * 1
                    # (conservative lower bound, noted in EXPERIMENTS.md).
                    wire = float(rbytes)
                else:
                    wire = float(rbytes)
                out[kind]["count"] += 1
                out[kind]["result_bytes"] += rbytes
                out[kind]["wire_bytes"] += wire
                break
    return out


def collective_bytes(hlo_text: str) -> float:
    tallies = parse_collectives(hlo_text)
    return sum(v["wire_bytes"] for v in tallies.values())


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    collectives: Dict[str, Dict[str, float]] = field(default_factory=dict)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def to_dict(self) -> Dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "dominant": self.dominant,
            "collectives": self.collectives,
        }


def roofline_terms(
    cost: Dict, hlo_text: str, hw: Dict = V5E
) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    tallies = parse_collectives(hlo_text)
    wire = sum(v["wire_bytes"] for v in tallies.values())
    return RooflineTerms(
        compute_s=flops / hw["peak_flops"],
        memory_s=bytes_accessed / hw["hbm_bw"],
        collective_s=wire / hw["ici_bw"],
        flops_per_device=flops,
        bytes_per_device=bytes_accessed,
        wire_bytes_per_device=wire,
        collectives=tallies,
    )


def model_flops(cfg, shape_kind: str, seq_len: int, global_batch: int) -> float:
    """MODEL_FLOPS = 6 N D (train) / 2 N D (inference), N = active params."""
    n_active = active_params(cfg)
    if shape_kind == "train":
        tokens = seq_len * global_batch
        return 6.0 * n_active * tokens
    if shape_kind == "prefill":
        tokens = seq_len * global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * global_batch


def active_params(cfg) -> float:
    """Parameter count excluding non-routed experts (MoE: top-k active)."""
    d, L = cfg.d_model, cfg.n_layers
    hd = cfg.resolved_head_dim
    total = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    for i in range(L):
        layer = 0
        if cfg.family != "ssm":
            layer += d * cfg.n_heads * hd * 2  # wq, wo
            layer += d * cfg.n_kv_heads * hd * 2  # wk, wv
        if cfg.family in ("ssm", "hybrid"):
            di = cfg.d_inner
            layer += d * (2 * di + 2 * cfg.ssm_state + cfg.ssm_heads)
            layer += di * d
        if cfg.is_moe_layer(i):
            f = cfg.moe_d_ff or cfg.d_ff
            layer += cfg.experts_per_token * 3 * d * f  # active experts only
            layer += cfg.n_shared_experts * 3 * d * f
            if cfg.moe_dense_residual:
                layer += 3 * d * cfg.d_ff
        elif cfg.d_ff:
            layer += 3 * d * cfg.d_ff
        total += layer
    if cfg.n_enc_layers:
        enc_layer = d * cfg.n_heads * hd * 2 + d * cfg.n_kv_heads * hd * 2 + 3 * d * cfg.d_ff
        total += cfg.n_enc_layers * enc_layer
        total += L * (d * cfg.n_heads * hd * 2 + d * cfg.n_kv_heads * hd * 2)  # cross
    return float(total)


def total_params(cfg) -> float:
    """All parameters (MoE: every expert)."""
    d, L = cfg.d_model, cfg.n_layers
    hd = cfg.resolved_head_dim
    total = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    for i in range(L):
        layer = 0
        if cfg.family != "ssm":
            layer += d * cfg.n_heads * hd * 2 + d * cfg.n_kv_heads * hd * 2
        if cfg.family in ("ssm", "hybrid"):
            di = cfg.d_inner
            layer += d * (2 * di + 2 * cfg.ssm_state + cfg.ssm_heads) + di * d
        if cfg.is_moe_layer(i):
            f = cfg.moe_d_ff or cfg.d_ff
            layer += cfg.n_experts * 3 * d * f + cfg.n_shared_experts * 3 * d * f
            if cfg.moe_dense_residual:
                layer += 3 * d * cfg.d_ff
        elif cfg.d_ff:
            layer += 3 * d * cfg.d_ff
        total += layer
    if cfg.n_enc_layers:
        enc_layer = d * cfg.n_heads * hd * 2 + d * cfg.n_kv_heads * hd * 2 + 3 * d * cfg.d_ff
        total += cfg.n_enc_layers * enc_layer
        total += L * (d * cfg.n_heads * hd * 2 + d * cfg.n_kv_heads * hd * 2)
    return float(total)
