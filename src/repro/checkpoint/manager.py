"""Fault-tolerant checkpointing (DESIGN.md §6).

Design goals (what a real fleet needs, scaled to this repo):
  * ATOMIC commits: write to a temp dir, fsync, rename — a crash mid-save
    never corrupts the latest checkpoint;
  * mesh-agnostic layout: full logical arrays are saved (gathered), so a
    checkpoint written on a 16x16 mesh restores onto 8x8 or 1 device —
    the elastic re-mesh path;
  * rotation with retention, resume-from-latest;
  * async save thread: the train loop donates a host copy and keeps
    stepping while the previous checkpoint serializes (straggler hiding).

Format: one .npz per top-level param group + JSON manifest with step,
tree structure, and integrity digests.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


_NATIVE_KINDS = set("fiub")  # float/int/uint/bool numpy kinds


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(e, "key", getattr(e, "idx", getattr(e, "name", e))))
            for e in path
        )
        arr = np.asarray(leaf)
        if arr.dtype.kind not in _NATIVE_KINDS or arr.dtype.name == "bfloat16":
            # npz cannot round-trip extension dtypes (bf16): widen to f32;
            # the template dtype restores it on load.
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _unflatten_like(template, flat: Dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, tleaf in paths:
        key = "/".join(
            str(getattr(e, "key", getattr(e, "idx", getattr(e, "name", e))))
            for e in path
        )
        arr = flat[key]
        if hasattr(tleaf, "dtype") and str(arr.dtype) != str(tleaf.dtype):
            import jax.numpy as jnp

            arr = np.asarray(jnp.asarray(arr).astype(tleaf.dtype))
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(directory: str | Path, step: int, state: Dict[str, Any]) -> Path:
    """Atomic checkpoint save. ``state`` is a dict of named pytrees."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:010d}"
    tmp = Path(tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=directory))
    manifest = {"step": step, "groups": {}, "time": time.time()}
    try:
        for name, tree in state.items():
            flat = _flatten_with_paths(tree)
            fname = f"{name}.npz"
            np.savez(tmp / fname, **flat)
            digest = hashlib.sha256((tmp / fname).read_bytes()).hexdigest()[:16]
            manifest["groups"][name] = {"file": fname, "sha256_16": digest}
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
        with open(tmp / "manifest.json") as f:
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def _verify(path: Path) -> bool:
    try:
        manifest = json.loads((path / "manifest.json").read_text())
        for name, info in manifest["groups"].items():
            digest = hashlib.sha256((path / info["file"]).read_bytes()).hexdigest()[:16]
            if digest != info["sha256_16"]:
                return False
        return True
    except Exception:
        return False


def load_latest(directory: str | Path, templates: Dict[str, Any]):
    """Restore the newest valid checkpoint; returns (step, state) or None.

    Corrupt/partial checkpoints (failed integrity check) are skipped —
    the restart path after a mid-save crash.
    """
    directory = Path(directory)
    if not directory.exists():
        return None
    candidates = sorted(
        (d for d in directory.iterdir() if d.name.startswith("step_")), reverse=True
    )
    for cand in candidates:
        if not _verify(cand):
            continue
        manifest = json.loads((cand / "manifest.json").read_text())
        state = {}
        for name, template in templates.items():
            data = np.load(cand / manifest["groups"][name]["file"])
            state[name] = _unflatten_like(template, dict(data))
        return manifest["step"], state
    return None


def load_latest_raw(directory: str | Path):
    """Restore the newest valid checkpoint WITHOUT templates: returns
    ``(step, {group: {leaf_path: np.ndarray}})`` or None.

    The template-free twin of ``load_latest`` for callers that own their
    serialization layout (``repro.resilience.checkpoint`` packs path
    state into flat dict groups, so the stored arrays ARE the state —
    no pytree reconstruction needed). Corrupt/partial checkpoints are
    skipped exactly like ``load_latest``.
    """
    directory = Path(directory)
    if not directory.exists():
        return None
    candidates = sorted(
        (d for d in directory.iterdir() if d.name.startswith("step_")), reverse=True
    )
    for cand in candidates:
        if not _verify(cand):
            continue
        manifest = json.loads((cand / "manifest.json").read_text())
        state = {}
        for name, info in manifest["groups"].items():
            with np.load(cand / info["file"]) as data:
                state[name] = {k: data[k] for k in data.files}
        return manifest["step"], state
    return None


def prune_checkpoints(directory: str | Path, keep: int = 3) -> None:
    """Drop all but the newest ``keep`` checkpoints (standalone twin of
    ``CheckpointManager._rotate`` for the functional save path)."""
    directory = Path(directory)
    if not directory.exists() or keep <= 0:
        return
    ckpts = sorted(d for d in directory.iterdir() if d.name.startswith("step_"))
    for old in ckpts[:-keep]:
        shutil.rmtree(old, ignore_errors=True)


class CheckpointManager:
    """Rotation + optional async (background-thread) saves."""

    def __init__(
        self,
        directory: str | Path,
        *,
        keep: int = 3,
        async_save: bool = False,
    ):
        self.directory = Path(directory)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self.save_count = 0

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, state: Dict[str, Any]):
        # snapshot to host BEFORE returning control (donation safety)
        host_state = jax.tree.map(lambda x: np.asarray(x), state)

        def _do():
            save_checkpoint(self.directory, step, host_state)
            self._rotate()

        self.save_count += 1
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(target=_do, daemon=True)
            self._thread.start()
        else:
            _do()

    def restore(self, templates: Dict[str, Any]):
        self.wait()
        return load_latest(self.directory, templates)

    def _rotate(self):
        ckpts = sorted(
            d for d in self.directory.iterdir() if d.name.startswith("step_")
        )
        for old in ckpts[: -self.keep]:
            shutil.rmtree(old, ignore_errors=True)
