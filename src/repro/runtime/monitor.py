"""Deprecated shim: ``repro.runtime.monitor`` moved to
``repro.obs.monitor`` (DESIGN.md §Observability).

The EWMA straggler / heartbeat logic now lives with the rest of the
observability layer — same ``StepMonitor`` API plus an injectable clock
and a straggler flag in the heartbeat payload. Import from
``repro.obs.monitor``; this module re-exports for back-compat and warns.
"""
from __future__ import annotations

import warnings

from repro.obs.monitor import StepMonitor  # noqa: F401

warnings.warn(
    "repro.runtime.monitor is deprecated; use repro.obs.monitor",
    DeprecationWarning,
    stacklevel=2,
)
