"""Straggler / health monitoring for the training loop (DESIGN.md §6).

On a real fleet this feeds the control plane (pod replacement, elastic
downsizing). Here it implements the detection logic: EWMA step-time
tracking, straggler flagging, and a heartbeat file other processes (or a
supervisor) can watch.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import List, Optional


@dataclass
class StepMonitor:
    ewma_alpha: float = 0.1
    straggler_factor: float = 3.0  # step > factor * ewma => flag
    heartbeat_path: Optional[Path] = None

    ewma: float = 0.0
    last_step_time: float = 0.0
    stragglers: List[int] = field(default_factory=list)
    _t0: float = field(default=0.0, repr=False)
    step: int = 0

    def begin(self):
        self._t0 = time.perf_counter()

    def end(self) -> bool:
        """Record a step; returns True if this step was a straggler."""
        dt = time.perf_counter() - self._t0
        self.last_step_time = dt
        self.step += 1
        is_straggler = False
        if self.ewma > 0 and dt > self.straggler_factor * self.ewma:
            self.stragglers.append(self.step)
            is_straggler = True
        self.ewma = dt if self.ewma == 0 else (
            self.ewma_alpha * dt + (1 - self.ewma_alpha) * self.ewma
        )
        if self.heartbeat_path is not None:
            self.heartbeat_path.write_text(
                json.dumps(
                    {
                        "step": self.step,
                        "t": time.time(),
                        "step_time": dt,
                        "ewma": self.ewma,
                    }
                )
            )
        return is_straggler
