from repro.runtime.trainer import Trainer, TrainerConfig
from repro.obs.monitor import StepMonitor

__all__ = ["Trainer", "TrainerConfig", "StepMonitor"]
