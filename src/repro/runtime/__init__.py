from repro.runtime.trainer import Trainer, TrainerConfig
from repro.runtime.monitor import StepMonitor

__all__ = ["Trainer", "TrainerConfig", "StepMonitor"]
