"""Fault-tolerant training loop: checkpoint/restart, straggler monitor,
deterministic data order, crash-equivalent resume (tested).

This is the host-side driver wrapping the jitted train_step; it is mesh-
agnostic (works on 1 CPU device in tests and on the production mesh via
launch/train.py).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.models.config import ModelConfig
from repro.obs.monitor import StepMonitor
from repro.training import init_train_state, make_train_step


@dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    checkpoint_dir: str = "checkpoints"
    keep_checkpoints: int = 3
    async_checkpoint: bool = True
    microbatches: int = 1
    base_lr: float = 3e-4
    seed: int = 0
    log_every: int = 10


class Trainer:
    def __init__(
        self,
        model_cfg: ModelConfig,
        trainer_cfg: TrainerConfig,
        data_fn: Callable[[int], Dict],  # step -> batch (deterministic)
        jit_kwargs: Optional[dict] = None,
    ):
        self.model_cfg = model_cfg
        self.cfg = trainer_cfg
        self.data_fn = data_fn
        step_fn = make_train_step(
            model_cfg,
            microbatches=trainer_cfg.microbatches,
            base_lr=trainer_cfg.base_lr,
            total_steps=trainer_cfg.total_steps,
        )
        self.train_step = jax.jit(step_fn, donate_argnums=(0, 1), **(jit_kwargs or {}))
        self.ckpt = CheckpointManager(
            trainer_cfg.checkpoint_dir,
            keep=trainer_cfg.keep_checkpoints,
            async_save=trainer_cfg.async_checkpoint,
        )
        Path(trainer_cfg.checkpoint_dir).mkdir(parents=True, exist_ok=True)
        self.monitor = StepMonitor(
            heartbeat_path=Path(trainer_cfg.checkpoint_dir) / "heartbeat.json"
        )
        self.history = []

    def init_or_restore(self):
        params, opt_state = init_train_state(
            jax.random.PRNGKey(self.cfg.seed), self.model_cfg
        )
        restored = self.ckpt.restore({"params": params, "opt": opt_state})
        if restored is not None:
            step, state = restored
            return state["params"], state["opt"], step
        return params, opt_state, 0

    def run(self, crash_at: Optional[int] = None):
        """Train to total_steps; ``crash_at`` simulates a failure (tests)."""
        params, opt_state, start = self.init_or_restore()
        step = start
        while step < self.cfg.total_steps:
            if crash_at is not None and step >= crash_at:
                raise RuntimeError(f"simulated crash at step {step}")
            batch = self.data_fn(step)
            self.monitor.begin()
            params, opt_state, metrics = self.train_step(params, opt_state, batch)
            metrics["loss"].block_until_ready()
            self.monitor.end()
            step += 1
            self.history.append(float(metrics["loss"]))
            if step % self.cfg.checkpoint_every == 0 or step == self.cfg.total_steps:
                self.ckpt.save(step, {"params": params, "opt": opt_state})
        self.ckpt.wait()
        return params, opt_state, step
