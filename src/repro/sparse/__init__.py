"""Sparse column-block subsystem (DESIGN.md §Sparse).

matrix — block-ELL/padded-CSC SparseBlockMatrix storage + converters
io     — svmlight text reader/writer, .npz shard streaming
ops    — solver-facing primitives (scores/colstats/residual/matvec)
"""
from repro.sparse.matrix import SparseBlockMatrix
from repro.sparse.io import (
    COOData,
    convert_svmlight_to_shards,
    iter_shards,
    load_shards,
    load_shards_as_matrix,
    load_svmlight,
    read_manifest,
    save_svmlight,
    write_shards,
)
from repro.sparse import ops

__all__ = [
    "SparseBlockMatrix",
    "COOData",
    "convert_svmlight_to_shards",
    "iter_shards",
    "load_shards",
    "load_shards_as_matrix",
    "load_svmlight",
    "read_manifest",
    "save_svmlight",
    "write_shards",
    "ops",
]
