"""Block-ELL / padded-CSC storage for the feature-major design matrix.

The randomized FW iteration only ever touches a sampled *feature block*
per step (DESIGN.md §4.4), so the sparse format is organised around that
access pattern: features (columns of X = rows of the feature-major Xt)
are grouped into aligned blocks of ``block_size``, and every feature
stores its nonzeros as a fixed-width (ELL) row of ``nnz_max`` slots,

    values[b, t, k]  value of the k-th nonzero of feature b*block_size+t
    rows[b, t, k]    sample index of that nonzero

zero-padded past the feature's true nnz (padded slots carry value 0.0 at
row 0, so gathers stay in bounds and scatter-adds are no-ops). The
feature axis itself is zero-padded up to a whole number of blocks — the
same convention as ``kernels/padding.pad_rows`` for the dense kernels
(DESIGN.md §Padding): a padded feature's score is exactly 0 and the
solver masks indices >= p out of the argmax.

The rectangular (nblocks, block_size, nnz_max) layout is what makes the
format JAX-friendly: a sampled block is ONE dynamic slice along the
leading axis (scalar-prefetchable on TPU), and every op is a dense
gather + reduction over a fixed shape — no ragged indexing inside jit.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True, eq=False)
class SparseBlockMatrix:
    """Feature-major sparse design matrix in block-ELL layout.

    Logical shape is ``(p, m)`` — the same orientation as the dense ``Xt``
    everywhere else in the repo — with ``p`` the TRUE feature count
    (``values`` covers ``nblocks * block_size >= p`` padded features).
    """

    values: jax.Array  # (nblocks, block_size, nnz_max) float
    rows: jax.Array  # (nblocks, block_size, nnz_max) int32 sample indices
    p: int  # true feature count (un-padded)
    m: int  # sample count
    block_size: int
    nnz_max: int  # per-feature nnz budget (ELL width)

    # ---- dense-array compatibility surface (path.py etc. read these) ----
    @property
    def shape(self) -> Tuple[int, int]:
        return (self.p, self.m)

    @property
    def dtype(self):
        return self.values.dtype

    @property
    def nblocks(self) -> int:
        return self.values.shape[0]

    @property
    def p_padded(self) -> int:
        return self.nblocks * self.block_size

    @property
    def nbytes(self) -> int:
        """Actual storage footprint (values + row indices)."""
        itemsize = np.dtype(self.values.dtype).itemsize
        slots = self.nblocks * self.block_size * self.nnz_max
        return slots * (itemsize + 4)

    def to_dense(self) -> jax.Array:
        """Materialize the dense feature-major ``Xt`` of shape (p, m).

        Padded slots contribute +0.0 via scatter-ADD, so explicit zeros
        and padding never clobber real entries.
        """
        pp = self.p_padded
        feat = jnp.repeat(jnp.arange(pp), self.nnz_max)
        dense = jnp.zeros((pp, self.m), self.values.dtype)
        dense = dense.at[feat, self.rows.reshape(-1)].add(self.values.reshape(-1))
        return dense[: self.p]

    @classmethod
    def from_coo(
        cls,
        sample_rows: np.ndarray,
        feature_cols: np.ndarray,
        vals: np.ndarray,
        shape: Tuple[int, int],
        *,
        block_size: int = 256,
        nnz_max: Optional[int] = None,
        dtype=np.float32,
    ) -> "SparseBlockMatrix":
        """Build from COO triplets in the natural (sample, feature) = (m, p)
        orientation of svmlight files. Duplicate coordinates are assumed
        absent (svmlight guarantees this); the per-feature nnz budget
        defaults to the max feature nnz and raising it is a no-op, while an
        insufficient explicit budget is an error (we never silently drop
        entries)."""
        m, p = shape
        sample_rows = np.asarray(sample_rows, np.int64)
        feature_cols = np.asarray(feature_cols, np.int64)
        vals = np.asarray(vals)
        if sample_rows.size and (sample_rows.min() < 0 or sample_rows.max() >= m):
            raise ValueError("sample row index out of range for shape")
        if feature_cols.size and (feature_cols.min() < 0 or feature_cols.max() >= p):
            raise ValueError("feature column index out of range for shape")
        counts = np.bincount(feature_cols, minlength=p)
        required = int(counts.max()) if counts.size else 0
        if nnz_max is None:
            nnz_max = max(1, required)
        elif required > nnz_max:
            raise ValueError(
                f"nnz budget {nnz_max} too small: densest feature has "
                f"{required} nonzeros (pass nnz_max>={required})"
            )
        nnz_max = max(1, int(nnz_max))

        nblocks = -(-p // block_size)
        pp = nblocks * block_size
        values = np.zeros((pp, nnz_max), dtype)
        rows = np.zeros((pp, nnz_max), np.int32)
        order = np.argsort(feature_cols, kind="stable")
        fc = feature_cols[order]
        starts = np.zeros(p + 1, np.int64)
        np.cumsum(counts, out=starts[1:])
        slot = np.arange(fc.size) - starts[fc]
        values[fc, slot] = vals[order].astype(dtype)
        rows[fc, slot] = sample_rows[order].astype(np.int32)
        return cls(
            values=jnp.asarray(values.reshape(nblocks, block_size, nnz_max)),
            rows=jnp.asarray(rows.reshape(nblocks, block_size, nnz_max)),
            p=p,
            m=m,
            block_size=block_size,
            nnz_max=nnz_max,
        )

    @classmethod
    def from_dense(
        cls,
        Xt: np.ndarray,
        *,
        block_size: int = 256,
        nnz_max: Optional[int] = None,
    ) -> "SparseBlockMatrix":
        """Convert a dense feature-major ``Xt`` (p, m) array."""
        Xt = np.asarray(Xt)
        p, m = Xt.shape
        feat, samp = np.nonzero(Xt)
        return cls.from_coo(
            samp,
            feat,
            Xt[feat, samp],
            (m, p),
            block_size=block_size,
            nnz_max=nnz_max,
            dtype=Xt.dtype,
        )

    def astype(self, dtype) -> "SparseBlockMatrix":
        return dataclasses.replace(self, values=self.values.astype(dtype))

    def pad_geometry(
        self, *, nblocks: Optional[int] = None, nnz_max: Optional[int] = None
    ) -> "SparseBlockMatrix":
        """Grow the storage geometry to (nblocks, block_size, nnz_max)
        with zero padding — shrink is an error (entries are never
        dropped). The distributed shard placement uses this to equalize
        per-cell shapes across the mesh (every shard_map operand must
        share one static local shape); padded blocks are all-zero
        features under the standard §Padding contract, padded slots are
        value-0 row-0 no-ops.
        """
        nblocks = self.nblocks if nblocks is None else int(nblocks)
        nnz_max = self.nnz_max if nnz_max is None else int(nnz_max)
        if nblocks < self.nblocks or nnz_max < self.nnz_max:
            raise ValueError(
                f"pad_geometry cannot shrink ({self.nblocks}, {self.nnz_max})"
                f" -> ({nblocks}, {nnz_max})"
            )
        if nblocks == self.nblocks and nnz_max == self.nnz_max:
            return self
        pad = (
            (0, nblocks - self.nblocks),
            (0, 0),
            (0, nnz_max - self.nnz_max),
        )
        return dataclasses.replace(
            self,
            values=jnp.pad(self.values, pad),
            rows=jnp.pad(self.rows, pad),
            nnz_max=nnz_max,
        )

    def density(self) -> float:
        """Structural density: stored-slot fraction of the logical p*m."""
        nnz = int(jnp.sum(self.values != 0))
        return nnz / float(max(1, self.p * self.m))


jax.tree_util.register_dataclass(
    SparseBlockMatrix,
    data_fields=["values", "rows"],
    meta_fields=["p", "m", "block_size", "nnz_max"],
)
