"""svmlight/libsvm text IO and .npz shard streaming (DESIGN.md §Sparse).

Two interchange layers feed the sparse subsystem:

* ``load_svmlight`` / ``save_svmlight`` — the text format the paper's
  real datasets (E2006-tfidf / E2006-log1p) ship in: one sample per line,
  ``label idx:val idx:val ...`` with 1-based indices by convention.
* ``write_shards`` / ``iter_shards`` / ``load_shards_as_matrix`` — a
  row-range .npz shard layout plus a JSON manifest so multi-GB datasets
  convert once and then load block-by-block out of core: the streaming
  assembler makes two passes over the shards (per-feature nnz counts,
  then ELL fill) and never materializes a dense array or even the full
  COO triplet set.

Everything here is numpy-only; device placement happens at
SparseBlockMatrix construction.
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import time
from typing import Dict, Iterator, List, NamedTuple, Optional, Tuple

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs.trace import traced
from repro.resilience import faults as _faults
from repro.sparse.matrix import SparseBlockMatrix

MANIFEST_NAME = "manifest.json"
SHARD_FORMAT = "coo-npz-v1"

# Bounded exponential backoff for checksum-failed shard reads
# (DESIGN.md §Resilience): transient damage — a torn NFS read, an
# injected byte flip — heals on re-read; persistent on-disk corruption
# exhausts the retries and raises ShardIntegrityError.
SHARD_READ_RETRIES = 3
SHARD_RETRY_BASE_S = 0.05


class ShardIntegrityError(RuntimeError):
    """A shard file failed its manifest sha256 on every read attempt."""


def _sha256_hex(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


def _shard_checksums(out_dir, names) -> Dict[str, str]:
    """sha256 of each just-written shard file, for the manifest."""
    sums = {}
    for name in names:
        with open(os.path.join(out_dir, name), "rb") as fh:
            sums[name] = _sha256_hex(fh.read())
    return sums


def _read_shard_bytes_verified(
    shard_dir, name: str, expected: Optional[str]
) -> bytes:
    """Read one shard file, verify it against the manifest checksum, and
    retry with exponential backoff on mismatch. ``expected=None``
    (legacy pre-checksum manifests) skips verification. The parsed
    arrays always come from the VERIFIED byte buffer, so what was
    checked is exactly what is used."""
    path = os.path.join(shard_dir, name)
    reg = obs_metrics.get_registry()
    for attempt in range(SHARD_READ_RETRIES + 1):
        with open(path, "rb") as fh:
            data = fh.read()
        data = _faults.maybe_corrupt_bytes(name, data)
        if expected is None or _sha256_hex(data) == expected:
            return data
        if reg is not None:
            reg.counter(
                "fw_shard_checksum_failures",
                "shard reads whose bytes failed the manifest sha256",
                ("shard",),
            ).inc(1, shard=name)
        if attempt < SHARD_READ_RETRIES:
            time.sleep(SHARD_RETRY_BASE_S * (2**attempt))
            if reg is not None:
                reg.counter(
                    "fw_shard_retries",
                    "checksum-failed shard reads retried with backoff",
                    ("shard",),
                ).inc(1, shard=name)
    raise ShardIntegrityError(
        f"shard {name!r} failed its manifest sha256 on "
        f"{SHARD_READ_RETRIES + 1} read attempts — on-disk corruption; "
        "re-fetch or re-convert the dataset (scripts/fetch_libsvm.py)"
    )


def verify_shards(shard_dir, *, manifest: Optional[dict] = None) -> List[str]:
    """Names of shard files whose on-disk bytes fail the manifest sha256
    (empty list = healthy, or a legacy manifest without checksums).
    Reads the disk directly — deliberately NOT routed through the
    fault-injection hook, so it reports true on-disk state."""
    if manifest is None:
        manifest = read_manifest(shard_dir)
    sums = manifest.get("checksums")
    if not sums:
        return []
    bad = []
    for name in manifest["shards"]:
        try:
            with open(os.path.join(shard_dir, name), "rb") as fh:
                ok = _sha256_hex(fh.read()) == sums.get(name)
        except OSError:
            ok = False
        if not ok:
            bad.append(name)
    return bad


class COOData(NamedTuple):
    """COO triplets in (sample, feature) orientation plus targets."""

    rows: np.ndarray  # (nnz,) sample indices
    cols: np.ndarray  # (nnz,) feature indices
    vals: np.ndarray  # (nnz,) float32
    y: np.ndarray  # (m,) float32 labels/targets
    shape: Tuple[int, int]  # (m, p)


# --------------------------------------------------------------------------
# svmlight / libsvm text format
# --------------------------------------------------------------------------


@traced("sparse_io/load_svmlight", cat="io")
def load_svmlight(
    path,
    *,
    n_features: Optional[int] = None,
    zero_based: str | bool = "auto",
    dtype=np.float32,
) -> COOData:
    """Parse an svmlight/libsvm text file into COO triplets.

    ``zero_based='auto'`` treats the file as 0-based only when a 0 index
    appears (the libsvm convention is 1-based). CAVEAT: a genuinely
    0-based file whose feature 0 happens to have no nonzeros is
    indistinguishable from a 1-based one — pass ``zero_based`` explicitly
    whenever the writer's convention is known (e.g. round-tripping
    ``save_svmlight(zero_based=True)``). ``qid:`` tokens and ``#``
    comments are ignored. ``n_features`` widens p beyond the max seen
    index (needed for consistent train/test shapes).

    This reader holds the full COO set in memory; for files that do not
    fit, ``convert_svmlight_to_shards`` streams straight to the .npz
    shard layout with one shard of rows in memory at a time.
    """
    rows, cols, vals, y = [], [], [], []
    with open(path, "rt") as fh:
        for line in fh:
            line = line.split("#", 1)[0].strip()
            if not line:
                continue
            parts = line.split()
            y.append(float(parts[0]))
            r = len(y) - 1
            for tok in parts[1:]:
                if tok.startswith("qid:"):
                    continue
                i, v = tok.split(":")
                rows.append(r)
                cols.append(int(i))
                vals.append(float(v))
    rows_a = np.asarray(rows, np.int64)
    cols_a = np.asarray(cols, np.int64)
    vals_a = np.asarray(vals, dtype)
    if zero_based == "auto":
        zero_based = bool(cols_a.size) and int(cols_a.min()) == 0
    if not zero_based:
        cols_a = cols_a - 1
    if cols_a.size and cols_a.min() < 0:
        raise ValueError("negative feature index after base adjustment")
    p = int(cols_a.max()) + 1 if cols_a.size else 0
    if n_features is not None:
        if n_features < p:
            raise ValueError(f"n_features={n_features} < max index + 1 = {p}")
        p = n_features
    return COOData(rows_a, cols_a, vals_a, np.asarray(y, np.float32), (len(y), p))


@traced("sparse_io/save_svmlight", cat="io")
def save_svmlight(path, data: COOData, *, zero_based: bool = False) -> None:
    """Write COO triplets as svmlight text (1-based indices by default).

    Entries are emitted sorted by (row, col) — the canonical layout every
    libsvm tool expects.
    """
    m, _ = data.shape
    order = np.lexsort((data.cols, data.rows))
    rows, cols, vals = data.rows[order], data.cols[order], data.vals[order]
    base = 0 if zero_based else 1
    starts = np.searchsorted(rows, np.arange(m + 1))
    with open(path, "wt") as fh:
        for r in range(m):
            feats = " ".join(
                f"{int(c) + base}:{float(v):.9g}"
                for c, v in zip(
                    cols[starts[r] : starts[r + 1]], vals[starts[r] : starts[r + 1]]
                )
            )
            fh.write(f"{float(data.y[r]):.9g} {feats}".rstrip() + "\n")


# --------------------------------------------------------------------------
# .npz row-range shards + manifest
# --------------------------------------------------------------------------


@traced("sparse_io/write_shards", cat="io")
def write_shards(
    out_dir,
    data: COOData,
    *,
    rows_per_shard: int = 4096,
) -> str:
    """Split a COO dataset into row-range .npz shards + a JSON manifest.

    Returns the manifest path. Shard k holds rows
    [k*rows_per_shard, (k+1)*rows_per_shard) with LOCAL row indices and
    its slice of y, so a consumer never needs more than one shard in
    memory.
    """
    os.makedirs(out_dir, exist_ok=True)
    m, p = data.shape
    n_shards = max(1, -(-m // rows_per_shard))
    order = np.argsort(data.rows, kind="stable")
    rows, cols, vals = data.rows[order], data.cols[order], data.vals[order]
    bounds = np.searchsorted(rows, np.arange(n_shards + 1) * rows_per_shard)
    names = []
    for k in range(n_shards):
        lo_row = k * rows_per_shard
        hi_row = min(m, lo_row + rows_per_shard)
        sl = slice(bounds[k], bounds[k + 1])
        name = f"shard_{k:05d}.npz"
        np.savez(
            os.path.join(out_dir, name),
            rows=(rows[sl] - lo_row).astype(np.int32),
            cols=cols[sl].astype(np.int64),
            vals=vals[sl],
            y=data.y[lo_row:hi_row],
            row_offset=np.int64(lo_row),
        )
        names.append(name)
    manifest = {
        "format": SHARD_FORMAT,
        "m": int(m),
        "p": int(p),
        "rows_per_shard": int(rows_per_shard),
        "shards": names,
        "checksums": _shard_checksums(out_dir, names),
    }
    manifest_path = os.path.join(out_dir, MANIFEST_NAME)
    with open(manifest_path, "wt") as fh:
        json.dump(manifest, fh, indent=2)
    return manifest_path


@traced("sparse_io/convert_svmlight_to_shards", cat="io")
def convert_svmlight_to_shards(
    svm_path,
    out_dir,
    *,
    rows_per_shard: int = 4096,
    zero_based: bool = False,
    n_features: Optional[int] = None,
    dtype=np.float32,
) -> str:
    """Stream an svmlight text file straight into the shard layout.

    Unlike ``load_svmlight`` + ``write_shards`` this never holds more
    than one shard of rows in memory, so multi-GB source files convert on
    shard-sized RAM. ``zero_based`` must be stated explicitly (default:
    the libsvm 1-based convention) — auto-detection needs a full pass and
    is exactly the ambiguity the streaming path avoids. Returns the
    manifest path.
    """
    os.makedirs(out_dir, exist_ok=True)
    base = 0 if zero_based else 1
    names = []
    max_col = -1
    m = 0

    rows_l: list = []
    cols_l: list = []
    vals_l: list = []
    y_l: list = []

    def _flush():
        nonlocal rows_l, cols_l, vals_l, y_l
        k = len(names)
        name = f"shard_{k:05d}.npz"
        np.savez(
            os.path.join(out_dir, name),
            rows=np.asarray(rows_l, np.int32),
            cols=np.asarray(cols_l, np.int64),
            vals=np.asarray(vals_l, dtype),
            y=np.asarray(y_l, np.float32),
            row_offset=np.int64(k * rows_per_shard),
        )
        names.append(name)
        rows_l, cols_l, vals_l, y_l = [], [], [], []

    with open(svm_path, "rt") as fh:
        for line in fh:
            line = line.split("#", 1)[0].strip()
            if not line:
                continue
            parts = line.split()
            y_l.append(float(parts[0]))
            r_local = m % rows_per_shard
            m += 1
            for tok in parts[1:]:
                if tok.startswith("qid:"):
                    continue
                i, v = tok.split(":")
                c = int(i) - base
                if c < 0:
                    raise ValueError("negative feature index after base adjustment")
                max_col = max(max_col, c)
                rows_l.append(r_local)
                cols_l.append(c)
                vals_l.append(float(v))
            if m % rows_per_shard == 0:
                _flush()
    if y_l or not names:
        _flush()

    p = max_col + 1
    if n_features is not None:
        if n_features < p:
            raise ValueError(f"n_features={n_features} < max index + 1 = {p}")
        p = n_features
    manifest = {
        "format": SHARD_FORMAT,
        "m": int(m),
        "p": int(p),
        "rows_per_shard": int(rows_per_shard),
        "shards": names,
        "checksums": _shard_checksums(out_dir, names),
    }
    manifest_path = os.path.join(out_dir, MANIFEST_NAME)
    with open(manifest_path, "wt") as fh:
        json.dump(manifest, fh, indent=2)
    return manifest_path


def read_manifest(shard_dir) -> dict:
    with open(os.path.join(shard_dir, MANIFEST_NAME), "rt") as fh:
        manifest = json.load(fh)
    if manifest.get("format") != SHARD_FORMAT:
        raise ValueError(f"unknown shard format {manifest.get('format')!r}")
    return manifest


def iter_shards(shard_dir) -> Iterator[Tuple[COOData, int]]:
    """Stream (chunk, row_offset) pairs; chunk row ids are GLOBAL."""
    manifest = read_manifest(shard_dir)
    yield from iter_shards_for_rows(shard_dir, 0, manifest["m"],
                                    manifest=manifest)


def shards_for_rows(manifest: dict, lo: int, hi: int) -> list:
    """Shard names overlapping the row range [lo, hi) — pure manifest
    arithmetic (shard k holds rows [k*R, (k+1)*R)), no file IO.

    This is the coo-npz-v1 -> mesh-coordinate mapping the distributed
    loader uses: a host holding the "data" slice [lo, hi) reads ONLY
    these files (repro.distributed.shard.load_sharded_matrix).
    """
    if manifest.get("format") != SHARD_FORMAT:
        raise ValueError(f"unknown shard format {manifest.get('format')!r}")
    R = int(manifest["rows_per_shard"])
    names = manifest["shards"]
    lo = max(0, lo)
    hi = min(int(manifest["m"]), hi)
    if hi <= lo:
        return []
    first = lo // R
    last = -(-hi // R)  # ceil: shard holding row hi-1, inclusive
    return names[first:last]


def iter_shards_for_rows(
    shard_dir, lo: int, hi: int, *, manifest: Optional[dict] = None
) -> Iterator[Tuple[COOData, int]]:
    """Stream only the shards overlapping rows [lo, hi) (GLOBAL row ids,
    like ``iter_shards``). The per-mesh-cell read path: a data-slice
    owner never opens a file outside its row range. ``manifest`` skips
    the re-read when the caller already holds it."""
    if manifest is None:
        manifest = read_manifest(shard_dir)
    p = manifest["p"]
    checksums = manifest.get("checksums") or {}
    reg = obs_metrics.get_registry()
    for name in shards_for_rows(manifest, lo, hi):
        t0 = time.perf_counter()
        # checksum-verified read with bounded retries; the arrays parse
        # from the verified buffer (never a second unverified disk read)
        data = _read_shard_bytes_verified(shard_dir, name, checksums.get(name))
        with np.load(io.BytesIO(data)) as z:
            off = int(z["row_offset"])
            chunk = COOData(
                z["rows"].astype(np.int64) + off,
                z["cols"].astype(np.int64),
                z["vals"],
                z["y"],
                (manifest["m"], p),
            )
        if reg is not None:
            # shard-read accounting: decompressed-in wall time + on-disk
            # bytes per .npz open (the unit the out-of-core assembler and
            # the per-mesh-cell loader both pay)
            elapsed = time.perf_counter() - t0
            n_bytes = len(data)
            reg.counter(
                "fw_shard_reads", "coo-npz-v1 shard files opened"
            ).inc(1)
            reg.counter(
                "fw_shard_read_bytes", "on-disk bytes of shard files read"
            ).inc(n_bytes)
            reg.histogram(
                "fw_shard_read_seconds",
                "wall time per shard .npz open + array materialization",
            ).observe(elapsed)
            reg.histogram(
                "fw_shard_file_bytes",
                "on-disk size distribution of shard files read",
                buckets=obs_metrics.BYTES_BUCKETS,
            ).observe(float(n_bytes))
        yield chunk, off


@traced("sparse_io/load_shards", cat="io")
def load_shards(shard_dir) -> COOData:
    """Concatenate all shards back into one in-memory COO dataset."""
    manifest = read_manifest(shard_dir)
    chunks = [c for c, _ in iter_shards(shard_dir)]
    return COOData(
        np.concatenate([c.rows for c in chunks]),
        np.concatenate([c.cols for c in chunks]),
        np.concatenate([c.vals for c in chunks]),
        np.concatenate([c.y for c in chunks]),
        (manifest["m"], manifest["p"]),
    )


@traced("sparse_io/load_shards_as_matrix", cat="io")
def load_shards_as_matrix(
    shard_dir,
    *,
    block_size: int = 256,
    nnz_max: Optional[int] = None,
    dtype=np.float32,
):
    """Two-pass streaming assembly: shards -> SparseBlockMatrix + y.

    Pass 1 accumulates per-feature nnz counts (sizes the ELL budget);
    pass 2 fills the block-ELL arrays shard by shard. Peak extra memory
    is one shard plus the output arrays — no full COO set, no dense X.
    """
    manifest = read_manifest(shard_dir)
    m, p = manifest["m"], manifest["p"]
    counts = np.zeros(p, np.int64)
    for chunk, _ in iter_shards(shard_dir):
        counts += np.bincount(chunk.cols, minlength=p)
    required = int(counts.max()) if p else 0
    if nnz_max is None:
        nnz_max = max(1, required)
    elif required > nnz_max:
        raise ValueError(
            f"nnz budget {nnz_max} too small: densest feature has {required} "
            f"nonzeros (pass nnz_max>={required})"
        )
    nnz_max = max(1, int(nnz_max))

    nblocks = -(-p // block_size)
    pp = nblocks * block_size
    values = np.zeros((pp, nnz_max), dtype)
    rows = np.zeros((pp, nnz_max), np.int32)
    y = np.zeros(m, np.float32)
    cursor = np.zeros(p, np.int64)
    for chunk, lo in iter_shards(shard_dir):
        y[lo : lo + chunk.y.shape[0]] = chunk.y
        order = np.argsort(chunk.cols, kind="stable")
        cs = chunk.cols[order]
        uniq, first, cnt = np.unique(cs, return_index=True, return_counts=True)
        local = np.arange(cs.size) - np.repeat(first, cnt)
        slot = cursor[cs] + local
        values[cs, slot] = chunk.vals[order].astype(dtype)
        rows[cs, slot] = chunk.rows[order].astype(np.int32)
        cursor[uniq] += cnt
    import jax.numpy as jnp

    mat = SparseBlockMatrix(
        values=jnp.asarray(values.reshape(nblocks, block_size, nnz_max)),
        rows=jnp.asarray(rows.reshape(nblocks, block_size, nnz_max)),
        p=p,
        m=m,
        block_size=block_size,
        nnz_max=nnz_max,
    )
    return mat, y
