"""Solver-facing ops over SparseBlockMatrix (XLA fallback + kernel dispatch).

These are the sparse twins of the three O(m)/O(kappa*m) primitives the
backends share (DESIGN.md §4.5), plus the matvecs the warm start and the
certification-time duality gap need. Everything is a dense gather +
reduction over the rectangular block-ELL arrays, so all ops jit cleanly
and cost O(touched_slots) = O(kappa * nnz_max) instead of O(kappa * m).

Score/stat accumulation is f32 regardless of storage dtype (the dense
Pallas kernels' ``preferred_element_type=jnp.float32`` contract), but the
solver-facing results are returned in the matrix's STORAGE dtype — the
same boundary the dense XLA backend has (``Xt @ y`` on bf16 accumulates
in f32 and yields bf16), which keeps the solver's weakly-typed scalar
recursions in the storage dtype end to end.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.kernels.sparse_colstats.sparse_colstats import sparse_colstats_fused
from repro.kernels.sparse_grad.ref import sparse_sampled_scores_ref
from repro.kernels.sparse_grad.sparse_grad import sparse_sampled_scores
from repro.sparse.matrix import SparseBlockMatrix

ExtraFn = Callable[[jax.Array], jax.Array]


def sparse_block_scores(
    mat: SparseBlockMatrix,
    resid: jax.Array,
    blk: jax.Array,
    *,
    use_kernel: bool = False,
    interpret: bool = False,
    gather_mode: str = "take",
) -> jax.Array:
    """FW scores (-z_i^T R) for the features of the sampled blocks.

    ``use_kernel`` routes through the Pallas scalar-prefetch kernel
    (``kernels/sparse_grad``); otherwise the pure-XLA oracle runs — the
    off-TPU production path, not just a test double. ``gather_mode``
    selects the in-kernel residual read ('take' gather vs the 'onehot'
    matmul fallback); the XLA oracle always gathers.
    """
    if use_kernel:
        return sparse_sampled_scores(
            mat.values, mat.rows, resid, blk, interpret=interpret,
            gather_mode=gather_mode,
        )
    return sparse_sampled_scores_ref(mat.values, mat.rows, resid, blk)


def sparse_fw_vertex_general(
    mat: SparseBlockMatrix,
    w: jax.Array,
    blk: jax.Array,
    *,
    use_kernel: bool = False,
    interpret: bool = False,
    extra_fn: Optional[ExtraFn] = None,
    gather_mode: str = "take",
):
    """(i_star, g_raw, g_sel) over the sampled blocks, masking padding.

    ``g_raw`` is the linear score -z^T w; ``g_sel`` additionally carries
    the oracle's per-coordinate shift ``extra_fn(idx)`` (the elastic-net
    ``+l2 * alpha_i`` term — with ``extra_fn=None`` the two coincide).
    Padded ELL slots and padded tail features score exactly 0, but they
    must still be excluded from the argmax (an all-zero sample would
    otherwise select a phantom coordinate) — same contract as the dense
    ``fw_grad.ops.fw_vertex`` with ``p_valid``. ``extra_fn`` sees clipped
    gathers for padded idx >= p, which the mask makes unselectable.
    """
    scores = sparse_block_scores(
        mat, w, blk, use_kernel=use_kernel, interpret=interpret,
        gather_mode=gather_mode,
    )
    idx = (
        blk[:, None] * mat.block_size + jnp.arange(mat.block_size)[None, :]
    ).reshape(-1)
    sel = scores if extra_fn is None else scores + extra_fn(idx)
    mag = jnp.where(idx < mat.p, jnp.abs(sel), -1.0)
    j = jnp.argmax(mag)
    return idx[j], scores[j].astype(mat.dtype), sel[j].astype(mat.dtype)


def sparse_fw_vertex(
    mat: SparseBlockMatrix,
    resid: jax.Array,
    blk: jax.Array,
    *,
    use_kernel: bool = False,
    interpret: bool = False,
):
    """(i_star, g_star) over the sampled blocks — the pure-linear (lasso)
    reduction of ``sparse_fw_vertex_general``."""
    i_star, g_star, _ = sparse_fw_vertex_general(
        mat, resid, blk, use_kernel=use_kernel, interpret=interpret
    )
    return i_star, g_star


def sparse_gather_scores(mat: SparseBlockMatrix, w: jax.Array, idx: jax.Array):
    """Raw f32 scores -z_i^T w for arbitrary sampled coordinates
    ('uniform' mode). Width-1 gathers have no aligned-block structure to
    prefetch, so this is XLA-only (mirroring how the dense kernel path
    degrades uniform sampling to width-1 bricks). ``idx`` entries are
    < p by construction."""
    b = idx // mat.block_size
    t = idx % mat.block_size
    vals = mat.values[b, t].astype(jnp.float32)  # (kappa, nnz_max)
    rows = mat.rows[b, t]
    return -jnp.sum(vals * jnp.take(w.astype(jnp.float32), rows, axis=0), axis=1)


def sparse_gather_vertex_general(
    mat: SparseBlockMatrix,
    w: jax.Array,
    idx: jax.Array,
    *,
    extra_fn: Optional[ExtraFn] = None,
):
    """(i_star, g_raw, g_sel) for arbitrary sampled coordinates, with the
    optional oracle score shift (see ``sparse_fw_vertex_general``)."""
    scores = sparse_gather_scores(mat, w, idx)
    sel = scores if extra_fn is None else scores + extra_fn(idx)
    j = jnp.argmax(jnp.abs(sel))
    return idx[j], scores[j].astype(mat.dtype), sel[j].astype(mat.dtype)


def sparse_gather_vertex(mat: SparseBlockMatrix, resid: jax.Array, idx: jax.Array):
    """(i_star, g_star) for arbitrary sampled coordinates (lasso form)."""
    i_star, g_star, _ = sparse_gather_vertex_general(mat, resid, idx)
    return i_star, g_star


def sparse_colstats(
    mat: SparseBlockMatrix,
    y: jax.Array,
    *,
    use_kernel: bool = False,
    interpret: bool = False,
    gather_mode: str = "take",
):
    """One pass over the stored slots: z_i^T y and ||z_i||^2 (paper §4.2).

    O(total stored nnz) instead of the dense O(p * m) sweep. With
    ``use_kernel`` the fused Pallas twin (``kernels/sparse_colstats``)
    computes both statistics in one pass over the ELL bricks — the
    sparse analogue of ``kernels/colstats`` for the TPU setup pass; the
    XLA sweep is the production CPU path. Accumulates in f32 and returns
    length-p arrays in the storage dtype (padding sliced off).
    """
    if use_kernel:
        zty_pad, zn2_pad = sparse_colstats_fused(
            mat.values, mat.rows, y, interpret=interpret,
            gather_mode=gather_mode,
        )
        return (
            zty_pad[: mat.p].astype(mat.dtype),
            zn2_pad[: mat.p].astype(mat.dtype),
        )
    vals = mat.values.astype(jnp.float32)
    gathered = jnp.take(y.astype(jnp.float32), mat.rows, axis=0)
    zty = jnp.sum(vals * gathered, axis=2).reshape(-1)[: mat.p]
    znorm2 = jnp.sum(vals * vals, axis=2).reshape(-1)[: mat.p]
    return zty.astype(mat.dtype), znorm2.astype(mat.dtype)


def sparse_column(mat: SparseBlockMatrix, i: jax.Array):
    """(values, rows) ELL slots of feature ``i`` — the z_star the residual
    recursion (eq. 10) touches. One dynamic gather of nnz_max slots."""
    b = i // mat.block_size
    t = i % mat.block_size
    return mat.values[b, t], mat.rows[b, t]


def sparse_column_dense(mat: SparseBlockMatrix, i: jax.Array) -> jax.Array:
    """Dense (m,) column z_i via margin-scatter of the ELL slots.

    The logistic bisection line search needs the whole direction vector
    d_margin = delta_t * z_star - margin, so the sparse column is
    materialized once per step — O(nnz_max) scatter-adds into an O(m)
    zeros vector, amortized against the O(m)-per-probe bisection that
    consumes it. Padded slots add 0.0 at row 0 (structural no-op).
    """
    vals, rows = sparse_column(mat, i)
    z = jnp.zeros((mat.m,), mat.dtype)
    return z.at[rows].add(vals.astype(mat.dtype))


def sparse_residual_update(
    resid: jax.Array,
    y: jax.Array,
    col_vals: jax.Array,
    col_rows: jax.Array,
    lam: jax.Array,
    delta_t: jax.Array,
) -> jax.Array:
    """R <- (1-lam) R + lam (y - delta_t z_star), z_star sparse.

    The dense O(m) part is two vector ops; the z_star term is a
    scatter-add over nnz_max slots (padded slots add 0.0 at row 0 — a
    structural no-op).
    """
    out = (1.0 - lam) * resid + lam * y
    return out.at[col_rows].add((-lam * delta_t) * col_vals.astype(resid.dtype))


def sparse_matvec(mat: SparseBlockMatrix, beta: jax.Array) -> jax.Array:
    """X @ alpha for a coefficient vector of length p (warm-start init)."""
    pp = mat.p_padded
    beta_pad = jnp.zeros((pp,), jnp.float32).at[: mat.p].set(
        beta.astype(jnp.float32)
    )
    contrib = mat.values.reshape(pp, mat.nnz_max).astype(jnp.float32) * beta_pad[:, None]
    out = jnp.zeros((mat.m,), jnp.float32)
    out = out.at[mat.rows.reshape(-1)].add(contrib.reshape(-1))
    return out.astype(beta.dtype)


def sparse_transpose_matvec(mat: SparseBlockMatrix, r: jax.Array) -> jax.Array:
    """Xt @ r over ALL features — O(total nnz). Certification/grids only
    (duality_gap, lambda_grid); the hot loop never calls this."""
    vals = mat.values.astype(jnp.float32)
    gathered = jnp.take(r.astype(jnp.float32), mat.rows, axis=0)
    return jnp.sum(vals * gathered, axis=2).reshape(-1)[: mat.p].astype(mat.dtype)
