"""GPipe-style pipeline parallelism via shard_map + collective_permute.

Stages are laid out along a mesh axis ("stage"); microbatches stream
through the pipeline with the classic (n_micro + n_stages - 1)-tick
schedule. Differentiable end-to-end (jax.grad flows through ppermute),
so it composes with the training stack; validated against sequential
execution in tests/test_pipeline.py.

This is the PP building block for stacking the "pod" axis as a pipeline
dimension at fleet scale (DESIGN.md §6); the dry-run cells use DP/TP/
FSDP/EP+SP, and PP is exercised here as a first-class library feature.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def make_pipeline_fn(
    mesh: Mesh,
    stage_fn: Callable,  # (stage_params, x) -> y, same shape
    n_stages: int,
    axis: str = "stage",
):
    """Returns pipe(params_stacked, xs) -> ys.

    params_stacked: pytree with leading dim n_stages (sharded over `axis`).
    xs: (n_micro, mb, ...) microbatched inputs (replicated).
    ys: (n_micro, mb, ...) outputs of the final stage (replicated).
    """

    def shard_body(params_local, xs):
        # params_local: leading dim 1 (this device's stage)
        params_stage = jax.tree.map(lambda a: a[0], params_local)
        stage_idx = jax.lax.axis_index(axis)
        n_micro = xs.shape[0]
        T = n_micro + n_stages - 1
        mb_shape = xs.shape[1:]

        fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            buf, outs = carry  # buf: activation entering this device
            inject = jnp.where(t < n_micro, t, 0)
            x_in = jnp.where(stage_idx == 0, xs[inject], buf)
            y = stage_fn(params_stage, x_in)
            # last stage records its output at position t - (n_stages - 1)
            out_slot = t - (n_stages - 1)
            do_store = (stage_idx == n_stages - 1) & (out_slot >= 0)
            outs = jax.lax.cond(
                do_store,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(out_slot, 0), 0
                ),
                lambda o: o,
                outs,
            )
            # shift activations forward one stage
            buf_next = jax.lax.ppermute(y, axis, fwd_perm)
            return (buf_next, outs), None

        buf0 = jnp.zeros(mb_shape, xs.dtype)
        outs0 = jnp.zeros((n_micro,) + mb_shape, xs.dtype)
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0), jnp.arange(T))
        # broadcast final outputs from the last stage to all devices
        outs = jax.lax.psum(
            jnp.where(stage_idx == n_stages - 1, outs, jnp.zeros_like(outs)), axis
        )
        return outs

    return shard_map(
        shard_body,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False,
    )
