from repro.parallel.pipeline import make_pipeline_fn

__all__ = ["make_pipeline_fn"]
