"""Top-k gradient compression with error feedback (DESIGN.md §6).

For bandwidth-limited DP all-reduces: transmit only the top-k magnitude
entries per leaf, accumulate the residual locally (error feedback, Stich
et al. 2018) so the compression error is re-injected on later steps —
convergence is preserved while wire bytes drop by ~p/k.

Under GSPMD the all-reduce is implicit; the transform is exposed both as
a pure function (tested for the EF invariant) and as a shard_map DP
example (examples/compressed_dp.py) where the psum really does see the
sparse values.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    error: Any  # per-leaf residual (error feedback memory)


def init_compression(grads) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    )


def _topk_mask(x: jax.Array, k: int) -> jax.Array:
    flat = jnp.abs(x.reshape(-1))
    if k >= flat.shape[0]:
        return jnp.ones_like(x, bool)
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh) & (jnp.abs(x) > 0)


def compress_decompress(
    grads,
    state: CompressionState,
    ratio: float = 0.01,
    min_k: int = 16,
) -> Tuple[Any, CompressionState]:
    """Returns (sparse grads ready for all-reduce, new error state)."""

    def leaf(g, e):
        gf = g.astype(jnp.float32) + e  # error feedback injection
        k = max(int(ratio * gf.size), min(min_k, gf.size))
        mask = _topk_mask(gf, k)
        sent = jnp.where(mask, gf, 0.0)
        new_err = gf - sent
        return sent.astype(g.dtype), new_err

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(state.error)
    outs = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    sparse = jax.tree.unflatten(treedef, [o[0] for o in outs])
    errors = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return sparse, CompressionState(error=errors)


def wire_bytes_saved(grads, ratio: float) -> Tuple[int, int]:
    """(dense_bytes, compressed_bytes) — index+value encoding estimate."""
    dense = sum(g.size * 4 for g in jax.tree.leaves(grads))
    comp = sum(
        max(int(ratio * g.size), 16) * 8 for g in jax.tree.leaves(grads)
    )  # 4B value + 4B index
    return dense, comp
