from repro.compression.topk import (
    CompressionState,
    compress_decompress,
    init_compression,
)

__all__ = ["CompressionState", "compress_decompress", "init_compression"]
