from repro.data.synthetic import make_regression, standardize
from repro.data.proxies import (
    PROXY_SPECS,
    SparseDataset,
    dense_proxy_bytes,
    make_proxy,
    make_sparse_proxy,
)

__all__ = [
    "make_regression",
    "standardize",
    "make_proxy",
    "make_sparse_proxy",
    "dense_proxy_bytes",
    "SparseDataset",
    "PROXY_SPECS",
]
