from repro.data.synthetic import make_regression, standardize
from repro.data.proxies import make_proxy, PROXY_SPECS

__all__ = ["make_regression", "standardize", "make_proxy", "PROXY_SPECS"]
