"""Deterministic synthetic LM data pipeline (DESIGN.md §2).

Seeded, step-addressable batches: batch(step) is a pure function of
(seed, step), so a restarted job consumes the exact same token stream —
the property the crash-equivalence test asserts. A background prefetch
thread hides host-side generation latency (straggler mitigation).

The synthetic stream is a mixture of Zipfian unigrams and deterministic
motifs so the loss actually decreases during the e2e example runs.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig


def batch_at_step(
    cfg: ModelConfig, step: int, *, batch: int, seq_len: int, seed: int = 0
) -> Dict:
    rng = np.random.default_rng((seed, step))
    V = cfg.vocab_size
    # Zipf-ish unigram over a capped vocab + copy motif for learnable signal
    base = rng.zipf(1.3, size=(batch, seq_len + 1)).astype(np.int64)
    tokens = np.minimum(base, V - 1).astype(np.int32)
    # motif: second half repeats the first half (copy task)
    half = (seq_len + 1) // 2
    tokens[:, half : 2 * half] = tokens[:, :half]
    out = {"tokens": tokens}
    if cfg.n_prefix_embeds:
        out["patches"] = rng.standard_normal(
            (batch, cfg.n_prefix_embeds, cfg.d_model)
        ).astype(np.float32)
    if cfg.n_enc_layers:
        out["frames"] = rng.standard_normal(
            (batch, seq_len, cfg.d_model)
        ).astype(np.float32)
    return out


class PrefetchingLoader:
    """Background-thread prefetch of step-addressable batches."""

    def __init__(
        self,
        cfg: ModelConfig,
        *,
        batch: int,
        seq_len: int,
        seed: int = 0,
        prefetch: int = 2,
        start_step: int = 0,
    ):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        while not self._stop.is_set():
            b = batch_at_step(
                self.cfg,
                self._step,
                batch=self.batch,
                seq_len=self.seq_len,
                seed=self.seed,
            )
            self._q.put((self._step, b))
            self._step += 1

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
