"""Synthetic regression data (paper §5: scikit-learn make_regression clone).

The paper generates Synthetic-10000 / Synthetic-50000 with
sklearn.datasets.make_regression (m=200 train + 200 test, p=10000/50000,
32/100 and 158/500 informative features). We reproduce that generator in
numpy: standard-normal X, a sparse ground-truth coefficient vector with
uniform(0, 100) nonzero entries, and additive Gaussian noise.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import numpy as np


class Dataset(NamedTuple):
    X: np.ndarray  # (m, p) float32, standardized columns (unit l2 norm)
    y: np.ndarray  # (m,) float32, centered
    X_test: Optional[np.ndarray]
    y_test: Optional[np.ndarray]
    coef: Optional[np.ndarray]  # ground-truth coefficients, if known
    name: str


def make_regression(
    m: int,
    p: int,
    n_informative: int,
    noise: float = 1.0,
    m_test: int = 0,
    seed: int = 0,
    name: str = "synthetic",
) -> Dataset:
    rng = np.random.default_rng(seed)
    n = m + m_test
    X = rng.standard_normal((n, p)).astype(np.float32)
    coef = np.zeros(p, np.float32)
    support = rng.choice(p, size=n_informative, replace=False)
    coef[support] = rng.uniform(0.0, 100.0, size=n_informative).astype(np.float32)
    y = X @ coef + noise * rng.standard_normal(n).astype(np.float32)
    X_tr, y_tr = X[:m], y[:m]
    X_te = X[m:] if m_test else None
    y_te = y[m:] if m_test else None
    return Dataset(X_tr, y_tr.astype(np.float32), X_te, y_te, coef, name)


def standardize(ds: Dataset) -> Dataset:
    """Center y; scale each predictor to unit l2 norm (paper §4.1 assumption).

    Test data is transformed with the training statistics.
    """
    X = ds.X.astype(np.float64)
    mu = X.mean(axis=0)
    Xc = X - mu
    norms = np.sqrt((Xc * Xc).sum(axis=0))
    norms[norms < 1e-12] = 1.0
    Xs = (Xc / norms).astype(np.float32)
    y_mu = ds.y.mean()
    ys = (ds.y - y_mu).astype(np.float32)

    X_te, y_te = ds.X_test, ds.y_test
    if X_te is not None:
        X_te = ((X_te - mu) / norms).astype(np.float32)
        y_te = (ds.y_test - y_mu).astype(np.float32)
    coef = None if ds.coef is None else (ds.coef * norms).astype(np.float32)
    return Dataset(Xs, ys, X_te, y_te, coef, ds.name)


def paper_synthetic(p: int, n_informative: int, seed: int = 0) -> Dataset:
    """The paper's synthetic configurations: m = t = 200."""
    return standardize(
        make_regression(
            m=200,
            p=p,
            n_informative=n_informative,
            noise=1.0,
            m_test=200,
            seed=seed,
            name=f"synthetic-{p}-{n_informative}",
        )
    )
