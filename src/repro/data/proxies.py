"""Offline proxies for the paper's real benchmark datasets (Table 1).

The container has no network access, so Pyrim / Triazines / E2006-tfidf /
E2006-log1p cannot be downloaded. We generate synthetic proxies that match
the published (m, p) and qualitative structure (sparse columns for the
text datasets, dense polynomial-feature-like correlated columns for the
QSAR ones) at a scale factor chosen for single-core CPU runtime. The scale
factor and true sizes are recorded in every benchmark output and in
EXPERIMENTS.md.
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import numpy as np

from repro.data.synthetic import Dataset, standardize


class ProxySpec(NamedTuple):
    m: int
    t: int  # test examples
    p: int
    col_density: float  # fraction of nonzeros per predictor column
    n_relevant: int  # informative features in the generating model


# Published sizes (paper Table 1) with qualitative structure.
PROXY_SPECS: Dict[str, ProxySpec] = {
    "pyrim": ProxySpec(m=74, t=0, p=201_376, col_density=1.0, n_relevant=60),
    "triazines": ProxySpec(m=186, t=0, p=635_376, col_density=1.0, n_relevant=150),
    "e2006-tfidf": ProxySpec(m=16_087, t=3_308, p=150_360, col_density=0.01, n_relevant=150),
    "e2006-log1p": ProxySpec(m=16_087, t=3_308, p=4_272_227, col_density=0.002, n_relevant=300),
}


def make_proxy(name: str, scale: float = 1.0, seed: int = 0) -> Dataset:
    """Generate a proxy dataset. ``scale`` < 1 shrinks m, t and p uniformly
    (CPU-budget control); scale=1.0 reproduces the published sizes."""
    spec = PROXY_SPECS[name]
    m = max(32, int(spec.m * scale))
    t = int(spec.t * scale)
    p = max(256, int(spec.p * scale))
    n_rel = max(8, int(spec.n_relevant * min(1.0, scale * 2)))

    rng = np.random.default_rng(seed)
    n = m + t
    if spec.col_density >= 1.0:
        # QSAR-like: dense, mildly correlated columns (product features).
        base = rng.standard_normal((n, max(16, p // 64))).astype(np.float32)
        mix = rng.standard_normal((base.shape[1], p)).astype(np.float32) / np.sqrt(
            base.shape[1]
        )
        X = base @ mix + 0.5 * rng.standard_normal((n, p)).astype(np.float32)
    else:
        # Text-like: sparse nonnegative counts, heavy-tailed.
        X = np.zeros((n, p), np.float32)
        nnz_per_row = max(4, int(spec.col_density * p))
        for i in range(n):
            idx = rng.choice(p, size=nnz_per_row, replace=False)
            X[i, idx] = rng.exponential(1.0, size=nnz_per_row).astype(np.float32)

    coef = np.zeros(p, np.float32)
    support = rng.choice(p, size=n_rel, replace=False)
    coef[support] = rng.standard_normal(n_rel).astype(np.float32) * 10.0
    y = X @ coef + 0.5 * rng.standard_normal(n).astype(np.float32)

    ds = Dataset(
        X=X[:m],
        y=y[:m].astype(np.float32),
        X_test=X[m:] if t else None,
        y_test=y[m:].astype(np.float32) if t else None,
        coef=coef,
        name=f"{name}-scale{scale:g}",
    )
    return standardize(ds)
