"""Offline proxies for the paper's real benchmark datasets (Table 1).

The container has no network access, so Pyrim / Triazines / E2006-tfidf /
E2006-log1p cannot be downloaded. We generate synthetic proxies that match
the published (m, p) and qualitative structure (sparse columns for the
text datasets, dense polynomial-feature-like correlated columns for the
QSAR ones) at a scale factor chosen for single-core CPU runtime. The scale
factor and true sizes are recorded in every benchmark output and in
EXPERIMENTS.md.

Two builders:

* ``make_proxy`` — dense (m, p) Dataset. Guarded by a memory budget:
  building E2006-log1p at scale 1.0 would allocate ~270 GB, so any build
  whose dense bytes exceed the budget raises with the estimate instead of
  silently densifying (or OOM-killing the host).
* ``make_sparse_proxy`` — sparse-native builder for the text datasets:
  generates COO triplets directly and assembles a feature-major
  SparseBlockMatrix (DESIGN.md §Sparse) without EVER materializing the
  dense matrix, so the published sizes fit in memory.
"""
from __future__ import annotations

import os
from typing import Dict, NamedTuple, Optional

import numpy as np

from repro.data.synthetic import Dataset, standardize
from repro.sparse.matrix import SparseBlockMatrix

# Default dense-build budget (bytes); override per call or via env.
DENSE_BUDGET_ENV = "REPRO_DENSE_BUDGET_BYTES"
DEFAULT_DENSE_BUDGET = 2 << 30  # 2 GiB


class ProxySpec(NamedTuple):
    m: int
    t: int  # test examples
    p: int
    col_density: float  # fraction of nonzeros per predictor column
    n_relevant: int  # informative features in the generating model


# Published sizes (paper Table 1) with qualitative structure.
PROXY_SPECS: Dict[str, ProxySpec] = {
    "pyrim": ProxySpec(m=74, t=0, p=201_376, col_density=1.0, n_relevant=60),
    "triazines": ProxySpec(m=186, t=0, p=635_376, col_density=1.0, n_relevant=150),
    "e2006-tfidf": ProxySpec(m=16_087, t=3_308, p=150_360, col_density=0.01, n_relevant=150),
    "e2006-log1p": ProxySpec(m=16_087, t=3_308, p=4_272_227, col_density=0.002, n_relevant=300),
}


class SparseDataset(NamedTuple):
    """Sparse-native proxy: feature-major block-ELL matrix + targets.

    Columns are scaled to unit l2 norm (no centering — centering a sparse
    matrix densifies it; the paper's text datasets are used uncentered)
    and y is centered, so the solver sees the same conditioning contract
    as ``standardize`` gives the dense path.
    """

    mat: SparseBlockMatrix
    y: np.ndarray  # (m,) float32, centered
    coef: Optional[np.ndarray]  # generating coefficients (pre-scaling)
    name: str


def dense_proxy_bytes(name: str, scale: float = 1.0, dtype_bytes: int = 4) -> int:
    """Estimated bytes of the dense (m+t, p) build ``make_proxy`` performs."""
    spec = PROXY_SPECS[name]
    m = max(32, int(spec.m * scale))
    t = int(spec.t * scale)
    p = max(256, int(spec.p * scale))
    return (m + t) * p * dtype_bytes


def _dense_budget(max_dense_bytes: Optional[int]) -> int:
    if max_dense_bytes is not None:
        return int(max_dense_bytes)
    return int(os.environ.get(DENSE_BUDGET_ENV, DEFAULT_DENSE_BUDGET))


def make_proxy(
    name: str,
    scale: float = 1.0,
    seed: int = 0,
    max_dense_bytes: Optional[int] = None,
) -> Dataset:
    """Generate a dense proxy dataset. ``scale`` < 1 shrinks m, t and p
    uniformly (CPU-budget control); scale=1.0 reproduces the published
    sizes. Raises MemoryError (with the estimate) when the dense build
    would exceed ``max_dense_bytes`` (default $REPRO_DENSE_BUDGET_BYTES
    or 2 GiB) — route large text datasets through ``make_sparse_proxy``.
    """
    spec = PROXY_SPECS[name]
    budget = _dense_budget(max_dense_bytes)
    est = dense_proxy_bytes(name, scale)
    if est > budget:
        hint = (
            " Use make_sparse_proxy (sparse-native, no densification)."
            if spec.col_density < 1.0
            else " Lower `scale` or raise the budget."
        )
        raise MemoryError(
            f"dense build of {name!r} at scale={scale:g} needs ~{est:,} bytes "
            f"({est / 2**30:.2f} GiB) > budget {budget:,} bytes.{hint}"
        )
    m = max(32, int(spec.m * scale))
    t = int(spec.t * scale)
    p = max(256, int(spec.p * scale))
    n_rel = max(8, int(spec.n_relevant * min(1.0, scale * 2)))

    rng = np.random.default_rng(seed)
    n = m + t
    if spec.col_density >= 1.0:
        # QSAR-like: dense, mildly correlated columns (product features).
        base = rng.standard_normal((n, max(16, p // 64))).astype(np.float32)
        mix = rng.standard_normal((base.shape[1], p)).astype(np.float32) / np.sqrt(
            base.shape[1]
        )
        X = base @ mix + 0.5 * rng.standard_normal((n, p)).astype(np.float32)
    else:
        # Text-like: sparse nonnegative counts, heavy-tailed.
        X = np.zeros((n, p), np.float32)
        nnz_per_row = max(4, int(spec.col_density * p))
        for i in range(n):
            idx = rng.choice(p, size=nnz_per_row, replace=False)
            X[i, idx] = rng.exponential(1.0, size=nnz_per_row).astype(np.float32)

    coef = np.zeros(p, np.float32)
    support = rng.choice(p, size=n_rel, replace=False)
    coef[support] = rng.standard_normal(n_rel).astype(np.float32) * 10.0
    y = X @ coef + 0.5 * rng.standard_normal(n).astype(np.float32)

    ds = Dataset(
        X=X[:m],
        y=y[:m].astype(np.float32),
        X_test=X[m:] if t else None,
        y_test=y[m:].astype(np.float32) if t else None,
        coef=coef,
        name=f"{name}-scale{scale:g}",
    )
    return standardize(ds)


def make_sparse_coo(
    m: int,
    p: int,
    col_density: float,
    n_relevant: int,
    seed: int = 0,
):
    """Text-like sparse regression triplets, never densified.

    Per row, ~col_density*p feature slots are drawn with replacement and
    deduplicated (collisions are O(nnz^2/p) — negligible at the densities
    this serves), with heavy-tailed exponential values; the response is
    accumulated by scatter from a sparse generating coefficient vector.
    Returns (rows, cols, vals, y, coef) with UNIT-NORM columns and
    centered y.
    """
    rng = np.random.default_rng(seed)
    nnz_per_row = max(4, int(col_density * p))
    rows_l, cols_l = [], []
    for i in range(m):
        idx = np.unique(rng.integers(0, p, size=nnz_per_row))
        rows_l.append(np.full(idx.size, i, np.int64))
        cols_l.append(idx)
    rows = np.concatenate(rows_l)
    cols = np.concatenate(cols_l)
    vals = rng.exponential(1.0, size=rows.size).astype(np.float32)

    # unit l2 column norms (no centering — keeps the matrix sparse)
    norm2 = np.zeros(p, np.float64)
    np.add.at(norm2, cols, vals.astype(np.float64) ** 2)
    norms = np.sqrt(norm2)
    norms[norms < 1e-12] = 1.0
    vals = (vals / norms[cols]).astype(np.float32)

    coef = np.zeros(p, np.float32)
    support = rng.choice(p, size=min(n_relevant, p), replace=False)
    coef[support] = rng.standard_normal(support.size).astype(np.float32) * 10.0
    y = np.zeros(m, np.float64)
    np.add.at(y, rows, (vals * coef[cols]).astype(np.float64))
    y += 0.05 * rng.standard_normal(m)
    y -= y.mean()
    return rows, cols, vals, y.astype(np.float32), coef


def make_sparse_proxy(
    name: str,
    scale: float = 1.0,
    seed: int = 0,
    block_size: int = 256,
    nnz_max: Optional[int] = None,
) -> SparseDataset:
    """Sparse-native proxy for the text datasets (E2006-*): builds the
    block-ELL matrix straight from generated COO triplets — memory is
    O(nnz), so the published 4.2M-feature size fits where the dense build
    needs ~270 GB."""
    spec = PROXY_SPECS[name]
    if spec.col_density >= 1.0:
        raise ValueError(
            f"{name!r} is a dense (QSAR-like) dataset; use make_proxy"
        )
    m = max(32, int(spec.m * scale))
    p = max(256, int(spec.p * scale))
    n_rel = max(8, int(spec.n_relevant * min(1.0, scale * 2)))
    rows, cols, vals, y, coef = make_sparse_coo(
        m, p, spec.col_density, n_rel, seed=seed
    )
    mat = SparseBlockMatrix.from_coo(
        rows, cols, vals, (m, p), block_size=block_size, nnz_max=nnz_max
    )
    return SparseDataset(mat=mat, y=y, coef=coef, name=f"{name}-sparse-scale{scale:g}")
