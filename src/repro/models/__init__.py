from repro.models.config import ModelConfig
from repro.models import attention, layers, model, moe, sharding, ssm

__all__ = ["ModelConfig", "attention", "layers", "model", "moe", "sharding", "ssm"]
