"""Grouped-query attention with RoPE, KV cache, sliding windows, softcap.

Covers every attention variant in the assigned pool:
  * GQA with arbitrary (n_heads, n_kv_heads), optional QKV bias (qwen2),
  * local/global alternation + attn-logit softcapping (gemma2),
  * bidirectional encoder attention + cross attention (seamless),
  * one-token decode against a preallocated KV cache (serve_step).

The XLA path below is what the dry-run lowers; a Pallas flash kernel is a
drop-in for TPU runs (kernels/ — validated in interpret mode).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, dense_init, softcap

NEG_INF = -2.0**30  # large-negative fp32/bf16-safe mask value


class KVCache(NamedTuple):
    """Per-layer slice of the decode cache."""

    k: jax.Array  # (B, max_seq, KV, hd)
    v: jax.Array  # (B, max_seq, KV, hd)


def init_attention(key, cfg: ModelConfig, cross: bool = False) -> Dict:
    dt = jnp.dtype(cfg.dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, cfg.d_model, cfg.q_dim, dt),
        "wk": dense_init(k2, cfg.d_model, cfg.kv_dim, dt),
        "wv": dense_init(k3, cfg.d_model, cfg.kv_dim, dt),
        "wo": dense_init(k4, cfg.q_dim, cfg.d_model, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dt)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dt)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dt)
    return p


def _project_qkv(params: Dict, xq: jax.Array, xkv: jax.Array, cfg: ModelConfig):
    B, Sq, _ = xq.shape
    Skv = xkv.shape[1]
    hd = cfg.resolved_head_dim
    q = xq @ params["wq"]
    k = xkv @ params["wk"]
    v = xkv @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, Sq, cfg.n_heads, hd)
    k = k.reshape(B, Skv, cfg.n_kv_heads, hd)
    v = v.reshape(B, Skv, cfg.n_kv_heads, hd)
    return q, k, v


def _sdpa(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Skv, KV, hd)
    v: jax.Array,  # (B, Skv, KV, hd)
    mask: Optional[jax.Array],  # broadcastable to (B, H, Sq, Skv) or None
    cfg: ModelConfig,
    *,
    decode: bool = False,
) -> jax.Array:
    """SDPA with GQA via KV-head repetition, fp32 softmax.

    The repeat-KV formulation keeps a single shardable head axis (Megatron
    GQA-TP): q heads shard over "model" while the repeated K/V slices are
    formed locally from the (replicated or seq-sharded) KV projections.
    The grouped (B,KV,G,Sq,Skv) einsum variant cannot shard KV=8 over a
    16-way model axis and replicates the score tensor — measured 4.3GB/dev
    on qwen2 train (EXPERIMENTS.md §Perf).

    decode=True keeps K/V in the cache's (possibly seq-sharded) layout and
    leaves repeated heads unsharded — flash-decode style: scores/out reduce
    over the sharded cache-seq dim via psum instead of re-sharding the
    cache per token.
    """
    from repro.models import sharding as sh_lib

    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    if KV != H:
        k = jnp.repeat(k, H // KV, axis=2)
        v = jnp.repeat(v, H // KV, axis=2)
    if decode:
        pass  # inherit the cache layout (seq- or head-sharded) — no reshard
    else:
        q = sh_lib.constrain(q, "batch", "seq", "heads", None)
        k = sh_lib.constrain(k, "batch", "kv_seq", "heads", None)
        v = sh_lib.constrain(v, "batch", "kv_seq", "heads", None)
    # bf16 operands, fp32 accumulate/output — MXU-native; avoids XLA
    # hoisting an f32 conversion of the whole KV cache (measured 21GB/dev
    # on qwen2 decode_32k, EXPERIMENTS.md §Perf)
    scores = jnp.einsum(
        "bqhd,bshd->bhqs", q, k, preferred_element_type=jnp.float32
    )
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    if cfg.attn_softcap:
        scores = softcap(scores, cfg.attn_softcap)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, v)
    return out


def _streaming_sdpa(
    q: jax.Array,  # (B, S, H, hd) — RoPE already applied
    k: jax.Array,  # (B, S, H, hd) — KV heads already repeated
    v: jax.Array,
    cfg: ModelConfig,
    is_local,  # traced bool (per-layer flag)
) -> jax.Array:
    """Flash-style attention in pure XLA: outer scan over query chunks,
    inner scan over KV chunks with online max/sum. Peak score memory is
    O(qc * kc) per step instead of O(S^2); FLOPs match the dense masked
    formulation (which also computes the full square).

    Local (sliding-window) layers with window == chunk use a STATIC
    2-chunk band — 16x fewer score FLOPs at 32k/window=1024 (hymba).
    """
    from repro.models import sharding as sh_lib

    B, S, H, hd = q.shape
    C = min(cfg.streaming_chunk, S)
    if cfg.sliding_window:
        C = min(C, max(cfg.sliding_window, 128))
    nq = S // C
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    qc = q.reshape(B, nq, C, H, hd)
    kc = k.reshape(B, nq, C, H, hd)
    vc = v.reshape(B, nq, C, H, hd)

    q_pos = jnp.arange(S).reshape(nq, C)

    def attend_block(qi, q_blk, kv_idx, k_blk, v_blk, m, l, acc):
        """Online-softmax update of one (q_blk, kv_blk) pair."""
        s = jnp.einsum(
            "bchd,bkhd->bhck", q_blk, k_blk, preferred_element_type=jnp.float32
        ) * scale
        if cfg.attn_softcap:
            s = softcap(s, cfg.attn_softcap)
        qp = q_pos[qi][:, None]  # (C, 1)
        kp = (kv_idx * C + jnp.arange(C))[None, :]  # (1, C)
        mask = kp <= qp
        if cfg.sliding_window:
            local_m = mask & (kp > qp - cfg.sliding_window)
            mask = jnp.where(jnp.asarray(is_local), local_m, mask)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))  # (B, H, C)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhck,bkhd->bhcd", p.astype(q.dtype), v_blk,
            preferred_element_type=jnp.float32,
        )
        return m_new, l_new, acc_new

    def q_chunk_body(carry, qi):
        q_blk = qc[:, qi]  # (B, C, H, hd)
        m0 = jnp.full((B, H, C), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, C), jnp.float32)
        a0 = jnp.zeros((B, H, C, hd), jnp.float32)

        def band():
            # static 2-chunk band: kv chunks {qi-1, qi} (window <= C)
            prev = jnp.maximum(qi - 1, 0)
            m1, l1, a1 = attend_block(
                qi, q_blk, prev, kc[:, prev], vc[:, prev], m0, l0, a0
            )
            return attend_block(qi, q_blk, qi, kc[:, qi], vc[:, qi], m1, l1, a1)

        def full_scan():
            def kv_body(c, kj):
                m, l, a = c
                m, l, a = attend_block(qi, q_blk, kj, kc[:, kj], vc[:, kj], m, l, a)
                return (m, l, a), None

            (m1, l1, a1), _ = jax.lax.scan(
                kv_body, (m0, l0, a0), jnp.arange(nq)
            )
            return m1, l1, a1

        if cfg.sliding_window and cfg.sliding_window <= C:
            m, l, acc = jax.lax.cond(jnp.asarray(is_local), band, full_scan)
        else:
            m, l, acc = full_scan()
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        return carry, out.transpose(0, 2, 1, 3)  # (B, C, H, hd)

    _, outs = jax.lax.scan(q_chunk_body, 0, jnp.arange(nq))
    # outs: (nq, B, C, H, hd) -> (B, S, H, hd)
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)


def causal_mask(Sq: int, Skv: int, window: int = 0, offset: int = 0) -> jax.Array:
    """(1, 1, Sq, Skv) boolean mask. ``offset`` = absolute position of query 0.
    ``window`` > 0 restricts to a sliding window (local attention)."""
    qpos = jnp.arange(Sq)[:, None] + offset
    kpos = jnp.arange(Skv)[None, :]
    m = kpos <= qpos
    if window > 0:
        m = m & (kpos > qpos - window)
    return m[None, None]


def attend(
    params: Dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    *,
    is_local: jax.Array | bool = False,
    causal: bool = True,
) -> jax.Array:
    """Full-sequence attention (training / prefill without cache return)."""
    out, _ = attend_with_kv(params, x, positions, cfg, is_local=is_local, causal=causal)
    return out


def attend_with_kv(
    params: Dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    *,
    is_local: jax.Array | bool = False,
    causal: bool = True,
) -> Tuple[jax.Array, KVCache]:
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, x, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if causal and S >= cfg.streaming_attn_threshold and S % min(cfg.streaming_chunk, S) == 0:
        from repro.models import sharding as sh_lib

        H = cfg.n_heads
        KV = k.shape[2]
        kf = jnp.repeat(k, H // KV, axis=2) if KV != H else k
        vf = jnp.repeat(v, H // KV, axis=2) if KV != H else v
        qs = sh_lib.constrain(q, "batch", "seq", "heads", None)
        kf = sh_lib.constrain(kf, "batch", "kv_seq", "heads", None)
        vf = sh_lib.constrain(vf, "batch", "kv_seq", "heads", None)
        out = _streaming_sdpa(qs, kf, vf, cfg, is_local)
    else:
        if causal:
            full = causal_mask(S, S)
            if cfg.sliding_window:
                local = causal_mask(S, S, window=cfg.sliding_window)
                mask = jnp.where(jnp.asarray(is_local), local, full)
            else:
                mask = full
        else:
            mask = jnp.ones((1, 1, S, S), bool)
        out = _sdpa(q, k, v, mask, cfg)
    out = out.reshape(B, S, cfg.q_dim) @ params["wo"]
    return out, KVCache(k=k, v=v)


def cross_attend(
    params: Dict,
    x: jax.Array,
    memory: jax.Array,
    cfg: ModelConfig,
) -> jax.Array:
    """Encoder-decoder cross attention (no RoPE on cross keys, full mask)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, x, memory, cfg)
    out = _sdpa(q, k, v, None, cfg)
    return out.reshape(B, S, cfg.q_dim) @ params["wo"]


def decode_attend(
    params: Dict,
    x: jax.Array,  # (B, 1, D) current token activations
    cache: KVCache,  # preallocated (B, max_seq, KV, hd)
    cache_len: jax.Array,  # (B,) current lengths (tokens already in cache)
    cfg: ModelConfig,
    *,
    is_local: jax.Array | bool = False,
) -> Tuple[jax.Array, KVCache]:
    """One-token decode: append K/V at cache_len, attend over the prefix."""
    B = x.shape[0]
    max_seq = cache.k.shape[1]
    positions = cache_len[:, None]  # (B, 1)
    q, k, v = _project_qkv(params, x, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cfg.ragged_decode:
        # per-row positions (continuous batching): one-hot scatter-add.
        # Costs two cache-sized temporaries (baseline in §Perf).
        onehot = jax.nn.one_hot(cache_len, max_seq, dtype=cache.k.dtype)
        k_cache = cache.k + onehot[:, :, None, None] * k
        v_cache = cache.v + onehot[:, :, None, None] * v
    else:
        # uniform-length fast path: in-place row update, no temporaries
        pos = cache_len[0]
        k_cache = jax.lax.dynamic_update_slice(
            cache.k, k.astype(cache.k.dtype), (0, pos, 0, 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            cache.v, v.astype(cache.v.dtype), (0, pos, 0, 0)
        )

    kpos = jnp.arange(max_seq)[None, :]
    valid = kpos <= cache_len[:, None]
    if cfg.sliding_window:
        local_valid = valid & (kpos > (cache_len[:, None] - cfg.sliding_window))
        valid = jnp.where(jnp.asarray(is_local), local_valid, valid)
    mask = valid[:, None, None, :]  # (B, 1, 1(Sq), max_seq)

    out = _sdpa(q, k_cache, v_cache, mask, cfg, decode=True)
    out = out.reshape(B, 1, cfg.q_dim) @ params["wo"]
    return out, KVCache(k=k_cache, v=v_cache)
