"""Mamba2 / SSD (state-space duality) blocks — arXiv:2405.21060.

Implements the chunked SSD algorithm (quadratic intra-chunk + decayed
inter-chunk state passing) for training/prefill, and the O(1)-per-token
recurrent step for decode. Grouping G=1 (single B/C group broadcast over
heads), depthwise causal conv of width 4, gated RMSNorm, SiLU.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, init_rmsnorm, rmsnorm


class SSMCache(NamedTuple):
    conv: jax.Array  # (B, W-1, conv_dim) last inputs of the causal conv
    state: jax.Array  # (B, H, P, N) recurrent SSM state


def conv_dim(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_state  # x, B, C channels (G=1)


def init_ssm(key, cfg: ModelConfig) -> Dict:
    dt = jnp.dtype(cfg.dtype)
    H = cfg.ssm_heads
    N = cfg.ssm_state
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d_in_proj = 2 * cfg.d_inner + 2 * N + H  # z, x, B, C, dt
    # Mamba2 reference init: A ~ -Uniform(1, 16); dt sampled log-uniform in
    # [1e-3, 1e-1] through an inverse-softplus bias.
    a_init = jax.random.uniform(k3, (H,), jnp.float32, 1.0, 16.0)
    dt_init = jnp.exp(
        jax.random.uniform(k3, (H,), jnp.float32) * (jnp.log(0.1) - jnp.log(1e-3))
        + jnp.log(1e-3)
    )
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))  # softplus^{-1}(dt)
    return {
        "in_proj": dense_init(k1, cfg.d_model, d_in_proj, dt),
        "conv_w": (jax.random.normal(k2, (cfg.ssm_conv_width, conv_dim(cfg)), jnp.float32) * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_dim(cfg),), dt),
        "A_log": jnp.log(a_init),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_bias,
        "norm": init_rmsnorm(cfg.d_inner, dt),
        "out_proj": dense_init(k4, cfg.d_inner, cfg.d_model, dt),
    }


def _split_in_proj(z_x_b_c_dt: jax.Array, cfg: ModelConfig):
    N = cfg.ssm_state
    di = cfg.d_inner
    H = cfg.ssm_heads
    z, xbc, dt = jnp.split(z_x_b_c_dt, [di, 2 * di + 2 * N], axis=-1)
    return z, xbc, dt  # xbc = [x, B, C] conv channels


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, S, C) with kernel (W, C)."""
    W = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(W):  # W=4: unrolled taps, fused by XLA
        out = out + pad[:, i : i + xbc.shape[1], :] * w[i]
    return out + b


def _segsum(x: jax.Array) -> jax.Array:
    """(..., T) -> (..., T, T) lower-tri segment sums; -inf above diagonal."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan(
    x: jax.Array,  # (B, S, H, P) inputs (dt already applied by caller)
    dA: jax.Array,  # (B, S, H)  = dt * A  (negative)
    Bmat: jax.Array,  # (B, S, N)  G=1 group
    Cmat: jax.Array,  # (B, S, N)
    chunk: int,
    initial_state: jax.Array | None = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    N = Bmat.shape[-1]
    assert S % chunk == 0, f"seq {S} not divisible by chunk {chunk}"
    C_ = S // chunk

    xc = x.reshape(Bsz, C_, chunk, H, P)
    Ac = dA.reshape(Bsz, C_, chunk, H).transpose(0, 3, 1, 2)  # (B, H, C, L)
    Bc = Bmat.reshape(Bsz, C_, chunk, N)
    Cc = Cmat.reshape(Bsz, C_, chunk, N)

    A_cumsum = jnp.cumsum(Ac, axis=-1)  # (B, H, C, L)

    # 1. intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(Ac))  # (B, H, C, L, L)
    Y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", Cc, Bc, L, xc)

    # 2. per-chunk end states
    decay_states = jnp.exp(A_cumsum[..., -1:] - A_cumsum)  # (B, H, C, L)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", Bc, decay_states, xc)

    # 3. inter-chunk recurrence (scan over chunks) — state kept in f32
    # (recurrent accumulation; also keeps the scan carry type stable when
    # activations are bf16)
    chunk_decay = jnp.exp(A_cumsum[..., -1])  # (B, H, C) f32
    s0 = (
        jnp.zeros((Bsz, H, P, N), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def step(carry, inp):
        state_in = carry  # (B, H, P, N) f32
        chunk_state, decay = inp  # (B, H, P, N), (B, H)
        state_out = state_in * decay[..., None, None] + chunk_state.astype(jnp.float32)
        return state_out, state_in  # emit the state *entering* this chunk

    states_t = states.astype(jnp.float32).transpose(1, 0, 2, 3, 4)  # (C,B,H,P,N)
    decay_t = chunk_decay.transpose(2, 0, 1)  # (C, B, H)
    final_state, entry_states = jax.lax.scan(step, s0, (states_t, decay_t))
    entry_states = entry_states.transpose(1, 0, 2, 3, 4)  # (B, C, H, P, N)

    # 4. contribution of the entering state to each position in the chunk
    state_decay = jnp.exp(A_cumsum)  # (B, H, C, L)
    Y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cc, entry_states, state_decay)

    y = (Y_diag + Y_off).astype(x.dtype).reshape(Bsz, S, H, P)
    return y, final_state


def apply_ssm(
    params: Dict,
    x: jax.Array,  # (B, S, D)
    cfg: ModelConfig,
) -> jax.Array:
    y, _ = apply_ssm_with_state(params, x, cfg)
    return y


def apply_ssm_with_state(params: Dict, x: jax.Array, cfg: ModelConfig):
    Bsz, S, _ = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state

    zxbcdt = x @ params["in_proj"]
    z, xbc, dt = _split_in_proj(zxbcdt, cfg)
    xbc = jax.nn.silu(_causal_conv(xbc, params["conv_w"], params["conv_b"]))
    xs, Bmat, Cmat = jnp.split(xbc, [cfg.d_inner, cfg.d_inner + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    A = -jnp.exp(params["A_log"])  # (H,)
    xs_h = xs.reshape(Bsz, S, H, P)
    x_dt = xs_h * dt[..., None].astype(xs.dtype)
    dA = dt * A  # (B, S, H) fp32

    # pad to a chunk multiple; padded steps are identity (dA=0, x=0) so the
    # final state is exact for any S
    S_pad = -(-S // cfg.ssm_chunk) * cfg.ssm_chunk
    if S_pad != S:
        pad = S_pad - S
        x_dt = jnp.pad(x_dt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))

    y, final_state = ssd_scan(x_dt, dA, Bmat, Cmat, cfg.ssm_chunk)
    if S_pad != S:
        y = y[:, :S]
    y = y + params["D"].astype(y.dtype)[None, None, :, None] * xs_h
    y = y.reshape(Bsz, S, cfg.d_inner)
    y = y * jax.nn.silu(z)
    y = rmsnorm(params["norm"], y, cfg.norm_eps)
    return y @ params["out_proj"], final_state


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> SSMCache:
    return SSMCache(
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim(cfg)), dtype),
        state=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
    )


def decode_ssm(
    params: Dict,
    x: jax.Array,  # (B, 1, D)
    cache: SSMCache,
    cfg: ModelConfig,
) -> Tuple[jax.Array, SSMCache]:
    """Single-token recurrent step: h <- exp(dt A) h + dt B x ; y = C h + D x."""
    Bsz = x.shape[0]
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state

    zxbcdt = x[:, 0] @ params["in_proj"]  # (B, proj)
    z, xbc, dt = _split_in_proj(zxbcdt, cfg)

    # conv over the cached window + current input
    window = jnp.concatenate([cache.conv, xbc[:, None, :]], axis=1)  # (B, W, C)
    conv_out = jnp.einsum("bwc,wc->bc", window, params["conv_w"]) + params["conv_b"]
    xbc_t = jax.nn.silu(conv_out)
    new_conv = window[:, 1:, :]

    xs, Bmat, Cmat = jnp.split(xbc_t, [cfg.d_inner, cfg.d_inner + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B, H)
    A = -jnp.exp(params["A_log"])  # (H,)
    dA = jnp.exp(dt * A)  # (B, H)

    xs_h = xs.reshape(Bsz, H, P).astype(jnp.float32)
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt, Bmat.astype(jnp.float32), xs_h)
    state = cache.state * dA[..., None, None] + dBx  # (B, H, P, N)
    y = jnp.einsum("bhpn,bn->bhp", state, Cmat.astype(jnp.float32))
    y = y + params["D"][None, :, None] * xs_h
    y = y.reshape(Bsz, cfg.d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(params["norm"], y, cfg.norm_eps)
    out = (y @ params["out_proj"])[:, None, :]  # (B, 1, D)
    return out, SSMCache(conv=new_conv, state=state)
