"""Composable decoder / encoder-decoder LM covering all assigned families.

Blocks are pre-norm residual (optionally sandwich-norm, gemma2); the mixer
is attention, SSD, or both in parallel (hymba); the FFN is a gated MLP,
an MoE layer, or absent (mamba2, d_ff=0). Layer stacks run under
``jax.lax.scan`` over stacked params with optional remat, which keeps HLO
size and compile time bounded at 80 layers x 512 devices.

Entry points:
  init_params(key, cfg)                     -> param pytree
  forward(params, batch, cfg)               -> fp32 logits (train/prefill)
  loss_fn(params, batch, cfg)               -> scalar CE loss + metrics
  init_cache(cfg, batch, max_seq, dtype)    -> decode cache pytree
  prefill(params, batch, cfg, cache)        -> (logits_last, cache)
  decode_step(params, tokens, cache, cfg)   -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import sharding as sh_lib
from repro.models.attention import KVCache
from repro.models.config import ModelConfig
from repro.models.layers import (
    dense_init,
    embed_tokens,
    init_embedding,
    init_lm_head,
    init_mlp,
    init_rmsnorm,
    lm_logits,
    apply_mlp,
    rmsnorm,
)

# ---------------------------------------------------------------------------
# Per-layer flags
# ---------------------------------------------------------------------------


def local_layer_flags(cfg: ModelConfig, n_layers: int) -> np.ndarray:
    """Boolean array: True where the layer uses local (sliding) attention."""
    if cfg.global_layer_indices:
        flags = np.ones(n_layers, bool)
        for i in cfg.global_layer_indices:
            if i < n_layers:
                flags[i] = False
        return flags
    return np.array(
        [cfg.pattern_for_layer(i) == "local" for i in range(n_layers)], bool
    )


def _has_attn(cfg: ModelConfig) -> bool:
    return cfg.family != "ssm"


def _has_ssm(cfg: ModelConfig) -> bool:
    return cfg.family in ("ssm", "hybrid")


def _has_ffn(cfg: ModelConfig) -> bool:
    return cfg.d_ff > 0 or cfg.n_experts > 0


# ---------------------------------------------------------------------------
# Block init
# ---------------------------------------------------------------------------


def _init_block(key, cfg: ModelConfig, *, use_moe: bool, cross: bool = False) -> Dict:
    dt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    p: Dict = {"ln1": init_rmsnorm(cfg.d_model, dt)}
    if _has_attn(cfg):
        p["attn"] = attn_lib.init_attention(keys[0], cfg)
    if _has_ssm(cfg):
        p["ssm"] = ssm_lib.init_ssm(keys[1], cfg)
    if cross:
        p["ln_cross"] = init_rmsnorm(cfg.d_model, dt)
        p["cross"] = attn_lib.init_attention(keys[2], cfg)
    if _has_ffn(cfg):
        p["ln2"] = init_rmsnorm(cfg.d_model, dt)
        if use_moe:
            p["moe"] = moe_lib.init_moe(keys[3], cfg)
        else:
            p["mlp"] = init_mlp(keys[4], cfg)
    if cfg.sandwich_norm:
        p["ln1_post"] = init_rmsnorm(cfg.d_model, dt)
        if _has_ffn(cfg):
            p["ln2_post"] = init_rmsnorm(cfg.d_model, dt)
    return p


def _stack_blocks(key, cfg: ModelConfig, n: int, *, use_moe: bool, cross: bool = False):
    keys = jax.random.split(key, n)
    return jax.vmap(
        lambda k: _init_block(k, cfg, use_moe=use_moe, cross=cross)
    )(keys)


def init_params(key, cfg: ModelConfig) -> Dict:
    k_embed, k_pre, k_main, k_enc, k_head, k_front = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.dtype)
    params: Dict = {"embed": init_embedding(k_embed, cfg)}

    n_moe_layers = cfg.n_layers - cfg.first_k_dense if cfg.n_experts else cfg.n_layers
    if cfg.n_experts and cfg.first_k_dense:
        params["prefix_layers"] = _stack_blocks(
            k_pre, cfg, cfg.first_k_dense, use_moe=False
        )
    params["layers"] = _stack_blocks(
        k_main, cfg, n_moe_layers, use_moe=cfg.n_experts > 0,
        cross=cfg.cross_attention,
    )
    if cfg.n_enc_layers:
        ke1, ke2 = jax.random.split(k_enc)
        params["encoder"] = {
            "frontend": dense_init(ke1, cfg.d_model, cfg.d_model, dt),
            "layers": _stack_blocks(ke2, cfg, cfg.n_enc_layers, use_moe=False),
            "norm": init_rmsnorm(cfg.d_model, dt),
        }
    if cfg.n_prefix_embeds:
        params["patch_proj"] = dense_init(k_front, cfg.d_model, cfg.d_model, dt)
    params["final_norm"] = init_rmsnorm(cfg.d_model, dt)
    params["lm_head"] = init_lm_head(k_head, cfg)
    return params


# ---------------------------------------------------------------------------
# Block apply (full sequence)
# ---------------------------------------------------------------------------


def _apply_block(
    block: Dict,
    x: jax.Array,
    positions: jax.Array,
    is_local,
    cfg: ModelConfig,
    *,
    causal: bool = True,
    memory: Optional[jax.Array] = None,
) -> jax.Array:
    h = rmsnorm(block["ln1"], x, cfg.norm_eps)
    mix = 0.0
    if "attn" in block:
        mix = attn_lib.attend(
            block["attn"], h, positions, cfg, is_local=is_local, causal=causal
        )
    if "ssm" in block:
        s = ssm_lib.apply_ssm(block["ssm"], h, cfg)
        mix = 0.5 * (mix + s) if "attn" in block else s
    if cfg.sandwich_norm:
        mix = rmsnorm(block["ln1_post"], mix, cfg.norm_eps)
    x = x + mix

    if memory is not None and "cross" in block:
        hc = rmsnorm(block["ln_cross"], x, cfg.norm_eps)
        x = x + attn_lib.cross_attend(block["cross"], hc, memory, cfg)

    if "ln2" in block:
        h2 = rmsnorm(block["ln2"], x, cfg.norm_eps)
        if "moe" in block:
            ff = moe_lib.apply_moe(block["moe"], h2, cfg)
        else:
            ff = apply_mlp(block["mlp"], h2, cfg)
        if cfg.sandwich_norm:
            ff = rmsnorm(block["ln2_post"], ff, cfg.norm_eps)
        x = x + ff
    return x


def _maybe_scan(body, x, xs_tree, cfg: ModelConfig):
    """lax.scan over stacked layers, or a Python unroll when
    cfg.scan_layers=False (used by the dry-run cost probe: XLA's
    cost_analysis counts while-loop bodies once)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, x, xs_tree)
    n = jax.tree.leaves(xs_tree)[0].shape[0]
    ys = []
    for i in range(n):
        x, y = body(x, jax.tree.map(lambda a: a[i], xs_tree))
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        stacked = None
    return x, stacked


def _scan_stack(
    stacked: Dict,
    x: jax.Array,
    positions: jax.Array,
    local_flags: jax.Array,
    cfg: ModelConfig,
    *,
    causal: bool = True,
    memory: Optional[jax.Array] = None,
) -> jax.Array:
    def body(carry, layer):
        block, is_local = layer
        out = _apply_block(
            block, carry, positions, is_local, cfg, causal=causal, memory=memory
        )
        return out, None

    fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) if cfg.remat else body
    if cfg.scan_layers:
        x, _ = jax.lax.scan(fn, x, (stacked, local_flags))
        return x
    n = local_flags.shape[0]
    for i in range(n):
        block = jax.tree.map(lambda a: a[i], stacked)
        x, _ = fn(x, (block, local_flags[i]))
    return x


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------


def _encode(params: Dict, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Encoder over stub frame embeddings (B, S_enc, D)."""
    enc = params["encoder"]
    x = frames.astype(jnp.dtype(cfg.dtype)) @ enc["frontend"]
    x = sh_lib.constrain(x, "batch", "seq", "act_embed")
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None], x.shape[:2])
    flags = jnp.zeros((cfg.n_enc_layers,), bool)
    x = _scan_stack(enc["layers"], x, positions, flags, cfg, causal=False)
    return rmsnorm(enc["norm"], x, cfg.norm_eps)


def _decoder_inputs(params, batch, cfg: ModelConfig):
    """Token embeddings (+ multimodal prefix) and positions for the decoder."""
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens, cfg)
    prefix_len = 0
    if cfg.n_prefix_embeds and "patches" in batch:
        patches = batch["patches"].astype(x.dtype) @ params["patch_proj"]
        x = jnp.concatenate([patches, x], axis=1)
        prefix_len = patches.shape[1]
    B, S = x.shape[:2]
    x = sh_lib.constrain(x, "batch", "seq", "act_embed")
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    return x, positions, prefix_len


def forward(params: Dict, batch: Dict, cfg: ModelConfig) -> jax.Array:
    """Full-sequence forward -> fp32 logits over the decoder positions."""
    memory = None
    if cfg.n_enc_layers:
        memory = _encode(params, batch["frames"], cfg)

    x, positions, prefix_len = _decoder_inputs(params, batch, cfg)

    if "prefix_layers" in params:
        pre_flags = jnp.asarray(local_layer_flags(cfg, cfg.first_k_dense))
        x = _scan_stack(params["prefix_layers"], x, positions, pre_flags, cfg,
                        memory=memory)
    n_main = cfg.n_layers - (cfg.first_k_dense if cfg.n_experts else 0)
    offset = cfg.first_k_dense if cfg.n_experts else 0
    flags_all = local_layer_flags(cfg, cfg.n_layers)
    flags = jnp.asarray(flags_all[offset:])
    x = _scan_stack(params["layers"], x, positions, flags, cfg, memory=memory)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if prefix_len:
        x = x[:, prefix_len:]
    return lm_logits(params["lm_head"], params["embed"], x, cfg)


def loss_fn(params: Dict, batch: Dict, cfg: ModelConfig):
    """Next-token cross entropy. batch['tokens'] has S+1 positions."""
    tokens = batch["tokens"]
    inputs = dict(batch)
    inputs["tokens"] = tokens[:, :-1]
    labels = tokens[:, 1:]
    logits = forward(params, inputs, cfg)  # (B, S, V) fp32
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = -jnp.mean(ll)
    metrics = {"loss": loss, "ppl_proxy": jnp.exp(jnp.minimum(loss, 20.0))}
    return loss, metrics


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None) -> Dict:
    dt = jnp.dtype(dtype or cfg.dtype)
    hd = cfg.resolved_head_dim
    L = cfg.n_layers
    cache: Dict = {"len": jnp.zeros((batch,), jnp.int32)}
    if _has_attn(cfg):
        cache["k"] = jnp.zeros((L, batch, max_seq, cfg.n_kv_heads, hd), dt)
        cache["v"] = jnp.zeros((L, batch, max_seq, cfg.n_kv_heads, hd), dt)
    if _has_ssm(cfg):
        cache["conv"] = jnp.zeros(
            (L, batch, cfg.ssm_conv_width - 1, ssm_lib.conv_dim(cfg)), dt
        )
        cache["ssm"] = jnp.zeros(
            (L, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
        )
    return cache


def prefill(params: Dict, batch: Dict, cfg: ModelConfig, max_seq: int):
    """Process the prompt, build the cache, return last-position logits.

    Note: for simplicity the prompt occupies positions [0, S); all batch
    rows share the prompt length (synthetic serving harness).
    """
    memory = _encode(params, batch["frames"], cfg) if cfg.n_enc_layers else None
    x, positions, prefix_len = _decoder_inputs(params, batch, cfg)
    B, S = x.shape[:2]
    cache = init_cache(cfg, B, max(max_seq, S))  # prefix embeds may extend S
    flags_all = local_layer_flags(cfg, cfg.n_layers)

    # run block-by-block collecting KV (scan emits per-layer cache slices)
    def body(carry, layer):
        block, is_local = layer
        h = rmsnorm(block["ln1"], carry, cfg.norm_eps)
        new_caches = {}
        mix = 0.0
        if "attn" in block:
            a, kv = attn_lib.attend_with_kv(
                block["attn"], h, positions, cfg, is_local=is_local
            )
            mix = a
            new_caches["k"], new_caches["v"] = kv.k, kv.v
        if "ssm" in block:
            s, state = ssm_lib.apply_ssm_with_state(block["ssm"], h, cfg)
            mix = 0.5 * (mix + s) if "attn" in block else s
            new_caches["ssm"] = state
            # conv cache: last W-1 conv inputs of the prompt
            zxbcdt = h @ block["ssm"]["in_proj"]
            _, xbc, _ = ssm_lib._split_in_proj(zxbcdt, cfg)
            new_caches["conv"] = xbc[:, -(cfg.ssm_conv_width - 1):, :]
        if cfg.sandwich_norm:
            mix = rmsnorm(block["ln1_post"], mix, cfg.norm_eps)
        out = carry + mix
        if memory is not None and "cross" in block:
            hc = rmsnorm(block["ln_cross"], out, cfg.norm_eps)
            out = out + attn_lib.cross_attend(block["cross"], hc, memory, cfg)
        if "ln2" in block:
            h2 = rmsnorm(block["ln2"], out, cfg.norm_eps)
            ff = moe_lib.apply_moe(block["moe"], h2, cfg) if "moe" in block else apply_mlp(block["mlp"], h2, cfg)
            if cfg.sandwich_norm:
                ff = rmsnorm(block["ln2_post"], ff, cfg.norm_eps)
            out = out + ff
        return out, new_caches

    stacks = []
    if "prefix_layers" in params:
        stacks.append((params["prefix_layers"], flags_all[: cfg.first_k_dense]))
        stacks.append((params["layers"], flags_all[cfg.first_k_dense :]))
    else:
        stacks.append((params["layers"], flags_all))

    collected = []
    for stacked, flags in stacks:
        x, caches = _maybe_scan(body, x, (stacked, jnp.asarray(flags)), cfg)
        collected.append(caches)

    # merge per-stack caches into the preallocated buffers
    layer_off = 0
    for caches in collected:
        n = jax.tree.leaves(caches)[0].shape[0] if caches else 0
        if not caches:
            continue
        if "k" in caches and "k" in cache:
            cache["k"] = jax.lax.dynamic_update_slice_in_dim(
                cache["k"],
                jax.lax.dynamic_update_slice_in_dim(
                    jnp.zeros_like(cache["k"][layer_off : layer_off + n]),
                    caches["k"], 0, axis=2,
                ),
                layer_off, axis=0,
            )
            cache["v"] = jax.lax.dynamic_update_slice_in_dim(
                cache["v"],
                jax.lax.dynamic_update_slice_in_dim(
                    jnp.zeros_like(cache["v"][layer_off : layer_off + n]),
                    caches["v"], 0, axis=2,
                ),
                layer_off, axis=0,
            )
        if "ssm" in caches and "ssm" in cache:
            cache["ssm"] = jax.lax.dynamic_update_slice_in_dim(
                cache["ssm"], caches["ssm"].astype(cache["ssm"].dtype), layer_off, axis=0
            )
            cache["conv"] = jax.lax.dynamic_update_slice_in_dim(
                cache["conv"], caches["conv"].astype(cache["conv"].dtype), layer_off, axis=0
            )
        layer_off += n

    cache["len"] = jnp.full((B,), S, jnp.int32)  # S already includes prefix
    if memory is not None:
        cache["memory"] = memory

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_logits(params["lm_head"], params["embed"], x[:, -1:], cfg)
    return logits, cache


def decode_step(params: Dict, tokens: jax.Array, cache: Dict, cfg: ModelConfig):
    """One decode step. tokens: (B, 1) -> (logits (B,1,V), updated cache)."""
    x = embed_tokens(params["embed"], tokens, cfg)
    x = sh_lib.constrain(x, "batch", "seq", "act_embed")
    memory = cache.get("memory")
    flags_all = jnp.asarray(local_layer_flags(cfg, cfg.n_layers))
    cache_len = cache["len"]

    def body(carry, layer):
        block, is_local, layer_cache = layer
        h = rmsnorm(block["ln1"], carry, cfg.norm_eps)
        new_cache = {}
        mix = 0.0
        if "attn" in block:
            kv = KVCache(k=layer_cache["k"], v=layer_cache["v"])
            a, kv = attn_lib.decode_attend(
                block["attn"], h, kv, cache_len, cfg, is_local=is_local
            )
            mix = a
            new_cache["k"], new_cache["v"] = kv.k, kv.v
        if "ssm" in block:
            sc = ssm_lib.SSMCache(conv=layer_cache["conv"], state=layer_cache["ssm"])
            s, sc = ssm_lib.decode_ssm(block["ssm"], h, sc, cfg)
            mix = 0.5 * (mix + s) if "attn" in block else s
            new_cache["conv"], new_cache["ssm"] = sc.conv, sc.state
        if cfg.sandwich_norm:
            mix = rmsnorm(block["ln1_post"], mix, cfg.norm_eps)
        out = carry + mix
        if memory is not None and "cross" in block:
            hc = rmsnorm(block["ln_cross"], out, cfg.norm_eps)
            out = out + attn_lib.cross_attend(block["cross"], hc, memory, cfg)
        if "ln2" in block:
            h2 = rmsnorm(block["ln2"], out, cfg.norm_eps)
            ff = moe_lib.apply_moe(block["moe"], h2, cfg) if "moe" in block else apply_mlp(block["mlp"], h2, cfg)
            if cfg.sandwich_norm:
                ff = rmsnorm(block["ln2_post"], ff, cfg.norm_eps)
            out = out + ff
        return out, new_cache

    cache_keys = [k for k in ("k", "v", "conv", "ssm") if k in cache]

    layer_off = 0
    x_cur = x
    stacks = []
    if "prefix_layers" in params:
        stacks.append((params["prefix_layers"], cfg.first_k_dense))
        stacks.append((params["layers"], cfg.n_layers - cfg.first_k_dense))
    else:
        stacks.append((params["layers"], cfg.n_layers))

    for stacked, n in stacks:
        flags = jax.lax.dynamic_slice_in_dim(flags_all, layer_off, n)
        slice_cache = {
            k: jax.lax.dynamic_slice_in_dim(cache[k], layer_off, n, axis=0)
            for k in cache_keys
        }
        x_cur, new_slices = _maybe_scan(body, x_cur, (stacked, flags, slice_cache), cfg)
        for k in cache_keys:
            if k in new_slices:
                cache[k] = jax.lax.dynamic_update_slice_in_dim(
                    cache[k], new_slices[k].astype(cache[k].dtype), layer_off, axis=0
                )
        layer_off += n

    cache["len"] = cache_len + 1
    x_cur = rmsnorm(params["final_norm"], x_cur, cfg.norm_eps)
    logits = lm_logits(params["lm_head"], params["embed"], x_cur, cfg)
    return logits, cache
