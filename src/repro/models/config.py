"""Model configuration covering all assigned architecture families.

One frozen dataclass drives the composable decoder/enc-dec stack in
models/model.py: dense GQA transformers, MoE (token-dropping grouped
routing), Mamba2 SSD, hybrid (parallel attn+SSM), encoder-decoder, and
VLM/audio backbones with stub frontends.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # attention
    rope_theta: float = 1e4
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    sliding_window: int = 0  # 0 -> no local attention anywhere
    # cycled over layers; entries: "global" | "local"
    layer_pattern: Tuple[str, ...] = ("global",)
    # explicit overrides (e.g. hymba: global attention only at {0, mid, last})
    global_layer_indices: Tuple[int, ...] = ()
    sandwich_norm: bool = False  # gemma2: post-norms after attn/mlp

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    moe_dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    n_shared_experts: int = 0  # kimi/deepseek-style always-on expert
    first_k_dense: int = 0  # first k layers use a dense FFN instead of MoE
    capacity_factor: float = 1.25
    min_capacity: int = 8

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv_width: int = 4

    # encoder-decoder
    n_enc_layers: int = 0
    cross_attention: bool = False

    # multimodal stub frontends (precomputed embeddings from input_specs)
    n_prefix_embeds: int = 0  # e.g. ViT patch embeddings for VLM

    # serving: per-row cache positions (continuous batching) via one-hot
    # scatter; False = uniform-length fast path (dynamic_update_slice, no
    # cache-sized temporaries — §Perf hillclimb)
    ragged_decode: bool = True

    # streaming (flash-style) attention for sequences >= this threshold:
    # online-softmax over KV chunks, O(S*chunk) score memory instead of
    # O(S^2); local layers use a static 2-chunk band (§Perf hillclimb).
    # Default off (baseline); optimized configs set e.g. 8192.
    streaming_attn_threshold: int = 1 << 60
    streaming_chunk: int = 1024

    # misc
    norm_eps: float = 1e-6
    act: str = "silu"
    tie_embeddings: bool = False
    dtype: str = "bfloat16"  # activation/param dtype
    remat: bool = True
    scan_layers: bool = True
    optimizer: str = "adamw"  # adamw | adafactor (framework default per arch)

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.resolved_head_dim

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM state or sliding-window-only attention."""
        if self.family == "ssm":
            return True
        if self.family == "hybrid":
            return True  # SSM heads + sliding-window attention
        return False

    def pattern_for_layer(self, i: int) -> str:
        return self.layer_pattern[i % len(self.layer_pattern)]

    def is_moe_layer(self, i: int) -> bool:
        return self.n_experts > 0 and i >= self.first_k_dense

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        base = dict(
            n_layers=min(self.n_layers, 2 + self.first_k_dense),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            moe_d_ff=128 if self.n_experts else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            ssm_chunk=32,
            n_enc_layers=min(self.n_enc_layers, 2),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            n_prefix_embeds=min(self.n_prefix_embeds, 8),
            dtype="float32",
            min_capacity=4,
        )
        base.update(overrides)
        return dataclasses.replace(self, **base)
