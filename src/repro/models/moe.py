"""Mixture-of-Experts with grouped, capacity-bounded token routing.

GSPMD-friendly "dropping" implementation (MaxText-style):
  * tokens are routed within GROUPS aligned with the data-parallel batch
    sharding, so the per-group argsort never crosses shards;
  * each group owns capacity = ceil(tokens_per_group * top_k * cf / E),
    overflowing tokens are dropped (training-time standard);
  * expert weights are stacked (E, D, F) and sharded over the `model`
    (expert-parallel) axis — the dispatch/combine einsums become the EP
    collectives under pjit;
  * top-k gates renormalized (DeepSeek-style), optional shared experts
    (kimi) and a dense parallel residual (arctic).

Decode shapes (one token per sequence) route with a generous capacity
floor (cfg.min_capacity) so collisions do not drop tokens in practice.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import _activation, dense_init, init_mlp, apply_mlp


def init_moe(key, cfg: ModelConfig) -> Dict:
    dt = jnp.dtype(cfg.dtype)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.moe_d_ff or cfg.d_ff
    kr, k1, k2, k3, ks, kd = jax.random.split(key, 6)
    scale_in = 1.0 / math.sqrt(D)
    scale_out = 1.0 / math.sqrt(F)
    p = {
        "router": dense_init(kr, D, E, jnp.float32),  # fp32 routing logits
        "w_gate": (jax.random.normal(k1, (E, D, F), jnp.float32) * scale_in).astype(dt),
        "w_up": (jax.random.normal(k2, (E, D, F), jnp.float32) * scale_in).astype(dt),
        "w_down": (jax.random.normal(k3, (E, F, D), jnp.float32) * scale_out).astype(dt),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks, cfg, d_ff=(cfg.moe_d_ff or cfg.d_ff) * cfg.n_shared_experts)
    if cfg.moe_dense_residual:
        p["dense"] = init_mlp(kd, cfg, d_ff=cfg.d_ff)
    return p


def _capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    cap = math.ceil(tokens_per_group * cfg.experts_per_token * cfg.capacity_factor / cfg.n_experts)
    return max(cap, cfg.min_capacity)


def apply_moe(params: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, D). Groups = batch entries (aligned with DP sharding)."""
    Bsz, S, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    C = _capacity(S, cfg)

    # ---- routing (fp32) ----------------------------------------------------
    logits = x.astype(jnp.float32) @ params["router"]  # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)  # (B, S, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # ---- position-in-expert via per-group sort ------------------------------
    flat_e = expert_idx.reshape(Bsz, S * K)  # (B, T) expert id per assignment
    order = jnp.argsort(flat_e, axis=-1, stable=True)  # (B, T)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    # counts per expert -> segment offsets
    counts = jax.vmap(lambda e: jnp.zeros((E,), jnp.int32).at[e].add(1))(flat_e)
    offsets = jnp.cumsum(counts, axis=-1) - counts  # (B, E)
    pos_sorted = (
        jnp.arange(S * K)[None, :] - jnp.take_along_axis(offsets, sorted_e, axis=-1)
    )
    # scatter positions back to assignment order
    pos = jnp.zeros_like(pos_sorted).at[
        jnp.arange(Bsz)[:, None], order
    ].set(pos_sorted)  # (B, T)
    pos = pos.reshape(Bsz, S, K)

    keep = pos < C  # dropped assignments
    dest = jnp.where(keep, expert_idx * C + pos, E * C)  # overflow slot

    # ---- dispatch: (B, S, D) -> (B, E*C+1, D) -------------------------------
    buf = jnp.zeros((Bsz, E * C + 1, D), x.dtype)
    src = jnp.repeat(x[:, :, None, :], K, axis=2).reshape(Bsz, S * K, D)
    buf = buf.at[jnp.arange(Bsz)[:, None], dest.reshape(Bsz, S * K)].add(src)
    expert_in = buf[:, : E * C, :].reshape(Bsz, E, C, D)

    # ---- expert computation (EP-sharded einsums) ----------------------------
    # under weight-stationary rules the constraint shards the dispatch
    # buffer's expert dim over 'model' (the EP all-to-all) so expert
    # weights never move; baseline rules make this a no-op
    from repro.models import sharding as sh_lib

    expert_in = sh_lib.constrain(expert_in, "batch", "experts_act", None, None)
    act = _activation(cfg.act)
    h = act(jnp.einsum("becd,edf->becf", expert_in, params["w_gate"]))
    h = h * jnp.einsum("becd,edf->becf", expert_in, params["w_up"])
    expert_out = jnp.einsum("becf,efd->becd", h, params["w_down"])
    expert_out = sh_lib.constrain(expert_out, "batch", "experts_act", None, None)

    # ---- combine: gather back + weight by gates ------------------------------
    flat_out = expert_out.reshape(Bsz, E * C, D)
    flat_out = jnp.concatenate([flat_out, jnp.zeros((Bsz, 1, D), x.dtype)], axis=1)
    gathered = jnp.take_along_axis(
        flat_out, dest.reshape(Bsz, S * K, 1), axis=1
    ).reshape(Bsz, S, K, D)
    w = jnp.where(keep, gate_vals, 0.0).astype(x.dtype)
    y = jnp.einsum("bskd,bsk->bsd", gathered, w)

    # ---- always-on branches --------------------------------------------------
    if cfg.n_shared_experts:
        y = y + apply_mlp(params["shared"], x, cfg)
    if cfg.moe_dense_residual:
        y = y + apply_mlp(params["dense"], x, cfg)
    return y


def load_balance_loss(logits: jax.Array, expert_idx: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Switch-style auxiliary loss (fraction routed x mean router prob)."""
    E = cfg.n_experts
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = jnp.mean(probs.reshape(-1, E), axis=0)
    onehot = jax.nn.one_hot(expert_idx.reshape(-1), E)
    ce = jnp.mean(onehot, axis=0) * E
    return jnp.sum(me * ce)
