"""Logical-axis sharding rules (MaxText-style) for params, batches, caches.

Every param leaf gets logical axis names derived from its path and rank;
``rules`` map logical names to mesh axes. A dimension that does not divide
evenly by its mesh-axis size falls back to replication (e.g. hymba's 25
query heads on a 16-way model axis).

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
Batch is sharded over ("pod","data") [DP], weights' heads/mlp/vocab/experts
over "model" [TP/EP], and large embed dims over "data" [FSDP/ZeRO-3-style]
when ``fsdp=True``.
"""
from __future__ import annotations

import re
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Logical axes per param leaf (matched on the tree path suffix)
# ---------------------------------------------------------------------------

# pattern -> logical axes of the *unstacked* leaf (no layer dim)
_PARAM_AXES = [
    (r"embed/tok$", ("vocab", "embed")),
    (r"lm_head/w$", ("embed", "vocab")),
    (r"patch_proj$", ("embed", "embed2")),
    (r"encoder/frontend$", ("embed", "embed2")),
    (r"(attn|cross)/wq$", ("embed", "heads")),
    (r"(attn|cross)/wk$", ("embed", "kv_heads")),
    (r"(attn|cross)/wv$", ("embed", "kv_heads")),
    (r"(attn|cross)/wo$", ("heads", "embed")),
    (r"(attn|cross)/bq$", ("heads",)),
    (r"(attn|cross)/b[kv]$", ("kv_heads",)),
    (r"(mlp|shared|dense)/w_gate$", ("embed", "mlp")),
    (r"(mlp|shared|dense)/w_up$", ("embed", "mlp")),
    (r"(mlp|shared|dense)/w_down$", ("mlp", "embed")),
    (r"moe/router$", ("embed", None)),
    (r"moe/w_gate$", ("experts", "moe_embed", "moe_mlp")),
    (r"moe/w_up$", ("experts", "moe_embed", "moe_mlp")),
    (r"moe/w_down$", ("experts", "moe_mlp", "moe_embed")),
    (r"ssm/in_proj$", ("embed", "mlp")),
    (r"ssm/out_proj$", ("mlp", "embed")),
    (r"ssm/conv_w$", (None, "mlp")),
    (r"ssm/conv_b$", ("mlp",)),
    (r"ssm/(A_log|D|dt_bias)$", (None,)),
    (r"(norm|ln1|ln2|ln1_post|ln2_post|ln_cross|final_norm)(/scale)?$", (None,)),
]

# logical axis -> mesh axis (None = replicate)
DEFAULT_RULES: Dict[str, Optional[str]] = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
    "embed": None,  # flipped to "data" under fsdp
    "embed2": None,
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,
    "act_embed": None,  # residual-stream feature dim ('model' = seq-par/TP-act)
    # MoE expert weights: baseline mirrors dense rules (embed FSDP-gathered
    # per microbatch). The weight-stationary alternative (hillclimb) sets
    # moe_mlp -> 'data' + experts_act -> 'model' so expert weights never
    # move and tokens all-to-all instead (DESIGN.md / EXPERIMENTS.md §Perf).
    "moe_embed": None,  # flipped to "data" under fsdp (baseline)
    "moe_mlp": None,
    "experts_act": None,  # expert dim of dispatch buffers
}


def weight_stationary_moe_rules(fsdp_dense: bool = True) -> Dict:
    """Rules for the weight-stationary MoE scheme (§Perf)."""
    rules = dict(DEFAULT_RULES)
    if fsdp_dense:
        rules["embed"] = "data"
    rules["moe_embed"] = None
    rules["moe_mlp"] = "data"
    rules["experts_act"] = "model"
    return rules


# ---------------------------------------------------------------------------
# Activation sharding constraints (mesh context set by the launcher; no-op
# in mesh-less unit tests). GSPMD cannot infer a good output sharding for
# the embedding gather when the table is sharded on both dims — without an
# explicit constraint it replicates the whole residual stream.
# ---------------------------------------------------------------------------

_ACT_CTX: Dict[str, object] = {"mesh": None, "rules": None}


class activation_mesh:
    """Context manager: enable activation constraints under this mesh."""

    def __init__(self, mesh: Mesh, rules: Optional[Dict] = None):
        self.mesh = mesh
        self.rules = dict(rules or DEFAULT_RULES)

    def __enter__(self):
        self._saved = dict(_ACT_CTX)
        _ACT_CTX["mesh"] = self.mesh
        _ACT_CTX["rules"] = self.rules
        return self

    def __exit__(self, *exc):
        _ACT_CTX.update(self._saved)


def constrain(x, *axes):
    """with_sharding_constraint by logical axis names; no-op without context."""
    mesh = _ACT_CTX["mesh"]
    if mesh is None:
        return x
    spec = spec_for(x.shape, axes, mesh, _ACT_CTX["rules"])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _path_str(path) -> str:
    parts = []
    for entry in path:
        if hasattr(entry, "key"):
            parts.append(str(entry.key))
        elif hasattr(entry, "idx"):
            parts.append(str(entry.idx))
    return "/".join(parts)


def logical_axes(params) -> Dict:
    """Pytree of logical-axis tuples matching ``params``' structure.

    Leaves stacked with a leading layer dim (from scan-over-layers init)
    get a leading None automatically when rank exceeds the pattern rank.
    """

    def assign(path, leaf):
        ps = _path_str(path)
        for pat, axes in _PARAM_AXES:
            if re.search(pat, ps):
                extra = leaf.ndim - len(axes)
                assert extra >= 0, f"{ps}: rank {leaf.ndim} < {axes}"
                return (None,) * extra + tuple(axes)
        # unknown leaves replicate
        return (None,) * leaf.ndim

    return jax.tree_util.tree_map_with_path(assign, params)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis if a in mesh.shape]))
    return int(mesh.shape.get(axis, 1))


def spec_for(
    shape: Tuple[int, ...],
    axes: Tuple,
    mesh: Mesh,
    rules: Dict[str, Optional[str]],
) -> P:
    """PartitionSpec with divisibility fallback to replication."""
    spec = []
    used = set()
    for dim, name in zip(shape, axes):
        mesh_axis = rules.get(name) if name else None
        if mesh_axis is None:
            spec.append(None)
            continue
        axes_tuple = mesh_axis if isinstance(mesh_axis, tuple) else (mesh_axis,)
        axes_tuple = tuple(a for a in axes_tuple if a in mesh.shape and a not in used)
        size = int(np.prod([mesh.shape[a] for a in axes_tuple])) if axes_tuple else 1
        if not axes_tuple or size == 1 or dim % size != 0:
            spec.append(None)
            continue
        used.update(axes_tuple)
        spec.append(axes_tuple[0] if len(axes_tuple) == 1 else axes_tuple)
    return P(*spec)


def param_specs(params_shapes, mesh: Mesh, fsdp: bool = False, rules=None):
    """PartitionSpec pytree for a (shape-only) param pytree."""
    rules = dict(rules or DEFAULT_RULES)
    if fsdp:
        rules["embed"] = "data"
        if rules.get("moe_mlp") is None:
            rules["moe_embed"] = "data"  # baseline: FSDP MoE weights too
    axes_tree = logical_axes(params_shapes)

    def to_spec(leaf, axes):
        return spec_for(leaf.shape, axes, mesh, rules)

    return jax.tree.map(to_spec, params_shapes, axes_tree)


def param_shardings(params_shapes, mesh: Mesh, fsdp: bool = False, rules=None):
    specs = param_specs(params_shapes, mesh, fsdp=fsdp, rules=rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


# ---------------------------------------------------------------------------
# Batch / cache shardings
# ---------------------------------------------------------------------------


def batch_specs(batch_shapes, mesh: Mesh, rules=None):
    """tokens/frames/patches: shard the leading batch dim over DP axes."""
    rules = dict(rules or DEFAULT_RULES)

    def to_spec(leaf):
        axes = ("batch",) + (None,) * (leaf.ndim - 1)
        return spec_for(leaf.shape, axes, mesh, rules)

    return jax.tree.map(to_spec, batch_shapes)


def cache_specs(cache_shapes, mesh: Mesh, rules=None):
    """Decode cache: (L, B, S, KV, hd) -> B over DP, KV over model when it
    divides; otherwise the seq dim takes the model axis (long-context)."""
    rules = dict(rules or DEFAULT_RULES)
    model_size = _axis_size(mesh, "model")

    def to_spec(path, leaf):
        name = _path_str(path)
        if name.endswith("len"):
            return spec_for(leaf.shape, ("batch",), mesh, rules)
        if name.endswith("k") or name.endswith("v"):
            kv = leaf.shape[3]
            if kv % model_size == 0:
                axes = (None, "batch", None, "kv_heads", None)
            else:
                axes = (None, "batch", "kv_seq", None, None)
                rules2 = dict(rules)
                rules2["kv_seq"] = "model"
                return spec_for(leaf.shape, axes, mesh, rules2)
            return spec_for(leaf.shape, axes, mesh, rules)
        if name.endswith("conv"):
            return spec_for(leaf.shape, (None, "batch", None, "mlp"), mesh, rules)
        if name.endswith("ssm"):
            return spec_for(leaf.shape, (None, "batch", "mlp", None, None), mesh, rules)
        if name.endswith("memory"):
            return spec_for(leaf.shape, ("batch", None, None), mesh, rules)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(to_spec, cache_shapes)
