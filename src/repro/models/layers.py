"""Shared neural layers: norms, RoPE, gated MLP, embeddings.

Pure-JAX (no flax): params are plain dicts, init_* builds them, apply
functions are stateless. All matmuls run in the config dtype with fp32
normalization statistics and fp32 logits at the loss boundary.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / jnp.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype) -> Dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Dict, x: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponent)  # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    sin = jnp.sin(angles)[:, :, None, :]
    cos = jnp.cos(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d_ff: int = 0) -> Dict:
    d_ff = d_ff or cfg.d_ff
    dt = _dtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, cfg.d_model, d_ff, dt),
        "w_up": dense_init(k2, cfg.d_model, d_ff, dt),
        "w_down": dense_init(k3, d_ff, cfg.d_model, dt),
    }


def _activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True)}[name]


def apply_mlp(params: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    act = _activation(cfg.act)
    h = act(x @ params["w_gate"]) * (x @ params["w_up"])
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# Embeddings / LM head
# ---------------------------------------------------------------------------


def init_embedding(key, cfg: ModelConfig) -> Dict:
    dt = _dtype(cfg)
    scale = 1.0 / jnp.sqrt(cfg.d_model)  # O(1) logits whether tied or not
    p = {
        "tok": (
            jax.random.normal(key, (cfg.vocab_size, cfg.d_model), jnp.float32) * scale
        ).astype(dt)
    }
    return p


def embed_tokens(params: Dict, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = jnp.take(params["tok"], tokens, axis=0)
    if cfg.tie_embeddings:
        # gemma-style sqrt(d) scaling when the table doubles as the LM head
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
    return x


def init_lm_head(key, cfg: ModelConfig) -> Dict:
    if cfg.tie_embeddings:
        return {}
    dt = _dtype(cfg)
    return {"w": dense_init(key, cfg.d_model, cfg.vocab_size, dt)}


def lm_logits(head: Dict, embed: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Final projection, fp32 output, optional logit softcapping (gemma2)."""
    if cfg.tie_embeddings:
        logits = jnp.einsum(
            "bsd,vd->bsv", x, embed["tok"], preferred_element_type=jnp.float32
        )
    else:
        logits = jnp.einsum(
            "bsd,dv->bsv", x, head["w"], preferred_element_type=jnp.float32
        )
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def softcap(x: jax.Array, cap) -> jax.Array:
    return cap * jnp.tanh(x / cap)
