"""Aggregated metrics plane (DESIGN.md §Observability).

A ``MetricsRegistry`` is the host-side aggregation layer that the raw
observability primitives — ``TelemetryRing`` flushes, ``Tracer``
spans/counters, ``StepMonitor``/``LaneProgressMonitor`` — feed, and that
the export layer (``repro.obs.export``: OpenMetrics text, JSON
snapshots, the background ``/metrics`` HTTP endpoint) serves. Three
metric kinds, all labeled:

  * ``Counter`` — monotone totals (solves_total, lane_freezes_total);
  * ``Gauge``   — last-written values (queue depth, EWMA step time);
  * ``Histogram`` — fixed-bucket distributions with ``_sum``/``_count``
    and bucket-interpolated quantiles (p50/p95/p99). Buckets are FIXED
    at construction so two snapshots of the same metric are always
    mergeable/diffable — the same reason the paper's BENCH artifacts
    pin their shapes.

The plane is OFF by default: ``get_registry()`` returns None until a
registry is installed (``install_registry`` / ``use_registry``), and
every instrumentation site in the solver is gated on that — the
no-registry program is the pre-metrics program, matching the
``FWConfig.telemetry=None`` contract one layer down. All recording is
host-side and thread-safe; nothing here ever runs inside a jitted
function.
"""
from __future__ import annotations

import bisect
import math
import threading
from contextlib import contextmanager
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

# Default latency buckets (seconds): log-ish spacing from 100us to 2min,
# wide enough for both a single fused chunk and a full CI-scale path.
LATENCY_BUCKETS_S: Tuple[float, ...] = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0, 120.0,
)

# Duality-gap magnitude buckets: the certified gap spans ~1e-8 .. 1e4
# across the regularization path, so decades are the natural resolution.
GAP_BUCKETS: Tuple[float, ...] = tuple(10.0 ** e for e in range(-8, 5))

# Shard-IO byte buckets: 4 KB .. 1 GB in powers of 4.
BYTES_BUCKETS: Tuple[float, ...] = tuple(float(4096 * 4 ** e) for e in range(10))

QUANTILES: Tuple[float, ...] = (0.5, 0.95, 0.99)

_LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labelnames: Sequence[str], labels: Dict[str, str]) -> _LabelKey:
    if set(labels) != set(labelnames):
        raise ValueError(
            f"labels {sorted(labels)} != declared labelnames {sorted(labelnames)}"
        )
    return tuple((name, str(labels[name])) for name in labelnames)


class Counter:
    """Monotone labeled counter."""

    kind = "counter"

    def __init__(self, name: str, help: str, labelnames: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._values: Dict[_LabelKey, float] = {}
        self._lock = threading.Lock()

    def inc(self, value: float = 1.0, **labels: str) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name} cannot decrease ({value})")
        key = _label_key(self.labelnames, labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + value

    def value(self, **labels: str) -> float:
        key = _label_key(self.labelnames, labels)
        with self._lock:
            return self._values.get(key, 0.0)

    def series(self) -> List[Tuple[_LabelKey, float]]:
        with self._lock:
            return sorted(self._values.items())


class Gauge:
    """Last-value-wins labeled gauge (set / add)."""

    kind = "gauge"

    def __init__(self, name: str, help: str, labelnames: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._values: Dict[_LabelKey, float] = {}
        self._lock = threading.Lock()

    def set(self, value: float, **labels: str) -> None:
        key = _label_key(self.labelnames, labels)
        with self._lock:
            self._values[key] = float(value)

    def add(self, value: float, **labels: str) -> None:
        key = _label_key(self.labelnames, labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + float(value)

    def value(self, **labels: str) -> float:
        key = _label_key(self.labelnames, labels)
        with self._lock:
            return self._values.get(key, 0.0)

    def series(self) -> List[Tuple[_LabelKey, float]]:
        with self._lock:
            return sorted(self._values.items())


class _HistSeries:
    __slots__ = ("counts", "sum", "count")

    def __init__(self, n_buckets: int):
        self.counts = [0] * (n_buckets + 1)  # +1 for the +Inf bucket
        self.sum = 0.0
        self.count = 0


class Histogram:
    """Fixed-bucket labeled histogram with interpolated quantiles.

    ``buckets`` are the upper bounds (le) of each finite bucket; a +Inf
    bucket is implicit. ``quantile(q)`` linearly interpolates inside the
    bucket holding the q-th observation — exact enough for p50/p95/p99
    reporting at the fixed-bucket resolution, and computable from a
    scraped snapshot alone (the same arithmetic a Prometheus
    ``histogram_quantile`` applies server-side).
    """

    kind = "histogram"

    def __init__(
        self,
        name: str,
        help: str,
        labelnames: Sequence[str] = (),
        buckets: Sequence[float] = LATENCY_BUCKETS_S,
    ):
        if not buckets:
            raise ValueError(f"histogram {name} needs at least one bucket")
        bounds = [float(b) for b in buckets]
        if bounds != sorted(bounds) or len(set(bounds)) != len(bounds):
            raise ValueError(f"histogram {name} buckets must strictly increase")
        if math.isinf(bounds[-1]):
            bounds = bounds[:-1]  # +Inf is implicit
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self.buckets = tuple(bounds)
        self._series: Dict[_LabelKey, _HistSeries] = {}
        self._lock = threading.Lock()

    def observe(self, value: float, **labels: str) -> None:
        key = _label_key(self.labelnames, labels)
        idx = bisect.bisect_left(self.buckets, float(value))
        with self._lock:
            s = self._series.setdefault(key, _HistSeries(len(self.buckets)))
            s.counts[idx] += 1
            s.sum += float(value)
            s.count += 1

    def snapshot(self, **labels: str) -> Optional[Dict]:
        """{"buckets": [(le, cumulative_count)...], "sum", "count"} for
        one label set (None when never observed)."""
        key = _label_key(self.labelnames, labels)
        with self._lock:
            s = self._series.get(key)
            if s is None:
                return None
            cum, out = 0, []
            for le, c in zip(self.buckets + (math.inf,), s.counts):
                cum += c
                out.append((le, cum))
            return {"buckets": out, "sum": s.sum, "count": s.count}

    def quantile(self, q: float, **labels: str) -> float:
        """Interpolated q-quantile for one label set (NaN when empty)."""
        snap = self.snapshot(**labels)
        if snap is None or snap["count"] == 0:
            return float("nan")
        target = q * snap["count"]
        prev_le, prev_cum = 0.0, 0
        for le, cum in snap["buckets"]:
            if cum >= target:
                if math.isinf(le):
                    return self.buckets[-1] if self.buckets else float("nan")
                if cum == prev_cum:
                    return le
                frac = (target - prev_cum) / (cum - prev_cum)
                return prev_le + frac * (le - prev_le)
            prev_le, prev_cum = le, cum
        return float(snap["buckets"][-1][0])

    def series(self) -> List[Tuple[_LabelKey, Dict]]:
        with self._lock:
            keys = sorted(self._series)
        return [(k, self.snapshot(**dict(k))) for k in keys]


class MetricsRegistry:
    """Named metric families; get-or-create semantics so instrumentation
    sites can declare their metric inline without an init ceremony.
    Re-declaring a name with a different kind/labels/buckets is an error
    (two writers disagreeing about a metric is a bug, not a merge)."""

    def __init__(self, namespace: str = "fw"):
        self.namespace = namespace
        self._metrics: Dict[str, object] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, cls, name: str, help: str, labelnames, **kw):
        with self._lock:
            existing = self._metrics.get(name)
            if existing is not None:
                if not isinstance(existing, cls) or existing.labelnames != tuple(
                    labelnames
                ):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{type(existing).__name__}({existing.labelnames})"
                    )
                if kw.get("buckets") is not None and tuple(
                    float(b) for b in kw["buckets"]
                ) != existing.buckets:
                    raise ValueError(f"metric {name!r} bucket mismatch")
                return existing
            metric = cls(name, help, labelnames, **{
                k: v for k, v in kw.items() if v is not None
            })
            self._metrics[name] = metric
            return metric

    def counter(self, name: str, help: str = "", labelnames: Sequence[str] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "", labelnames: Sequence[str] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(
        self,
        name: str,
        help: str = "",
        labelnames: Sequence[str] = (),
        buckets: Optional[Sequence[float]] = None,
    ) -> Histogram:
        return self._get_or_create(
            Histogram, name, help, labelnames,
            buckets=tuple(buckets) if buckets is not None else LATENCY_BUCKETS_S,
        )

    def collect(self) -> List[object]:
        with self._lock:
            return [self._metrics[name] for name in sorted(self._metrics)]

    def get(self, name: str):
        with self._lock:
            return self._metrics.get(name)


# --------------------------------------------------------------------------
# Install plumbing: the plane is OFF until a registry is installed
# --------------------------------------------------------------------------

_installed: Optional[MetricsRegistry] = None
_stack: List[MetricsRegistry] = []
_install_lock = threading.Lock()


def get_registry() -> Optional[MetricsRegistry]:
    """The active registry, or None — the OFF state every solver
    instrumentation site gates on."""
    with _install_lock:
        return _stack[-1] if _stack else _installed


def install_registry(registry: Optional[MetricsRegistry]) -> Optional[MetricsRegistry]:
    """Install ``registry`` process-wide (None uninstalls). Returns the
    previously installed registry."""
    global _installed
    with _install_lock:
        prev, _installed = _installed, registry
    return prev


@contextmanager
def use_registry(registry: MetricsRegistry):
    """Scoped install — the with-block's instrumentation lands on
    ``registry``; nesting wins innermost, like ``use_tracer``."""
    with _install_lock:
        _stack.append(registry)
    try:
        yield registry
    finally:
        with _install_lock:
            _stack.remove(registry)


# --------------------------------------------------------------------------
# Bridges from the raw observability primitives
# --------------------------------------------------------------------------

# telemetry-ring event names live in obs.telemetry; imported lazily in
# ring_batch_to_registry to keep this module import-light (export/server
# code paths must not pull jax transitively)


def ring_batch_to_registry(
    batch: Dict[str, np.ndarray], registry: MetricsRegistry, **labels: str
) -> None:
    """Fold one ring flush batch (``ring_to_records`` dict format) into
    the registry: iteration totals and per-event step counters. Usable
    directly as a streaming sink via ``install_ring_sink``."""
    from repro.obs import telemetry as obs_telemetry

    n = len(batch.get("k", ()))
    if n == 0:
        return
    label_names = tuple(sorted(labels))
    registry.counter(
        "fw_ring_iterations_total",
        "solver iterations observed through telemetry-ring flushes",
        label_names,
    ).inc(n, **labels)
    events = np.asarray(batch["event"], np.int64)
    ctr = registry.counter(
        "fw_step_events_total",
        "step-rule events by kind (telemetry-ring event codes)",
        label_names + ("event",),
    )
    for code, name in enumerate(obs_telemetry.EVENT_NAMES):
        c = int((events == code).sum())
        if c:
            ctr.inc(c, event=name, **labels)
    gaps = np.asarray(batch.get("gap", ()), np.float64)
    gaps = gaps[np.isfinite(gaps) & (gaps > 0)]
    if gaps.size:
        hist = registry.histogram(
            "fw_sampled_gap",
            "per-iteration sampled FW duality gap (ring flushes)",
            label_names,
            buckets=GAP_BUCKETS,
        )
        for g in gaps:
            hist.observe(float(g), **labels)


RING_SINK_NAME = "metrics-registry"


def install_ring_sink(
    registry: Optional[MetricsRegistry] = None, name: str = RING_SINK_NAME,
    **labels: str,
) -> str:
    """Register a telemetry streaming sink that folds every flushed ring
    batch into the registry (the live one at flush time when ``registry``
    is None). Use as ``TelemetrySpec(stream_to=install_ring_sink())``.
    Returns the sink name; unregister with
    ``obs.telemetry.unregister_sink``."""
    from repro.obs import telemetry as obs_telemetry

    def sink(batch):
        reg = registry if registry is not None else get_registry()
        if reg is not None:
            ring_batch_to_registry(batch, reg, **labels)

    obs_telemetry.register_sink(name, sink)
    return name


def tracer_to_registry(tracer, registry: MetricsRegistry) -> None:
    """Fold a Tracer's aggregate view into the registry: per-span-name
    duration histograms and the trace-time counter table. Incremental —
    a bridge position is kept on the tracer, so calling this repeatedly
    against the same (accumulating) tracer observes each span once and
    counters advance by their delta."""
    hist = registry.histogram(
        "fw_span_seconds",
        "host-side span durations by span name (Tracer bridge)",
        ("span",),
    )
    events = list(tracer.events)
    start = getattr(tracer, "_metrics_bridge_pos", 0)
    for ev in events[start:]:
        if ev.get("ph") == "X":
            hist.observe(ev.get("dur", 0.0) / 1e6, span=ev["name"])
    tracer._metrics_bridge_pos = len(events)
    ctr = registry.counter(
        "fw_trace_counter",
        "Tracer aggregate counters (trace-time sites for jitted code)",
        ("counter",),
    )
    for name, value in tracer.counter_table().items():
        already = ctr.value(counter=name)
        if value > already:
            ctr.inc(value - already, counter=name)
