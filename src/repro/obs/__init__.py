"""Solver observability layer (DESIGN.md §Observability).

Three parts, all off the hot path by default:
  * ``obs.telemetry`` — device-side per-iteration metric rings, carried
    in ``EngineState`` when ``FWConfig.telemetry`` is set (telemetry off
    keeps the default jaxpr bit-identical);
  * ``obs.trace`` — host-side nested span tracing emitting
    Chrome/Perfetto ``trace_event`` JSON plus an aggregate counter
    table;
  * ``obs.monitor`` / ``obs.report`` — EWMA straggler + lane-progress
    monitoring and markdown/JSON run-report rendering (CLI:
    ``scripts/solver_report.py``);
  * ``obs.metrics`` / ``obs.export`` — the aggregated metrics plane:
    a labeled Counter/Gauge/Histogram registry the raw primitives
    bridge into, exposed as OpenMetrics text / JSON snapshots / a
    background ``/metrics`` HTTP endpoint. OFF until a registry is
    installed (``install_registry`` / ``use_registry``).

NOTE: ``repro.core.solver_config`` imports ``obs.telemetry``, so this
package must stay import-clean of ``repro.core``.
"""
from repro.obs.export import (
    MetricsServer,
    render_openmetrics,
    scrape,
    snapshot_json,
    validate_openmetrics,
)
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    get_registry,
    install_registry,
    install_ring_sink,
    ring_batch_to_registry,
    tracer_to_registry,
    use_registry,
)
from repro.obs.monitor import LaneProgressMonitor, StepMonitor
from repro.obs.report import build_report, render_markdown, write_report
from repro.obs.telemetry import (
    EVENT_AWAY,
    EVENT_DROP,
    EVENT_FW,
    EVENT_LAZY_HIT,
    EVENT_NAMES,
    EVENT_PAIRWISE,
    EVENT_PARTAN,
    TelemetryRing,
    TelemetrySpec,
    register_sink,
    ring_to_records,
    unregister_sink,
)
from repro.obs.trace import (
    Tracer,
    get_tracer,
    traced,
    use_tracer,
    validate_chrome_trace,
)

__all__ = [
    "Counter", "EVENT_AWAY", "EVENT_DROP", "EVENT_FW", "EVENT_LAZY_HIT",
    "EVENT_NAMES", "EVENT_PAIRWISE", "EVENT_PARTAN", "Gauge", "Histogram",
    "LaneProgressMonitor", "MetricsRegistry", "MetricsServer", "StepMonitor",
    "Tracer", "TelemetryRing", "TelemetrySpec", "build_report", "get_registry",
    "get_tracer", "install_registry", "install_ring_sink", "register_sink",
    "render_markdown", "render_openmetrics", "ring_batch_to_registry",
    "ring_to_records", "scrape", "snapshot_json", "tracer_to_registry",
    "traced", "unregister_sink", "use_registry", "use_tracer",
    "validate_chrome_trace", "validate_openmetrics", "write_report",
]
