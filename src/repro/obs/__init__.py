"""Solver observability layer (DESIGN.md §Observability).

Three parts, all off the hot path by default:
  * ``obs.telemetry`` — device-side per-iteration metric rings, carried
    in ``EngineState`` when ``FWConfig.telemetry`` is set (telemetry off
    keeps the default jaxpr bit-identical);
  * ``obs.trace`` — host-side nested span tracing emitting
    Chrome/Perfetto ``trace_event`` JSON plus an aggregate counter
    table;
  * ``obs.monitor`` / ``obs.report`` — EWMA straggler + lane-progress
    monitoring (absorbed from ``runtime.monitor``) and markdown/JSON
    run-report rendering (CLI: ``scripts/solver_report.py``).

NOTE: ``repro.core.solver_config`` imports ``obs.telemetry``, so this
package must stay import-clean of ``repro.core``.
"""
from repro.obs.monitor import LaneProgressMonitor, StepMonitor
from repro.obs.report import build_report, render_markdown, write_report
from repro.obs.telemetry import (
    EVENT_AWAY,
    EVENT_DROP,
    EVENT_FW,
    EVENT_LAZY_HIT,
    EVENT_NAMES,
    EVENT_PAIRWISE,
    EVENT_PARTAN,
    TelemetryRing,
    TelemetrySpec,
    register_sink,
    ring_to_records,
    unregister_sink,
)
from repro.obs.trace import (
    Tracer,
    get_tracer,
    traced,
    use_tracer,
    validate_chrome_trace,
)

__all__ = [
    "EVENT_AWAY", "EVENT_DROP", "EVENT_FW", "EVENT_LAZY_HIT", "EVENT_NAMES",
    "EVENT_PAIRWISE", "EVENT_PARTAN", "LaneProgressMonitor", "StepMonitor",
    "Tracer", "TelemetryRing", "TelemetrySpec", "build_report", "get_tracer",
    "register_sink", "render_markdown", "ring_to_records", "traced",
    "unregister_sink", "use_tracer", "validate_chrome_trace", "write_report",
]
