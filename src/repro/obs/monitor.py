"""EWMA step monitoring and path-driver lane progress (DESIGN.md
§Observability).

``StepMonitor`` is the straggler/heartbeat detector (absorbed from the
former ``repro.runtime.monitor``, whose deprecation shim is now
retired): EWMA step-time tracking, straggler flagging when a step
exceeds ``straggler_factor`` x the EWMA, and a JSON heartbeat file a
supervisor can watch. The clock is injectable so straggler logic is
testable without sleeps.

``LaneProgressMonitor`` attaches the same EWMA machinery to the batched
path driver's chunk cadence and keeps the per-lane story the driver's
aggregate result discards: per-lane iteration counts, the freeze point
of each early-converged lane, and the lane-iterations saved by pruning.
Summaries land on the active tracer as counters + instant events, so a
traced ``fw_path_batched`` run shows its lane behavior in the same
artifact as its spans.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, List, Optional

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


@dataclass
class StepMonitor:
    ewma_alpha: float = 0.1
    straggler_factor: float = 3.0  # step > factor * ewma => flag
    heartbeat_path: Optional[Path] = None
    clock: Callable[[], float] = time.perf_counter

    ewma: float = 0.0
    last_step_time: float = 0.0
    stragglers: List[int] = field(default_factory=list)
    _t0: float = field(default=0.0, repr=False)
    step: int = 0

    def begin(self):
        self._t0 = self.clock()

    def end(self) -> bool:
        """Record a step; returns True if this step was a straggler."""
        dt = self.clock() - self._t0
        self.last_step_time = dt
        self.step += 1
        is_straggler = False
        if self.ewma > 0 and dt > self.straggler_factor * self.ewma:
            self.stragglers.append(self.step)
            is_straggler = True
        self.ewma = dt if self.ewma == 0 else (
            self.ewma_alpha * dt + (1 - self.ewma_alpha) * self.ewma
        )
        reg = obs_metrics.get_registry()
        if reg is not None:
            # metrics-plane bridge: monitored-step durations + straggler
            # totals land in the registry alongside the tracer artifacts
            reg.histogram(
                "fw_monitor_step_seconds",
                "durations of StepMonitor-wrapped units (path points, "
                "lane chunks)",
            ).observe(dt)
            reg.gauge(
                "fw_monitor_step_ewma_seconds",
                "EWMA of monitored step durations (straggler baseline)",
            ).set(self.ewma)
            if is_straggler:
                reg.counter(
                    "fw_monitor_stragglers",
                    "monitored steps exceeding straggler_factor x EWMA",
                ).inc(1)
        if self.heartbeat_path is not None:
            self.heartbeat_path.write_text(
                json.dumps(
                    {
                        "step": self.step,
                        "t": time.time(),
                        "step_time": dt,
                        "ewma": self.ewma,
                        "straggler": is_straggler,
                        "stragglers": self.stragglers,
                    }
                )
            )
        return is_straggler


@dataclass
class LaneProgressMonitor:
    """Per-lane progress of one batched-path run (``fw_path_batched``)."""

    max_iters: int
    chunk_monitor: StepMonitor = field(default_factory=StepMonitor)
    chunks: List[dict] = field(default_factory=list)

    def begin_chunk(self):
        self.chunk_monitor.begin()

    def end_chunk(self, chunk_index: int, deltas, iterations, saved_iters: int,
                  converged) -> dict:
        """Record one lane chunk. ``iterations``/``converged`` are the
        per-lane values off the batched SolveResult; a lane that stopped
        before the chunk's slowest lane froze at ``iterations[i]`` — its
        freeze point — and was spared ``max(iters) - iters[i]`` lane
        iterations."""
        straggler = self.chunk_monitor.end()
        iters = [int(v) for v in iterations]
        longest = max(iters) if iters else 0
        rec = {
            "chunk": int(chunk_index),
            "seconds": self.chunk_monitor.last_step_time,
            "straggler": straggler,
            "deltas": [float(d) for d in deltas],
            "lane_iters": iters,
            "freeze_at": [it if it < longest else None for it in iters],
            "lane_saved": [longest - it for it in iters],
            "converged": [bool(c) for c in converged],
            "saved_iters": int(saved_iters),
        }
        self.chunks.append(rec)
        tracer = obs_trace.get_tracer()
        tracer.counter("path/lane_chunks", 1)
        tracer.counter("path/saved_iters", int(saved_iters))
        tracer.instant(
            "fw_path_batched/chunk", cat="path", chunk=rec["chunk"],
            lane_iters=iters, lane_saved=rec["lane_saved"],
            straggler=straggler,
        )
        reg = obs_metrics.get_registry()
        if reg is not None:
            reg.counter(
                "fw_monitor_lane_chunks", "batched-path lane chunks observed"
            ).inc(1)
            n_frozen = sum(1 for f in rec["freeze_at"] if f is not None)
            if n_frozen:
                reg.counter(
                    "fw_monitor_frozen_lanes",
                    "lanes that froze before their chunk's slowest lane",
                ).inc(n_frozen)
            if saved_iters:
                reg.counter(
                    "fw_monitor_saved_iterations",
                    "lane-iterations pruned, as seen by the lane monitor",
                ).inc(int(saved_iters))
        return rec

    def summary(self) -> dict:
        lane_iters = [it for c in self.chunks for it in c["lane_iters"]]
        saved = sum(c["saved_iters"] for c in self.chunks)
        return {
            "chunks": len(self.chunks),
            "lanes": len(lane_iters),
            "total_lane_iters": sum(lane_iters),
            "saved_iters": saved,
            "mean_chunk_seconds": self.chunk_monitor.ewma,
            "straggler_chunks": list(self.chunk_monitor.stragglers),
            "frozen_lanes": sum(
                1 for c in self.chunks for f in c["freeze_at"] if f is not None
            ),
        }
