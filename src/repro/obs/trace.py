"""Host-side span tracing (DESIGN.md §Observability).

A ``Tracer`` collects nested wall-clock spans, instant markers, and an
aggregate counter table, and emits them in the Chrome/Perfetto
``trace_event`` JSON format (the ``{"traceEvents": [...]}`` container
with ``ph: "X"`` complete events), so a solver run can be dropped
straight into https://ui.perfetto.dev or chrome://tracing.

Placement contract: spans measure HOST-side phases (path-driver grid
points, shard IO, distributed-solver dispatch, eager colstats). A span
opened inside a jitted function measures trace time, not run time —
the device-side per-iteration story lives in the telemetry ring
(``repro.obs.telemetry``), not here. Counters recorded at trace time
(e.g. the per-collective counters in ``distributed/backend``) count
ops PER COMPILED PROGRAM; multiply by iterations for run totals.

There is always an active tracer: ``get_tracer()`` returns the tracer
installed by the innermost ``use_tracer(...)`` context, falling back to
a process-global default, so instrumentation points never need a
None-check and ``utils.timing.timed`` always has a sink.
"""
from __future__ import annotations

import functools
import json
import os
import threading
import time
from contextlib import contextmanager
from typing import Any, Callable, Dict, List, Optional

_VALID_PH = {"B", "E", "X", "i", "I", "C", "M", "b", "e", "n", "s", "t", "f"}


class Tracer:
    """Collects trace events; thread-safe appends, one timebase per
    instance (microseconds since construction)."""

    def __init__(self, name: str = "repro"):
        self.name = name
        self.events: List[dict] = []
        self.counters: Dict[str, float] = {}
        self._lock = threading.Lock()
        self._t0 = time.perf_counter()
        self._pid = os.getpid()
        self._add(
            {"name": "process_name", "ph": "M", "pid": self._pid, "tid": 0,
             "ts": 0, "args": {"name": name}}
        )

    # -- low-level ---------------------------------------------------------
    def _add(self, ev: dict) -> None:
        with self._lock:
            self.events.append(ev)

    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def _tid(self) -> int:
        return threading.get_ident() & 0xFFFF

    # -- recording ---------------------------------------------------------
    @contextmanager
    def span(self, name: str, cat: str = "solver", **args: Any):
        """Nested wall-clock span -> one ``ph: "X"`` complete event."""
        ts = self._now_us()
        try:
            yield self
        finally:
            self._add(
                {"name": name, "cat": cat, "ph": "X", "ts": ts,
                 "dur": max(self._now_us() - ts, 0.0), "pid": self._pid,
                 "tid": self._tid(), "args": dict(args)}
            )

    def complete(self, name: str, t0: float, dur: float, cat: str = "solver",
                 **args: Any) -> None:
        """Record an already-measured span; ``t0`` is a
        ``time.perf_counter()`` reading, ``dur`` seconds."""
        self._add(
            {"name": name, "cat": cat, "ph": "X",
             "ts": max((t0 - self._t0) * 1e6, 0.0), "dur": max(dur, 0.0) * 1e6,
             "pid": self._pid, "tid": self._tid(), "args": dict(args)}
        )

    def instant(self, name: str, cat: str = "solver", **args: Any) -> None:
        self._add(
            {"name": name, "cat": cat, "ph": "i", "s": "t",
             "ts": self._now_us(), "pid": self._pid, "tid": self._tid(),
             "args": dict(args)}
        )

    def counter(self, name: str, value: float = 1.0, cat: str = "counter") -> None:
        """Accumulate into the aggregate counter table (and emit a ``C``
        event so the running value shows as a Perfetto counter track)."""
        with self._lock:
            total = self.counters.get(name, 0.0) + value
            self.counters[name] = total
            self.events.append(
                {"name": name, "cat": cat, "ph": "C", "ts": self._now_us(),
                 "pid": self._pid, "tid": 0, "args": {"value": total}}
            )

    # -- aggregation / output ----------------------------------------------
    def counter_table(self) -> Dict[str, float]:
        with self._lock:
            return dict(self.counters)

    def span_table(self) -> Dict[str, Dict[str, float]]:
        """Per-span-name aggregate: {name: {count, total_s, mean_s}}."""
        agg: Dict[str, Dict[str, float]] = {}
        with self._lock:
            events = list(self.events)
        for ev in events:
            if ev.get("ph") != "X":
                continue
            row = agg.setdefault(ev["name"], {"count": 0, "total_s": 0.0})
            row["count"] += 1
            row["total_s"] += ev.get("dur", 0.0) / 1e6
        for row in agg.values():
            row["mean_s"] = row["total_s"] / max(row["count"], 1)
        return agg

    def to_chrome(self) -> dict:
        """The Chrome/Perfetto ``trace_event`` JSON object."""
        with self._lock:
            events = [dict(ev) for ev in self.events]
            counters = dict(self.counters)
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {"tracer": self.name, "counters": counters},
        }

    def save(self, path) -> str:
        path = os.fspath(path)
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "wt") as fh:
            json.dump(self.to_chrome(), fh)
        return path


# --------------------------------------------------------------------------
# Active-tracer plumbing
# --------------------------------------------------------------------------

_default_tracer = Tracer("repro-default")
_stack: List[Tracer] = []
_stack_lock = threading.Lock()


def get_tracer() -> Tracer:
    """The innermost ``use_tracer`` tracer, else the process default."""
    with _stack_lock:
        return _stack[-1] if _stack else _default_tracer


@contextmanager
def use_tracer(tracer: Tracer):
    """Install ``tracer`` as the active sink for the with-block."""
    with _stack_lock:
        _stack.append(tracer)
    try:
        yield tracer
    finally:
        with _stack_lock:
            _stack.remove(tracer)


def traced(name: Optional[str] = None, cat: str = "solver") -> Callable:
    """Decorator: run the function under a span on the active tracer."""

    def deco(fn):
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*a, **kw):
            with get_tracer().span(label, cat=cat):
                return fn(*a, **kw)

        return wrapper

    return deco


# --------------------------------------------------------------------------
# Perfetto / Chrome trace_event schema validation
# --------------------------------------------------------------------------


def validate_chrome_trace(obj) -> List[str]:
    """Validate a trace object (or already-serialized JSON string) against
    the Chrome ``trace_event`` schema subset Perfetto loads. Returns a
    list of error strings — empty means loadable."""
    errors: List[str] = []
    if isinstance(obj, (str, bytes)):
        try:
            obj = json.loads(obj)
        except json.JSONDecodeError as e:
            return [f"not valid JSON: {e}"]
    if isinstance(obj, list):
        events = obj  # the bare-array container format is also accepted
    elif isinstance(obj, dict):
        events = obj.get("traceEvents")
        if not isinstance(events, list):
            return ["top-level object has no 'traceEvents' array"]
    else:
        return [f"trace must be an object or array, got {type(obj).__name__}"]

    open_begins: Dict[tuple, int] = {}
    for n, ev in enumerate(events):
        where = f"event[{n}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if not isinstance(ph, str) or ph not in _VALID_PH:
            errors.append(f"{where}: bad ph {ph!r}")
            continue
        if not isinstance(ev.get("name"), str):
            errors.append(f"{where}: missing/non-string name")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            errors.append(f"{where}: missing/non-numeric ts")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"{where}: X event needs numeric dur >= 0")
        if "args" in ev and not isinstance(ev["args"], dict):
            errors.append(f"{where}: args must be an object")
        track = (ev.get("pid"), ev.get("tid"))
        if ph == "B":
            open_begins[track] = open_begins.get(track, 0) + 1
        elif ph == "E":
            if open_begins.get(track, 0) <= 0:
                errors.append(f"{where}: E event without matching B")
            else:
                open_begins[track] -= 1
    for track, n_open in open_begins.items():
        if n_open:
            errors.append(f"track {track}: {n_open} unclosed B event(s)")
    try:
        json.dumps(events)
    except (TypeError, ValueError) as e:
        errors.append(f"events not JSON-serializable: {e}")
    return errors
