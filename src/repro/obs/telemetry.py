"""Device-side metric rings (DESIGN.md §Observability).

A ``TelemetryRing`` is a fixed-size buffer of per-iteration solver
records — winner index, step size, step-rule event code, sampled duality
gap, objective, stopping statistics, cumulative dot-product count —
carried as an optional pytree slot in ``engine.EngineState`` and filled
on-device inside the hot loop. Telemetry is OFF by default
(``FWConfig.telemetry is None``): every recording site is gated at
trace time, so the default jaxpr — and therefore every pinned golden
trajectory — is unchanged, bit for bit.

Overhead contract when ON: recording is O(1) scalar scatters per
iteration plus (with ``record_objective``) the oracle's O(1)/O(m)
objective and gap scalars; no host synchronization happens in the hot
loop. Host flushes (``stream_to``) run through ``jax.debug.callback``
only when the ring is about to wrap and once at the end of the solve —
chunk/patience boundaries, never per step.

The ring wraps: with ``capacity = C`` the last C records survive;
``cursor`` counts ALL records ever written, so a wrapped ring still
tells you the true iteration count and which slots are live.
``ring_to_records`` gives the chronological host-side view.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

# step-rule event codes (ring ``event`` field)
EVENT_FW = 0  # classic Frank-Wolfe vertex step
EVENT_AWAY = 1  # away-step over the tracked active set
EVENT_PAIRWISE = 2  # pairwise mass transfer
EVENT_DROP = 3  # away/pairwise step that hit g_max: atom dropped exactly
EVENT_LAZY_HIT = 4  # lazy LMO served the step from the winner cache
EVENT_PARTAN = 5  # classic step + PARTAN extrapolation

EVENT_NAMES = ("fw", "away", "pairwise", "drop", "lazy-hit", "partan")


@dataclasses.dataclass(frozen=True)
class TelemetrySpec:
    """Static telemetry config; hashable, rides inside ``FWConfig`` as
    part of the jit key (a different spec is a different program — the
    DEFAULT ``telemetry=None`` program is the pre-telemetry jaxpr).

    Attributes:
      capacity: ring slots; the last ``capacity`` iterations survive.
      record_objective: record the oracle objective and the sampled FW
        duality gap per step. O(1) scalars for lasso / elastic-net, one
        O(m) reduction for logistic (an extra psum per step under the
        distributed backend). When on, the fused megakernel chunk
        executor is bypassed in favor of the bit-identical fori-of-step
        executor (the kernel does not emit per-step objectives); with it
        off the megakernel runs and the ring records the kernel's own
        per-step (i_star, lam, stall) records with NaN objective/gap.
      stream_to: name of a host sink registered via ``register_sink`` to
        receive record batches at ring-wrap boundaries and once at the
        end of the solve (``jax.debug.callback``; single-device
        sequential solves only — the batched and distributed drivers
        keep the ring device-resident and surface it on the result).
    """

    capacity: int = 256
    record_objective: bool = True
    stream_to: Optional[str] = None

    def __post_init__(self):
        if self.capacity < 1:
            raise ValueError(f"telemetry capacity must be >= 1, got {self.capacity}")


class TelemetryRing(NamedTuple):
    """The device-side buffer. ``cursor``/``flushed`` are totals (not
    modulo); array fields have shape (capacity,)."""

    cursor: jax.Array  # () i32  records ever written
    flushed: jax.Array  # () i32  records already streamed to the host sink
    k: jax.Array  # (C,) i32  iteration index (-1 = empty slot)
    i_star: jax.Array  # (C,) i32  winner coordinate
    event: jax.Array  # (C,) i32  EVENT_* code
    stall: jax.Array  # (C,) i32  stall counter AFTER the step
    lam: jax.Array  # (C,) f32  step size (gamma for the direction rules)
    gap: jax.Array  # (C,) f32  sampled FW duality gap (NaN when unrecorded)
    objective: jax.Array  # (C,) f32  post-step objective (NaN when unrecorded)
    step_inf: jax.Array  # (C,) f32  ||alpha_{k+1}-alpha_k||_inf bound
    n_dots: jax.Array  # (C,) f32  cumulative dot-product count


# names of the (C,)-shaped record fields, in TelemetryRing order
RECORD_FIELDS = (
    "k", "i_star", "event", "stall", "lam", "gap", "objective",
    "step_inf", "n_dots",
)
_INT_FIELDS = frozenset(("k", "i_star", "event", "stall"))


def init_ring(spec: TelemetrySpec) -> TelemetryRing:
    c = spec.capacity
    i0 = jnp.zeros((), jnp.int32)
    return TelemetryRing(
        cursor=i0,
        flushed=i0,
        k=jnp.full((c,), -1, jnp.int32),
        i_star=jnp.full((c,), -1, jnp.int32),
        event=jnp.zeros((c,), jnp.int32),
        stall=jnp.zeros((c,), jnp.int32),
        lam=jnp.full((c,), jnp.nan, jnp.float32),
        gap=jnp.full((c,), jnp.nan, jnp.float32),
        objective=jnp.full((c,), jnp.nan, jnp.float32),
        step_inf=jnp.full((c,), jnp.nan, jnp.float32),
        n_dots=jnp.full((c,), jnp.nan, jnp.float32),
    )


def _cast(name: str, value) -> jax.Array:
    dt = jnp.int32 if name in _INT_FIELDS else jnp.float32
    return jnp.asarray(value).astype(dt)


def record(ring: TelemetryRing, **fields) -> TelemetryRing:
    """Write one record at the cursor slot (wrapping) and advance. All
    ops are O(1) scalar scatters — no host traffic."""
    slot = jnp.mod(ring.cursor, ring.k.shape[0])
    upd = {
        name: getattr(ring, name).at[slot].set(_cast(name, fields[name]))
        for name in RECORD_FIELDS
    }
    return ring._replace(cursor=ring.cursor + 1, **upd)


def amend_last(ring: TelemetryRing, **fields) -> TelemetryRing:
    """Overwrite fields of the most recent record in place (cursor does
    NOT advance) — used by composite rules (PARTAN) whose inner classic
    step already recorded and whose final statistics supersede it."""
    slot = jnp.mod(ring.cursor - 1, ring.k.shape[0])
    upd = {
        name: getattr(ring, name).at[slot].set(_cast(name, value))
        for name, value in fields.items()
    }
    return ring._replace(**upd)


def history_spec(spec: Optional[TelemetrySpec], n_iters: int) -> TelemetrySpec:
    """The spec ``solve_with_history`` runs under: capacity = n_iters
    (slot t IS iteration t — no wrap) with per-step objectives on;
    a caller-provided spec keeps its streaming sink."""
    base = spec if spec is not None else TelemetrySpec()
    return dataclasses.replace(
        base, capacity=max(int(n_iters), 1), record_objective=True
    )


def ring_to_records(ring, limit: Optional[int] = None) -> Dict[str, np.ndarray]:
    """Chronological host-side view of the live ring contents: a dict of
    1-D numpy arrays (oldest surviving record first) plus the absolute
    ``record_index`` of each row. Single ring only — index a lane axis
    off a batched result before calling."""
    cursor = int(np.asarray(ring.cursor))
    cap = int(np.asarray(ring.k).shape[0])
    n = min(cursor, cap)
    if limit is not None:
        n = min(n, int(limit))
    start = cursor - n
    idx = (start + np.arange(n)) % cap
    out = {name: np.asarray(getattr(ring, name))[idx] for name in RECORD_FIELDS}
    out["record_index"] = start + np.arange(n)
    return out


# --------------------------------------------------------------------------
# Host streaming sinks (jax.debug.callback flushes at wrap boundaries)
# --------------------------------------------------------------------------

_SINKS: Dict[str, Callable[[Dict[str, np.ndarray]], None]] = {}


def register_sink(name: str, fn: Callable[[Dict[str, np.ndarray]], None]) -> None:
    """Register a host callable receiving record batches (the dict format
    of ``ring_to_records``) for ``TelemetrySpec(stream_to=name)``."""
    _SINKS[name] = fn


def unregister_sink(name: str) -> None:
    _SINKS.pop(name, None)


def _host_flush(sink_name: str, capacity: int):
    def cb(cursor, flushed, *leaves):
        fn = _SINKS.get(sink_name)
        if fn is None:
            return
        cursor = int(cursor)
        n = min(cursor - int(flushed), capacity)
        if n <= 0:
            return
        start = cursor - n
        idx = (start + np.arange(n)) % capacity
        batch = {
            name: np.asarray(leaf)[idx]
            for name, leaf in zip(RECORD_FIELDS, leaves)
        }
        batch["record_index"] = start + np.arange(n)
        fn(batch)

    return cb


def stream_flush(ring: TelemetryRing, spec: TelemetrySpec, *,
                 final: bool) -> TelemetryRing:
    """Flush unstreamed records to the spec's host sink. ``final=False``
    flushes only when the ring is full of unflushed records (i.e. about
    to wrap) — the chunk-boundary cadence; ``final=True`` flushes the
    remainder unconditionally (end of solve / patience stop). Trace-time
    no-op when the spec has no sink."""
    if spec is None or spec.stream_to is None:
        return ring
    cb = _host_flush(spec.stream_to, spec.capacity)

    def do(r: TelemetryRing) -> TelemetryRing:
        fields = tuple(getattr(r, name) for name in RECORD_FIELDS)
        jax.debug.callback(cb, r.cursor, r.flushed, *fields)
        return r._replace(flushed=r.cursor)

    if final:
        return do(ring)
    return jax.lax.cond(
        ring.cursor - ring.flushed >= spec.capacity, do, lambda r: r, ring
    )
