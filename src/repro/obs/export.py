"""Metrics exposition (DESIGN.md §Observability).

Serializes a ``MetricsRegistry`` three ways:

  * ``render_openmetrics`` — OpenMetrics/Prometheus text format, with
    cumulative ``_bucket{le=...}`` series, ``_sum``/``_count``, AND
    summary-style ``{quantile="0.5|0.95|0.99"}`` samples derived from
    the fixed buckets, so a scrape alone answers "what is p99 solve
    latency" without a query engine;
  * ``snapshot_json`` — a structured dict (same content, machine-first)
    for report artifacts;
  * ``MetricsServer`` — a daemon-thread HTTP endpoint serving
    ``/metrics`` (text) and ``/metrics.json``, the scrape surface the
    serving layer (ROADMAP direction 1) points Prometheus at.

``validate_openmetrics`` is the exposition checker CI's telemetry smoke
runs against a live scrape: TYPE/HELP lines, sample syntax, bucket
monotonicity and the ``# EOF`` terminator.

Import-light on purpose: no jax, no repro.core — this module must be
loadable from a scrape-only process.
"""
from __future__ import annotations

import http.server
import json
import math
import re
import threading
import urllib.request
from typing import Dict, List, Optional

from repro.obs.metrics import (
    QUANTILES,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    get_registry,
)


def _fmt(v: float) -> str:
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if math.isnan(v):
        return "NaN"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


_ESCAPES = {"\\": "\\\\", "\n": "\\n", '"': '\\"'}


def _escape_label(v: str) -> str:
    return "".join(_ESCAPES.get(ch, ch) for ch in v)


def _labels_str(pairs, extra=()) -> str:
    items = [f'{k}="{_escape_label(v)}"' for k, v in (*pairs, *extra)]
    return "{" + ",".join(items) + "}" if items else ""


def render_openmetrics(registry: Optional[MetricsRegistry] = None) -> str:
    """The registry as OpenMetrics text (ends with ``# EOF``)."""
    registry = registry if registry is not None else get_registry()
    lines: List[str] = []
    for metric in registry.collect() if registry is not None else ():
        name = metric.name
        if metric.help:
            lines.append(f"# HELP {name} {metric.help}")
        if isinstance(metric, Counter):
            lines.append(f"# TYPE {name} counter")
            for key, value in metric.series():
                lines.append(f"{name}_total{_labels_str(key)} {_fmt(value)}")
        elif isinstance(metric, Gauge):
            lines.append(f"# TYPE {name} gauge")
            for key, value in metric.series():
                lines.append(f"{name}{_labels_str(key)} {_fmt(value)}")
        elif isinstance(metric, Histogram):
            lines.append(f"# TYPE {name} histogram")
            for key, snap in metric.series():
                for le, cum in snap["buckets"]:
                    lines.append(
                        f"{name}_bucket"
                        f"{_labels_str(key, (('le', _fmt(le)),))} {cum}"
                    )
                lines.append(f"{name}_sum{_labels_str(key)} {_fmt(snap['sum'])}")
                lines.append(f"{name}_count{_labels_str(key)} {snap['count']}")
                for q in QUANTILES:
                    val = metric.quantile(q, **dict(key))
                    lines.append(
                        f"{name}"
                        f"{_labels_str(key, (('quantile', _fmt(q)),))} {_fmt(val)}"
                    )
        else:  # pragma: no cover - registry only creates the three kinds
            raise TypeError(f"unknown metric kind {type(metric).__name__}")
    lines.append("# EOF")
    return "\n".join(lines) + "\n"


def snapshot_json(registry: Optional[MetricsRegistry] = None) -> Dict:
    """Machine-first snapshot: {metric: {kind, help, series: [...]}}."""
    registry = registry if registry is not None else get_registry()
    out: Dict[str, Dict] = {}
    for metric in registry.collect() if registry is not None else ():
        entry: Dict = {"kind": metric.kind, "help": metric.help, "series": []}
        if isinstance(metric, Histogram):
            entry["buckets"] = list(metric.buckets)
            for key, snap in metric.series():
                entry["series"].append(
                    {
                        "labels": dict(key),
                        "sum": snap["sum"],
                        "count": snap["count"],
                        "bucket_counts": [c for _, c in snap["buckets"]],
                        "quantiles": {
                            _fmt(q): metric.quantile(q, **dict(key))
                            for q in QUANTILES
                        },
                    }
                )
        else:
            for key, value in metric.series():
                entry["series"].append({"labels": dict(key), "value": value})
        out[metric.name] = entry
    return out


# --------------------------------------------------------------------------
# Exposition checker
# --------------------------------------------------------------------------

_METRIC_NAME = r"[a-zA-Z_:][a-zA-Z0-9_:]*"
_SAMPLE_RE = re.compile(
    rf"^({_METRIC_NAME})(\{{.*\}})? (-?\d+(\.\d+)?([eE][+-]?\d+)?|[+-]Inf|NaN)$"
)
_LABELS_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def validate_openmetrics(text: str) -> List[str]:
    """Check OpenMetrics text; returns a list of problems (empty = valid).

    Validates: every non-comment line parses as a sample; TYPE declared
    before its samples; histogram buckets are cumulative (monotone,
    ending at +Inf == _count); counters use the _total suffix; the text
    terminates with ``# EOF``.
    """
    problems: List[str] = []
    lines = text.splitlines()
    if not lines or lines[-1].strip() != "# EOF":
        problems.append("missing '# EOF' terminator")
    types: Dict[str, str] = {}
    # per (hist-name, labels-minus-le): [(le, cum)...] in appearance order
    hist_buckets: Dict[tuple, List[tuple]] = {}
    hist_counts: Dict[tuple, float] = {}
    for ln, raw in enumerate(lines, 1):
        line = raw.rstrip()
        if not line or line == "# EOF":
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) >= 3 and parts[1] == "TYPE":
                if len(parts) < 4 or parts[3] not in (
                    "counter", "gauge", "histogram", "summary", "untyped",
                ):
                    problems.append(f"line {ln}: malformed TYPE: {line!r}")
                else:
                    types[parts[2]] = parts[3]
            elif len(parts) >= 2 and parts[1] not in ("HELP", "TYPE", "UNIT"):
                problems.append(f"line {ln}: unknown comment directive: {line!r}")
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            problems.append(f"line {ln}: unparseable sample: {line!r}")
            continue
        name, labels_part, value_str = m.group(1), m.group(2), m.group(3)
        labels = dict(_LABELS_RE.findall(labels_part or ""))
        base = name
        for suffix in ("_total", "_bucket", "_sum", "_count"):
            if name.endswith(suffix) and name[: -len(suffix)] in types:
                base = name[: -len(suffix)]
                break
        mtype = types.get(base)
        if mtype is None:
            problems.append(f"line {ln}: sample {name!r} has no preceding TYPE")
            continue
        if mtype == "counter" and not name.endswith("_total"):
            problems.append(f"line {ln}: counter sample {name!r} missing _total")
        if mtype == "histogram":
            key = (base, tuple(sorted((k, v) for k, v in labels.items() if k != "le")))
            if name.endswith("_bucket"):
                if "le" not in labels:
                    problems.append(f"line {ln}: histogram bucket missing le label")
                    continue
                le = math.inf if labels["le"] == "+Inf" else float(labels["le"])
                hist_buckets.setdefault(key, []).append((le, float(value_str)))
            elif name.endswith("_count"):
                hist_counts[key] = float(value_str)
    for (base, lbls), buckets in hist_buckets.items():
        les = [le for le, _ in buckets]
        cums = [c for _, c in buckets]
        if les != sorted(les):
            problems.append(f"{base}{dict(lbls)}: bucket le bounds not sorted")
        if any(b > a for a, b in zip(cums[1:], cums)):
            problems.append(f"{base}{dict(lbls)}: bucket counts not cumulative")
        if not les or not math.isinf(les[-1]):
            problems.append(f"{base}{dict(lbls)}: missing le=+Inf bucket")
        elif (base, lbls) in hist_counts and cums[-1] != hist_counts[(base, lbls)]:
            problems.append(f"{base}{dict(lbls)}: +Inf bucket != _count")
    return problems


# --------------------------------------------------------------------------
# Background /metrics endpoint
# --------------------------------------------------------------------------


class _Handler(http.server.BaseHTTPRequestHandler):
    server_version = "fw-metrics/1"

    def do_GET(self):  # noqa: N802 (http.server API)
        registry = self.server._metrics_registry_fn()
        if self.path.split("?")[0] == "/metrics":
            body = render_openmetrics(registry).encode()
            ctype = "application/openmetrics-text; version=1.0.0; charset=utf-8"
        elif self.path.split("?")[0] == "/metrics.json":
            body = json.dumps(snapshot_json(registry), indent=2).encode()
            ctype = "application/json"
        else:
            self.send_error(404, "try /metrics or /metrics.json")
            return
        self.send_response(200)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, *args):  # quiet: scrapes are not stdout events
        pass


class MetricsServer:
    """Daemon-thread HTTP scrape endpoint.

    ``port=0`` (default) binds an ephemeral port, read it back from
    ``.port`` / ``.url``. Context manager for scoped use::

        with MetricsServer(registry) as srv:
            ...solve...
            text = urllib.request.urlopen(srv.url).read()
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 host: str = "127.0.0.1", port: int = 0):
        self._registry = registry
        self._httpd = http.server.ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        # late-bound so a server started before install_registry still scrapes
        self._httpd._metrics_registry_fn = (
            (lambda: self._registry) if registry is not None else get_registry
        )
        self._thread: Optional[threading.Thread] = None

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self._httpd.server_address[0]}:{self.port}/metrics"

    def start(self) -> "MetricsServer":
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="fw-metrics", daemon=True
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

    def __enter__(self) -> "MetricsServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


def scrape(url: str, timeout: float = 5.0) -> str:
    """GET an exposition endpoint (convenience for tests/smoke)."""
    with urllib.request.urlopen(url, timeout=timeout) as resp:
        return resp.read().decode()
