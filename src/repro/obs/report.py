"""Run-report rendering: telemetry ring + tracer -> markdown/JSON
artifacts (DESIGN.md §Observability; CLI in ``scripts/solver_report.py``).

Pure data-shuffling on the host — no jax imports — so report rendering
is usable from tests, benchmarks, and CI without touching the device.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

import numpy as np

from repro.obs import telemetry as obs_telemetry

# max convergence-curve rows rendered into the markdown table (the JSON
# artifact always carries every surviving ring record)
_CURVE_ROWS = 24


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        if np.isnan(v):
            return "nan"
        return f"{v:.6g}"
    return str(v)


def build_report(
    *,
    meta: Dict,
    runs: Optional[List[Dict]] = None,
    tracer=None,
) -> Dict:
    """Assemble the JSON-shaped report.

    ``meta``: run provenance (git sha, jax/device info, timestamp, ...).
    ``runs``: one entry per solve — ``{"name", "backend", "ring"?,
    "iterations", "n_dots", "seconds"?, "objective"?, "gap"?,
    "comm_fraction"?}`` where ``ring`` is a TelemetryRing (or an
    already-decoded ``ring_to_records`` dict).
    ``tracer``: an ``obs.trace.Tracer`` for the time breakdown/counters.
    """
    report: Dict = {"meta": dict(meta), "runs": []}
    for run in runs or []:
        entry = {k: v for k, v in run.items() if k != "ring"}
        ring = run.get("ring")
        if ring is not None:
            records = (
                ring if isinstance(ring, dict)
                else obs_telemetry.ring_to_records(ring)
            )
            entry["records"] = {
                name: np.asarray(col).tolist() for name, col in records.items()
            }
            events = np.asarray(records["event"], np.int64)
            entry["event_counts"] = {
                obs_telemetry.EVENT_NAMES[code]: int((events == code).sum())
                for code in range(len(obs_telemetry.EVENT_NAMES))
                if int((events == code).sum())
            }
        report["runs"].append(entry)
    if tracer is not None:
        report["spans"] = tracer.span_table()
        report["counters"] = tracer.counter_table()
    return report


def _curve_table(records: Dict[str, list]) -> List[str]:
    n = len(records.get("k", []))
    if n == 0:
        return ["(empty ring)"]
    rows = ["| k | event | i_star | lam | gap | objective | step_inf | stall |",
            "|---|---|---|---|---|---|---|---|"]
    take = np.unique(
        np.linspace(0, n - 1, min(n, _CURVE_ROWS)).astype(int)
    )
    for t in take:
        ev = int(records["event"][t])
        name = (
            obs_telemetry.EVENT_NAMES[ev]
            if 0 <= ev < len(obs_telemetry.EVENT_NAMES) else str(ev)
        )
        rows.append(
            "| " + " | ".join(
                _fmt(v) for v in (
                    records["k"][t], name, records["i_star"][t],
                    float(records["lam"][t]), float(records["gap"][t]),
                    float(records["objective"][t]),
                    float(records["step_inf"][t]), records["stall"][t],
                )
            ) + " |"
        )
    return rows


def render_markdown(report: Dict) -> str:
    """The human-facing artifact: provenance, per-run convergence curve,
    dots-per-backend table, span time breakdown, counter table."""
    lines = ["# Solver run report", ""]
    lines.append("## Provenance")
    for k, v in report.get("meta", {}).items():
        lines.append(f"- **{k}**: {_fmt(v)}")
    lines.append("")

    runs = report.get("runs", [])
    if runs:
        lines.append("## Runs (dots per backend)")
        lines.append(
            "| run | backend | iterations | n_dots | objective | gap "
            "| seconds | comm fraction |"
        )
        lines.append("|---|---|---|---|---|---|---|---|")
        for run in runs:
            lines.append(
                "| " + " | ".join(
                    _fmt(run.get(k)) for k in (
                        "name", "backend", "iterations", "n_dots",
                        "objective", "gap", "seconds", "comm_fraction",
                    )
                ) + " |"
            )
        lines.append("")
    for run in runs:
        if "records" not in run:
            continue
        lines.append(f"## Convergence curve — {run.get('name', '?')}")
        if run.get("event_counts"):
            lines.append(
                "step events: " + ", ".join(
                    f"{k}={v}" for k, v in run["event_counts"].items()
                )
            )
            lines.append("")
        lines.extend(_curve_table(run["records"]))
        lines.append("")

    spans = report.get("spans")
    if spans:
        lines.append("## Time breakdown (host spans)")
        lines.append("| span | count | total s | mean s |")
        lines.append("|---|---|---|---|")
        for name in sorted(spans, key=lambda n: -spans[n]["total_s"]):
            row = spans[name]
            lines.append(
                f"| {name} | {row['count']} | {row['total_s']:.4f} "
                f"| {row['mean_s']:.4f} |"
            )
        lines.append("")
    counters = report.get("counters")
    if counters:
        lines.append("## Counters")
        lines.append("| counter | value |")
        lines.append("|---|---|")
        for name in sorted(counters):
            lines.append(f"| {name} | {_fmt(counters[name])} |")
        lines.append("")
    return "\n".join(lines)


def write_report(out_dir, report: Dict, name: str = "solver_report") -> Dict[str, str]:
    """Write ``<name>.json`` + ``<name>.md`` under ``out_dir``; returns
    the paths."""
    os.makedirs(out_dir, exist_ok=True)
    json_path = os.path.join(out_dir, f"{name}.json")
    md_path = os.path.join(out_dir, f"{name}.md")
    with open(json_path, "wt") as fh:
        json.dump(report, fh, indent=2)
    with open(md_path, "wt") as fh:
        fh.write(render_markdown(report))
    return {"json": json_path, "markdown": md_path}
