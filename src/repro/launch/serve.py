"""Serving launcher: prefill a batch of synthetic requests, decode N
tokens with the jitted serve_step, report tokens/s.

    PYTHONPATH=src python -m repro.launch.serve --arch deepseek_7b --reduced
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import model as M
from repro.training import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    batch = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.n_prefix_embeds:
        batch["patches"] = jax.random.normal(key, (args.batch, cfg.n_prefix_embeds, cfg.d_model))
    if cfg.n_enc_layers:
        batch["frames"] = jax.random.normal(key, (args.batch, 32, cfg.d_model))

    max_seq = args.prompt_len + args.tokens + cfg.n_prefix_embeds + 8
    t0 = time.perf_counter()
    logits, cache = M.prefill(params, batch, cfg, max_seq=max_seq)
    jax.block_until_ready(logits)
    print(f"[serve] prefill: {time.perf_counter()-t0:.2f}s")

    serve = jax.jit(make_serve_step(cfg), donate_argnums=(2,))
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    t0 = time.perf_counter()
    for _ in range(args.tokens):
        tok, _, cache = serve(params, tok, cache)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    print(
        f"[serve] {args.batch * args.tokens} tokens in {dt:.2f}s "
        f"({args.batch * args.tokens / dt:.1f} tok/s)"
    )


if __name__ == "__main__":
    main()
