"""The (architecture x input-shape) cell plan for the multi-pod dry-run.

Four LM shapes (brief):
  train_4k    seq 4096,   global_batch 256   -> train_step
  prefill_32k seq 32768,  global_batch 32    -> prefill
  decode_32k  cache 32768, global_batch 128  -> serve_step (1 new token)
  long_500k   cache 524288, global_batch 1   -> serve_step; SSM/hybrid only

Per-cell knobs (microbatches, FSDP, MoE serve sharding) are the
production-tuning surface; they are recorded in EXPERIMENTS.md per cell.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models.config import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# microbatch counts for train_4k, sized so scan-over-layers carries stay
# within a few GB/device at dp=16 (see DESIGN.md §5)
TRAIN_MICROBATCHES: Dict[str, int] = {
    "mamba2_130m": 1,
    "internlm2_20b": 16,
    "deepseek_7b": 8,
    "gemma2_9b": 8,
    "qwen2_72b": 16,
    "internvl2_76b": 16,
    "arctic_480b": 16,
    "kimi_k2_1t_a32b": 16,
    "hymba_1_5b": 4,
    "seamless_m4t_medium": 2,
}

# MoE/huge archs shard the expert/mlp dim over 'data' too while serving so
# bf16 params fit 16GB/chip (DESIGN.md §5)
SERVE_MLP_DATA = {"arctic_480b", "kimi_k2_1t_a32b", "internvl2_76b"}


def cell_skip_reason(arch: str, shape: str) -> Optional[str]:
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.supports_long_context:
        return "full-attention arch: 500k decode is quadratic-regime (DESIGN.md)"
    return None


def iter_cells():
    for arch in ARCH_IDS:
        for shape in SHAPES:
            yield arch, shape, cell_skip_reason(arch, shape)


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_specs_for(cfg: ModelConfig, shape: ShapeSpec) -> Dict:
    """Training/prefill batch ShapeDtypeStructs."""
    B = shape.global_batch
    S = shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    batch = {}
    if shape.kind == "train":
        batch["tokens"] = _sds((B, S + 1), jnp.int32)
    else:
        batch["tokens"] = _sds((B, S), jnp.int32)
    if cfg.n_prefix_embeds:
        batch["patches"] = _sds((B, cfg.n_prefix_embeds, cfg.d_model), dt)
    if cfg.n_enc_layers:
        # encoder memory length: full seq for training, capped for serving
        enc_len = S if shape.kind == "train" else min(S, 4096)
        batch["frames"] = _sds((B, enc_len, cfg.d_model), dt)
    return batch


def decode_inputs_for(cfg: ModelConfig, shape: ShapeSpec):
    """(tokens, cache) ShapeDtypeStructs for serve_step."""
    from repro.models import model as model_lib

    B, S = shape.global_batch, shape.seq_len
    tokens = _sds((B, 1), jnp.int32)
    cache = jax.eval_shape(
        lambda: model_lib.init_cache(cfg, B, S, dtype=cfg.dtype)
    )
    if cfg.n_enc_layers:
        cache = dict(cache)
        cache["memory"] = _sds((B, min(S, 4096), cfg.d_model), jnp.dtype(cfg.dtype))
    return tokens, cache


def params_spec_for(cfg: ModelConfig):
    from repro.models import model as model_lib

    return jax.eval_shape(
        lambda k: model_lib.init_params(k, cfg), jax.random.PRNGKey(0)
    )


def opt_spec_for(cfg: ModelConfig, params_spec):
    from repro.training import optimizers as opt_lib

    return jax.eval_shape(
        lambda p: opt_lib.init_optimizer(cfg.optimizer, p), params_spec
    )
