"""Production mesh construction (single-pod 16x16 and 2-pod 2x16x16).

A FUNCTION (not module-level constant) so importing never touches jax
device state. The dry-run entry point sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over locally available devices (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


def dp_size(mesh) -> int:
    n = mesh.shape.get("data", 1)
    n *= mesh.shape.get("pod", 1)
    return n


def tp_size(mesh) -> int:
    return mesh.shape.get("model", 1)
