import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
512 placeholder CPU devices, record memory/cost analysis + collective
tallies for the roofline (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2_72b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod both]
Outputs JSON per cell under experiments/dryrun/.
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import roofline as rf
from repro.configs import ARCH_IDS, get_config
from repro.launch import cells as cell_lib
from repro.launch.mesh import make_production_mesh
from repro.models import sharding as sh
from repro.models.config import ModelConfig
from repro.training import optimizers as opt_lib
from repro.training.train_step import make_serve_step, make_train_step, make_prefill_step

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _opt_shardings(opt_spec, params_spec, mesh, fsdp, rules=None):
    """Optimizer-state shardings derived from the param specs."""
    pspecs = sh.param_specs(params_spec, mesh, fsdp=fsdp, rules=rules)

    def leaf(ospec_leaf_path, oleaf):
        return None  # placeholder, replaced below

    # AdamLeaf(m, v, master): same spec as param. FactorLeaf: reduced dims.
    def map_state(pspec, state_leaf):
        if isinstance(state_leaf, opt_lib.AdamLeaf):
            ns = NamedSharding(mesh, pspec)
            master_ns = (
                ns
                if state_leaf.master.ndim == len(pspec)
                else NamedSharding(mesh, P(None))  # fp32 placeholder master
            )
            return opt_lib.AdamLeaf(m=ns, v=ns, master=master_ns)
        if isinstance(state_leaf, opt_lib.FactorLeaf):
            parts = list(pspec)
            row = P(*parts[:-1]) if state_leaf.v_row.ndim == len(parts) - 1 else P()
            col = (
                P(*(parts[:-2] + parts[-1:]))
                if state_leaf.v_col.ndim == len(parts) - 1
                else P()
            )
            full = P(*parts) if state_leaf.v_full.ndim == len(parts) else P()
            return opt_lib.FactorLeaf(
                v_row=NamedSharding(mesh, row),
                v_col=NamedSharding(mesh, col),
                v_full=NamedSharding(mesh, full),
            )
        raise TypeError(type(state_leaf))

    inner = jax.tree.map(
        map_state,
        pspecs,
        opt_spec.inner,
        is_leaf=lambda x: isinstance(x, (opt_lib.AdamLeaf, opt_lib.FactorLeaf)),
    )
    return opt_lib.OptState(
        step=NamedSharding(mesh, P()),
        inner=inner,
    )


def _serve_rules(arch: str):
    if arch in cell_lib.SERVE_MLP_DATA:
        rules = dict(sh.DEFAULT_RULES)
        rules["mlp"] = "data"
        rules["moe_mlp"] = "data"  # expert weights shard F over data too
        return rules
    return None


def _dp_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def lower_cell(arch: str, shape_name: str, mesh, *, probe: bool = False,
               cfg_override=None) -> tuple:
    """Returns (lowered, meta) for one cell.

    ``probe=True`` lowers a cost-analysis variant: layers unrolled and no
    microbatch loop, so cost_analysis() counts every layer (XLA counts
    while-loop bodies once — see _probe_costs for the two-point scheme).
    """
    import dataclasses as dc

    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = cell_lib.SHAPES[shape_name]
    if probe:
        cfg = dc.replace(cfg, scan_layers=False)
    params_spec = cell_lib.params_spec_for(cfg)

    if shape.kind == "train":
        dp = int(np.prod([mesh.shape[a] for a in _dp_axes(mesh)]))
        mb = min(cell_lib.TRAIN_MICROBATCHES[arch], max(shape.global_batch // dp, 1))
        pshard = sh.param_shardings(params_spec, mesh, fsdp=True)
        opt_spec = cell_lib.opt_spec_for(cfg, params_spec)
        oshard = _opt_shardings(opt_spec, params_spec, mesh, fsdp=True)
        batch_spec = cell_lib.batch_specs_for(cfg, shape)
        bshard = jax.tree.map(
            lambda s: NamedSharding(mesh, s), sh.batch_specs(batch_spec, mesh)
        )
        step = make_train_step(
            cfg, microbatches=1 if probe else mb, dp_axes=_dp_axes(mesh)
        )
        jitted = jax.jit(
            step,
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(params_spec, opt_spec, batch_spec)
        meta = {"microbatches": mb, "fsdp": True}
    elif shape.kind == "prefill":
        rules = _serve_rules(arch)
        pshard = sh.param_shardings(params_spec, mesh, fsdp=False, rules=rules)
        batch_spec = cell_lib.batch_specs_for(cfg, shape)
        bshard = jax.tree.map(
            lambda s: NamedSharding(mesh, s), sh.batch_specs(batch_spec, mesh)
        )
        step = make_prefill_step(cfg, max_seq=shape.seq_len)
        jitted = jax.jit(step, in_shardings=(pshard, bshard))
        lowered = jitted.lower(params_spec, batch_spec)
        meta = {"fsdp": False, "serve_rules": arch in cell_lib.SERVE_MLP_DATA}
    else:  # decode
        rules = _serve_rules(arch)
        pshard = sh.param_shardings(params_spec, mesh, fsdp=False, rules=rules)
        tokens_spec, cache_spec = cell_lib.decode_inputs_for(cfg, shape)
        cshard = jax.tree.map(
            lambda s: NamedSharding(mesh, s), sh.cache_specs(cache_spec, mesh)
        )
        tokens_pspec = sh.spec_for(
            tokens_spec.shape, ("batch", None), mesh, sh.DEFAULT_RULES
        )  # falls back to replication when batch < dp (long_500k B=1)
        tshard = NamedSharding(mesh, tokens_pspec)
        step = make_serve_step(cfg)
        jitted = jax.jit(
            step,
            in_shardings=(pshard, tshard, cshard),
            out_shardings=(None, None, cshard),
            donate_argnums=(2,),
        )
        lowered = jitted.lower(params_spec, tokens_spec, cache_spec)
        meta = {"fsdp": False, "serve_rules": arch in cell_lib.SERVE_MLP_DATA}
    return lowered, meta, cfg, shape


def _probe_costs(arch: str, shape_name: str, mesh) -> rf.RooflineTerms:
    """Two-point depth probe: compile unrolled L1/L2-layer variants and
    extrapolate FLOPs / bytes / collective tallies linearly in depth.

    Exact for uniform stacks (every layer identical modulo the cycled
    local/global pattern, which both probe depths sample at the same
    ratio). The MoE dense prefix and embed/head/optimizer costs land in
    the intercept. cost_analysis() undercounts loop bodies, hence the
    unrolled probes (DESIGN.md).
    """
    import dataclasses as dc

    cfg = get_config(arch)
    prefix = cfg.first_k_dense if cfg.n_experts else 0
    L_main = cfg.n_layers - prefix
    period = max(len(cfg.layer_pattern), 1)
    L1 = min(2 * period, L_main)
    L2 = min(4 * period, L_main)

    def measure(Lk: int):
        n_enc = (
            max(1, round(cfg.n_enc_layers * Lk / L_main)) if cfg.n_enc_layers else 0
        )
        cfg_k = dc.replace(
            cfg,
            n_layers=Lk + prefix,
            n_enc_layers=n_enc,
            global_layer_indices=(0,) if cfg.global_layer_indices else (),
        )
        lowered, *_ = lower_cell(arch, shape_name, mesh, probe=True, cfg_override=cfg_k)
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        tallies = rf.parse_collectives(compiled.as_text())
        return (
            float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            tallies,
        )

    f2, b2, t2 = measure(L2)
    if L2 == L_main:  # model already this shallow: exact, no extrapolation
        flops, bytes_acc, tallies = f2, b2, t2
    else:
        f1, b1, t1 = measure(L1)
        scale = (L_main - L2) / (L2 - L1)
        flops = f2 + (f2 - f1) * scale
        bytes_acc = b2 + (b2 - b1) * scale
        tallies = {}
        for kind in t2:
            tallies[kind] = {
                k: t2[kind][k] + (t2[kind][k] - t1[kind][k]) * scale
                for k in t2[kind]
            }

    wire = sum(v["wire_bytes"] for v in tallies.values())
    hw = rf.V5E
    return rf.RooflineTerms(
        compute_s=flops / hw["peak_flops"],
        memory_s=bytes_acc / hw["hbm_bw"],
        collective_s=wire / hw["ici_bw"],
        flops_per_device=flops,
        bytes_per_device=bytes_acc,
        wire_bytes_per_device=wire,
        collectives=tallies,
    )


def run_cell(arch: str, shape_name: str, multi_pod: bool, save_hlo: bool = False) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.time()
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "status": "ok",
    }
    skip = cell_lib.cell_skip_reason(arch, shape_name)
    if skip:
        record["status"] = "skipped"
        record["reason"] = skip
        return record
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        with mesh, sh.activation_mesh(mesh):
            lowered, meta, cfg, shape = lower_cell(arch, shape_name, mesh)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            # two-point cost probe (unrolled 4/8-layer variants, linear
            # extrapolation in depth — exact for uniform stacks)
            t_probe0 = time.time()
            terms = _probe_costs(arch, shape_name, mesh)
            t_probe = time.time() - t_probe0

            n_chips = 512 if multi_pod else 256
            mflops = rf.model_flops(cfg, shape.kind, shape.seq_len, shape.global_batch)
            hlo_total_flops = terms.flops_per_device * n_chips
            record.update(meta)
            record.update(
                {
                    "lower_s": round(t_lower, 1),
                    "compile_s": round(t_compile, 1),
                    "probe_s": round(t_probe, 1),
                    "memory": {
                        "argument_size": getattr(mem, "argument_size_in_bytes", None),
                        "output_size": getattr(mem, "output_size_in_bytes", None),
                        "temp_size": getattr(mem, "temp_size_in_bytes", None),
                        "generated_code_size": getattr(
                            mem, "generated_code_size_in_bytes", None
                        ),
                    },
                    "roofline": terms.to_dict(),
                    "model_flops_total": mflops,
                    "hlo_flops_total": hlo_total_flops,
                    "useful_flops_ratio": mflops / max(hlo_total_flops, 1.0),
                    "hbm_per_device_gb": (
                        (getattr(mem, "argument_size_in_bytes", 0) or 0)
                        + (getattr(mem, "temp_size_in_bytes", 0) or 0)
                    )
                    / 1e9,
                }
            )
            if save_hlo:
                hlo_path = OUT_DIR / f"{arch}_{shape_name}_{mesh_name}.hlo.txt"
                hlo_path.write_text(hlo)
                record["hlo_path"] = str(hlo_path)
    except Exception as e:  # noqa: BLE001 — record and continue
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    record["total_s"] = round(time.time() - t0, 1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(cell_lib.SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", choices=["no", "yes", "both"], default="no")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    pods = {"no": [False], "yes": [True], "both": [False, True]}[args.multipod]

    if args.all:
        cells = [(a, s) for a, s, _ in cell_lib.iter_cells()]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    for arch, shape in cells:
        for mp in pods:
            mesh_name = "2x16x16" if mp else "16x16"
            out = OUT_DIR / f"{arch}_{shape}_{mesh_name}.json"
            if args.skip_existing and out.exists():
                prev = json.loads(out.read_text())
                if prev.get("status") in ("ok", "skipped"):
                    print(f"[skip existing] {out.name}")
                    continue
            print(f"[dryrun] {arch} x {shape} x {mesh_name} ...", flush=True)
            rec = run_cell(arch, shape, mp, save_hlo=args.save_hlo)
            out.write_text(json.dumps(rec, indent=2, default=str))
            status = rec["status"]
            extra = ""
            if status == "ok":
                r = rec["roofline"]
                extra = (
                    f" compile={rec['compile_s']}s dominant={r['dominant']}"
                    f" hbm/dev={rec['hbm_per_device_gb']:.2f}GB"
                    f" useful={rec['useful_flops_ratio']:.3f}"
                )
            elif status == "error":
                extra = f" ERROR {rec['error'][:200]}"
            print(f"[dryrun] {arch} x {shape} x {mesh_name}: {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
