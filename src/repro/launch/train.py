"""Production training launcher.

On a real fleet this runs under multi-host jax.distributed with one
process per host; here it drives the same code path on the local device
set. The dry-run (launch/dryrun.py) proves the production mesh compiles.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch mamba2_130m \
      --steps 50 --batch 8 --seq 128 [--reduced] [--resume]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.data.lm_pipeline import batch_at_step
from repro.runtime import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--ckpt-dir", default="checkpoints/train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    def data_fn(step):
        return batch_at_step(cfg, step, batch=args.batch, seq_len=args.seq, seed=0)

    trainer = Trainer(
        cfg,
        TrainerConfig(
            total_steps=args.steps,
            checkpoint_every=args.ckpt_every,
            checkpoint_dir=f"{args.ckpt_dir}/{args.arch}",
            base_lr=args.lr,
            microbatches=args.microbatches,
        ),
        data_fn,
    )
    params, opt_state, start = trainer.init_or_restore()
    print(f"[train] {args.arch} starting at step {start}")
    t0 = time.time()
    trainer.run()
    dt = time.time() - t0
    n = len(trainer.history)
    print(
        f"[train] done: {n} steps in {dt:.1f}s "
        f"({dt / max(n,1):.2f}s/step), loss {trainer.history[0]:.3f} -> "
        f"{trainer.history[-1]:.3f}, stragglers={len(trainer.monitor.stragglers)}"
    )


if __name__ == "__main__":
    main()
