"""Path-level checkpoint/resume (DESIGN.md §Resilience).

Packs the regularization-path driver's loop state — completed
:class:`~repro.core.path.PathPoint` list, the post-point PRNG key, the
warm-start carry vector, and the lane-pruning totals — into the atomic
checkpoint layout of ``repro.checkpoint.manager`` (tmp dir + fsync +
rename, per-group sha256 digests), and restores it for
``fw_path(..., resume_from=)`` / ``fw_path_batched(..., resume_from=)``.

Bit-identity contract: the per-point index stream is a pure function of
the PRNG key at the grid-point (or lane-chunk) boundary, and the warm
start is a pure function of the carried alpha — so a run killed at any
grid point and resumed from its last checkpoint replays the remaining
points bit-identically to an uninterrupted run (tests/test_resilience).

The nnz coefficient vectors are stored ragged: one concatenated value /
index array plus per-point lengths, preserving the solve dtype exactly.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import manager as ckpt_manager

PATH_GROUP = "path_points"
POS_GROUP = "path_pos"


def _key_to_np(key) -> Tuple[np.ndarray, bool]:
    """Raw PRNG key data + whether the key was the new typed kind."""
    try:
        if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
            return np.asarray(jax.random.key_data(key)), True
    except (AttributeError, TypeError):
        pass
    return np.asarray(key), False


def _key_from_np(arr: np.ndarray, typed: bool):
    if typed:
        return jax.random.wrap_key_data(jnp.asarray(arr))
    return jnp.asarray(arr)


def pack_points(points) -> Dict[str, np.ndarray]:
    """PathPoint list -> flat dict of arrays (manager-serializable)."""
    n = len(points)
    if n:
        idx_cat = np.concatenate([np.asarray(pt.alpha_nnz_idx, np.int64)
                                  for pt in points])
        val_cat = np.concatenate([np.asarray(pt.alpha_nnz_val)
                                  for pt in points])
    else:
        idx_cat = np.zeros(0, np.int64)
        val_cat = np.zeros(0, np.float32)
    return {
        "reg": np.asarray([pt.reg for pt in points], np.float64),
        "objective": np.asarray([pt.objective for pt in points], np.float64),
        "l1": np.asarray([pt.l1 for pt in points], np.float64),
        "gap": np.asarray([pt.gap for pt in points], np.float64),
        "seconds": np.asarray([pt.seconds for pt in points], np.float64),
        "active": np.asarray([pt.active for pt in points], np.int64),
        "iterations": np.asarray([pt.iterations for pt in points], np.int64),
        "n_dots": np.asarray([pt.n_dots for pt in points], np.int64),
        "nnz_len": np.asarray(
            [np.asarray(pt.alpha_nnz_idx).shape[0] for pt in points], np.int64
        ),
        "nnz_idx": idx_cat,
        "nnz_val": val_cat,
    }


def unpack_points(flat: Dict[str, np.ndarray]) -> list:
    from repro.core.path import PathPoint  # lazy: core.path imports us

    lens = np.asarray(flat["nnz_len"], np.int64)
    bounds = np.concatenate([[0], np.cumsum(lens)])
    points = []
    for i in range(lens.shape[0]):
        lo, hi = int(bounds[i]), int(bounds[i + 1])
        points.append(
            PathPoint(
                reg=float(flat["reg"][i]),
                objective=float(flat["objective"][i]),
                l1=float(flat["l1"][i]),
                active=int(flat["active"][i]),
                iterations=int(flat["iterations"][i]),
                n_dots=int(flat["n_dots"][i]),
                seconds=float(flat["seconds"][i]),
                alpha_nnz_idx=np.asarray(flat["nnz_idx"][lo:hi], np.int64),
                alpha_nnz_val=np.asarray(flat["nnz_val"][lo:hi]),
                gap=float(flat["gap"][i]),
            )
        )
    return points


def save_path_checkpoint(
    directory,
    index: int,
    key,
    carry,
    points,
    saved_iters: int = 0,
    *,
    keep: int = 3,
) -> None:
    """Atomic snapshot at a grid-point / lane-chunk boundary.

    ``index`` is the next point (or chunk) to run; ``key`` the PRNG key
    AFTER the completed points' splits; ``carry`` the warm-start alpha
    the next point starts from."""
    key_np, typed = _key_to_np(key)
    pos = {
        "next": np.int64(index),
        "key": key_np,
        "key_typed": np.int64(typed),
        "carry": np.asarray(carry),
        "saved": np.int64(saved_iters),
    }
    ckpt_manager.save_checkpoint(
        directory, index, {POS_GROUP: pos, PATH_GROUP: pack_points(points)}
    )
    ckpt_manager.prune_checkpoints(directory, keep=keep)


def load_path_checkpoint(directory):
    """Latest valid path checkpoint, or None.

    Returns ``(next_index, key, carry, points, saved_iters)`` with
    ``key`` ready for ``jax.random.split`` and ``carry`` a jnp array.
    """
    loaded = ckpt_manager.load_latest_raw(directory)
    if loaded is None:
        return None
    _, state = loaded
    pos = state[POS_GROUP]
    key = _key_from_np(pos["key"], bool(int(pos["key_typed"])))
    carry = jnp.asarray(pos["carry"])
    points = unpack_points(state[PATH_GROUP])
    return int(pos["next"]), key, carry, points, int(pos["saved"])
