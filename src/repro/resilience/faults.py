"""Seeded, deterministic fault injection (DESIGN.md §Resilience).

The harness is a stack of :class:`FaultPlan` objects installed with the
``inject`` context manager. Production code calls the tiny hook
functions below at its fault sites; with no plan installed every hook is
a constant-time no-op, so the harness costs nothing outside tests and
the chaos CI step. With a plan installed, each hook consults the plan's
deterministic spec list — seeded byte flips, NaN/Inf state corruption,
kills, and delays all replay bit-identically for a fixed
``REPRO_FAULT_SEED`` (or an explicit ``seed=``).

Fault sites and their ``kind``:

  * ``shard_corrupt`` — ``maybe_corrupt_bytes``: flips bytes of a shard
    file read (``sparse/io.py``) so the manifest checksum catches it;
  * ``co_nan`` / ``beta_nan`` — ``maybe_corrupt_state``: poisons the
    oracle co-state / coefficient vector between fused chunks (the
    guard watchdog's trip wire, ``resilience/guards.py``);
  * ``kill`` — ``check_kill``: raises :class:`InjectedKill` at a path
    grid point / chunk boundary (``core/path.py``), exercising
    checkpoint/resume;
  * ``delay`` — ``maybe_delay``: sleeps inside a distributed dispatch
    (``distributed/driver.py``), exercising the timeout/re-dispatch
    policy.

Matching: every hook call increments a per-``(kind, site)`` occurrence
counter; a spec fires when its kind matches, its ``site`` filter matches
(empty = any), the occurrence index equals ``at`` (or ``at < 0`` = any),
and the spec has firings left (``count``, one-shot by default — which is
what lets a bounded retry heal the fault). Fired events are logged on
the plan and counted in the metrics registry (``fw_faults_injected``).

Import-light on purpose: jax/numpy + the metrics plane only — the
engine imports nothing from here, so there is no cycle with
``repro.core``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.obs import metrics as obs_metrics

KINDS = ("shard_corrupt", "co_nan", "beta_nan", "kill", "delay")

ENV_SEED = "REPRO_FAULT_SEED"


class InjectedKill(RuntimeError):
    """Raised by ``check_kill`` — simulates a preempted host mid-path."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One deterministic fault to inject.

    Attributes:
      kind: one of :data:`KINDS`.
      at: occurrence index (per ``(kind, site)`` hook-call counter) to
        fire at; ``-1`` fires on any occurrence (bounded by ``count``).
      site: site-name filter; empty matches every site of the kind
        (e.g. a shard file name for ``shard_corrupt``, ``"path_point"``
        / ``"path_chunk"`` for ``kill``).
      value: poison payload for ``co_nan`` / ``beta_nan`` (default NaN).
      count: number of firings before the spec is spent (1 = one-shot,
        the default — retries then see clean behavior and heal).
      seconds: sleep duration for ``delay``.
      n_bytes: bytes to flip for ``shard_corrupt`` (0 = size-scaled).
    """

    kind: str
    at: int = 0
    site: str = ""
    value: float = float("nan")
    count: int = 1
    seconds: float = 0.0
    n_bytes: int = 0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} (one of {KINDS})")


class FaultPlan:
    """A seeded, ordered set of faults plus the firing log."""

    def __init__(self, specs, seed: Optional[int] = None):
        if seed is None:
            seed = int(os.environ.get(ENV_SEED, "0"))
        self.seed = int(seed)
        self.rng = np.random.default_rng(self.seed)
        self.specs: List[FaultSpec] = list(specs)
        self._remaining: List[int] = [s.count for s in self.specs]
        self._seen: Dict[Tuple[str, str], int] = {}
        self.events: List[dict] = []

    def fired(self, kind: Optional[str] = None) -> List[dict]:
        """Events fired so far (optionally filtered by kind)."""
        if kind is None:
            return list(self.events)
        return [e for e in self.events if e["kind"] == kind]

    def _observe(self, kind: str, site: str) -> None:
        reg = obs_metrics.get_registry()
        if reg is not None:
            reg.counter(
                "fw_faults_injected",
                "faults injected by the resilience test harness",
                ("kind", "site"),
            ).inc(1, kind=kind, site=site or "any")

    def fire(self, kind: str, site: str) -> List[FaultSpec]:
        """Match + consume the specs firing at this hook call."""
        idx = self._seen.get((kind, site), 0)
        self._seen[(kind, site)] = idx + 1
        hits = []
        for i, spec in enumerate(self.specs):
            if spec.kind != kind or self._remaining[i] <= 0:
                continue
            if spec.site and spec.site != site:
                continue
            if spec.at >= 0 and spec.at != idx:
                continue
            self._remaining[i] -= 1
            self.events.append({"kind": kind, "site": site, "at": idx})
            self._observe(kind, site)
            hits.append(spec)
        return hits


_PLANS: List[FaultPlan] = []


def active_plan() -> Optional[FaultPlan]:
    return _PLANS[-1] if _PLANS else None


@contextlib.contextmanager
def inject(plan: FaultPlan):
    """Install ``plan`` for the dynamic extent of the with-block."""
    _PLANS.append(plan)
    try:
        yield plan
    finally:
        _PLANS.remove(plan)


# --------------------------------------------------------------------------
# Hook functions (no-ops with no active plan)
# --------------------------------------------------------------------------


def maybe_corrupt_bytes(site: str, data: bytes) -> bytes:
    """Shard-read corruption site: flip seeded byte positions of one read
    so the coo-npz-v1 manifest checksum catches the damage."""
    plan = active_plan()
    if plan is None:
        return data
    hits = plan.fire("shard_corrupt", site)
    if not hits or not data:
        return data
    buf = bytearray(data)
    for spec in hits:
        n = spec.n_bytes or max(1, len(buf) // 4096)
        pos = plan.rng.integers(0, len(buf), size=n)
        for q in pos:
            buf[q] ^= 0xFF
    return bytes(buf)


def _poison_leaf(leaf, value: float, rng) -> Any:
    """NaN/Inf one element of a floating array leaf (jax .at update)."""
    q = int(rng.integers(0, leaf.shape[0]))
    return leaf.at[q].set(value)


def maybe_corrupt_state(state, index_unused: int = 0):
    """Numerical-corruption site between fused chunks: poison one entry
    of the co-state (``co_nan``) or of beta (``beta_nan``). ``state`` is
    an ``engine.EngineState``; returns it (possibly) poisoned."""
    plan = active_plan()
    if plan is None:
        return state
    for spec in plan.fire("co_nan", "engine_state"):
        flat, treedef = jax.tree_util.tree_flatten(state.co)
        target = next(
            (
                l
                for l in flat
                if hasattr(l, "ndim") and l.ndim >= 1 and l.dtype.kind == "f"
            ),
            None,
        )
        if target is not None:
            bad = _poison_leaf(target, spec.value, plan.rng)
            flat = [bad if l is target else l for l in flat]
            state = state._replace(co=jax.tree_util.tree_unflatten(treedef, flat))
    for spec in plan.fire("beta_nan", "engine_state"):
        state = state._replace(
            beta=_poison_leaf(state.beta, spec.value, plan.rng)
        )
    return state


def check_kill(site: str, index_hint: int = 0) -> None:
    """Kill site: raise :class:`InjectedKill` when a kill spec fires.
    Hook-call occurrence order gives the grid/chunk index semantics
    (the hook runs once per grid point / chunk, in order)."""
    plan = active_plan()
    if plan is None:
        return
    if plan.fire("kill", site):
        raise InjectedKill(f"injected kill at {site}[{index_hint}]")


def maybe_delay(site: str) -> None:
    """Straggler site: sleep when a delay spec fires (the distributed
    dispatch timeout's test fixture)."""
    plan = active_plan()
    if plan is None:
        return
    for spec in plan.fire("delay", site):
        time.sleep(spec.seconds)
