"""Resilient solver runtime (DESIGN.md §Resilience).

Four layers, importable independently:

  * :mod:`repro.resilience.faults` — seeded deterministic fault
    injection (test/chaos-CI harness; no-op hooks in production);
  * :mod:`repro.resilience.guards` — the between-chunk numerical-health
    watchdog and graceful-degradation ladder (``solve_resilient``);
  * :mod:`repro.resilience.checkpoint` — atomic path checkpoint/resume
    packing for ``fw_path(..., resume_from=)``;
  * :mod:`repro.resilience.validate` — early NaN/Inf input validation
    at the solver entry points.

Submodules load lazily (PEP 562): importing ``repro.resilience.faults``
or ``.validate`` never pulls the engine, so the low-level hooks stay
cycle-free and cheap.
"""
from __future__ import annotations

_EXPORTS = {
    "FaultSpec": "faults",
    "FaultPlan": "faults",
    "InjectedKill": "faults",
    "inject": "faults",
    "active_plan": "faults",
    "GuardSpec": "guards",
    "UnrecoverableFaultError": "guards",
    "solve_resilient": "guards",
    "solve_resilient_sharded": "guards",
    "resilient_solve_fn": "guards",
    "fallback_config": "guards",
    "save_path_checkpoint": "checkpoint",
    "load_path_checkpoint": "checkpoint",
    "validate_inputs": "validate",
    "validation_enabled": "validate",
}

__all__ = sorted(_EXPORTS) + ["faults", "guards", "checkpoint", "validate"]


def __getattr__(name: str):
    import importlib

    if name in ("faults", "guards", "checkpoint", "validate"):
        return importlib.import_module(f"{__name__}.{name}")
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    return getattr(importlib.import_module(f"{__name__}.{mod}"), name)
