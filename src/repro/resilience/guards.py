"""Numerical-health watchdog + graceful-degradation ladder (DESIGN.md
§Resilience).

``solve_resilient`` is a host-driven twin of ``engine.solve``: the same
jitted loop body advances in chunks of ``GuardSpec.chunk_steps`` loop
turns (each turn = one fused K-step chunk or one rule step — exactly
``engine.run_loop``'s turn, with the §Stopping condition masked inside
the chunk so a no-fault run is bit-identical to ``engine.solve``), and
BETWEEN chunks a jitted health check inspects the state: NaN/Inf in
beta / scale / the oracle co-state, plus (opt-in) certified-gap
monotonicity within a tolerance band.

On a trip the guard walks a graceful-degradation ladder:

  1. **rebuild co-state** by exact matvec from the live alpha —
     generalizing the PARTAN drift odometer in ``core/step_rule.py``
     (``oracle.init_co(y, X @ alpha, ...)``): FW tolerates an
     approximate oracle (Kerdreux et al., 2018), so a ulp-level co
     rebuild preserves the convergence guarantee;
  2. **retry the chunk** from the pre-chunk state through the per-step
     reference executor (``engine._fused_ref_chunk`` — bit-identical to
     the megakernel by the §Perf contract), discarding the corrupt
     result entirely;
  3. **fall back a backend rung** — pallas→xla, sparse-kernel→plain
     sparse gathers — re-deriving the padded matrix and column stats
     under the degraded config, and continue there.

Every check, trip, and recovery is counted in the ``obs/metrics.py``
registry (``fw_guard_checks`` / ``fw_guard_trips{reason}`` /
``fw_guard_recoveries{rung}``); an exhausted ladder raises
:class:`UnrecoverableFaultError`.

``solve_resilient_sharded`` runs the same watchdog + ladder (rungs 1-2)
over the distributed driver's chunked shard_map programs — the co-state
is all-gathered to replicated form at chunk boundaries so the host can
inspect and heal it, and re-sliced per mesh cell on the way back in
(an exact round trip: no bit drift).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import engine, vertex
from repro.core.solver_config import FWConfig
from repro.obs import metrics as obs_metrics
from repro.resilience import faults, validate


class UnrecoverableFaultError(RuntimeError):
    """The degradation ladder ran out of rungs (or trips) — the run
    cannot be healed; the caller decides whether to restart cold."""


@dataclasses.dataclass(frozen=True)
class GuardSpec:
    """Watchdog configuration.

    Attributes:
      chunk_steps: loop turns per host dispatch (each turn advances
        ``cfg.fuse_steps`` iterations when fused, else 1) — the health
        check granularity.
      check_every: health-check every N chunks (1 = every chunk).
      gap_check_every: certified-gap monotonicity check every N chunks;
        0 (default) disables it — the gap is a full O(nnz) pass.
      gap_growth_limit: trip when the certified gap exceeds
        ``limit * running_min`` (the paper's gap decays on average;
        explosive growth means corrupt state).
      max_trips: total ladder trips tolerated before giving up.
    """

    chunk_steps: int = 8
    check_every: int = 1
    gap_check_every: int = 0
    gap_growth_limit: float = 100.0
    max_trips: int = 8


# --------------------------------------------------------------------------
# Jitted pieces (compile once per (oracle, cfg) like the engine entries)
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("oracle", "cfg"))
def _prep(oracle, Xt, y, cfg, key, alpha0=None):
    """Stats + initial state + padded hot-loop matrix — the same ops
    ``engine.solve`` runs before its while_loop, in one jitted program
    so the produced values match the engine's bit-for-bit."""
    stats = engine.precompute_colstats(Xt, y, cfg) if oracle.needs_stats else None
    state0 = engine.init_state(oracle, Xt, y, key, alpha0, cfg)
    Xt_run = vertex.pad_backend_matrix(Xt, cfg)
    return stats, state0, Xt_run


@functools.partial(jax.jit, static_argnames=("oracle", "cfg", "n_turns", "use_ref"))
def _advance(oracle, Xt_run, y, stats, state, cfg, delta, n_turns, use_ref):
    """``n_turns`` of ``engine.run_loop``'s body with the loop condition
    masked per turn — a fixed-length, resumable rendering of the same
    while_loop (identical final state; spent turns are no-ops).
    ``use_ref=True`` forces the per-step reference executor for fused
    configs (ladder rung 2)."""
    patience = engine._patience(cfg)
    fused = vertex.fused_supported(oracle, cfg)

    def turn(s):
        if fused and not use_ref:
            return engine.fused_chunk(oracle, Xt_run, y, stats, s, cfg, delta)
        if fused:
            return engine._fused_ref_chunk(oracle, Xt_run, y, stats, s, cfg, delta)
        return engine.rule_step(oracle, Xt_run, y, stats, s, cfg, delta)

    def body(_, s):
        return jax.lax.cond(
            (s.k < cfg.max_iters) & (s.stall < patience),
            turn,
            lambda st: st,
            s,
        )

    return jax.lax.fori_loop(0, n_turns, body, state)


@jax.jit
def _health_flags(state):
    """(beta_ok, co_ok, done-ish scalars) in ONE device round trip."""
    beta_ok = jnp.all(jnp.isfinite(state.beta)) & jnp.isfinite(state.scale)
    co_ok = jnp.asarray(True)
    for leaf in jax.tree_util.tree_leaves(state.co):
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating):
            co_ok = co_ok & jnp.all(jnp.isfinite(leaf))
    return beta_ok, co_ok, state.k, state.stall


@functools.partial(jax.jit, static_argnames=("oracle", "cfg"))
def _rebuild_co(oracle, Xt_run, y, state, cfg):
    """Ladder rung 1: exact-matvec co-state rebuild from the live alpha
    (the PARTAN odometer's refresh, generalized to any oracle)."""
    alpha = state.scale * state.beta
    v = vertex.matvec(Xt_run, alpha, cfg)
    co = oracle.init_co(y, v, alpha, state.beta.dtype, cfg)
    return state._replace(co=co)


@functools.partial(jax.jit, static_argnames=("oracle", "cfg"))
def _gap(oracle, Xt_run, y, state, cfg, delta):
    return engine.certified_gap(
        oracle, Xt_run, y, state.co, state.beta, state.scale, delta, cfg
    )


@functools.partial(jax.jit, static_argnames=("oracle", "cfg"))
def _finalize(oracle, Xt_run, y, stats, state, cfg, delta):
    return engine._result(
        oracle, Xt_run, y, stats, state, engine._patience(cfg), cfg, delta
    )


# --------------------------------------------------------------------------
# Ladder bookkeeping
# --------------------------------------------------------------------------


def fallback_config(cfg: FWConfig) -> Optional[FWConfig]:
    """One rung down the backend ladder, or None at the bottom:
    pallas -> xla (same math, no custom kernels); kernel-dispatched
    sparse -> plain-XLA sparse gathers. The matrix layout never changes
    (a SparseBlockMatrix stays sparse), so the state carries over."""
    if cfg.backend == "pallas":
        return dataclasses.replace(cfg, backend="xla")
    if cfg.backend == "sparse" and vertex.use_sparse_kernel(cfg):
        return dataclasses.replace(cfg, sparse_kernel=False)
    return None


def _observe(name: str, backend: str, **labels) -> None:
    reg = obs_metrics.get_registry()
    if reg is None:
        return
    helps = {
        "fw_guard_checks": "watchdog health checks between chunks",
        "fw_guard_trips": "watchdog trips by trip reason",
        "fw_guard_recoveries": "successful ladder recoveries by rung",
        "fw_guard_unrecovered": "ladder exhaustions (solve aborted)",
    }
    names = ("backend",) + tuple(sorted(labels))
    reg.counter(name, helps[name], names).inc(1, backend=backend, **labels)


def _healthy(state) -> tuple:
    beta_ok, co_ok, _, _ = _health_flags(state)
    return bool(beta_ok), bool(co_ok)


# --------------------------------------------------------------------------
# Single-device resilient solve
# --------------------------------------------------------------------------


def solve_resilient(
    oracle,
    Xt,
    y,
    cfg: FWConfig,
    key,
    alpha0=None,
    delta=None,
    *,
    guard: Optional[GuardSpec] = None,
) -> engine.SolveResult:
    """``engine.solve`` with the watchdog + degradation ladder. With no
    faults and no trips the returned SolveResult is bit-identical to
    ``engine.solve``'s (same jitted ops, same trajectory)."""
    if cfg.backend == "distributed":
        raise ValueError(
            "distributed operands go through solve_resilient_sharded"
        )
    guard = GuardSpec() if guard is None else guard
    validate.validate_inputs(Xt, y)
    vertex.check_matrix_backend(Xt, cfg)
    delta_arr = jnp.asarray(cfg.delta if delta is None else delta)
    stats, state, Xt_run = _prep(oracle, Xt, y, cfg, key, alpha0)
    live_cfg = cfg
    trips = 0
    chunk = 0
    min_gap = float("inf")

    def done(s) -> bool:
        patience = engine._patience(live_cfg)
        return bool((s.k >= live_cfg.max_iters) | (s.stall >= patience))

    while not done(state):
        prev = state
        state = _advance(
            oracle, Xt_run, y, stats, state, live_cfg, delta_arr,
            guard.chunk_steps, False,
        )
        state = faults.maybe_corrupt_state(state, chunk)
        chunk += 1
        if chunk % guard.check_every:
            continue
        _observe("fw_guard_checks", live_cfg.backend)
        beta_ok, co_ok = _healthy(state)
        reason = None
        if not (beta_ok and co_ok):
            reason = "nonfinite_beta" if not beta_ok else "nonfinite_co"
        elif guard.gap_check_every and chunk % guard.gap_check_every == 0:
            g = float(_gap(oracle, Xt_run, y, state, live_cfg, delta_arr))
            if g == g and g < min_gap:  # finite and improving
                min_gap = g
            elif g != g or (
                min_gap < float("inf")
                and g > guard.gap_growth_limit * max(abs(min_gap), 1e-30)
            ):
                reason = "gap_regression"
        if reason is None:
            continue

        # ---- the ladder -------------------------------------------------
        trips += 1
        _observe("fw_guard_trips", live_cfg.backend, reason=reason)
        if trips > guard.max_trips:
            _observe("fw_guard_unrecovered", live_cfg.backend)
            raise UnrecoverableFaultError(
                f"guard tripped {trips} times (> max_trips="
                f"{guard.max_trips}); last reason: {reason}"
            )
        recovered = False
        # rung 1: exact-matvec co rebuild (needs a finite alpha)
        if beta_ok:
            cand = _rebuild_co(oracle, Xt_run, y, state, live_cfg)
            if all(_healthy(cand)):
                state, recovered = cand, True
                min_gap = float("inf")
                _observe(
                    "fw_guard_recoveries", live_cfg.backend, rung="rebuild_co"
                )
        # rung 2: discard the chunk, retry from prev via per-step executor
        if not recovered:
            cand = _advance(
                oracle, Xt_run, y, stats, prev, live_cfg, delta_arr,
                guard.chunk_steps, True,
            )
            if all(_healthy(cand)):
                state, recovered = cand, True
                min_gap = float("inf")
                _observe(
                    "fw_guard_recoveries", live_cfg.backend, rung="retry_chunk"
                )
        # rung 3: degrade the backend and retry from prev there
        if not recovered:
            fb = fallback_config(live_cfg)
            if fb is not None:
                fb_stats, _, fb_run = _prep(oracle, Xt, y, fb, key, alpha0)
                cand = _advance(
                    oracle, fb_run, y, fb_stats, prev, fb, delta_arr,
                    guard.chunk_steps, False,
                )
                if all(_healthy(cand)):
                    _observe(
                        "fw_guard_recoveries", fb.backend,
                        rung="backend_fallback",
                    )
                    state, recovered = cand, True
                    live_cfg, stats, Xt_run = fb, fb_stats, fb_run
                    min_gap = float("inf")
        if not recovered:
            _observe("fw_guard_unrecovered", live_cfg.backend)
            raise UnrecoverableFaultError(
                f"degradation ladder exhausted (reason: {reason}, "
                f"backend: {live_cfg.backend})"
            )

    return _finalize(oracle, Xt_run, y, stats, state, live_cfg, delta_arr)


def resilient_solve_fn(guard: Optional[GuardSpec] = None):
    """A ``solve_fn`` for ``path.fw_path(..., solve_fn=...)`` that routes
    every grid point through ``solve_resilient``."""

    def fn(oracle, Xt, y, cfg, key, alpha0, delta):
        return solve_resilient(
            oracle, Xt, y, cfg, key, alpha0, delta, guard=guard
        )

    return fn


# --------------------------------------------------------------------------
# Distributed resilient solve (shard_map chunks, ladder rungs 1-2)
# --------------------------------------------------------------------------


def solve_resilient_sharded(
    oracle,
    op,
    cfg: FWConfig,
    key,
    alpha0=None,
    delta=None,
    *,
    guard: Optional[GuardSpec] = None,
) -> engine.SolveResult:
    """``distributed.driver.solve`` under the watchdog: the loop runs as
    chunked shard_map dispatches ("rchunk" mode) whose co-state comes
    back all-gathered/replicated, so the host can health-check and heal
    it between chunks exactly like the single-device guard. Ladder:
    rung 1 (co rebuild, "rrebuild" program) and rung 2 (chunk retry) —
    there is no backend rung on a mesh. Bit-identical to
    ``driver.solve`` for a no-fault run (the gather/slice round trip is
    exact and the chunked loop replays ``run_loop``'s turns)."""
    from repro.distributed import driver as ddriver  # lazy: layered on top

    guard = GuardSpec() if guard is None else guard
    validate.validate_inputs(op, op.y)
    dcfg = ddriver.dist_config(cfg, op)
    if dcfg.step_rule != "classic" or dcfg.telemetry is not None:
        raise ValueError(
            "solve_resilient_sharded supports the classic step rule with "
            "telemetry off (rule/ring state is not gathered across chunks)"
        )
    delta_arr = jnp.asarray(cfg.delta if delta is None else delta)
    mkey = (op.mesh, oracle, dcfg, op.geom)
    rinit, f0 = ddriver._traced_solver(*mkey, "rinit", alpha0 is not None, None)
    rchunk, f1 = ddriver._traced_solver(
        *mkey, "rchunk", False, guard.chunk_steps
    )
    rrebuild, _ = ddriver._traced_solver(*mkey, "rrebuild", False, None)
    rresult, _ = ddriver._traced_solver(*mkey, "rresult", False, None)

    mat = op.matrix_args
    state = ddriver._call_with_policy(
        "rinit", rinit, (*mat, op.y, key, ddriver._alpha0_arr(op, alpha0))
    )
    patience = engine._patience(dcfg)
    trips = 0
    chunk = 0

    def done(s) -> bool:
        return bool((s.k >= dcfg.max_iters) | (s.stall >= patience))

    while not done(state):
        prev = state
        state = ddriver._call_with_policy(
            "rchunk", rchunk, (*mat, op.y, state, delta_arr)
        )
        state = faults.maybe_corrupt_state(state, chunk)
        chunk += 1
        if chunk % guard.check_every:
            continue
        _observe("fw_guard_checks", "distributed")
        beta_ok, co_ok = _healthy(state)
        if beta_ok and co_ok:
            continue
        reason = "nonfinite_beta" if not beta_ok else "nonfinite_co"
        trips += 1
        _observe("fw_guard_trips", "distributed", reason=reason)
        if trips > guard.max_trips:
            _observe("fw_guard_unrecovered", "distributed")
            raise UnrecoverableFaultError(
                f"guard tripped {trips} times on the mesh (reason: {reason})"
            )
        recovered = False
        if beta_ok:
            cand = ddriver._call_with_policy(
                "rrebuild", rrebuild, (*mat, op.y, state)
            )
            if all(_healthy(cand)):
                state, recovered = cand, True
                _observe(
                    "fw_guard_recoveries", "distributed", rung="rebuild_co"
                )
        if not recovered:
            cand = ddriver._call_with_policy(
                "rchunk", rchunk, (*mat, op.y, prev, delta_arr)
            )
            if all(_healthy(cand)):
                state, recovered = cand, True
                _observe(
                    "fw_guard_recoveries", "distributed", rung="retry_chunk"
                )
        if not recovered:
            _observe("fw_guard_unrecovered", "distributed")
            raise UnrecoverableFaultError(
                f"mesh ladder exhausted (reason: {reason}) — no backend "
                "rung exists under shard_map"
            )

    return ddriver._call_with_policy(
        "rresult", rresult, (*mat, op.y, state, delta_arr)
    )
