"""Early input validation at the solver entry points (DESIGN.md
§Resilience).

A NaN or Inf anywhere in the design matrix or targets turns a solve
into a silent non-converging run: every sampled score goes NaN, the
argmax picks garbage, and the stall counter never fires. The engine
entry points (``engine.solve`` / ``solve_with_history`` /
``solve_batched`` via their ``_MetricsEntry`` host shims) and the
distributed driver entries call :func:`validate_inputs` BEFORE
dispatching, so bad data raises a clear ``ValueError`` naming the
offending operand and its NaN/Inf counts instead of burning a full
``max_iters`` run.

Cost: one ``isfinite`` reduction per operand per entry call — O(nnz),
negligible next to a solve. A tiny identity cache (the last few
validated array objects) makes a 100-point regularization path pay the
check once, not per grid point. ``REPRO_SKIP_INPUT_VALIDATION=1``
disables the check entirely (e.g. deliberately-censored data flows).
"""
from __future__ import annotations

import os
from collections import deque
from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from repro.sparse.matrix import SparseBlockMatrix

ENV_SKIP = "REPRO_SKIP_INPUT_VALIDATION"

# identity cache of recently-validated operands: a path driver passes the
# SAME Xt/y objects for every grid point, so the O(nnz) pass runs once.
# Bounded (strong refs pin at most this many arrays).
_RECENT: deque = deque(maxlen=8)


def validation_enabled() -> bool:
    return os.environ.get(ENV_SKIP, "0") not in ("1", "true")


def _named_arrays(Xt, y) -> Dict[str, object]:
    arrays: Dict[str, object] = {}
    if Xt is not None:
        if hasattr(Xt, "matrix_args"):  # distributed ShardedOperand
            for i, a in enumerate(Xt.matrix_args):
                arrays[f"X.shard[{i}]"] = a
        elif isinstance(Xt, SparseBlockMatrix):
            arrays["X.values"] = Xt.values
        else:
            arrays["X"] = Xt
    if y is not None:
        arrays["y"] = y
    return arrays


def _nonfinite(a) -> Optional[Tuple[int, int]]:
    """(n_nan, n_inf) when the array has non-finite entries, else None."""
    a = jnp.asarray(a)
    if not jnp.issubdtype(a.dtype, jnp.floating):
        return None
    if bool(jnp.all(jnp.isfinite(a))):
        return None
    return int(jnp.isnan(a).sum()), int(jnp.isinf(a).sum())


def validate_inputs(Xt, y=None) -> None:
    """Raise ``ValueError`` if the design matrix or targets contain
    NaN/Inf. ``Xt`` may be a dense feature-major array, a
    ``SparseBlockMatrix``, a distributed ``ShardedOperand`` (its stored
    shard arrays are checked), or None."""
    if not validation_enabled():
        return
    arrays = _named_arrays(Xt, y)
    todo = {
        name: a
        for name, a in arrays.items()
        if a is not None and not any(a is seen for seen in _RECENT)
    }
    if not todo:
        return
    bad = {}
    for name, a in todo.items():
        counts = _nonfinite(a)
        if counts is not None:
            bad[name] = counts
    if bad:
        detail = ", ".join(
            f"{name}: {n_nan} NaN / {n_inf} Inf" for name, (n_nan, n_inf) in bad.items()
        )
        raise ValueError(
            f"non-finite values in solver inputs ({detail}) — the solver "
            "would run to max_iters without converging; clean or impute "
            f"the data, or set {ENV_SKIP}=1 to skip this check"
        )
    for a in todo.values():
        _RECENT.append(a)
