"""kimi-k2-1t-a32b [moe] — trillion-param MoE (arXiv:2501.kimi2).

61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840; MoE 384 experts
top-8 with 1 shared expert; first layer dense (DeepSeek-V3-style).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=18432,  # dense first layer FFN (DSv3-style wide dense layer)
    vocab_size=163840,
    rope_theta=5e4,
    n_experts=384,
    experts_per_token=8,
    moe_d_ff=2048,
    n_shared_experts=1,
    first_k_dense=1,
    optimizer="adafactor",
)
