"""internvl2-76b [vlm] — InternViT + LLM backbone (arXiv:2404.16821).

Backbone: 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
The ViT frontend is a STUB: input_specs() provides 256 precomputed patch
embeddings per image, projected and prepended to the token sequence.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=1e6,
    n_prefix_embeds=256,
    optimizer="adafactor",
)
