"""gemma2-9b [dense] — local/global alternation + softcaps (arXiv:2408.00118).

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000; head_dim=256,
sliding_window=4096 on local layers, attn softcap 50, logit softcap 30,
sandwich norms, GeGLU, tied embeddings.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    rope_theta=1e4,
    sliding_window=4096,
    layer_pattern=("local", "global"),
    attn_softcap=50.0,
    logit_softcap=30.0,
    sandwich_norm=True,
    act="gelu_tanh",
    tie_embeddings=True,
    optimizer="adamw",
)
