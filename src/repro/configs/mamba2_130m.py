"""mamba2-130m [ssm] — SSD, attention-free (arXiv:2405.21060).

24L d_model=768, d_ff=0 (pure Mamba blocks, no MLP), vocab=50280,
ssm_state=128, expand=2 -> d_inner=1536, head_dim=64 -> 24 SSD heads.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    tie_embeddings=True,
    optimizer="adamw",
)
