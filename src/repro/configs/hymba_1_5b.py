"""hymba-1.5b [hybrid] — parallel attention + mamba heads (arXiv:2411.13676).

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16;
sliding-window attention everywhere except global layers {0, 16, 31}.
head_dim=64 (25 x 64 = 1600).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    rope_theta=1e4,
    sliding_window=1024,
    global_layer_indices=(0, 16, 31),
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    optimizer="adamw",
)
