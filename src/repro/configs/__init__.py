"""Assigned-architecture registry: --arch <id> selects one of these."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS: List[str] = [
    "mamba2_130m",
    "internlm2_20b",
    "deepseek_7b",
    "gemma2_9b",
    "qwen2_72b",
    "internvl2_76b",
    "arctic_480b",
    "kimi_k2_1t_a32b",
    "hymba_1_5b",
    "seamless_m4t_medium",
]

_ALIAS = {i.replace("_", "-"): i for i in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    arch = _ALIAS.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
