"""seamless-m4t-medium [audio] — enc-dec backbone (arXiv:2308.11596).

12L decoder + 12L encoder, d_model=1024 16H (kv=16) d_ff=4096
vocab=256206. The speech frontend is a STUB: input_specs() provides
precomputed frame embeddings (B, S_enc, d_model) for the encoder.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    rope_theta=1e4,
    n_enc_layers=12,
    cross_attention=True,
    optimizer="adamw",
)
