"""Distributed FW subsystem (DESIGN.md §Distributed): mesh-sharded
sparse/dense design matrices + a shard-aware 'distributed' backend that
runs the SAME engine hot loop — every oracle, both path drivers, lane
pruning and all — under one shard_map over a (data, model) mesh.

    mesh = distributed.fw_mesh(n_data=2, n_model=4)
    op = distributed.shard_sparse(mat, y, mesh)   # or shard_dense /
                                                  # load_sharded_matrix
    res = distributed.solve(LASSO, op, cfg, key)

Supersedes the dense-only, lasso-only shard_map loop that used to live
in ``repro.core.distributed`` (the deprecation shim is retired; import
from here).
"""
from repro.distributed import backend, driver, shard
from repro.distributed.driver import (
    certified_gap,
    dist_config,
    fw_path,
    fw_path_batched,
    solve,
    solve_batched,
    solve_with_history,
)
from repro.distributed.shard import (
    ShardedOperand,
    fw_mesh,
    load_sharded_matrix,
    mesh_spec,
    shard_dense,
    shard_sparse,
)

__all__ = [
    "ShardedOperand",
    "backend",
    "certified_gap",
    "dist_config",
    "driver",
    "fw_mesh",
    "fw_path",
    "fw_path_batched",
    "load_sharded_matrix",
    "mesh_spec",
    "shard",
    "shard_dense",
    "shard_sparse",
    "solve",
    "solve_batched",
    "solve_with_history",
]
