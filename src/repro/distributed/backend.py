"""Distributed implementations of the engine's vertex / colstats / update
contract (DESIGN.md §Distributed).

These are the collectives behind ``FWConfig(backend='distributed')``:
``core.vertex`` dispatches here (lazily — this package layers ABOVE the
core) when the engine step runs inside the shard_map built by
``repro.distributed.driver``. Everything in this module assumes the
sharding vocabulary of ``DistSpec`` / ``repro.distributed.shard``:

    matrix   feature blocks over ``model_axis``, samples over ``data_axis``
             (a dense (p_local, m_local) tile, or a local SparseBlockMatrix
             whose ELL rows are LOCAL sample indices);
    w, v, y  per-"data"-slice (m_local,) vectors, replicated over "model";
    beta,    REPLICATED length-p vectors (O(p) per host is ~17 MB at the
    stats    paper's p = 4.2M — the O(nnz)/O(p*m) matrix is what sharding
             must split);
    scalars  replicated (every shard computes the same line search).

Per-iteration communication budget (the scalability story at cluster
scale): ONE psum of the |S| sampled partial scores over BOTH axes
(completes the gradient coordinates AND zero-fills non-owners, so the
argmax runs on a replicated score vector — same tie-breaking as the
single-device engine, which is what makes uniform-sampling trajectories
bit-identical on a 1-data-shard mesh), one psum of the winning column's
(m_local,) slice over "model", and the O(1) scalar psums of the oracle
recursions. Everything else is local O(kappa * nnz) work.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import vertex
from repro.core.solver_config import FWConfig
from repro.obs import trace as obs_trace
from repro.sparse import ops as sparse_ops
from repro.sparse.matrix import SparseBlockMatrix


def _count_collective(name: str):
    """Trace-time collective counter: these functions run inside jit /
    shard_map, so the counter fires once per collective SITE per compiled
    program (NOT per executed iteration — XLA replays the compiled loop
    without re-entering Python). That is exactly the comm-structure
    audit a trace wants: how many psum/all_gather sites each program
    carries, keyed by which primitive."""
    obs_trace.get_tracer().counter(f"dist/collectives/{name}", 1)


def _spec(cfg: FWConfig):
    spec = vertex.dist_spec(cfg)
    if spec is None:
        raise ValueError("distributed backend ops need cfg.backend='distributed'")
    return spec


def _both_axes(spec):
    return (spec.data_axis, spec.model_axis)


def feature_range(Xt_l, spec):
    """(offset, p_local) of this shard's global feature range. The local
    feature count is PADDED (whole blocks / equal tiles), so the mapping
    global = offset + local holds uniformly across shards."""
    if isinstance(Xt_l, SparseBlockMatrix):
        p_loc = Xt_l.p_padded
    else:
        p_loc = Xt_l.shape[0]
    mo = jax.lax.axis_index(spec.model_axis)
    return mo * p_loc, p_loc


# --------------------------------------------------------------------------
# Sampled-vertex selection
# --------------------------------------------------------------------------


def _local_scores(Xt_l, w_l, idx, off, p_loc):
    """Masked local partial scores for GLOBAL sampled coordinates ``idx``:
    the owner shard contributes its partial -z_i^T w over its sample
    slice, everyone else exact zeros (so the completing psum is also the
    owner selection)."""
    own = (idx >= off) & (idx < off + p_loc)
    loc = jnp.clip(idx - off, 0, p_loc - 1)
    if isinstance(Xt_l, SparseBlockMatrix):
        raw = sparse_ops.sparse_gather_scores(Xt_l, w_l, loc)
    else:
        rows = jnp.take(Xt_l, loc, axis=0)  # (|S|, m_local)
        raw = -(rows @ w_l)
    return jnp.where(own, raw, 0.0)


def dist_sample_vertex(
    Xt_l, w_l: jax.Array, key: jax.Array, p: int, cfg: FWConfig, extra_fn=None
):
    """Distributed twin of ``vertex.sample_vertex``: global index stream
    (a pure function of the replicated key — bit-identical to the
    single-device draw), masked local partial scores, ONE psum over
    (data, model) to complete + replicate them, then a replicated argmax.

    Returns the engine contract ``(i_star, g_raw, g_sel, n_scored)`` with
    every output replicated across the mesh.
    """
    spec = _spec(cfg)
    _count_collective("score_psum")
    off, p_loc = feature_range(Xt_l, spec)
    is_sparse = isinstance(Xt_l, SparseBlockMatrix)

    if cfg.sampling == "block" and is_sparse:
        # aligned global blocks (the shared draw — same stream as the
        # single-device sparse backend), scored through the block-ELL
        # kernel path
        bs = Xt_l.block_size
        blk = vertex.sample_blocks(key, -(-p // bs), bs, cfg)
        nb_req = blk.shape[0]
        nb_loc = p_loc // bs
        mo = jax.lax.axis_index(spec.model_axis)
        own_blk = (blk >= mo * nb_loc) & (blk < (mo + 1) * nb_loc)
        loc_blk = jnp.clip(blk - mo * nb_loc, 0, nb_loc - 1)
        scores_l = sparse_ops.sparse_block_scores(
            Xt_l,
            w_l,
            loc_blk,
            use_kernel=vertex.use_sparse_kernel(cfg),
            interpret=vertex.use_interpret(cfg),
            gather_mode=vertex.resolve_gather_mode(cfg),
        ).reshape(nb_req, bs)
        raw = jax.lax.psum(
            jnp.where(own_blk[:, None], scores_l, 0.0), _both_axes(spec)
        ).reshape(-1)
        idx = (blk[:, None] * bs + jnp.arange(bs)[None, :]).reshape(-1)
        n_scored = nb_req * bs
    else:
        # 'uniform' / 'full' (and dense 'block', whose XLA index stream is
        # already a flat wrapped-gather): global indices, width-1 gathers
        idx = vertex.sample_indices(key, p, cfg)
        raw = jax.lax.psum(
            _local_scores(Xt_l, w_l, idx, off, p_loc), _both_axes(spec)
        )
        n_scored = idx.shape[0]

    sel = raw if extra_fn is None else raw + extra_fn(idx)
    mag = jnp.where(idx < p, jnp.abs(sel), -1.0)
    j = jnp.argmax(mag)
    dtype = Xt_l.dtype
    if is_sparse:
        # the sparse single-device path casts f32 scores to storage dtype
        return idx[j], raw[j].astype(dtype), sel[j].astype(dtype), n_scored
    return idx[j], raw[j], sel[j], n_scored


def dist_score_indices(Xt_l, w_l: jax.Array, idx: jax.Array, cfg: FWConfig):
    """Distributed twin of ``vertex.score_indices``: the step rules'
    re-scoring pass over caller-chosen coordinates (the away/pairwise
    active-set buffer, the lazy-LMO winner cache). Same masked-owner
    partial scores as the sampled draw, ONE psum over BOTH axes to
    complete the gradient coordinates and replicate them — this is the
    score psum extended to the away candidates, so every step rule runs
    under ``backend='distributed'`` with replicated selections."""
    spec = _spec(cfg)
    _count_collective("rescore_psum")
    off, p_loc = feature_range(Xt_l, spec)
    raw = jax.lax.psum(
        _local_scores(Xt_l, w_l, idx, off, p_loc), _both_axes(spec)
    )
    if isinstance(Xt_l, SparseBlockMatrix):
        # the sparse single-device path hands back storage-dtype scores
        raw = raw.astype(Xt_l.dtype)
    return raw


# --------------------------------------------------------------------------
# Winning-column broadcast + eq. 10 update
# --------------------------------------------------------------------------


def _owned_column(Xt_l, i_star, spec):
    """This shard's contribution to the winning column's LOCAL sample
    slice: the owner materializes it (dense slice or sparse scatter of
    the ELL slots), everyone else exact zeros. The psum over "model" is
    the winning-column broadcast."""
    off, p_loc = feature_range(Xt_l, spec)
    own = (i_star >= off) & (i_star < off + p_loc)
    loc = jnp.clip(i_star - off, 0, p_loc - 1)
    if isinstance(Xt_l, SparseBlockMatrix):
        vals, rows = sparse_ops.sparse_column(Xt_l, loc)
        z = jnp.zeros((Xt_l.m,), Xt_l.dtype)
        z = z.at[rows].add(jnp.where(own, vals.astype(Xt_l.dtype), 0.0))
    else:
        z = jnp.where(
            own, jax.lax.dynamic_slice_in_dim(Xt_l, loc, 1, axis=0)[0], 0.0
        )
    return jax.lax.psum(z, spec.model_axis)


def dist_column_update(Xt_l, v_l, y_l, i_star, lam, delta_t, cfg: FWConfig):
    """v <- (1-lam) v + lam (y - delta_t z_star) on the local "data" slice
    (eq. 10 / margin recursion), winning column broadcast as a masked
    psum over "model" — in the sparse layout the owner's contribution is
    an O(nnz_max) scatter of the PRE-SCALED slot values, so the broadcast
    carries one (m_local,) vector regardless of p.

    Both branches replay the exact op sequence of their single-device
    twin (``sparse_ops.sparse_residual_update`` / the dense jnp
    expression): the psum only ever adds exact zeros from non-owners, so
    a 1-data-shard mesh stays bit-identical to one device.
    """
    spec = _spec(cfg)
    _count_collective("column_broadcast")
    if isinstance(Xt_l, SparseBlockMatrix):
        off, p_loc = feature_range(Xt_l, spec)
        own = (i_star >= off) & (i_star < off + p_loc)
        loc = jnp.clip(i_star - off, 0, p_loc - 1)
        vals, rows = sparse_ops.sparse_column(Xt_l, loc)
        out = (1.0 - lam) * v_l + lam * y_l
        contrib = jnp.zeros_like(v_l).at[rows].add(
            (-lam * delta_t) * jnp.where(own, vals.astype(v_l.dtype), 0.0)
        )
        return out + jax.lax.psum(contrib, spec.model_axis)
    z = _owned_column(Xt_l, i_star, spec)
    return (1.0 - lam) * v_l + lam * (y_l - delta_t * z)


def dist_column_dense(Xt_l, i_star, cfg: FWConfig) -> jax.Array:
    """Local (m_local,) slice of the dense winning column (the logistic
    bisection's direction vector)."""
    _count_collective("column_broadcast")
    return _owned_column(Xt_l, i_star, _spec(cfg))


# --------------------------------------------------------------------------
# Column statistics, matvec, full gradient (setup / certification passes)
# --------------------------------------------------------------------------


def _gather_model(x_l, spec):
    """Concatenate per-shard feature vectors into the replicated global
    (padded) feature axis, ordered by model-shard index."""
    return jax.lax.all_gather(x_l, spec.model_axis, tiled=True)


def dist_colstats(Xt_l, y_l: jax.Array, cfg: FWConfig, p: int):
    """(zty, znorm2, yty) replicated at the TRUE global p: local sweeps
    over the shard's features, psum over "data" to complete the sample
    axis, all_gather over "model" to assemble the feature axis. One-time
    setup pass (§4.2) — O(nnz_local) compute, O(p) comm, once per solve."""
    spec = _spec(cfg)
    _count_collective("colstats_gather")
    if isinstance(Xt_l, SparseBlockMatrix):
        vals = Xt_l.values.astype(jnp.float32)
        gathered = jnp.take(y_l.astype(jnp.float32), Xt_l.rows, axis=0)
        zty_l = jnp.sum(vals * gathered, axis=2).reshape(-1)  # (p_local,)
        zn2_l = jnp.sum(vals * vals, axis=2).reshape(-1)
        dtype = Xt_l.dtype
    else:
        zty_l = Xt_l @ y_l
        # same fused einsum as the single-device precompute_colstats — the
        # bit-identity contract needs identical per-shard rounding (and it
        # skips the O(p_local * m_local) squared temporary)
        zn2_l = jnp.einsum("pm,pm->p", Xt_l, Xt_l)
        dtype = Xt_l.dtype
    zty_l = jax.lax.psum(zty_l, spec.data_axis)
    zn2_l = jax.lax.psum(zn2_l, spec.data_axis)
    zty = _gather_model(zty_l, spec)[:p].astype(dtype)
    znorm2 = _gather_model(zn2_l, spec)[:p].astype(dtype)
    yty = jax.lax.psum(jnp.dot(y_l, y_l), spec.data_axis)
    return zty, znorm2, yty


def _beta_slice(beta: jax.Array, off, p_loc: int, p: int):
    """This shard's slice of the replicated beta, zero-padded past the
    true p (gather with clipped indices + mask — dynamic_slice would
    clamp the start and misalign the last shard)."""
    gidx = off + jnp.arange(p_loc)
    vals = jnp.take(beta, jnp.clip(gidx, 0, p - 1))
    return jnp.where(gidx < p, vals, 0.0)


def dist_matvec(Xt_l, beta: jax.Array, cfg: FWConfig) -> jax.Array:
    """Local (m_local,) slice of X alpha from the replicated beta —
    warm-start initialization. psum over "model" completes the feature
    sum."""
    spec = _spec(cfg)
    _count_collective("matvec_psum")
    off, p_loc = feature_range(Xt_l, spec)
    b_l = _beta_slice(beta, off, p_loc, beta.shape[0]).astype(Xt_l.dtype)
    if isinstance(Xt_l, SparseBlockMatrix):
        v_l = sparse_ops.sparse_matvec(Xt_l, b_l)
    else:
        v_l = b_l @ Xt_l
    return jax.lax.psum(v_l, spec.model_axis)


def dist_grad_full(Xt_l, w_l: jax.Array, cfg: FWConfig) -> jax.Array:
    """Replicated full linear gradient -X^T w over the PADDED feature
    axis (callers slice [:p]) — the certification pass behind the oracle
    ``gap()`` protocol. O(nnz_local) compute + one O(p) all_gather."""
    spec = _spec(cfg)
    _count_collective("grad_gather")
    if isinstance(Xt_l, SparseBlockMatrix):
        vals = Xt_l.values.astype(jnp.float32)
        gathered = jnp.take(w_l.astype(jnp.float32), Xt_l.rows, axis=0)
        g_l = -jnp.sum(vals * gathered, axis=2).reshape(-1)
        g_l = jax.lax.psum(g_l, spec.data_axis).astype(Xt_l.dtype)
    else:
        g_l = jax.lax.psum(-(Xt_l @ w_l), spec.data_axis)
    return _gather_model(g_l, spec)
