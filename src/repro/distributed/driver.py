"""Mesh-sharded FW solve drivers (DESIGN.md §Distributed).

ONE shard_map wraps the SAME engine hot loop that serves the
single-device backends: ``engine.step`` runs verbatim per mesh cell with
``cfg.backend='distributed'``, so every oracle (lasso / logistic /
elastic-net), the lane-pruned batched driver, and both regularization-
path protocols scale to the mesh without a distributed fork of the
iteration. The only distributed-specific code is (a) the per-shard
operand reconstruction, (b) the setup collectives (colstats, warm-start
matvec), and (c) the drivers' entry/exit plumbing — the collectives
inside the step live in ``repro.distributed.backend`` behind the
``core.vertex`` dispatch.

Solvers compile once per (mesh, oracle, cfg, geometry, mode): ``delta``
stays a traced argument, so a whole regularization path — sequential or
lane-pruned batched — reuses one compiled program, exactly like the
single-device drivers (§Perf).
"""
from __future__ import annotations

import concurrent.futures
import contextlib
import dataclasses
import functools
import time
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import engine, path as path_lib, vertex
from repro.core.engine import ColStats
from repro.core.solver_config import FWConfig
from repro.distributed import backend as dbackend
from repro.distributed.shard import ShardedOperand
from repro.obs import metrics as obs_metrics
from repro.obs import telemetry as obs_telemetry
from repro.obs import trace as obs_trace
from repro.resilience import faults, validate as _validate
from repro.sparse.matrix import SparseBlockMatrix


_warned_fuse_steps = False


def dist_config(cfg: FWConfig, op: ShardedOperand) -> FWConfig:
    """The static config the engine step sees inside the shard_map: the
    distributed backend plus the operand's mesh vocabulary. The caller's
    ``backend`` field is irrelevant here — the operand layout decides.

    ``fuse_steps`` is forced to 1: the fused chunk (DESIGN.md §Perf) is
    single-device-only for now — a per-shard chunk would have to carry
    the score psum and the winning-column broadcast INSIDE the kernel
    (K collective rounds per launch), which is a follow-on (ROADMAP).
    The override is no longer silent: a one-time warning fires, and the
    effective value is surfaced on ``SolveResult.effective_fuse_steps``
    so callers can tell what actually ran."""
    global _warned_fuse_steps
    if cfg.fuse_steps != 1 and not _warned_fuse_steps:
        _warned_fuse_steps = True
        warnings.warn(
            f"distributed driver forces fuse_steps=1 (requested "
            f"{cfg.fuse_steps}): the fused multi-step chunk is "
            "single-device-only; see SolveResult.effective_fuse_steps "
            "for what actually ran",
            stacklevel=3,
        )
    return dataclasses.replace(
        cfg, backend="distributed", dist=op.spec, fuse_steps=1
    )


def _local_matrix(geom, mat_args):
    """Rebuild this cell's matrix view from the shard_map-local leaves."""
    layout, p, m, m_local, p_local, bs, nnz, nb_loc = geom
    if layout == "dense":
        return mat_args[0]
    values_l, rows_l = mat_args
    return SparseBlockMatrix(
        values=values_l[0],
        rows=rows_l[0],
        p=p_local,  # padded local range; global-p masking is the backend's
        m=m_local,
        block_size=bs,
        nnz_max=nnz,
    )


@functools.lru_cache(maxsize=64)
def _solver(mesh, oracle, cfg: FWConfig, geom, mode: str, warm: bool,
            n_iters: Optional[int]):
    """Build + jit the shard_map-wrapped driver for one static key."""
    spec = cfg.dist
    layout, p, m, m_local, p_local, bs, nnz, nb_loc = geom
    da, mo = spec.data_axis, spec.model_axis
    if layout == "dense":
        mat_specs = (P(mo, da),)
    else:
        mat_specs = (P(da, mo, None, None), P(da, mo, None, None))
    patience = engine._patience(cfg)

    def _prep(mat_args, y_l):
        Xt_l = _local_matrix(geom, mat_args)
        stats = (
            ColStats(*dbackend.dist_colstats(Xt_l, y_l, cfg, p))
            if oracle.needs_stats
            else None
        )
        return Xt_l, stats

    def _init(Xt_l, y_l, key, alpha0):
        return engine.init_state(
            oracle, Xt_l, y_l, key, alpha0 if warm else None, cfg, p
        )

    if mode == "solve":

        def body(*args):
            *mat_args, y_l, key, alpha0, delta = args
            Xt_l, stats = _prep(mat_args, y_l)
            state0 = _init(Xt_l, y_l, key, alpha0)
            final = engine.run_loop(
                oracle, Xt_l, y_l, stats, state0, cfg, delta, patience
            )
            return engine._result(
                oracle, Xt_l, y_l, stats, final, patience, cfg, delta
            )

    elif mode == "history":

        def body(*args):
            *mat_args, y_l, key, alpha0 = args
            Xt_l, stats = _prep(mat_args, y_l)
            state0 = _init(Xt_l, y_l, key, alpha0)
            # ring-based history (DESIGN.md §Observability): cfg already
            # carries max_iters=n_iters + a capacity-n_iters ring (see
            # solve_with_history below), and history_patience never
            # stops early — the SAME run_loop as mode="solve" replays
            # the old fixed-length scan's exact step sequence
            final = engine.run_loop(
                oracle, Xt_l, y_l, stats, state0, cfg,
                jnp.asarray(cfg.delta), engine.history_patience(n_iters),
            )
            res = engine._result(
                oracle, Xt_l, y_l, stats, final, patience, cfg,
                jnp.asarray(cfg.delta),
            )
            return res, final.tel.objective[:n_iters]

    elif mode == "batched":

        def body(*args):
            *mat_args, y_l, keys, alpha0s, deltas = args
            Xt_l, stats = _prep(mat_args, y_l)
            states0 = jax.vmap(lambda k, a0: _init(Xt_l, y_l, k, a0))(
                keys, alpha0s
            )
            final, saved = engine.batched_loop(
                oracle, Xt_l, y_l, stats, states0, cfg, deltas, patience
            )
            res = engine.batched_result(
                oracle, Xt_l, y_l, stats, final, patience, cfg, deltas
            )
            return res, saved

    elif mode in ("rinit", "rchunk", "rrebuild", "rresult"):
        # Resilient chunked executor programs (resilience/guards.py):
        # the solve loop is driven from the HOST in chunks so a watchdog
        # can inspect and heal the state between dispatches. The state
        # crosses the shard_map boundary with its data-sharded co leaves
        # all-gathered to replicated global form ("gather out") and
        # re-sliced to the local rows on the way back in ("scatter in")
        # — an exact round trip, so chunked == monolithic bit-for-bit.
        n_data = mesh.shape[da]

        def _gather_state(state):
            def g(leaf):
                if getattr(leaf, "ndim", 0) >= 1 and leaf.shape[0] == m_local:
                    return jax.lax.all_gather(leaf, da, tiled=True)
                return leaf

            return state._replace(co=jax.tree_util.tree_map(g, state.co))

        def _scatter_state(state):
            def s(leaf):
                if (
                    getattr(leaf, "ndim", 0) >= 1
                    and leaf.shape[0] == m_local * n_data
                ):
                    i = jax.lax.axis_index(da)
                    return jax.lax.dynamic_slice_in_dim(
                        leaf, i * m_local, m_local
                    )
                return leaf

            return state._replace(
                co=jax.tree_util.tree_map(s, state.co)
            )

        if mode == "rinit":

            def body(*args):
                *mat_args, y_l, key, alpha0 = args
                Xt_l, _ = _prep(mat_args, y_l)
                return _gather_state(_init(Xt_l, y_l, key, alpha0))

        elif mode == "rchunk":
            n_turns = n_iters  # loop turns per dispatch, not iterations

            def body(*args):
                *mat_args, y_l, state, delta = args
                Xt_l, stats = _prep(mat_args, y_l)
                state = _scatter_state(state)

                def turn(s):
                    return engine.rule_step(
                        oracle, Xt_l, y_l, stats, s, cfg, delta
                    )

                def fbody(_, s):
                    return jax.lax.cond(
                        (s.k < cfg.max_iters) & (s.stall < patience),
                        turn,
                        lambda st: st,
                        s,
                    )

                state = jax.lax.fori_loop(0, n_turns, fbody, state)
                return _gather_state(state)

        elif mode == "rrebuild":

            def body(*args):
                *mat_args, y_l, state = args
                Xt_l, _ = _prep(mat_args, y_l)
                state = _scatter_state(state)
                alpha = state.scale * state.beta
                v = vertex.matvec(Xt_l, alpha, cfg)
                co = oracle.init_co(y_l, v, alpha, state.beta.dtype, cfg)
                return _gather_state(state._replace(co=co))

        else:  # rresult

            def body(*args):
                *mat_args, y_l, state, delta = args
                Xt_l, stats = _prep(mat_args, y_l)
                state = _scatter_state(state)
                return engine._result(
                    oracle, Xt_l, y_l, stats, state, patience, cfg, delta
                )

    else:  # pragma: no cover - internal
        raise ValueError(f"unknown driver mode {mode!r}")

    n_extra = {
        "solve": 4,       # y, key, alpha0, delta
        "history": 3,     # y, key, alpha0
        "batched": 4,     # y, keys, alpha0s, deltas
        "rinit": 3,       # y, key, alpha0
        "rchunk": 3,      # y, state, delta
        "rrebuild": 2,    # y, state
        "rresult": 3,     # y, state, delta
    }[mode]
    n_operands = len(mat_specs) + n_extra
    in_specs = mat_specs + (P(da),) + (P(),) * (n_operands - len(mat_specs) - 1)
    mapped = shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=P(), check_rep=False
    )
    return jax.jit(mapped)


def _traced_solver(*key):
    """``_solver`` plus compile detection for the dispatch spans: returns
    ``(fn, fresh)`` where ``fresh`` flags a new static key — the next
    call pays trace + XLA compile, and the span that wraps it should say
    so instead of letting a 100x first-call duration read as a collective
    regression."""
    before = _solver.cache_info().misses
    fn = _solver(*key)
    return fn, _solver.cache_info().misses > before


def _alpha0_arr(op: ShardedOperand, alpha0):
    if alpha0 is None:
        return jnp.zeros((op.p,), op.dtype)
    return jnp.asarray(alpha0, op.dtype)


class DispatchTimeoutError(RuntimeError):
    """A shard_map dispatch exceeded the active ``dispatch_policy``
    timeout on every allowed attempt."""


@dataclasses.dataclass(frozen=True)
class DispatchPolicy:
    timeout_s: float
    retries: int = 1


_policy: Optional[DispatchPolicy] = None


@contextlib.contextmanager
def dispatch_policy(timeout_s: float, retries: int = 1):
    """Bound every distributed dispatch in the with-block to
    ``timeout_s`` wall seconds, re-dispatching up to ``retries`` times
    before raising :class:`DispatchTimeoutError` (DESIGN.md
    §Resilience). Each attempt runs the dispatch to completion
    (``block_until_ready``) on a worker thread; a timed-out attempt's
    thread cannot be cancelled — it is abandoned (XLA has no dispatch
    cancellation) — so this is a straggler detector, not a reaper.
    Re-dispatches are counted as ``fw_dist_redispatches`` in the
    metrics registry."""
    global _policy
    prev = _policy
    _policy = DispatchPolicy(float(timeout_s), int(retries))
    try:
        yield
    finally:
        _policy = prev


def _call_with_policy(entry: str, fn, args):
    """Run one dispatch under the active timeout policy (pass-through
    when none is installed). The injected-delay fault site lives inside
    the attempt, so a one-shot delay spec stalls the first attempt only
    and the re-dispatch lands clean."""
    pol = _policy

    def _attempt():
        faults.maybe_delay("dist_dispatch")
        out = fn(*args)
        if pol is not None:
            jax.block_until_ready(out)
        return out

    if pol is None:
        return _attempt()
    reg = obs_metrics.get_registry()
    for attempt in range(pol.retries + 1):
        ex = concurrent.futures.ThreadPoolExecutor(max_workers=1)
        fut = ex.submit(_attempt)
        try:
            return fut.result(timeout=pol.timeout_s)
        except concurrent.futures.TimeoutError:
            if reg is not None:
                reg.counter(
                    "fw_dist_redispatches",
                    "distributed dispatch attempts abandoned after the "
                    "dispatch_policy timeout",
                    ("entry",),
                ).inc(1, entry=entry)
        finally:
            ex.shutdown(wait=False, cancel_futures=True)
    raise DispatchTimeoutError(
        f"dist/{entry} exceeded {pol.timeout_s}s on "
        f"{pol.retries + 1} attempt(s)"
    )


def _dispatch(entry: str, fresh: bool, dcfg: FWConfig, fn, args, **span_kw):
    """Run one shard_map dispatch under its tracer span and — only when a
    metrics registry is installed — time it to completion and fold
    dispatch latency, program-freshness counters, per-lane solve totals,
    and the tracer's trace-time collective counters into the registry.
    Registry-off is a straight pass-through: no block_until_ready, no
    extra host sync (same contract as ``engine._MetricsEntry``)."""
    reg = obs_metrics.get_registry()
    tracer = obs_trace.get_tracer()
    t0 = time.perf_counter()
    with tracer.span(f"dist/{entry}", cat="dist", new_program=fresh,
                     **span_kw):
        out = _call_with_policy(entry, fn, args)
        if reg is not None:
            jax.block_until_ready(out)
    if reg is not None:
        elapsed = time.perf_counter() - t0
        # solve returns a bare SolveResult; history/batched return
        # (SolveResult, extra) — and SolveResult is itself a tuple
        res = out if isinstance(out, engine.SolveResult) else out[0]
        reg.counter(
            "fw_dist_dispatches",
            "distributed shard_map dispatches by program freshness "
            "('fresh' paid trace + XLA compile)",
            ("entry", "program"),
        ).inc(1, entry=entry, program="fresh" if fresh else "cached")
        reg.histogram(
            "fw_dist_dispatch_seconds",
            "host wall time per distributed dispatch (compile included "
            "when the program is fresh)",
            ("entry",),
        ).observe(elapsed, entry=entry)
        engine._observe_solve(reg, f"dist/{entry}", dcfg, res, elapsed)
        # per-collective trace-time counters (dist/collectives/*) and the
        # dist span-duration histograms ride the incremental bridge
        obs_metrics.tracer_to_registry(tracer, reg)
    return out


def solve(
    oracle,
    op: ShardedOperand,
    cfg: FWConfig,
    key: jax.Array,
    alpha0: Optional[jax.Array] = None,
    delta=None,
) -> engine.SolveResult:
    """Distributed twin of ``engine.solve``: same stopping rule, same
    trajectory contract (uniform sampling replays the single-device
    index stream; on a 1-data-shard mesh the sparse lasso run is
    bit-identical). All result leaves come back replicated."""
    _validate.validate_inputs(op, op.y)
    dcfg = dist_config(cfg, op)
    fn, fresh = _traced_solver(op.mesh, oracle, dcfg, op.geom, "solve",
                               alpha0 is not None, None)
    delta = jnp.asarray(cfg.delta if delta is None else delta)
    return _dispatch(
        "solve", fresh, dcfg, fn,
        (*op.matrix_args, op.y, key, _alpha0_arr(op, alpha0), delta),
        layout=op.geom[0],
    )


def solve_with_history(
    oracle,
    op: ShardedOperand,
    cfg: FWConfig,
    key: jax.Array,
    n_iters: int,
    alpha0: Optional[jax.Array] = None,
):
    """Fixed-iteration distributed run recording the objective per step
    (through the telemetry ring — same machinery as the single-device
    ``engine.solve_with_history``)."""
    _validate.validate_inputs(op, op.y)
    dcfg = dist_config(cfg, op)
    hcfg = dataclasses.replace(
        dcfg,
        max_iters=int(n_iters),
        telemetry=obs_telemetry.history_spec(dcfg.telemetry, int(n_iters)),
    )
    fn, fresh = _traced_solver(op.mesh, oracle, hcfg, op.geom, "history",
                               alpha0 is not None, int(n_iters))
    return _dispatch(
        "solve_with_history", fresh, hcfg, fn,
        (*op.matrix_args, op.y, key, _alpha0_arr(op, alpha0)),
        n_iters=int(n_iters),
    )


def solve_batched(
    oracle,
    op: ShardedOperand,
    cfg: FWConfig,
    keys: jax.Array,
    alpha0s: jax.Array,
    deltas: jax.Array,
):
    """Lane-pruned batched solve under ONE shard_map: the engine's
    masked-lane while_loop runs per mesh cell (collectives vmap over the
    lane axis), so converged lanes freeze exactly as on one device.
    Returns ``(batched SolveResult, saved_iters)``."""
    _validate.validate_inputs(op, op.y)
    dcfg = dist_config(cfg, op)
    fn, fresh = _traced_solver(op.mesh, oracle, dcfg, op.geom, "batched",
                               True, None)
    return _dispatch(
        "solve_batched", fresh, dcfg, fn,
        (*op.matrix_args, op.y, keys, jnp.asarray(alpha0s, op.dtype),
         jnp.asarray(deltas)),
        lanes=int(jnp.asarray(deltas).shape[0]),
    )


def fw_path(
    op: ShardedOperand,
    deltas,
    base_cfg: FWConfig,
    seed: int = 0,
    oracle=None,
    report_gap: bool = True,
    *,
    checkpoint_dir=None,
    checkpoint_every: int = 1,
    resume_from=None,
) -> path_lib.PathResult:
    """Sequential regularization path on the mesh (paper §5 protocol,
    l1-rescaling warm starts). Certified duality gaps (oracle ``gap()``
    gradients) ride along by default — ``PathPoint.gap``. Checkpoint /
    resume kwargs behave exactly as on ``path.fw_path`` (the loop state
    lives on the host, so mesh runs snapshot and resume identically)."""
    cfg = dataclasses.replace(base_cfg, report_gap=report_gap)

    def solve_fn(oracle_, Xt_, y_, cfg_, key, alpha0, delta):
        return solve(oracle_, op, cfg_, key, alpha0, delta)

    return path_lib.fw_path(op, op.y, deltas, cfg, seed, oracle,
                            solve_fn=solve_fn,
                            checkpoint_dir=checkpoint_dir,
                            checkpoint_every=checkpoint_every,
                            resume_from=resume_from)


def fw_path_batched(
    op: ShardedOperand,
    deltas,
    base_cfg: FWConfig,
    seed: int = 0,
    lane_width: Optional[int] = None,
    oracle=None,
    report_gap: bool = True,
    *,
    checkpoint_dir=None,
    checkpoint_every: int = 1,
    resume_from=None,
) -> path_lib.PathResult:
    """Lane-pruned batched path on the mesh: chunks of deltas solve as
    lanes of ONE compiled distributed program; converged lanes freeze
    early and the pruning win reports as ``PathResult.saved_iters``."""
    cfg = dataclasses.replace(base_cfg, report_gap=report_gap)

    def solve_batched_fn(oracle_, Xt_, y_, cfg_, keys, alpha0s, d_arr):
        return solve_batched(oracle_, op, cfg_, keys, alpha0s, d_arr)

    return path_lib.fw_path_batched(
        op, op.y, deltas, cfg, seed, lane_width, oracle,
        solve_batched_fn=solve_batched_fn,
        checkpoint_dir=checkpoint_dir,
        checkpoint_every=checkpoint_every,
        resume_from=resume_from,
    )


@functools.lru_cache(maxsize=64)
def _gap_fn(mesh, oracle, cfg: FWConfig, geom):
    """Cached jitted shard_map gap program (one compile per static key,
    like ``_solver`` — alpha and delta stay traced)."""
    spec = cfg.dist

    def body(*args):
        *mat_args, y_l, a, d = args
        Xt_l = _local_matrix(geom, mat_args)
        return engine.oracle_gap(oracle, Xt_l, y_l, a, d, cfg)

    if geom[0] == "dense":
        mat_specs = (P(spec.model_axis, spec.data_axis),)
    else:
        mat_specs = (
            P(spec.data_axis, spec.model_axis, None, None),
        ) * 2
    mapped = shard_map(
        body,
        mesh=mesh,
        in_specs=mat_specs + (P(spec.data_axis), P(), P()),
        out_specs=P(),
        check_rep=False,
    )
    return jax.jit(mapped)


def certified_gap(
    oracle, op: ShardedOperand, alpha: jax.Array, delta, cfg: FWConfig
) -> jax.Array:
    """Standalone certified duality gap at ``alpha`` on the mesh (the
    oracle ``gap()`` protocol run under shard_map)."""
    dcfg = dist_config(cfg, op)
    fn = _gap_fn(op.mesh, oracle, dcfg, op.geom)
    with obs_trace.get_tracer().span("dist/certified_gap", cat="dist"):
        return fn(
            *op.matrix_args, op.y, jnp.asarray(alpha, op.dtype),
            jnp.asarray(delta),
        )
