"""Mesh placement for the FW design matrix (DESIGN.md §Distributed).

One sharding vocabulary for the whole subsystem, over a 2-D
("data", "model") mesh:

    dense Xt (p, m)      P(model, data) tiles of (p_local, m_local)
    sparse block-ELL     per-cell LOCAL SparseBlockMatrix: cell (d, mo)
                         stores the nonzeros of its feature block-range
                         that fall in its sample slice, with LOCAL row
                         indices — laid out as (n_data, n_model * nb_loc,
                         block_size, nnz_max) arrays sharded
                         P(data, model, None, None)
    y (m,)               P(data) slices of m_local
    beta, ColStats       replicated (O(p) per host)

Feature and sample axes zero-pad up to equal per-shard shapes (the
§Padding contract: padded features score exactly 0 and are masked out of
the argmax by global index >= p; padded samples carry y = 0 and all-zero
matrix entries, so every dot they touch contributes exactly 0 — the
logistic oracle masks its per-sample loss on y != 0 for the same
reason). The per-cell nnz budget is the GLOBAL max so all cells share
one static ELL width; on a 1-data-shard mesh the cells are pure block
slices of the input matrix — same slots, same order — which is what
makes uniform-sampling lasso trajectories bit-identical to the
single-device engine.

``load_sharded_matrix`` maps the coo-npz-v1 row-range shard manifest
(sparse/io.py) onto mesh coordinates: the data-slice owner of rows
[d*m_local, (d+1)*m_local) opens ONLY the .npz shards overlapping that
range (``sparse.io.shards_for_rows``), so a multi-host deployment reads
each byte exactly once.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.solver_config import DistSpec
from repro.sparse import io as sparse_io
from repro.sparse.matrix import SparseBlockMatrix


def fw_mesh(n_data: int = 1, n_model: Optional[int] = None, devices=None) -> Mesh:
    """A (data, model) mesh over the available devices. With only
    ``n_data`` given, "model" absorbs the rest of the device count."""
    devices = jax.devices() if devices is None else devices
    if n_model is None:
        n_model = len(devices) // n_data
    if n_data * n_model > len(devices):
        raise ValueError(
            f"mesh ({n_data}, {n_model}) needs {n_data * n_model} devices, "
            f"have {len(devices)}"
        )
    arr = np.asarray(devices[: n_data * n_model]).reshape(n_data, n_model)
    return Mesh(arr, ("data", "model"))


def mesh_spec(mesh: Mesh) -> DistSpec:
    """DistSpec from a mesh: axes named data/model map by name; any other
    2-D mesh maps (first, second) -> (data, model) positionally."""
    names = tuple(mesh.axis_names)
    if len(names) != 2:
        raise ValueError(f"need a 2-D (data, model) mesh, got axes {names}")
    if set(names) == {"data", "model"}:
        da, mo = "data", "model"
    else:
        da, mo = names
    return DistSpec(
        n_data=int(mesh.shape[da]),
        n_model=int(mesh.shape[mo]),
        data_axis=da,
        model_axis=mo,
    )


@dataclasses.dataclass(frozen=True, eq=False)
class ShardedOperand:
    """A mesh-placed (design matrix, targets) pair plus its static
    sharding vocabulary — what ``repro.distributed.driver`` solves on.

    Exactly one of the dense (``Xt``) or sparse (``values``/``rows``)
    layouts is populated. ``p``/``m`` are the TRUE global sizes; the
    stored arrays carry the padded per-shard geometry described in the
    module docstring.
    """

    mesh: Mesh
    spec: DistSpec
    p: int
    m: int
    m_local: int
    y: jax.Array  # (n_data * m_local,) sharded P(data)
    Xt: Optional[jax.Array] = None  # dense (n_model*p_local, n_data*m_local)
    values: Optional[jax.Array] = None  # (n_data, n_model*nb_loc, bs, nnz)
    rows: Optional[jax.Array] = None
    block_size: int = 0
    nnz_max: int = 0
    nb_local: int = 0

    # ---- dense-array compatibility surface (path drivers read these) ----
    @property
    def shape(self) -> Tuple[int, int]:
        return (self.p, self.m)

    @property
    def dtype(self):
        return self.Xt.dtype if self.Xt is not None else self.values.dtype

    @property
    def layout(self) -> str:
        return "dense" if self.Xt is not None else "sparse"

    @property
    def p_local(self) -> int:
        if self.Xt is not None:
            return self.Xt.shape[0] // self.spec.n_model
        return self.nb_local * self.block_size

    @property
    def geom(self) -> tuple:
        """Hashable static-geometry key for the driver's solver cache."""
        return (
            self.layout, self.p, self.m, self.m_local, self.p_local,
            self.block_size, self.nnz_max, self.nb_local,
        )

    @property
    def matrix_args(self) -> tuple:
        return (self.Xt,) if self.Xt is not None else (self.values, self.rows)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _resolve_nnz_budget(counts: np.ndarray, nnz_max: Optional[int]) -> int:
    """Global per-(cell, feature) ELL budget: default to the densest
    count; an insufficient explicit budget is a hard error (entries are
    never silently dropped — the SparseBlockMatrix.from_coo rule)."""
    required = int(counts.max()) if counts.size else 0
    if nnz_max is None:
        nnz_max = max(1, required)
    elif required > nnz_max:
        raise ValueError(
            f"nnz budget {nnz_max} too small: densest (cell, feature) has "
            f"{required} nonzeros (pass nnz_max>={required})"
        )
    return max(1, int(nnz_max))


def _place_y(y: np.ndarray, mesh: Mesh, spec: DistSpec, m_local: int) -> jax.Array:
    y_pad = np.zeros(spec.n_data * m_local, np.asarray(y).dtype)
    y_pad[: y.shape[0]] = np.asarray(y)
    return jax.device_put(
        jnp.asarray(y_pad), NamedSharding(mesh, P(spec.data_axis))
    )


def shard_dense(Xt, y, mesh: Mesh) -> ShardedOperand:
    """Place a dense feature-major (p, m) matrix: zero-pad both axes to
    equal per-shard tiles, device_put as P(model, data)."""
    spec = mesh_spec(mesh)
    Xt = np.asarray(Xt)
    p, m = Xt.shape
    p_loc = _ceil_div(p, spec.n_model)
    m_loc = _ceil_div(m, spec.n_data)
    Xt_pad = np.zeros((spec.n_model * p_loc, spec.n_data * m_loc), Xt.dtype)
    Xt_pad[:p, :m] = Xt
    Xt_dev = jax.device_put(
        jnp.asarray(Xt_pad),
        NamedSharding(mesh, P(spec.model_axis, spec.data_axis)),
    )
    return ShardedOperand(
        mesh=mesh, spec=spec, p=p, m=m, m_local=m_loc,
        y=_place_y(y, mesh, spec, m_loc), Xt=Xt_dev,
    )


def _place_cells(values, rows, y, mesh, spec, p, m, m_loc, bs, nnz, nb_loc):
    sharding = NamedSharding(
        mesh, P(spec.data_axis, spec.model_axis, None, None)
    )
    return ShardedOperand(
        mesh=mesh, spec=spec, p=p, m=m, m_local=m_loc,
        y=_place_y(y, mesh, spec, m_loc),
        values=jax.device_put(jnp.asarray(values), sharding),
        rows=jax.device_put(jnp.asarray(rows), sharding),
        block_size=bs, nnz_max=nnz, nb_local=nb_loc,
    )


def _assemble_cells(
    samp: np.ndarray,
    feat: np.ndarray,
    vals: np.ndarray,
    m: int,
    p: int,
    spec: DistSpec,
    block_size: int,
    nnz_max: Optional[int],
    dtype,
):
    """COO triplets -> per-mesh-cell block-ELL arrays with LOCAL rows.

    Cell (d, mo) receives the entries with ``samp`` in its data slice and
    ``feat`` in its feature block-range; slot order within a feature is
    the stable input order (matching ``SparseBlockMatrix.from_coo``).
    Returns (values, rows, m_local, nb_local, nnz_max) with array shape
    (n_data, n_model * nb_local * block_size, nnz_max) pre-reshape.
    """
    m_loc = _ceil_div(m, spec.n_data)
    nb_loc = _ceil_div(_ceil_div(p, block_size), spec.n_model)
    p_cell = nb_loc * block_size
    n_cells_feat = spec.n_model * p_cell
    d = samp // m_loc
    key = d * n_cells_feat + feat  # feat < p <= n_model * p_cell
    n_keys = spec.n_data * n_cells_feat
    counts = np.bincount(key, minlength=n_keys)
    nnz_max = _resolve_nnz_budget(counts, nnz_max)
    values = np.zeros((spec.n_data, n_cells_feat, nnz_max), dtype)
    rows_out = np.zeros((spec.n_data, n_cells_feat, nnz_max), np.int32)
    order = np.argsort(key, kind="stable")
    k_s = key[order]
    starts = np.zeros(n_keys + 1, np.int64)
    np.cumsum(counts, out=starts[1:])
    slot = np.arange(k_s.size) - starts[k_s]
    d_s = k_s // n_cells_feat
    f_s = k_s % n_cells_feat
    values[d_s, f_s, slot] = vals[order].astype(dtype)
    rows_out[d_s, f_s, slot] = (samp[order] - d_s * m_loc).astype(np.int32)
    shape = (spec.n_data, spec.n_model * nb_loc, block_size, nnz_max)
    return values.reshape(shape), rows_out.reshape(shape), m_loc, nb_loc, nnz_max


def shard_sparse(
    mat: SparseBlockMatrix, y, mesh: Mesh, *, nnz_max: Optional[int] = None
) -> ShardedOperand:
    """Place an in-memory SparseBlockMatrix on the mesh.

    With one data shard the cells are pure BLOCK SLICES of the input
    arrays — identical slots in identical order, preserving bit-level
    score parity with the single-device engine. With n_data > 1 the
    nonzeros re-bucket by (sample slice, feature range) through the COO
    assembler (explicit stored zeros, which carry no information, are
    dropped).
    """
    spec = mesh_spec(mesh)
    p, m = mat.shape
    bs = mat.block_size
    if spec.n_data == 1:
        # same budget contract as the COO path below: an insufficient
        # explicit budget is an error, never a silent grow
        if nnz_max is not None and nnz_max < mat.nnz_max:
            raise ValueError(
                f"nnz budget {nnz_max} too small: densest (cell, feature) "
                f"has {mat.nnz_max} nonzeros (pass nnz_max>={mat.nnz_max})"
            )
        nb_loc = _ceil_div(mat.nblocks, spec.n_model)
        padded = mat.pad_geometry(
            nblocks=spec.n_model * nb_loc, nnz_max=nnz_max
        )
        shape = (1, spec.n_model * nb_loc, bs, padded.nnz_max)
        return _place_cells(
            np.asarray(padded.values).reshape(shape),
            np.asarray(padded.rows).reshape(shape),
            y, mesh, spec, p, m, m, bs, padded.nnz_max, nb_loc,
        )
    vals_np = np.asarray(mat.values).reshape(-1, mat.nnz_max)
    rows_np = np.asarray(mat.rows).reshape(-1, mat.nnz_max)
    feat, slot = np.nonzero(vals_np)
    keep = feat < p
    feat, slot = feat[keep], slot[keep]
    values, rows, m_loc, nb_loc, nnz = _assemble_cells(
        rows_np[feat, slot], feat, vals_np[feat, slot],
        m, p, spec, bs, nnz_max, np.asarray(mat.values).dtype,
    )
    return _place_cells(
        values, rows, y, mesh, spec, p, m, m_loc, bs, nnz, nb_loc
    )


def load_sharded_matrix(
    shard_dir,
    mesh: Mesh,
    *,
    block_size: int = 256,
    nnz_max: Optional[int] = None,
    dtype=np.float32,
) -> ShardedOperand:
    """coo-npz-v1 shard manifest -> mesh-placed operand, reading each
    data slice's row range through ``sparse.io.iter_shards_for_rows`` —
    the per-host load path (a host opens only the files overlapping its
    mesh coordinate's rows). Two streaming passes like
    ``load_shards_as_matrix``: per-(cell, feature) counts size the global
    ELL budget, then the fill pass scatters each shard chunk straight
    into its cell arrays.
    """
    spec = mesh_spec(mesh)
    manifest = sparse_io.read_manifest(shard_dir)
    m, p = int(manifest["m"]), int(manifest["p"])
    m_loc = _ceil_div(m, spec.n_data)
    nb_loc = _ceil_div(_ceil_div(p, block_size), spec.n_model)
    p_cell = nb_loc * block_size
    n_cells_feat = spec.n_model * p_cell

    counts = np.zeros(spec.n_data * n_cells_feat, np.int64)
    y_dtype = np.float32
    for d in range(spec.n_data):
        lo, hi = d * m_loc, min(m, (d + 1) * m_loc)
        for chunk, _ in sparse_io.iter_shards_for_rows(shard_dir, lo, hi):
            y_dtype = chunk.y.dtype  # preserve the stored target dtype
            within = (chunk.rows >= lo) & (chunk.rows < hi)
            counts += np.bincount(
                d * n_cells_feat + chunk.cols[within],
                minlength=counts.shape[0],
            )
    nnz_max = _resolve_nnz_budget(counts, nnz_max)

    values = np.zeros((spec.n_data, n_cells_feat, nnz_max), dtype)
    rows_out = np.zeros((spec.n_data, n_cells_feat, nnz_max), np.int32)
    y = np.zeros(m, y_dtype)
    cursor = np.zeros(spec.n_data * n_cells_feat, np.int64)
    for d in range(spec.n_data):
        lo, hi = d * m_loc, min(m, (d + 1) * m_loc)
        for chunk, off in sparse_io.iter_shards_for_rows(shard_dir, lo, hi):
            y[off : off + chunk.y.shape[0]] = chunk.y
            within = (chunk.rows >= lo) & (chunk.rows < hi)
            cols = chunk.cols[within]
            order = np.argsort(cols, kind="stable")
            cs = cols[order]
            key = d * n_cells_feat + cs
            uniq, first, cnt = np.unique(key, return_index=True, return_counts=True)
            local = np.arange(cs.size) - np.repeat(first, cnt)
            slot = cursor[key] + local
            values[d, cs, slot] = chunk.vals[within][order].astype(dtype)
            rows_out[d, cs, slot] = (chunk.rows[within][order] - lo).astype(np.int32)
            cursor[uniq] += cnt
    shape = (spec.n_data, spec.n_model * nb_loc, block_size, nnz_max)
    return _place_cells(
        values.reshape(shape), rows_out.reshape(shape),
        y, mesh, spec, p, m, m_loc, block_size, nnz_max, nb_loc,
    )
