"""Pure-jnp oracle for the fused residual update (paper eq. 10)."""
from __future__ import annotations

import jax.numpy as jnp


def residual_update_ref(r, y, z, lam, delta_t):
    return (1.0 - lam) * r + lam * (y - delta_t * z)
