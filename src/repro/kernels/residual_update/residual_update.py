"""Pallas TPU kernel: fused FW residual update (paper eq. 10).

    R <- (1 - lam) * R + lam * (y - delta_t * z)

One pass over three m-vectors instead of XLA's potential multi-pass;
scalars (lam, delta_t) live in SMEM. Bandwidth-bound by design — the
point is minimum HBM traffic per FW iteration (read 3m, write m).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(scal_ref, r_ref, y_ref, z_ref, out_ref):
    lam = scal_ref[0]
    dt = scal_ref[1]
    # accumulate in f32 (lam/dt live in SMEM as f32; inputs may be bf16)
    r = r_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    z = z_ref[...].astype(jnp.float32)
    out_ref[...] = ((1.0 - lam) * r + lam * (y - dt * z)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("m_tile", "interpret"))
def residual_update(
    r: jax.Array,  # (m,)
    y: jax.Array,  # (m,)
    z: jax.Array,  # (m,) selected predictor column
    lam: jax.Array,  # () step size
    delta_t: jax.Array,  # () signed vertex scale
    *,
    m_tile: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    m = r.shape[0]
    if m % m_tile != 0:
        m_tile = m
    grid = (m // m_tile,)
    scal = jnp.stack([lam.astype(jnp.float32), delta_t.astype(jnp.float32)])
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, m_tile), lambda i: (0, i)),
            pl.BlockSpec((1, m_tile), lambda i: (0, i)),
            pl.BlockSpec((1, m_tile), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, m_tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, m), r.dtype),
        interpret=interpret,
        name="fw_residual_update",
    )(scal, r.reshape(1, m), y.reshape(1, m), z.reshape(1, m))
    return out.reshape(m)
