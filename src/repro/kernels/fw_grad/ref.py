"""Pure-jnp oracle for the sampled block-gradient kernel.

Given the feature-major design matrix Xt (p, m), residual r (m,), and a
set of sampled block indices blk (nb,), block size bs: compute the FW
scores for the sampled coordinates,

    scores[i*bs + t] = - Xt[blk[i]*bs + t, :] @ r

and the (argmax |score|, score) pair over the sample (paper eq. 9).
"""
from __future__ import annotations

import jax.numpy as jnp


def sampled_scores_ref(Xt, r, blk, block_size: int):
    idx = (blk[:, None] * block_size + jnp.arange(block_size)[None, :]).reshape(-1)
    rows = jnp.take(Xt, idx, axis=0)  # (nb*bs, m)
    return -(rows @ r), idx


def sampled_argmax_ref(Xt, r, blk, block_size: int):
    scores, idx = sampled_scores_ref(Xt, r, blk, block_size)
    j = jnp.argmax(jnp.abs(scores))
    return idx[j], scores[j]
