"""Pallas TPU kernel: sampled column-block FW scores (DESIGN.md §4).

The hot loop of the stochastic FW iteration is computing the sampled
gradient coordinates |z_i^T R| for i in S and reducing to the argmax.
On TPU we sample ALIGNED ROW BLOCKS of the feature-major matrix Xt (p, m)
and drive the gather with a scalar-prefetched block-index array: the
BlockSpec index_map reads blk[i], so each grid step DMAs one
(block_size x m_tile) brick of Xt from HBM into VMEM, computes its
contribution to the scores on the MXU/VPU, and accumulates over m tiles.

Grid: (nb, m_tiles); the score block is revisited across the inner m
dimension (sequential on TPU), giving one HBM pass over the sampled rows
and zero intermediate materialization.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.padding import pad_rows


def _kernel(blk_ref, x_ref, r_ref, out_ref):
    """One (block_size x m_tile) brick: accumulate -X r into scores."""
    j = pl.program_id(1)
    partial = -jnp.dot(
        x_ref[...], r_ref[0, :], preferred_element_type=jnp.float32
    )  # (block_size,)

    @pl.when(j == 0)
    def _init():
        out_ref[0, :] = partial

    @pl.when(j > 0)
    def _acc():
        out_ref[0, :] = out_ref[0, :] + partial


@functools.partial(
    jax.jit, static_argnames=("block_size", "m_tile", "interpret")
)
def sampled_scores(
    Xt: jax.Array,  # (p, m) feature-major design matrix
    r: jax.Array,  # (m,) residual
    blk: jax.Array,  # (nb,) int32 sampled block indices
    *,
    block_size: int = 256,
    m_tile: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Scores (nb * block_size,) for the sampled coordinates.

    Non-divisible shapes are handled by fallbacks rather than asserts:
    ``p % block_size != 0`` zero-pads the trailing rows of ``Xt`` (padded
    coordinates score exactly 0 — callers that must never select them mask
    by global index, see ``ops.fw_vertex``), and ``m % m_tile != 0`` drops
    to a single m tile.
    """
    p, m = Xt.shape
    nb = blk.shape[0]
    Xt = pad_rows(Xt, block_size)
    if m % m_tile != 0:
        m_tile = m  # small-m fallback: single tile
    m_tiles = m // m_tile

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, m_tiles),
        in_specs=[
            pl.BlockSpec((block_size, m_tile), lambda i, j, blk: (blk[i], j)),
            pl.BlockSpec((1, m_tile), lambda i, j, blk: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_size), lambda i, j, blk: (i, 0)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nb, block_size), jnp.float32),
        interpret=interpret,
        name="fw_sampled_scores",
    )(blk, Xt, r.reshape(1, m))
    return out.reshape(nb * block_size)
