"""Jit'd public wrapper for the sampled FW-score kernel.

``fw_vertex(Xt, r, blk)`` returns (i_star, g_star): the sampled FW vertex
(paper eq. 9) — global coordinate index and its gradient value. The Pallas
kernel produces the fused gathered-block scores; the O(kappa) argmax runs
in XLA. On CPU the kernel executes in interpret mode (TPU is the target).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fw_grad.fw_grad import sampled_scores


@functools.partial(
    jax.jit, static_argnames=("block_size", "m_tile", "interpret")
)
def fw_vertex(
    Xt: jax.Array,
    r: jax.Array,
    blk: jax.Array,
    *,
    block_size: int = 256,
    m_tile: int = 512,
    interpret: bool = False,
):
    scores = sampled_scores(
        Xt, r, blk, block_size=block_size, m_tile=m_tile, interpret=interpret
    )
    idx = (blk[:, None] * block_size + jnp.arange(block_size)[None, :]).reshape(-1)
    j = jnp.argmax(jnp.abs(scores))
    return idx[j], scores[j]
