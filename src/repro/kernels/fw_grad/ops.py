"""Jit'd public wrapper for the sampled FW-score kernel.

``fw_vertex(Xt, r, blk)`` returns (i_star, g_star): the sampled FW vertex
(paper eq. 9) — global coordinate index and its gradient value. The Pallas
kernel produces the fused gathered-block scores; the O(kappa) argmax runs
in XLA. On CPU the kernel executes in interpret mode (TPU is the target).

When ``p_valid`` is given (required whenever ``p % block_size != 0``, see
DESIGN.md §Padding), coordinates at global index >= p_valid are zero-padded
rows of ``Xt``; they are excluded from the argmax so the selected vertex is
always a real predictor.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.fw_grad.fw_grad import sampled_scores


@functools.partial(
    jax.jit, static_argnames=("block_size", "m_tile", "interpret", "p_valid")
)
def fw_vertex(
    Xt: jax.Array,
    r: jax.Array,
    blk: jax.Array,
    *,
    block_size: int = 256,
    m_tile: int = 512,
    interpret: bool = False,
    p_valid: Optional[int] = None,
):
    scores = sampled_scores(
        Xt, r, blk, block_size=block_size, m_tile=m_tile, interpret=interpret
    )
    idx = (blk[:, None] * block_size + jnp.arange(block_size)[None, :]).reshape(-1)
    mag = jnp.abs(scores)
    if p_valid is not None:
        mag = jnp.where(idx < p_valid, mag, -1.0)
    j = jnp.argmax(mag)
    return idx[j], scores[j]
