"""Pallas TPU kernel: fused sparse setup pass (DESIGN.md §Sparse).

The sparse twin of ``kernels/colstats``: one sweep over the block-ELL
slots of a ``SparseBlockMatrix`` computing BOTH per-feature statistics
the solver precomputes once (paper §4.2),

    zty[i]    = z_i^T y
    znorm2[i] = ||z_i||^2

fused so the (block_size x nnz_max) values brick is read from HBM
exactly once. The grid walks every feature block in order (a full sweep,
so no scalar prefetch is needed — the index map IS the grid index); the
targets vector y stays VMEM-resident (m floats, small by construction in
the p >> m regime the paper targets) and the per-slot gather + two
reductions run on the VPU. Traffic is O(total stored slots) instead of
the dense kernel's O(p * m).

Padded ELL slots (value 0.0 at row 0) and padded tail features
contribute exactly 0 to both outputs; the caller slices the feature
padding off (same §Padding contract as the dense colstats kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.sparse_grad.sparse_grad import gather_vmem


def _kernel(vals_ref, rows_ref, y_ref, zty_ref, zn2_ref, *, gather_mode):
    """One feature block: gather y at the stored rows, fused dual reduce."""
    vals = vals_ref[0].astype(jnp.float32)  # (block_size, nnz_max)
    rows = rows_ref[0]  # (block_size, nnz_max) int32
    y = y_ref[0].astype(jnp.float32)  # (m,)
    gathered = gather_vmem(y, rows, gather_mode)  # (block_size, nnz_max)
    zty_ref[0, :] = jnp.sum(vals * gathered, axis=1)
    zn2_ref[0, :] = jnp.sum(vals * vals, axis=1)


@functools.partial(jax.jit, static_argnames=("interpret", "gather_mode"))
def sparse_colstats_fused(
    values: jax.Array,  # (nblocks, block_size, nnz_max)
    rows: jax.Array,  # (nblocks, block_size, nnz_max) int32
    y: jax.Array,  # (m,) targets
    *,
    interpret: bool = False,
    gather_mode: str = "take",
):
    """(zty, znorm2) of padded length nblocks * block_size, f32."""
    nblocks, block_size, nnz_max = values.shape
    m = y.shape[0]
    zty, zn2 = pl.pallas_call(
        functools.partial(_kernel, gather_mode=gather_mode),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((1, block_size, nnz_max), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, block_size, nnz_max), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_size), lambda i: (i, 0)),
            pl.BlockSpec((1, block_size), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks, block_size), jnp.float32),
            jax.ShapeDtypeStruct((nblocks, block_size), jnp.float32),
        ],
        interpret=interpret,
        name="fw_sparse_colstats",
    )(values, rows, y.reshape(1, m))
    return zty.reshape(-1), zn2.reshape(-1)
