"""Pure-jnp oracle for the sparse sampled block-gradient kernel.

Given the block-ELL arrays of a SparseBlockMatrix, residual r (m,), and
sampled block indices blk (nb,): gather the referenced residual entries
and segment-dot,

    scores[i*bs + t] = - sum_k values[blk[i], t, k] * r[rows[blk[i], t, k]]

This is also the XLA fallback the solver runs off-TPU (the Pallas kernel
targets the scalar-prefetch DMA path; interpret mode is for validation).
"""
from __future__ import annotations

import jax.numpy as jnp


def sparse_sampled_scores_ref(values, rows, r, blk):
    vals = jnp.take(values, blk, axis=0).astype(jnp.float32)  # (nb, bs, k)
    idx = jnp.take(rows, blk, axis=0)  # (nb, bs, k)
    gathered = jnp.take(r.astype(jnp.float32), idx, axis=0)
    scores = -jnp.sum(vals * gathered, axis=2)  # (nb, bs)
    return scores.reshape(-1)
