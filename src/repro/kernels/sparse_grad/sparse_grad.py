"""Pallas TPU kernel: sampled sparse-block FW scores (DESIGN.md §Sparse).

The sparse twin of ``kernels/fw_grad``: the hot loop of the stochastic FW
iteration scores the sampled coordinates |z_i^T R| and reduces to the
argmax, but here z_i lives in the block-ELL layout of
``repro.sparse.matrix.SparseBlockMatrix`` — a (block_size, nnz_max) brick
of values plus the matching sample indices per feature block.

The sampled block ids are scalar-prefetched exactly like the dense
kernel: the BlockSpec index_map reads ``blk[i]``, so grid step i DMAs ONE
(block_size x nnz_max) values brick and its row-index brick from HBM,
gathers the referenced residual entries from the VMEM-resident residual
(m floats — small by construction in the p >> m regime the paper
targets), and segment-dots them on the VPU. Per grid step the kernel
reads O(block_size * nnz_max) instead of the dense kernel's
O(block_size * m): at col_density 0.002 that is a ~500x traffic cut.

Padded ELL slots carry value 0.0 at row 0, and padded tail FEATURES are
all-zero rows, so both score exactly 0 and the caller masks global
indices >= p out of the argmax (same §Padding contract as fw_grad).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def gather_vmem(vec, rows, gather_mode: str):
    """Read ``vec[rows]`` inside a kernel: (block_size, nnz_max) values of
    the VMEM-resident (m,) vector at the stored row indices.

    'take' is the direct gather; 'onehot' rewrites it as a one-hot matmul
    (rows == iota compare, then MXU dot) — the fallback for TPU targets
    where the VMEM gather fails to lower. Shared by sparse_grad and
    sparse_colstats so both kernels survive the same hardware.
    """
    if gather_mode == "take":
        return jnp.take(vec, rows, axis=0)
    if gather_mode == "onehot":
        bs, nnz = rows.shape
        m = vec.shape[0]
        onehot = (
            rows.reshape(bs * nnz, 1)
            == jax.lax.broadcasted_iota(jnp.int32, (bs * nnz, m), 1)
        ).astype(vec.dtype)
        return (onehot @ vec).reshape(bs, nnz)
    raise ValueError(f"unknown gather_mode {gather_mode!r} (take|onehot)")


def _kernel(blk_ref, vals_ref, rows_ref, r_ref, out_ref, *, gather_mode):
    """One sampled block: gather residual entries, segment-dot, negate."""
    vals = vals_ref[0].astype(jnp.float32)  # (block_size, nnz_max)
    rows = rows_ref[0]  # (block_size, nnz_max) int32
    r = r_ref[0].astype(jnp.float32)  # (m,)
    gathered = gather_vmem(r, rows, gather_mode)  # (block_size, nnz_max)
    out_ref[0, :] = -jnp.sum(vals * gathered, axis=1)


@functools.partial(jax.jit, static_argnames=("interpret", "gather_mode"))
def sparse_sampled_scores(
    values: jax.Array,  # (nblocks, block_size, nnz_max)
    rows: jax.Array,  # (nblocks, block_size, nnz_max) int32
    r: jax.Array,  # (m,) residual
    blk: jax.Array,  # (nb,) int32 sampled block indices
    *,
    interpret: bool = False,
    gather_mode: str = "take",
) -> jax.Array:
    """Scores (nb * block_size,) for the sampled feature blocks."""
    _, block_size, nnz_max = values.shape
    nb = blk.shape[0]
    m = r.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block_size, nnz_max), lambda i, blk: (blk[i], 0, 0)),
            pl.BlockSpec((1, block_size, nnz_max), lambda i, blk: (blk[i], 0, 0)),
            pl.BlockSpec((1, m), lambda i, blk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_size), lambda i, blk: (i, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, gather_mode=gather_mode),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nb, block_size), jnp.float32),
        interpret=interpret,
        name="fw_sparse_sampled_scores",
    )(blk, values, rows, r.reshape(1, m))
    return out.reshape(nb * block_size)
