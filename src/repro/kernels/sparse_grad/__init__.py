from repro.kernels.sparse_grad.sparse_grad import sparse_sampled_scores
from repro.kernels.sparse_grad.ref import sparse_sampled_scores_ref

__all__ = ["sparse_sampled_scores", "sparse_sampled_scores_ref"]
