"""Pallas TPU kernel: fused setup pass over the design matrix (§4.2).

Computes both per-predictor statistics the solver precomputes once,

    zty[i]    = Xt[i, :] @ y
    znorm2[i] = ||Xt[i, :]||^2

in a single sweep over Xt (one HBM read instead of two).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.padding import pad_rows


def _kernel(x_ref, y_ref, zty_ref, zn2_ref):
    j = pl.program_id(1)
    x = x_ref[...]
    dot = jnp.dot(x, y_ref[0, :], preferred_element_type=jnp.float32)
    sq = jnp.sum(x.astype(jnp.float32) * x, axis=1)

    @pl.when(j == 0)
    def _init():
        zty_ref[0, :] = dot
        zn2_ref[0, :] = sq

    @pl.when(j > 0)
    def _acc():
        zty_ref[0, :] = zty_ref[0, :] + dot
        zn2_ref[0, :] = zn2_ref[0, :] + sq


@functools.partial(jax.jit, static_argnames=("p_tile", "m_tile", "interpret"))
def colstats(
    Xt: jax.Array,  # (p, m)
    y: jax.Array,  # (m,)
    *,
    p_tile: int = 256,
    m_tile: int = 512,
    interpret: bool = False,
):
    p, m = Xt.shape
    # zero-pad trailing rows; their stats are 0 and sliced off below
    Xt = pad_rows(Xt, p_tile)
    p_pad = Xt.shape[0]
    if m % m_tile != 0:
        m_tile = m
    grid = (p_pad // p_tile, m // m_tile)
    zty, zn2 = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((p_tile, m_tile), lambda i, j: (i, j)),
            pl.BlockSpec((1, m_tile), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, p_tile), lambda i, j: (0, i)),
            pl.BlockSpec((1, p_tile), lambda i, j: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, p_pad), jnp.float32),
            jax.ShapeDtypeStruct((1, p_pad), jnp.float32),
        ],
        interpret=interpret,
        name="fw_colstats",
    )(Xt, y.reshape(1, m))
    return zty.reshape(p_pad)[:p], zn2.reshape(p_pad)[:p]
