"""Pure-jnp oracle for the fused column-stats pass."""
from __future__ import annotations

import jax.numpy as jnp


def colstats_ref(Xt, y):
    return Xt @ y, jnp.sum(Xt.astype(jnp.float32) * Xt, axis=1)
