"""Pallas TPU kernels for the FW-Lasso hot loop (validated in interpret
mode on CPU; enabled on real TPUs via FWConfig/solver flags).

fw_grad:          sampled column-block scores (scalar-prefetch gather)
residual_update:  fused R <- (1-lam) R + lam (y - dt z)
colstats:         fused z^T y and ||z||^2 setup pass
sparse_grad:      sampled block-ELL scores (sparse twin of fw_grad)
sparse_colstats:  fused sparse z^T y and ||z||^2 (sparse twin of colstats)
fused_step:       K fused FW iterations per launch, co-state VMEM-resident
"""
from repro.kernels.fw_grad.ops import fw_vertex
from repro.kernels.fw_grad.fw_grad import sampled_scores
from repro.kernels.residual_update.residual_update import residual_update
from repro.kernels.colstats.colstats import colstats
from repro.kernels.sparse_grad.sparse_grad import sparse_sampled_scores
from repro.kernels.sparse_colstats.sparse_colstats import sparse_colstats_fused
from repro.kernels.fused_step.fused_step import (
    dense_fused_chunk,
    sparse_fused_chunk,
)

__all__ = [
    "fw_vertex",
    "sampled_scores",
    "residual_update",
    "colstats",
    "sparse_sampled_scores",
    "sparse_colstats_fused",
    "dense_fused_chunk",
    "sparse_fused_chunk",
]
