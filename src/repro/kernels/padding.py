"""Shared feature-row zero-padding for the kernel grid geometry.

All kernel grids tile the feature axis in fixed-size bricks; non-divisible
p is handled by zero-padding trailing rows rather than asserting
(DESIGN.md §Padding). Padded rows score exactly 0 in the sampled-gradient
kernel and are masked out of the argmax, so they are never selected. This
is the ONE definition of that padding — the solver pre-pads once per solve
with it, and the kernel wrappers apply it defensively for direct calls.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pad_rows(Xt: jax.Array, multiple: int) -> jax.Array:
    """Zero-pad Xt's leading (feature) axis up to a multiple of ``multiple``."""
    pad_p = -Xt.shape[0] % multiple
    if pad_p:
        Xt = jnp.pad(Xt, ((0, pad_p), (0, 0)))
    return Xt
