"""Pure-XLA reference of the fused K-step chunk (kernels/fused_step).

Mirrors the megakernel's input/output contract — pregenerated index
stream, pregathered column statistics, chunk-start co-state in, per-step
records + final co-state out — with plain jnp gathers instead of the
scalar-prefetched BlockSpec DMA, so kernel-vs-ref parity can be pinned
without the solver engine in the loop (tests/test_engine.py). The scalar
algebra comes from the SAME oracle ``fused_*`` methods the kernel
executes.

Note the engine's own non-kernel fused executor is a fori_loop over the
unfused ``engine.step`` (bit-exact by construction); this module is the
kernel-shaped reference, not the production CPU path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _chunk_ref(score_fn, update_fn, oracle, y, resid, scal, idx, zty_s,
               zn2_s, alpha_s, k0, delta, *, eps_den, gap_rtol,
               refresh_every, max_iters):
    K, kappa = idx.shape
    y = y.astype(jnp.float32)
    resid = resid.astype(jnp.float32)
    delta = jnp.asarray(delta, jnp.float32)
    if alpha_s is None:
        alpha_s = jnp.zeros((K, kappa), jnp.float32)

    def body(s, carry):
        resid, scal3, P, ladd, lidx, recs = carry
        i_stars, lams, delta_ts, no_progs = recs
        ids = idx[s]
        raw, ctx = score_fn(ids, resid)  # (kappa,) linear scores -z^T R
        if oracle.fused_needs_alpha:
            corr = jnp.sum(
                jnp.where(lidx[None, :] == ids[:, None], ladd[None, :], 0.0),
                axis=1,
            )
            a = P * alpha_s[s] + corr
            sel = raw + oracle.fused_score_shift(a)
        else:
            a = jnp.zeros_like(raw)
            sel = raw
        j = jnp.argmax(jnp.abs(sel))
        i_star, g_raw, g_sel, a_star = ids[j], raw[j], sel[j], a[j]
        delta_t = -delta * jnp.sign(g_sel)
        lam, no_prog, g_lin = oracle.fused_line_search(
            scal3, g_raw, g_sel, a_star, delta_t, zty_s[s, j], zn2_s[s, j],
            eps_den, gap_rtol,
        )
        k_glob = k0 + s
        active = k_glob < max_iters
        one_m = 1.0 - lam
        new_resid = update_fn(resid, y, ctx, j, lam, delta_t)
        ns, nf, nq = oracle.fused_scalar_update(
            scal3, g_lin, a_star, lam, delta_t, zty_s[s, j], zn2_s[s, j]
        )
        refresh = (k_glob % refresh_every) == (refresh_every - 1)
        v = y - new_resid
        ns = jnp.where(refresh, jnp.dot(v, v), ns)
        nf = jnp.where(refresh, jnp.dot(v, y), nf)
        keep = lambda new, old: jnp.where(active, new, old)
        carry = (
            keep(new_resid, resid),
            (keep(ns, scal3[0]), keep(nf, scal3[1]), keep(nq, scal3[2])),
            keep(P * one_m, P),
            keep(ladd.at[s].set(lam * delta_t) * jnp.where(
                jnp.arange(K) == s, 1.0, one_m), ladd),
            keep(lidx.at[s].set(i_star), lidx),
            (
                i_stars.at[s].set(i_star),
                lams.at[s].set(lam),
                delta_ts.at[s].set(delta_t),
                no_progs.at[s].set(no_prog),
            ),
        )
        return carry

    scal3 = tuple(jnp.asarray(x, jnp.float32) for x in scal)
    recs0 = (
        jnp.zeros((K,), jnp.int32),
        jnp.zeros((K,), jnp.float32),
        jnp.zeros((K,), jnp.float32),
        jnp.zeros((K,), jnp.bool_),
    )
    carry = (
        resid,
        scal3,
        jnp.ones((), jnp.float32),
        jnp.zeros((K,), jnp.float32),
        jnp.full((K,), -1, jnp.int32),
        recs0,
    )
    resid, scal3, _, _, _, recs = jax.lax.fori_loop(0, K, body, carry)
    i_stars, lams, delta_ts, no_progs = recs
    return i_stars, lams, delta_ts, no_progs, resid, scal3


def dense_fused_chunk_ref(Xt, y, resid, scal, idx, zty_s, zn2_s, alpha_s,
                          k0, delta, *, oracle, eps_den, gap_rtol,
                          refresh_every, max_iters, **_):
    """XLA mirror of ``fused_step.dense_fused_chunk`` (same returns)."""

    def score(ids, r):
        rows = jnp.take(Xt, ids, axis=0).astype(jnp.float32)  # (kappa, m)
        return -(rows @ r), rows

    def update(r, yv, rows, j, lam, delta_t):
        return (1.0 - lam) * r + lam * (yv - delta_t * rows[j])

    return _chunk_ref(score, update, oracle, y, resid, scal, idx, zty_s,
                      zn2_s, alpha_s, k0, delta, eps_den=eps_den,
                      gap_rtol=gap_rtol, refresh_every=refresh_every,
                      max_iters=max_iters)


def sparse_fused_chunk_ref(values, rows, y, resid, scal, idx, zty_s, zn2_s,
                           alpha_s, k0, delta, *, oracle, eps_den, gap_rtol,
                           refresh_every, max_iters, **_):
    """XLA mirror of ``fused_step.sparse_fused_chunk`` over the block-ELL
    slot arrays (same returns)."""
    bs = values.shape[1]

    def score(ids, r):
        vals = values[ids // bs, ids % bs].astype(jnp.float32)  # (kappa, nnz)
        rws = rows[ids // bs, ids % bs]
        raw = -jnp.sum(vals * jnp.take(r, rws, axis=0), axis=1)
        return raw, (vals, rws)

    def update(r, yv, ctx, j, lam, delta_t):
        vals, rws = ctx
        out = (1.0 - lam) * r + lam * yv
        return out.at[rws[j]].add((-lam * delta_t) * vals[j])

    return _chunk_ref(score, update, oracle, y, resid, scal, idx, zty_s,
                      zn2_s, alpha_s, k0, delta, eps_den=eps_den,
                      gap_rtol=gap_rtol, refresh_every=refresh_every,
                      max_iters=max_iters)
