"""Fused multi-step FW megakernel: K iterations per launch with the
co-state and scalar recursions VMEM-resident (DESIGN.md §Perf)."""
from repro.kernels.fused_step.fused_step import (
    dense_fused_chunk,
    sparse_fused_chunk,
)
from repro.kernels.fused_step.ref import (
    dense_fused_chunk_ref,
    sparse_fused_chunk_ref,
)

__all__ = [
    "dense_fused_chunk",
    "sparse_fused_chunk",
    "dense_fused_chunk_ref",
    "sparse_fused_chunk_ref",
]
