"""Wall-clock timing helpers used by benchmarks and the runtime monitor."""
from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, field


@dataclass
class Timer:
    """Accumulating timer. ``with timer:`` adds elapsed seconds to .total."""

    total: float = 0.0
    count: int = 0
    _t0: float = field(default=0.0, repr=False)

    def __enter__(self) -> "Timer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.total += time.perf_counter() - self._t0
        self.count += 1

    @property
    def mean(self) -> float:
        return self.total / max(self.count, 1)


@contextmanager
def timed(label: str, sink=None):
    """Context manager printing (or collecting) elapsed time."""
    t0 = time.perf_counter()
    yield
    dt = time.perf_counter() - t0
    if sink is not None:
        sink[label] = sink.get(label, 0.0) + dt
    else:
        print(f"[timed] {label}: {dt:.4f}s")
