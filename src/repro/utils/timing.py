"""Wall-clock timing helpers used by benchmarks and the runtime monitor.

``timed`` no longer prints to stdout by default: every timed block lands
on the active ``repro.obs.trace`` tracer as a completed ``"timed"`` span
(so benchmark phases show up in the same Perfetto artifact as the solver
spans), and ``sink`` optionally ALSO accumulates into a ``Timer`` or a
legacy ``{label: seconds}`` dict. Pass ``verbose=True`` for the old
print behavior — interleaving timings with CSV rows on stdout is now an
explicit opt-in, not the default.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, field

from repro.obs import trace as obs_trace


@dataclass
class Timer:
    """Accumulating timer. ``with timer:`` adds elapsed seconds to .total."""

    total: float = 0.0
    count: int = 0
    _t0: float = field(default=0.0, repr=False)

    def __enter__(self) -> "Timer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.total += time.perf_counter() - self._t0
        self.count += 1

    @property
    def mean(self) -> float:
        return self.total / max(self.count, 1)

    def add(self, seconds: float, count: int = 1) -> "Timer":
        self.total += seconds
        self.count += count
        return self

    def merge(self, other: "Timer") -> "Timer":
        """Fold another Timer into this one — benchmarks aggregate
        per-arm timers with this instead of hand-rolled float dicts."""
        return self.add(other.total, other.count)


@contextmanager
def timed(label: str, sink=None, verbose: bool = False):
    """Time a block onto the active tracer (a ``ph:"X"`` span, cat
    ``"timed"``). ``sink`` may be a ``Timer`` or a dict mapping label ->
    accumulated seconds (the legacy shape)."""
    t0 = time.perf_counter()
    yield
    dt = time.perf_counter() - t0
    obs_trace.get_tracer().complete(label, t0, dt, cat="timed")
    if isinstance(sink, Timer):
        sink.add(dt)
    elif sink is not None:
        sink[label] = sink.get(label, 0.0) + dt
    if verbose:
        print(f"[timed] {label}: {dt:.4f}s")
