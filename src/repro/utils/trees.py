"""Pytree helpers (param counting, byte accounting) shared across subsystems."""
from __future__ import annotations

import jax
import numpy as np


def tree_param_count(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    total = 0
    for x in jax.tree.leaves(tree):
        dt = getattr(x, "dtype", None)
        itemsize = np.dtype(dt).itemsize if dt is not None else 4
        total += int(np.prod(x.shape)) * itemsize
    return total
