"""Small shared utilities: timing, rng, pytree helpers."""
from repro.utils.timing import Timer, timed
from repro.utils.trees import tree_bytes, tree_param_count

__all__ = ["Timer", "timed", "tree_bytes", "tree_param_count"]
